//! Quickstart: fine-tune a tiny OPT-style model with ZO2 in a dozen lines.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the rust analogue of the paper's Fig. 6b API: configure, loop
//! `train_step`, then apply the final deferred update (`flush_updates`)
//! before evaluating.

use anyhow::Result;
use zo2::data::SyntheticCorpus;
use zo2::runtime::Runtime;
use zo2::util::fmt_mb;
use zo2::zo::{Zo2Engine, Zo2Options, ZoConfig};

fn main() -> Result<()> {
    // 1. Load the AOT-compiled artifacts for a config ("tiny": 2 blocks).
    let rt = Runtime::load_config("tiny")?;
    let (b, t, v) = {
        let c = &rt.manifest().config;
        (c.batch, c.seq_len, c.vocab)
    };

    // 2. Build the ZO2 engine: blocks live on the "CPU" tier and stream
    //    through the reusable device buffer with the dynamic scheduler.
    let mut engine = Zo2Engine::new(
        rt,
        ZoConfig { lr: 2e-3, eps: 1e-2, seed: 42 },
        Zo2Options::default(),
    )?;

    // 3. Train on a synthetic corpus.
    let mut corpus = SyntheticCorpus::new(v, 7);
    for step in 0..30 {
        let batch = corpus.sample(b, t);
        let stats = engine.train_step(&batch.ids)?;
        if step % 5 == 0 {
            println!("step {step:>3}  loss {:.4}  g {:+.3e}", stats.loss(), stats.g);
        }
    }

    // 4. Final deferred update + evaluation.
    engine.flush_updates()?;
    let batch = corpus.sample(b, t);
    let (eval_loss, _) = engine.eval(&batch.ids)?;
    let tr = engine.transfers.lock().unwrap();
    println!(
        "eval loss {:.4} | device peak {} MB | interconnect traffic {} MB ({} uploads)",
        eval_loss,
        fmt_mb(engine.device.peak()),
        fmt_mb(tr.total_bytes()),
        tr.h2d.ops,
    );
    Ok(())
}
