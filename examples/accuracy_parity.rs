//! Table 3 reproduction driver: fine-tune on the seven synthetic benchmark
//! stand-ins (SST-2, RTE, CB, BoolQ, WSC, WIC, MultiRC) with MeZO and with
//! ZO2 and print both accuracy rows — they must be **identical**, because
//! ZO2 is bit-exact w.r.t. MeZO (the RNG state manager, §5.1).
//!
//!     make artifacts && cargo run --release --example accuracy_parity
//!       [-- --steps 40 --eval-batches 8]

use anyhow::Result;
use zo2::data::table3_tasks;
use zo2::runtime::Runtime;
use zo2::util::cli::Args;
use zo2::zo::{MezoEngine, Zo2Engine, Zo2Options, ZoConfig};

fn main() -> Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 40);
    let eval_batches = args.get_usize("eval-batches", 8);
    let cfg = ZoConfig { lr: 2e-3, eps: 1e-2, seed: 31337 };

    let mut mezo_row = Vec::new();
    let mut zo2_row = Vec::new();
    let mut names = Vec::new();

    let task_names: Vec<String> = {
        let rt = Runtime::load_config("tiny")?;
        table3_tasks(rt.manifest().config.vocab, 1).iter().map(|t| t.name.clone()).collect()
    };

    for (idx, tname) in task_names.iter().enumerate() {
        names.push(tname.clone());
        // MeZO.
        let rt = Runtime::load_config("tiny")?;
        let (b, t, v) = {
            let c = &rt.manifest().config;
            (c.batch, c.seq_len, c.vocab)
        };
        let mut task = table3_tasks(v, 1).swap_remove(idx);
        let mut engine = MezoEngine::new(rt, cfg)?;
        for _ in 0..steps {
            let (batch, _) = task.sample(b, t);
            engine.train_step(&batch.ids)?;
        }
        let mut acc = 0.0;
        let mut eval_task = {
            // fresh task with same seed -> same distribution, fresh stream
            table3_tasks(v, 2).swap_remove(idx)
        };
        for _ in 0..eval_batches {
            let (batch, labels) = eval_task.sample(b, t);
            let (_, logits) = engine.eval(&batch.ids)?;
            acc += task.accuracy(&logits, v, &labels);
        }
        mezo_row.push(100.0 * acc / eval_batches as f64);

        // ZO2 — identical data streams (same seeds).
        let rt = Runtime::load_config("tiny")?;
        let mut task = table3_tasks(v, 1).swap_remove(idx);
        let mut engine = Zo2Engine::new(rt, cfg, Zo2Options::default())?;
        for _ in 0..steps {
            let (batch, _) = task.sample(b, t);
            engine.train_step(&batch.ids)?;
        }
        engine.flush_updates()?;
        let mut acc = 0.0;
        let mut eval_task = table3_tasks(v, 2).swap_remove(idx);
        for _ in 0..eval_batches {
            let (batch, labels) = eval_task.sample(b, t);
            let (_, logits) = engine.eval(&batch.ids)?;
            acc += task.accuracy(&logits, v, &labels);
        }
        zo2_row.push(100.0 * acc / eval_batches as f64);
    }

    println!("\nTable 3 (synthetic stand-ins, tiny config, {steps} ZO steps):");
    print!("{:<8}", "Method");
    for n in &names {
        print!("{n:>9}");
    }
    println!();
    print!("{:<8}", "MeZO");
    for a in &mezo_row {
        print!("{a:>8.1} ");
    }
    println!();
    print!("{:<8}", "ZO2");
    for a in &zo2_row {
        print!("{a:>8.1} ");
    }
    println!();

    let identical = mezo_row
        .iter()
        .zip(&zo2_row)
        .all(|(a, b)| (a - b).abs() < f64::EPSILON);
    println!(
        "\nrows identical: {} (paper Table 3: ZO2 == MeZO on every benchmark)",
        if identical { "YES" } else { "NO — PARITY VIOLATION" }
    );
    std::process::exit(if identical { 0 } else { 1 });
}
