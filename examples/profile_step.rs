//! Phase-level profiler for one ZO2 training step (perf pass tooling).
//!
//!     cargo run --release --example profile_step -- --config gpt2-100m

use anyhow::Result;
use zo2::rng::GaussianRng;
use zo2::runtime::{lit_f32, lit_i32, lit_scalar, lit_to_f32, Runtime};
use zo2::util::cli::Args;

fn t<R>(label: &str, f: impl FnOnce() -> R) -> R {
    let t0 = std::time::Instant::now();
    let r = f();
    println!("{label:<28} {:>9.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    r
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let rt = Runtime::load_config(&args.get_or("config", "gpt2-100m"))?;
    let m = rt.manifest().clone();
    let (b, tt, nb) = (m.config.batch as i64, m.config.seq_len as i64, m.block.size);
    t("compile_all", || rt.compile_all())?;

    let mut rng = GaussianRng::new(1, 1);
    let mut bucket = vec![0.0f32; nb];
    rng.fill_gaussian(&mut bucket);
    let mut z = vec![0.0f32; nb];

    t("fill_gaussian 1 bucket", || rng.fill_gaussian(&mut z));
    let lit_b = t("lit_f32 bucket", || lit_f32(&bucket, &[nb as i64]).unwrap());
    let lit_z = t("lit_f32 z", || lit_f32(&z, &[nb as i64]).unwrap());

    let h = vec![0.01f32; (b * tt) as usize * m.config.d_model];
    let hp = lit_f32(&h, &[b, tt, m.config.d_model as i64])?;
    let hm = hp.clone();
    let ids: Vec<i32> = (0..b * tt).map(|i| (i % 100) as i32).collect();
    let ids_lit = lit_i32(&ids, &[b, tt])?;

    // Warm-up once (first exec includes lazy init).
    let inputs = [
        lit_b.clone(), lit_z.clone(), lit_scalar(0.0), lit_scalar(1e-4),
        lit_z.clone(), lit_scalar(1e-3), hp.clone(), hm.clone(),
    ];
    t("block_step warmup", || rt.run("block_step", &inputs))?;
    for i in 0..3 {
        let out = t(&format!("block_step run {i}"), || rt.run("block_step", &inputs))?;
        if i == 0 {
            t("lit_to_f32 bucket out", || lit_to_f32(&out[0]).unwrap());
        }
    }
    let up = [lit_b.clone(), lit_z.clone(), lit_scalar(1e-4), lit_scalar(0.5)];
    t("update_block warmup", || rt.run("update_block", &up))?;
    t("update_block run", || rt.run("update_block", &up))?;

    let einputs = [
        lit_f32(&vec![0.01f32; m.embed.size], &[m.embed.size as i64])?,
        lit_f32(&vec![0.01f32; m.embed.size], &[m.embed.size as i64])?,
        lit_scalar(0.0), lit_scalar(1e-4),
        lit_f32(&vec![0.01f32; m.embed.size], &[m.embed.size as i64])?,
        lit_scalar(1e-3), ids_lit.clone(),
    ];
    t("embed_step warmup", || rt.run("embed_step", &einputs))?;
    t("embed_step run", || rt.run("embed_step", &einputs))?;

    let hinputs = [
        lit_f32(&vec![0.01f32; m.head.size], &[m.head.size as i64])?,
        lit_f32(&vec![0.01f32; m.head.size], &[m.head.size as i64])?,
        lit_scalar(0.0), lit_scalar(1e-4),
        lit_f32(&vec![0.01f32; m.head.size], &[m.head.size as i64])?,
        lit_scalar(1e-3), hp.clone(), hm.clone(), ids_lit,
    ];
    t("head_step warmup", || rt.run("head_step", &hinputs))?;
    t("head_step run", || rt.run("head_step", &hinputs))?;
    Ok(())
}
