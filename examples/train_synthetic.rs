//! End-to-end driver (DESIGN.md "End-to-end validation"): train the
//! ~100M-parameter `gpt2-100m` config with ZO2 for a few hundred steps on
//! the synthetic corpus and log the loss curve.
//!
//!     make artifacts && cargo run --release --example train_synthetic
//!       [-- --steps 200 --lr 2e-4 --eps 1e-3 --out loss_curve.csv]
//!
//! Every layer is exercised for real: Pallas dual-matmul kernels inside the
//! AOT block executables (L1/L2), and the full ZO2 machinery (L3): host-tier
//! blocks, reusable slots, three-stream overlap, deferred updates, RNG state
//! management.  ZO convergence is slow by nature (the paper fine-tunes
//! pretrained checkpoints; we train from scratch), so the pass criterion is
//! a clearly falling loss, not convergence to the corpus entropy floor.

use anyhow::Result;
use zo2::data::SyntheticCorpus;
use zo2::runtime::Runtime;
use zo2::telemetry::Series;
use zo2::util::cli::Args;
use zo2::util::fmt_mb;
use zo2::zo::{Zo2Engine, Zo2Options, ZoConfig};

fn main() -> Result<()> {
    let args = Args::from_env();
    let config = args.get_or("config", "gpt2-100m");
    let steps = args.get_usize("steps", 200);
    let lr = args.get_f64("lr", 3e-4) as f32;
    let eps = args.get_f64("eps", 1e-3) as f32;
    let out = args.get_or("out", "loss_curve.csv");

    let rt = Runtime::load_config(&config)?;
    rt.manifest().validate()?;
    let (b, t, v, params) = {
        let c = &rt.manifest().config;
        (c.batch, c.seq_len, c.vocab, c.total_params)
    };
    println!(
        "config {config}: {:.1}M params, batch {b} x seq {t}, vocab {v}",
        params as f64 / 1e6
    );
    println!("compiling executables…");
    let t0 = std::time::Instant::now();
    rt.compile_all()?;
    println!("compiled in {:.1}s", t0.elapsed().as_secs_f64());

    let mut engine = Zo2Engine::new(rt, ZoConfig { lr, eps, seed: 42 }, Zo2Options::default())?;
    let mut corpus = SyntheticCorpus::new(v, 0xE2E);
    println!("corpus entropy floor ≈ {:.3} nats", corpus.entropy_floor());

    let mut losses = Series::new("loss");
    let mut tokens = 0usize;
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let batch = corpus.sample(b, t);
        let stats = engine.train_step(&batch.ids)?;
        tokens += b * t;
        losses.push(step as f64, stats.loss() as f64);
        if step % 10 == 0 || step + 1 == steps {
            println!(
                "step {:>4}/{steps}  loss {:.4}  g {:+.3e}  {:.0} tok/s  elapsed {:.0}s",
                step,
                stats.loss(),
                stats.g,
                tokens as f64 / t0.elapsed().as_secs_f64(),
                t0.elapsed().as_secs_f64()
            );
        }
    }
    engine.flush_updates()?;

    let batch = corpus.sample(b, t);
    let (eval_loss, _) = engine.eval(&batch.ids)?;
    let first10 = losses.points[..10.min(losses.points.len())]
        .iter()
        .map(|p| p.1)
        .sum::<f64>()
        / 10f64.min(losses.points.len() as f64);
    let last10 = losses.tail_mean(10);

    std::fs::write(&out, losses.to_csv())?;
    let tr = engine.transfers.lock().unwrap();
    println!("--------------------------------------------------------------");
    println!("loss:   first-10 mean {first10:.4} -> last-10 mean {last10:.4}  (eval {eval_loss:.4})");
    println!("speed:  {:.0} tokens/s over {} steps", tokens as f64 / t0.elapsed().as_secs_f64(), steps);
    println!(
        "memory: device peak {} MB ({} resident embed+head + {} block slots)",
        fmt_mb(engine.device.peak()),
        fmt_mb(((engine.params.embed.len() + engine.params.head.len()) * 4) as u64),
        engine.opts.slots
    );
    println!("trans:  {} MB over {} block uploads", fmt_mb(tr.total_bytes()), tr.h2d.ops);
    println!("curve written to {out}");
    if last10 < first10 - 0.01 {
        println!("RESULT: loss decreased — end-to-end stack verified");
    } else {
        println!("RESULT: WARNING loss did not decrease");
    }
    Ok(())
}
