//! Beyond the paper's headline: **OPT-175B on a 64 GB-DRAM workstation.**
//!
//!     cargo run --release --example opt175b_64gb_dram
//!
//! The paper's two-tier system assumes the CPU side holds every master copy
//! (~350 GB for fp16, ~700 GB for fp32) — a DGX-class assumption.  The
//! three-tier extension spills the overflow to NVMe and streams it through
//! a DRAM staging window, so the same 18 GB-GPU scenario runs on a
//! workstation.  This example sweeps the DRAM budget and shows throughput
//! recovering from disk-bound to two-tier parity as the budget grows,
//! using the discrete-event simulator over the real five-stream dependency
//! machinery (R→U→C→O→W) with the calibrated A100 + PCIe4-NVMe cost model.

use zo2::costmodel::{
    plan_three_tier, two_tier_dram_bytes, ComputeMode, Hardware, MemoryBudget, SimCost, Workload,
};
use zo2::model::opt_by_name;
use zo2::precision::Codec;
use zo2::sched::{build_plan, simulate, Policy, SpillPlacement};
use zo2::util::fmt_mb;

const SIM_STEPS: usize = 3;

fn main() {
    let hw = Hardware::a100_pcie4();
    let shape = opt_by_name("OPT-175B").unwrap();
    let wl = Workload {
        shape: shape.clone(),
        batch: 1,
        seq: 2048,
        wire: Codec::Fp16,
        compute: ComputeMode::Fp16,
    };
    let costs = SimCost::new(&hw, &wl);
    println!(
        "OPT-175B fp16: {} layers x {} MB buckets = {} MB of master copies \
         (two-tier DDR requirement)",
        shape.n_layers,
        fmt_mb(wl.block_wire_bytes()),
        fmt_mb(two_tier_dram_bytes(&wl))
    );
    println!(
        "box: 18 GB HBM, NVMe read {:.1} / write {:.1} GB/s, DRAM swept below\n",
        hw.nvme_read.bytes_per_s / 1e9,
        hw.nvme_write.bytes_per_s / 1e9
    );

    // Two-tier reference (needs the full DDR footprint).
    let two = Policy::default();
    let (s2, _) = simulate(&build_plan(shape.n_layers, SIM_STEPS, two), &costs, two);
    let tokens = (wl.batch * wl.seq) as f64;
    let t2 = tokens / s2.steady_step_s;

    println!(
        "{:>9} {:>9} {:>9} {:>11} {:>11} {:>11} {:>10} {:>9} {:>14}",
        "DRAM", "resident", "spilled", "HBM peak", "DDR peak", "NVMe peak", "tokens/s",
        "vs 2tier", "bottleneck"
    );
    for gb in [16u64, 32, 64, 96, 128, 192, 256, 384, 512] {
        let budget = MemoryBudget { hbm: 18 << 30, dram: gb << 30, nvme: 2 << 40 };
        let plan = plan_three_tier(&wl, &budget, 3, 4, 2, &hw, SpillPlacement::Trailing);
        let policy = plan.policy();
        let (s, _) = simulate(&build_plan(shape.n_layers, SIM_STEPS, policy), &costs, policy);
        let tps = tokens / s.steady_step_s;
        let fits = if budget.fits(&plan.peaks) { "" } else { "  OVER BUDGET" };
        println!(
            "{:>6} GB {:>9} {:>9} {:>8} MB {:>8} MB {:>8} MB {:>10.1} {:>8.2}x {:>14}{}",
            gb,
            plan.resident_blocks,
            plan.spilled_blocks,
            fmt_mb(plan.peaks.hbm),
            fmt_mb(plan.peaks.dram),
            fmt_mb(plan.peaks.nvme),
            tps,
            tps / t2,
            s.bottleneck(),
            fits
        );
    }
    println!(
        "\ntwo-tier reference: {:.1} tokens/s ({}; DDR {} MB — does not fit below ~350 GB)",
        t2,
        s2.bottleneck(),
        fmt_mb(two_tier_dram_bytes(&wl))
    );
    println!("(64 GB row = the paper's 18 GB-GPU headline on a workstation, paid for in NVMe");
    println!(" bandwidth; the ratio column shows the overhead of the disk tier vanishing as");
    println!(" DRAM grows and the spill set empties.)");
}
