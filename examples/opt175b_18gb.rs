//! The paper's headline (abstract, Fig. 1, Table 2): **OPT-175B fine-tuning
//! within ~18 GB of GPU memory** — unreachable for AdamW/SGD/MeZO.
//!
//!     cargo run --release --example opt175b_18gb
//!
//! OPT-175B cannot execute for real on this testbed, so this example drives
//! the *actual* scheduler/dependency machinery on virtual time with the
//! calibrated A100-PCIe4 cost model (DESIGN.md §Hardware-Adaptation) and
//! prints the memory accounting for each optimizer strategy.

use zo2::costmodel::{gpu_memory_bytes, ComputeMode, Hardware, SimCost, Strategy, Workload};
use zo2::model::opt_by_name;
use zo2::precision::Codec;
use zo2::sched::{build_plan, simulate, Policy};
use zo2::util::fmt_mb;

fn main() {
    let hw = Hardware::a100_pcie4();
    let shape = opt_by_name("OPT-175B").unwrap();
    println!(
        "OPT-175B: {} layers, d={}, {:.1}B params  |  device: {} ({} GB HBM)",
        shape.n_layers,
        shape.d_model,
        shape.total_params() as f64 / 1e9,
        hw.name,
        hw.hbm_capacity >> 30
    );
    println!();

    // --- memory: who fits? (Fig. 1) -----------------------------------------
    println!("GPU memory required, B=1 T=2048 (MB; X = exceeds 80 GB):");
    for (label, strat, pbytes) in [
        ("AdamW  (fp32)", Strategy::AdamW, 4),
        ("SGD    (fp32)", Strategy::Sgd, 4),
        ("MeZO   (fp32)", Strategy::Mezo, 4),
        ("MeZO   (fp16)", Strategy::Mezo, 2),
        ("ZO2    (fp32)", Strategy::Zo2 { slots: 3 }, 4),
        ("ZO2    (fp16)", Strategy::Zo2 { slots: 3 }, 2),
    ] {
        let wl = Workload {
            shape: shape.clone(),
            batch: 1,
            seq: 2048,
            wire: if pbytes == 2 { Codec::Fp16 } else { Codec::F32 },
            compute: ComputeMode::Fp32,
        };
        let bytes = gpu_memory_bytes(strat, &wl, pbytes, &hw);
        let fits = bytes <= hw.hbm_capacity;
        println!(
            "  {label:<14} {:>10} MB   {}",
            fmt_mb(bytes),
            if fits { "fits" } else { "X (OOM)" }
        );
    }
    println!();

    // --- throughput: the streaming schedule (Table 2 bottom row) ------------
    for (label, wire, compute) in [
        ("fp32 wire / fp32 compute", Codec::F32, ComputeMode::Fp32),
        ("fp16 wire / fp16 compute", Codec::Fp16, ComputeMode::Fp16),
    ] {
        let wl = Workload { shape: shape.clone(), batch: 1, seq: 2048, wire, compute };
        let costs = SimCost::new(&hw, &wl);
        let policy = Policy::default();
        let plan = build_plan(shape.n_layers, 3, policy);
        let (sched, timeline) = simulate(&plan, &costs, policy);
        let tokens = (wl.batch * wl.seq) as f64;
        println!(
            "{label}: {:>6.1} s/step  ->  {:>5.0} tokens/s   (upload busy {:.0}%, compute busy {:.0}%)",
            sched.steady_step_s,
            tokens / sched.steady_step_s,
            100.0 * timeline.utilization("upload"),
            100.0 * timeline.utilization("compute"),
        );
    }
    println!();
    println!("paper Table 2 reference: ZO2 OPT-175B = 34 GB fp32 / 18 GB fp16,");
    println!("14 tokens/s fp32, 37 tokens/s fp16 (A100 measured).");
}
