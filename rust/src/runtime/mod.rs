//! PJRT runtime: load AOT-lowered HLO-text artifacts and execute them.
//!
//! The interchange format is HLO **text** (see `python/compile/aot.py`): jax
//! >= 0.5 serialises HloModuleProto with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids cleanly.
//!
//! In this reproduction the PJRT **CPU** client plays the role of the
//! paper's GPU: it runs exactly the executables a GPU/TPU deployment would
//! run (same HLO, Pallas kernels under interpret=True), while the paper's
//! CPU side is the plain rust heap.  Transfer timing between the two tiers
//! is modelled by [`crate::memory::transfer`].

mod manifest;

pub use manifest::{BucketSpec, Manifest, ModelDims, ParamEntry};

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// Executable names emitted by aot.py for every config.
pub const EXE_NAMES: &[&str] = &[
    "embed_step", "block_step", "head_step",
    "embed_fwd", "block_fwd", "head_eval",
    "update_embed", "update_block", "update_head",
];

/// A loaded artifact bundle for one model config.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    exes: RefCell<BTreeMap<String, xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Load `artifacts/<config>/` (manifest now, executables lazily).
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        Ok(Self { client, dir: dir.to_path_buf(), manifest, exes: RefCell::new(BTreeMap::new()) })
    }

    /// Load by config name from the repo artifacts dir.
    pub fn load_config(name: &str) -> Result<Self> {
        Self::load(&crate::artifacts_dir().join(name))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (once) and cache the named executable.
    pub fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.exes.borrow().contains_key(name) {
            return Ok(());
        }
        let rel = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown executable `{name}`"))?;
        let path = self.dir.join(rel);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.exes.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute `name` with the given inputs; outputs are the decomposed
    /// elements of the (always-tupled) root.
    pub fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.ensure_compiled(name)?;
        let exes = self.exes.borrow();
        let exe = exes.get(name).unwrap();
        let bufs = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("readback {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }

    /// Warm every executable (used by the trainer so that compile time never
    /// lands inside a timed region).
    pub fn compile_all(&self) -> Result<()> {
        for name in EXE_NAMES {
            if self.manifest.artifacts.contains_key(*name) {
                self.ensure_compiled(name)?;
            }
        }
        Ok(())
    }
}

// --- literal helpers ---------------------------------------------------------

fn as_bytes<T>(data: &[T]) -> &[u8] {
    // Plain-old-data views for literal construction (single-copy path).
    // SAFETY: every caller instantiates T with a plain-old-data scalar
    // (f32/i32/u32), so all byte patterns are valid; the u8 view covers
    // exactly `size_of_val(data)` bytes of the borrowed slice and inherits
    // its lifetime, so it cannot outlive or exceed the allocation.
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    }
}

/// f32 tensor literal with the given dims (one copy, no reshape round-trip).
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "dims {:?} vs len {}", dims, data.len());
    let dims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, &dims, as_bytes(data))
        .map_err(|e| anyhow!("literal f32: {e:?}"))
}

/// i32 tensor literal.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "dims {:?} vs len {}", dims, data.len());
    let dims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, &dims, as_bytes(data))
        .map_err(|e| anyhow!("literal i32: {e:?}"))
}

/// u32[2] threefry key-data literal (the shipped RNG state, §5.1).
pub fn lit_key(key: [u32; 2]) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U32, &[2], as_bytes(&key))
        .map_err(|e| anyhow!("literal key: {e:?}"))
}

/// f32 scalar literal.
pub fn lit_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Extract a literal's payload as Vec<f32>.
pub fn lit_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
}

/// Extract a scalar f32 literal.
pub fn lit_to_scalar(lit: &xla::Literal) -> Result<f32> {
    let v = lit_to_f32(lit)?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elements", v.len());
    Ok(v[0])
}
