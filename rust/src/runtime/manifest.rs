//! Artifact manifest — the python↔rust ABI, emitted by `compile/aot.py`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One named parameter inside a flat bucket.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl ParamEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Layout of one module bucket (embedding / block / head).
#[derive(Debug, Clone, PartialEq)]
pub struct BucketSpec {
    pub size: usize,
    pub layout: Vec<ParamEntry>,
}

/// Model dimensions the artifacts were specialised to.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelDims {
    pub name: String,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub ffn_mult: usize,
    pub total_params: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: ModelDims,
    pub embed: BucketSpec,
    pub block: BucketSpec,
    pub head: BucketSpec,
    pub artifacts: BTreeMap<String, String>,
}

fn bucket(j: &Json) -> Result<BucketSpec> {
    let mut layout = Vec::new();
    for e in j.get("layout")?.as_arr()? {
        layout.push(ParamEntry {
            name: e.get("name")?.as_str()?.to_string(),
            offset: e.get("offset")?.as_usize()?,
            shape: e.get("shape")?.as_arr()?.iter().map(|s| s.as_usize()).collect::<Result<_>>()?,
        });
    }
    Ok(BucketSpec { size: j.get("size")?.as_usize()?, layout })
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let c = j.get("config")?;
        let config = ModelDims {
            name: c.get("name")?.as_str()?.to_string(),
            d_model: c.get("d_model")?.as_usize()?,
            n_heads: c.get("n_heads")?.as_usize()?,
            n_layers: c.get("n_layers")?.as_usize()?,
            vocab: c.get("vocab")?.as_usize()?,
            seq_len: c.get("seq_len")?.as_usize()?,
            batch: c.get("batch")?.as_usize()?,
            ffn_mult: c.get("ffn_mult")?.as_usize()?,
            total_params: c.get("total_params")?.as_usize()?,
        };
        let b = j.get("buckets")?;
        let mut artifacts = BTreeMap::new();
        for (k, v) in j.get("artifacts")?.as_obj()? {
            artifacts.insert(k.clone(), v.as_str()?.to_string());
        }
        Ok(Manifest {
            config,
            embed: bucket(b.get("embed")?)?,
            block: bucket(b.get("block")?)?,
            head: bucket(b.get("head")?)?,
            artifacts,
        })
    }

    /// Consistency invariant: layouts are dense, ordered and sum to `size`.
    pub fn validate(&self) -> Result<()> {
        for (name, spec) in [("embed", &self.embed), ("block", &self.block), ("head", &self.head)] {
            let mut off = 0;
            for p in &spec.layout {
                anyhow::ensure!(p.offset == off, "{name}: `{}` offset {} != {off}", p.name, p.offset);
                off += p.numel();
            }
            anyhow::ensure!(off == spec.size, "{name}: layout sums to {off}, size {}", spec.size);
        }
        let total = self.embed.size + self.config.n_layers * self.block.size + self.head.size;
        anyhow::ensure!(total == self.config.total_params,
            "total_params {} != layout total {total}", self.config.total_params);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": {"name": "t", "d_model": 4, "n_heads": 2, "n_layers": 1,
                 "vocab": 8, "seq_len": 2, "batch": 1, "ffn_mult": 4,
                 "total_params": 20},
      "buckets": {
        "embed": {"size": 8, "layout": [{"name": "tok", "offset": 0, "shape": [2, 4]}]},
        "block": {"size": 8, "layout": [{"name": "w", "offset": 0, "shape": [8]}]},
        "head": {"size": 4, "layout": [{"name": "h", "offset": 0, "shape": [4]}]}
      },
      "artifacts": {"block_step": "block_step.hlo.txt"}
    }"#;

    #[test]
    fn parse_and_validate() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.config.d_model, 4);
        assert_eq!(m.block.size, 8);
        assert_eq!(m.embed.layout[0].numel(), 8);
        m.validate().unwrap();
    }

    #[test]
    fn validate_catches_bad_total() {
        let bad = SAMPLE.replace("\"total_params\": 20", "\"total_params\": 21");
        assert!(Manifest::parse(&bad).unwrap().validate().is_err());
    }
}
