//! Minimal io_uring batch reader (Linux ≥ 5.6) for the disk tier.
//!
//! [`crate::memory::disk::DiskPool::read_batch`] previously issued its
//! "batched" reads as a pread loop — one syscall and one NVMe round-trip
//! per bucket.  This module submits the whole batch through a real
//! submission/completion ring (`IORING_OP_READ`, offset-addressed, so the
//! shared file cursor is never touched), letting the kernel keep the queue
//! depth up.  Everything is raw syscalls — no external crates — and every
//! failure path degrades to the positioned-read loop in `disk.rs`, which
//! produces byte-identical results.
//!
//! Scope deliberately small: one ring per pool, read-only, caller-owned
//! buffers, waves of at most the ring size, fully drained before the next
//! wave (so submission-queue space never runs out and partial submits
//! cannot happen in steady state).

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::OnceLock;

use anyhow::{bail, Result};

const SYS_IO_URING_SETUP: std::ffi::c_long = 425;
const SYS_IO_URING_ENTER: std::ffi::c_long = 426;

const IORING_OFF_SQ_RING: i64 = 0;
const IORING_OFF_CQ_RING: i64 = 0x800_0000;
const IORING_OFF_SQES: i64 = 0x1000_0000;
const IORING_ENTER_GETEVENTS: u32 = 1;
const IORING_FEAT_SINGLE_MMAP: u32 = 1;
/// `IORING_OP_READ`: positioned read into a plain user buffer (5.6+).
const IORING_OP_READ: u8 = 22;

const PROT_READ: i32 = 1;
const PROT_WRITE: i32 = 2;
const MAP_SHARED: i32 = 0x01;
const MAP_POPULATE: i32 = 0x8000;

const EINTR: i32 = 4;
const EAGAIN: i32 = 11;

extern "C" {
    fn syscall(num: std::ffi::c_long, ...) -> std::ffi::c_long;
    fn mmap(addr: *mut u8, len: usize, prot: i32, flags: i32, fd: i32, off: i64) -> *mut u8;
    fn munmap(addr: *mut u8, len: usize) -> i32;
    fn close(fd: i32) -> i32;
}

#[repr(C)]
#[derive(Debug, Default, Clone, Copy)]
struct SqOffsets {
    head: u32,
    tail: u32,
    ring_mask: u32,
    ring_entries: u32,
    flags: u32,
    dropped: u32,
    array: u32,
    resv1: u32,
    user_addr: u64,
}

#[repr(C)]
#[derive(Debug, Default, Clone, Copy)]
struct CqOffsets {
    head: u32,
    tail: u32,
    ring_mask: u32,
    ring_entries: u32,
    overflow: u32,
    cqes: u32,
    flags: u32,
    resv1: u32,
    user_addr: u64,
}

/// `struct io_uring_params` (120 bytes).
#[repr(C)]
#[derive(Debug, Default, Clone, Copy)]
struct UringParams {
    sq_entries: u32,
    cq_entries: u32,
    flags: u32,
    sq_thread_cpu: u32,
    sq_thread_idle: u32,
    features: u32,
    wq_fd: u32,
    resv: [u32; 3],
    sq_off: SqOffsets,
    cq_off: CqOffsets,
}

/// `struct io_uring_sqe` (64 bytes), the fields `IORING_OP_READ` uses.
#[repr(C)]
#[derive(Clone, Copy)]
struct Sqe {
    opcode: u8,
    flags: u8,
    ioprio: u16,
    fd: i32,
    off: u64,
    addr: u64,
    len: u32,
    rw_flags: u32,
    user_data: u64,
    buf_index: u16,
    personality: u16,
    splice_fd_in: i32,
    pad: [u64; 2],
}

/// `struct io_uring_cqe` (16 bytes).
#[repr(C)]
#[derive(Clone, Copy)]
struct Cqe {
    user_data: u64,
    res: i32,
    flags: u32,
}

/// One read-only io_uring with its three mmapped regions.
pub(crate) struct UringReader {
    fd: i32,
    entries: u32,
    sq_ptr: *mut u8,
    sq_map_len: usize,
    cq_ptr: *mut u8,
    cq_map_len: usize,
    single_mmap: bool,
    sqes: *mut Sqe,
    sqes_len: usize,
    sq_ktail: *const AtomicU32,
    sq_mask: u32,
    sq_array: *mut u32,
    cq_khead: *const AtomicU32,
    cq_ktail: *const AtomicU32,
    cq_mask: u32,
    cqes: *const Cqe,
}

// Safety: the ring is exclusively owned; all pointers target mmapped
// memory that lives until Drop, and the kernel side is thread-agnostic.
unsafe impl Send for UringReader {}

impl std::fmt::Debug for UringReader {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("UringReader")
            .field("fd", &self.fd)
            .field("entries", &self.entries)
            .finish()
    }
}

impl UringReader {
    /// Whether this kernel/container permits io_uring at all.  Probed once
    /// per process (a ring is set up and torn down); `ENOSYS`, `EPERM`
    /// (seccomp-restricted containers) and friends all report `false`.
    pub(crate) fn available() -> bool {
        static AVAIL: OnceLock<bool> = OnceLock::new();
        *AVAIL.get_or_init(|| UringReader::new(8).is_ok())
    }

    pub(crate) fn new(entries: u32) -> Result<Self> {
        let mut p = UringParams::default();
        // Safety: p outlives the call; the kernel writes the offsets back.
        let fd = unsafe {
            syscall(SYS_IO_URING_SETUP, entries as std::ffi::c_long, &mut p as *mut UringParams)
        };
        if fd < 0 {
            bail!("io_uring_setup: {}", std::io::Error::last_os_error());
        }
        let fd = fd as i32;
        let sq_len = p.sq_off.array as usize + p.sq_entries as usize * 4;
        let cq_len = p.cq_off.cqes as usize + p.cq_entries as usize * std::mem::size_of::<Cqe>();
        let single_mmap = p.features & IORING_FEAT_SINGLE_MMAP != 0;
        let (sq_map_len, cq_map_len) =
            if single_mmap { (sq_len.max(cq_len), sq_len.max(cq_len)) } else { (sq_len, cq_len) };
        let map = |len: usize, off: i64| -> Result<*mut u8> {
            // Safety: standard io_uring ring mapping.
            let ptr = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    len,
                    PROT_READ | PROT_WRITE,
                    MAP_SHARED | MAP_POPULATE,
                    fd,
                    off,
                )
            };
            if ptr as usize == usize::MAX {
                bail!("io_uring mmap: {}", std::io::Error::last_os_error());
            }
            Ok(ptr)
        };
        let sq_ptr = match map(sq_map_len, IORING_OFF_SQ_RING) {
            Ok(p) => p,
            Err(e) => {
                // SAFETY: fd came from io_uring_setup above and nothing else
                // owns it yet; closing it on the error path is the only use.
                unsafe { close(fd) };
                return Err(e);
            }
        };
        let cq_ptr = if single_mmap {
            sq_ptr
        } else {
            match map(cq_map_len, IORING_OFF_CQ_RING) {
                Ok(p) => p,
                Err(e) => {
                    // SAFETY: undoing exactly what succeeded so far — the SQ
                    // mapping of sq_map_len bytes and the setup fd.
                    unsafe {
                        munmap(sq_ptr, sq_map_len);
                        close(fd);
                    }
                    return Err(e);
                }
            }
        };
        let sqes_len = p.sq_entries as usize * std::mem::size_of::<Sqe>();
        let sqes = match map(sqes_len, IORING_OFF_SQES) {
            Ok(p) => p as *mut Sqe,
            Err(e) => {
                // SAFETY: undoing exactly the mappings made above (CQ only
                // when it was a second mapping) plus the setup fd.
                unsafe {
                    munmap(sq_ptr, sq_map_len);
                    if !single_mmap {
                        munmap(cq_ptr, cq_map_len);
                    }
                    close(fd);
                }
                return Err(e);
            }
        };
        // Safety: offsets come from the kernel for these mappings; the
        // masks are constants after setup, the head/tail words are the
        // shared atomics of the ring protocol.
        unsafe {
            Ok(Self {
                fd,
                entries: p.sq_entries,
                sq_ptr,
                sq_map_len,
                cq_ptr,
                cq_map_len,
                single_mmap,
                sqes,
                sqes_len,
                sq_ktail: sq_ptr.add(p.sq_off.tail as usize) as *const AtomicU32,
                sq_mask: *(sq_ptr.add(p.sq_off.ring_mask as usize) as *const u32),
                sq_array: sq_ptr.add(p.sq_off.array as usize) as *mut u32,
                cq_khead: cq_ptr.add(p.cq_off.head as usize) as *const AtomicU32,
                cq_ktail: cq_ptr.add(p.cq_off.tail as usize) as *const AtomicU32,
                cq_mask: *(cq_ptr.add(p.cq_off.ring_mask as usize) as *const u32),
                cqes: cq_ptr.add(p.cq_off.cqes as usize) as *const Cqe,
            })
        }
    }

    /// Submit positioned reads of `reqs` (`(file_offset, buffer)`) against
    /// `file_fd` and wait for all completions.  Returns the raw per-request
    /// `cqe.res` (bytes read, or `-errno`), indexed like `reqs`; the caller
    /// completes short reads / retries failures with plain positioned
    /// reads.  Errors only on ring-level failures (submission rejected) —
    /// after which the caller should discard this ring.
    pub(crate) fn read_batch(&mut self, file_fd: i32, reqs: &mut [(u64, &mut [u8])]) -> Result<Vec<i64>> {
        let mut res = vec![0i64; reqs.len()];
        let mut done = 0usize;
        while done < reqs.len() {
            let wave = (reqs.len() - done).min(self.entries as usize);
            // Safety: the ring is drained (previous waves completed), so
            // tail..tail+wave are free sqe slots; buffers outlive the wait
            // below.
            unsafe {
                let tail0 = (*self.sq_ktail).load(Ordering::Relaxed);
                for k in 0..wave {
                    let (off, buf) = &mut reqs[done + k];
                    let idx = ((tail0.wrapping_add(k as u32)) & self.sq_mask) as usize;
                    *self.sqes.add(idx) = Sqe {
                        opcode: IORING_OP_READ,
                        flags: 0,
                        ioprio: 0,
                        fd: file_fd,
                        off: *off,
                        addr: buf.as_mut_ptr() as u64,
                        len: buf.len() as u32,
                        rw_flags: 0,
                        user_data: (done + k) as u64,
                        buf_index: 0,
                        personality: 0,
                        splice_fd_in: 0,
                        pad: [0; 2],
                    };
                    *self.sq_array.add(idx) = idx as u32;
                }
                (*self.sq_ktail).store(tail0.wrapping_add(wave as u32), Ordering::Release);
            }
            let mut completed = 0usize;
            let mut to_submit = wave as u32;
            while completed < wave {
                let want = (wave - completed) as std::ffi::c_long;
                // Safety: plain io_uring_enter; null sigset.
                let r = unsafe {
                    syscall(
                        SYS_IO_URING_ENTER,
                        self.fd as std::ffi::c_long,
                        to_submit as std::ffi::c_long,
                        want,
                        IORING_ENTER_GETEVENTS as std::ffi::c_long,
                        std::ptr::null::<u8>(),
                        0usize,
                    )
                };
                if r < 0 {
                    match std::io::Error::last_os_error().raw_os_error() {
                        Some(EINTR) | Some(EAGAIN) => continue,
                        _ => bail!("io_uring_enter: {}", std::io::Error::last_os_error()),
                    }
                }
                if to_submit > 0 && (r as u32) < to_submit {
                    // Should be impossible with a drained ring; treat as a
                    // ring-level failure rather than guessing.
                    bail!("io_uring_enter submitted {r} of {to_submit}");
                }
                to_submit = 0;
                // Safety: standard completion-queue reap with the ring's
                // acquire/release protocol.
                unsafe {
                    let mut head = (*self.cq_khead).load(Ordering::Relaxed);
                    let tail = (*self.cq_ktail).load(Ordering::Acquire);
                    while head != tail {
                        let cqe = *self.cqes.add((head & self.cq_mask) as usize);
                        if (cqe.user_data as usize) < res.len() {
                            res[cqe.user_data as usize] = cqe.res as i64;
                        }
                        head = head.wrapping_add(1);
                        completed += 1;
                    }
                    (*self.cq_khead).store(head, Ordering::Release);
                }
            }
            done += wave;
        }
        Ok(res)
    }
}

impl Drop for UringReader {
    fn drop(&mut self) {
        // Safety: unmapping exactly what `new` mapped, then closing the fd.
        unsafe {
            munmap(self.sqes as *mut u8, self.sqes_len);
            munmap(self.sq_ptr, self.sq_map_len);
            if !self.single_mmap {
                munmap(self.cq_ptr, self.cq_map_len);
            }
            close(self.fd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::os::unix::io::AsRawFd;

    #[test]
    fn batch_read_matches_file_contents() {
        if !UringReader::available() {
            eprintln!("io_uring unavailable; skipping");
            return;
        }
        let path = std::env::temp_dir()
            .join(format!("zo2-uring-test-{}.bin", std::process::id()));
        let data: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        let mut f = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .unwrap();
        f.write_all(&data).unwrap();
        f.flush().unwrap();
        // More requests than the ring has entries → multiple waves.
        let mut ring = UringReader::new(4).unwrap();
        let spans: Vec<(u64, usize)> =
            (0..37).map(|i| ((i * 2_700) as u64, 1_000 + (i % 7) * 13)).collect();
        let mut bufs: Vec<Vec<u8>> = spans.iter().map(|&(_, l)| vec![0u8; l]).collect();
        let mut reqs: Vec<(u64, &mut [u8])> = spans
            .iter()
            .zip(bufs.iter_mut())
            .map(|(&(o, _), b)| (o, b.as_mut_slice()))
            .collect();
        let res = ring.read_batch(f.as_raw_fd(), &mut reqs).unwrap();
        for ((&(off, len), buf), r) in spans.iter().zip(&bufs).zip(&res) {
            assert_eq!(*r, len as i64, "offset {off}");
            assert_eq!(buf.as_slice(), &data[off as usize..off as usize + len]);
        }
        drop(f);
        std::fs::remove_file(&path).unwrap();
    }
}
