//! Disk (NVMe) tier below host DDR: file-backed parameter buckets with an
//! accounted DRAM staging window.
//!
//! The paper's two-tier system still assumes the CPU side can hold every
//! master copy — ~700 GB of DRAM for OPT-175B fp32.  This module adds the
//! third tier: block buckets that do not fit the DRAM budget *spill* to a
//! pool file on disk, stored in the same wire codec they would cross PCIe
//! in (compressed storage, so AMP shrinks the disk footprint and the NVMe
//! traffic by the same factor as the link traffic).
//!
//! As with [`super::DevicePool`], the *data movement* is real — bytes are
//! written to and re-read from an actual file — while the *time* an NVMe
//! device would take is given by a [`TransferModel`] pair (read/write
//! bandwidths differ on real drives).  The [`DramWindow`] is the staging
//! counterpart of the §5.3 reusable device buffer: a fixed number of
//! block-sized DRAM slots through which spilled buckets stream, giving the
//! disk prefetcher a bounded look-ahead of `slots` blocks.

use std::fs::{File, OpenOptions};
#[cfg(not(unix))]
use std::io::Read;
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::transfer::{TransferModel, TransferStats};
use crate::precision::Codec;

/// Process-wide `--disk-uring auto|off` switch.  Default `auto`: batched
/// reads go through real io_uring rings where the kernel permits it, with
/// the positioned-read loop as the byte-identical fallback everywhere else.
static URING_OFF: AtomicBool = AtomicBool::new(false);

pub fn set_disk_uring(auto: bool) {
    URING_OFF.store(!auto, Ordering::Relaxed);
}

pub fn disk_uring_auto() -> bool {
    !URING_OFF.load(Ordering::Relaxed)
}

/// Handle to one codec-encoded bucket inside a [`DiskPool`] file.
#[derive(Debug, Clone)]
pub struct DiskBucket {
    codec: Codec,
    numel: usize,
    offset: u64,
    len: usize,
}

impl DiskBucket {
    pub fn codec(&self) -> Codec {
        self.codec
    }

    pub fn numel(&self) -> usize {
        self.numel
    }

    /// On-disk (= wire-format) bytes of this bucket.
    pub fn wire_len(&self) -> usize {
        self.len
    }

    /// Byte offset of this bucket inside its pool file.
    pub fn offset(&self) -> u64 {
        self.offset
    }

    /// Reconstruct a bucket handle from persisted layout metadata (the
    /// checkpoint loader's counterpart of [`DiskPool::append`]).  The
    /// caller owns the invariant that `(offset, numel·codec-width)` really
    /// describes a bucket of the pool file it is used against.
    pub fn at(codec: Codec, numel: usize, offset: u64) -> Self {
        Self { codec, numel, offset, len: numel * codec.bytes_per_el() }
    }
}

/// File-backed bucket pool with capacity accounting and an NVMe cost model.
///
/// Reads and writes take `&self` (the file handle is behind a mutex) so the
/// disk-read and disk-write pipeline threads can share one pool.
#[derive(Debug)]
pub struct DiskPool {
    file: Mutex<File>,
    path: PathBuf,
    end: AtomicU64,
    capacity: u64,
    /// Persistent pools (checkpoints) survive drop; scratch pools (the
    /// engine's spill tier) are unlinked when the pool goes away.
    persistent: bool,
    pub read_model: TransferModel,
    pub write_model: TransferModel,
    reads: Mutex<TransferStats>,
    writes: Mutex<TransferStats>,
    /// Lazily-built io_uring for batched reads; `None` until first use or
    /// after a ring-level failure (which falls back to positioned reads).
    #[cfg(target_os = "linux")]
    uring: Mutex<Option<super::uring::UringReader>>,
}

impl DiskPool {
    /// Create (truncating) a pool file at `path`.
    pub fn create(
        path: PathBuf,
        capacity: u64,
        read_model: TransferModel,
        write_model: TransferModel,
    ) -> Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("creating disk pool {}", path.display()))?;
        Ok(Self {
            file: Mutex::new(file),
            path,
            end: AtomicU64::new(0),
            capacity,
            persistent: false,
            read_model,
            write_model,
            reads: Mutex::new(TransferStats::default()),
            writes: Mutex::new(TransferStats::default()),
            #[cfg(target_os = "linux")]
            uring: Mutex::new(None),
        })
    }

    /// Create (truncating) a pool file that *survives* the pool handle —
    /// the checkpoint variant of [`Self::create`].
    pub fn create_persistent(
        path: PathBuf,
        capacity: u64,
        read_model: TransferModel,
        write_model: TransferModel,
    ) -> Result<Self> {
        let mut pool = Self::create(path, capacity, read_model, write_model)?;
        pool.persistent = true;
        Ok(pool)
    }

    /// Reopen an existing pool file without truncating it (checkpoint
    /// restore after a process kill).  The append cursor starts at the
    /// current file end, so previously-appended buckets keep their offsets
    /// and new appends land after them.
    pub fn open_persistent(
        path: PathBuf,
        read_model: TransferModel,
        write_model: TransferModel,
    ) -> Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("opening disk pool {}", path.display()))?;
        let end = file
            .metadata()
            .with_context(|| format!("stat of disk pool {}", path.display()))?
            .len();
        Ok(Self {
            file: Mutex::new(file),
            path,
            end: AtomicU64::new(end),
            capacity: u64::MAX,
            persistent: true,
            read_model,
            write_model,
            reads: Mutex::new(TransferStats::default()),
            writes: Mutex::new(TransferStats::default()),
            #[cfg(target_os = "linux")]
            uring: Mutex::new(None),
        })
    }

    /// Create a pool file with a unique name in the system temp directory.
    pub fn in_temp(
        capacity: u64,
        read_model: TransferModel,
        write_model: TransferModel,
    ) -> Result<Self> {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let n = SEQ.fetch_add(1, Ordering::SeqCst);
        let path =
            std::env::temp_dir().join(format!("zo2-disk-{}-{}.pool", std::process::id(), n));
        Self::create(path, capacity, read_model, write_model)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append a new bucket (initial spill of a block to the disk tier).
    pub fn append(&self, codec: Codec, numel: usize, bytes: &[u8]) -> Result<DiskBucket> {
        anyhow::ensure!(
            bytes.len() == numel * codec.bytes_per_el(),
            "bucket payload {} bytes vs {} x {}-byte elements",
            bytes.len(),
            numel,
            codec.bytes_per_el()
        );
        let len = bytes.len();
        let offset = self.end.fetch_add(len as u64, Ordering::SeqCst);
        if offset + len as u64 > self.capacity {
            self.end.fetch_sub(len as u64, Ordering::SeqCst);
            bail!(
                "disk tier full: {} + {} exceeds capacity {} (simulated NVMe)",
                offset,
                len,
                self.capacity
            );
        }
        self.write_at(offset, bytes)?;
        self.record(&self.writes, len as u64, &self.write_model);
        if crate::telemetry::metrics::enabled() {
            crate::telemetry::metrics::counter_add("disk_write_bytes_total", &[], len as u64);
            crate::telemetry::metrics::observe("disk_write_batch_bytes", &[], len as f64);
        }
        Ok(DiskBucket { codec, numel, offset, len })
    }

    /// Read a bucket's encoded bytes back into DRAM.
    pub fn read(&self, b: &DiskBucket) -> Result<Vec<u8>> {
        let mut buf = vec![0u8; b.len];
        self.read_exact_at_off(b.offset, &mut buf)?;
        self.record(&self.reads, b.len as u64, &self.read_model);
        if crate::telemetry::metrics::enabled() {
            crate::telemetry::metrics::counter_add("disk_read_bytes_total", &[], b.len as u64);
            crate::telemetry::metrics::observe("disk_read_batch_bytes", &[], b.len as f64);
        }
        Ok(buf)
    }

    /// Positioned read (never moves the shared cursor on unix, so readers
    /// need not serialise against the seek+write path's cursor use).
    fn read_exact_at_off(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            let f = self.file.lock().unwrap();
            f.read_exact_at(buf, offset)
                .with_context(|| format!("disk read at {}+{}", offset, buf.len()))?;
        }
        #[cfg(not(unix))]
        {
            let mut f = self.file.lock().unwrap();
            f.seek(SeekFrom::Start(offset))?;
            f.read_exact(buf)
                .with_context(|| format!("disk read at {}+{}", offset, buf.len()))?;
        }
        Ok(())
    }

    /// Read several buckets in one batch.  On Linux with io_uring permitted
    /// (`--disk-uring auto`, kernel support probed once) the whole batch
    /// goes down as a single submission-queue wave, keeping NVMe queue
    /// depth up; everywhere else — and on *any* ring-level failure — it
    /// degrades to the positioned-read loop.  Bytes returned and per-bucket
    /// transfer accounting are identical on both paths; only the syscall
    /// shape (and the `disk_uring_batches_total` counter) differs.
    pub fn read_batch(&self, buckets: &[&DiskBucket]) -> Result<Vec<Vec<u8>>> {
        let mut bufs: Vec<Vec<u8>> = buckets.iter().map(|b| vec![0u8; b.len]).collect();
        let via_uring = self.read_batch_uring(buckets, &mut bufs);
        if !via_uring {
            for (b, buf) in buckets.iter().zip(bufs.iter_mut()) {
                self.read_exact_at_off(b.offset, buf)?;
            }
        }
        for b in buckets {
            self.record(&self.reads, b.len as u64, &self.read_model);
            if crate::telemetry::metrics::enabled() {
                crate::telemetry::metrics::counter_add("disk_read_bytes_total", &[], b.len as u64);
                crate::telemetry::metrics::observe("disk_read_batch_bytes", &[], b.len as f64);
            }
        }
        if via_uring && crate::telemetry::metrics::enabled() {
            crate::telemetry::metrics::counter_add("disk_uring_batches_total", &[], 1);
        }
        Ok(bufs)
    }

    /// io_uring leg of [`Self::read_batch`]: `false` means "not attempted
    /// or failed — run the fallback loop" (buffers may then hold partial
    /// data; the fallback rewrites them in full).
    #[cfg(target_os = "linux")]
    fn read_batch_uring(&self, buckets: &[&DiskBucket], bufs: &mut [Vec<u8>]) -> bool {
        use std::os::unix::io::AsRawFd;
        if !disk_uring_auto() || buckets.len() < 2 || !super::uring::UringReader::available() {
            return false;
        }
        let mut guard = self.uring.lock().unwrap();
        if guard.is_none() {
            match super::uring::UringReader::new(64) {
                Ok(r) => *guard = Some(r),
                Err(_) => return false,
            }
        }
        // The fd stays valid: `self.file` lives as long as `self`, and the
        // raw fd is only used while `self` is borrowed.
        let fd = self.file.lock().unwrap().as_raw_fd();
        let mut reqs: Vec<(u64, &mut [u8])> = buckets
            .iter()
            .zip(bufs.iter_mut())
            .map(|(b, buf)| (b.offset, buf.as_mut_slice()))
            .collect();
        let res = match guard.as_mut().unwrap().read_batch(fd, &mut reqs) {
            Ok(r) => r,
            Err(_) => {
                // Ring-level failure: discard the ring, let pread redo it.
                *guard = None;
                return false;
            }
        };
        drop(reqs);
        drop(guard);
        // Complete short reads / redo per-request failures positionally.
        for (b, (buf, r)) in buckets.iter().zip(bufs.iter_mut().zip(res)) {
            let got = if r < 0 { 0 } else { (r as usize).min(buf.len()) };
            if got < buf.len()
                && self.read_exact_at_off(b.offset + got as u64, &mut buf[got..]).is_err()
            {
                return false;
            }
        }
        true
    }

    #[cfg(not(target_os = "linux"))]
    fn read_batch_uring(&self, _buckets: &[&DiskBucket], _bufs: &mut [Vec<u8>]) -> bool {
        false
    }

    /// Read a bucket and decode it to fp32 through the host compute pool
    /// (parity checks, eval paths).  The disk stores wire bytes verbatim;
    /// this is the read + pooled-decode composition in one call.
    pub fn read_decoded(
        &self,
        b: &DiskBucket,
        pool: &crate::hostpool::HostPool,
    ) -> Result<Vec<f32>> {
        let bytes = self.read(b)?;
        let mut out = vec![0.0f32; b.numel];
        crate::hostpool::fused::decode_pooled(b.codec, &bytes, &mut out, pool);
        Ok(out)
    }

    /// Encode fp32 data through the host compute pool and write it back to
    /// the bucket (checkpoint-restore style writes).
    pub fn write_encoded(
        &self,
        b: &DiskBucket,
        data: &[f32],
        pool: &crate::hostpool::HostPool,
    ) -> Result<()> {
        anyhow::ensure!(
            data.len() == b.numel,
            "bucket rewrite {} elems vs {}",
            data.len(),
            b.numel
        );
        let mut bytes = vec![0u8; b.len];
        crate::hostpool::fused::encode_pooled(b.codec, data, &mut bytes, pool);
        self.write(b, &bytes)
    }

    /// Overwrite a bucket in place (write-back of an updated block).  The
    /// wire codec is fixed-width, so the encoded length never changes.
    pub fn write(&self, b: &DiskBucket, bytes: &[u8]) -> Result<()> {
        anyhow::ensure!(
            bytes.len() == b.len,
            "bucket rewrite {} bytes vs reserved {}",
            bytes.len(),
            b.len
        );
        self.write_at(b.offset, bytes)?;
        self.record(&self.writes, b.len as u64, &self.write_model);
        if crate::telemetry::metrics::enabled() {
            crate::telemetry::metrics::counter_add("disk_write_bytes_total", &[], b.len as u64);
            crate::telemetry::metrics::observe("disk_write_batch_bytes", &[], b.len as f64);
        }
        Ok(())
    }

    fn write_at(&self, offset: u64, bytes: &[u8]) -> Result<()> {
        let mut f = self.file.lock().unwrap();
        f.seek(SeekFrom::Start(offset))?;
        f.write_all(bytes)
            .with_context(|| format!("disk write at {}+{}", offset, bytes.len()))?;
        Ok(())
    }

    fn record(&self, which: &Mutex<TransferStats>, bytes: u64, model: &TransferModel) {
        let mut s = which.lock().unwrap();
        s.ops += 1;
        s.bytes += bytes;
        s.modeled_s += model.time_for(bytes);
    }

    /// Bytes currently occupied in the pool file (== peak: buckets are
    /// appended once and rewritten in place).
    pub fn used(&self) -> u64 {
        self.end.load(Ordering::SeqCst)
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn read_stats(&self) -> TransferStats {
        self.reads.lock().unwrap().clone()
    }

    pub fn write_stats(&self) -> TransferStats {
        self.writes.lock().unwrap().clone()
    }
}

impl Drop for DiskPool {
    fn drop(&mut self) {
        if !self.persistent {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// Accounted DRAM staging window for the disk tier: at most `slots`
/// block-sized buckets of spilled blocks may be DRAM-resident at once
/// (from disk-read start until write-back end).  Backpressure itself comes
/// from the pipeline's bounded token ring; this type *enforces and tracks*
/// the invariant, mirroring how [`super::DevicePool`] accounts HBM.
#[derive(Debug)]
pub struct DramWindow {
    slots: usize,
    slot_bytes: u64,
    in_flight: AtomicU64,
    used_bytes: AtomicU64,
    peak_slots: AtomicU64,
    peak_bytes: AtomicU64,
}

impl DramWindow {
    pub fn new(slots: usize, slot_bytes: u64) -> Self {
        Self {
            slots: slots.max(1),
            slot_bytes,
            in_flight: AtomicU64::new(0),
            used_bytes: AtomicU64::new(0),
            peak_slots: AtomicU64::new(0),
            peak_bytes: AtomicU64::new(0),
        }
    }

    /// Claim one staging slot for `bytes` of encoded bucket.
    pub fn acquire(&self, bytes: u64) -> Result<()> {
        let now = self.in_flight.fetch_add(1, Ordering::SeqCst) + 1;
        if now as usize > self.slots {
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            bail!("DRAM staging window exhausted: {} slots all in flight", self.slots);
        }
        self.peak_slots.fetch_max(now, Ordering::SeqCst);
        let used = self.used_bytes.fetch_add(bytes, Ordering::SeqCst) + bytes;
        self.peak_bytes.fetch_max(used, Ordering::SeqCst);
        Ok(())
    }

    /// Return a staging slot after its bucket left DRAM.
    pub fn release(&self, bytes: u64) {
        self.in_flight.fetch_sub(1, Ordering::SeqCst);
        self.used_bytes.fetch_sub(bytes, Ordering::SeqCst);
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.slots as u64 * self.slot_bytes
    }

    pub fn peak_slots(&self) -> usize {
        self.peak_slots.load(Ordering::SeqCst) as usize
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::HostBucket;

    fn models() -> (TransferModel, TransferModel) {
        (TransferModel::nvme_read(), TransferModel::nvme_write())
    }

    #[test]
    fn bucket_roundtrip_is_byte_exact() {
        let (r, w) = models();
        let pool = DiskPool::in_temp(u64::MAX, r, w).unwrap();
        let data: Vec<f32> = (0..257).map(|i| (i as f32) * 0.37 - 11.0).collect();
        for codec in [Codec::F32, Codec::Bf16, Codec::Fp8E4M3] {
            let hb = HostBucket::from_f32(&data, codec);
            let entry = pool.append(codec, data.len(), hb.wire()).unwrap();
            let back = pool.read(&entry).unwrap();
            assert_eq!(back, hb.wire(), "{codec:?}: disk must preserve wire bytes");
            let rebuilt = HostBucket::from_wire(codec, data.len(), back);
            assert_eq!(rebuilt.to_f32(), hb.to_f32());
        }
    }

    #[test]
    fn rewrite_in_place_and_accounting() {
        let (r, w) = models();
        let pool = DiskPool::in_temp(u64::MAX, r, w).unwrap();
        let a = vec![1u8; 64];
        let b = vec![2u8; 64];
        let e0 = pool.append(Codec::Fp8E4M3, 64, &a).unwrap();
        let e1 = pool.append(Codec::Fp8E4M3, 64, &b).unwrap();
        assert_eq!(pool.used(), 128);
        pool.write(&e0, &b).unwrap();
        assert_eq!(pool.read(&e0).unwrap(), b);
        assert_eq!(pool.read(&e1).unwrap(), b, "neighbour untouched");
        assert_eq!(pool.used(), 128, "rewrite must not grow the pool");
        let ws = pool.write_stats();
        let rs = pool.read_stats();
        assert_eq!(ws.ops, 3);
        assert_eq!(ws.bytes, 192);
        assert_eq!(rs.ops, 2);
        assert!(ws.modeled_s > 0.0 && rs.modeled_s > 0.0);
    }

    #[test]
    fn pooled_read_write_roundtrip() {
        let (r, w) = models();
        let pool_file = DiskPool::in_temp(u64::MAX, r, w).unwrap();
        let pool = crate::hostpool::HostPool::new(4);
        let data: Vec<f32> = (0..5000).map(|i| (i as f32) * 0.01 - 25.0).collect();
        for codec in [Codec::F32, Codec::Fp16] {
            let hb = HostBucket::from_f32(&data, codec);
            let entry = pool_file.append(codec, data.len(), hb.wire()).unwrap();
            let dec = pool_file.read_decoded(&entry, &pool).unwrap();
            assert_eq!(dec, hb.to_f32(), "{codec:?} pooled decode");
            // Write the decoded values back; bytes on disk must be stable.
            pool_file.write_encoded(&entry, &dec, &pool).unwrap();
            assert_eq!(pool_file.read(&entry).unwrap(), hb.wire(), "{codec:?} stable rewrite");
        }
    }

    #[test]
    fn read_batch_matches_sequential_reads_on_both_paths() {
        let (r, w) = models();
        let pool = DiskPool::in_temp(u64::MAX, r, w).unwrap();
        let mut entries = Vec::new();
        for i in 0..9usize {
            let n = 500 + i * 37;
            let bytes: Vec<u8> = (0..n).map(|j| ((i * 31 + j) % 251) as u8).collect();
            entries.push((pool.append(Codec::Fp8E4M3, n, &bytes).unwrap(), bytes));
        }
        let refs: Vec<&DiskBucket> = entries.iter().map(|(e, _)| e).collect();
        // Forced positioned-read path.
        set_disk_uring(false);
        let seq = pool.read_batch(&refs).unwrap();
        // Auto path: io_uring where the kernel permits it, fallback
        // elsewhere — bytes must be identical either way.
        set_disk_uring(true);
        let auto = pool.read_batch(&refs).unwrap();
        for (((_, want), a), b) in entries.iter().zip(&seq).zip(&auto) {
            assert_eq!(a, want);
            assert_eq!(b, want);
        }
        let rs = pool.read_stats();
        assert_eq!(rs.ops, 2 * entries.len() as u64, "per-bucket accounting on both paths");
    }

    #[test]
    fn capacity_enforced() {
        let (r, w) = models();
        let pool = DiskPool::in_temp(100, r, w).unwrap();
        pool.append(Codec::Fp8E4M3, 60, &vec![0u8; 60]).unwrap();
        assert!(pool.append(Codec::Fp8E4M3, 60, &vec![0u8; 60]).is_err(), "should hit capacity");
        assert_eq!(pool.used(), 60, "failed append must roll back");
    }

    #[test]
    fn persistent_pool_survives_drop_and_reopens() {
        let (r, w) = models();
        let path = std::env::temp_dir()
            .join(format!("zo2-disk-persist-{}.pool", std::process::id()));
        let payload: Vec<u8> = (0..64u8).collect();
        let (off, codec, numel) = {
            let pool = DiskPool::create_persistent(path.clone(), u64::MAX, r, w).unwrap();
            let e = pool.append(Codec::Fp8E4M3, 64, &payload).unwrap();
            (e.offset(), e.codec(), e.numel())
        };
        assert!(path.is_file(), "persistent pool must survive drop");
        let pool = DiskPool::open_persistent(path.clone(), r, w).unwrap();
        assert_eq!(pool.used(), 64, "reopen resumes the append cursor at file end");
        let bucket = DiskBucket::at(codec, numel, off);
        assert_eq!(pool.read(&bucket).unwrap(), payload, "bytes survive the process boundary");
        // Appends after reopen land behind the existing buckets.
        let e2 = pool.append(Codec::Fp8E4M3, 8, &[9u8; 8]).unwrap();
        assert_eq!(e2.offset(), 64);
        drop(pool);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn pool_file_removed_on_drop() {
        let (r, w) = models();
        let pool = DiskPool::in_temp(u64::MAX, r, w).unwrap();
        let path = pool.path().to_path_buf();
        pool.append(Codec::F32, 4, &[0u8; 16]).unwrap();
        assert!(path.is_file());
        drop(pool);
        assert!(!path.exists(), "pool file should be cleaned up");
    }

    #[test]
    fn dram_window_enforces_slots_and_tracks_peaks() {
        let win = DramWindow::new(2, 100);
        win.acquire(100).unwrap();
        win.acquire(100).unwrap();
        assert!(win.acquire(100).is_err(), "third slot must be refused");
        assert_eq!(win.peak_slots(), 2);
        assert_eq!(win.peak_bytes(), 200);
        win.release(100);
        win.acquire(100).unwrap();
        assert_eq!(win.peak_slots(), 2, "peak unchanged by steady streaming");
        win.release(100);
        win.release(100);
        assert_eq!(win.capacity_bytes(), 200);
    }
}
