//! Interconnect (PCIe) transfer model.
//!
//! The paper's testbed moves buckets over PCIe between CPU DDR and GPU HBM.
//! Here the *data movement itself* is real (decode/encode between the host
//! bucket's wire format and the device-side f32 slot — the actual bytes the
//! paper would push over PCIe), while the *time* a PCIe link would take is
//! given by a linear latency + bandwidth model.  Real-mode engines can
//! optionally throttle to that model so overlap behaviour is observable at
//! tiny scale; the discrete-event simulator uses it directly.

/// Linear cost model of one direction of the interconnect.
#[derive(Debug, Clone, Copy)]
pub struct TransferModel {
    /// Per-operation latency (s) — driver/DMA setup.
    pub latency_s: f64,
    /// Sustained bandwidth (bytes/s).
    pub bytes_per_s: f64,
}

impl TransferModel {
    /// PCIe 4.0 x16 effective: ~16 GB/s sustained, ~10 µs per op.
    pub fn pcie4() -> Self {
        Self { latency_s: 10e-6, bytes_per_s: 16e9 }
    }

    /// PCIe 4.0 x4 NVMe, sequential read: ~6.8 GB/s, ~100 µs per op
    /// (submission + flash read latency).
    pub fn nvme_read() -> Self {
        Self { latency_s: 100e-6, bytes_per_s: 6.8e9 }
    }

    /// PCIe 4.0 x4 NVMe, sustained sequential write: ~5 GB/s (post-SLC-cache
    /// rate on datacenter drives), ~100 µs per op.
    pub fn nvme_write() -> Self {
        Self { latency_s: 100e-6, bytes_per_s: 5.0e9 }
    }

    /// Scale to a target sustained bandwidth in GB/s (CLI `--nvme-gbps`).
    pub fn with_gbps(self, gbps: f64) -> Self {
        Self { bytes_per_s: gbps * 1e9, ..self }
    }

    pub fn time_for(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bytes_per_s
    }
}

/// Byte-accounting transfer engine shared by both directions.
#[derive(Debug, Default)]
pub struct TransferEngine {
    pub h2d: TransferStats,
    pub d2h: TransferStats,
}

#[derive(Debug, Default, Clone)]
pub struct TransferStats {
    pub ops: u64,
    pub bytes: u64,
    /// Modelled interconnect seconds (not wallclock).
    pub modeled_s: f64,
}

impl TransferEngine {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_h2d(&mut self, bytes: u64, model: &TransferModel) {
        self.h2d.ops += 1;
        self.h2d.bytes += bytes;
        self.h2d.modeled_s += model.time_for(bytes);
        if crate::telemetry::metrics::enabled() {
            let labels = [("dir", "h2d")];
            crate::telemetry::metrics::counter_add("transfer_bytes_total", &labels, bytes);
        }
    }

    pub fn record_d2h(&mut self, bytes: u64, model: &TransferModel) {
        self.d2h.ops += 1;
        self.d2h.bytes += bytes;
        self.d2h.modeled_s += model.time_for(bytes);
        if crate::telemetry::metrics::enabled() {
            let labels = [("dir", "d2h")];
            crate::telemetry::metrics::counter_add("transfer_bytes_total", &labels, bytes);
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.h2d.bytes + self.d2h.bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_model() {
        let m = TransferModel { latency_s: 1e-5, bytes_per_s: 1e9 };
        assert!((m.time_for(0) - 1e-5).abs() < 1e-12);
        assert!((m.time_for(1_000_000_000) - 1.00001).abs() < 1e-9);
    }

    #[test]
    fn accounting() {
        let m = TransferModel::pcie4();
        let mut e = TransferEngine::new();
        e.record_h2d(1 << 20, &m);
        e.record_h2d(1 << 20, &m);
        e.record_d2h(1 << 10, &m);
        assert_eq!(e.h2d.ops, 2);
        assert_eq!(e.h2d.bytes, 2 << 20);
        assert_eq!(e.d2h.ops, 1);
        assert_eq!(e.total_bytes(), (2 << 20) + (1 << 10));
        assert!(e.h2d.modeled_s > 0.0);
    }
}
