//! Tiered memory substrate: host "CPU DDR" pool, device "GPU HBM" pool,
//! the disk (NVMe) tier below DDR, communication buckets (§5.3), the
//! reusable block buffer (§5.3) and the transfer engine with its PCIe cost
//! model.
//!
//! The real testbed has no GPU, so the *device* tier is an accounted region
//! of host memory: every allocation that would live in HBM is registered
//! with [`DevicePool`], which enforces a capacity, tracks the peak (the
//! numbers in paper Fig. 1 / Table 2) and charges a per-allocation latency
//! when the reusable buffer is disabled (the Table 4 "no reusable memory"
//! ablation — cudaMalloc is what that feature removes).  The disk tier
//! ([`DiskPool`]) is file-backed for real: spilled buckets round-trip
//! through an actual pool file, staged through the accounted [`DramWindow`].

pub mod disk;
pub mod transfer;
#[cfg(target_os = "linux")]
pub(crate) mod uring;

pub use disk::{DiskBucket, DiskPool, DramWindow};
pub use transfer::{TransferEngine, TransferModel};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::hostpool::HostPool;
use crate::precision::Codec;

/// A host-side parameter bucket: the master copy of one module's parameters
/// in the storage format of the current mode (fp32, or compressed when AMP
/// low-bit transfer compression is on — paper §5.5 keeps the *CPU-side*
/// copy in the wire format and restores fp32 on the GPU).
#[derive(Debug, Clone)]
pub struct HostBucket {
    codec: Codec,
    numel: usize,
    bytes: Vec<u8>,
}

impl HostBucket {
    /// Create from an fp32 master copy, encoding into `codec`.
    pub fn from_f32(data: &[f32], codec: Codec) -> Self {
        let mut bytes = Vec::new();
        codec.encode_into(data, &mut bytes);
        Self { codec, numel: data.len(), bytes }
    }

    /// Rebuild from wire-format bytes (e.g. read back from the disk tier).
    pub fn from_wire(codec: Codec, numel: usize, bytes: Vec<u8>) -> Self {
        assert_eq!(bytes.len(), numel * codec.bytes_per_el(), "wire payload size");
        Self { codec, numel, bytes }
    }

    /// Shape-only stand-in for a bucket whose bytes live on the disk tier.
    /// Keeps `numel`/`codec` queries valid while the payload is spilled;
    /// decoding a placeholder is a bug (guard with [`Self::is_materialized`]).
    pub fn placeholder(codec: Codec, numel: usize) -> Self {
        Self { codec, numel, bytes: Vec::new() }
    }

    /// Whether the encoded payload is DRAM-resident (false for spilled
    /// placeholders).
    pub fn is_materialized(&self) -> bool {
        self.numel == 0 || !self.bytes.is_empty()
    }

    /// Wire-format payload (what crosses PCIe, and what the disk tier
    /// stores verbatim).
    pub fn wire(&self) -> &[u8] {
        &self.bytes
    }

    pub fn numel(&self) -> usize {
        self.numel
    }

    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Bytes that cross the interconnect when this bucket is transferred.
    pub fn wire_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Decode ("upload + decompress on GPU") into a device-side f32 slot.
    pub fn decode_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.numel);
        self.codec.decode_into(&self.bytes, out);
    }

    /// Encode ("compress + offload to CPU") from a device-side f32 slot.
    pub fn encode_from(&mut self, src: &[f32]) {
        assert_eq!(src.len(), self.numel);
        self.codec.encode_into(src, &mut self.bytes);
    }

    /// Pooled decode across the host compute pool — bit-identical to
    /// [`Self::decode_into`] at any thread count.
    pub fn decode_into_pooled(&self, out: &mut [f32], pool: &HostPool) {
        assert_eq!(out.len(), self.numel);
        crate::hostpool::fused::decode_pooled(self.codec, &self.bytes, out, pool);
    }

    /// Pooled encode — byte-identical to [`Self::encode_from`] at any
    /// thread count, with the same capacity shrink policy.
    pub fn encode_from_pooled(&mut self, src: &[f32], pool: &HostPool) {
        assert_eq!(src.len(), self.numel);
        let need = self.numel * self.codec.bytes_per_el();
        if self.bytes.len() != need {
            // Size change only (never on the steady offload path): one
            // zero-fill pass before the pooled encode overwrites it.
            self.bytes.clear();
            self.bytes.resize(need, 0);
        }
        crate::hostpool::fused::encode_pooled(self.codec, src, &mut self.bytes, pool);
        crate::util::shrink_excess(&mut self.bytes, need);
    }

    /// Apply a deferred ZO-SGD update *in the wire domain*: one fused
    /// decode→update→encode pass per chunk over the host pool, never
    /// materialising the bucket in fp32 (the CPU update site's hot path).
    pub fn fused_sgd_update(
        &mut self,
        state: crate::rng::RngState,
        lr: f32,
        g: f32,
        pool: &HostPool,
    ) {
        crate::hostpool::fused::fused_zo_sgd(
            self.codec,
            &mut self.bytes,
            self.numel,
            state,
            lr,
            g,
            pool,
        );
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.codec.decode(&self.bytes, self.numel)
    }

    /// Pooled [`Self::to_f32`].
    pub fn to_f32_pooled(&self, pool: &HostPool) -> Vec<f32> {
        let mut out = vec![0.0f32; self.numel];
        self.decode_into_pooled(&mut out, pool);
        out
    }
}

/// Accounted "GPU HBM" region with capacity enforcement and peak tracking.
#[derive(Debug)]
pub struct DevicePool {
    capacity: u64,
    used: AtomicU64,
    peak: AtomicU64,
    allocs: AtomicU64,
}

impl DevicePool {
    pub fn new(capacity_bytes: u64) -> Arc<Self> {
        Arc::new(Self {
            capacity: capacity_bytes,
            used: AtomicU64::new(0),
            peak: AtomicU64::new(0),
            allocs: AtomicU64::new(0),
        })
    }

    pub fn unlimited() -> Arc<Self> {
        Self::new(u64::MAX)
    }

    pub fn alloc(&self, bytes: u64) -> Result<()> {
        let prev = self.used.fetch_add(bytes, Ordering::SeqCst);
        let now = prev + bytes;
        if now > self.capacity {
            self.used.fetch_sub(bytes, Ordering::SeqCst);
            bail!(
                "device OOM: {} + {} exceeds capacity {} (simulated HBM)",
                prev, bytes, self.capacity
            );
        }
        self.peak.fetch_max(now, Ordering::SeqCst);
        self.allocs.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }

    pub fn free(&self, bytes: u64) {
        self.used.fetch_sub(bytes, Ordering::SeqCst);
    }

    pub fn used(&self) -> u64 {
        self.used.load(Ordering::SeqCst)
    }

    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::SeqCst)
    }

    pub fn alloc_count(&self) -> u64 {
        self.allocs.load(Ordering::SeqCst)
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }
}

/// The §5.3 reusable block buffer: `slots` pre-allocated block-sized f32
/// regions on the device, assigned round-robin to in-flight blocks.  With
/// the feature disabled each acquisition is a fresh device allocation that
/// the cost model charges cudaMalloc latency for.
pub struct ReusableBlockBuffer {
    pool: Arc<DevicePool>,
    numel: usize,
    slots: Vec<Vec<f32>>,
    reusable: bool,
}

impl ReusableBlockBuffer {
    /// `numel` — block bucket size; `n_slots` — in-flight blocks
    /// (compute + prefetch + offload = 3 for the full dynamic scheduler).
    pub fn new(pool: Arc<DevicePool>, numel: usize, n_slots: usize, reusable: bool) -> Result<Self> {
        let mut slots = Vec::new();
        if reusable {
            // One up-front allocation, held for the lifetime of training.
            pool.alloc((numel * n_slots * 4) as u64)?;
            for _ in 0..n_slots {
                slots.push(vec![0.0f32; numel]);
            }
        }
        Ok(Self { pool, numel, slots, reusable })
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    pub fn reusable(&self) -> bool {
        self.reusable
    }

    /// Take the slot for block position `i` (round-robin). In non-reusable
    /// mode this is a fresh allocation (caller charges malloc latency).
    pub fn acquire(&mut self, i: usize) -> Result<Vec<f32>> {
        if self.reusable {
            let n = self.slots.len();
            Ok(std::mem::take(&mut self.slots[i % n]))
        } else {
            self.pool.alloc((self.numel * 4) as u64)?;
            Ok(vec![0.0f32; self.numel])
        }
    }

    /// Return a slot after its block was offloaded.
    pub fn release(&mut self, i: usize, buf: Vec<f32>) {
        if self.reusable {
            let n = self.slots.len();
            self.slots[i % n] = buf;
        } else {
            self.pool.free((self.numel * 4) as u64);
            drop(buf);
        }
    }
}

impl Drop for ReusableBlockBuffer {
    fn drop(&mut self) {
        if self.reusable {
            self.pool.free((self.numel * self.slots.capacity().max(self.slots.len()) * 4) as u64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_bucket_roundtrip_f32_exact() {
        let data: Vec<f32> = (0..100).map(|i| i as f32 * 0.3).collect();
        let hb = HostBucket::from_f32(&data, Codec::F32);
        assert_eq!(hb.wire_bytes(), 400);
        assert_eq!(hb.to_f32(), data);
    }

    #[test]
    fn host_bucket_compressed_wire_volume() {
        let data = vec![0.5f32; 1000];
        assert_eq!(HostBucket::from_f32(&data, Codec::Bf16).wire_bytes(), 2000);
        assert_eq!(HostBucket::from_f32(&data, Codec::Fp8E4M3).wire_bytes(), 1000);
        // 0.5 is exactly representable everywhere.
        assert_eq!(HostBucket::from_f32(&data, Codec::Fp8E4M3).to_f32(), data);
    }

    #[test]
    fn host_bucket_wire_rebuild_and_placeholder() {
        let data: Vec<f32> = (0..64).map(|i| i as f32 * 0.5 - 3.0).collect();
        let hb = HostBucket::from_f32(&data, Codec::Bf16);
        let rebuilt = HostBucket::from_wire(Codec::Bf16, data.len(), hb.wire().to_vec());
        assert_eq!(rebuilt.to_f32(), hb.to_f32());
        assert!(rebuilt.is_materialized());
        let ph = HostBucket::placeholder(Codec::Bf16, data.len());
        assert!(!ph.is_materialized());
        assert_eq!(ph.numel(), data.len());
        assert_eq!(ph.codec(), Codec::Bf16);
    }

    #[test]
    fn device_pool_enforces_capacity_and_tracks_peak() {
        let p = DevicePool::new(1000);
        p.alloc(600).unwrap();
        p.alloc(300).unwrap();
        assert!(p.alloc(200).is_err(), "should OOM");
        assert_eq!(p.used(), 900);
        p.free(300);
        assert_eq!(p.used(), 600);
        assert_eq!(p.peak(), 900);
        assert_eq!(p.alloc_count(), 2);
    }

    #[test]
    fn reusable_buffer_constant_memory() {
        let p = DevicePool::new(10_000_000);
        let mut rb = ReusableBlockBuffer::new(p.clone(), 1000, 3, true).unwrap();
        let base = p.used();
        for i in 0..10 {
            let buf = rb.acquire(i).unwrap();
            assert_eq!(p.used(), base, "reusable: no per-step allocations");
            rb.release(i, buf);
        }
        assert_eq!(p.alloc_count(), 1, "single up-front allocation");
    }

    #[test]
    fn non_reusable_buffer_allocates_per_acquire() {
        let p = DevicePool::new(10_000_000);
        let mut rb = ReusableBlockBuffer::new(p.clone(), 1000, 3, false).unwrap();
        for i in 0..5 {
            let buf = rb.acquire(i).unwrap();
            rb.release(i, buf);
        }
        assert_eq!(p.alloc_count(), 5);
        assert_eq!(p.used(), 0);
    }
}
