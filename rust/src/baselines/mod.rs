//! First-order offloading baselines (paper §4.1, Fig. 3, Fig. 1).
//!
//! The paper motivates ZO2 by the *communication structure* of first-order
//! offloading: every block's parameters must be on the GPU twice per step
//! (forward + backward), activations must round-trip, and gradients (same
//! size as parameters) must move for the optimizer step.  We model that
//! structure analytically — the point of these baselines is transfer volume
//! and schedule shape, not FO numerics (which ZO2 never runs).

use crate::costmodel::Workload;

/// Per-step interconnect traffic (bytes) for one strategy, per §4.1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommVolume {
    pub h2d: u64,
    pub d2h: u64,
}

impl CommVolume {
    pub fn total(&self) -> u64 {
        self.h2d + self.d2h
    }
}

/// ZO2: each block crosses once per direction per step (§5.4 deferred
/// update fuses the update into the same cycle).
pub fn zo2_comm_per_step(wl: &Workload) -> CommVolume {
    let blocks = wl.shape.n_layers as u64;
    let wire = wl.block_wire_bytes();
    CommVolume { h2d: blocks * wire, d2h: blocks * wire }
}

/// First-order offloading (§4.1): parameters uploaded for forward AND
/// backward; activations offloaded during forward and re-uploaded for
/// backward; gradients offloaded; updated params re-uploaded next step
/// (counted via the double parameter upload).
pub fn first_order_comm_per_step(wl: &Workload) -> CommVolume {
    let blocks = wl.shape.n_layers as u64;
    let pbytes = (wl.shape.block_params() * 4) as u64;
    let b = wl.batch as u64;
    let t = wl.seq as u64;
    let d = wl.shape.d_model as u64;
    let f = wl.shape.d_ffn() as u64;
    let h = wl.shape.n_heads as u64;
    // Retained activations per block (hidden + attn probs + ffn mid), fp32.
    let act = b * t * d * 4 + b * h * t * t * 4 + b * t * f * 4;
    CommVolume {
        // params twice (fwd + bwd) per block; activations re-uploaded for bwd
        h2d: blocks * (2 * pbytes + act),
        // activations offloaded after fwd; gradients offloaded after bwd
        d2h: blocks * (act + pbytes),
    }
}

/// Communication *operations* per block per step (Fig. 3's "multiple
/// communication operations" point).
pub fn comm_ops_per_block(first_order: bool) -> u64 {
    if first_order {
        // fwd upload, act offload, act upload, bwd upload(param), grad offload
        5
    } else {
        // ZO2: one upload + one offload
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::ComputeMode;
    use crate::model::opt_by_name;
    use crate::precision::Codec;

    fn wl() -> Workload {
        Workload {
            shape: opt_by_name("OPT-1.3B").unwrap(),
            batch: 1,
            seq: 2048,
            wire: Codec::F32,
            compute: ComputeMode::Fp32,
        }
    }

    #[test]
    fn first_order_moves_far_more_data() {
        let w = wl();
        let zo = zo2_comm_per_step(&w);
        let fo = first_order_comm_per_step(&w);
        assert!(fo.total() > 2 * zo.total(),
                "FO {} should be >2x ZO2 {}", fo.total(), zo.total());
        assert!(fo.h2d > 2 * zo.h2d, "param double-upload plus activations");
    }

    #[test]
    fn zo2_comm_is_exactly_param_volume_both_ways() {
        let w = wl();
        let zo = zo2_comm_per_step(&w);
        let expect = (w.shape.n_layers * w.shape.block_params() * 4) as u64;
        assert_eq!(zo.h2d, expect);
        assert_eq!(zo.d2h, expect);
    }

    #[test]
    fn op_counts() {
        assert_eq!(comm_ops_per_block(true), 5);
        assert_eq!(comm_ops_per_block(false), 2);
    }
}
