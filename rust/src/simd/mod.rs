//! Runtime-dispatched SIMD host kernels (`--host-simd auto|off`).
//!
//! The host-side roofline of ZO2 is the chunked decode→ZO-update→encode
//! loops in [`crate::hostpool`] / [`crate::zo`] and the Gaussian `z` fill
//! feeding them — at paper scale, loops over ~1e11 elements per step.  This
//! module vectorises them with explicit AVX2 intrinsics behind *runtime*
//! CPU-feature detection; the scalar loops remain the always-available
//! fallback and the specification.
//!
//! # Bit-identity contract
//!
//! Every vector kernel is constructed to be **bit-identical** to its scalar
//! reference, so `--host-simd auto` and `--host-simd off` produce the same
//! trajectory:
//!
//! * codec decodes gather from the *same* LUTs the scalar path indexes;
//! * bf16/fp16 encodes are pure integer arithmetic mirroring the scalar
//!   bit-twiddling (NaN lanes patched through the scalar reference; fp8
//!   encode stays scalar — its subnormal rounding is branchy and fp8 is
//!   compute-light anyway);
//! * update kernels use only IEEE-exact ops (mul/add/sub/div/sqrt — never
//!   FMA, which would change rounding) in the scalar op order;
//! * the Gaussian fill mirrors the shared [`crate::rng::fastmath`]
//!   polynomials one vector instruction per scalar op.
//!
//! The 16Ki-element chunk grid is a multiple of every lane width used here
//! (8 × f32 / 4 × f64), so full chunks split evenly into vector iterations;
//! tail elements (only ever in a bucket's last chunk) take the scalar path,
//! which is bit-for-bit the same math.
//!
//! The mode is a process-wide switch (like [`crate::telemetry::metrics`]):
//! the CLI sets it once at startup; tests may toggle it, which is race-free
//! *because* both paths produce identical bytes.

use std::sync::atomic::{AtomicU8, Ordering};

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;

/// CLI-selectable dispatch policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimdMode {
    /// Use the best instruction set the CPU reports (scalar if none).
    #[default]
    Auto,
    /// Force the scalar reference path.
    Off,
}

impl SimdMode {
    pub fn parse(s: &str) -> Option<SimdMode> {
        match s {
            "auto" | "on" => Some(SimdMode::Auto),
            "off" | "scalar" => Some(SimdMode::Off),
            _ => None,
        }
    }
}

/// Resolved instruction set for one kernel invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    Scalar,
    Avx2,
}

static MODE: AtomicU8 = AtomicU8::new(0); // 0 = Auto, 1 = Off

/// Set the process-wide dispatch mode (`--host-simd`).
pub fn set_mode(mode: SimdMode) {
    MODE.store(matches!(mode, SimdMode::Off) as u8, Ordering::Relaxed);
}

pub fn mode() -> SimdMode {
    if MODE.load(Ordering::Relaxed) == 0 {
        SimdMode::Auto
    } else {
        SimdMode::Off
    }
}

/// Whether this CPU can run the AVX2 kernels at all (independent of the
/// mode switch).  Detection is cached by the standard library.
pub fn avx2_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_64_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The level the current mode resolves to on this CPU.
pub fn level() -> SimdLevel {
    if mode() == SimdMode::Auto && avx2_supported() {
        SimdLevel::Avx2
    } else {
        SimdLevel::Scalar
    }
}

/// True when kernels should take the vector path.
#[inline]
pub fn active() -> bool {
    level() == SimdLevel::Avx2
}

/// Bulk-fill the leading multiple-of-8 elements of `out` with the Gaussian
/// stream starting at `state`, returning how many elements were written
/// (0 when the vector path is off/unsupported).  The caller advances its
/// counter by `written / 2` and finishes the tail with the scalar pair
/// loop — which lands on exactly the same values the vector path would.
pub(crate) fn fill_gaussian_bulk(state: crate::rng::RngState, out: &mut [f32]) -> usize {
    #[cfg(target_arch = "x86_64")]
    {
        if active() && out.len() >= 8 {
            let m8 = out.len() / 8 * 8;
            // Safety: AVX2 availability is checked by `active()`.
            unsafe { avx2::fill_gaussian(state, &mut out[..m8]) };
            return m8;
        }
    }
    let _ = (state, out);
    0
}

/// Vectorised in-place `w[i] -= scale·z[i]` when active; `false` asks the
/// caller to run the scalar loop instead.
pub(crate) fn try_sgd_update(w: &mut [f32], z: &[f32], scale: f32) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if active() {
            // Safety: AVX2 availability is checked by `active()`.
            unsafe { avx2::sgd_update(w, z, scale) };
            return true;
        }
    }
    let _ = (w, z, scale);
    false
}

/// Vectorised in-place fused ZO-AdamW step when active; `false` asks the
/// caller to run the scalar loop instead.
#[allow(clippy::too_many_arguments)]
pub(crate) fn try_adamw_update(
    w: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    z: &[f32],
    g: f32,
    hp: crate::zo::AdamHp,
    b1t: f32,
    b2t: f32,
) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if active() {
            // Safety: AVX2 availability is checked by `active()`.
            unsafe { avx2::adamw_update(w, m, v, z, g, hp, b1t, b2t) };
            return true;
        }
    }
    let _ = (w, m, v, z, g, hp, b1t, b2t);
    false
}
