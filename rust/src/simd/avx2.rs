//! AVX2 kernels — each bit-identical to its scalar reference.
//!
//! Everything in this file is `unsafe fn` + `#[target_feature(enable =
//! "avx2")]`: callers (all inside this crate) must have checked
//! [`super::avx2_supported`] first — [`super::active`] and the
//! `*_chunk_with` codec entry points do.
//!
//! Identity arguments, kernel by kernel:
//!
//! * **decodes** — fp16/fp8 gather from the same `OnceLock` LUTs the scalar
//!   loops index (identical by construction); bf16 is `bits << 16`, pure
//!   integer.
//! * **bf16 encode** — the scalar round-to-nearest-even is three integer
//!   adds and a shift; integer vector ops are exact, NaN lanes blend to the
//!   scalar's quieten-and-truncate result.
//! * **fp16 encode** — mirrors the class-table encoder through u32-widened
//!   tables.  All intermediate sums fit in 16 bits (max `base + shifted` is
//!   0xFBFF), so u32 lane adds equal the scalar's wrapping u16 adds.  The
//!   `u32::MAX` "never rounds" sentinel survives the unsigned-compare trick
//!   (sign-flip + signed compare maps it to `i32::MAX`, which no remainder
//!   exceeds).  NaN lanes are patched via the scalar reference (rare).
//! * **updates** — mul/add/sub/div/sqrt only (no FMA: it would change
//!   rounding), in the scalar op order; `_mm256_sqrt_ps` and `_mm256_cvtpd_ps`
//!   are IEEE-correctly-rounded like their scalar counterparts.
//! * **Gaussian fill** — SplitMix64 on 64-bit lanes (32×32 partial
//!   products), then the [`crate::rng::fastmath`] polynomials one vector op
//!   per scalar op with the same constants.  Negation = sign-bit XOR
//!   (exact), u32→f64 by the 2⁵² magic-number trick (exact).

use std::arch::x86_64::*;

use crate::precision::{self, Codec};
use crate::rng::{fastmath, RngState};
use crate::zo::AdamHp;

// --- 64-bit lane helpers -------------------------------------------------------

/// `(a * b) mod 2^64` per lane: AVX2 has no 64-bit multiply, so assemble it
/// from 32×32→64 partial products (the high×high term shifts out).
// SAFETY: pure register arithmetic — no memory access; callers are
// themselves `avx2` target-feature fns, so the intrinsics are available.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn mul64(a: __m256i, b: __m256i) -> __m256i {
    let lo_lo = _mm256_mul_epu32(a, b);
    let a_hi = _mm256_srli_epi64::<32>(a);
    let b_hi = _mm256_srli_epi64::<32>(b);
    let cross = _mm256_add_epi64(_mm256_mul_epu32(a_hi, b), _mm256_mul_epu32(a, b_hi));
    _mm256_add_epi64(lo_lo, _mm256_slli_epi64::<32>(cross))
}

/// Four independent SplitMix64 finalisations — same constants and op order
/// as the scalar `splitmix64`.
// SAFETY: register-only; unsafe solely for the avx2 target-feature, which
// every caller in this module already carries.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn splitmix64x4(x: __m256i) -> __m256i {
    let x = _mm256_add_epi64(x, _mm256_set1_epi64x(0x9E37_79B9_7F4A_7C15u64 as i64));
    let x = _mm256_xor_si256(x, _mm256_srli_epi64::<30>(x));
    let x = mul64(x, _mm256_set1_epi64x(0xBF58_476D_1CE4_E5B9u64 as i64));
    let x = _mm256_xor_si256(x, _mm256_srli_epi64::<27>(x));
    let x = mul64(x, _mm256_set1_epi64x(0x94D0_49BB_1331_11EBu64 as i64));
    _mm256_xor_si256(x, _mm256_srli_epi64::<31>(x))
}

/// Exact u64-lane (< 2³²) → f64 conversion: OR the value into the mantissa
/// of 2⁵² and subtract 2⁵² (both steps exact).
// SAFETY: register-only bit manipulation; avx2 guaranteed by the callers'
// own target-feature attributes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn u32s_to_f64(v: __m256i) -> __m256d {
    let magic = _mm256_set1_epi64x(0x4330_0000_0000_0000u64 as i64);
    _mm256_sub_pd(
        _mm256_castsi256_pd(_mm256_or_si256(v, magic)),
        _mm256_set1_pd(fastmath::EXP52),
    )
}

// --- fastmath mirrors ----------------------------------------------------------

/// Vector mirror of [`fastmath::ln`]: same decomposition, same constants,
/// one vector instruction per scalar op.
// SAFETY: register-only polynomial evaluation; avx2 guaranteed by callers.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn ln4(x: __m256d) -> __m256d {
    let bits = _mm256_castpd_si256(x);
    // Raw exponent (sign bit clear: x > 0) to f64 via the magic-number
    // trick, bias folded into the one exact subtraction.
    let e_raw = _mm256_srli_epi64::<52>(bits);
    let magic = _mm256_set1_epi64x(0x4330_0000_0000_0000u64 as i64);
    let mut e = _mm256_sub_pd(
        _mm256_castsi256_pd(_mm256_or_si256(e_raw, magic)),
        _mm256_set1_pd(fastmath::EXP52 + 1023.0),
    );
    let mant = _mm256_and_si256(bits, _mm256_set1_epi64x(0x000F_FFFF_FFFF_FFFFu64 as i64));
    let mut m =
        _mm256_castsi256_pd(_mm256_or_si256(mant, _mm256_set1_epi64x(0x3FF0_0000_0000_0000u64 as i64)));
    // if m > sqrt(2) { m *= 0.5; e += 1.0 } — both arms exact, blended.
    let fold = _mm256_cmp_pd::<_CMP_GT_OQ>(m, _mm256_set1_pd(std::f64::consts::SQRT_2));
    m = _mm256_blendv_pd(m, _mm256_mul_pd(m, _mm256_set1_pd(0.5)), fold);
    e = _mm256_add_pd(e, _mm256_and_pd(fold, _mm256_set1_pd(1.0)));
    let one = _mm256_set1_pd(1.0);
    let s = _mm256_div_pd(_mm256_sub_pd(m, one), _mm256_add_pd(m, one));
    let s2 = _mm256_mul_pd(s, s);
    let mut p = _mm256_set1_pd(fastmath::LN_P6);
    p = _mm256_add_pd(_mm256_mul_pd(p, s2), _mm256_set1_pd(fastmath::LN_P5));
    p = _mm256_add_pd(_mm256_mul_pd(p, s2), _mm256_set1_pd(fastmath::LN_P4));
    p = _mm256_add_pd(_mm256_mul_pd(p, s2), _mm256_set1_pd(fastmath::LN_P3));
    p = _mm256_add_pd(_mm256_mul_pd(p, s2), _mm256_set1_pd(fastmath::LN_P2));
    p = _mm256_add_pd(_mm256_mul_pd(p, s2), _mm256_set1_pd(fastmath::LN_P1));
    p = _mm256_add_pd(_mm256_mul_pd(p, s2), _mm256_set1_pd(fastmath::LN_P0));
    _mm256_add_pd(
        _mm256_mul_pd(e, _mm256_set1_pd(std::f64::consts::LN_2)),
        _mm256_mul_pd(s, p),
    )
}

/// Vector mirror of [`fastmath::sincos_2pi`].  Quadrant selection is
/// blend + sign-bit XOR, both exact, so it equals the scalar `match`.
// SAFETY: register-only polynomial evaluation; avx2 guaranteed by callers.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn sincos_2pi4(u: __m256d) -> (__m256d, __m256d) {
    let t = _mm256_mul_pd(u, _mm256_set1_pd(4.0));
    let q = _mm256_floor_pd(t);
    let a = _mm256_mul_pd(_mm256_sub_pd(t, q), _mm256_set1_pd(std::f64::consts::FRAC_PI_2));
    let a2 = _mm256_mul_pd(a, a);
    let mut sp = _mm256_set1_pd(fastmath::SIN_C6);
    sp = _mm256_add_pd(_mm256_mul_pd(sp, a2), _mm256_set1_pd(fastmath::SIN_C5));
    sp = _mm256_add_pd(_mm256_mul_pd(sp, a2), _mm256_set1_pd(fastmath::SIN_C4));
    sp = _mm256_add_pd(_mm256_mul_pd(sp, a2), _mm256_set1_pd(fastmath::SIN_C3));
    sp = _mm256_add_pd(_mm256_mul_pd(sp, a2), _mm256_set1_pd(fastmath::SIN_C2));
    sp = _mm256_add_pd(_mm256_mul_pd(sp, a2), _mm256_set1_pd(fastmath::SIN_C1));
    sp = _mm256_add_pd(_mm256_mul_pd(sp, a2), _mm256_set1_pd(fastmath::SIN_C0));
    let sp = _mm256_mul_pd(a, sp);
    let mut cp = _mm256_set1_pd(fastmath::COS_C7);
    cp = _mm256_add_pd(_mm256_mul_pd(cp, a2), _mm256_set1_pd(fastmath::COS_C6));
    cp = _mm256_add_pd(_mm256_mul_pd(cp, a2), _mm256_set1_pd(fastmath::COS_C5));
    cp = _mm256_add_pd(_mm256_mul_pd(cp, a2), _mm256_set1_pd(fastmath::COS_C4));
    cp = _mm256_add_pd(_mm256_mul_pd(cp, a2), _mm256_set1_pd(fastmath::COS_C3));
    cp = _mm256_add_pd(_mm256_mul_pd(cp, a2), _mm256_set1_pd(fastmath::COS_C2));
    cp = _mm256_add_pd(_mm256_mul_pd(cp, a2), _mm256_set1_pd(fastmath::COS_C1));
    cp = _mm256_add_pd(_mm256_mul_pd(cp, a2), _mm256_set1_pd(fastmath::COS_C0));
    // Quadrant map: q0 (s,c)  q1 (c,-s)  q2 (-s,-c)  q3 (-c,s).
    let one = _mm256_set1_pd(1.0);
    let two = _mm256_set1_pd(2.0);
    let swap = _mm256_or_pd(
        _mm256_cmp_pd::<_CMP_EQ_OQ>(q, one),
        _mm256_cmp_pd::<_CMP_EQ_OQ>(q, _mm256_set1_pd(3.0)),
    );
    let sin_sel = _mm256_blendv_pd(sp, cp, swap);
    let cos_sel = _mm256_blendv_pd(cp, sp, swap);
    let sign = _mm256_set1_pd(-0.0);
    let neg_sin = _mm256_and_pd(_mm256_cmp_pd::<_CMP_GE_OQ>(q, two), sign);
    let neg_cos = _mm256_and_pd(
        _mm256_and_pd(_mm256_cmp_pd::<_CMP_GE_OQ>(q, one), _mm256_cmp_pd::<_CMP_LE_OQ>(q, two)),
        sign,
    );
    (_mm256_xor_pd(sin_sel, neg_sin), _mm256_xor_pd(cos_sel, neg_cos))
}

// --- Gaussian fill -------------------------------------------------------------

/// Fill `out` (length a multiple of 8) with the Gaussian stream from
/// `state` — bit-identical to the scalar `fill_gaussian` pair loop.  Four
/// counter ticks per iteration, each yielding an interleaved (cos, sin)
/// pair, exactly like the scalar layout `out[2j], out[2j+1]`.
///
/// # Safety
/// AVX2 must be available; `out.len() % 8 == 0`.
#[target_feature(enable = "avx2")]
pub unsafe fn fill_gaussian(state: RngState, out: &mut [f32]) {
    debug_assert_eq!(out.len() % 8, 0);
    let k = crate::rng::splitmix64(state.seed ^ crate::rng::splitmix64(state.stream));
    let kv = _mm256_set1_epi64x(k as i64);
    let cmul = _mm256_set1_epi64x(0xD6E8_FEB8_6659_FD93u64 as i64);
    let lo_mask = _mm256_set1_epi64x(0xFFFF_FFFFu64 as i64);
    let one = _mm256_set1_pd(1.0);
    let inv = _mm256_set1_pd(fastmath::INV_2P32);
    let neg_two = _mm256_set1_pd(-2.0);
    let mut counter = state.counter;
    let mut i = 0usize;
    while i < out.len() {
        // Lanes 0..3 = counters c, c+1, c+2, c+3 (set_epi64x is high→low).
        let c = _mm256_set_epi64x(
            counter.wrapping_add(3) as i64,
            counter.wrapping_add(2) as i64,
            counter.wrapping_add(1) as i64,
            counter as i64,
        );
        let v = splitmix64x4(_mm256_xor_si256(kv, mul64(c, cmul)));
        let u1 = _mm256_mul_pd(_mm256_add_pd(u32s_to_f64(_mm256_srli_epi64::<32>(v)), one), inv);
        let u2 = _mm256_mul_pd(u32s_to_f64(_mm256_and_si256(v, lo_mask)), inv);
        let r = _mm256_sqrt_pd(_mm256_mul_pd(neg_two, ln4(u1)));
        let (s, co) = sincos_2pi4(u2);
        let x = _mm256_cvtpd_ps(_mm256_mul_pd(r, co)); // out[2j]   (r·cos)
        let y = _mm256_cvtpd_ps(_mm256_mul_pd(r, s)); // out[2j+1] (r·sin)
        _mm_storeu_ps(out.as_mut_ptr().add(i), _mm_unpacklo_ps(x, y));
        _mm_storeu_ps(out.as_mut_ptr().add(i + 4), _mm_unpackhi_ps(x, y));
        counter = counter.wrapping_add(4);
        i += 8;
    }
}

// --- codec kernels -------------------------------------------------------------

/// Pack 8 u32 lanes (each ≤ 0xFFFF — saturation never fires) into 8 u16
/// and store them little-endian at `dst`.
// SAFETY: the single unaligned store writes exactly 16 bytes at `dst`;
// callers pass pointers into an output slice with ≥ 16 bytes remaining.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn store_u16x8(v: __m256i, dst: *mut u8) {
    let packed = _mm256_packus_epi32(v, v);
    let ordered = _mm256_permute4x64_epi64::<0b1101_1000>(packed);
    _mm_storeu_si128(dst as *mut __m128i, _mm256_castsi256_si128(ordered));
}

/// Vector decode of one chunk — same per-element conversion as the scalar
/// [`Codec::decode_chunk`] body (gathers index the same LUTs).
///
/// # Safety
/// AVX2 must be available; `src.len() == out.len() * codec.bytes_per_el()`.
#[target_feature(enable = "avx2")]
pub unsafe fn decode_chunk(codec: Codec, src: &[u8], out: &mut [f32]) {
    debug_assert_eq!(src.len(), out.len() * codec.bytes_per_el());
    match codec {
        Codec::F32 => {
            std::ptr::copy_nonoverlapping(src.as_ptr(), out.as_mut_ptr() as *mut u8, src.len());
        }
        Codec::Bf16 => {
            let n = out.len();
            let n8 = n / 8 * 8;
            let mut i = 0;
            while i < n8 {
                let codes = _mm_loadu_si128(src.as_ptr().add(2 * i) as *const __m128i);
                let w = _mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(codes));
                _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_castsi256_ps(w));
                i += 8;
            }
            for j in n8..n {
                out[j] = precision::bf16_to_f32(u16::from_le_bytes([src[2 * j], src[2 * j + 1]]));
            }
        }
        Codec::Fp16 => {
            let lut = precision::fp16_lut();
            let base = lut.as_ptr();
            let n = out.len();
            let n8 = n / 8 * 8;
            let mut i = 0;
            while i < n8 {
                let codes = _mm_loadu_si128(src.as_ptr().add(2 * i) as *const __m128i);
                let idx = _mm256_cvtepu16_epi32(codes);
                _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_i32gather_ps::<4>(base, idx));
                i += 8;
            }
            for j in n8..n {
                out[j] = lut[u16::from_le_bytes([src[2 * j], src[2 * j + 1]]) as usize];
            }
        }
        Codec::Fp8E4M3 => {
            let lut = precision::fp8_lut();
            let base = lut.as_ptr();
            let n = out.len();
            let n8 = n / 8 * 8;
            let mut i = 0;
            while i < n8 {
                let codes = _mm_loadl_epi64(src.as_ptr().add(i) as *const __m128i);
                let idx = _mm256_cvtepu8_epi32(codes);
                _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_i32gather_ps::<4>(base, idx));
                i += 8;
            }
            for j in n8..n {
                out[j] = lut[src[j] as usize];
            }
        }
    }
}

/// Vector encode of one chunk — bit-identical to the scalar
/// [`Codec::encode_chunk`] body (fp8 stays on the scalar reference: its
/// subnormal round-ties-even is branchy and the codec is 1 byte/el).
///
/// # Safety
/// AVX2 must be available; `out.len() == src.len() * codec.bytes_per_el()`.
#[target_feature(enable = "avx2")]
pub unsafe fn encode_chunk(codec: Codec, src: &[f32], out: &mut [u8]) {
    debug_assert_eq!(out.len(), src.len() * codec.bytes_per_el());
    match codec {
        Codec::F32 => {
            std::ptr::copy_nonoverlapping(src.as_ptr() as *const u8, out.as_mut_ptr(), out.len());
        }
        Codec::Bf16 => encode_bf16(src, out),
        Codec::Fp16 => encode_fp16(src, out),
        Codec::Fp8E4M3 => {
            for (b, &x) in out.iter_mut().zip(src) {
                *b = precision::f32_to_fp8_e4m3(x);
            }
        }
    }
}

// SAFETY: all loads/stores stay inside `src`/`out` — the vector loop stops
// at the last full 8-lane group (n8 ≤ n, with `out` sized 2 bytes per
// element by `encode_chunk`'s contract) and the scalar tail covers the rest.
#[target_feature(enable = "avx2")]
unsafe fn encode_bf16(src: &[f32], out: &mut [u8]) {
    let n = src.len();
    let n8 = n / 8 * 8;
    let bias = _mm256_set1_epi32(0x7FFF);
    let lsb = _mm256_set1_epi32(1);
    let quiet = _mm256_set1_epi32(0x0040);
    let mut i = 0;
    while i < n8 {
        let x = _mm256_loadu_ps(src.as_ptr().add(i));
        let bits = _mm256_castps_si256(x);
        // Round-to-nearest-even: (bits + 0x7FFF + ((bits >> 16) & 1)) >> 16.
        let round = _mm256_and_si256(_mm256_srli_epi32::<16>(bits), lsb);
        let rne = _mm256_srli_epi32::<16>(_mm256_add_epi32(bits, _mm256_add_epi32(bias, round)));
        // NaN: quieten and truncate, like the scalar branch.
        let nan_out = _mm256_or_si256(_mm256_srli_epi32::<16>(bits), quiet);
        let is_nan = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_UNORD_Q>(x, x));
        store_u16x8(_mm256_blendv_epi8(rne, nan_out, is_nan), out.as_mut_ptr().add(2 * i));
        i += 8;
    }
    for j in n8..n {
        out[2 * j..2 * j + 2].copy_from_slice(&precision::f32_to_bf16(src[j]).to_le_bytes());
    }
}

// SAFETY: same bounds discipline as `encode_bf16` (8-lane groups within
// `src`, 16-byte stores within `out`, scalar tail); the gathers index the
// 512-entry f16 class tables with a 9-bit class, which cannot overrun.
#[target_feature(enable = "avx2")]
unsafe fn encode_fp16(src: &[f32], out: &mut [u8]) {
    let t = precision::f16_enc_w();
    let n = src.len();
    let n8 = n / 8 * 8;
    let man_mask = _mm256_set1_epi32(0x007F_FFFF);
    let sign_flip = _mm256_set1_epi32(i32::MIN);
    let lsb = _mm256_set1_epi32(1);
    let mut i = 0;
    while i < n8 {
        let x = _mm256_loadu_ps(src.as_ptr().add(i));
        let bits = _mm256_castps_si256(x);
        let cls = _mm256_srli_epi32::<23>(bits); // 9-bit sign+exponent class
        let base = _mm256_i32gather_epi32::<4>(t.base.as_ptr() as *const i32, cls);
        let shift = _mm256_i32gather_epi32::<4>(t.shift.as_ptr() as *const i32, cls);
        let mask = _mm256_i32gather_epi32::<4>(t.mask.as_ptr() as *const i32, cls);
        let half = _mm256_i32gather_epi32::<4>(t.half.as_ptr() as *const i32, cls);
        let imp = _mm256_i32gather_epi32::<4>(t.imp.as_ptr() as *const i32, cls);
        let full = _mm256_or_si256(_mm256_and_si256(bits, man_mask), imp);
        // base + (full >> shift): every sum ≤ 0xFBFF (+1 below), so u32
        // adds equal the scalar u16 wrapping adds.
        let o = _mm256_add_epi32(base, _mm256_srlv_epi32(full, shift));
        let rem = _mm256_and_si256(full, mask);
        // Unsigned rem > half via sign-flip + signed compare; the
        // `u32::MAX` never-rounds sentinel flips to i32::MAX — unreachable.
        let gt = _mm256_cmpgt_epi32(
            _mm256_xor_si256(rem, sign_flip),
            _mm256_xor_si256(half, sign_flip),
        );
        let eq = _mm256_cmpeq_epi32(rem, half);
        // inc = (rem > half) | (rem == half && out odd), as 0/1 lanes.
        let inc = _mm256_and_si256(_mm256_or_si256(gt, _mm256_and_si256(eq, o)), lsb);
        store_u16x8(_mm256_add_epi32(o, inc), out.as_mut_ptr().add(2 * i));
        // The class table clamps inf *and NaN* classes to ±inf; the scalar
        // reference returns a quiet NaN payload instead — patch those lanes
        // (never parameter data).
        let nan = _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_UNORD_Q>(x, x));
        if nan != 0 {
            for l in 0..8 {
                if nan & (1 << l) != 0 {
                    let b = src[i + l].to_bits();
                    let h = (((b >> 16) & 0x8000) as u16) | 0x7E00;
                    out[2 * (i + l)..2 * (i + l) + 2].copy_from_slice(&h.to_le_bytes());
                }
            }
        }
        i += 8;
    }
    for j in n8..n {
        out[2 * j..2 * j + 2].copy_from_slice(&precision::f32_to_fp16_tab(src[j]).to_le_bytes());
    }
}

// --- update kernels ------------------------------------------------------------

/// In-place `w[i] -= scale·z[i]` — mul then sub, like the scalar loop.
///
/// # Safety
/// AVX2 must be available; `w.len() == z.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn sgd_update(w: &mut [f32], z: &[f32], scale: f32) {
    debug_assert_eq!(w.len(), z.len());
    let n = w.len();
    let n8 = n / 8 * 8;
    let sv = _mm256_set1_ps(scale);
    let mut i = 0;
    while i < n8 {
        let wv = _mm256_loadu_ps(w.as_ptr().add(i));
        let zv = _mm256_loadu_ps(z.as_ptr().add(i));
        _mm256_storeu_ps(w.as_mut_ptr().add(i), _mm256_sub_ps(wv, _mm256_mul_ps(sv, zv)));
        i += 8;
    }
    for j in n8..n {
        w[j] -= scale * z[j];
    }
}

/// In-place fused ZO-AdamW step over one chunk — the vector transcription
/// of `adamw_el` (same op order; division and square root are IEEE-exact).
///
/// # Safety
/// AVX2 must be available; all slices share one length.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub unsafe fn adamw_update(
    w: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    z: &[f32],
    g: f32,
    hp: AdamHp,
    b1t: f32,
    b2t: f32,
) {
    debug_assert!(w.len() == z.len() && m.len() == z.len() && v.len() == z.len());
    let n = w.len();
    let n8 = n / 8 * 8;
    let gv = _mm256_set1_ps(g);
    let b1 = _mm256_set1_ps(hp.beta1);
    let omb1 = _mm256_set1_ps(1.0 - hp.beta1);
    let b2 = _mm256_set1_ps(hp.beta2);
    let omb2 = _mm256_set1_ps(1.0 - hp.beta2);
    let b1tv = _mm256_set1_ps(b1t);
    let b2tv = _mm256_set1_ps(b2t);
    let epsv = _mm256_set1_ps(hp.eps);
    let lrv = _mm256_set1_ps(hp.lr);
    let wdv = _mm256_set1_ps(hp.weight_decay);
    let mut i = 0;
    while i < n8 {
        let wv = _mm256_loadu_ps(w.as_ptr().add(i));
        let mv = _mm256_loadu_ps(m.as_ptr().add(i));
        let vv = _mm256_loadu_ps(v.as_ptr().add(i));
        let zv = _mm256_loadu_ps(z.as_ptr().add(i));
        let gi = _mm256_mul_ps(gv, zv);
        let m2 = _mm256_add_ps(_mm256_mul_ps(b1, mv), _mm256_mul_ps(omb1, gi));
        let v2 =
            _mm256_add_ps(_mm256_mul_ps(b2, vv), _mm256_mul_ps(_mm256_mul_ps(omb2, gi), gi));
        let mhat = _mm256_div_ps(m2, b1tv);
        let vhat = _mm256_div_ps(v2, b2tv);
        let denom = _mm256_add_ps(_mm256_sqrt_ps(vhat), epsv);
        let step = _mm256_add_ps(_mm256_div_ps(mhat, denom), _mm256_mul_ps(wdv, wv));
        _mm256_storeu_ps(m.as_mut_ptr().add(i), m2);
        _mm256_storeu_ps(v.as_mut_ptr().add(i), v2);
        _mm256_storeu_ps(w.as_mut_ptr().add(i), _mm256_sub_ps(wv, _mm256_mul_ps(lrv, step)));
        i += 8;
    }
    for j in n8..n {
        w[j] = crate::zo::cpu_optim::adamw_el(w[j], &mut m[j], &mut v[j], g * z[j], hp, b1t, b2t);
    }
}
