//! Time sources: real wallclock and the virtual clock used by the
//! discrete-event simulator (paper-scale models cannot run for real on this
//! testbed, so Tables 2/4/5/6/7 at OPT sizes are simulated on virtual time).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Monotonic seconds source.
pub trait Clock: Send + Sync {
    fn now(&self) -> f64;
}

/// Wallclock (real mode).
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Virtual clock: advanced explicitly by the simulator.  Stored as
/// nanoseconds in an atomic so traces can be taken from any thread.
pub struct VirtualClock {
    nanos: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self { nanos: AtomicU64::new(0) }
    }

    pub fn advance_to(&self, t: f64) {
        let n = (t * 1e9) as u64;
        self.nanos.fetch_max(n, Ordering::SeqCst);
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.nanos.load(Ordering::SeqCst) as f64 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_monotone() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance_to(1.5);
        assert!((c.now() - 1.5).abs() < 1e-9);
        c.advance_to(1.0); // never goes backwards
        assert!((c.now() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn wallclock_advances() {
        let c = WallClock::new();
        let a = c.now();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(c.now() > a);
    }
}
