//! Model shapes: the OPT family (paper Table 1) for analytic/simulated
//! experiments, plus mirrors of the AOT-compiled configs.
//!
//! Parameter-count formulas must match `python/compile/configs.py` layouts
//! exactly (validated against the manifest in tests).

use crate::runtime::Manifest;

/// Architecture dimensions (decoder-only, OPT-style, ReLU FFN, learned
/// positional embeddings).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelShape {
    pub name: String,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub ffn_mult: usize,
}

impl ModelShape {
    pub fn new(name: &str, d_model: usize, n_heads: usize, n_layers: usize,
               vocab: usize, max_seq: usize) -> Self {
        Self { name: name.into(), d_model, n_heads, n_layers, vocab, max_seq, ffn_mult: 4 }
    }

    pub fn d_ffn(&self) -> usize {
        self.ffn_mult * self.d_model
    }

    /// Embedding bucket elements: token + learned positional tables.
    pub fn embed_params(&self) -> usize {
        self.vocab * self.d_model + self.max_seq * self.d_model
    }

    /// One transformer block's bucket elements
    /// (2 LayerNorms, q/k/v/o projections + biases, 2-layer FFN + biases).
    pub fn block_params(&self) -> usize {
        let d = self.d_model;
        let f = self.d_ffn();
        2 * d                       // ln1
            + 4 * (d * d + d)       // wq/bq wk/bk wv/bv wo/bo
            + 2 * d                 // ln2
            + (d * f + f)           // fc1
            + (f * d + d)           // fc2
    }

    /// LM head bucket elements (final LN + untied projection).
    pub fn head_params(&self) -> usize {
        2 * self.d_model + self.d_model * self.vocab
    }

    pub fn total_params(&self) -> usize {
        self.embed_params() + self.n_layers * self.block_params() + self.head_params()
    }

    /// From an artifact manifest (AOT-compiled configs).
    pub fn from_manifest(m: &Manifest) -> Self {
        Self {
            name: m.config.name.clone(),
            d_model: m.config.d_model,
            n_heads: m.config.n_heads,
            n_layers: m.config.n_layers,
            vocab: m.config.vocab,
            max_seq: m.config.seq_len,
            ffn_mult: m.config.ffn_mult,
        }
    }
}

/// The OPT family exactly as in paper Table 1 (seq len 2048; OPT vocab
/// 50272 plus 2048 learned positions).
pub fn opt_family() -> Vec<ModelShape> {
    const V: usize = 50272;
    const T: usize = 2048;
    vec![
        ModelShape::new("OPT-1.3B", 2048, 32, 24, V, T),
        ModelShape::new("OPT-2.7B", 2560, 32, 32, V, T),
        ModelShape::new("OPT-6.7B", 4096, 32, 32, V, T),
        ModelShape::new("OPT-13B", 5120, 40, 40, V, T),
        ModelShape::new("OPT-30B", 7168, 56, 48, V, T),
        ModelShape::new("OPT-66B", 9216, 72, 64, V, T),
        ModelShape::new("OPT-175B", 12288, 96, 96, V, T),
    ]
}

pub fn opt_by_name(name: &str) -> Option<ModelShape> {
    opt_family().into_iter().find(|m| m.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opt_param_counts_land_on_nameplates() {
        // Param-count formulas should reproduce the nameplate sizes within
        // a few percent (exact OPT has tied embeddings & slight variations).
        let expect = [
            ("OPT-1.3B", 1.3e9),
            ("OPT-2.7B", 2.7e9),
            ("OPT-6.7B", 6.7e9),
            ("OPT-13B", 13e9),
            ("OPT-30B", 30e9),
            ("OPT-66B", 66e9),
            ("OPT-175B", 175e9),
        ];
        for (name, want) in expect {
            let m = opt_by_name(name).unwrap();
            let got = m.total_params() as f64;
            let rel = (got - want).abs() / want;
            assert!(rel < 0.10, "{name}: {got:.3e} vs nameplate {want:.1e} (rel {rel:.3})");
        }
    }

    #[test]
    fn block_formula_matches_tiny_manifest_layout() {
        // tiny config: d=32, f=128 -> 12704 elements (pinned in python tests).
        let t = ModelShape::new("tiny", 32, 2, 2, 64, 16);
        assert_eq!(t.block_params(), 12704);
        assert_eq!(t.embed_params(), 64 * 32 + 16 * 32);
        assert_eq!(t.head_params(), 2 * 32 + 32 * 64);
    }

    #[test]
    fn gpt2_100m_in_band() {
        let g = ModelShape::new("gpt2-100m", 768, 12, 12, 8192, 32);
        let p = g.total_params() as f64;
        assert!(85e6 < p && p < 120e6, "{p}");
    }
}
