//! Telemetry: timeline traces (paper Fig. 4), memory reports, throughput,
//! and the host-scratch gauge (DRAM bytes held by reusable scratch buffers).

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};

/// A current/peak byte gauge (atomic, process-wide).
#[derive(Debug)]
pub struct Gauge {
    cur: AtomicU64,
    peak: AtomicU64,
}

impl Gauge {
    pub const fn new() -> Self {
        Self { cur: AtomicU64::new(0), peak: AtomicU64::new(0) }
    }

    pub fn add(&self, bytes: u64) {
        let now = self.cur.fetch_add(bytes, Ordering::SeqCst) + bytes;
        self.peak.fetch_max(now, Ordering::SeqCst);
    }

    pub fn sub(&self, bytes: u64) {
        self.cur.fetch_sub(bytes, Ordering::SeqCst);
    }

    pub fn current(&self) -> u64 {
        self.cur.load(Ordering::SeqCst)
    }

    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::SeqCst)
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Self::new()
    }
}

/// Host DRAM held by reusable scratch buffers (z-replay scratch etc.) —
/// the accounting half of the scratch shrink policy: scratch is invisible
/// to the tier budgets, so it gets its own gauge instead.
pub static HOST_SCRATCH: Gauge = Gauge::new();

/// One scheduled interval on a stream.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub stream: &'static str,
    pub label: String,
    pub start: f64,
    pub end: f64,
}

/// A collection of trace events with CSV + ASCII-gantt rendering.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    pub events: Vec<TraceEvent>,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, e: TraceEvent) {
        self.events.push(e);
    }

    pub fn makespan(&self) -> f64 {
        self.events.iter().map(|e| e.end).fold(0.0, f64::max)
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("stream,label,start_s,end_s\n");
        for e in &self.events {
            let _ = writeln!(s, "{},{},{:.9},{:.9}", e.stream, e.label.replace(',', ";"), e.start, e.end);
        }
        s
    }

    /// Render an ASCII gantt chart (one row per stream), `width` columns.
    /// This is the textual Figure 4.
    pub fn to_ascii_gantt(&self, width: usize) -> String {
        let total = self.makespan();
        if total <= 0.0 || self.events.is_empty() {
            return String::from("(empty timeline)\n");
        }
        let mut streams: Vec<&'static str> = Vec::new();
        for e in &self.events {
            if !streams.contains(&e.stream) {
                streams.push(e.stream);
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "timeline: {:.3} ms total, {} tasks", total * 1e3, self.events.len());
        for s in streams {
            let mut row = vec![' '; width];
            for e in self.events.iter().filter(|e| e.stream == s) {
                let a = ((e.start / total) * width as f64) as usize;
                let b = (((e.end / total) * width as f64).ceil() as usize).min(width);
                let ch = match e.label.chars().next().unwrap_or('?') {
                    'U' => 'U',
                    'O' => 'O',
                    'C' => '#',
                    c => c,
                };
                for cell in row.iter_mut().take(b).skip(a) {
                    *cell = ch;
                }
            }
            let _ = writeln!(out, "{:>8} |{}|", s, row.iter().collect::<String>());
        }
        out
    }

    /// Fraction of the makespan each stream is busy.
    pub fn utilization(&self, stream: &str) -> f64 {
        let total = self.makespan();
        if total <= 0.0 {
            return 0.0;
        }
        let busy: f64 = self.events.iter().filter(|e| e.stream == stream).map(|e| e.end - e.start).sum();
        busy / total
    }
}

/// Loss-curve / metric series writer (CSV) for the e2e example.
#[derive(Debug, Default)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    pub fn to_csv(&self) -> String {
        let mut s = format!("step,{}\n", self.name);
        for (x, y) in &self.points {
            let _ = writeln!(s, "{x},{y}");
        }
        s
    }

    /// Mean of the last `k` values (used to report converged loss).
    pub fn tail_mean(&self, k: usize) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        let n = self.points.len();
        let k = k.min(n);
        self.points[n - k..].iter().map(|p| p.1).sum::<f64>() / k as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gantt_and_utilization() {
        let mut t = Timeline::new();
        t.push(TraceEvent { stream: "compute", label: "C b0".into(), start: 0.0, end: 2.0 });
        t.push(TraceEvent { stream: "upload", label: "U b1".into(), start: 0.0, end: 1.0 });
        t.push(TraceEvent { stream: "compute", label: "C b1".into(), start: 2.0, end: 4.0 });
        assert_eq!(t.makespan(), 4.0);
        assert!((t.utilization("compute") - 1.0).abs() < 1e-12);
        assert!((t.utilization("upload") - 0.25).abs() < 1e-12);
        let g = t.to_ascii_gantt(40);
        assert!(g.contains("compute"));
        assert!(g.contains('#'));
        let csv = t.to_csv();
        assert!(csv.lines().count() == 4);
    }

    #[test]
    fn gauge_tracks_current_and_peak() {
        let g = Gauge::new();
        g.add(100);
        g.add(50);
        assert_eq!(g.current(), 150);
        g.sub(120);
        assert_eq!(g.current(), 30);
        assert_eq!(g.peak(), 150);
        g.add(10);
        assert_eq!(g.peak(), 150, "peak unchanged below the high-water mark");
    }

    #[test]
    fn series_tail_mean() {
        let mut s = Series::new("loss");
        for i in 0..10 {
            s.push(i as f64, 10.0 - i as f64);
        }
        assert!((s.tail_mean(2) - 1.5).abs() < 1e-12);
        assert!(s.to_csv().starts_with("step,loss"));
    }
}
