//! Telemetry: timeline traces (paper Fig. 4), memory reports, throughput,
//! and the host-scratch gauge (DRAM bytes held by reusable scratch buffers).
//!
//! Submodules: [`metrics`] — the labeled counter/gauge/histogram registry
//! behind the disabled-by-default process-wide sink; [`trace`] — the
//! Chrome-trace-event exporter shared by simulator plans and measured
//! engine runs, plus the sim-vs-measured drift report.

pub mod metrics;
pub mod trace;

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};

/// A current/peak byte gauge (atomic, process-wide).
#[derive(Debug)]
pub struct Gauge {
    cur: AtomicU64,
    peak: AtomicU64,
}

impl Gauge {
    pub const fn new() -> Self {
        Self { cur: AtomicU64::new(0), peak: AtomicU64::new(0) }
    }

    pub fn add(&self, bytes: u64) {
        let now = self.cur.fetch_add(bytes, Ordering::SeqCst) + bytes;
        self.peak.fetch_max(now, Ordering::SeqCst);
    }

    pub fn sub(&self, bytes: u64) {
        self.cur.fetch_sub(bytes, Ordering::SeqCst);
    }

    pub fn current(&self) -> u64 {
        self.cur.load(Ordering::SeqCst)
    }

    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::SeqCst)
    }

    /// Zero both the current value and the peak.  Process-wide gauges
    /// (e.g. [`HOST_SCRATCH`]) call this at engine construction so
    /// back-to-back runs in one process don't inherit a stale peak.
    pub fn reset(&self) {
        self.cur.store(0, Ordering::SeqCst);
        self.peak.store(0, Ordering::SeqCst);
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Self::new()
    }
}

/// Host DRAM held by reusable scratch buffers (z-replay scratch etc.) —
/// the accounting half of the scratch shrink policy: scratch is invisible
/// to the tier budgets, so it gets its own gauge instead.
pub static HOST_SCRATCH: Gauge = Gauge::new();

/// One scheduled interval on a stream.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub stream: &'static str,
    /// Task category from the shared simulator/engine vocabulary
    /// ([`crate::sched::TaskKind::cat_name`]); the drift report joins the
    /// two traces on this, independent of which stream the work ran on
    /// (the sequential-mode engine runs everything on one thread).
    pub cat: &'static str,
    pub label: String,
    pub start: f64,
    pub end: f64,
}

/// A collection of trace events with CSV + ASCII-gantt rendering.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    pub events: Vec<TraceEvent>,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, e: TraceEvent) {
        self.events.push(e);
    }

    pub fn makespan(&self) -> f64 {
        self.events.iter().map(|e| e.end).fold(0.0, f64::max)
    }

    /// Append every event of `other`, shifted by `offset` seconds.  The
    /// trainer uses this to concatenate per-step engine timelines into one
    /// whole-run trace.
    pub fn extend_offset(&mut self, other: &Timeline, offset: f64) {
        for e in &other.events {
            self.events.push(TraceEvent {
                stream: e.stream,
                cat: e.cat,
                label: e.label.clone(),
                start: e.start + offset,
                end: e.end + offset,
            });
        }
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("stream,cat,label,start_s,end_s\n");
        for e in &self.events {
            let _ = writeln!(
                s,
                "{},{},{},{:.9},{:.9}",
                e.stream,
                e.cat,
                e.label.replace(',', ";"),
                e.start,
                e.end
            );
        }
        s
    }

    /// Render an ASCII gantt chart (one row per stream), `width` columns.
    /// This is the textual Figure 4.
    pub fn to_ascii_gantt(&self, width: usize) -> String {
        let width = width.max(1);
        let total = self.makespan();
        if total <= 0.0 || self.events.is_empty() {
            return String::from("(empty timeline)\n");
        }
        let mut streams: Vec<&'static str> = Vec::new();
        for e in &self.events {
            if !streams.contains(&e.stream) {
                streams.push(e.stream);
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "timeline: {:.3} ms total, {} tasks", total * 1e3, self.events.len());
        for s in streams {
            let mut row = vec![' '; width];
            for e in self.events.iter().filter(|e| e.stream == s) {
                // Clamp so every event renders at least one cell: a
                // zero-duration event (or one ending exactly at the
                // makespan) must not round to an empty span or spill past
                // the row.
                let a = (((e.start / total) * width as f64) as usize).min(width - 1);
                let b = ((((e.end / total) * width as f64).ceil()) as usize).clamp(a + 1, width);
                let ch = match e.label.chars().next().unwrap_or('?') {
                    'U' => 'U',
                    'O' => 'O',
                    'C' => '#',
                    c => c,
                };
                for cell in row.iter_mut().take(b).skip(a) {
                    *cell = ch;
                }
            }
            let _ = writeln!(out, "{:>8} |{}|", s, row.iter().collect::<String>());
        }
        out
    }

    /// Fraction of the makespan each stream is busy.
    pub fn utilization(&self, stream: &str) -> f64 {
        let total = self.makespan();
        if total <= 0.0 {
            return 0.0;
        }
        let busy: f64 = self.events.iter().filter(|e| e.stream == stream).map(|e| e.end - e.start).sum();
        busy / total
    }
}

/// Loss-curve / metric series writer (CSV) for the e2e example.
#[derive(Debug, Default)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    pub fn to_csv(&self) -> String {
        let mut s = format!("step,{}\n", self.name);
        for (x, y) in &self.points {
            let _ = writeln!(s, "{x},{y}");
        }
        s
    }

    /// Mean of the last `k` values (used to report converged loss).
    pub fn tail_mean(&self, k: usize) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        let n = self.points.len();
        let k = k.min(n);
        self.points[n - k..].iter().map(|p| p.1).sum::<f64>() / k as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(stream: &'static str, label: &str, start: f64, end: f64) -> TraceEvent {
        TraceEvent { stream, cat: "compute", label: label.into(), start, end }
    }

    #[test]
    fn gantt_and_utilization() {
        let mut t = Timeline::new();
        t.push(ev("compute", "C b0", 0.0, 2.0));
        t.push(ev("upload", "U b1", 0.0, 1.0));
        t.push(ev("compute", "C b1", 2.0, 4.0));
        assert_eq!(t.makespan(), 4.0);
        assert!((t.utilization("compute") - 1.0).abs() < 1e-12);
        assert!((t.utilization("upload") - 0.25).abs() < 1e-12);
        let g = t.to_ascii_gantt(40);
        assert!(g.contains("compute"));
        assert!(g.contains('#'));
        let csv = t.to_csv();
        assert!(csv.lines().count() == 4);
    }

    #[test]
    fn gantt_renders_zero_width_events() {
        let mut t = Timeline::new();
        // Zero-duration event at t=0, and an event whose span rounds to
        // less than one cell ending exactly at the makespan: both must
        // still paint one cell, and no row may exceed `width`.
        t.push(ev("compute", "C b0", 0.0, 10.0));
        t.push(ev("upload", "U b0", 0.0, 0.0));
        t.push(ev("offload", "O b0", 9.999, 10.0));
        let g = t.to_ascii_gantt(10);
        let upload_row = g.lines().find(|l| l.contains("upload")).unwrap();
        assert!(upload_row.contains('U'), "zero-duration event vanished: {upload_row}");
        let offload_row = g.lines().find(|l| l.contains("offload")).unwrap();
        assert!(offload_row.contains('O'), "makespan-edge event vanished: {offload_row}");
        for row in g.lines().skip(1) {
            let cells = row.split('|').nth(1).unwrap();
            assert_eq!(cells.chars().count(), 10, "row width must be exactly 10: {row}");
        }
        // Degenerate width is clamped to one column rather than panicking.
        assert!(t.to_ascii_gantt(0).contains('|'));
    }

    #[test]
    fn gauge_tracks_current_and_peak() {
        let g = Gauge::new();
        g.add(100);
        g.add(50);
        assert_eq!(g.current(), 150);
        g.sub(120);
        assert_eq!(g.current(), 30);
        assert_eq!(g.peak(), 150);
        g.add(10);
        assert_eq!(g.peak(), 150, "peak unchanged below the high-water mark");
        g.reset();
        assert_eq!(g.current(), 0);
        assert_eq!(g.peak(), 0, "reset clears the high-water mark");
    }

    #[test]
    fn extend_offset_shifts_events() {
        let mut step = Timeline::new();
        step.push(ev("compute", "C b0", 0.0, 1.0));
        let mut run = Timeline::new();
        run.extend_offset(&step, 0.0);
        run.extend_offset(&step, step.makespan());
        assert_eq!(run.events.len(), 2);
        assert!((run.events[1].start - 1.0).abs() < 1e-12);
        assert!((run.makespan() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn series_tail_mean() {
        let mut s = Series::new("loss");
        for i in 0..10 {
            s.push(i as f64, 10.0 - i as f64);
        }
        assert!((s.tail_mean(2) - 1.5).abs() < 1e-12);
        assert!(s.to_csv().starts_with("step,loss"));
    }
}
