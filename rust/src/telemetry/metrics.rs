//! Labeled metrics registry with a deterministic JSON snapshot.
//!
//! Two usage modes share one type:
//!
//! * **Process-wide sink** — [`global()`] behind an [`enabled()`] flag that
//!   is off by default.  Instrumented hot paths (engine pipelines, host
//!   kernels, the disk tier) branch on `enabled()` *before* building label
//!   slices, so with no `--metrics-out` flag the cost is one relaxed atomic
//!   load and zero allocations — the pay-for-what-you-use contract that
//!   keeps golden/trajectory tests bit-identical.
//! * **Local registries** — benches and the simulator build their own
//!   [`MetricsRegistry`] and embed its [`MetricsRegistry::snapshot_json`]
//!   in their output files, so `BENCH_*.json` calibration blocks and
//!   `--metrics-out` dumps speak one schema (`zo2-metrics-v1`).
//!
//! Metric identity is `(name, sorted label pairs)`; the snapshot is sorted
//! by that identity (a `BTreeMap` keyed on the rendered id), so two runs
//! that record the same values emit byte-identical JSON.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::util::json::Json;

/// Schema tag written into every snapshot.
pub use crate::util::schema::METRICS_SCHEMA;

#[derive(Debug, Clone)]
enum Value {
    Counter(u64),
    /// Last-set value plus the high-water mark across sets.
    Gauge { value: f64, peak: f64 },
    Histogram { count: u64, sum: f64, min: f64, max: f64 },
}

#[derive(Debug, Clone)]
struct Entry {
    name: String,
    labels: Vec<(String, String)>,
    value: Value,
}

/// A set of named, labeled counters/gauges/histograms.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    entries: Mutex<BTreeMap<String, Entry>>,
}

/// Rendered identity: `name{k=v,k2=v2}` with label keys sorted.
fn render_key(name: &str, labels: &[(&str, &str)]) -> (String, Vec<(String, String)>) {
    let mut sorted: Vec<(String, String)> =
        labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    sorted.sort();
    let mut key = String::from(name);
    key.push('{');
    for (i, (k, v)) in sorted.iter().enumerate() {
        if i > 0 {
            key.push(',');
        }
        key.push_str(k);
        key.push('=');
        key.push_str(v);
    }
    key.push('}');
    (key, sorted)
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    fn update(&self, name: &str, labels: &[(&str, &str)], f: impl FnOnce(Option<Value>) -> Value) {
        let (key, sorted) = render_key(name, labels);
        let mut entries = self.entries.lock().unwrap();
        match entries.get_mut(&key) {
            Some(e) => e.value = f(Some(e.value.clone())),
            None => {
                let value = f(None);
                entries.insert(key, Entry { name: name.to_string(), labels: sorted, value });
            }
        }
    }

    /// Add `v` to a monotonically-increasing counter.
    pub fn counter_add(&self, name: &str, labels: &[(&str, &str)], v: u64) {
        self.update(name, labels, |old| match old {
            Some(Value::Counter(c)) => Value::Counter(c + v),
            _ => Value::Counter(v),
        });
    }

    /// Set a gauge; its peak tracks the maximum ever set.
    pub fn gauge_set(&self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.update(name, labels, |old| match old {
            Some(Value::Gauge { peak, .. }) => Value::Gauge { value: v, peak: peak.max(v) },
            _ => Value::Gauge { value: v, peak: v },
        });
    }

    /// Record one observation into a count/sum/min/max histogram.
    pub fn observe(&self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.update(name, labels, |old| match old {
            Some(Value::Histogram { count, sum, min, max }) => Value::Histogram {
                count: count + 1,
                sum: sum + v,
                min: min.min(v),
                max: max.max(v),
            },
            _ => Value::Histogram { count: 1, sum: v, min: v, max: v },
        });
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every entry (fresh run in the same process).
    pub fn reset(&self) {
        self.entries.lock().unwrap().clear();
    }

    /// Deterministic snapshot: `{"schema": ..., "metrics": [...]}`, entries
    /// sorted by `(name, labels)`.
    pub fn snapshot_json(&self) -> Json {
        let entries = self.entries.lock().unwrap();
        let mut arr = Vec::with_capacity(entries.len());
        for e in entries.values() {
            let mut obj = BTreeMap::new();
            obj.insert("name".to_string(), Json::Str(e.name.clone()));
            let labels: BTreeMap<String, Json> =
                e.labels.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect();
            obj.insert("labels".to_string(), Json::Obj(labels));
            match &e.value {
                Value::Counter(c) => {
                    obj.insert("kind".to_string(), Json::Str("counter".to_string()));
                    obj.insert("value".to_string(), Json::Num(*c as f64));
                }
                Value::Gauge { value, peak } => {
                    obj.insert("kind".to_string(), Json::Str("gauge".to_string()));
                    obj.insert("value".to_string(), Json::Num(*value));
                    obj.insert("peak".to_string(), Json::Num(*peak));
                }
                Value::Histogram { count, sum, min, max } => {
                    obj.insert("kind".to_string(), Json::Str("histogram".to_string()));
                    obj.insert("count".to_string(), Json::Num(*count as f64));
                    obj.insert("sum".to_string(), Json::Num(*sum));
                    obj.insert("min".to_string(), Json::Num(*min));
                    obj.insert("max".to_string(), Json::Num(*max));
                    obj.insert("mean".to_string(), Json::Num(*sum / (*count).max(1) as f64));
                }
            }
            arr.push(Json::Obj(obj));
        }
        let mut root = BTreeMap::new();
        root.insert("schema".to_string(), Json::Str(METRICS_SCHEMA.to_string()));
        root.insert("metrics".to_string(), Json::Arr(arr));
        Json::Obj(root)
    }
}

/// Look a metric's primary `value` up in a snapshot produced by
/// [`MetricsRegistry::snapshot_json`].  `labels` must match the entry's
/// label set exactly (same keys, same values).
pub fn find_value(snapshot: &Json, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
    let arr = snapshot.get("metrics").ok()?.as_arr().ok()?;
    for entry in arr {
        if entry.get("name").ok()?.as_str().ok()? != name {
            continue;
        }
        let got = entry.get("labels").ok()?.as_obj().ok()?;
        if got.len() != labels.len() {
            continue;
        }
        let all_match =
            labels.iter().all(|(k, v)| got.get(*k).and_then(|j| j.as_str().ok()) == Some(*v));
        if all_match {
            return entry.get("value").ok()?.as_f64().ok();
        }
    }
    None
}

// --- process-wide sink -------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();

/// Whether the process-wide sink records anything.  Instrumented paths
/// branch on this *before* building labels, so the disabled cost is one
/// relaxed load and zero allocations.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn global() -> &'static MetricsRegistry {
    GLOBAL.get_or_init(MetricsRegistry::new)
}

/// Counter add on the global sink; no-op while disabled.
pub fn counter_add(name: &str, labels: &[(&str, &str)], v: u64) {
    if enabled() {
        global().counter_add(name, labels, v);
    }
}

/// Gauge set on the global sink; no-op while disabled.
pub fn gauge_set(name: &str, labels: &[(&str, &str)], v: f64) {
    if enabled() {
        global().gauge_set(name, labels, v);
    }
}

/// Histogram observation on the global sink; no-op while disabled.
pub fn observe(name: &str, labels: &[(&str, &str)], v: f64) {
    if enabled() {
        global().observe(name, labels, v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_records_and_snapshots_deterministically() {
        let r = MetricsRegistry::new();
        r.counter_add("bytes_total", &[("dir", "h2d")], 100);
        r.counter_add("bytes_total", &[("dir", "h2d")], 50);
        r.counter_add("bytes_total", &[("dir", "d2h")], 7);
        r.gauge_set("window_slots", &[], 3.0);
        r.gauge_set("window_slots", &[], 2.0);
        r.observe("chunks", &[("op", "decode")], 4.0);
        r.observe("chunks", &[("op", "decode")], 10.0);
        assert_eq!(r.len(), 4);

        let snap = r.snapshot_json();
        assert_eq!(snap.get("schema").unwrap().as_str().unwrap(), METRICS_SCHEMA);
        assert_eq!(find_value(&snap, "bytes_total", &[("dir", "h2d")]), Some(150.0));
        assert_eq!(find_value(&snap, "bytes_total", &[("dir", "d2h")]), Some(7.0));
        // Gauge value is last-set; peak is tracked separately.
        assert_eq!(find_value(&snap, "window_slots", &[]), Some(2.0));
        // Label sets must match exactly — a subset is not a match.
        assert_eq!(find_value(&snap, "bytes_total", &[]), None);
        assert_eq!(find_value(&snap, "missing", &[]), None);

        // Byte-identical snapshots for identical contents, and label order
        // at the call site never matters.
        let r2 = MetricsRegistry::new();
        r2.observe("chunks", &[("op", "decode")], 4.0);
        r2.observe("chunks", &[("op", "decode")], 10.0);
        r2.gauge_set("window_slots", &[], 3.0);
        r2.gauge_set("window_slots", &[], 2.0);
        r2.counter_add("bytes_total", &[("dir", "d2h")], 7);
        r2.counter_add("bytes_total", &[("dir", "h2d")], 150);
        assert_eq!(snap.to_string_pretty(), r2.snapshot_json().to_string_pretty());

        r.reset();
        assert!(r.is_empty());
    }

    #[test]
    fn histogram_tracks_count_sum_min_max() {
        let r = MetricsRegistry::new();
        for v in [5.0, 1.0, 9.0] {
            r.observe("h", &[], v);
        }
        let snap = r.snapshot_json();
        let m = snap.get("metrics").unwrap().as_arr().unwrap();
        let h = &m[0];
        assert_eq!(h.get("count").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(h.get("sum").unwrap().as_f64().unwrap(), 15.0);
        assert_eq!(h.get("min").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(h.get("max").unwrap().as_f64().unwrap(), 9.0);
        assert_eq!(h.get("mean").unwrap().as_f64().unwrap(), 5.0);
    }

    #[test]
    fn label_order_is_canonicalised() {
        let r = MetricsRegistry::new();
        r.counter_add("x", &[("b", "2"), ("a", "1")], 1);
        r.counter_add("x", &[("a", "1"), ("b", "2")], 1);
        assert_eq!(r.len(), 1, "same labels in any order are one series");
        let snap = r.snapshot_json();
        assert_eq!(find_value(&snap, "x", &[("a", "1"), ("b", "2")]), Some(2.0));
    }
}
