//! Chrome-trace-event (Perfetto) export and predicted-vs-measured drift
//! reports.
//!
//! Both the analytic simulator's plan [`Timeline`] and the real engine's
//! measured [`Timeline`] export through [`chrome_trace`] with one shared
//! mapping — `pid` = device index, `tid` = stream kind (the fixed
//! [`crate::sched::STREAM_KINDS`] order) — so a simulated and a measured
//! trace of the same config overlay track-for-track in `chrome://tracing`
//! or <https://ui.perfetto.dev>.  [`drift_report`] joins such a pair on
//! `(pid, tid)` and on the task category (`cat`, the shared
//! [`crate::sched::TaskKind::cat_name`] vocabulary) and emits per-stream
//! busy-time, per-task-kind duration, and makespan deltas — the
//! calibration input the autotuner roadmap item needs.

use std::collections::BTreeMap;

use anyhow::{ensure, Context, Result};

use crate::sched::STREAM_KINDS;
use crate::telemetry::Timeline;
use crate::util::json::Json;

/// Schema tag embedded in every exported trace (under `otherData`).
pub use crate::util::schema::TRACE_SCHEMA;

/// Schema tag of the drift-report JSON.
pub use crate::util::schema::DRIFT_SCHEMA;

/// `tid` used for stream names outside the fixed kind vocabulary.
const TID_OTHER: usize = STREAM_KINDS.len();

/// Split a timeline stream name ("compute", "d2.disk_read") into
/// `(device, kind_name)`.
fn stream_parts(stream: &str) -> (usize, &str) {
    if let Some(rest) = stream.strip_prefix('d') {
        if let Some((dev, kind)) = rest.split_once('.') {
            if let Ok(d) = dev.parse::<usize>() {
                return (d, kind);
            }
        }
    }
    (0, stream)
}

fn tid_of(kind: &str) -> usize {
    STREAM_KINDS.iter().position(|k| k.name() == kind).unwrap_or(TID_OTHER)
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Export a timeline as Chrome trace-event JSON: `ph:"M"` metadata naming
/// each process (device) and thread (stream kind), then `ph:"X"` complete
/// events sorted by `(ts, pid, tid)`.  Timestamps are microseconds.
pub fn chrome_trace(tl: &Timeline) -> Json {
    // (pid, tid) -> kind name, discovered from the events.
    let mut threads: BTreeMap<(usize, usize), &str> = BTreeMap::new();
    for e in &tl.events {
        let (dev, kind) = stream_parts(e.stream);
        threads.insert((dev, tid_of(kind)), kind);
    }

    let mut events: Vec<Json> = Vec::new();
    let mut seen_pid = None;
    for (&(pid, tid), &kind) in &threads {
        if seen_pid != Some(pid) {
            seen_pid = Some(pid);
            events.push(obj(vec![
                ("ph", Json::Str("M".into())),
                ("name", Json::Str("process_name".into())),
                ("pid", Json::Num(pid as f64)),
                ("tid", Json::Num(0.0)),
                ("args", obj(vec![("name", Json::Str(format!("device{pid}")))])),
            ]));
        }
        events.push(obj(vec![
            ("ph", Json::Str("M".into())),
            ("name", Json::Str("thread_name".into())),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(tid as f64)),
            ("args", obj(vec![("name", Json::Str(kind.to_string()))])),
        ]));
    }

    // Sort complete events by (ts, pid, tid) for a deterministic file even
    // when the threaded engine pushed them in completion order.
    let mut xs: Vec<(f64, usize, usize, &crate::telemetry::TraceEvent)> = tl
        .events
        .iter()
        .map(|e| {
            let (dev, kind) = stream_parts(e.stream);
            (e.start, dev, tid_of(kind), e)
        })
        .collect();
    xs.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
    for (start, pid, tid, e) in xs {
        let dur_us = ((e.end - e.start).max(0.0)) * 1e6;
        events.push(obj(vec![
            ("ph", Json::Str("X".into())),
            ("name", Json::Str(e.label.clone())),
            ("cat", Json::Str(e.cat.to_string())),
            ("ts", Json::Num(start * 1e6)),
            ("dur", Json::Num(dur_us)),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(tid as f64)),
        ]));
    }

    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
        ("otherData", obj(vec![("schema", Json::Str(TRACE_SCHEMA.into()))])),
    ])
}

/// Write a timeline to `path` as Chrome trace-event JSON.
pub fn write_chrome_trace(path: &str, tl: &Timeline) -> Result<()> {
    std::fs::write(path, chrome_trace(tl).to_string_pretty())
        .with_context(|| format!("writing trace {path}"))
}

/// Parse a trace file written by [`write_chrome_trace`].
pub fn load_trace(path: &str) -> Result<Json> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    let doc = Json::parse(&text).with_context(|| format!("parsing trace {path}"))?;
    ensure!(doc.get("traceEvents").is_ok(), "{path}: not a trace file (no traceEvents)");
    Ok(doc)
}

/// Aggregates of one trace: stream/process names, per-stream busy seconds,
/// per-category (duration, count), and the event span.
struct TraceStats {
    threads: BTreeMap<(usize, usize), String>,
    busy_s: BTreeMap<(usize, usize), f64>,
    cats: BTreeMap<String, (f64, u64)>,
    makespan_s: f64,
}

fn trace_stats(doc: &Json) -> Result<TraceStats> {
    let events = doc.get("traceEvents")?.as_arr()?;
    let mut s = TraceStats {
        threads: BTreeMap::new(),
        busy_s: BTreeMap::new(),
        cats: BTreeMap::new(),
        makespan_s: 0.0,
    };
    for e in events {
        let ph = e.get("ph")?.as_str()?;
        match ph {
            "M" => {
                if e.get("name")?.as_str()? == "thread_name" {
                    let pid = e.get("pid")?.as_usize()?;
                    let tid = e.get("tid")?.as_usize()?;
                    let name = e.get("args")?.get("name")?.as_str()?.to_string();
                    s.threads.insert((pid, tid), name);
                }
            }
            "X" => {
                let pid = e.get("pid")?.as_usize()?;
                let tid = e.get("tid")?.as_usize()?;
                let ts = e.get("ts")?.as_f64()?;
                let dur = e.get("dur")?.as_f64()?;
                ensure!(dur >= 0.0, "negative duration in trace");
                *s.busy_s.entry((pid, tid)).or_insert(0.0) += dur / 1e6;
                if let Ok(cat) = e.get("cat") {
                    let entry = s.cats.entry(cat.as_str()?.to_string()).or_insert((0.0, 0));
                    entry.0 += dur / 1e6;
                    entry.1 += 1;
                }
                s.makespan_s = s.makespan_s.max((ts + dur) / 1e6);
            }
            _ => {}
        }
    }
    Ok(s)
}

fn ratio(sim: f64, measured: f64) -> Json {
    if sim > 0.0 {
        Json::Num(measured / sim)
    } else {
        Json::Null
    }
}

/// Diff a simulated-plan trace against a measured-run trace of the same
/// config.  Streams join on `(pid, tid)` — the shared export mapping —
/// and task kinds join on `cat`.  Ratios are `measured / sim`
/// (`null` when the sim side is zero or absent).
pub fn drift_report(sim: &Json, measured: &Json) -> Result<Json> {
    let a = trace_stats(sim).context("sim trace")?;
    let b = trace_stats(measured).context("measured trace")?;

    let mut streams = Vec::new();
    let mut keys: Vec<(usize, usize)> =
        a.busy_s.keys().chain(b.busy_s.keys()).copied().collect();
    keys.sort();
    keys.dedup();
    for key in keys {
        let (pid, tid) = key;
        let name = a
            .threads
            .get(&key)
            .or_else(|| b.threads.get(&key))
            .cloned()
            .unwrap_or_else(|| format!("tid{tid}"));
        let sa = a.busy_s.get(&key).copied().unwrap_or(0.0);
        let sb = b.busy_s.get(&key).copied().unwrap_or(0.0);
        streams.push(obj(vec![
            ("device", Json::Num(pid as f64)),
            ("stream", Json::Str(name)),
            ("sim_busy_s", Json::Num(sa)),
            ("measured_busy_s", Json::Num(sb)),
            ("delta_s", Json::Num(sb - sa)),
            ("ratio", ratio(sa, sb)),
        ]));
    }

    let mut kinds = Vec::new();
    let mut cats: Vec<String> = a.cats.keys().chain(b.cats.keys()).cloned().collect();
    cats.sort();
    cats.dedup();
    for cat in cats {
        let (sa, ca) = a.cats.get(&cat).copied().unwrap_or((0.0, 0));
        let (sb, cb) = b.cats.get(&cat).copied().unwrap_or((0.0, 0));
        kinds.push(obj(vec![
            ("kind", Json::Str(cat)),
            ("sim_s", Json::Num(sa)),
            ("sim_count", Json::Num(ca as f64)),
            ("measured_s", Json::Num(sb)),
            ("measured_count", Json::Num(cb as f64)),
            ("delta_s", Json::Num(sb - sa)),
            ("ratio", ratio(sa, sb)),
        ]));
    }

    Ok(obj(vec![
        ("schema", Json::Str(DRIFT_SCHEMA.into())),
        (
            "makespan_s",
            obj(vec![
                ("sim", Json::Num(a.makespan_s)),
                ("measured", Json::Num(b.makespan_s)),
                ("delta", Json::Num(b.makespan_s - a.makespan_s)),
                ("ratio", ratio(a.makespan_s, b.makespan_s)),
            ]),
        ),
        ("streams", Json::Arr(streams)),
        ("task_kinds", Json::Arr(kinds)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::TraceEvent;

    fn ev(stream: &'static str, cat: &'static str, label: &str, s: f64, e: f64) -> TraceEvent {
        TraceEvent { stream, cat, label: label.to_string(), start: s, end: e }
    }

    #[test]
    fn stream_parts_and_tids() {
        assert_eq!(stream_parts("compute"), (0, "compute"));
        assert_eq!(stream_parts("d3.disk_write"), (3, "disk_write"));
        assert_eq!(stream_parts("dx.bogus"), (0, "dx.bogus"));
        assert_eq!(tid_of("upload"), 0);
        assert_eq!(tid_of("interconnect"), 5);
        assert_eq!(tid_of("mystery"), TID_OTHER);
    }

    #[test]
    fn empty_timeline_exports_zero_events() {
        let doc = chrome_trace(&Timeline::new());
        assert!(doc.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn export_is_deterministic_under_push_order() {
        let mut t1 = Timeline::new();
        t1.push(ev("compute", "compute", "C b0", 1.0, 2.0));
        t1.push(ev("upload", "upload", "U b0", 0.0, 1.0));
        let mut t2 = Timeline::new();
        t2.push(ev("upload", "upload", "U b0", 0.0, 1.0));
        t2.push(ev("compute", "compute", "C b0", 1.0, 2.0));
        assert_eq!(
            chrome_trace(&t1).to_string_pretty(),
            chrome_trace(&t2).to_string_pretty()
        );
    }

    #[test]
    fn drift_report_on_empty_and_degenerate_traces_round_trips() {
        // Empty pair: zero makespans must yield explicit null ratios and a
        // report our own parser accepts (a NaN/Inf ratio would make
        // `to_string_pretty` emit a non-parseable file).
        let empty = chrome_trace(&Timeline::new());
        let rep = drift_report(&empty, &empty).unwrap();
        assert!(matches!(rep.get("makespan_s").unwrap().get("ratio").unwrap(), Json::Null));
        let re = Json::parse(&rep.to_string_pretty()).unwrap();
        assert_eq!(re, rep);

        // Degenerate pair: the sim side has only zero-duration events (zero
        // busy time on every stream), the measured side is real.
        let mut sim = Timeline::new();
        sim.push(ev("compute", "compute", "C b0", 1.0, 1.0));
        let mut measured = Timeline::new();
        measured.push(ev("compute", "compute", "C b0", 0.0, 2.0));
        let rep = drift_report(&chrome_trace(&sim), &chrome_trace(&measured)).unwrap();
        let streams = rep.get("streams").unwrap().as_arr().unwrap();
        assert!(
            matches!(streams[0].get("ratio").unwrap(), Json::Null),
            "zero sim busy time must report a null ratio, not NaN/Inf"
        );
        let re = Json::parse(&rep.to_string_pretty()).unwrap();
        assert_eq!(re, rep);
    }

    #[test]
    fn drift_report_joins_streams_and_kinds() {
        let mut sim = Timeline::new();
        sim.push(ev("compute", "compute", "C b0", 0.0, 2.0));
        sim.push(ev("upload", "upload", "U b0", 0.0, 1.0));
        let mut measured = Timeline::new();
        measured.push(ev("compute", "compute", "C b0", 0.0, 3.0));
        measured.push(ev("compute", "disk_read", "R b0", 3.0, 3.5));

        let rep =
            drift_report(&chrome_trace(&sim), &chrome_trace(&measured)).unwrap();
        assert_eq!(rep.get("schema").unwrap().as_str().unwrap(), DRIFT_SCHEMA);
        let mk = rep.get("makespan_s").unwrap();
        assert!((mk.get("sim").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-9);
        assert!((mk.get("measured").unwrap().as_f64().unwrap() - 3.5).abs() < 1e-9);
        assert!((mk.get("ratio").unwrap().as_f64().unwrap() - 1.75).abs() < 1e-9);

        let streams = rep.get("streams").unwrap().as_arr().unwrap();
        let compute = streams
            .iter()
            .find(|s| s.get("stream").unwrap().as_str().unwrap() == "compute")
            .unwrap();
        assert!((compute.get("sim_busy_s").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-9);
        assert!((compute.get("measured_busy_s").unwrap().as_f64().unwrap() - 3.5).abs() < 1e-9);
        // Upload ran in the sim but not the measured run: ratio 0, not null.
        let upload = streams
            .iter()
            .find(|s| s.get("stream").unwrap().as_str().unwrap() == "upload")
            .unwrap();
        assert!((upload.get("ratio").unwrap().as_f64().unwrap()).abs() < 1e-9);

        let kinds = rep.get("task_kinds").unwrap().as_arr().unwrap();
        let dr = kinds
            .iter()
            .find(|k| k.get("kind").unwrap().as_str().unwrap() == "disk_read")
            .unwrap();
        assert_eq!(dr.get("sim_count").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(dr.get("measured_count").unwrap().as_f64().unwrap(), 1.0);
        assert!(matches!(dr.get("ratio").unwrap(), Json::Null));
    }
}
