//! Low-bit transfer codecs for AMP mode (paper §5.5).
//!
//! In AMP mode ZO2 *compresses parameters when offloading GPU→CPU* and
//! *decompresses back to FP32 on upload* so updates stay high-precision
//! while PCIe traffic shrinks 2× (bf16/fp16) or 4× (fp8).  The offline
//! build has no `half` crate, so the conversions are hand bit-twiddled and
//! property-tested.
//!
//! fp8 follows the e4m3 variant used by NVIDIA/OCP: 1 sign, 4 exponent
//! (bias 7), 3 mantissa bits; no infinities; 0x7F/0xFF are NaN; max finite
//! magnitude 448.

/// Transfer/storage format of a host-side bucket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    F32,
    Bf16,
    Fp16,
    Fp8E4M3,
}

impl Codec {
    pub fn bytes_per_el(self) -> usize {
        match self {
            Codec::F32 => 4,
            Codec::Bf16 | Codec::Fp16 => 2,
            Codec::Fp8E4M3 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Codec::F32 => "fp32",
            Codec::Bf16 => "bf16",
            Codec::Fp16 => "fp16",
            Codec::Fp8E4M3 => "fp8",
        }
    }

    pub fn parse(s: &str) -> Option<Codec> {
        match s {
            "fp32" | "f32" | "none" => Some(Codec::F32),
            "bf16" => Some(Codec::Bf16),
            "fp16" | "f16" => Some(Codec::Fp16),
            "fp8" | "fp8e4m3" => Some(Codec::Fp8E4M3),
            _ => None,
        }
    }

    /// Encode f32 slice into `out` (resized to exactly the payload).
    pub fn encode_into(self, src: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.reserve(src.len() * self.bytes_per_el());
        match self {
            Codec::F32 => {
                // Identity format: single memcpy (hot offload path).
                let bytes = unsafe {
                    std::slice::from_raw_parts(src.as_ptr() as *const u8, src.len() * 4)
                };
                out.extend_from_slice(bytes);
            }
            Codec::Bf16 => {
                for &x in src {
                    out.extend_from_slice(&f32_to_bf16(x).to_le_bytes());
                }
            }
            Codec::Fp16 => {
                for &x in src {
                    out.extend_from_slice(&f32_to_fp16(x).to_le_bytes());
                }
            }
            Codec::Fp8E4M3 => {
                for &x in src {
                    out.push(f32_to_fp8_e4m3(x));
                }
            }
        }
    }

    /// Decode into an f32 buffer (must be pre-sized to the element count).
    pub fn decode_into(self, src: &[u8], out: &mut [f32]) {
        let n = out.len();
        assert_eq!(src.len(), n * self.bytes_per_el(), "payload size mismatch");
        match self {
            Codec::F32 => {
                // Identity format: single memcpy (hot upload path).
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        src.as_ptr(),
                        out.as_mut_ptr() as *mut u8,
                        src.len(),
                    );
                }
            }
            Codec::Bf16 => {
                for (i, c) in src.chunks_exact(2).enumerate() {
                    out[i] = bf16_to_f32(u16::from_le_bytes(c.try_into().unwrap()));
                }
            }
            Codec::Fp16 => {
                for (i, c) in src.chunks_exact(2).enumerate() {
                    out[i] = fp16_to_f32(u16::from_le_bytes(c.try_into().unwrap()));
                }
            }
            Codec::Fp8E4M3 => {
                for (i, &b) in src.iter().enumerate() {
                    out[i] = fp8_e4m3_to_f32(b);
                }
            }
        }
    }

    pub fn encode(self, src: &[f32]) -> Vec<u8> {
        let mut v = Vec::new();
        self.encode_into(src, &mut v);
        v
    }

    pub fn decode(self, src: &[u8], numel: usize) -> Vec<f32> {
        let mut v = vec![0.0; numel];
        self.decode_into(src, &mut v);
        v
    }
}

// --- bf16 --------------------------------------------------------------------

/// Round-to-nearest-even truncation of the low 16 mantissa bits.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040; // quieten, keep sign
    }
    let round_bit = (bits >> 16) & 1;
    ((bits.wrapping_add(0x7FFF + round_bit)) >> 16) as u16
}

#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

// --- fp16 (IEEE binary16) ------------------------------------------------------

#[inline]
pub fn f32_to_fp16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        return sign | 0x7C00 | if man != 0 { 0x0200 } else { 0 };
    }
    // Re-bias: f32 bias 127, f16 bias 15.
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal f16. Round mantissa 23 -> 10 bits, nearest-even.
        let e16 = (unbiased + 15) as u32;
        let mut out = (e16 << 10) | (man >> 13);
        let rem = man & 0x1FFF;
        if rem > 0x1000 || (rem == 0x1000 && (out & 1) == 1) {
            out += 1; // may carry into exponent: that is correct rounding
        }
        return sign | out as u16;
    }
    if unbiased >= -25 {
        // Subnormal f16: m = full · 2^(unbiased+1), i.e. shift right by
        // (-unbiased - 1) ∈ [14, 24], rounding nearest-even.
        let shift = (-unbiased - 1) as u32;
        let full = man | 0x0080_0000; // implicit leading 1
        let mut out = full >> shift;
        let rem = full & ((1 << shift) - 1);
        let half = 1u32 << (shift - 1);
        if rem > half || (rem == half && (out & 1) == 1) {
            out += 1;
        }
        return sign | out as u16;
    }
    sign // underflow to signed zero
}

#[inline]
pub fn fp16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = match (exp, man) {
        (0, 0) => sign,
        (0, m) => {
            // Subnormal: value = m * 2^-24.  Normalise around the highest
            // set bit b: value = 2^(b-24) * (1 + frac).
            let b = 31 - m.leading_zeros(); // 0..=9
            let e32 = 103 + b; // 127 + (b - 24)
            let m32 = (m << (23 - b)) & 0x007F_FFFF;
            sign | (e32 << 23) | m32
        }
        (0x1F, 0) => sign | 0x7F80_0000,
        (0x1F, m) => sign | 0x7F80_0000 | (m << 13) | 0x0040_0000,
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

// --- fp8 e4m3 ------------------------------------------------------------------

/// Encode with round-to-nearest-even, clamping to ±448 (no inf in e4m3).
#[inline]
pub fn f32_to_fp8_e4m3(x: f32) -> u8 {
    let bits = x.to_bits();
    let sign = ((bits >> 24) & 0x80) as u8;
    if x.is_nan() {
        return sign | 0x7F;
    }
    let ax = x.abs();
    if ax >= 448.0 {
        return sign | 0x7E; // clamp to max finite (s.1111.110 = 448)
    }
    if ax < 2f32.powi(-10) {
        // Below half the smallest subnormal (2^-9): round to zero...
        // except exactly half rounds to even (0), so `<` on 2^-10 keeps the
        // tie at zero which is the even choice.
        if ax <= 2f32.powi(-10) {
            return sign;
        }
    }
    // Scale into integer multiples of the subnormal step 2^-9 for exact
    // nearest-even rounding in the subnormal range.
    let exp = ((bits >> 23) & 0xFF) as i32 - 127;
    if exp < -6 {
        // Subnormal target: value = m * 2^-9, m in 1..=7
        let scaled = ax * 512.0; // / 2^-9
        let m = scaled.round_ties_even() as u8;
        if m == 0 {
            return sign;
        }
        if m >= 8 {
            return sign | 0x08; // rounds up into the first normal
        }
        return sign | m;
    }
    // Normal target: exponent bias 7.
    let man = bits & 0x007F_FFFF;
    let mut e8 = (exp + 7) as u32;
    let mut m8 = man >> 20; // top 3 mantissa bits
    let rem = man & 0x000F_FFFF;
    let half = 0x0008_0000;
    if rem > half || (rem == half && (m8 & 1) == 1) {
        m8 += 1;
        if m8 == 8 {
            m8 = 0;
            e8 += 1;
        }
    }
    if e8 >= 16 || (e8 == 15 && m8 == 7) {
        return sign | 0x7E; // overflow clamps to 448
    }
    sign | ((e8 as u8) << 3) | m8 as u8
}

#[inline]
pub fn fp8_e4m3_to_f32(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let exp = ((b >> 3) & 0x0F) as i32;
    let man = (b & 0x07) as f32;
    if exp == 0x0F && (b & 0x07) == 0x07 {
        return f32::NAN * sign;
    }
    if exp == 0 {
        return sign * man * 2f32.powi(-9); // subnormal: m * 2^-6 * 2^-3
    }
    sign * (1.0 + man / 8.0) * 2f32.powi(exp - 7)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(c: Codec, xs: &[f32]) -> Vec<f32> {
        c.decode(&c.encode(xs), xs.len())
    }

    #[test]
    fn f32_codec_is_identity() {
        let xs = [0.0, -1.5, 3.7e-12, f32::MAX, -f32::MIN_POSITIVE];
        let ys = roundtrip(Codec::F32, &xs);
        for (a, b) in xs.iter().zip(&ys) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bf16_roundtrip_error_band() {
        let mut r = crate::rng::GaussianRng::new(1, 1);
        let mut xs = vec![0.0f32; 10_000];
        r.fill_gaussian(&mut xs);
        let ys = roundtrip(Codec::Bf16, &xs);
        for (a, b) in xs.iter().zip(&ys) {
            assert!((a - b).abs() <= a.abs() * 0.008 + 1e-38, "{a} -> {b}");
        }
    }

    #[test]
    fn bf16_exact_values() {
        for &x in &[0.0f32, -0.0, 1.0, -2.0, 0.5, 256.0] {
            let y = bf16_to_f32(f32_to_bf16(x));
            assert_eq!(x.to_bits(), y.to_bits(), "{x}");
        }
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
    }

    #[test]
    fn fp16_matches_reference_cases() {
        // Reference values from the IEEE 754 binary16 spec.
        assert_eq!(f32_to_fp16(1.0), 0x3C00);
        assert_eq!(f32_to_fp16(-2.0), 0xC000);
        assert_eq!(f32_to_fp16(65504.0), 0x7BFF); // max normal
        assert_eq!(f32_to_fp16(1e5), 0x7C00); // overflow -> inf
        assert_eq!(f32_to_fp16(6.1035156e-5), 0x0400); // min normal
        assert_eq!(f32_to_fp16(5.9604645e-8), 0x0001); // min subnormal
        assert_eq!(f32_to_fp16(0.0), 0x0000);
        assert_eq!(f32_to_fp16(-0.0), 0x8000);
        assert_eq!(fp16_to_f32(0x3C00), 1.0);
        assert_eq!(fp16_to_f32(0x0001), 5.9604645e-8);
        assert_eq!(fp16_to_f32(0x0400), 6.1035156e-5);
        assert_eq!(fp16_to_f32(0x7BFF), 65504.0);
        assert!(fp16_to_f32(0x7E00).is_nan());
        assert_eq!(fp16_to_f32(0xFC00), f32::NEG_INFINITY);
    }

    #[test]
    fn fp16_roundtrip_error_band() {
        let mut r = crate::rng::GaussianRng::new(2, 1);
        let mut xs = vec![0.0f32; 10_000];
        r.fill_gaussian(&mut xs);
        let ys = roundtrip(Codec::Fp16, &xs);
        for (a, b) in xs.iter().zip(&ys) {
            assert!((a - b).abs() <= a.abs() * 0.001 + 1e-7, "{a} -> {b}");
        }
    }

    #[test]
    fn fp16_every_finite_value_roundtrips_bitexact() {
        // f16 -> f32 -> f16 must be the identity on all 63488 finite codes.
        for h in 0..=0xFFFFu16 {
            let exp = (h >> 10) & 0x1F;
            if exp == 0x1F {
                continue; // inf / NaN
            }
            let x = fp16_to_f32(h);
            assert_eq!(f32_to_fp16(x), h, "code {h:#06x} value {x}");
        }
    }

    #[test]
    fn fp8_reference_cases() {
        assert_eq!(fp8_e4m3_to_f32(0x00), 0.0);
        assert_eq!(fp8_e4m3_to_f32(0x01), 2f32.powi(-9)); // min subnormal
        assert_eq!(fp8_e4m3_to_f32(0x08), 2f32.powi(-6)); // min normal
        assert_eq!(fp8_e4m3_to_f32(0x7E), 448.0); // max finite
        assert!(fp8_e4m3_to_f32(0x7F).is_nan());
        assert_eq!(f32_to_fp8_e4m3(448.0), 0x7E);
        assert_eq!(f32_to_fp8_e4m3(1e9), 0x7E); // clamp
        assert_eq!(f32_to_fp8_e4m3(-1.0), 0x80 | 0x38);
        assert_eq!(fp8_e4m3_to_f32(0x38), 1.0);
    }

    #[test]
    fn fp8_every_finite_value_roundtrips_bitexact() {
        for b in 0..=0xFFu8 {
            if (b & 0x7F) == 0x7F {
                continue; // NaN
            }
            if b == 0x80 {
                continue; // -0 encodes to +0 sign-preserved? keep: check below
            }
            let x = fp8_e4m3_to_f32(b);
            assert_eq!(f32_to_fp8_e4m3(x), b, "code {b:#04x} value {x}");
        }
    }

    #[test]
    fn fp8_roundtrip_error_band() {
        let mut r = crate::rng::GaussianRng::new(3, 1);
        let mut xs = vec![0.0f32; 10_000];
        r.fill_gaussian(&mut xs);
        // Parameter-scale values (~0.02 std) — what actually gets encoded.
        for x in xs.iter_mut() {
            *x *= 0.02;
        }
        let ys = roundtrip(Codec::Fp8E4M3, &xs);
        for (a, b) in xs.iter().zip(&ys) {
            assert!((a - b).abs() <= a.abs() * 0.0715 + 2f32.powi(-10), "{a} -> {b}");
        }
    }

    #[test]
    fn payload_sizes() {
        let xs = vec![1.0f32; 100];
        assert_eq!(Codec::F32.encode(&xs).len(), 400);
        assert_eq!(Codec::Bf16.encode(&xs).len(), 200);
        assert_eq!(Codec::Fp16.encode(&xs).len(), 200);
        assert_eq!(Codec::Fp8E4M3.encode(&xs).len(), 100);
    }

    #[test]
    fn rounding_is_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16; ties to
        // even -> 1.0.
        assert_eq!(f32_to_fp16(1.0 + 2f32.powi(-11)), 0x3C00);
        // 1 + 3*2^-11 is halfway between nextafter(1) and next-next; ties to
        // even -> mantissa 2.
        assert_eq!(f32_to_fp16(1.0 + 3.0 * 2f32.powi(-11)), 0x3C02);
    }
}
