//! Low-bit transfer codecs for AMP mode (paper §5.5).
//!
//! In AMP mode ZO2 *compresses parameters when offloading GPU→CPU* and
//! *decompresses back to FP32 on upload* so updates stay high-precision
//! while PCIe traffic shrinks 2× (bf16/fp16) or 4× (fp8).  The offline
//! build has no `half` crate, so the conversions are hand bit-twiddled and
//! property-tested.
//!
//! fp8 follows the e4m3 variant used by NVIDIA/OCP: 1 sign, 4 exponent
//! (bias 7), 3 mantissa bits; no infinities; 0x7F/0xFF are NaN; max finite
//! magnitude 448.
//!
//! The hot paths are **table-driven**: fp16 decodes through a 65536-entry
//! LUT and encodes through per-exponent-class base/shift/round tables, fp8
//! decodes through a 256-entry LUT — killing the per-element subnormal
//! branches of the bit-twiddled reference conversions (which stay as the
//! specification and are asserted bit-equal).  [`Codec::encode_chunk`] /
//! [`Codec::decode_chunk`] are the slice-range entry points the
//! [`crate::hostpool`] kernels fan out over.

use std::sync::OnceLock;

/// Transfer/storage format of a host-side bucket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    F32,
    Bf16,
    Fp16,
    Fp8E4M3,
}

impl Codec {
    pub fn bytes_per_el(self) -> usize {
        match self {
            Codec::F32 => 4,
            Codec::Bf16 | Codec::Fp16 => 2,
            Codec::Fp8E4M3 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Codec::F32 => "fp32",
            Codec::Bf16 => "bf16",
            Codec::Fp16 => "fp16",
            Codec::Fp8E4M3 => "fp8",
        }
    }

    pub fn parse(s: &str) -> Option<Codec> {
        match s {
            "fp32" | "f32" | "none" => Some(Codec::F32),
            "bf16" => Some(Codec::Bf16),
            "fp16" | "f16" => Some(Codec::Fp16),
            "fp8" | "fp8e4m3" => Some(Codec::Fp8E4M3),
            _ => None,
        }
    }

    /// Encode one slice range into an exactly-sized wire buffer.  This is
    /// the chunk entry point the host pool fans out over; ranges encoded
    /// piecewise are byte-identical to a single whole-slice encode.
    /// Dispatches to the SIMD kernel when `--host-simd` resolves to one —
    /// bit-identical to the scalar body by construction.
    pub fn encode_chunk(self, src: &[f32], out: &mut [u8]) {
        self.encode_chunk_with(crate::simd::level(), src, out)
    }

    /// Encode with an explicit dispatch level (bench/test entry point).
    /// A vector level silently degrades to scalar on CPUs without the
    /// instruction set, keeping this API safe.
    pub fn encode_chunk_with(self, level: crate::simd::SimdLevel, src: &[f32], out: &mut [u8]) {
        assert_eq!(out.len(), src.len() * self.bytes_per_el(), "payload size mismatch");
        #[cfg(target_arch = "x86_64")]
        if level == crate::simd::SimdLevel::Avx2 && crate::simd::avx2_supported() {
            // Safety: AVX2 availability checked; sizes asserted above.
            unsafe { crate::simd::avx2::encode_chunk(self, src, out) };
            return;
        }
        let _ = level;
        match self {
            Codec::F32 => {
                // Identity format: single memcpy (hot offload path).
                // SAFETY: reinterpreting `src` as bytes is valid for any f32
                // payload; the assert above pins `out.len()` to exactly
                // `src.len() * 4`, and `src`/`out` are distinct borrowed
                // slices, so the copy is in-bounds and non-overlapping.
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        src.as_ptr() as *const u8,
                        out.as_mut_ptr(),
                        out.len(),
                    );
                }
            }
            Codec::Bf16 => {
                for (c, &x) in out.chunks_exact_mut(2).zip(src) {
                    c.copy_from_slice(&f32_to_bf16(x).to_le_bytes());
                }
            }
            Codec::Fp16 => {
                for (c, &x) in out.chunks_exact_mut(2).zip(src) {
                    c.copy_from_slice(&f32_to_fp16_tab(x).to_le_bytes());
                }
            }
            Codec::Fp8E4M3 => {
                for (b, &x) in out.iter_mut().zip(src) {
                    *b = f32_to_fp8_e4m3(x);
                }
            }
        }
    }

    /// Decode one wire range into an exactly-sized f32 buffer (chunk entry
    /// point; piecewise decodes are bit-identical to a whole-slice decode).
    /// SIMD-dispatched like [`Codec::encode_chunk`].
    pub fn decode_chunk(self, src: &[u8], out: &mut [f32]) {
        self.decode_chunk_with(crate::simd::level(), src, out)
    }

    /// Decode with an explicit dispatch level (bench/test entry point).
    pub fn decode_chunk_with(self, level: crate::simd::SimdLevel, src: &[u8], out: &mut [f32]) {
        assert_eq!(src.len(), out.len() * self.bytes_per_el(), "payload size mismatch");
        #[cfg(target_arch = "x86_64")]
        if level == crate::simd::SimdLevel::Avx2 && crate::simd::avx2_supported() {
            // Safety: AVX2 availability checked; sizes asserted above.
            unsafe { crate::simd::avx2::decode_chunk(self, src, out) };
            return;
        }
        let _ = level;
        match self {
            Codec::F32 => {
                // Identity format: single memcpy (hot upload path).
                // SAFETY: every 4-byte pattern is a valid f32, so filling
                // `out` bytewise is sound; the assert above pins `src.len()`
                // to exactly `out.len() * 4`, and `src`/`out` are distinct
                // borrowed slices, so the copy is in-bounds and
                // non-overlapping.
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        src.as_ptr(),
                        out.as_mut_ptr() as *mut u8,
                        src.len(),
                    );
                }
            }
            Codec::Bf16 => {
                for (o, c) in out.iter_mut().zip(src.chunks_exact(2)) {
                    *o = bf16_to_f32(u16::from_le_bytes([c[0], c[1]]));
                }
            }
            Codec::Fp16 => {
                let lut = fp16_lut();
                for (o, c) in out.iter_mut().zip(src.chunks_exact(2)) {
                    *o = lut[u16::from_le_bytes([c[0], c[1]]) as usize];
                }
            }
            Codec::Fp8E4M3 => {
                let lut = fp8_lut();
                for (o, &b) in out.iter_mut().zip(src) {
                    *o = lut[b as usize];
                }
            }
        }
    }

    /// Encode f32 slice into `out` (resized to exactly the payload).
    ///
    /// Shrink policy: a buffer reused across bucket sizes must not pin its
    /// high-water mark forever, so capacity beyond 2× the payload is
    /// released (the "cap at the largest live bucket" rule — steady reuse
    /// at one size never reallocates, a size drop frees the excess).
    pub fn encode_into(self, src: &[f32], out: &mut Vec<u8>) {
        let need = src.len() * self.bytes_per_el();
        if out.len() != need {
            // Size changed: one zero-fill pass.  The steady state (same
            // bucket size every step) skips this and pays exactly one
            // write pass — the encode itself.
            out.clear();
            out.resize(need, 0);
        }
        self.encode_chunk(src, out);
        crate::util::shrink_excess(out, need);
    }

    /// Decode into an f32 buffer (must be pre-sized to the element count).
    pub fn decode_into(self, src: &[u8], out: &mut [f32]) {
        self.decode_chunk(src, out);
    }

    pub fn encode(self, src: &[f32]) -> Vec<u8> {
        let mut v = Vec::new();
        self.encode_into(src, &mut v);
        v
    }

    pub fn decode(self, src: &[u8], numel: usize) -> Vec<f32> {
        let mut v = vec![0.0; numel];
        self.decode_into(src, &mut v);
        v
    }
}

// --- bf16 --------------------------------------------------------------------

/// Round-to-nearest-even truncation of the low 16 mantissa bits.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040; // quieten, keep sign
    }
    let round_bit = (bits >> 16) & 1;
    ((bits.wrapping_add(0x7FFF + round_bit)) >> 16) as u16
}

#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

// --- fp16 (IEEE binary16) ------------------------------------------------------

#[inline]
pub fn f32_to_fp16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        return sign | 0x7C00 | if man != 0 { 0x0200 } else { 0 };
    }
    // Re-bias: f32 bias 127, f16 bias 15.
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal f16. Round mantissa 23 -> 10 bits, nearest-even.
        let e16 = (unbiased + 15) as u32;
        let mut out = (e16 << 10) | (man >> 13);
        let rem = man & 0x1FFF;
        if rem > 0x1000 || (rem == 0x1000 && (out & 1) == 1) {
            out += 1; // may carry into exponent: that is correct rounding
        }
        return sign | out as u16;
    }
    if unbiased >= -25 {
        // Subnormal f16: m = full · 2^(unbiased+1), i.e. shift right by
        // (-unbiased - 1) ∈ [14, 24], rounding nearest-even.
        let shift = (-unbiased - 1) as u32;
        let full = man | 0x0080_0000; // implicit leading 1
        let mut out = full >> shift;
        let rem = full & ((1 << shift) - 1);
        let half = 1u32 << (shift - 1);
        if rem > half || (rem == half && (out & 1) == 1) {
            out += 1;
        }
        return sign | out as u16;
    }
    sign // underflow to signed zero
}

#[inline]
pub fn fp16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = match (exp, man) {
        (0, 0) => sign,
        (0, m) => {
            // Subnormal: value = m * 2^-24.  Normalise around the highest
            // set bit b: value = 2^(b-24) * (1 + frac).
            let b = 31 - m.leading_zeros(); // 0..=9
            let e32 = 103 + b; // 127 + (b - 24)
            let m32 = (m << (23 - b)) & 0x007F_FFFF;
            sign | (e32 << 23) | m32
        }
        (0x1F, 0) => sign | 0x7F80_0000,
        (0x1F, m) => sign | 0x7F80_0000 | (m << 13) | 0x0040_0000,
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

// --- fp16 tables ---------------------------------------------------------------

/// 65536-entry fp16 → f32 table (256 KiB, built once): replaces the
/// subnormal branch + `leading_zeros` of [`fp16_to_f32`] with one load.
/// `pub(crate)` so the AVX2 decode gathers from the *same* table.
pub(crate) fn fp16_lut() -> &'static [f32; 65536] {
    static LUT: OnceLock<Box<[f32; 65536]>> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = vec![0.0f32; 65536].into_boxed_slice();
        for h in 0..=0xFFFFu16 {
            t[h as usize] = fp16_to_f32(h);
        }
        t.try_into().expect("65536-entry table")
    })
}

/// Table-driven fp16 decode — bit-identical to [`fp16_to_f32`] by
/// construction (the table is built from it).
#[inline]
pub fn fp16_to_f32_lut(h: u16) -> f32 {
    fp16_lut()[h as usize]
}

/// Per-(sign, exponent)-class constants for the table-driven fp16 encode:
/// `out = base[cls] + (full >> shift[cls]) + rne(full & mask[cls])` where
/// `cls = f32_bits >> 23` (9 bits) and `full = mantissa | imp[cls]`.
struct F16Enc {
    base: [u16; 512],
    shift: [u8; 512],
    mask: [u32; 512],
    /// RNE tie point of the dropped bits; `u32::MAX` marks classes that
    /// never round (underflow-to-zero, overflow-to-inf), keeping the
    /// rounding arithmetic branch-free.
    half: [u32; 512],
    imp: [u32; 512],
}

fn f16_enc() -> &'static F16Enc {
    static TAB: OnceLock<Box<F16Enc>> = OnceLock::new();
    TAB.get_or_init(|| {
        let mut t = Box::new(F16Enc {
            base: [0; 512],
            shift: [0; 512],
            mask: [0; 512],
            half: [0; 512],
            imp: [0; 512],
        });
        for cls in 0..512usize {
            let sign = ((cls >> 8) as u16) << 15;
            let exp8 = (cls & 0xFF) as i32;
            let unbiased = exp8 - 127;
            if unbiased > 15 {
                // Overflow (and the inf/NaN class, which the encoder
                // branches around): clamp to signed infinity, no rounding.
                t.base[cls] = sign | 0x7C00;
                t.shift[cls] = 31;
                t.mask[cls] = 0;
                t.half[cls] = u32::MAX;
                t.imp[cls] = 0;
            } else if unbiased >= -14 {
                // Normal f16 target: rebias, keep the top 10 mantissa bits.
                t.base[cls] = sign | (((unbiased + 15) as u16) << 10);
                t.shift[cls] = 13;
                t.mask[cls] = 0x1FFF;
                t.half[cls] = 0x1000;
                t.imp[cls] = 0;
            } else if unbiased >= -25 {
                // Subnormal f16 target: shift the full significand
                // (implicit bit included) right by 14..=24.
                let shift = (-unbiased - 1) as u8;
                t.base[cls] = sign;
                t.shift[cls] = shift;
                t.mask[cls] = (1u32 << shift) - 1;
                t.half[cls] = 1u32 << (shift - 1);
                t.imp[cls] = 0x0080_0000;
            } else {
                // Underflow: signed zero, no rounding.
                t.base[cls] = sign;
                t.shift[cls] = 31;
                t.mask[cls] = 0;
                t.half[cls] = u32::MAX;
                t.imp[cls] = 0;
            }
        }
        t
    })
}

/// Table-driven f32 → fp16 with round-to-nearest-even — bit-identical to
/// [`f32_to_fp16`] (asserted exhaustively in tests) but branch-free on the
/// hot path: one class lookup + shift + branchless rounding.  The only
/// branch is the inf/NaN class, never taken for parameter data.
#[inline]
pub fn f32_to_fp16_tab(x: f32) -> u16 {
    let bits = x.to_bits();
    if (bits >> 23) & 0xFF == 0xFF {
        return f32_to_fp16(x); // inf/NaN: rare, keep the reference path
    }
    let t = f16_enc();
    let cls = (bits >> 23) as usize;
    let full = (bits & 0x007F_FFFF) | t.imp[cls];
    let out = t.base[cls].wrapping_add((full >> t.shift[cls]) as u16);
    let rem = full & t.mask[cls];
    let half = t.half[cls];
    let inc = u16::from(rem > half) | (u16::from(rem == half) & (out & 1));
    out.wrapping_add(inc)
}

/// [`F16Enc`] widened to u32 lanes for the AVX2 encoder's 32-bit gathers.
/// Values are bit-for-bit the [`f16_enc`] tables (`u16`/`u8` zero-extended;
/// the `u32::MAX` never-rounds sentinel carried through unchanged), so the
/// vector encode computes with literally the same constants as the scalar.
pub(crate) struct F16EncW {
    pub(crate) base: [u32; 512],
    pub(crate) shift: [u32; 512],
    pub(crate) mask: [u32; 512],
    pub(crate) half: [u32; 512],
    pub(crate) imp: [u32; 512],
}

pub(crate) fn f16_enc_w() -> &'static F16EncW {
    static TAB: OnceLock<Box<F16EncW>> = OnceLock::new();
    TAB.get_or_init(|| {
        let n = f16_enc();
        let mut w = Box::new(F16EncW {
            base: [0; 512],
            shift: [0; 512],
            mask: [0; 512],
            half: [0; 512],
            imp: [0; 512],
        });
        for cls in 0..512 {
            w.base[cls] = n.base[cls] as u32;
            w.shift[cls] = n.shift[cls] as u32;
            w.mask[cls] = n.mask[cls];
            w.half[cls] = n.half[cls];
            w.imp[cls] = n.imp[cls];
        }
        w
    })
}

// --- fp8 e4m3 ------------------------------------------------------------------

/// Encode with round-to-nearest-even, clamping to ±448 (no inf in e4m3).
#[inline]
pub fn f32_to_fp8_e4m3(x: f32) -> u8 {
    let bits = x.to_bits();
    let sign = ((bits >> 24) & 0x80) as u8;
    if x.is_nan() {
        return sign | 0x7F;
    }
    let ax = x.abs();
    if ax >= 448.0 {
        return sign | 0x7E; // clamp to max finite (s.1111.110 = 448)
    }
    if ax < 2f32.powi(-10) {
        // Below half the smallest subnormal (2^-9): round to zero...
        // except exactly half rounds to even (0), so `<` on 2^-10 keeps the
        // tie at zero which is the even choice.
        if ax <= 2f32.powi(-10) {
            return sign;
        }
    }
    // Scale into integer multiples of the subnormal step 2^-9 for exact
    // nearest-even rounding in the subnormal range.
    let exp = ((bits >> 23) & 0xFF) as i32 - 127;
    if exp < -6 {
        // Subnormal target: value = m * 2^-9, m in 1..=7
        let scaled = ax * 512.0; // / 2^-9
        let m = scaled.round_ties_even() as u8;
        if m == 0 {
            return sign;
        }
        if m >= 8 {
            return sign | 0x08; // rounds up into the first normal
        }
        return sign | m;
    }
    // Normal target: exponent bias 7.
    let man = bits & 0x007F_FFFF;
    let mut e8 = (exp + 7) as u32;
    let mut m8 = man >> 20; // top 3 mantissa bits
    let rem = man & 0x000F_FFFF;
    let half = 0x0008_0000;
    if rem > half || (rem == half && (m8 & 1) == 1) {
        m8 += 1;
        if m8 == 8 {
            m8 = 0;
            e8 += 1;
        }
    }
    if e8 >= 16 || (e8 == 15 && m8 == 7) {
        return sign | 0x7E; // overflow clamps to 448
    }
    sign | ((e8 as u8) << 3) | m8 as u8
}

#[inline]
pub fn fp8_e4m3_to_f32(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let exp = ((b >> 3) & 0x0F) as i32;
    let man = (b & 0x07) as f32;
    if exp == 0x0F && (b & 0x07) == 0x07 {
        return f32::NAN * sign;
    }
    if exp == 0 {
        return sign * man * 2f32.powi(-9); // subnormal: m * 2^-6 * 2^-3
    }
    sign * (1.0 + man / 8.0) * 2f32.powi(exp - 7)
}

/// 256-entry fp8 → f32 table (1 KiB, built once from the reference
/// conversion): the whole decode becomes one load.
/// `pub(crate)` so the AVX2 decode gathers from the *same* table.
pub(crate) fn fp8_lut() -> &'static [f32; 256] {
    static LUT: OnceLock<[f32; 256]> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [0.0f32; 256];
        for (b, slot) in t.iter_mut().enumerate() {
            *slot = fp8_e4m3_to_f32(b as u8);
        }
        t
    })
}

/// Table-driven fp8 decode — bit-identical to [`fp8_e4m3_to_f32`] by
/// construction.
#[inline]
pub fn fp8_e4m3_to_f32_lut(b: u8) -> f32 {
    fp8_lut()[b as usize]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(c: Codec, xs: &[f32]) -> Vec<f32> {
        c.decode(&c.encode(xs), xs.len())
    }

    #[test]
    fn f32_codec_is_identity() {
        let xs = [0.0, -1.5, 3.7e-12, f32::MAX, -f32::MIN_POSITIVE];
        let ys = roundtrip(Codec::F32, &xs);
        for (a, b) in xs.iter().zip(&ys) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bf16_roundtrip_error_band() {
        let mut r = crate::rng::GaussianRng::new(1, 1);
        let mut xs = vec![0.0f32; 10_000];
        r.fill_gaussian(&mut xs);
        let ys = roundtrip(Codec::Bf16, &xs);
        for (a, b) in xs.iter().zip(&ys) {
            assert!((a - b).abs() <= a.abs() * 0.008 + 1e-38, "{a} -> {b}");
        }
    }

    #[test]
    fn bf16_exact_values() {
        for &x in &[0.0f32, -0.0, 1.0, -2.0, 0.5, 256.0] {
            let y = bf16_to_f32(f32_to_bf16(x));
            assert_eq!(x.to_bits(), y.to_bits(), "{x}");
        }
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
    }

    #[test]
    fn fp16_matches_reference_cases() {
        // Reference values from the IEEE 754 binary16 spec.
        assert_eq!(f32_to_fp16(1.0), 0x3C00);
        assert_eq!(f32_to_fp16(-2.0), 0xC000);
        assert_eq!(f32_to_fp16(65504.0), 0x7BFF); // max normal
        assert_eq!(f32_to_fp16(1e5), 0x7C00); // overflow -> inf
        assert_eq!(f32_to_fp16(6.1035156e-5), 0x0400); // min normal
        assert_eq!(f32_to_fp16(5.9604645e-8), 0x0001); // min subnormal
        assert_eq!(f32_to_fp16(0.0), 0x0000);
        assert_eq!(f32_to_fp16(-0.0), 0x8000);
        assert_eq!(fp16_to_f32(0x3C00), 1.0);
        assert_eq!(fp16_to_f32(0x0001), 5.9604645e-8);
        assert_eq!(fp16_to_f32(0x0400), 6.1035156e-5);
        assert_eq!(fp16_to_f32(0x7BFF), 65504.0);
        assert!(fp16_to_f32(0x7E00).is_nan());
        assert_eq!(fp16_to_f32(0xFC00), f32::NEG_INFINITY);
    }

    #[test]
    fn fp16_roundtrip_error_band() {
        let mut r = crate::rng::GaussianRng::new(2, 1);
        let mut xs = vec![0.0f32; 10_000];
        r.fill_gaussian(&mut xs);
        let ys = roundtrip(Codec::Fp16, &xs);
        for (a, b) in xs.iter().zip(&ys) {
            assert!((a - b).abs() <= a.abs() * 0.001 + 1e-7, "{a} -> {b}");
        }
    }

    #[test]
    fn fp16_every_finite_value_roundtrips_bitexact() {
        // f16 -> f32 -> f16 must be the identity on all 63488 finite codes.
        for h in 0..=0xFFFFu16 {
            let exp = (h >> 10) & 0x1F;
            if exp == 0x1F {
                continue; // inf / NaN
            }
            let x = fp16_to_f32(h);
            assert_eq!(f32_to_fp16(x), h, "code {h:#06x} value {x}");
        }
    }

    #[test]
    fn fp8_reference_cases() {
        assert_eq!(fp8_e4m3_to_f32(0x00), 0.0);
        assert_eq!(fp8_e4m3_to_f32(0x01), 2f32.powi(-9)); // min subnormal
        assert_eq!(fp8_e4m3_to_f32(0x08), 2f32.powi(-6)); // min normal
        assert_eq!(fp8_e4m3_to_f32(0x7E), 448.0); // max finite
        assert!(fp8_e4m3_to_f32(0x7F).is_nan());
        assert_eq!(f32_to_fp8_e4m3(448.0), 0x7E);
        assert_eq!(f32_to_fp8_e4m3(1e9), 0x7E); // clamp
        assert_eq!(f32_to_fp8_e4m3(-1.0), 0x80 | 0x38);
        assert_eq!(fp8_e4m3_to_f32(0x38), 1.0);
    }

    #[test]
    fn fp8_every_finite_value_roundtrips_bitexact() {
        for b in 0..=0xFFu8 {
            if (b & 0x7F) == 0x7F {
                continue; // NaN
            }
            if b == 0x80 {
                continue; // -0 encodes to +0 sign-preserved? keep: check below
            }
            let x = fp8_e4m3_to_f32(b);
            assert_eq!(f32_to_fp8_e4m3(x), b, "code {b:#04x} value {x}");
        }
    }

    #[test]
    fn fp8_roundtrip_error_band() {
        let mut r = crate::rng::GaussianRng::new(3, 1);
        let mut xs = vec![0.0f32; 10_000];
        r.fill_gaussian(&mut xs);
        // Parameter-scale values (~0.02 std) — what actually gets encoded.
        for x in xs.iter_mut() {
            *x *= 0.02;
        }
        let ys = roundtrip(Codec::Fp8E4M3, &xs);
        for (a, b) in xs.iter().zip(&ys) {
            assert!((a - b).abs() <= a.abs() * 0.0715 + 2f32.powi(-10), "{a} -> {b}");
        }
    }

    #[test]
    fn payload_sizes() {
        let xs = vec![1.0f32; 100];
        assert_eq!(Codec::F32.encode(&xs).len(), 400);
        assert_eq!(Codec::Bf16.encode(&xs).len(), 200);
        assert_eq!(Codec::Fp16.encode(&xs).len(), 200);
        assert_eq!(Codec::Fp8E4M3.encode(&xs).len(), 100);
    }

    #[test]
    fn rounding_is_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16; ties to
        // even -> 1.0.
        assert_eq!(f32_to_fp16(1.0 + 2f32.powi(-11)), 0x3C00);
        // 1 + 3*2^-11 is halfway between nextafter(1) and next-next; ties to
        // even -> mantissa 2.
        assert_eq!(f32_to_fp16(1.0 + 3.0 * 2f32.powi(-11)), 0x3C02);
    }

    #[test]
    fn fp16_decode_lut_matches_reference_on_every_code() {
        for h in 0..=0xFFFFu16 {
            let a = fp16_to_f32(h);
            let b = fp16_to_f32_lut(h);
            assert_eq!(a.to_bits(), b.to_bits(), "code {h:#06x}");
        }
    }

    #[test]
    fn fp8_decode_lut_matches_reference_on_every_code() {
        for b in 0..=0xFFu8 {
            let x = fp8_e4m3_to_f32(b);
            let y = fp8_e4m3_to_f32_lut(b);
            assert_eq!(x.to_bits(), y.to_bits(), "code {b:#04x}");
        }
    }

    #[test]
    fn fp16_table_encode_matches_reference() {
        // Every (sign, exponent) class x structured mantissas: zeros, ones,
        // the RNE tie patterns around both the 13-bit (normal) and variable
        // (subnormal) drop widths, and the extremes.
        let mans: Vec<u32> = {
            let mut m = vec![0u32, 1, 0x7F_FFFF, 0x40_0000, 0x3F_FFFF];
            for shift in 13..=24u32 {
                let half = 1u32 << (shift - 1);
                for d in [half.wrapping_sub(1), half, half + 1] {
                    m.push(d & 0x7F_FFFF);
                }
                // Tie with odd/even truncated result.
                m.push((half | (1 << shift)) & 0x7F_FFFF);
            }
            m
        };
        for cls in 0..512u32 {
            for &man in &mans {
                let bits = (cls << 23) | man;
                let x = f32::from_bits(bits);
                assert_eq!(
                    f32_to_fp16(x),
                    f32_to_fp16_tab(x),
                    "bits {bits:#010x} (cls {cls}, man {man:#08x})"
                );
            }
        }
        // All f16-exact values roundtrip through the table encoder too.
        for h in 0..=0xFFFFu16 {
            if (h >> 10) & 0x1F == 0x1F {
                continue; // inf/NaN handled by the reference branch
            }
            assert_eq!(f32_to_fp16_tab(fp16_to_f32(h)), h, "code {h:#06x}");
        }
        // And a broad random sweep over raw bit patterns.
        let mut r = crate::rng::GaussianRng::new(77, 0);
        for _ in 0..2_000_000 {
            let bits = (r.next_below(u32::MAX as u64 + 1)) as u32;
            let x = f32::from_bits(bits);
            if x.is_nan() {
                // NaN payloads funnel through the same reference branch.
                assert_eq!(f32_to_fp16(x), f32_to_fp16_tab(x));
                continue;
            }
            assert_eq!(f32_to_fp16(x), f32_to_fp16_tab(x), "bits {bits:#010x}");
        }
    }

    #[test]
    fn chunked_encode_decode_equals_whole_slice() {
        let mut r = crate::rng::GaussianRng::new(4, 2);
        let mut xs = vec![0.0f32; 10_001];
        r.fill_gaussian(&mut xs);
        for x in xs.iter_mut() {
            *x *= 0.02;
        }
        for codec in [Codec::F32, Codec::Bf16, Codec::Fp16, Codec::Fp8E4M3] {
            let whole = codec.encode(&xs);
            let bpe = codec.bytes_per_el();
            // Piecewise encode with uneven splits.
            let mut piecewise = vec![0u8; whole.len()];
            let mut start = 0usize;
            for len in [1usize, 999, 4096, 2000, 2905] {
                codec.encode_chunk(
                    &xs[start..start + len],
                    &mut piecewise[start * bpe..(start + len) * bpe],
                );
                start += len;
            }
            assert_eq!(start, xs.len());
            assert_eq!(piecewise, whole, "{codec:?} encode");
            // Piecewise decode.
            let mut whole_dec = vec![0.0f32; xs.len()];
            codec.decode_into(&whole, &mut whole_dec);
            let mut piece_dec = vec![0.0f32; xs.len()];
            let mut start = 0usize;
            for len in [4097usize, 1, 2903, 3000] {
                codec.decode_chunk(
                    &whole[start * bpe..(start + len) * bpe],
                    &mut piece_dec[start..start + len],
                );
                start += len;
            }
            assert_eq!(start, xs.len());
            let same =
                whole_dec.iter().zip(&piece_dec).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "{codec:?} decode");
        }
    }

    #[test]
    fn encode_into_releases_oversized_capacity() {
        let big = vec![1.0f32; 1 << 16];
        let small = vec![1.0f32; 64];
        let mut buf = Vec::new();
        Codec::Bf16.encode_into(&big, &mut buf);
        assert!(buf.capacity() >= big.len() * 2);
        Codec::Bf16.encode_into(&small, &mut buf);
        assert_eq!(buf.len(), 128);
        assert!(
            buf.capacity() <= big.len() * 2 / 4,
            "capacity {} must shrink after the bucket size drops",
            buf.capacity()
        );
    }
}
