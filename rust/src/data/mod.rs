//! Synthetic workloads.
//!
//! The paper fine-tunes OPT checkpoints on SST-2 / SuperGLUE; neither the
//! checkpoints nor the datasets are available here, so we build synthetic
//! substitutes that exercise the same code paths (see DESIGN.md
//! substitution table):
//!
//! * [`SyntheticCorpus`] — an n-gram language with planted structure for the
//!   e2e loss-curve run: a learnable next-token distribution (templated
//!   clauses over a Zipf vocabulary) so that even slow ZO progress is
//!   visible as falling cross-entropy.
//! * [`ClassificationTask`] — SST-2-style template tasks ("<pattern tokens>
//!   … <label token>") used for the Table-3 accuracy-parity experiments:
//!   the model must put mass on the correct label token at the last
//!   position.

use crate::rng::GaussianRng;

/// Token-id batches shaped [batch, seq] for a fixed (B, T) AOT config.
#[derive(Debug, Clone)]
pub struct Batch {
    pub ids: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

/// N-gram corpus with planted bigram structure + templated clauses.
pub struct SyntheticCorpus {
    vocab: usize,
    /// Per-token preferred successor (deterministic bigram skeleton).
    next: Vec<i32>,
    rng: GaussianRng,
    /// Probability of following the skeleton vs drawing noise.
    fidelity: f64,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = GaussianRng::new(seed, 0xC0FFEE);
        let mut next = Vec::with_capacity(vocab);
        for _ in 0..vocab {
            next.push(rng.next_below(vocab as u64) as i32);
        }
        Self { vocab, next, rng, fidelity: 0.85 }
    }

    /// Sample one batch of continuation sequences.
    pub fn sample(&mut self, batch: usize, seq: usize) -> Batch {
        let mut ids = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut tok = self.rng.next_below(self.vocab as u64) as i32;
            ids.push(tok);
            for _ in 1..seq {
                tok = if self.rng.next_uniform() < self.fidelity {
                    self.next[tok as usize]
                } else {
                    self.rng.next_below(self.vocab as u64) as i32
                };
                ids.push(tok);
            }
        }
        Batch { ids, batch, seq }
    }

    /// Entropy floor of the corpus in nats (best achievable CE): the
    /// skeleton transition has probability `fidelity` + uniform leak.
    pub fn entropy_floor(&self) -> f64 {
        let p = self.fidelity + (1.0 - self.fidelity) / self.vocab as f64;
        let q = (1.0 - self.fidelity) / self.vocab as f64;
        -(p * p.ln() + (self.vocab as f64 - 1.0) * q * q.ln())
    }
}

/// A templated binary classification task (SST-2-like).
///
/// Each example is `[CTX...] pattern-tokens [CTX...] label-token`, where the
/// pattern determines the label.  Evaluation asks whether the model's
/// last-position argmax over the two label tokens matches.
pub struct ClassificationTask {
    pub name: String,
    vocab: usize,
    pub label_tokens: [i32; 2],
    /// Signature token planted in the context for each class.
    signature: [i32; 2],
    rng: GaussianRng,
}

impl ClassificationTask {
    /// `idx` selects one of the 7 synthetic tasks (stand-ins for SST-2, RTE,
    /// CB, BoolQ, WSC, WIC, MultiRC — same pipeline, different seeds).
    pub fn new(name: &str, vocab: usize, idx: u64, seed: u64) -> Self {
        assert!(vocab >= 8);
        let mut rng = GaussianRng::new(seed, 0xBEEF ^ idx);
        let l0 = rng.next_below((vocab - 2) as u64) as i32;
        let l1 = l0 + 1;
        let s0 = rng.next_below((vocab - 2) as u64) as i32;
        let s1 = (s0 + 3) % (vocab as i32 - 2);
        Self { name: name.into(), vocab, label_tokens: [l0, l1], signature: [s0, s1], rng }
    }

    /// Sample a labelled batch: returns ids [B, T] whose final token is the
    /// *true* label token (so LM loss teaches the mapping), plus labels.
    pub fn sample(&mut self, batch: usize, seq: usize) -> (Batch, Vec<u8>) {
        let mut ids = Vec::with_capacity(batch * seq);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let y = (self.rng.next_below(2)) as u8;
            labels.push(y);
            for t in 0..seq - 1 {
                // Plant the class signature at several positions.
                if t % 4 == 1 {
                    ids.push(self.signature[y as usize]);
                } else {
                    ids.push(self.rng.next_below(self.vocab as u64) as i32);
                }
            }
            ids.push(self.label_tokens[y as usize]);
        }
        (Batch { ids, batch, seq }, labels)
    }

    /// Accuracy of predictions (argmax restricted to the two label tokens,
    /// from the model's last-position logits).
    pub fn accuracy(&self, logits_last: &[f32], vocab: usize, labels: &[u8]) -> f64 {
        let b = labels.len();
        assert_eq!(logits_last.len(), b * vocab);
        let mut ok = 0;
        for (i, &y) in labels.iter().enumerate() {
            let row = &logits_last[i * vocab..(i + 1) * vocab];
            let s0 = row[self.label_tokens[0] as usize];
            let s1 = row[self.label_tokens[1] as usize];
            let pred = if s1 > s0 { 1 } else { 0 };
            if pred == y {
                ok += 1;
            }
        }
        ok as f64 / b as f64
    }
}

/// The 7 benchmark stand-ins of paper Table 3.
pub fn table3_tasks(vocab: usize, seed: u64) -> Vec<ClassificationTask> {
    ["SST-2", "RTE", "CB", "BoolQ", "WSC", "WIC", "MultiRC"]
        .iter()
        .enumerate()
        .map(|(i, name)| ClassificationTask::new(name, vocab, i as u64, seed))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic_and_structured() {
        let mut a = SyntheticCorpus::new(64, 7);
        let mut b = SyntheticCorpus::new(64, 7);
        let ba = a.sample(2, 16);
        let bb = b.sample(2, 16);
        assert_eq!(ba.ids, bb.ids);
        assert!(ba.ids.iter().all(|&t| (0..64).contains(&t)));
        // Structure: following the skeleton most of the time means repeated
        // bigrams appear far more often than chance.
        let big = a.sample(8, 512);
        let mut follows = 0;
        let mut total = 0;
        let c = SyntheticCorpus::new(64, 7); // fresh skeleton view
        for row in big.ids.chunks(512) {
            for w in row.windows(2) {
                total += 1;
                if c.next[w[0] as usize] == w[1] {
                    follows += 1;
                }
            }
        }
        let frac = follows as f64 / total as f64;
        assert!(frac > 0.7, "skeleton-following fraction {frac}");
    }

    #[test]
    fn entropy_floor_sane() {
        let c = SyntheticCorpus::new(64, 1);
        let h = c.entropy_floor();
        assert!(h > 0.0 && h < (64f64).ln(), "floor {h} vs uniform {}", (64f64).ln());
    }

    #[test]
    fn classification_task_batches() {
        let mut t = ClassificationTask::new("SST-2", 64, 0, 5);
        let (b, y) = t.sample(8, 16);
        assert_eq!(b.ids.len(), 128);
        assert_eq!(y.len(), 8);
        // Last token of each row is the label token.
        for (row, &lab) in b.ids.chunks(16).zip(&y) {
            assert_eq!(*row.last().unwrap(), t.label_tokens[lab as usize]);
        }
    }

    #[test]
    fn accuracy_metric() {
        let t = ClassificationTask::new("x", 8, 0, 1);
        let labels = vec![0u8, 1u8];
        let mut logits = vec![0.0f32; 2 * 8];
        logits[t.label_tokens[0] as usize] = 5.0; // row 0 predicts label 0
        logits[8 + t.label_tokens[1] as usize] = 5.0; // row 1 predicts label 1
        assert_eq!(t.accuracy(&logits, 8, &labels), 1.0);
    }

    #[test]
    fn seven_tasks() {
        assert_eq!(table3_tasks(64, 3).len(), 7);
    }
}
