//! Message transports for the elastic DP backend.
//!
//! Three concrete transports implement one small trait:
//!
//! - [`ChanTransport`] — in-process `mpsc` channels carrying encoded frames;
//!   the default for tests and for the serial reference run.
//! - [`StreamTransport`] — a framed byte stream over a Unix socket or TCP
//!   connection, used by real worker processes (and by in-test thread
//!   workers exercising the socket path).
//!
//! Every message is encoded by `protocol::Msg::encode` and framed with a u32
//! little-endian length prefix on streams. `recv_timeout` returns
//! `Ok(None)` on timeout (the peer may just be slow) and `Err` only when
//! the peer is gone for good — the supervisor maps the former to heartbeat
//! misses and the latter to membership removal.

// zo2-lint: allow-file(no-wall-clock): recv_timeout deadlines over real sockets
// are wall-clock by nature; timeouts surface as `Ok(None)`, never as data.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::protocol::Msg;

/// A bidirectional, message-oriented endpoint.
pub trait Transport: Send {
    /// Send one message. Errors mean the peer is unreachable.
    fn send(&mut self, msg: &Msg) -> Result<()>;

    /// Receive one message, waiting at most `timeout`. `Ok(None)` means the
    /// timeout elapsed with no complete message; `Err` means the peer hung
    /// up or the stream is corrupt.
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Msg>>;
}

/// In-process transport over `mpsc` channels of encoded frames.
pub struct ChanTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

/// Create a connected pair of in-process endpoints.
pub fn chan_pair() -> (ChanTransport, ChanTransport) {
    let (atx, brx) = mpsc::channel();
    let (btx, arx) = mpsc::channel();
    (ChanTransport { tx: atx, rx: arx }, ChanTransport { tx: btx, rx: brx })
}

impl Transport for ChanTransport {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        self.tx.send(msg.encode()).map_err(|_| anyhow::anyhow!("dp chan peer disconnected"))
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Msg>> {
        match self.rx.recv_timeout(timeout) {
            Ok(bytes) => Msg::decode(&bytes).map(Some),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => bail!("dp chan peer disconnected"),
        }
    }
}

enum Stream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Stream {
    fn set_read_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(t),
            Stream::Unix(s) => s.set_read_timeout(t),
        }
    }

    fn read_some(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Unix(s) => s.read(buf),
        }
    }

    fn write_all_bytes(&mut self, buf: &[u8]) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.write_all(buf),
            Stream::Unix(s) => s.write_all(buf),
        }
    }
}

/// Framed socket transport (TCP or Unix domain).
pub struct StreamTransport {
    stream: Stream,
    /// Bytes read from the stream that do not yet form a complete frame.
    pending: Vec<u8>,
}

impl StreamTransport {
    fn new(stream: Stream) -> Self {
        StreamTransport { stream, pending: Vec::new() }
    }

    /// If `pending` holds a complete frame, pop and decode it.
    fn try_pop_frame(&mut self) -> Result<Option<Msg>> {
        if self.pending.len() < 4 {
            return Ok(None);
        }
        let len =
            u32::from_le_bytes([self.pending[0], self.pending[1], self.pending[2], self.pending[3]])
                as usize;
        if self.pending.len() < 4 + len {
            return Ok(None);
        }
        let msg = Msg::decode(&self.pending[4..4 + len])?;
        self.pending.drain(..4 + len);
        Ok(Some(msg))
    }
}

impl Transport for StreamTransport {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        let body = msg.encode();
        let mut frame = Vec::with_capacity(4 + body.len());
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&body);
        self.stream.write_all_bytes(&frame).context("dp stream send")
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Msg>> {
        if let Some(msg) = self.try_pop_frame()? {
            return Ok(Some(msg));
        }
        self.stream
            .set_read_timeout(Some(timeout.max(Duration::from_millis(1))))
            .context("dp stream set timeout")?;
        let mut buf = [0u8; 4096];
        loop {
            match self.stream.read_some(&mut buf) {
                Ok(0) => bail!("dp stream peer closed the connection"),
                Ok(n) => {
                    self.pending.extend_from_slice(&buf[..n]);
                    if let Some(msg) = self.try_pop_frame()? {
                        return Ok(Some(msg));
                    }
                    // Partial frame: keep reading until the timeout fires.
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                    return Ok(None);
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(e).context("dp stream recv"),
            }
        }
    }
}

enum ListenerInner {
    Tcp(TcpListener),
    Unix(UnixListener),
}

/// A listening socket accepting worker connections.
pub struct Listener {
    inner: ListenerInner,
    /// The address workers should connect to (`tcp:host:port` or
    /// `unix:/path`), with any ephemeral port resolved.
    pub addr: String,
}

impl Listener {
    /// Bind a listener. `spec` is `tcp:HOST:PORT` (PORT may be 0 for an
    /// ephemeral port) or `unix:/path/to/socket`.
    pub fn bind(spec: &str) -> Result<Listener> {
        if let Some(addr) = spec.strip_prefix("tcp:") {
            let l = TcpListener::bind(addr).with_context(|| format!("bind tcp {addr}"))?;
            let local = l.local_addr().context("tcp local addr")?;
            Ok(Listener { inner: ListenerInner::Tcp(l), addr: format!("tcp:{local}") })
        } else if let Some(path) = spec.strip_prefix("unix:") {
            let _ = std::fs::remove_file(path);
            let l = UnixListener::bind(path).with_context(|| format!("bind unix {path}"))?;
            Ok(Listener { inner: ListenerInner::Unix(l), addr: format!("unix:{path}") })
        } else {
            bail!("transport spec must start with tcp: or unix:, got {spec:?}")
        }
    }

    /// Accept one connection, waiting at most `timeout`. `Ok(None)` on
    /// timeout.
    pub fn accept_timeout(&self, timeout: Duration) -> Result<Option<StreamTransport>> {
        match &self.inner {
            ListenerInner::Tcp(l) => {
                l.set_nonblocking(true).context("tcp set nonblocking")?;
                let deadline = std::time::Instant::now() + timeout;
                loop {
                    match l.accept() {
                        Ok((s, _)) => {
                            s.set_nonblocking(false).context("tcp stream blocking")?;
                            return Ok(Some(StreamTransport::new(Stream::Tcp(s))));
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            if std::time::Instant::now() >= deadline {
                                return Ok(None);
                            }
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(e) => return Err(e).context("tcp accept"),
                    }
                }
            }
            ListenerInner::Unix(l) => {
                l.set_nonblocking(true).context("unix set nonblocking")?;
                let deadline = std::time::Instant::now() + timeout;
                loop {
                    match l.accept() {
                        Ok((s, _)) => {
                            s.set_nonblocking(false).context("unix stream blocking")?;
                            return Ok(Some(StreamTransport::new(Stream::Unix(s))));
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            if std::time::Instant::now() >= deadline {
                                return Ok(None);
                            }
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(e) => return Err(e).context("unix accept"),
                    }
                }
            }
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        if let Some(path) = self.addr.strip_prefix("unix:") {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Connect to a supervisor listener address (`tcp:HOST:PORT` or
/// `unix:/path`).
pub fn connect(addr: &str) -> Result<StreamTransport> {
    if let Some(a) = addr.strip_prefix("tcp:") {
        let s = TcpStream::connect(a).with_context(|| format!("connect tcp {a}"))?;
        s.set_nodelay(true).ok();
        Ok(StreamTransport::new(Stream::Tcp(s)))
    } else if let Some(p) = addr.strip_prefix("unix:") {
        let s = UnixStream::connect(p).with_context(|| format!("connect unix {p}"))?;
        Ok(StreamTransport::new(Stream::Unix(s)))
    } else {
        bail!("connect addr must start with tcp: or unix:, got {addr:?}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chan_pair_roundtrips_and_times_out() {
        let (mut a, mut b) = chan_pair();
        a.send(&Msg::Ping { nonce: 9 }).unwrap();
        let got = b.recv_timeout(Duration::from_millis(200)).unwrap();
        assert_eq!(got, Some(Msg::Ping { nonce: 9 }));
        let none = b.recv_timeout(Duration::from_millis(10)).unwrap();
        assert_eq!(none, None);
        drop(a);
        assert!(b.recv_timeout(Duration::from_millis(10)).is_err());
    }

    #[test]
    fn tcp_stream_frames_messages_across_partial_reads() {
        let listener = Listener::bind("tcp:127.0.0.1:0").unwrap();
        let addr = listener.addr.clone();
        let client = std::thread::spawn(move || {
            let mut t = connect(&addr).unwrap();
            t.send(&Msg::Hello { worker: 3 }).unwrap();
            t.send(&Msg::Losses {
                worker: 3,
                step: 1,
                shard_ids: vec![0, 1],
                pairs: vec![(1.0, 2.0), (3.0, 4.0)],
            })
            .unwrap();
            let reply = t.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(reply, Some(Msg::Shutdown));
        });
        let mut server = listener.accept_timeout(Duration::from_secs(5)).unwrap().expect("accept");
        let hello = server.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(hello, Some(Msg::Hello { worker: 3 }));
        let losses = server.recv_timeout(Duration::from_secs(5)).unwrap();
        match losses {
            Some(Msg::Losses { worker: 3, step: 1, .. }) => {}
            other => panic!("unexpected {other:?}"),
        }
        server.send(&Msg::Shutdown).unwrap();
        client.join().unwrap();
    }

    #[test]
    fn unix_stream_roundtrips() {
        let path = std::env::temp_dir().join(format!("zo2_dp_test_{}.sock", std::process::id()));
        let spec = format!("unix:{}", path.display());
        let listener = Listener::bind(&spec).unwrap();
        let addr = listener.addr.clone();
        let client = std::thread::spawn(move || {
            let mut t = connect(&addr).unwrap();
            t.send(&Msg::Hello { worker: 0 }).unwrap();
            assert_eq!(
                t.recv_timeout(Duration::from_secs(5)).unwrap(),
                Some(Msg::Commit { step: 4, g: 0.5 })
            );
        });
        let mut server = listener.accept_timeout(Duration::from_secs(5)).unwrap().expect("accept");
        assert_eq!(
            server.recv_timeout(Duration::from_secs(5)).unwrap(),
            Some(Msg::Hello { worker: 0 })
        );
        server.send(&Msg::Commit { step: 4, g: 0.5 }).unwrap();
        client.join().unwrap();
    }

    #[test]
    fn listener_rejects_bad_spec() {
        assert!(Listener::bind("carrier-pigeon:coop").is_err());
        assert!(connect("smoke-signal:hill").is_err());
    }
}
