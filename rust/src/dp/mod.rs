//! Elastic fault-tolerant data-parallel backend (the "real" DP runtime).
//!
//! ZO2's DP wire contract is one seed broadcast and one scalar all-reduce
//! per step, and the all-reduce folds shard losses in canonical shard
//! order, so the loss trajectory depends only on the shard set — never on
//! how many workers exist or which worker evaluated which shard. This
//! module exploits that: workers can die, straggle, join mid-run, or be
//! resumed from a checkpoint, and the trajectory stays bit-identical to a
//! fault-free single-worker run.
//!
//! Layout:
//! - [`protocol`] — the message set and its wire encoding;
//! - [`transport`] — in-process channels plus Unix/TCP framed streams;
//! - [`faults`] — deterministic fault schedules and the injecting wrapper;
//! - [`worker`] — the replica trait, reference worker, and serve loop;
//! - [`supervisor`] — membership, heartbeats, reassignment, all-reduce;
//! - [`checkpoint`] — snapshot persistence through the `DiskPool`.
//!
//! [`run_elastic`] wires these together for the CLI and tests: it spawns
//! workers (threads over channels or sockets, or real processes running
//! `dp-worker`), registers scheduled joiners, and supervises the run.

pub mod checkpoint;
pub mod faults;
pub mod protocol;
pub mod supervisor;
pub mod transport;
pub mod worker;

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

pub use faults::{Fault, FaultSchedule, MsgKind, WorkerFaults};
pub use protocol::{Msg, WorkerSnapshot};
pub use supervisor::{Joiner, RunOutcome, StepRecord, Supervisor, SupervisorConfig};
pub use transport::{chan_pair, connect, ChanTransport, Listener, StreamTransport, Transport};
pub use worker::{serve, ElasticWorker, SeedZoWorker, ServeExit};

/// FNV-1a over the little-endian bit patterns of `params`: a compact
/// fingerprint for comparing final states across runs (logs, CI) without
/// shipping full vectors.
pub fn params_fingerprint(params: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for p in params {
        for b in p.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Which channel workers speak over.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process mpsc channels (the serial reference path).
    Chan,
    /// Unix domain socket at this path.
    Unix(PathBuf),
    /// TCP on this host:port ("127.0.0.1:0" picks an ephemeral port).
    Tcp(String),
}

impl TransportKind {
    /// Parse `chan`, `unix[:/path]`, or `tcp[:host:port]`.
    pub fn parse(spec: &str) -> Result<TransportKind> {
        if spec == "chan" {
            Ok(TransportKind::Chan)
        } else if spec == "unix" {
            let p = std::env::temp_dir().join(format!("zo2_dp_{}.sock", std::process::id()));
            Ok(TransportKind::Unix(p))
        } else if let Some(path) = spec.strip_prefix("unix:") {
            Ok(TransportKind::Unix(PathBuf::from(path)))
        } else if spec == "tcp" {
            Ok(TransportKind::Tcp("127.0.0.1:0".to_string()))
        } else if let Some(addr) = spec.strip_prefix("tcp:") {
            Ok(TransportKind::Tcp(addr.to_string()))
        } else {
            bail!("unknown --dp-transport {spec:?} (want chan | unix[:/path] | tcp[:host:port])")
        }
    }
}

/// Configuration for one elastic DP run.
#[derive(Debug, Clone)]
pub struct ElasticRunConfig {
    pub transport: TransportKind,
    /// Initial worker count (joiners from the fault schedule come extra).
    pub workers: usize,
    pub shards: usize,
    pub shard_len: usize,
    pub steps: u64,
    pub schedule: FaultSchedule,
    /// Persistent checkpoint pool path.
    pub checkpoint: Option<PathBuf>,
    /// Checkpoint every N steps (0 = final only, when a path is set).
    pub checkpoint_every: u64,
    /// Resume from `checkpoint` if it exists.
    pub resume: bool,
    pub seed: u64,
    pub data_seed: u64,
    pub n_params: usize,
    /// Spawn real `dp-worker` processes (socket transports only); when
    /// false, socket workers run as in-process threads over real sockets.
    pub processes: bool,
}

impl ElasticRunConfig {
    pub fn quick(workers: usize, shards: usize, steps: u64) -> ElasticRunConfig {
        ElasticRunConfig {
            transport: TransportKind::Chan,
            workers,
            shards,
            shard_len: 8,
            steps,
            schedule: FaultSchedule::none(),
            checkpoint: None,
            checkpoint_every: 0,
            resume: false,
            seed: 90,
            data_seed: 4242,
            n_params: 64,
            processes: false,
        }
    }
}

type ThreadHandle = std::thread::JoinHandle<Result<ServeExit>>;

/// Everything spawned for a run that must be reaped afterwards.
#[derive(Default)]
struct Reaper {
    threads: Vec<ThreadHandle>,
    processes: Vec<std::process::Child>,
}

impl Reaper {
    /// Join every worker; injected kills are expected exits, anything else
    /// abnormal is an error.
    fn reap(mut self) -> Result<()> {
        for h in self.threads.drain(..) {
            match h.join() {
                Ok(Ok(_exit)) => {}
                Ok(Err(e)) => return Err(e.context("worker thread failed")),
                Err(_) => bail!("worker thread panicked"),
            }
        }
        for mut p in self.processes.drain(..) {
            let status = p.wait().context("waiting for worker process")?;
            ensure!(status.success(), "worker process exited with {status}");
        }
        Ok(())
    }
}

fn spawn_thread_worker(
    reaper: &Mutex<Reaper>,
    transport: impl Transport + 'static,
    id: u32,
    faults: WorkerFaults,
    seed: u64,
    n_params: usize,
) {
    let h = std::thread::spawn(move || {
        serve(transport, SeedZoWorker::new(seed, n_params), id, faults, Duration::from_secs(60))
    });
    reaper.lock().unwrap().threads.push(h);
}

fn spawn_process_worker(
    reaper: &Mutex<Reaper>,
    addr: &str,
    id: u32,
    faults: WorkerFaults,
    seed: u64,
    n_params: usize,
) -> Result<()> {
    let exe = std::env::current_exe().context("locating dp-worker executable")?;
    let mut cmd = std::process::Command::new(exe);
    cmd.arg("dp-worker")
        .arg("--connect")
        .arg(addr)
        .arg("--worker")
        .arg(id.to_string())
        .arg("--seed")
        .arg(seed.to_string())
        .arg("--n-params")
        .arg(n_params.to_string());
    if let Some(ks) = faults.kill_step {
        cmd.arg("--kill-at").arg(ks.to_string());
    }
    if let Some((ss, ms)) = faults.stall {
        cmd.arg("--stall-at").arg(ss.to_string()).arg("--stall-ms").arg(ms.to_string());
    }
    let child = cmd.spawn().context("spawning dp-worker process")?;
    reaper.lock().unwrap().processes.push(child);
    Ok(())
}

/// Spawn one worker (by the configured mechanism) and hand back the
/// supervisor-side transport, fault-wrapped.
fn launch_worker(
    cfg: &ElasticRunConfig,
    listener: Option<&Arc<Listener>>,
    reaper: &Mutex<Reaper>,
    id: u32,
) -> Result<Box<dyn Transport>> {
    let faults = cfg.schedule.worker_faults(id);
    match (&cfg.transport, listener) {
        (TransportKind::Chan, _) => {
            let (sup, wrk) = chan_pair();
            spawn_thread_worker(reaper, wrk, id, faults, cfg.seed, cfg.n_params);
            Ok(Box::new(cfg.schedule.wrap(id, sup)))
        }
        (_, Some(listener)) => {
            if cfg.processes {
                spawn_process_worker(reaper, &listener.addr, id, faults, cfg.seed, cfg.n_params)?;
            } else {
                let addr = listener.addr.clone();
                let (seed, n_params) = (cfg.seed, cfg.n_params);
                let h = std::thread::spawn(move || {
                    let t = connect(&addr)?;
                    serve(t, SeedZoWorker::new(seed, n_params), id, faults, Duration::from_secs(60))
                });
                reaper.lock().unwrap().threads.push(h);
            }
            let t = listener
                .accept_timeout(Duration::from_secs(20))?
                .context("worker did not connect before the accept deadline")?;
            Ok(Box::new(cfg.schedule.wrap(id, t)))
        }
        (_, None) => bail!("socket transport requires a listener"),
    }
}

/// Run the elastic DP backend end to end: spawn the initial workers,
/// register scheduled joiners, supervise the trajectory, and reap every
/// worker. Returns the canonical per-step records and final state.
pub fn run_elastic(cfg: &ElasticRunConfig) -> Result<RunOutcome> {
    ensure!(cfg.workers > 0, "need at least one initial worker");
    let listener = match &cfg.transport {
        TransportKind::Chan => None,
        TransportKind::Unix(path) => {
            Some(Arc::new(Listener::bind(&format!("unix:{}", path.display()))?))
        }
        TransportKind::Tcp(addr) => Some(Arc::new(Listener::bind(&format!("tcp:{addr}"))?)),
    };
    ensure!(listener.is_some() || !cfg.processes, "--dp-processes requires a socket transport");

    let resume_snap = match (&cfg.checkpoint, cfg.resume) {
        (Some(path), true) if path.exists() => {
            Some(checkpoint::load_worker_checkpoint(path).context("loading resume checkpoint")?)
        }
        (None, true) => bail!("resume requested but no --checkpoint path given"),
        _ => None,
    };

    let sup_cfg = SupervisorConfig {
        shards: cfg.shards,
        shard_len: cfg.shard_len,
        steps: cfg.steps,
        seed: cfg.seed,
        data_seed: cfg.data_seed,
        n_params: cfg.n_params,
        recv_timeout: Duration::from_millis(150),
        max_retries: 8,
        checkpoint: cfg.checkpoint.clone(),
        checkpoint_every: cfg.checkpoint_every,
    };
    let mut sup = Supervisor::new(sup_cfg, resume_snap)?;

    let reaper = Arc::new(Mutex::new(Reaper::default()));
    for id in 0..cfg.workers as u32 {
        let t = launch_worker(cfg, listener.as_ref(), &reaper, id)?;
        sup.add_worker(id, t);
    }
    for (jw, jstep) in cfg.schedule.joins() {
        let cfg2 = cfg.clone();
        let listener2 = listener.clone();
        let reaper2 = Arc::clone(&reaper);
        sup.add_joiner(Joiner {
            worker: jw,
            step: jstep,
            connect: Box::new(move || launch_worker(&cfg2, listener2.as_ref(), &reaper2, jw)),
        });
    }

    let outcome = sup.run()?;
    match Arc::try_unwrap(reaper) {
        Ok(m) => m.into_inner().unwrap().reap()?,
        Err(_) => bail!("worker bookkeeping leaked past the run"),
    }
    Ok(outcome)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("chan").unwrap(), TransportKind::Chan);
        assert_eq!(
            TransportKind::parse("unix:/tmp/x.sock").unwrap(),
            TransportKind::Unix(PathBuf::from("/tmp/x.sock"))
        );
        assert_eq!(
            TransportKind::parse("tcp:127.0.0.1:7777").unwrap(),
            TransportKind::Tcp("127.0.0.1:7777".to_string())
        );
        assert!(matches!(TransportKind::parse("unix").unwrap(), TransportKind::Unix(_)));
        assert!(matches!(TransportKind::parse("tcp").unwrap(), TransportKind::Tcp(_)));
        assert!(TransportKind::parse("telegraph").is_err());
    }
}
