//! Wire protocol for the elastic data-parallel backend.
//!
//! ZO2's data-parallel step needs exactly two logical messages per worker per
//! step: a shard assignment carrying the token batch (the "seed broadcast" —
//! the perturbation itself is derived from the shared RNG contract, so only
//! data and shard ids travel) and a scalar loss-pair reply that feeds the
//! all-reduce. Everything else in this enum exists for membership: liveness
//! probes, state transfer for joiners, and commit broadcasts that let a
//! worker which missed a round catch up from the g-scalar log.
//!
//! The encoding is a tiny hand-rolled little-endian binary format with a
//! one-byte tag per message; streams frame each message with a u32 length
//! prefix (see `transport`). No external serialization crates are used.

use anyhow::{ensure, Context, Result};

/// Full state of a worker replica: the step it has committed through and its
/// flat parameter vector. Checkpoints and joiner catch-up both move this.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerSnapshot {
    /// Number of fully committed steps (the next step to run).
    pub step: u64,
    /// Flat f32 parameters, bit-exact.
    pub params: Vec<f32>,
}

/// Messages exchanged between the supervisor and workers.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Worker announces itself after connecting.
    Hello { worker: u32 },
    /// Supervisor assigns shards for one step. `tokens` is the full step
    /// batch laid out shard-major (`shard_len` tokens per shard);
    /// `shard_ids` selects which shards this worker evaluates. `catchup`
    /// carries committed g scalars for steps `[catchup_from, step)` that the
    /// worker may have missed (dropped Commit messages self-repair here).
    Assign {
        step: u64,
        shard_len: u32,
        shard_ids: Vec<u32>,
        tokens: Vec<i32>,
        catchup_from: u64,
        catchup: Vec<f32>,
    },
    /// Worker replies with one (loss_plus, loss_minus) pair per assigned
    /// shard, in the same order as `shard_ids`.
    Losses {
        worker: u32,
        step: u64,
        shard_ids: Vec<u32>,
        pairs: Vec<(f32, f32)>,
    },
    /// Supervisor broadcasts the all-reduced projected gradient for a step.
    Commit { step: u64, g: f32 },
    /// Liveness probe.
    Ping { nonce: u64 },
    /// Liveness reply.
    Pong { worker: u32, nonce: u64 },
    /// Supervisor pushes a snapshot plus a g-scalar replay tail to bring a
    /// joiner to the current step.
    LoadState { snap: WorkerSnapshot, replay: Vec<f32> },
    /// Supervisor asks a worker for its current snapshot (used to verify
    /// bitwise agreement at shutdown).
    FetchState,
    /// Worker returns its snapshot.
    State { snap: WorkerSnapshot },
    /// Orderly shutdown.
    Shutdown,
}

const TAG_HELLO: u8 = 1;
const TAG_ASSIGN: u8 = 2;
const TAG_LOSSES: u8 = 3;
const TAG_COMMIT: u8 = 4;
const TAG_PING: u8 = 5;
const TAG_PONG: u8 = 6;
const TAG_LOAD_STATE: u8 = 7;
const TAG_FETCH_STATE: u8 = 8;
const TAG_STATE: u8 = 9;
const TAG_SHUTDOWN: u8 = 10;

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.pos + n <= self.buf.len(),
            "dp message truncated: need {} bytes at offset {}, have {}",
            n,
            self.pos,
            self.buf.len()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f32_vec(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32()?);
        }
        Ok(v)
    }

    fn u32_vec(&mut self) -> Result<Vec<u32>> {
        let n = self.u32()? as usize;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u32()?);
        }
        Ok(v)
    }

    fn i32_vec(&mut self) -> Result<Vec<i32>> {
        let n = self.u32()? as usize;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u32()? as i32);
        }
        Ok(v)
    }

    fn done(&self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "dp message has {} trailing bytes",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

fn put_f32_vec(buf: &mut Vec<u8>, v: &[f32]) {
    put_u32(buf, v.len() as u32);
    for &x in v {
        put_f32(buf, x);
    }
}

fn put_u32_vec(buf: &mut Vec<u8>, v: &[u32]) {
    put_u32(buf, v.len() as u32);
    for &x in v {
        put_u32(buf, x);
    }
}

fn put_i32_vec(buf: &mut Vec<u8>, v: &[i32]) {
    put_u32(buf, v.len() as u32);
    for &x in v {
        put_u32(buf, x as u32);
    }
}

fn put_snapshot(buf: &mut Vec<u8>, snap: &WorkerSnapshot) {
    put_u64(buf, snap.step);
    put_f32_vec(buf, &snap.params);
}

fn read_snapshot(r: &mut Reader<'_>) -> Result<WorkerSnapshot> {
    let step = r.u64()?;
    let params = r.f32_vec()?;
    Ok(WorkerSnapshot { step, params })
}

impl Msg {
    /// Encode to the little-endian wire format (without stream framing).
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        match self {
            Msg::Hello { worker } => {
                buf.push(TAG_HELLO);
                put_u32(&mut buf, *worker);
            }
            Msg::Assign { step, shard_len, shard_ids, tokens, catchup_from, catchup } => {
                buf.push(TAG_ASSIGN);
                put_u64(&mut buf, *step);
                put_u32(&mut buf, *shard_len);
                put_u32_vec(&mut buf, shard_ids);
                put_i32_vec(&mut buf, tokens);
                put_u64(&mut buf, *catchup_from);
                put_f32_vec(&mut buf, catchup);
            }
            Msg::Losses { worker, step, shard_ids, pairs } => {
                buf.push(TAG_LOSSES);
                put_u32(&mut buf, *worker);
                put_u64(&mut buf, *step);
                put_u32_vec(&mut buf, shard_ids);
                put_u32(&mut buf, pairs.len() as u32);
                for &(lp, lm) in pairs {
                    put_f32(&mut buf, lp);
                    put_f32(&mut buf, lm);
                }
            }
            Msg::Commit { step, g } => {
                buf.push(TAG_COMMIT);
                put_u64(&mut buf, *step);
                put_f32(&mut buf, *g);
            }
            Msg::Ping { nonce } => {
                buf.push(TAG_PING);
                put_u64(&mut buf, *nonce);
            }
            Msg::Pong { worker, nonce } => {
                buf.push(TAG_PONG);
                put_u32(&mut buf, *worker);
                put_u64(&mut buf, *nonce);
            }
            Msg::LoadState { snap, replay } => {
                buf.push(TAG_LOAD_STATE);
                put_snapshot(&mut buf, snap);
                put_f32_vec(&mut buf, replay);
            }
            Msg::FetchState => buf.push(TAG_FETCH_STATE),
            Msg::State { snap } => {
                buf.push(TAG_STATE);
                put_snapshot(&mut buf, snap);
            }
            Msg::Shutdown => buf.push(TAG_SHUTDOWN),
        }
        buf
    }

    /// Decode one message from an unframed byte slice.
    pub fn decode(bytes: &[u8]) -> Result<Msg> {
        let mut r = Reader::new(bytes);
        let tag = r.u8().context("dp message empty")?;
        let msg = match tag {
            TAG_HELLO => Msg::Hello { worker: r.u32()? },
            TAG_ASSIGN => {
                let step = r.u64()?;
                let shard_len = r.u32()?;
                let shard_ids = r.u32_vec()?;
                let tokens = r.i32_vec()?;
                let catchup_from = r.u64()?;
                let catchup = r.f32_vec()?;
                Msg::Assign { step, shard_len, shard_ids, tokens, catchup_from, catchup }
            }
            TAG_LOSSES => {
                let worker = r.u32()?;
                let step = r.u64()?;
                let shard_ids = r.u32_vec()?;
                let n = r.u32()? as usize;
                let mut pairs = Vec::with_capacity(n);
                for _ in 0..n {
                    let lp = r.f32()?;
                    let lm = r.f32()?;
                    pairs.push((lp, lm));
                }
                Msg::Losses { worker, step, shard_ids, pairs }
            }
            TAG_COMMIT => Msg::Commit { step: r.u64()?, g: r.f32()? },
            TAG_PING => Msg::Ping { nonce: r.u64()? },
            TAG_PONG => Msg::Pong { worker: r.u32()?, nonce: r.u64()? },
            TAG_LOAD_STATE => {
                let snap = read_snapshot(&mut r)?;
                let replay = r.f32_vec()?;
                Msg::LoadState { snap, replay }
            }
            TAG_FETCH_STATE => Msg::FetchState,
            TAG_STATE => Msg::State { snap: read_snapshot(&mut r)? },
            TAG_SHUTDOWN => Msg::Shutdown,
            other => anyhow::bail!("unknown dp message tag {other}"),
        };
        r.done()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) {
        let bytes = msg.encode();
        let back = Msg::decode(&bytes).expect("decode");
        assert_eq!(msg, back);
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Msg::Hello { worker: 7 });
        roundtrip(Msg::Assign {
            step: 12,
            shard_len: 3,
            shard_ids: vec![0, 2, 5],
            tokens: vec![1, -2, 40_000, 0, 9, 9, 1, 2, 3],
            catchup_from: 10,
            catchup: vec![0.25, -1.5],
        });
        roundtrip(Msg::Losses {
            worker: 2,
            step: 12,
            shard_ids: vec![1, 3],
            pairs: vec![(0.5, 0.25), (f32::MIN_POSITIVE, -0.0)],
        });
        roundtrip(Msg::Commit { step: 3, g: -0.125 });
        roundtrip(Msg::Ping { nonce: u64::MAX });
        roundtrip(Msg::Pong { worker: 0, nonce: 1 });
        roundtrip(Msg::LoadState {
            snap: WorkerSnapshot { step: 9, params: vec![1.0, 2.5, -3.75] },
            replay: vec![0.1, 0.2],
        });
        roundtrip(Msg::FetchState);
        roundtrip(Msg::State { snap: WorkerSnapshot { step: 0, params: vec![] } });
        roundtrip(Msg::Shutdown);
    }

    #[test]
    fn nan_g_survives_roundtrip_bitwise() {
        let msg = Msg::Commit { step: 1, g: f32::NAN };
        let back = Msg::decode(&msg.encode()).unwrap();
        match back {
            Msg::Commit { g, .. } => assert_eq!(g.to_bits(), f32::NAN.to_bits()),
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn truncated_and_trailing_bytes_are_rejected() {
        let bytes = Msg::Commit { step: 1, g: 0.5 }.encode();
        assert!(Msg::decode(&bytes[..bytes.len() - 1]).is_err());
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(Msg::decode(&extra).is_err());
        assert!(Msg::decode(&[]).is_err());
        assert!(Msg::decode(&[200]).is_err());
    }
}
