//! Checkpoint/restore of replica state through the persistent `DiskPool`.
//!
//! A DP checkpoint is deliberately tiny: because perturbations are derived
//! from (seed, step) and updates from the committed g scalars, the full
//! optimizer + RNG state reduces to *the committed step count plus the flat
//! parameters*. The parameters live as an fp32 bucket in a persistent
//! `DiskPool` file; a JSON sidecar (`<pool>.meta.json`) records the bucket
//! layout and step so `DiskBucket::at` can reconstruct the handle on
//! restore. fp32 round-trips bit-exactly through the pool, which is what
//! makes kill-and-resume continue the identical trajectory.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use super::protocol::WorkerSnapshot;
use crate::memory::{DiskBucket, DiskPool, TransferModel};
use crate::precision::Codec;

/// Schema tag written into the sidecar; bump on layout changes.
pub use crate::util::schema::DP_CKPT_SCHEMA as CKPT_SCHEMA;

fn meta_path(pool_path: &Path) -> std::path::PathBuf {
    let mut s = pool_path.as_os_str().to_os_string();
    s.push(".meta.json");
    std::path::PathBuf::from(s)
}

/// Write `snap` to `path` as a persistent pool file plus sidecar metadata.
/// Each save rewrites the pool from scratch — checkpoints supersede each
/// other; history is not kept.
pub fn save_worker_checkpoint(path: &Path, snap: &WorkerSnapshot) -> Result<()> {
    let _ = std::fs::remove_file(path);
    let pool = DiskPool::create_persistent(
        path.to_path_buf(),
        u64::MAX,
        TransferModel::nvme_read(),
        TransferModel::nvme_write(),
    )
    .context("creating checkpoint pool")?;
    let mut bytes = Vec::with_capacity(snap.params.len() * 4);
    for &p in &snap.params {
        bytes.extend_from_slice(&p.to_le_bytes());
    }
    let bucket = pool.append(Codec::F32, snap.params.len(), &bytes)?;
    let meta = format!(
        "{{\"schema\": \"{}\", \"step\": {}, \"numel\": {}, \"offset\": {}}}\n",
        CKPT_SCHEMA,
        snap.step,
        snap.params.len(),
        bucket.offset()
    );
    std::fs::write(meta_path(path), meta).context("writing checkpoint sidecar")?;
    Ok(())
}

/// Load a snapshot previously written by [`save_worker_checkpoint`].
pub fn load_worker_checkpoint(path: &Path) -> Result<WorkerSnapshot> {
    let meta_raw = std::fs::read_to_string(meta_path(path))
        .with_context(|| format!("reading checkpoint sidecar for {}", path.display()))?;
    let meta = crate::util::json::Json::parse(&meta_raw).context("parsing checkpoint sidecar")?;
    let schema = meta.get("schema")?.as_str()?;
    ensure!(schema == CKPT_SCHEMA, "unknown checkpoint schema {schema:?}");
    let step = meta.get("step")?.as_f64()? as u64;
    let numel = meta.get("numel")?.as_usize()?;
    let offset = meta.get("offset")?.as_f64()? as u64;
    let pool = DiskPool::open_persistent(
        path.to_path_buf(),
        TransferModel::nvme_read(),
        TransferModel::nvme_write(),
    )
    .context("opening checkpoint pool")?;
    let bucket = DiskBucket::at(Codec::F32, numel, offset);
    let bytes = pool.read(&bucket)?;
    ensure!(bytes.len() == numel * 4, "checkpoint bucket truncated");
    let params = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(WorkerSnapshot { step, params })
}

/// Remove a checkpoint and its sidecar (test hygiene).
pub fn remove_checkpoint(path: &Path) {
    let _ = std::fs::remove_file(path);
    let _ = std::fs::remove_file(meta_path(path));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_roundtrip_is_bit_exact() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("zo2_dp_ckpt_test_{}.pool", std::process::id()));
        let snap = WorkerSnapshot {
            step: 17,
            params: vec![0.1, -0.0, f32::MIN_POSITIVE, 1.0e30, -42.5],
        };
        save_worker_checkpoint(&path, &snap).unwrap();
        let back = load_worker_checkpoint(&path).unwrap();
        assert_eq!(back.step, 17);
        let a: Vec<u32> = snap.params.iter().map(|p| p.to_bits()).collect();
        let b: Vec<u32> = back.params.iter().map(|p| p.to_bits()).collect();
        assert_eq!(a, b);
        // A later save supersedes the first.
        let snap2 = WorkerSnapshot { step: 18, params: vec![7.0; 5] };
        save_worker_checkpoint(&path, &snap2).unwrap();
        assert_eq!(load_worker_checkpoint(&path).unwrap().step, 18);
        remove_checkpoint(&path);
        assert!(load_worker_checkpoint(&path).is_err());
    }
}
