//! Worker replica and serve loop for the elastic DP backend.
//!
//! A worker owns a full model replica and speaks the seed+scalar protocol:
//! evaluate (loss⁺, loss⁻) pairs for assigned shards, apply committed
//! projected gradients in step order, and transfer snapshots for joins and
//! shutdown verification. Evaluation never mutates parameters, so any
//! assignment can be retried idempotently until its step commits — that is
//! the property every recovery path in this module leans on.

use std::time::Duration;

use anyhow::{bail, ensure, Result};

use super::faults::WorkerFaults;
use super::protocol::{Msg, WorkerSnapshot};
use super::transport::Transport;
use crate::rng::GaussianRng;

/// A model replica driven by the elastic protocol. Implementations must keep
/// `eval_shards` free of side effects on parameters and apply commits
/// strictly in step order (ignoring duplicates of already-committed steps).
pub trait ElasticWorker: Send {
    /// Dual-perturbation loss pairs for `shards` at `step`. Pure in params.
    fn eval_shards(&mut self, step: u64, shards: &[&[i32]]) -> Result<Vec<(f32, f32)>>;
    /// Apply the all-reduced g for `step`. Duplicate commits of earlier
    /// steps are ignored; a gap (step beyond the next) is an error.
    fn commit(&mut self, step: u64, g: f32) -> Result<()>;
    /// Number of fully committed steps (the next step this worker can run).
    fn committed(&self) -> u64;
    /// Bit-exact state capture.
    fn snapshot(&self) -> WorkerSnapshot;
    /// Restore from a snapshot, then replay committed gs for the steps
    /// `snap.step, snap.step+1, ...` — the seed-replay catch-up path.
    fn restore(&mut self, snap: &WorkerSnapshot, replay: &[f32]) -> Result<()>;
}

/// The reference ZO worker: a quadratic surrogate whose perturbations and
/// updates follow the exact MeZO recipe (shared-seed z per step, dual loss
/// evaluation, `p -= lr * g * z`). Small enough to run hundreds of faulted
/// steps in CI, faithful enough that the DP wire contract is identical to
/// the full engine's.
pub struct SeedZoWorker {
    params: Vec<f32>,
    seed: u64,
    committed: u64,
    eps: f32,
    lr: f32,
}

impl SeedZoWorker {
    pub const EPS: f32 = 1e-3;
    pub const LR: f32 = 1e-2;

    pub fn new(seed: u64, n_params: usize) -> SeedZoWorker {
        let mut params = vec![0.0f32; n_params];
        GaussianRng::new(seed, u64::MAX).fill_gaussian(&mut params);
        SeedZoWorker { params, seed, committed: 0, eps: Self::EPS, lr: Self::LR }
    }

    pub fn eps(&self) -> f32 {
        self.eps
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// The shared-seed perturbation for `step`: every replica derives the
    /// same z from (seed, step), so only scalars ever cross the wire.
    fn z(&self, step: u64) -> Vec<f32> {
        let mut z = vec![0.0f32; self.params.len()];
        GaussianRng::new(self.seed, step).fill_gaussian(&mut z);
        z
    }

    fn loss(params: &[f32], shard: &[i32]) -> f32 {
        let mut acc = 0.0f32;
        for (j, &p) in params.iter().enumerate() {
            let tok = shard[j % shard.len()];
            let target = ((tok as f32) * 0.01).sin();
            let d = p - target;
            acc += d * d;
        }
        acc / params.len() as f32
    }
}

impl ElasticWorker for SeedZoWorker {
    fn eval_shards(&mut self, step: u64, shards: &[&[i32]]) -> Result<Vec<(f32, f32)>> {
        ensure!(
            step == self.committed,
            "eval for step {step} but worker has committed {} steps",
            self.committed
        );
        let z = self.z(step);
        let mut plus = self.params.clone();
        let mut minus = self.params.clone();
        for ((p, m), zi) in plus.iter_mut().zip(minus.iter_mut()).zip(&z) {
            *p += self.eps * zi;
            *m -= self.eps * zi;
        }
        let mut pairs = Vec::with_capacity(shards.len());
        for shard in shards {
            ensure!(!shard.is_empty(), "empty shard in eval at step {step}");
            pairs.push((Self::loss(&plus, shard), Self::loss(&minus, shard)));
        }
        Ok(pairs)
    }

    fn commit(&mut self, step: u64, g: f32) -> Result<()> {
        if step < self.committed {
            return Ok(()); // duplicate of an already-applied commit
        }
        ensure!(
            step == self.committed,
            "commit gap: got step {step}, worker has committed {} steps",
            self.committed
        );
        let z = self.z(step);
        for (p, zi) in self.params.iter_mut().zip(&z) {
            *p -= self.lr * g * zi;
        }
        self.committed += 1;
        Ok(())
    }

    fn committed(&self) -> u64 {
        self.committed
    }

    fn snapshot(&self) -> WorkerSnapshot {
        WorkerSnapshot { step: self.committed, params: self.params.clone() }
    }

    fn restore(&mut self, snap: &WorkerSnapshot, replay: &[f32]) -> Result<()> {
        self.params = snap.params.clone();
        self.committed = snap.step;
        for (i, &g) in replay.iter().enumerate() {
            self.commit(snap.step + i as u64, g)?;
        }
        Ok(())
    }
}

/// Why a serve loop ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeExit {
    /// Orderly shutdown requested by the supervisor.
    Shutdown,
    /// An injected kill fault fired: the worker dies abruptly, connection
    /// dropped mid-protocol.
    Killed,
    /// The supervisor hung up (e.g. it declared this worker dead after a
    /// straggle); the worker exits quietly rather than erroring.
    Orphaned,
}

/// Slice the shard-major step batch into this worker's assigned shards.
fn select_shards<'a>(
    tokens: &'a [i32],
    shard_len: usize,
    shard_ids: &[u32],
) -> Result<Vec<&'a [i32]>> {
    let mut out = Vec::with_capacity(shard_ids.len());
    for &sid in shard_ids {
        let start = sid as usize * shard_len;
        ensure!(
            start + shard_len <= tokens.len(),
            "assignment references shard {sid} beyond batch of {} tokens",
            tokens.len()
        );
        out.push(&tokens[start..start + shard_len]);
    }
    Ok(out)
}

/// Drive one worker over a transport until shutdown (or an injected kill).
/// `idle_timeout` bounds how long the worker waits with no supervisor
/// traffic before giving up.
pub fn serve<T: Transport, W: ElasticWorker>(
    mut transport: T,
    mut worker: W,
    id: u32,
    faults: WorkerFaults,
    idle_timeout: Duration,
) -> Result<ServeExit> {
    // Any transport failure means the supervisor is gone (it buried us or
    // crashed); that is an orphaned exit, not a worker error.
    if transport.send(&Msg::Hello { worker: id }).is_err() {
        return Ok(ServeExit::Orphaned);
    }
    let mut idle = Duration::ZERO;
    let tick = Duration::from_millis(200);
    loop {
        let msg = match transport.recv_timeout(tick) {
            Err(_) => return Ok(ServeExit::Orphaned),
            Ok(Some(m)) => {
                idle = Duration::ZERO;
                m
            }
            Ok(None) => {
                idle += tick;
                ensure!(idle < idle_timeout, "worker {id}: no supervisor traffic for {idle:?}");
                continue;
            }
        };
        match msg {
            Msg::Assign { step, shard_len, shard_ids, tokens, catchup_from, catchup } => {
                // Self-repair: apply any committed gs we missed (dropped
                // Commit broadcasts) before touching this step.
                for (i, &g) in catchup.iter().enumerate() {
                    let s = catchup_from + i as u64;
                    if s == worker.committed() && s < step {
                        worker.commit(s, g)?;
                    }
                }
                if faults.kill_step == Some(step) {
                    return Ok(ServeExit::Killed);
                }
                if let Some((s, ms)) = faults.stall {
                    if s == step {
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                }
                if step == worker.committed() {
                    let shards = select_shards(&tokens, shard_len as usize, &shard_ids)?;
                    let pairs = worker.eval_shards(step, &shards)?;
                    let reply = Msg::Losses { worker: id, step, shard_ids, pairs };
                    if transport.send(&reply).is_err() {
                        return Ok(ServeExit::Orphaned);
                    }
                } else if step > worker.committed() {
                    bail!(
                        "worker {id}: assignment for step {step} but only {} steps committed \
                         and catch-up did not cover the gap",
                        worker.committed()
                    );
                }
                // step < committed: a stale retry from before our commit
                // landed; the supervisor has already moved on.
            }
            Msg::Commit { step, g } => {
                // Only apply the next in-order commit; anything later will
                // arrive again via Assign catch-up.
                if step == worker.committed() {
                    worker.commit(step, g)?;
                }
            }
            Msg::Ping { nonce } => {
                if transport.send(&Msg::Pong { worker: id, nonce }).is_err() {
                    return Ok(ServeExit::Orphaned);
                }
            }
            Msg::LoadState { snap, replay } => {
                worker.restore(&snap, &replay)?;
                if transport.send(&Msg::State { snap: worker.snapshot() }).is_err() {
                    return Ok(ServeExit::Orphaned);
                }
            }
            Msg::FetchState => {
                if transport.send(&Msg::State { snap: worker.snapshot() }).is_err() {
                    return Ok(ServeExit::Orphaned);
                }
            }
            Msg::Shutdown => return Ok(ServeExit::Shutdown),
            other => bail!("worker {id}: unexpected message {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard(tok: i32) -> Vec<i32> {
        vec![tok; 8]
    }

    #[test]
    fn eval_is_pure_and_commit_advances() {
        let mut w = SeedZoWorker::new(90, 64);
        let before = w.snapshot();
        let s0 = shard(100);
        let shards = [s0.as_slice()];
        let a = w.eval_shards(0, &shards).unwrap();
        let b = w.eval_shards(0, &shards).unwrap();
        assert_eq!(a, b);
        assert_eq!(w.snapshot(), before);
        w.commit(0, 0.5).unwrap();
        assert_eq!(w.committed(), 1);
        assert_ne!(w.snapshot().params, before.params);
        // Duplicate commit of an applied step is a no-op.
        let after = w.snapshot();
        w.commit(0, 123.0).unwrap();
        assert_eq!(w.snapshot(), after);
        // A gap is an error.
        assert!(w.commit(5, 0.1).is_err());
    }

    #[test]
    fn restore_with_replay_matches_live_trajectory() {
        let gs = [0.5f32, -0.25, 0.125, 0.0625];
        let mut live = SeedZoWorker::new(7, 32);
        for (s, &g) in gs.iter().enumerate() {
            live.commit(s as u64, g).unwrap();
        }
        let mut resumed = SeedZoWorker::new(7, 32);
        for (s, &g) in gs.iter().take(2).enumerate() {
            resumed.commit(s as u64, g).unwrap();
        }
        // A joiner needs the matching seed (for z replay) plus the snapshot.
        let mut joiner = SeedZoWorker::new(7, 32);
        joiner.restore(&resumed.snapshot(), &gs[2..]).unwrap();
        assert_eq!(joiner.snapshot(), live.snapshot());
    }

    #[test]
    fn select_shards_bounds_checked() {
        let tokens: Vec<i32> = (0..16).collect();
        let got = select_shards(&tokens, 8, &[1]).unwrap();
        assert_eq!(got[0], &tokens[8..16]);
        assert!(select_shards(&tokens, 8, &[2]).is_err());
    }
}
