//! Deterministic fault injection for the elastic DP backend.
//!
//! A [`FaultSchedule`] is a fixed list of faults pinned to (worker, step)
//! coordinates: worker kills and stalls, mid-run joins, and message-level
//! drop/duplicate/delay rules. Schedules come from an explicit spec string
//! (`kill:w1@10,delay:losses:w0@4:2,join:w2@12`) or from a seed
//! (`seeded:123`), and are applied underneath the transport by
//! [`FaultyTransport`] so the supervisor and workers see faults exactly as
//! they would see real network misbehavior.
//!
//! Everything is counted in messages and steps, never wall-clock time, so a
//! given schedule replays identically on every run — which is what lets the
//! tests assert bit-identical loss trajectories under fire.

use std::collections::VecDeque;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use super::protocol::Msg;
use super::transport::Transport;
use crate::rng::GaussianRng;

/// Which protocol message a drop/dup/delay rule applies to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgKind {
    Assign,
    Losses,
    Commit,
}

impl MsgKind {
    fn parse(s: &str) -> Result<MsgKind> {
        match s {
            "assign" => Ok(MsgKind::Assign),
            "losses" => Ok(MsgKind::Losses),
            "commit" => Ok(MsgKind::Commit),
            other => bail!("unknown message kind {other:?} (want assign|losses|commit)"),
        }
    }
}

/// A single injected fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Worker exits abruptly when it receives the assignment for `step`.
    Kill { worker: u32, step: u64 },
    /// Worker sleeps `ms` milliseconds before answering the assignment for
    /// `step` (a straggler, not a death).
    Stall { worker: u32, step: u64, ms: u64 },
    /// A new worker with this id connects just before `step` runs.
    Join { worker: u32, step: u64 },
    /// The first matching message for (worker, step) is silently dropped.
    Drop { worker: u32, step: u64, what: MsgKind },
    /// The first matching message is delivered twice.
    Dup { worker: u32, step: u64, what: MsgKind },
    /// The first matching message is held back and delivered only after
    /// `by` further messages have moved in the same direction.
    Delay { worker: u32, step: u64, what: MsgKind, by: u32 },
}

/// A deterministic schedule of faults for one run.
#[derive(Debug, Clone, Default)]
pub struct FaultSchedule {
    faults: Vec<Fault>,
}

/// Worker-side faults for one worker, handed to its serve loop.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerFaults {
    /// Die when the assignment for this step arrives.
    pub kill_step: Option<u64>,
    /// Sleep (step, ms) before answering this step's assignment.
    pub stall: Option<(u64, u64)>,
}

fn parse_worker_at(spec: &str) -> Result<(u32, u64)> {
    // "w<i>@<step>"
    let rest = spec.strip_prefix('w').with_context(|| format!("expected w<i>@<step>: {spec:?}"))?;
    let (w, s) = rest.split_once('@').with_context(|| format!("expected w<i>@<step>: {spec:?}"))?;
    Ok((
        w.parse::<u32>().with_context(|| format!("bad worker index in {spec:?}"))?,
        s.parse::<u64>().with_context(|| format!("bad step in {spec:?}"))?,
    ))
}

fn next_part<'a>(
    parts: &mut std::str::Split<'a, char>,
    what: &str,
    entry: &str,
) -> Result<&'a str> {
    parts.next().with_context(|| format!("{what} missing in fault entry {entry:?}"))
}

impl FaultSchedule {
    /// An empty, fault-free schedule.
    pub fn none() -> FaultSchedule {
        FaultSchedule::default()
    }

    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    /// Parse a schedule spec. Entries are comma-separated:
    ///
    /// - `kill:w1@10` — kill worker 1 at step 10
    /// - `stall:w2@6:50` — worker 2 stalls 50 ms before answering step 6
    /// - `join:w3@20` — worker 3 joins just before step 20
    /// - `drop:assign:w0@5` — drop worker 0's step-5 assignment
    /// - `dup:losses:w2@4` — duplicate worker 2's step-4 loss reply
    /// - `delay:losses:w1@7:2` — hold worker 1's step-7 reply back 2 messages
    /// - `seeded:123` — generate a schedule from seed 123 (must be the only
    ///   entry); `workers` and `steps` bound the generated coordinates.
    pub fn parse(spec: &str, workers: usize, steps: u64) -> Result<FaultSchedule> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(FaultSchedule::none());
        }
        if let Some(seed) = spec.strip_prefix("seeded:") {
            let seed = seed.parse::<u64>().with_context(|| format!("bad seed in {spec:?}"))?;
            return Ok(FaultSchedule::seeded(seed, workers, steps));
        }
        let mut faults = Vec::new();
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let mut parts = entry.split(':');
            let head = parts.next().unwrap_or_default();
            match head {
                "kill" => {
                    let (worker, step) = parse_worker_at(next_part(&mut parts, "target", entry)?)?;
                    faults.push(Fault::Kill { worker, step });
                }
                "stall" => {
                    let (worker, step) = parse_worker_at(next_part(&mut parts, "target", entry)?)?;
                    let ms = next_part(&mut parts, "stall ms", entry)?
                        .parse::<u64>()
                        .with_context(|| format!("bad ms in {entry:?}"))?;
                    faults.push(Fault::Stall { worker, step, ms });
                }
                "join" => {
                    let (worker, step) = parse_worker_at(next_part(&mut parts, "target", entry)?)?;
                    faults.push(Fault::Join { worker, step });
                }
                "drop" | "dup" => {
                    let kind = MsgKind::parse(next_part(&mut parts, "message kind", entry)?)?;
                    let (worker, step) = parse_worker_at(next_part(&mut parts, "target", entry)?)?;
                    faults.push(if head == "drop" {
                        Fault::Drop { worker, step, what: kind }
                    } else {
                        Fault::Dup { worker, step, what: kind }
                    });
                }
                "delay" => {
                    let kind = MsgKind::parse(next_part(&mut parts, "message kind", entry)?)?;
                    let (worker, step) = parse_worker_at(next_part(&mut parts, "target", entry)?)?;
                    let by = next_part(&mut parts, "delay count", entry)?
                        .parse::<u32>()
                        .with_context(|| format!("bad delay count in {entry:?}"))?;
                    faults.push(Fault::Delay { worker, step, what: kind, by });
                }
                other => bail!("unknown fault {other:?} in {entry:?}"),
            }
            ensure!(parts.next().is_none(), "trailing fields in fault entry {entry:?}");
        }
        Ok(FaultSchedule { faults })
    }

    /// Generate a deterministic schedule from a seed. Always contains at
    /// least one kill (never of the last survivor), one message delay, one
    /// duplicate, one drop, and one mid-run join — the full acceptance
    /// gauntlet — with coordinates drawn from the seed.
    pub fn seeded(seed: u64, workers: usize, steps: u64) -> FaultSchedule {
        let k = workers.max(2) as u64;
        let span = steps.max(8);
        let mut rng = GaussianRng::new(seed, 0xFA_017);
        // Draw a step in the middle half of the run so recovery has room to
        // play out before the trajectory check.
        let mid = |rng: &mut GaussianRng| span / 4 + rng.next_below((span / 2).max(1));
        let kill_w = rng.next_below(k) as u32;
        let kill_s = mid(&mut rng);
        let delay_w = rng.next_below(k) as u32;
        let dup_w = rng.next_below(k) as u32;
        let drop_w = rng.next_below(k) as u32;
        let join_s = mid(&mut rng).max(2);
        let early = |rng: &mut GaussianRng| rng.next_below(span / 4 + 1);
        let faults = vec![
            Fault::Kill { worker: kill_w, step: kill_s },
            Fault::Delay {
                worker: delay_w,
                step: early(&mut rng),
                what: MsgKind::Losses,
                by: 1 + rng.next_below(2) as u32,
            },
            Fault::Dup { worker: dup_w, step: early(&mut rng), what: MsgKind::Losses },
            Fault::Drop { worker: drop_w, step: early(&mut rng), what: MsgKind::Commit },
            Fault::Join { worker: workers as u32, step: join_s },
            Fault::Stall {
                worker: rng.next_below(k) as u32,
                step: early(&mut rng),
                ms: 5 + rng.next_below(20),
            },
        ];
        FaultSchedule { faults }
    }

    /// Worker-side faults (kill/stall) for one worker id.
    pub fn worker_faults(&self, worker: u32) -> WorkerFaults {
        let mut wf = WorkerFaults::default();
        for f in &self.faults {
            match *f {
                Fault::Kill { worker: w, step } if w == worker => wf.kill_step = Some(step),
                Fault::Stall { worker: w, step, ms } if w == worker => wf.stall = Some((step, ms)),
                _ => {}
            }
        }
        wf
    }

    /// Scheduled joins as (worker id, step), sorted by step.
    pub fn joins(&self) -> Vec<(u32, u64)> {
        let mut js: Vec<(u32, u64)> = self
            .faults
            .iter()
            .filter_map(|f| match *f {
                Fault::Join { worker, step } => Some((worker, step)),
                _ => None,
            })
            .collect();
        js.sort_by_key(|&(_, s)| s);
        js
    }

    /// The highest worker id mentioned anywhere in the schedule.
    pub fn max_worker(&self) -> Option<u32> {
        self.faults
            .iter()
            .map(|f| match *f {
                Fault::Kill { worker, .. }
                | Fault::Stall { worker, .. }
                | Fault::Join { worker, .. }
                | Fault::Drop { worker, .. }
                | Fault::Dup { worker, .. }
                | Fault::Delay { worker, .. } => worker,
            })
            .max()
    }

    /// One-shot message rules for a worker's supervisor-side endpoint.
    fn rules_for(&self, worker: u32) -> Vec<MsgRule> {
        self.faults
            .iter()
            .filter_map(|f| match *f {
                Fault::Drop { worker: w, step, what } if w == worker => {
                    Some(MsgRule { step, what, action: MsgAction::Drop })
                }
                Fault::Dup { worker: w, step, what } if w == worker => {
                    Some(MsgRule { step, what, action: MsgAction::Dup })
                }
                Fault::Delay { worker: w, step, what, by } if w == worker => {
                    Some(MsgRule { step, what, action: MsgAction::Delay(by) })
                }
                _ => None,
            })
            .collect()
    }

    /// Wrap a supervisor-side endpoint for `worker` with this schedule's
    /// message faults.
    pub fn wrap<T: Transport>(&self, worker: u32, inner: T) -> FaultyTransport<T> {
        FaultyTransport {
            inner,
            rules: self.rules_for(worker),
            delayed_send: VecDeque::new(),
            recv_queue: VecDeque::new(),
            recv_delayed: Vec::new(),
        }
    }
}

#[derive(Debug, Clone, Copy)]
enum MsgAction {
    Drop,
    Dup,
    Delay(u32),
}

#[derive(Debug, Clone, Copy)]
struct MsgRule {
    step: u64,
    what: MsgKind,
    action: MsgAction,
}

fn classify(msg: &Msg) -> Option<(MsgKind, u64)> {
    match msg {
        Msg::Assign { step, .. } => Some((MsgKind::Assign, *step)),
        Msg::Losses { step, .. } => Some((MsgKind::Losses, *step)),
        Msg::Commit { step, .. } => Some((MsgKind::Commit, *step)),
        _ => None,
    }
}

/// A transport wrapper that injects the scheduled message faults for one
/// worker. Sits on the supervisor side so both directions are covered:
/// outbound Assign/Commit and inbound Losses.
pub struct FaultyTransport<T: Transport> {
    inner: T,
    /// One-shot rules; a rule is removed when it fires.
    rules: Vec<MsgRule>,
    /// Outbound messages held back: (messages still to let pass, payload).
    delayed_send: VecDeque<(u32, Msg)>,
    /// Inbound messages ready to return ahead of the wire (duplicates and
    /// released delays).
    recv_queue: VecDeque<Msg>,
    /// Inbound messages held back: (receives still to let pass, payload).
    recv_delayed: Vec<(u32, Msg)>,
}

impl<T: Transport> FaultyTransport<T> {
    /// Pop the first rule matching this message, if any.
    fn take_rule(&mut self, msg: &Msg) -> Option<MsgRule> {
        let (kind, step) = classify(msg)?;
        let idx = self.rules.iter().position(|r| r.what == kind && r.step == step)?;
        Some(self.rules.remove(idx))
    }
}

impl<T: Transport> Transport for FaultyTransport<T> {
    fn send(&mut self, msg: &Msg) -> Result<()> {
        // Age the held-back sends: each real send lets one tick pass.
        for d in self.delayed_send.iter_mut() {
            d.0 = d.0.saturating_sub(1);
        }
        match self.take_rule(msg).map(|r| r.action) {
            Some(MsgAction::Drop) => {}
            Some(MsgAction::Dup) => {
                self.inner.send(msg)?;
                self.inner.send(msg)?;
            }
            Some(MsgAction::Delay(by)) => {
                self.delayed_send.push_back((by, msg.clone()));
            }
            None => self.inner.send(msg)?,
        }
        while let Some(&(left, _)) = self.delayed_send.front() {
            if left > 0 {
                break;
            }
            let (_, held) = self.delayed_send.pop_front().expect("front checked");
            self.inner.send(&held)?;
        }
        Ok(())
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Msg>> {
        if let Some(msg) = self.recv_queue.pop_front() {
            return Ok(Some(msg));
        }
        let got = self.inner.recv_timeout(timeout)?;
        // Age the held-back receives on every wire attempt.
        for d in self.recv_delayed.iter_mut() {
            d.0 = d.0.saturating_sub(1);
        }
        let mut i = 0;
        while i < self.recv_delayed.len() {
            if self.recv_delayed[i].0 == 0 {
                let (_, held) = self.recv_delayed.remove(i);
                self.recv_queue.push_back(held);
            } else {
                i += 1;
            }
        }
        let out = match got {
            Some(msg) => match self.take_rule(&msg).map(|r| r.action) {
                Some(MsgAction::Drop) => None,
                Some(MsgAction::Dup) => {
                    self.recv_queue.push_back(msg.clone());
                    Some(msg)
                }
                Some(MsgAction::Delay(by)) => {
                    self.recv_delayed.push((by, msg));
                    None
                }
                None => Some(msg),
            },
            None => None,
        };
        match out {
            Some(msg) => Ok(Some(msg)),
            None => Ok(self.recv_queue.pop_front()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dp::transport::chan_pair;

    fn losses(step: u64) -> Msg {
        Msg::Losses { worker: 0, step, shard_ids: vec![0], pairs: vec![(1.0, 2.0)] }
    }

    #[test]
    fn parse_roundtrip_covers_all_kinds() {
        let sched = FaultSchedule::parse(
            "kill:w1@10, stall:w2@6:50, join:w3@20, drop:assign:w0@5, dup:losses:w2@4, delay:losses:w1@7:2",
            3,
            32,
        )
        .unwrap();
        assert_eq!(sched.faults().len(), 6);
        assert_eq!(sched.worker_faults(1).kill_step, Some(10));
        assert_eq!(sched.worker_faults(2).stall, Some((6, 50)));
        assert_eq!(sched.joins(), vec![(3, 20)]);
        assert_eq!(sched.max_worker(), Some(3));
        assert!(FaultSchedule::parse("explode:w0@1", 2, 8).is_err());
        assert!(FaultSchedule::parse("drop:smoke:w0@1", 2, 8).is_err());
        assert!(FaultSchedule::parse("", 2, 8).unwrap().faults().is_empty());
    }

    #[test]
    fn seeded_schedule_is_deterministic_and_complete() {
        let a = FaultSchedule::seeded(7, 3, 24);
        let b = FaultSchedule::seeded(7, 3, 24);
        assert_eq!(a.faults(), b.faults());
        let has = |p: fn(&Fault) -> bool| a.faults().iter().any(p);
        assert!(has(|f| matches!(f, Fault::Kill { .. })));
        assert!(has(|f| matches!(f, Fault::Delay { .. })));
        assert!(has(|f| matches!(f, Fault::Dup { .. })));
        assert!(has(|f| matches!(f, Fault::Drop { .. })));
        assert!(has(|f| matches!(f, Fault::Join { .. })));
        let c = FaultSchedule::seeded(8, 3, 24);
        assert_ne!(a.faults(), c.faults());
    }

    #[test]
    fn drop_dup_delay_fire_once_on_recv() {
        let sched =
            FaultSchedule::parse("drop:losses:w0@1, dup:losses:w0@2, delay:losses:w0@3:1", 1, 8)
                .unwrap();
        let (sup, mut wrk) = chan_pair();
        let mut faulty = sched.wrap(0, sup);
        let t = Duration::from_millis(50);

        // Dropped exactly once: the retry gets through.
        wrk.send(&losses(1)).unwrap();
        assert_eq!(faulty.recv_timeout(t).unwrap(), None);
        wrk.send(&losses(1)).unwrap();
        assert_eq!(faulty.recv_timeout(t).unwrap(), Some(losses(1)));

        // Duplicated: same message twice.
        wrk.send(&losses(2)).unwrap();
        assert_eq!(faulty.recv_timeout(t).unwrap(), Some(losses(2)));
        assert_eq!(faulty.recv_timeout(t).unwrap(), Some(losses(2)));

        // Delayed by one receive: a miss, then delivery.
        wrk.send(&losses(3)).unwrap();
        assert_eq!(faulty.recv_timeout(t).unwrap(), None);
        assert_eq!(faulty.recv_timeout(t).unwrap(), Some(losses(3)));
    }

    #[test]
    fn delayed_send_is_released_after_later_traffic() {
        let sched = FaultSchedule::parse("delay:commit:w0@1:1", 1, 8).unwrap();
        let (sup, mut wrk) = chan_pair();
        let mut faulty = sched.wrap(0, sup);
        let t = Duration::from_millis(50);
        faulty.send(&Msg::Commit { step: 1, g: 0.5 }).unwrap();
        assert_eq!(wrk.recv_timeout(t).unwrap(), None);
        faulty.send(&Msg::Commit { step: 2, g: 0.25 }).unwrap();
        assert_eq!(wrk.recv_timeout(t).unwrap(), Some(Msg::Commit { step: 2, g: 0.25 }));
        assert_eq!(wrk.recv_timeout(t).unwrap(), Some(Msg::Commit { step: 1, g: 0.5 }));
    }
}
