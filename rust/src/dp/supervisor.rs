//! Supervising coordinator for the elastic DP backend.
//!
//! The supervisor owns the canonical trajectory. It keeps a *shadow
//! replica* — a [`SeedZoWorker`] that never evaluates losses but applies
//! every committed g — so at any instant it can mint a bit-exact snapshot
//! for a joiner, write a checkpoint, or verify a worker's state at
//! shutdown. Because the all-reduce folds shard losses in canonical
//! ascending shard order, the committed g for a step depends only on the
//! shard set, never on which worker evaluated which shard — which is why
//! deaths, stragglers, retries, and joins all leave the loss trajectory
//! bit-identical to a fault-free single-worker run.
//!
//! Liveness is heartbeat-based: a member that owes shards and stays silent
//! past the receive timeout gets a Ping and an assignment retry with linear
//! backoff; after `max_retries` misses (or a transport error, which means
//! the peer is gone) it is declared dead and its unanswered shards are
//! reassigned round-robin to the survivors. The run degrades gracefully to
//! K=1 and only fails when no member is left and no joiner is due.

// zo2-lint: allow-file(no-wall-clock): heartbeat/hello/ack deadlines and recovery
// timing are inherently wall-clock; none of them feed the committed trajectory.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::checkpoint;
use super::protocol::{Msg, WorkerSnapshot};
use super::transport::Transport;
use super::worker::{ElasticWorker, SeedZoWorker};
use crate::rng::GaussianRng;
use crate::telemetry::metrics;

/// Vocabulary bound for synthetic step batches (matches the toy corpus used
/// by the scheduler property tests).
pub const VOCAB: u64 = 50_000;

/// Static configuration for one supervised run.
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// Number of gradient shards per step (the unit of reassignment).
    pub shards: usize,
    /// Tokens per shard.
    pub shard_len: usize,
    /// Total steps the trajectory should reach (resume continues toward
    /// the same target).
    pub steps: u64,
    /// Model seed: replica init and the shared per-step perturbation.
    pub seed: u64,
    /// Data seed: per-step synthetic batches, derived per step so resume
    /// needs no corpus fast-forward.
    pub data_seed: u64,
    /// Replica parameter count.
    pub n_params: usize,
    /// How long to wait for one message before a heartbeat miss.
    pub recv_timeout: Duration,
    /// Heartbeat misses tolerated per member per step before it is dead.
    pub max_retries: u32,
    /// Checkpoint file (a persistent `DiskPool`); `None` disables both
    /// checkpointing and checkpoint-based joiner catch-up.
    pub checkpoint: Option<PathBuf>,
    /// Write a checkpoint every N committed steps (0 = only at the end).
    pub checkpoint_every: u64,
}

impl SupervisorConfig {
    pub fn quick(shards: usize, steps: u64) -> SupervisorConfig {
        SupervisorConfig {
            shards,
            shard_len: 8,
            steps,
            seed: 90,
            data_seed: 4242,
            n_params: 64,
            recv_timeout: Duration::from_millis(120),
            max_retries: 6,
            checkpoint: None,
            checkpoint_every: 0,
        }
    }
}

/// One committed step of the canonical trajectory.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepRecord {
    pub step: u64,
    pub loss_plus: f32,
    pub loss_minus: f32,
    pub g: f32,
}

impl StepRecord {
    pub fn loss(&self) -> f32 {
        0.5 * (self.loss_plus + self.loss_minus)
    }
}

/// Result of a supervised run.
pub struct RunOutcome {
    /// Committed steps, in order, starting at the resume point.
    pub records: Vec<StepRecord>,
    /// Final shadow state (bitwise-verified against every surviving
    /// worker at shutdown).
    pub final_snap: WorkerSnapshot,
    /// Workers declared dead during the run.
    pub deaths: usize,
    /// Workers admitted mid-run.
    pub joins: usize,
}

/// A deferred connection for a worker that joins mid-run.
pub struct Joiner {
    pub worker: u32,
    pub step: u64,
    /// Invoked when the join step is reached; spawns/accepts the new
    /// worker's connection.
    pub connect: Box<dyn FnOnce() -> Result<Box<dyn Transport>> + Send>,
}

struct Member {
    id: u32,
    transport: Box<dyn Transport>,
    /// Shards this member still owes for the current step.
    owed: Vec<u32>,
    misses: u32,
}

/// Generate the deterministic step batch: `shards * shard_len` tokens drawn
/// from the (data_seed, step) stream, shard-major.
pub fn step_tokens(data_seed: u64, step: u64, shards: usize, shard_len: usize) -> Vec<i32> {
    let mut rng = GaussianRng::new(data_seed, step);
    (0..shards * shard_len).map(|_| rng.next_below(VOCAB) as i32).collect()
}

fn snapshots_bitwise_eq(a: &WorkerSnapshot, b: &WorkerSnapshot) -> bool {
    a.step == b.step
        && a.params.len() == b.params.len()
        && a.params.iter().zip(&b.params).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// The supervising coordinator.
pub struct Supervisor {
    cfg: SupervisorConfig,
    shadow: SeedZoWorker,
    /// Committed gs since `g_base`, the self-repair and joiner-replay log.
    g_log: Vec<f32>,
    /// Step number of the first entry in `g_log`.
    g_base: u64,
    members: Vec<Member>,
    joiners: Vec<Joiner>,
    deaths: usize,
    joins: usize,
}

impl Supervisor {
    /// Create a supervisor. `resume_from` restores the shadow replica from
    /// a checkpoint snapshot; workers are synced to it on connect.
    pub fn new(cfg: SupervisorConfig, resume_from: Option<WorkerSnapshot>) -> Result<Supervisor> {
        ensure!(cfg.shards > 0, "need at least one shard");
        ensure!(cfg.shard_len > 0, "need a positive shard length");
        let mut shadow = SeedZoWorker::new(cfg.seed, cfg.n_params);
        let mut g_base = 0;
        if let Some(snap) = resume_from {
            ensure!(
                snap.params.len() == cfg.n_params,
                "checkpoint has {} params, config expects {}",
                snap.params.len(),
                cfg.n_params
            );
            g_base = snap.step;
            shadow.restore(&snap, &[])?;
        }
        Ok(Supervisor {
            cfg,
            shadow,
            g_log: Vec::new(),
            g_base,
            members: Vec::new(),
            joiners: Vec::new(),
            deaths: 0,
            joins: 0,
        })
    }

    /// Register a worker that is connected from the start.
    pub fn add_worker(&mut self, id: u32, transport: Box<dyn Transport>) {
        self.members.push(Member { id, transport, owed: Vec::new(), misses: 0 });
    }

    /// Register a worker that joins when `step` is reached.
    pub fn add_joiner(&mut self, joiner: Joiner) {
        self.joiners.push(joiner);
        self.joiners.sort_by_key(|j| j.step);
    }

    fn hello_timeout(&self) -> Duration {
        // Process spawn + connect can take much longer than one message.
        self.cfg.recv_timeout.max(Duration::from_millis(100)) * (4 * self.cfg.max_retries.max(1))
    }

    /// Wait for a member's Hello and, if the trajectory is already past
    /// step 0, push state so the replica matches the shadow bit-for-bit.
    fn induct(&mut self, idx: usize, replayed_from_checkpoint: bool) -> Result<()> {
        let deadline = Instant::now() + self.hello_timeout();
        loop {
            match self.members[idx].transport.recv_timeout(self.cfg.recv_timeout)? {
                Some(Msg::Hello { worker }) => {
                    ensure!(
                        worker == self.members[idx].id,
                        "worker announced id {worker}, expected {}",
                        self.members[idx].id
                    );
                    break;
                }
                Some(other) => bail!("expected Hello, got {other:?}"),
                None => {
                    metrics::counter_add("zo2_dp_heartbeat_misses", &[], 1);
                    ensure!(Instant::now() < deadline, "no Hello from worker before deadline");
                }
            }
        }
        if self.shadow.committed() > 0 {
            let (snap, replay) = self.catchup_state(replayed_from_checkpoint)?;
            self.members[idx].transport.send(&Msg::LoadState { snap, replay })?;
            let deadline = Instant::now() + self.hello_timeout();
            loop {
                match self.members[idx].transport.recv_timeout(self.cfg.recv_timeout)? {
                    Some(Msg::State { snap }) => {
                        ensure!(
                            snapshots_bitwise_eq(&snap, &self.shadow.snapshot()),
                            "worker {} state diverged from the canonical trajectory after \
                             catch-up (step {} vs {})",
                            self.members[idx].id,
                            snap.step,
                            self.shadow.committed()
                        );
                        break;
                    }
                    Some(other) => bail!("expected State after LoadState, got {other:?}"),
                    None => {
                        metrics::counter_add("zo2_dp_retries", &[("op", "state")], 1);
                        ensure!(Instant::now() < deadline, "no State ack before deadline");
                    }
                }
            }
        }
        Ok(())
    }

    /// The snapshot + g-replay pair used to catch a replica up to the
    /// shadow. When a checkpoint exists the snapshot comes from disk and
    /// the tail is replayed from the g-log — the seed-replay path — else
    /// the shadow state ships directly.
    fn catchup_state(&self, prefer_checkpoint: bool) -> Result<(WorkerSnapshot, Vec<f32>)> {
        if prefer_checkpoint {
            if let Some(path) = &self.cfg.checkpoint {
                if path.exists() {
                    let snap = checkpoint::load_worker_checkpoint(path)
                        .context("loading joiner checkpoint")?;
                    ensure!(
                        snap.step >= self.g_base,
                        "checkpoint at step {} predates the g-log base {}",
                        snap.step,
                        self.g_base
                    );
                    let from = (snap.step - self.g_base) as usize;
                    ensure!(from <= self.g_log.len(), "checkpoint is ahead of the trajectory");
                    return Ok((snap, self.g_log[from..].to_vec()));
                }
            }
        }
        Ok((self.shadow.snapshot(), Vec::new()))
    }

    /// Admit every joiner scheduled at or before `step`.
    fn admit_joiners(&mut self, step: u64) -> Result<()> {
        while self.joiners.first().is_some_and(|j| j.step <= step) {
            let j = self.joiners.remove(0);
            let t0 = Instant::now();
            let transport = (j.connect)().context("connecting joiner")?;
            self.members.push(Member { id: j.worker, transport, owed: Vec::new(), misses: 0 });
            let idx = self.members.len() - 1;
            self.induct(idx, true)?;
            self.joins += 1;
            metrics::observe("zo2_dp_recovery_wall_s", &[], t0.elapsed().as_secs_f64());
        }
        Ok(())
    }

    fn assign_msg(&self, step: u64, tokens: &[i32], shard_ids: Vec<u32>) -> Msg {
        Msg::Assign {
            step,
            shard_len: self.cfg.shard_len as u32,
            shard_ids,
            tokens: tokens.to_vec(),
            catchup_from: self.g_base,
            catchup: self.g_log.clone(),
        }
    }

    /// Remove the member at `idx`, reassigning its unanswered shards
    /// round-robin to the survivors.
    fn bury(&mut self, idx: usize, step: u64, tokens: &[i32]) -> Result<()> {
        let t0 = Instant::now();
        let dead = self.members.remove(idx);
        self.deaths += 1;
        ensure!(
            !self.members.is_empty(),
            "all workers dead at step {step} with no joiner due; cannot continue"
        );
        let orphaned = dead.owed.len();
        if orphaned > 0 {
            metrics::counter_add("zo2_dp_reassigned_shards", &[], orphaned as u64);
            let n = self.members.len();
            for (i, &sid) in dead.owed.iter().enumerate() {
                self.members[i % n].owed.push(sid);
            }
            for m in &mut self.members {
                m.owed.sort_unstable();
            }
            // Ship the supplemental assignments; a failure here is that
            // member's own death, handled on its next receive.
            let mut extras: Vec<(usize, Msg)> = Vec::new();
            for (i, m) in self.members.iter().enumerate() {
                let extra: Vec<u32> =
                    m.owed.iter().copied().filter(|s| dead.owed.contains(s)).collect();
                if !extra.is_empty() {
                    extras.push((i, self.assign_msg(step, tokens, extra)));
                }
            }
            for (i, msg) in extras {
                let _ = self.members[i].transport.send(&msg);
            }
        }
        metrics::observe("zo2_dp_recovery_wall_s", &[], t0.elapsed().as_secs_f64());
        Ok(())
    }

    /// Run one committed step: assign, collect with retries and
    /// reassignment, all-reduce in canonical shard order, commit.
    fn run_step(&mut self, step: u64) -> Result<StepRecord> {
        self.admit_joiners(step)?;
        ensure!(!self.members.is_empty(), "no live workers at step {step}");
        let tokens = step_tokens(self.cfg.data_seed, step, self.cfg.shards, self.cfg.shard_len);

        // Round-robin shard assignment over members ordered by id.
        self.members.sort_by_key(|m| m.id);
        let k = self.members.len();
        for (i, m) in self.members.iter_mut().enumerate() {
            m.owed = (i..self.cfg.shards).step_by(k).map(|s| s as u32).collect();
            m.misses = 0;
        }
        let mut i = 0;
        while i < self.members.len() {
            let msg = self.assign_msg(step, &tokens, self.members[i].owed.clone());
            if self.members[i].transport.send(&msg).is_err() {
                // Peer already gone; bury reassigns its whole shard list.
                self.bury(i, step, &tokens)?;
            } else {
                i += 1;
            }
        }

        let mut per_shard: Vec<Option<(f32, f32)>> = vec![None; self.cfg.shards];
        let mut guard = 0u32;
        while per_shard.iter().any(|p| p.is_none()) {
            guard += 1;
            ensure!(guard < 10_000, "step {step} failed to converge after {guard} receive rounds");
            let mut died: Option<usize> = None;
            for i in 0..self.members.len() {
                if self.members[i].owed.iter().all(|&s| per_shard[s as usize].is_some()) {
                    continue; // nothing owed; don't block on idle members
                }
                match self.members[i].transport.recv_timeout(self.cfg.recv_timeout) {
                    Ok(Some(Msg::Losses { step: s, shard_ids, pairs })) if s == step => {
                        for (sid, pair) in shard_ids.iter().zip(pairs) {
                            ensure!(
                                (*sid as usize) < self.cfg.shards,
                                "losses reference unknown shard {sid}"
                            );
                            per_shard[*sid as usize] = Some(pair);
                        }
                        self.members[i].misses = 0;
                    }
                    Ok(Some(_)) => {} // stale losses, pongs: ignore
                    Ok(None) => {
                        let m = &mut self.members[i];
                        m.misses += 1;
                        metrics::counter_add("zo2_dp_heartbeat_misses", &[], 1);
                        if m.misses > self.cfg.max_retries {
                            died = Some(i);
                        } else {
                            // Probe liveness and retry the outstanding
                            // shards with linear backoff.
                            let owed: Vec<u32> = m
                                .owed
                                .iter()
                                .copied()
                                .filter(|&s| per_shard[s as usize].is_none())
                                .collect();
                            let backoff = self.cfg.recv_timeout / 4 * self.members[i].misses;
                            std::thread::sleep(backoff.min(Duration::from_millis(200)));
                            metrics::counter_add("zo2_dp_retries", &[("op", "assign")], 1);
                            let ping = Msg::Ping { nonce: (step << 8) | u64::from(guard) };
                            let assign = self.assign_msg(step, &tokens, owed);
                            let m = &mut self.members[i];
                            if m.transport.send(&ping).is_err()
                                || m.transport.send(&assign).is_err()
                            {
                                died = Some(i);
                            }
                        }
                    }
                    Err(_) => died = Some(i),
                }
                if died.is_some() {
                    break;
                }
            }
            if let Some(i) = died {
                // Keep only genuinely outstanding shards on the corpse so
                // bury() reassigns exactly what is missing.
                self.members[i].owed.retain(|&s| per_shard[s as usize].is_none());
                self.bury(i, step, &tokens)?;
            }
        }

        // Canonical all-reduce: ascending shard order, independent of which
        // worker produced each pair.
        let eps = self.shadow.eps();
        let s = self.cfg.shards;
        let mut lp_sum = 0.0f32;
        let mut lm_sum = 0.0f32;
        let mut g_sum = 0.0f32;
        for pair in per_shard.iter().flatten() {
            let (lp, lm) = *pair;
            lp_sum += lp;
            lm_sum += lm;
            g_sum += (lp - lm) / (2.0 * eps);
        }
        let g = g_sum / s as f32;
        self.shadow.commit(step, g)?;
        self.g_log.push(g);

        // Broadcast the commit; a dead peer here is only fatal if it was
        // the last one and more steps remain (checked next step).
        let mut i = 0;
        while i < self.members.len() {
            if self.members[i].transport.send(&Msg::Commit { step, g }).is_err() {
                self.members.remove(i);
                self.deaths += 1;
            } else {
                i += 1;
            }
        }

        if let Some(path) = &self.cfg.checkpoint {
            let every = self.cfg.checkpoint_every;
            if every > 0 && (step + 1) % every == 0 {
                checkpoint::save_worker_checkpoint(path, &self.shadow.snapshot())
                    .context("writing periodic checkpoint")?;
            }
        }

        Ok(StepRecord { step, loss_plus: lp_sum / s as f32, loss_minus: lm_sum / s as f32, g })
    }

    /// Run the full trajectory from the resume point to `cfg.steps`,
    /// verify every surviving worker bitwise, and shut them down.
    pub fn run(mut self) -> Result<RunOutcome> {
        for idx in 0..self.members.len() {
            self.induct(idx, false)?;
        }
        let mut records = Vec::new();
        let start = self.shadow.committed();
        for step in start..self.cfg.steps {
            records.push(self.run_step(step)?);
        }
        if let Some(path) = &self.cfg.checkpoint {
            checkpoint::save_worker_checkpoint(path, &self.shadow.snapshot())
                .context("writing final checkpoint")?;
        }
        let final_snap = self.shadow.snapshot();
        for m in &mut self.members {
            m.transport.send(&Msg::FetchState)?;
            let deadline = Instant::now() + Duration::from_secs(10);
            loop {
                match m.transport.recv_timeout(self.cfg.recv_timeout)? {
                    Some(Msg::State { snap }) => {
                        ensure!(
                            snapshots_bitwise_eq(&snap, &final_snap),
                            "worker {} final state diverged from the canonical trajectory",
                            m.id
                        );
                        break;
                    }
                    Some(_) => {} // late commits/pongs in flight
                    None => ensure!(Instant::now() < deadline, "no final State from worker"),
                }
            }
            m.transport.send(&Msg::Shutdown)?;
        }
        Ok(RunOutcome { records, final_snap, deaths: self.deaths, joins: self.joins })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_tokens_is_deterministic_per_step() {
        let a = step_tokens(4242, 3, 4, 8);
        let b = step_tokens(4242, 3, 4, 8);
        assert_eq!(a, b);
        assert_eq!(a.len(), 32);
        assert_ne!(a, step_tokens(4242, 4, 4, 8));
        assert!(a.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
    }

    #[test]
    fn snapshots_compare_bitwise() {
        let a = WorkerSnapshot { step: 1, params: vec![0.0, 1.5] };
        let mut b = a.clone();
        assert!(snapshots_bitwise_eq(&a, &b));
        b.params[0] = -0.0; // same value, different bits
        assert!(!snapshots_bitwise_eq(&a, &b));
    }
}
