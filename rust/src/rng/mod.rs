//! Counter-based Gaussian streams + the RNG state manager (paper §5.1).
//!
//! The whole correctness story of ZO2 hangs on one invariant: the Gaussian
//! direction `z` used to *perturb* a module at step `j` must be replayed
//! **identically** when that module is *updated* (which ZO2 defers to step
//! `j+1`, §5.4).  MeZO gets this by resetting a global seed; ZO2 cannot,
//! because the dual-forward is disaggregated per block and interleaved with
//! transfers.  The paper's fix — and ours — is to capture the RNG state
//! before each module's perturbation and restore it at update time
//! (Algorithm 2's `rs` / `lrs` / `rsb`).
//!
//! We use a *counter-based* generator (SplitMix64 mixing of
//! `(seed, stream, counter)`), so a state is three u64s: trivially
//! save/restorable, O(1) memory, and random-access.  `z` itself is never
//! stored — regenerating it from the saved state is the paper's §4.1
//! point (4): the true gradient `g·z` never materialises.

use std::collections::VecDeque;

pub mod fastmath;

/// A snapshot of a generator — the paper's `rng_state`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RngState {
    pub seed: u64,
    pub stream: u64,
    pub counter: u64,
}

/// Deterministic counter-based Gaussian generator.
///
/// Each `counter` tick yields one u64 which is split into two uniforms and
/// Box–Muller-transformed into two f32 Gaussians; array fills consume
/// `ceil(n/2)` ticks.  Identical `(seed, stream, counter)` ⇒ identical
/// output, on any thread, in any engine.
#[derive(Debug, Clone)]
pub struct GaussianRng {
    state: RngState,
}

#[inline]
pub(crate) fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

impl GaussianRng {
    pub fn new(seed: u64, stream: u64) -> Self {
        Self { state: RngState { seed, stream, counter: 0 } }
    }

    pub fn from_state(state: RngState) -> Self {
        Self { state }
    }

    /// The paper's `GetRngState`.
    pub fn state(&self) -> RngState {
        self.state
    }

    /// The paper's `SetRngState`.
    pub fn set_state(&mut self, state: RngState) {
        self.state = state;
    }

    #[inline]
    fn next_u64(&mut self) -> u64 {
        let k = splitmix64(self.state.seed ^ splitmix64(self.state.stream));
        let v = splitmix64(k ^ self.state.counter.wrapping_mul(0xD6E8_FEB8_6659_FD93));
        self.state.counter += 1;
        v
    }

    /// One Box–Muller pair per counter tick (see [`fastmath::box_muller`]
    /// for the shared scalar definition the SIMD fill mirrors).
    #[inline]
    fn next_pair(&mut self) -> (f32, f32) {
        fastmath::box_muller(self.next_u64())
    }

    /// Fill `out` with standard Gaussians (the module's direction `z`).
    ///
    /// Dispatches the leading multiple-of-8 elements to the SIMD bulk fill
    /// when `--host-simd` resolves to a vector path — bit-identical to the
    /// scalar pair loop, which finishes the tail either way.
    pub fn fill_gaussian(&mut self, out: &mut [f32]) {
        let mut i = crate::simd::fill_gaussian_bulk(self.state, out);
        self.state.counter = self.state.counter.wrapping_add((i / 2) as u64);
        while i + 1 < out.len() {
            let (a, b) = self.next_pair();
            out[i] = a;
            out[i + 1] = b;
            i += 2;
        }
        if i < out.len() {
            out[i] = self.next_pair().0; // odd tail: second value discarded
        }
    }

    pub fn next_gaussian(&mut self) -> f32 {
        self.next_pair().0
    }

    /// Uniform in [0, 1).
    pub fn next_uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn next_below(&mut self, n: u64) -> u64 {
        // Modulo bias is irrelevant at our n << 2^64.
        self.next_u64() % n.max(1)
    }
}

/// Per-iteration record of module perturbation states — one entry of the
/// paper's random state buffer `rsb`.
#[derive(Debug, Clone)]
pub struct IterStates {
    pub iter: u64,
    /// State *before* module `m`'s `z` was drawn, indexed by module position
    /// (0 = embedding, 1..=N = blocks, N+1 = LM head).
    pub per_module: Vec<RngState>,
}

/// The paper's RNG state manager (Algorithm 2 lines 4–9, 18–30).
///
/// `begin_iter` starts the iteration stream and records per-module states as
/// the engine draws each module's `z`; `pop_last_states` exposes `lrs` — the
/// previous iteration's states — so deferred updates replay the exact
/// perturbation directions.
#[derive(Debug)]
pub struct RngStateManager {
    base_seed: u64,
    rsb: VecDeque<IterStates>,
}

impl RngStateManager {
    pub fn new(base_seed: u64) -> Self {
        Self { base_seed, rsb: VecDeque::new() }
    }

    pub fn base_seed(&self) -> u64 {
        self.base_seed
    }

    /// Start iteration `j`: returns its Gaussian stream (counter 0) and
    /// pushes an empty state record onto `rsb`.
    pub fn begin_iter(&mut self, iter: u64) -> GaussianRng {
        self.rsb.push_back(IterStates { iter, per_module: Vec::new() });
        GaussianRng::new(self.base_seed, iter)
    }

    /// Record the state *before* drawing module `m`'s z (must be called in
    /// module order).
    pub fn record_module_state(&mut self, state: RngState) {
        self.rsb.back_mut().expect("begin_iter first").per_module.push(state);
    }

    /// The paper's `lrs = PopLeft(rsb)`: the *previous* iteration's record.
    /// Returns None on the first iteration (no deferred update yet).
    pub fn pop_last_states(&mut self) -> Option<IterStates> {
        if self.rsb.len() >= 2 {
            self.rsb.pop_front()
        } else {
            None
        }
    }

    /// Peek the record for the current iteration (testing / introspection).
    pub fn current(&self) -> Option<&IterStates> {
        self.rsb.back()
    }

    /// Drop the newest record.  Used by the DP sim-shard engine mode when a
    /// step is *replayed* on another microbatch shard: the replay's
    /// `begin_iter` pushed a duplicate of the step's record, which would
    /// otherwise accumulate one stale entry per extra shard.
    pub fn discard_current(&mut self) -> Option<IterStates> {
        self.rsb.pop_back()
    }

    pub fn buffered(&self) -> usize {
        self.rsb.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = GaussianRng::new(42, 7);
        let mut b = GaussianRng::new(42, 7);
        let mut va = vec![0.0; 1001];
        let mut vb = vec![0.0; 1001];
        a.fill_gaussian(&mut va);
        b.fill_gaussian(&mut vb);
        assert_eq!(va, vb);
    }

    #[test]
    fn state_restore_replays_exactly() {
        let mut r = GaussianRng::new(1, 2);
        let mut skip = vec![0.0; 37];
        r.fill_gaussian(&mut skip);
        let st = r.state();
        let mut z1 = vec![0.0; 501];
        r.fill_gaussian(&mut z1);
        r.set_state(st);
        let mut z2 = vec![0.0; 501];
        r.fill_gaussian(&mut z2);
        assert_eq!(z1, z2, "restored state must replay the same z");
    }

    #[test]
    fn streams_are_independent() {
        let mut a = GaussianRng::new(5, 0);
        let mut b = GaussianRng::new(5, 1);
        let (x, _) = a.next_pair();
        let (y, _) = b.next_pair();
        assert_ne!(x, y);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = GaussianRng::new(123, 0);
        let mut v = vec![0.0f32; 200_000];
        r.fill_gaussian(&mut v);
        let n = v.len() as f64;
        let mean = v.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var = v.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
        // Tail sanity: |z| > 7 should be absent at this sample size,
        // |z| > 3 present.
        assert!(v.iter().all(|x| x.abs() < 7.0));
        assert!(v.iter().any(|x| x.abs() > 3.0));
    }

    #[test]
    fn manager_rsb_protocol() {
        let mut m = RngStateManager::new(9);
        let mut r0 = m.begin_iter(0);
        for _ in 0..3 {
            m.record_module_state(r0.state());
            let mut z = vec![0.0; 10];
            r0.fill_gaussian(&mut z);
        }
        assert!(m.pop_last_states().is_none(), "no lrs on first iter");

        let mut r1 = m.begin_iter(1);
        m.record_module_state(r1.state());
        let lrs = m.pop_last_states().expect("lrs available from iter 0");
        assert_eq!(lrs.iter, 0);
        assert_eq!(lrs.per_module.len(), 3);

        // The recorded state for module 1 equals a fresh generator's state
        // after it consumed module 0's draw.
        let mut fresh = GaussianRng::new(9, 0);
        let mut z0 = vec![0.0; 10];
        fresh.fill_gaussian(&mut z0);
        assert_eq!(fresh.state(), lrs.per_module[1]);
    }

    #[test]
    fn uniform_below_bounds() {
        let mut r = GaussianRng::new(3, 3);
        for _ in 0..1000 {
            assert!(r.next_below(17) < 17);
            let u = r.next_uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }
}
