//! Crate-local deterministic `ln` / `sin·cos` for the Box–Muller transform.
//!
//! The Gaussian fill is the hot inner loop of every host-side kernel (one
//! `z` draw per parameter element per step), and on libm it is dominated by
//! the `ln`/`sin`/`cos` calls.  Two problems with libm here:
//!
//! 1. **Vectorisation**: a SIMD Gaussian fill must be *bit-identical* to
//!    the scalar one (the chunk-replay determinism contract), which is
//!    impossible against an opaque libm — its polynomial and table choices
//!    are not mirrorable lane-for-lane.
//! 2. **Portability**: libm results differ across platforms/versions, so
//!    trajectories were only reproducible on one build.  These
//!    straight-line polynomials make the Gaussian stream a pure function of
//!    `(seed, stream, counter)` on every platform.
//!
//! Every function here is a fixed sequence of IEEE-754 f64 operations
//! (add/sub/mul/div/sqrt/floor — each correctly rounded and therefore
//! deterministic) with coefficients shared as named constants.  The AVX2
//! fill in [`crate::simd`] mirrors each operation one vector instruction
//! per scalar op, in the same order, with the same constants — which is the
//! whole bit-identity argument; there is nothing to "verify" beyond op
//! order, and tests assert it exhaustively anyway.
//!
//! Accuracy: |error| < ~1e-9 absolute against libm over the used domains —
//! three orders of magnitude below f32 resolution of the emitted Gaussians,
//! so the statistical properties (moments, tails) are unaffected.  The
//! substitution *does* change the concrete trajectory once relative to the
//! old libm-based stream; all determinism tests compare run-vs-run, never
//! stored values, so this is a one-time, documented re-baseline.

/// Exactly 2⁻³², as a constant so scalar and SIMD scale uniforms with the
/// same (exact, power-of-two) multiply.
pub const INV_2P32: f64 = 1.0 / 4_294_967_296.0;

/// 2⁵² — the integer↔double "magic number" pivot used by the SIMD u32→f64
/// conversion; kept here so the scalar path documents the same constant.
pub const EXP52: f64 = 4_503_599_627_370_496.0;

// ln(m) on m ∈ [√2/2, √2] via the atanh series:
// ln(m) = s·(2 + 2s²/3 + 2s⁴/5 + …) with s = (m−1)/(m+1), |s| ≤ 3−2√2.
pub const LN_P0: f64 = 2.0;
pub const LN_P1: f64 = 2.0 / 3.0;
pub const LN_P2: f64 = 2.0 / 5.0;
pub const LN_P3: f64 = 2.0 / 7.0;
pub const LN_P4: f64 = 2.0 / 9.0;
pub const LN_P5: f64 = 2.0 / 11.0;
pub const LN_P6: f64 = 2.0 / 13.0;

// sin(a) = a·(1 + c₁a² + …) and cos(a) = 1 + d₁a² + … on a ∈ [0, π/2)
// (Taylor; the quadrant reduction keeps the argument small).
pub const SIN_C0: f64 = 1.0;
pub const SIN_C1: f64 = -1.0 / 6.0;
pub const SIN_C2: f64 = 1.0 / 120.0;
pub const SIN_C3: f64 = -1.0 / 5_040.0;
pub const SIN_C4: f64 = 1.0 / 362_880.0;
pub const SIN_C5: f64 = -1.0 / 39_916_800.0;
pub const SIN_C6: f64 = 1.0 / 6_227_020_800.0;

pub const COS_C0: f64 = 1.0;
pub const COS_C1: f64 = -1.0 / 2.0;
pub const COS_C2: f64 = 1.0 / 24.0;
pub const COS_C3: f64 = -1.0 / 720.0;
pub const COS_C4: f64 = 1.0 / 40_320.0;
pub const COS_C5: f64 = -1.0 / 3_628_800.0;
pub const COS_C6: f64 = 1.0 / 479_001_600.0;
pub const COS_C7: f64 = -1.0 / 87_178_291_200.0;

/// Natural log of a positive, finite, *normal* f64 (the uniforms here are
/// ≥ 2⁻³², far above the subnormal range).  Exponent/mantissa split, fold
/// the mantissa into [√2/2, √2], then the atanh series.
#[inline]
pub fn ln(x: f64) -> f64 {
    debug_assert!(x >= f64::MIN_POSITIVE && x.is_finite());
    let bits = x.to_bits();
    // Sign bit is clear (x > 0), so the raw exponent is just bits >> 52.
    let e_raw = (bits >> 52) as i64;
    let mut e = (e_raw - 1023) as f64; // integer-valued: exact
    let mut m = f64::from_bits((bits & 0x000F_FFFF_FFFF_FFFF) | 0x3FF0_0000_0000_0000);
    if m > std::f64::consts::SQRT_2 {
        m *= 0.5; // power-of-two scale: exact
        e += 1.0; // small-integer add: exact
    }
    let s = (m - 1.0) / (m + 1.0);
    let s2 = s * s;
    let mut p = LN_P6;
    p = p * s2 + LN_P5;
    p = p * s2 + LN_P4;
    p = p * s2 + LN_P3;
    p = p * s2 + LN_P2;
    p = p * s2 + LN_P1;
    p = p * s2 + LN_P0;
    e * std::f64::consts::LN_2 + s * p
}

/// `(sin 2πu, cos 2πu)` for `u ∈ [0, 1)`.  `u·4` is exact (u is a multiple
/// of 2⁻³² here, and ×4 is a power-of-two scale), the quadrant subtraction
/// `t − ⌊t⌋` is exact by Sterbenz, so both paths reduce to the *same*
/// polynomial argument in [0, π/2); negation is a sign-bit flip (exact).
#[inline]
pub fn sincos_2pi(u: f64) -> (f64, f64) {
    debug_assert!((0.0..1.0).contains(&u));
    let t = u * 4.0;
    let q = t.floor(); // 0, 1, 2 or 3
    let a = (t - q) * std::f64::consts::FRAC_PI_2;
    let a2 = a * a;
    let mut sp = SIN_C6;
    sp = sp * a2 + SIN_C5;
    sp = sp * a2 + SIN_C4;
    sp = sp * a2 + SIN_C3;
    sp = sp * a2 + SIN_C2;
    sp = sp * a2 + SIN_C1;
    sp = sp * a2 + SIN_C0;
    let sp = a * sp;
    let mut cp = COS_C7;
    cp = cp * a2 + COS_C6;
    cp = cp * a2 + COS_C5;
    cp = cp * a2 + COS_C4;
    cp = cp * a2 + COS_C3;
    cp = cp * a2 + COS_C2;
    cp = cp * a2 + COS_C1;
    cp = cp * a2 + COS_C0;
    match q as u32 {
        0 => (sp, cp),
        1 => (cp, -sp),
        2 => (-sp, -cp),
        _ => (-cp, sp),
    }
}

/// One Box–Muller pair from one counter tick's u64 — the shared scalar
/// definition of the Gaussian stream (the AVX2 fill mirrors it op-for-op).
/// High 32 bits → radius uniform in (0, 1] (avoids ln 0; u1 = 1 gives the
/// consistent `sqrt(-0.0) = -0.0` radius), low 32 → angle in [0, 1).
#[inline]
pub fn box_muller(v: u64) -> (f32, f32) {
    let u1 = ((v >> 32) as f64 + 1.0) * INV_2P32;
    let u2 = (v & 0xFFFF_FFFF) as f64 * INV_2P32;
    let r = (-2.0 * ln(u1)).sqrt();
    let (s, c) = sincos_2pi(u2);
    ((r * c) as f32, (r * s) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_tracks_libm_over_the_uniform_domain() {
        // The u1 domain is [2^-32, 1]; sweep it plus dyadic edges.
        let mut worst = 0.0f64;
        for i in 1..=200_000u64 {
            let x = i as f64 / 200_000.0;
            let err = (ln(x) - x.ln()).abs();
            worst = worst.max(err);
        }
        for e in 1..=32 {
            let x = 2f64.powi(-e);
            worst = worst.max((ln(x) - x.ln()).abs());
            let x = 1.5 * 2f64.powi(-e);
            worst = worst.max((ln(x) - x.ln()).abs());
        }
        assert!(worst < 1e-9, "worst ln error {worst:e}");
        assert_eq!(ln(1.0).to_bits(), 0.0f64.to_bits(), "ln(1) must be +0");
    }

    #[test]
    fn sincos_tracks_libm_over_the_angle_domain() {
        let mut worst = 0.0f64;
        for i in 0..200_000u64 {
            let u = i as f64 / 200_000.0;
            let (s, c) = sincos_2pi(u);
            let th = 2.0 * std::f64::consts::PI * u;
            worst = worst.max((s - th.sin()).abs()).max((c - th.cos()).abs());
        }
        assert!(worst < 1e-8, "worst sincos error {worst:e}");
        let (s0, c0) = sincos_2pi(0.0);
        assert_eq!(s0.to_bits(), 0.0f64.to_bits());
        assert_eq!(c0.to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn box_muller_radius_is_bounded() {
        // Max radius = sqrt(-2 ln 2^-32) ≈ 6.66: the |z| < 7 tail contract
        // of the Gaussian stream holds structurally.
        let (a, b) = box_muller(0); // u1 minimal → max radius at angle 0
        assert!(a.abs() < 7.0 && b.abs() < 7.0, "{a} {b}");
        let max_r = (-2.0 * ln(INV_2P32)).sqrt();
        assert!(max_r < 7.0, "max radius {max_r}");
    }
}
