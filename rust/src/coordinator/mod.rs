//! The trainer: data → engine → metrics, with the user-facing API shape of
//! the paper's Fig. 6b (configure, loop `train_step`, `eval`, final
//! `flush_updates`).

use anyhow::Result;

use crate::data::SyntheticCorpus;
use crate::precision::Codec;
use crate::runtime::Runtime;
use crate::sched::SpillPlacement;
use crate::telemetry::Series;
use crate::zo::{
    DpSimShard, MezoEngine, RunMode, StepStats, Tiering, UpdateSite, Zo2Engine, Zo2Options,
    ZoConfig,
};

/// Which engine backs the trainer.
pub enum Engine {
    Mezo(MezoEngine),
    Zo2(Zo2Engine),
    /// Seed-synchronous data-parallel ZO2: K in-process worker replicas
    /// over K batch shards per step (`TrainConfig::dp_workers > 1`).
    DpSim(DpSimShard<Zo2Engine>),
}

impl Engine {
    /// Token ids consumed per `train_step` call, in engine batches: the DP
    /// sim-shard engine eats one batch per shard.
    pub fn batches_per_step(&self) -> usize {
        match self {
            Engine::DpSim(e) => e.n_shards(),
            _ => 1,
        }
    }

    pub fn train_step(&mut self, ids: &[i32]) -> Result<StepStats> {
        match self {
            Engine::Mezo(e) => e.train_step(ids),
            Engine::Zo2(e) => e.train_step(ids),
            Engine::DpSim(e) => e.train_step(ids),
        }
    }

    pub fn eval(&mut self, ids: &[i32]) -> Result<(f32, Vec<f32>)> {
        match self {
            Engine::Mezo(e) => e.eval(ids),
            Engine::Zo2(e) => e.eval(ids),
            // Replicas are identical after each all-reduce: worker 0 evals.
            Engine::DpSim(e) => e.workers_mut()[0].eval(ids),
        }
    }

    pub fn flush(&mut self) -> Result<()> {
        match self {
            Engine::Mezo(_) => Ok(()), // MeZO updates in-step
            Engine::Zo2(e) => e.flush_updates(),
            Engine::DpSim(e) => {
                for w in e.workers_mut() {
                    w.flush_updates()?;
                }
                Ok(())
            }
        }
    }

    pub fn runtime(&self) -> &Runtime {
        match self {
            Engine::Mezo(e) => e.runtime(),
            Engine::Zo2(e) => e.runtime(),
            Engine::DpSim(e) => e.workers()[0].runtime(),
        }
    }

    /// Measured timeline of the most recent step, if the engine records
    /// one (DP sim-shard: worker 0's — replicas run the same schedule).
    pub fn last_timeline(&self) -> Option<&crate::telemetry::Timeline> {
        match self {
            Engine::Mezo(_) => None,
            Engine::Zo2(e) => Some(&e.last_timeline),
            Engine::DpSim(e) => Some(&e.workers()[0].last_timeline),
        }
    }
}

/// Training configuration for the CLI / examples.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub config_name: String,
    pub steps: usize,
    pub zo: ZoConfig,
    pub engine: EngineKind,
    pub wire: Codec,
    pub run_mode: RunMode,
    pub log_every: usize,
    /// Two-tier (all blocks in DDR) or three-tier (spill below the DRAM
    /// budget to the NVMe pool).
    pub tiering: Tiering,
    /// DRAM budget in bytes for block master copies (three-tier only;
    /// `None` = keep everything resident even in three-tier mode).
    pub dram_budget_bytes: Option<u64>,
    /// Staging-window slots for spilled buckets.
    pub dram_slots: usize,
    /// Which blocks spill under three-tier (trailing vs interleaved).
    pub spill_placement: SpillPlacement,
    /// Where the deferred block update runs (device §5.4, or fused on the
    /// host compute pool).
    pub update_site: UpdateSite,
    /// Host compute pool threads (0 = machine parallelism).
    pub host_threads: usize,
    /// Pin host-pool workers to cores (NUMA round-robin) with a static
    /// chunk→worker map (`--host-pin`).  Never changes numerics.
    pub host_pin: bool,
    /// Seed-synchronous DP sim-shard workers (1 = plain single-engine run).
    pub dp_workers: usize,
    /// DP microbatch shards per step (0 = one per worker).  The shard count
    /// is part of the trajectory's identity; the worker count is pure
    /// parallelisation — holding `dp_shards` fixed while varying
    /// `dp_workers` reproduces the same trajectory bit-for-bit.
    pub dp_shards: usize,
    /// Write the measured run timeline as Chrome trace-event JSON
    /// (`--trace-out`).  `None` = don't collect per-step timelines.
    pub trace_out: Option<String>,
    /// Enable the process-wide metrics sink and write its snapshot here
    /// (`--metrics-out`).  `None` = sink stays disabled: instrumented
    /// paths take one branch and allocate nothing.
    pub metrics_out: Option<String>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Mezo,
    Zo2,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            config_name: "tiny".into(),
            steps: 20,
            zo: ZoConfig::default(),
            engine: EngineKind::Zo2,
            wire: Codec::F32,
            run_mode: RunMode::Overlapped,
            log_every: 10,
            tiering: Tiering::TwoTier,
            dram_budget_bytes: None,
            dram_slots: 4,
            spill_placement: SpillPlacement::Trailing,
            update_site: UpdateSite::Device,
            host_threads: 0,
            host_pin: false,
            dp_workers: 1,
            dp_shards: 0,
            trace_out: None,
            metrics_out: None,
        }
    }
}

/// Outcome of a training run.
pub struct TrainReport {
    pub losses: Series,
    pub tokens_per_s: f64,
    pub final_eval_loss: f32,
    pub device_peak_bytes: u64,
    pub transfer_bytes: u64,
    /// NVMe traffic of the disk tier (0 in two-tier mode).
    pub disk_bytes: u64,
    /// Blocks whose master copy lived on the disk tier.
    pub spilled_blocks: usize,
}

/// [`Zo2Options`] realising `cfg` for one engine (or DP worker replica).
fn zo2_options(cfg: &TrainConfig, rt: &Runtime) -> Zo2Options {
    // Convert the DRAM byte budget into a resident-block count via the
    // same placement rule the analytic planner uses.
    let dram_resident_blocks = match (cfg.tiering, cfg.dram_budget_bytes) {
        (Tiering::ThreeTier, Some(budget)) => {
            let n = rt.manifest().config.n_layers;
            let wire = (rt.manifest().block.size * cfg.wire.bytes_per_el()) as u64;
            let resident =
                crate::costmodel::resident_blocks_for_budget(n, wire, budget, cfg.dram_slots);
            if resident >= n {
                usize::MAX
            } else {
                resident
            }
        }
        _ => usize::MAX,
    };
    Zo2Options {
        wire: cfg.wire,
        run_mode: cfg.run_mode,
        tiering: cfg.tiering,
        dram_slots: cfg.dram_slots,
        dram_resident_blocks,
        spill_placement: cfg.spill_placement,
        update_site: cfg.update_site,
        host_threads: cfg.host_threads,
        host_pin: cfg.host_pin,
        ..Zo2Options::default()
    }
}

/// Build an engine for `cfg`, loading the AOT artifacts.
pub fn build_engine(cfg: &TrainConfig) -> Result<Engine> {
    let rt = Runtime::load_config(&cfg.config_name)?;
    rt.manifest().validate()?;
    rt.compile_all()?;
    Ok(match cfg.engine {
        EngineKind::Mezo => {
            let e = MezoEngine::with_host_pool_opts(rt, cfg.zo, cfg.host_threads, cfg.host_pin)?;
            Engine::Mezo(e)
        }
        EngineKind::Zo2 if cfg.dp_workers > 1 || cfg.dp_shards > 1 => {
            // K seed-synchronous worker replicas over S microbatch shards
            // (one engine batch each; S defaults to K).  The first replica
            // reuses the runtime already loaded; the rest load their own.
            let shards = if cfg.dp_shards == 0 { cfg.dp_workers } else { cfg.dp_shards };
            let opts = zo2_options(cfg, &rt);
            let mut workers = vec![Zo2Engine::new(rt, cfg.zo, opts)?];
            for _ in 1..cfg.dp_workers {
                let rt = Runtime::load_config(&cfg.config_name)?;
                rt.compile_all()?;
                workers.push(Zo2Engine::new(rt, cfg.zo, opts)?);
            }
            Engine::DpSim(DpSimShard::new(workers, shards)?)
        }
        EngineKind::Zo2 => {
            let opts = zo2_options(cfg, &rt);
            Engine::Zo2(Zo2Engine::new(rt, cfg.zo, opts)?)
        }
    })
}

/// Train on the synthetic corpus and report loss curve + throughput.
pub fn train(cfg: &TrainConfig, verbose: bool) -> Result<TrainReport> {
    // Observability is pay-for-what-you-use: the process-wide sink is
    // switched to exactly what this run asked for (and cleared), so a run
    // without `--metrics-out` records nothing anywhere.
    crate::telemetry::metrics::set_enabled(cfg.metrics_out.is_some());
    if cfg.metrics_out.is_some() {
        crate::telemetry::metrics::global().reset();
    }
    let mut engine = build_engine(cfg)?;
    let (b, t) = {
        let m = engine.runtime().manifest();
        (m.config.batch, m.config.seq_len)
    };
    let vocab = engine.runtime().manifest().config.vocab;
    let mut corpus = SyntheticCorpus::new(vocab, cfg.zo.seed ^ 0xDA7A);

    let mut losses = Series::new("loss");
    let mut tokens = 0usize;
    // Whole-run measured timeline: per-step engine timelines concatenated
    // end-to-end (each step's events are step-relative).
    let mut run_timeline =
        cfg.trace_out.as_ref().map(|_| (crate::telemetry::Timeline::new(), 0.0));
    // zo2-lint: allow(no-wall-clock): tokens/sec telemetry only — reported, never fed back
    let t0 = std::time::Instant::now();
    let shards = engine.batches_per_step();
    for step in 0..cfg.steps {
        // One engine batch per DP shard (a plain engine samples one).
        let mut ids = Vec::with_capacity(shards * b * t);
        for _ in 0..shards {
            ids.extend(corpus.sample(b, t).ids);
        }
        let stats = engine.train_step(&ids)?;
        if let Some((tl, offset)) = run_timeline.as_mut() {
            if let Some(step_tl) = engine.last_timeline() {
                tl.extend_offset(step_tl, *offset);
                *offset += step_tl.makespan();
            }
        }
        tokens += shards * b * t;
        losses.push(step as f64, stats.loss() as f64);
        if verbose && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            println!(
                "step {:>5}  loss {:.4}  g {:+.3e}  {:.0} tok/s",
                step,
                stats.loss(),
                stats.g,
                tokens as f64 / t0.elapsed().as_secs_f64()
            );
        }
    }
    let train_secs = t0.elapsed().as_secs_f64();
    engine.flush()?;

    let eval_batch = corpus.sample(b, t);
    let (final_eval_loss, _) = engine.eval(&eval_batch.ids)?;

    let (device_peak_bytes, transfer_bytes, disk_bytes, spilled_blocks) = match &engine {
        Engine::Zo2(e) => (
            e.device.peak(),
            e.transfers.lock().unwrap().total_bytes(),
            e.disk_stats().map_or(0, |(r, w)| r.bytes + w.bytes),
            e.spilled_blocks(),
        ),
        Engine::DpSim(dp) => {
            // Per-device peak; traffic summed across the worker replicas.
            let peak = dp.workers().iter().map(|e| e.device.peak()).max().unwrap_or(0);
            let transfer =
                dp.workers().iter().map(|e| e.transfers.lock().unwrap().total_bytes()).sum();
            let disk = dp
                .workers()
                .iter()
                .map(|e| e.disk_stats().map_or(0, |(r, w)| r.bytes + w.bytes))
                .sum();
            (peak, transfer, disk, dp.workers()[0].spilled_blocks())
        }
        Engine::Mezo(e) => (e.device.peak(), 0, 0, 0),
    };

    if let (Some(path), Some((tl, _))) = (&cfg.trace_out, &run_timeline) {
        crate::telemetry::trace::write_chrome_trace(path, tl)?;
        if verbose {
            println!("wrote trace {path}");
        }
    }
    if let Some(path) = &cfg.metrics_out {
        use crate::telemetry::metrics;
        metrics::gauge_set("train_tokens_per_s", &[], tokens as f64 / train_secs);
        metrics::gauge_set("train_transfer_bytes", &[], transfer_bytes as f64);
        metrics::gauge_set("train_disk_bytes", &[], disk_bytes as f64);
        metrics::gauge_set("train_spilled_blocks", &[], spilled_blocks as f64);
        std::fs::write(path, metrics::global().snapshot_json().to_string_pretty())
            .map_err(|e| anyhow::anyhow!("writing metrics {path}: {e}"))?;
        if verbose {
            println!("wrote metrics {path}");
        }
    }

    Ok(TrainReport {
        losses,
        tokens_per_s: tokens as f64 / train_secs,
        final_eval_loss,
        device_peak_bytes,
        transfer_bytes,
        disk_bytes,
        spilled_blocks,
    })
}

/// Configuration for an elastic fault-tolerant DP run driven from the CLI
/// (`zo2 dp ...`).
pub struct ElasticTrainConfig {
    pub run: crate::dp::ElasticRunConfig,
    /// Write the canonical per-step trajectory (values + raw f32 bit
    /// patterns) as JSON, byte-comparable across runs.
    pub losses_out: Option<String>,
    /// Write the recovery-metrics snapshot as JSON.
    pub metrics_out: Option<String>,
    pub log_every: usize,
}

/// Render the canonical trajectory as JSON carrying raw f32 bit patterns,
/// so two runs can be checked for bit-identity with a plain byte diff.
pub fn elastic_losses_json(outcome: &crate::dp::RunOutcome) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(s, "{{\n  \"schema\": \"{}\",", crate::util::schema::DP_LOSSES_SCHEMA);
    let _ = writeln!(s, "  \"final_step\": {},", outcome.final_snap.step);
    let fnv = crate::dp::params_fingerprint(&outcome.final_snap.params);
    let _ = writeln!(s, "  \"final_params_fnv\": \"{fnv:#018x}\",");
    s.push_str("  \"records\": [\n");
    for (i, r) in outcome.records.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"step\": {}, \"loss\": {}, \"g_bits\": {}, \"lp_bits\": {}, \"lm_bits\": {}}}",
            r.step,
            r.loss(),
            r.g.to_bits(),
            r.loss_plus.to_bits(),
            r.loss_minus.to_bits()
        );
        s.push_str(if i + 1 == outcome.records.len() { "\n" } else { ",\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

/// Drive the elastic fault-tolerant DP backend end to end and report the
/// canonical trajectory.  Metrics follow the same pay-for-what-you-use
/// contract as [`train`]: the sink is enabled (and cleared) only when
/// `metrics_out` asks for a snapshot.
pub fn train_elastic(cfg: &ElasticTrainConfig, verbose: bool) -> Result<crate::dp::RunOutcome> {
    crate::telemetry::metrics::set_enabled(cfg.metrics_out.is_some());
    if cfg.metrics_out.is_some() {
        crate::telemetry::metrics::global().reset();
    }
    // zo2-lint: allow(no-wall-clock): run-duration telemetry for the log line only
    let t0 = std::time::Instant::now();
    let outcome = crate::dp::run_elastic(&cfg.run)?;
    let wall = t0.elapsed().as_secs_f64();
    if verbose {
        let every = cfg.log_every.max(1) as u64;
        for r in &outcome.records {
            if r.step % every == 0 || r.step + 1 == cfg.run.steps {
                println!("step {:>5}  loss {:.4}  g {:+.3e}", r.step, r.loss(), r.g);
            }
        }
        println!(
            "elastic dp: {} steps in {:.2}s ({} deaths, {} joins), final step {}, params fnv {:#018x}",
            outcome.records.len(),
            wall,
            outcome.deaths,
            outcome.joins,
            outcome.final_snap.step,
            crate::dp::params_fingerprint(&outcome.final_snap.params)
        );
    }
    if let Some(path) = &cfg.losses_out {
        std::fs::write(path, elastic_losses_json(&outcome))
            .map_err(|e| anyhow::anyhow!("writing losses {path}: {e}"))?;
        if verbose {
            println!("wrote losses {path}");
        }
    }
    if let Some(path) = &cfg.metrics_out {
        use crate::telemetry::metrics;
        metrics::gauge_set("zo2_dp_deaths", &[], outcome.deaths as f64);
        metrics::gauge_set("zo2_dp_joins", &[], outcome.joins as f64);
        std::fs::write(path, metrics::global().snapshot_json().to_string_pretty())
            .map_err(|e| anyhow::anyhow!("writing metrics {path}: {e}"))?;
        if verbose {
            println!("wrote metrics {path}");
        }
    }
    Ok(outcome)
}
