//! Persistent host compute pool for chunked bucket kernels.
//!
//! ZO2's CPU-offload design (paper §5.4–5.5) puts codec conversion and the
//! host-side optimizer arithmetic on the critical path of every offloaded
//! block.  At paper scale those are loops over 10¹¹ elements, so the
//! constant factor of the host kernels is a first-order term in step time
//! (the FZOO observation: ZO wall-clock is won or lost per-step).  This
//! module provides the execution substrate those kernels run on:
//!
//! * [`HostPool`] — a worker pool **spawned once per engine** (no
//!   per-bucket thread spawn, no external deps) that executes
//!   cache-blocked chunk jobs.  The submitting thread participates, so a
//!   1-thread pool is exactly the serial loop.
//! * [`fused`] — chunk kernels over encoded host buckets, including the
//!   fused decode→ZO-update→encode pass that updates a low-bit master copy
//!   without ever materialising a full-bucket fp32 intermediate.
//!
//! # Determinism contract
//!
//! Work is split into fixed-size chunks of [`CHUNK_ELEMS`] elements
//! regardless of thread count.  Every kernel in [`fused`] is elementwise
//! and writes disjoint chunk ranges, and per-chunk RNG draws are replayed
//! from counter offsets (`counter + start/2`, valid because chunk starts
//! are even — one Box–Muller counter tick yields two values).  Results are
//! therefore **bit-identical for any thread count**, and identical to the
//! unchunked scalar reference.  See DESIGN.md for why the chunk size is
//! part of the numerics contract.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

pub mod fused;

/// Elements per chunk.  Must stay **even** (Gaussian replay draws pairs per
/// counter tick; an odd chunk start would shear the pair alignment) and is
/// fixed independently of thread count so that chunk boundaries — and hence
/// every per-chunk RNG replay — never depend on the execution schedule.
/// 16 Ki f32 = 64 KiB per chunk: comfortably cache-blocked.
pub const CHUNK_ELEMS: usize = 16 * 1024;

/// One published chunk job: a borrowed closure plus claim/finish counters.
struct Job {
    f: RawFn,
    n_chunks: usize,
    /// Next unclaimed chunk index (may run past `n_chunks`).
    next: AtomicUsize,
    /// Finished chunk count; the job is complete when it reaches `n_chunks`.
    done: AtomicUsize,
    /// Set when any chunk's kernel panicked.  The panic is caught so the
    /// job still completes (the lifetime-erased borrow in `f` must outlive
    /// every worker access, and a dead worker must not strand the
    /// submitter's done-wait), then re-raised on the submitting thread.
    poisoned: AtomicBool,
}

/// Type-erased pointer to the submitter's chunk closure (the scoped-pool
/// trick).  A raw pointer rather than a reference so that a worker briefly
/// holding a completed job's `Arc` retains no reference-typed dangle —
/// only [`HostPool::drain`] ever dereferences it.
///
/// Safety: [`HostPool::run`] does not return until `done == n_chunks`, so
/// the pointee outlives every dereference.
struct RawFn(*const (dyn Fn(usize) + Sync));

unsafe impl Send for RawFn {}
unsafe impl Sync for RawFn {}

struct Slot {
    /// Bumped when a new job is published; workers remember the last
    /// generation they drained so a finished job is never re-entered.
    generation: u64,
    job: Option<Arc<Job>>,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    work_cv: Condvar,
    done_cv: Condvar,
}

/// Persistent worker pool.  `threads` counts *participants*: the submitter
/// always helps drain its own job, so a pool of `t` threads spawns `t − 1`
/// workers and `HostPool::new(1)` runs everything inline.
///
/// Jobs from concurrent submitters are serialised (one job in flight at a
/// time); each job already spans every worker, so serialisation conserves
/// total throughput for the memory-bound kernels this pool exists for.
/// Worker threads must never submit jobs themselves (the submitter lock is
/// not re-entrant).
pub struct HostPool {
    shared: Arc<Shared>,
    /// Serialises submitters.
    turn: Mutex<()>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl HostPool {
    /// `threads = 0` selects the machine's available parallelism.
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot { generation: 0, job: None, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|_| {
                let shared = shared.clone();
                std::thread::spawn(move || Self::worker_loop(&shared))
            })
            .collect();
        Self { shared, turn: Mutex::new(()), workers, threads }
    }

    /// Total participating threads (workers + the submitting thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn worker_loop(shared: &Shared) {
        let mut seen = 0u64;
        loop {
            let job: Arc<Job> = {
                let mut slot = shared.slot.lock().unwrap();
                loop {
                    if slot.shutdown {
                        return;
                    }
                    if slot.generation != seen {
                        if let Some(j) = &slot.job {
                            seen = slot.generation;
                            break j.clone();
                        }
                    }
                    slot = shared.work_cv.wait(slot).unwrap();
                }
            };
            Self::drain(&job);
            // The last chunk may have been ours: wake a waiting submitter.
            // Lock/unlock pairs the notify with the submitter's predicate
            // check (standard condvar discipline).
            drop(shared.slot.lock().unwrap());
            shared.done_cv.notify_all();
        }
    }

    /// Claim and run chunks until the job is exhausted.  Panics in a chunk
    /// kernel are caught and recorded: every claimed chunk is accounted in
    /// `done` no matter what, so the submitter's completion wait always
    /// terminates and the erased closure borrow is never outlived.
    fn drain(job: &Job) {
        // Safety: see `RawFn` — `run` blocks until every chunk retired.
        let f = unsafe { &*job.f.0 };
        loop {
            let i = job.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.n_chunks {
                return;
            }
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i))).is_err() {
                job.poisoned.store(true, Ordering::Release);
            }
            job.done.fetch_add(1, Ordering::Release);
        }
    }

    /// Run `f(chunk_index)` for every chunk in `0..n_chunks`, in parallel
    /// across the pool.  Blocks until every chunk has finished.  Chunks must
    /// touch disjoint data; the chunk→range mapping is the caller's.
    pub fn run<F: Fn(usize) + Sync>(&self, n_chunks: usize, f: F) {
        if n_chunks == 0 {
            return;
        }
        if self.workers.is_empty() || n_chunks == 1 {
            for i in 0..n_chunks {
                f(i);
            }
            return;
        }
        let _turn = self.turn.lock().unwrap();
        let f_obj: &(dyn Fn(usize) + Sync) = &f;
        // Safety: see `RawFn` — this function blocks until every chunk has
        // finished, so the erased pointee outlives all worker dereferences.
        // (Transmute first: a raw trait-object pointer's elided lifetime
        // bound defaults to 'static, which a plain cast cannot satisfy.)
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f_obj) };
        let job = Arc::new(Job {
            f: RawFn(f_static as *const (dyn Fn(usize) + Sync)),
            n_chunks,
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            poisoned: AtomicBool::new(false),
        });
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.generation += 1;
            slot.job = Some(job.clone());
        }
        self.shared.work_cv.notify_all();
        // The submitter participates instead of idling.
        Self::drain(&job);
        {
            let mut slot = self.shared.slot.lock().unwrap();
            while job.done.load(Ordering::Acquire) < n_chunks {
                slot = self.shared.done_cv.wait(slot).unwrap();
            }
            slot.job = None;
        }
        // Re-raise a caught kernel panic only after every chunk retired and
        // all locks are released (the closure borrow is safe to drop now).
        if job.poisoned.load(Ordering::Acquire) {
            panic!("host pool chunk kernel panicked");
        }
    }

    /// Run `f(chunk_index, start_elem, chunk_len)` over `len` elements split
    /// into [`CHUNK_ELEMS`]-sized chunks (the fixed, schedule-independent
    /// blocking every fused kernel uses).
    pub fn for_chunks<F: Fn(usize, usize, usize) + Sync>(&self, len: usize, f: F) {
        let n_chunks = len.div_ceil(CHUNK_ELEMS);
        self.run(n_chunks, |c| {
            let start = c * CHUNK_ELEMS;
            let clen = CHUNK_ELEMS.min(len - start);
            f(c, start, clen);
        });
    }
}

impl Drop for HostPool {
    fn drop(&mut self) {
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Shareable raw base pointer of a mutable slice, so pool chunks can write
/// disjoint ranges.  Callers must guarantee range disjointness; every use
/// in this crate derives ranges from the fixed chunk grid, which is
/// disjoint by construction.
pub(crate) struct SlicePtr<T>(*mut T);

unsafe impl<T: Send> Send for SlicePtr<T> {}
unsafe impl<T: Send> Sync for SlicePtr<T> {}

impl<T> SlicePtr<T> {
    pub(crate) fn new(s: &mut [T]) -> Self {
        Self(s.as_mut_ptr())
    }

    /// Pointer to element `i`.  Safety: `i` must be within the original
    /// slice and the caller must only form non-overlapping subslices.
    pub(crate) unsafe fn at(&self, i: usize) -> *mut T {
        self.0.add(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_chunk_exactly_once() {
        let pool = HostPool::new(4);
        for n in [0usize, 1, 2, 7, 64, 1000] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.run(n, |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1), "n = {n}");
        }
    }

    #[test]
    fn single_thread_pool_is_serial() {
        let pool = HostPool::new(1);
        assert_eq!(pool.threads(), 1);
        let sum = AtomicU64::new(0);
        pool.run(100, |i| {
            sum.fetch_add(i as u64, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 99 * 100 / 2);
    }

    #[test]
    fn for_chunks_covers_the_range_without_overlap() {
        let pool = HostPool::new(3);
        for len in [1usize, CHUNK_ELEMS - 1, CHUNK_ELEMS, CHUNK_ELEMS + 1, 3 * CHUNK_ELEMS + 17] {
            let covered = AtomicU64::new(0);
            let chunks = AtomicU64::new(0);
            pool.for_chunks(len, |c, start, clen| {
                assert_eq!(start, c * CHUNK_ELEMS);
                assert!(start + clen <= len);
                assert!(clen > 0);
                covered.fetch_add(clen as u64, Ordering::SeqCst);
                chunks.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(covered.load(Ordering::SeqCst), len as u64, "len = {len}");
            assert_eq!(chunks.load(Ordering::SeqCst), len.div_ceil(CHUNK_ELEMS) as u64);
        }
    }

    #[test]
    fn back_to_back_jobs_and_concurrent_submitters() {
        let pool = std::sync::Arc::new(HostPool::new(4));
        // Many sequential jobs reuse the same workers without respawn.
        for round in 0..50 {
            let count = AtomicU64::new(0);
            pool.run(17, |_| {
                count.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(count.load(Ordering::SeqCst), 17, "round {round}");
        }
        // Two submitters race; jobs serialise but both complete fully.
        let p2 = pool.clone();
        let t = std::thread::spawn(move || {
            for _ in 0..20 {
                let c = AtomicU64::new(0);
                p2.run(33, |_| {
                    c.fetch_add(1, Ordering::SeqCst);
                });
                assert_eq!(c.load(Ordering::SeqCst), 33);
            }
        });
        for _ in 0..20 {
            let c = AtomicU64::new(0);
            pool.run(29, |_| {
                c.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(c.load(Ordering::SeqCst), 29);
        }
        t.join().unwrap();
    }

    #[test]
    fn auto_thread_count_is_positive() {
        let pool = HostPool::new(0);
        assert!(pool.threads() >= 1);
        let c = AtomicU64::new(0);
        pool.run(8, |_| {
            c.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(c.load(Ordering::SeqCst), 8);
    }
}
