//! Persistent host compute pool for chunked bucket kernels.
//!
//! ZO2's CPU-offload design (paper §5.4–5.5) puts codec conversion and the
//! host-side optimizer arithmetic on the critical path of every offloaded
//! block.  At paper scale those are loops over 10¹¹ elements, so the
//! constant factor of the host kernels is a first-order term in step time
//! (the FZOO observation: ZO wall-clock is won or lost per-step).  This
//! module provides the execution substrate those kernels run on:
//!
//! * [`HostPool`] — a worker pool **spawned once per engine** (no
//!   per-bucket thread spawn, no external deps) that executes
//!   cache-blocked chunk jobs.  The submitting thread participates, so a
//!   1-thread pool is exactly the serial loop.
//! * [`fused`] — chunk kernels over encoded host buckets, including the
//!   fused decode→ZO-update→encode pass that updates a low-bit master copy
//!   without ever materialising a full-bucket fp32 intermediate.
//!
//! # Determinism contract
//!
//! Work is split into fixed-size chunks of [`CHUNK_ELEMS`] elements
//! regardless of thread count.  Every kernel in [`fused`] is elementwise
//! and writes disjoint chunk ranges, and per-chunk RNG draws are replayed
//! from counter offsets (`counter + start/2`, valid because chunk starts
//! are even — one Box–Muller counter tick yields two values).  Results are
//! therefore **bit-identical for any thread count**, and identical to the
//! unchunked scalar reference.  See DESIGN.md for why the chunk size is
//! part of the numerics contract.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

pub mod fused;

/// Elements per chunk.  Must stay **even** (Gaussian replay draws pairs per
/// counter tick; an odd chunk start would shear the pair alignment) and is
/// fixed independently of thread count so that chunk boundaries — and hence
/// every per-chunk RNG replay — never depend on the execution schedule.
/// 16 Ki f32 = 64 KiB per chunk: comfortably cache-blocked.
pub const CHUNK_ELEMS: usize = 16 * 1024;

/// One published chunk job: a borrowed closure plus claim/finish counters.
struct Job {
    f: RawFn,
    n_chunks: usize,
    /// `Some(t)` = static chunk→participant mapping (pinned pools):
    /// participant `p` runs chunks `p, p+t, p+2t, …`.  The mapping is a pure
    /// function of the chunk index, so the same chunk lands on the same
    /// (NUMA-pinned) thread every step — first-touch pages stay local.
    /// `None` = dynamic work-stealing claim via `next`.
    stride: Option<usize>,
    /// Next unclaimed chunk index (dynamic mode; may run past `n_chunks`).
    next: AtomicUsize,
    /// Finished chunk count; the job is complete when it reaches `n_chunks`.
    done: AtomicUsize,
    /// Set when any chunk's kernel panicked.  The panic is caught so the
    /// job still completes (the lifetime-erased borrow in `f` must outlive
    /// every worker access, and a dead worker must not strand the
    /// submitter's done-wait), then re-raised on the submitting thread.
    poisoned: AtomicBool,
}

/// Type-erased pointer to the submitter's chunk closure (the scoped-pool
/// trick).  A raw pointer rather than a reference so that a worker briefly
/// holding a completed job's `Arc` retains no reference-typed dangle —
/// only [`HostPool::drain`] ever dereferences it.
///
/// Safety: [`HostPool::run`] does not return until `done == n_chunks`, so
/// the pointee outlives every dereference.
struct RawFn(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is a `dyn Fn(usize) + Sync` borrowed by `run`, which
// parks on the finish gate until `done == n_chunks` — so the closure
// outlives every cross-thread access, and `Sync` makes the shared calls
// sound.
unsafe impl Send for RawFn {}
// SAFETY: same invariant as `Send` above — `run` pins the closure for the
// whole job and the erased target is `Sync`.
unsafe impl Sync for RawFn {}

struct Slot {
    /// Bumped when a new job is published; workers remember the last
    /// generation they drained so a finished job is never re-entered.
    generation: u64,
    job: Option<Arc<Job>>,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    work_cv: Condvar,
    done_cv: Condvar,
}

/// Persistent worker pool.  `threads` counts *participants*: the submitter
/// always helps drain its own job, so a pool of `t` threads spawns `t − 1`
/// workers and `HostPool::new(1)` runs everything inline.
///
/// Jobs from concurrent submitters are serialised (one job in flight at a
/// time); each job already spans every worker, so serialisation conserves
/// total throughput for the memory-bound kernels this pool exists for.
/// Worker threads must never submit jobs themselves (the submitter lock is
/// not re-entrant).
pub struct HostPool {
    shared: Arc<Shared>,
    /// Serialises submitters.
    turn: Mutex<()>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    pin: bool,
}

impl HostPool {
    /// `threads = 0` selects the machine's available parallelism.
    pub fn new(threads: usize) -> Self {
        Self::with_opts(threads, false)
    }

    /// Build a pool, optionally with NUMA-aware worker pinning
    /// (`--host-pin`).  Pinned pools additionally switch chunk claiming
    /// from dynamic stealing to the static strided mapping, so a chunk's
    /// pages are always touched from the same core — see [`Job::stride`].
    /// The submitting thread (participant 0) is deliberately *not* pinned:
    /// hijacking the caller's affinity would leak far beyond the pool.
    pub fn with_opts(threads: usize, pin: bool) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        // The affinity mask below covers 512 CPUs; also a sane upper bound
        // against accidental fork bombs from miskeyed CLI values.
        let threads = threads.min(512);
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot { generation: 0, job: None, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let topo = if pin { numa_nodes() } else { Vec::new() };
        let workers = (1..threads)
            .map(|participant| {
                let shared = shared.clone();
                let cpu = if pin { Some(cpu_for_participant(participant, &topo)) } else { None };
                std::thread::spawn(move || {
                    if let Some(cpu) = cpu {
                        pin_current_thread(cpu);
                    }
                    Self::worker_loop(&shared, participant)
                })
            })
            .collect();
        Self { shared, turn: Mutex::new(()), workers, threads, pin }
    }

    /// Total participating threads (workers + the submitting thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Whether workers are NUMA-pinned (and jobs strided).
    pub fn pinned(&self) -> bool {
        self.pin
    }

    fn worker_loop(shared: &Shared, participant: usize) {
        let mut seen = 0u64;
        loop {
            let job: Arc<Job> = {
                let mut slot = shared.slot.lock().unwrap();
                loop {
                    if slot.shutdown {
                        return;
                    }
                    if slot.generation != seen {
                        if let Some(j) = &slot.job {
                            seen = slot.generation;
                            break j.clone();
                        }
                    }
                    slot = shared.work_cv.wait(slot).unwrap();
                }
            };
            Self::drain(&job, participant);
            // The last chunk may have been ours: wake a waiting submitter.
            // Lock/unlock pairs the notify with the submitter's predicate
            // check (standard condvar discipline).
            drop(shared.slot.lock().unwrap());
            shared.done_cv.notify_all();
        }
    }

    /// Claim and run chunks until the job is exhausted.  Panics in a chunk
    /// kernel are caught and recorded: every claimed chunk is accounted in
    /// `done` no matter what, so the submitter's completion wait always
    /// terminates and the erased closure borrow is never outlived.
    fn drain(job: &Job, participant: usize) {
        // Safety: see `RawFn` — `run` blocks until every chunk retired.
        let f = unsafe { &*job.f.0 };
        let mut run_one = |i: usize| {
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i))).is_err() {
                job.poisoned.store(true, Ordering::Release);
            }
            job.done.fetch_add(1, Ordering::Release);
        };
        match job.stride {
            // Static mapping: this participant's residue class, exactly once
            // (the generation guard in `worker_loop` prevents re-entry, which
            // would double-run chunks here — unlike the idempotent claim
            // counter below).
            Some(t) => {
                let mut i = participant;
                while i < job.n_chunks {
                    run_one(i);
                    i += t;
                }
            }
            None => loop {
                let i = job.next.fetch_add(1, Ordering::Relaxed);
                if i >= job.n_chunks {
                    return;
                }
                run_one(i);
            },
        }
    }

    /// Run `f(chunk_index)` for every chunk in `0..n_chunks`, in parallel
    /// across the pool.  Blocks until every chunk has finished.  Chunks must
    /// touch disjoint data; the chunk→range mapping is the caller's.
    pub fn run<F: Fn(usize) + Sync>(&self, n_chunks: usize, f: F) {
        if n_chunks == 0 {
            return;
        }
        if self.workers.is_empty() || n_chunks == 1 {
            for i in 0..n_chunks {
                f(i);
            }
            return;
        }
        let _turn = self.turn.lock().unwrap();
        let f_obj: &(dyn Fn(usize) + Sync) = &f;
        // Safety: see `RawFn` — this function blocks until every chunk has
        // finished, so the erased pointee outlives all worker dereferences.
        // (Transmute first: a raw trait-object pointer's elided lifetime
        // bound defaults to 'static, which a plain cast cannot satisfy.)
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f_obj) };
        let job = Arc::new(Job {
            f: RawFn(f_static as *const (dyn Fn(usize) + Sync)),
            n_chunks,
            stride: if self.pin { Some(self.threads) } else { None },
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            poisoned: AtomicBool::new(false),
        });
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.generation += 1;
            slot.job = Some(job.clone());
        }
        self.shared.work_cv.notify_all();
        // The submitter participates instead of idling (participant 0).
        Self::drain(&job, 0);
        {
            let mut slot = self.shared.slot.lock().unwrap();
            while job.done.load(Ordering::Acquire) < n_chunks {
                slot = self.shared.done_cv.wait(slot).unwrap();
            }
            slot.job = None;
        }
        // Re-raise a caught kernel panic only after every chunk retired and
        // all locks are released (the closure borrow is safe to drop now).
        if job.poisoned.load(Ordering::Acquire) {
            panic!("host pool chunk kernel panicked");
        }
    }

    /// Run `f(chunk_index, start_elem, chunk_len)` over `len` elements split
    /// into [`CHUNK_ELEMS`]-sized chunks (the fixed, schedule-independent
    /// blocking every fused kernel uses).
    pub fn for_chunks<F: Fn(usize, usize, usize) + Sync>(&self, len: usize, f: F) {
        let n_chunks = len.div_ceil(CHUNK_ELEMS);
        self.run(n_chunks, |c| {
            let start = c * CHUNK_ELEMS;
            let clen = CHUNK_ELEMS.min(len - start);
            f(c, start, clen);
        });
    }
}

impl Drop for HostPool {
    fn drop(&mut self) {
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// --- NUMA topology / pinning (best-effort, Linux) ------------------------------

/// Per-NUMA-node CPU lists from sysfs; a single node spanning all CPUs when
/// the topology is unreadable (non-Linux, containers without sysfs).
fn numa_nodes() -> Vec<Vec<usize>> {
    let mut nodes = Vec::new();
    #[cfg(target_os = "linux")]
    for idx in 0.. {
        let path = format!("/sys/devices/system/node/node{idx}/cpulist");
        match std::fs::read_to_string(&path) {
            Ok(s) => {
                let cpus = parse_cpulist(&s);
                if !cpus.is_empty() {
                    nodes.push(cpus);
                }
            }
            Err(_) => break,
        }
    }
    if nodes.is_empty() {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        nodes.push((0..n).collect());
    }
    nodes
}

/// Parse a sysfs cpulist like `"0-15,32-47"` into explicit CPU ids.
fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for part in s.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((a, b)) = part.split_once('-') {
            if let (Ok(a), Ok(b)) = (a.trim().parse::<usize>(), b.trim().parse::<usize>()) {
                out.extend(a..=b.max(a));
            }
        } else if let Ok(c) = part.parse::<usize>() {
            out.push(c);
        }
    }
    out
}

/// Round-robin participants across nodes first, then within each node —
/// spreads the pool over memory controllers so pinned first-touch pages
/// distribute instead of piling onto node 0.
fn cpu_for_participant(participant: usize, nodes: &[Vec<usize>]) -> usize {
    let node = &nodes[participant % nodes.len()];
    node[(participant / nodes.len()) % node.len()]
}

/// Pin the calling thread to one CPU.  Best-effort: failure (restricted
/// cpuset, exotic kernel) leaves the thread unpinned — correctness never
/// depends on placement, only locality does.
#[cfg(target_os = "linux")]
fn pin_current_thread(cpu: usize) {
    const MASK_WORDS: usize = 8; // 512 CPUs
    if cpu >= MASK_WORDS * 64 {
        return;
    }
    let mut mask = [0u64; MASK_WORDS];
    mask[cpu / 64] |= 1u64 << (cpu % 64);
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    // SAFETY: plain syscall with pid 0 (= the calling thread) and a mask
    // buffer of exactly `cpusetsize` bytes that outlives the call; the
    // kernel only reads it, and a failure return is deliberately ignored.
    let _ = unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
}

#[cfg(not(target_os = "linux"))]
fn pin_current_thread(_cpu: usize) {}

/// Shareable raw base pointer of a mutable slice, so pool chunks can write
/// disjoint ranges.  Callers must guarantee range disjointness; every use
/// in this crate derives ranges from the fixed chunk grid, which is
/// disjoint by construction.
pub(crate) struct SlicePtr<T>(*mut T);

// SAFETY: the base pointer is only turned into element pointers via `at`,
// whose callers take disjoint chunk-grid ranges of a slice that `run`
// keeps mutably borrowed for the whole job; `T: Send` lets those disjoint
// views move across worker threads.
unsafe impl<T: Send> Send for SlicePtr<T> {}
// SAFETY: workers never alias an index (the chunk grid partitions the
// slice), so sharing `&SlicePtr` across threads is sound for `T: Send`.
unsafe impl<T: Send> Sync for SlicePtr<T> {}

impl<T> SlicePtr<T> {
    pub(crate) fn new(s: &mut [T]) -> Self {
        Self(s.as_mut_ptr())
    }

    /// Pointer to element `i`.  Safety: `i` must be within the original
    /// slice and the caller must only form non-overlapping subslices.
    pub(crate) unsafe fn at(&self, i: usize) -> *mut T {
        self.0.add(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_chunk_exactly_once() {
        let pool = HostPool::new(4);
        for n in [0usize, 1, 2, 7, 64, 1000] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.run(n, |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1), "n = {n}");
        }
    }

    #[test]
    fn single_thread_pool_is_serial() {
        let pool = HostPool::new(1);
        assert_eq!(pool.threads(), 1);
        let sum = AtomicU64::new(0);
        pool.run(100, |i| {
            sum.fetch_add(i as u64, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 99 * 100 / 2);
    }

    #[test]
    fn for_chunks_covers_the_range_without_overlap() {
        let pool = HostPool::new(3);
        for len in [1usize, CHUNK_ELEMS - 1, CHUNK_ELEMS, CHUNK_ELEMS + 1, 3 * CHUNK_ELEMS + 17] {
            let covered = AtomicU64::new(0);
            let chunks = AtomicU64::new(0);
            pool.for_chunks(len, |c, start, clen| {
                assert_eq!(start, c * CHUNK_ELEMS);
                assert!(start + clen <= len);
                assert!(clen > 0);
                covered.fetch_add(clen as u64, Ordering::SeqCst);
                chunks.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(covered.load(Ordering::SeqCst), len as u64, "len = {len}");
            assert_eq!(chunks.load(Ordering::SeqCst), len.div_ceil(CHUNK_ELEMS) as u64);
        }
    }

    #[test]
    fn back_to_back_jobs_and_concurrent_submitters() {
        let pool = std::sync::Arc::new(HostPool::new(4));
        // Many sequential jobs reuse the same workers without respawn.
        for round in 0..50 {
            let count = AtomicU64::new(0);
            pool.run(17, |_| {
                count.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(count.load(Ordering::SeqCst), 17, "round {round}");
        }
        // Two submitters race; jobs serialise but both complete fully.
        let p2 = pool.clone();
        let t = std::thread::spawn(move || {
            for _ in 0..20 {
                let c = AtomicU64::new(0);
                p2.run(33, |_| {
                    c.fetch_add(1, Ordering::SeqCst);
                });
                assert_eq!(c.load(Ordering::SeqCst), 33);
            }
        });
        for _ in 0..20 {
            let c = AtomicU64::new(0);
            pool.run(29, |_| {
                c.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(c.load(Ordering::SeqCst), 29);
        }
        t.join().unwrap();
    }

    #[test]
    fn pinned_pool_runs_every_chunk_exactly_once() {
        let pool = HostPool::with_opts(4, true);
        assert!(pool.pinned());
        for n in [0usize, 1, 2, 3, 4, 7, 64, 1000] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.run(n, |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1), "n = {n}");
        }
        // Back-to-back jobs on the strided pool complete fully too.
        for _ in 0..30 {
            let c = AtomicU64::new(0);
            pool.run(13, |_| {
                c.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(c.load(Ordering::SeqCst), 13);
        }
    }

    #[test]
    fn cpulist_parser_handles_ranges_and_singles() {
        assert_eq!(parse_cpulist("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpulist("0-1,4,8-9\n"), vec![0, 1, 4, 8, 9]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist("7"), vec![7]);
        // Participant→CPU round-robins across nodes first.
        let nodes = vec![vec![0, 1], vec![2, 3]];
        let cpus: Vec<usize> = (0..6).map(|p| cpu_for_participant(p, &nodes)).collect();
        assert_eq!(cpus, vec![0, 2, 1, 3, 0, 2]);
    }

    #[test]
    fn auto_thread_count_is_positive() {
        let pool = HostPool::new(0);
        assert!(pool.threads() >= 1);
        let c = AtomicU64::new(0);
        pool.run(8, |_| {
            c.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(c.load(Ordering::SeqCst), 8);
    }
}
