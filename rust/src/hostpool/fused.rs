//! Fused, pooled chunk kernels over encoded host buckets.
//!
//! The unfused host path of a deferred CPU-side update is three full passes
//! over the bucket — decode the wire bytes to an fp32 scratch, update the
//! scratch, encode it back — which costs 3× the memory traffic and a
//! bucket-sized fp32 intermediate.  The kernels here do all three steps in
//! a single pass per cache-blocked chunk: each element is decoded, updated
//! and re-encoded while it is hot in registers, so the low-bit master copy
//! is updated **without ever expanding to fp32 in memory** (the
//! quantized-ZO argument, arXiv 2505.13430).
//!
//! Every kernel is elementwise over the fixed chunk grid of
//! [`super::CHUNK_ELEMS`], which is what makes the pooled results
//! bit-identical to the scalar reference at any thread count — see the
//! determinism contract in the module docs of [`super`] and DESIGN.md.

use crate::precision::{bf16_to_f32, f32_to_bf16, f32_to_fp8_e4m3, Codec};
use crate::rng::{GaussianRng, RngState};

use super::{HostPool, SlicePtr, CHUNK_ELEMS};

/// RNG state replaying the draw for elements `start..` of a bucket whose
/// draw starts at `state`.  Valid only for even `start` (one counter tick
/// yields a Box–Muller pair), which the chunk grid guarantees.
#[inline]
pub(crate) fn offset_state(state: RngState, start: usize) -> RngState {
    debug_assert_eq!(start % 2, 0, "chunk starts must be pair-aligned");
    RngState { counter: state.counter + (start / 2) as u64, ..state }
}

/// Fill `z` with the replayed Gaussian draw for elements
/// `start..start + z.len()` — bit-identical to the corresponding range of a
/// contiguous whole-bucket fill.
#[inline]
pub(crate) fn fill_z_chunk(state: RngState, start: usize, z: &mut [f32]) {
    GaussianRng::from_state(offset_state(state, start)).fill_gaussian(z);
}

/// Map `f(i, w) → w′` over every element of one encoded chunk, decoding and
/// re-encoding in place.  The codec dispatch happens once per chunk, so the
/// inner loops stay branch-free (fp16 through the precision tables).
#[inline]
pub(crate) fn map_wire_chunk(
    codec: Codec,
    bytes: &mut [u8],
    len: usize,
    mut f: impl FnMut(usize, f32) -> f32,
) {
    debug_assert_eq!(bytes.len(), len * codec.bytes_per_el());
    match codec {
        Codec::F32 => {
            for (i, c) in bytes.chunks_exact_mut(4).enumerate().take(len) {
                let w = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                c.copy_from_slice(&f(i, w).to_le_bytes());
            }
        }
        Codec::Bf16 => {
            for (i, c) in bytes.chunks_exact_mut(2).enumerate().take(len) {
                let w = bf16_to_f32(u16::from_le_bytes([c[0], c[1]]));
                c.copy_from_slice(&f32_to_bf16(f(i, w)).to_le_bytes());
            }
        }
        Codec::Fp16 => {
            for (i, c) in bytes.chunks_exact_mut(2).enumerate().take(len) {
                let w = crate::precision::fp16_to_f32_lut(u16::from_le_bytes([c[0], c[1]]));
                c.copy_from_slice(&crate::precision::f32_to_fp16_tab(f(i, w)).to_le_bytes());
            }
        }
        Codec::Fp8E4M3 => {
            for (i, b) in bytes.iter_mut().enumerate().take(len) {
                let w = crate::precision::fp8_e4m3_to_f32_lut(*b);
                *b = f32_to_fp8_e4m3(f(i, w));
            }
        }
    }
}

/// Staged SIMD variant of a fused chunk pass: decode the chunk into a stack
/// buffer, vector-update, encode back.  Returns `false` when the vector
/// path is off/unsupported — the caller runs the single-pass scalar map
/// instead.  Both paths apply the same per-element math in the same order,
/// so they are bit-identical; the staging buffer is 64 KiB and
/// cache-resident, so the extra passes are cheap next to the scalar
/// per-element codec calls they replace.
#[inline]
pub(crate) fn simd_sgd_wire_chunk(
    codec: Codec,
    bytes: &mut [u8],
    len: usize,
    z: &[f32],
    scale: f32,
) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if crate::simd::active() && len <= CHUNK_ELEMS {
            let mut buf = [0.0f32; CHUNK_ELEMS];
            let w = &mut buf[..len];
            // Safety: AVX2 availability is checked by `active()`; slice
            // sizes match the chunk grid.
            unsafe {
                crate::simd::avx2::decode_chunk(codec, bytes, w);
                crate::simd::avx2::sgd_update(w, &z[..len], scale);
                crate::simd::avx2::encode_chunk(codec, w, bytes);
            }
            return true;
        }
    }
    let _ = (codec, bytes, len, z, scale);
    false
}

/// Pooled whole-bucket decode — bit-identical to [`Codec::decode_into`] at
/// any thread count (disjoint chunks, same per-element conversion).
pub fn decode_pooled(codec: Codec, src: &[u8], out: &mut [f32], pool: &HostPool) {
    let n = out.len();
    assert_eq!(src.len(), n * codec.bytes_per_el(), "payload size mismatch");
    observe_chunks("decode", codec, n);
    let bpe = codec.bytes_per_el();
    let outp = SlicePtr::new(out);
    pool.for_chunks(n, |_, start, len| {
        // Safety: chunk ranges are disjoint by construction.
        let dst = unsafe { std::slice::from_raw_parts_mut(outp.at(start), len) };
        codec.decode_chunk(&src[start * bpe..(start + len) * bpe], dst);
    });
}

/// Pooled whole-bucket encode into an exactly-sized wire buffer —
/// bit-identical to [`Codec::encode_into`]'s payload at any thread count.
pub fn encode_pooled(codec: Codec, src: &[f32], out: &mut [u8], pool: &HostPool) {
    let n = src.len();
    assert_eq!(out.len(), n * codec.bytes_per_el(), "payload size mismatch");
    observe_chunks("encode", codec, n);
    let bpe = codec.bytes_per_el();
    let outp = SlicePtr::new(out);
    pool.for_chunks(n, |_, start, len| {
        // Safety: chunk byte ranges are disjoint by construction.
        let dst = unsafe { std::slice::from_raw_parts_mut(outp.at(start * bpe), len * bpe) };
        codec.encode_chunk(&src[start..start + len], dst);
    });
}

/// Fused ZO-SGD on an encoded bucket: one pass of
/// `w ← w − (lr·g)·z` in the wire domain, `z` replayed per chunk from
/// `state`.  Bit-identical to the three-pass composition
/// decode → [`crate::zo::cpu_zo_sgd_update`] → encode, at any thread count.
pub fn fused_zo_sgd(
    codec: Codec,
    wire: &mut [u8],
    numel: usize,
    state: RngState,
    lr: f32,
    g: f32,
    pool: &HostPool,
) {
    assert_eq!(wire.len(), numel * codec.bytes_per_el(), "payload size mismatch");
    observe_chunks("update", codec, numel);
    let scale = lr * g;
    let bpe = codec.bytes_per_el();
    let wp = SlicePtr::new(wire);
    pool.for_chunks(numel, |_, start, len| {
        // Safety: chunk byte ranges are disjoint by construction.
        let bytes = unsafe { std::slice::from_raw_parts_mut(wp.at(start * bpe), len * bpe) };
        let mut z = [0.0f32; CHUNK_ELEMS];
        let z = &mut z[..len];
        fill_z_chunk(state, start, z);
        if !simd_sgd_wire_chunk(codec, bytes, len, z, scale) {
            // Same op order as the scalar reference: mul, then sub.
            map_wire_chunk(codec, bytes, len, |i, w| w - scale * z[i]);
        }
    });
}

/// Per-call chunk-batch histogram for the global metrics sink.  Recorded
/// once per kernel *entry* (never inside `for_chunks`), so the chunk
/// kernels and their determinism contract are untouched; a disabled sink
/// costs one branch.
#[inline]
fn observe_chunks(op: &'static str, codec: Codec, numel: usize) {
    if crate::telemetry::metrics::enabled() {
        crate::telemetry::metrics::observe(
            "hostpool_chunks_per_call",
            &[("op", op), ("codec", codec.name())],
            numel.div_ceil(CHUNK_ELEMS) as f64,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data(n: usize, seed: u64) -> Vec<f32> {
        let mut xs = vec![0.0f32; n];
        GaussianRng::new(seed, 0).fill_gaussian(&mut xs);
        for x in xs.iter_mut() {
            *x *= 0.02; // parameter-scale values, representable in fp8
        }
        xs
    }

    #[test]
    fn chunked_z_equals_contiguous_fill() {
        let state = RngState { seed: 3, stream: 9, counter: 40 };
        let n = 2 * CHUNK_ELEMS + 1001; // odd tail in the last chunk
        let mut whole = vec![0.0f32; n];
        GaussianRng::from_state(state).fill_gaussian(&mut whole);
        let mut start = 0;
        while start < n {
            let len = CHUNK_ELEMS.min(n - start);
            let mut z = vec![0.0f32; len];
            fill_z_chunk(state, start, &mut z);
            assert_eq!(z, &whole[start..start + len], "chunk at {start}");
            start += len;
        }
    }

    #[test]
    fn pooled_codec_roundtrip_matches_scalar() {
        let xs = data(CHUNK_ELEMS + 777, 1);
        let pool = HostPool::new(4);
        for codec in [Codec::F32, Codec::Bf16, Codec::Fp16, Codec::Fp8E4M3] {
            let scalar = codec.encode(&xs);
            let mut pooled = vec![0u8; scalar.len()];
            encode_pooled(codec, &xs, &mut pooled, &pool);
            assert_eq!(pooled, scalar, "{codec:?} encode");
            let mut back_scalar = vec![0.0f32; xs.len()];
            codec.decode_into(&scalar, &mut back_scalar);
            let mut back_pooled = vec![0.0f32; xs.len()];
            decode_pooled(codec, &pooled, &mut back_pooled, &pool);
            let same = back_scalar
                .iter()
                .zip(&back_pooled)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "{codec:?} decode");
        }
    }

    #[test]
    fn fused_sgd_equals_unfused_composition() {
        let state = RngState { seed: 11, stream: 2, counter: 7 };
        let pool = HostPool::new(4);
        for codec in [Codec::F32, Codec::Bf16, Codec::Fp16, Codec::Fp8E4M3] {
            for n in [5usize, CHUNK_ELEMS, CHUNK_ELEMS + 333] {
                let xs = data(n, 42);
                // Reference: decode the encoded bucket, update in fp32,
                // encode back (the three-pass path the fusion replaces).
                let wire0 = codec.encode(&xs);
                let mut ref_f32 = codec.decode(&wire0, n);
                let mut z = vec![0.0f32; n];
                GaussianRng::from_state(state).fill_gaussian(&mut z);
                let scale = 1e-2f32 * 0.75;
                for (w, zi) in ref_f32.iter_mut().zip(&z) {
                    *w -= scale * zi;
                }
                let want = codec.encode(&ref_f32);
                // Fused single pass.
                let mut got = wire0.clone();
                fused_zo_sgd(codec, &mut got, n, state, 1e-2, 0.75, &pool);
                assert_eq!(got, want, "{codec:?} n={n}");
            }
        }
    }

    #[test]
    fn fused_sgd_is_thread_count_invariant() {
        let state = RngState { seed: 5, stream: 0, counter: 0 };
        let xs = data(3 * CHUNK_ELEMS + 91, 7);
        for codec in [Codec::Bf16, Codec::Fp8E4M3] {
            let wire0 = codec.encode(&xs);
            let mut outs = Vec::new();
            for threads in [1usize, 2, 8] {
                let pool = HostPool::new(threads);
                let mut w = wire0.clone();
                fused_zo_sgd(codec, &mut w, xs.len(), state, 3e-3, -1.2, &pool);
                outs.push(w);
            }
            assert_eq!(outs[0], outs[1], "{codec:?} 1 vs 2 threads");
            assert_eq!(outs[0], outs[2], "{codec:?} 1 vs 8 threads");
        }
    }
}
