//! Simulator-driven autotuner (`zo2 tune`).
//!
//! The policy space has grown to shard strategy × layout × microbatches ×
//! slot-ring depth × DRAM-window depth × disk batch × spill placement; the
//! analytic simulator already prices all of it.  This module searches that
//! space with the simulator as the oracle:
//!
//! * **Search space** — a declarative [`SearchSpace`]: one value list per
//!   knob, enumerated as a mixed-radix space so every candidate has a
//!   stable index (the cache key, the neighbourhood structure and the
//!   report order all derive from it).
//! * **Oracle** — [`evaluate`] mirrors `zo2 simulate`'s exact planning +
//!   pricing path ([`plan_three_tier`]/[`plan_three_tier_owned`] →
//!   [`build_sharded_plan_tiered`] → [`crate::sched::simulate`]), so the
//!   best config replays through `simulate --config tuned.json` to the
//!   same steady-state step time.
//! * **Constraints** — infeasible points (budget-busting tier plans,
//!   structurally invalid knob combinations, planner refusals) are pruned
//!   with a reason, never panics: the tuner sweeps thousands of configs
//!   programmatically and must survive every edge the CLI guards.
//! * **Driver** — beam search over single-knob neighbours with a seeded
//!   simulated-annealing fallback; both draw every random choice from
//!   [`GaussianRng`] seeded by `--tune-seed`, so the whole run (and the
//!   emitted `zo2-tune-v1` report) is byte-deterministic.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::Result;

use crate::costmodel::{
    min_hbm_capacity, plan_three_tier, plan_three_tier_owned, Cluster, ClusterCost, Hardware,
    Interconnect, MemoryBudget, SimCost, TierPlan, Workload,
};
use crate::rng::GaussianRng;
use crate::sched::{build_plan, simulate, Policy, SpillPlacement, Tiering};
use crate::shard::{
    blocks_per_device, blocks_per_device_of, bottleneck_weights, build_sharded_plan_tiered,
    weighted_contiguous_owners, DeviceTier, ShardLayout, ShardSpec, ShardStrategy,
};
use crate::util::json::Json;

/// Schema tag of the tune report (`tuned.json`).
pub use crate::util::schema::TUNE_SCHEMA;

/// Block placement choice as the CLI models it: the two [`ShardLayout`]s
/// plus `weighted` (contiguous placement with the bottleneck-aware owner
/// hint), which is not a `ShardLayout` of its own.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayoutChoice {
    Contiguous,
    Cyclic,
    Weighted,
}

impl LayoutChoice {
    /// The canonical CLI spelling (`--layout`).
    pub fn name(self) -> &'static str {
        match self {
            LayoutChoice::Contiguous => "contiguous",
            LayoutChoice::Cyclic => "cyclic",
            LayoutChoice::Weighted => "weighted",
        }
    }

    /// Parse a CLI spelling (same aliases `main.rs` accepts).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "contiguous" | "block" => Some(LayoutChoice::Contiguous),
            "cyclic" | "roundrobin" => Some(LayoutChoice::Cyclic),
            "weighted" | "hint" => Some(LayoutChoice::Weighted),
            _ => None,
        }
    }
}

/// The fixed part of a tuning problem: what runs, on what cluster, under
/// which memory regime.  Everything the knobs do *not* vary.
#[derive(Clone)]
pub struct Scenario {
    pub wl: Workload,
    /// One entry per device; never empty for a well-formed scenario, but
    /// [`evaluate`] degrades to an infeasible verdict rather than panicking
    /// if a caller hands it one.
    pub hw: Vec<Hardware>,
    /// One sender link per device (ignored for a single device).
    pub links: Vec<Interconnect>,
    /// Per-host DDR budgets in bytes; `Some` = three-tier scenario.
    pub dram_budget_bytes: Option<Vec<u64>>,
    /// Simulated steps (the steady-state window).
    pub steps: usize,
    /// Master-copy bytes per element (the CLI's `wire.bytes_per_el().min(4)`).
    pub param_bytes: usize,
}

impl Scenario {
    pub fn devices(&self) -> usize {
        self.hw.len()
    }

    pub fn three_tier(&self) -> bool {
        self.dram_budget_bytes.is_some()
    }
}

/// One point of the search space: the tunable knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Candidate {
    pub strategy: ShardStrategy,
    pub layout: LayoutChoice,
    pub microbatches: usize,
    pub slots: usize,
    pub dram_slots: usize,
    pub disk_batch: usize,
    pub spill_placement: SpillPlacement,
}

impl Candidate {
    /// Canonical one-line label: the report's config identity.
    pub fn key(&self) -> String {
        format!(
            "shard={} layout={} microbatches={} slots={} dram-slots={} disk-batch={} \
             spill-placement={}",
            self.strategy.name(),
            self.layout.name(),
            self.microbatches,
            self.slots,
            self.dram_slots,
            self.disk_batch,
            self.spill_placement.name()
        )
    }

    /// The knobs as CLI flag pairs (keys without the leading `--`); merged
    /// over the scenario flags these form the replayable config.
    pub fn flags(&self) -> BTreeMap<String, String> {
        BTreeMap::from([
            ("shard".to_string(), self.strategy.name().to_string()),
            ("layout".to_string(), self.layout.name().to_string()),
            ("microbatches".to_string(), self.microbatches.to_string()),
            ("slots".to_string(), self.slots.to_string()),
            ("dram-slots".to_string(), self.dram_slots.to_string()),
            ("disk-batch".to_string(), self.disk_batch.to_string()),
            ("spill-placement".to_string(), self.spill_placement.name().to_string()),
        ])
    }
}

/// Declarative search space: one candidate per element of the cartesian
/// product of the axes.  Candidates are enumerated in mixed-radix order
/// (axis 0 least significant), giving every point a stable index.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    pub strategies: Vec<ShardStrategy>,
    pub layouts: Vec<LayoutChoice>,
    pub microbatches: Vec<usize>,
    pub slots: Vec<usize>,
    pub dram_slots: Vec<usize>,
    pub disk_batch: Vec<usize>,
    pub spill_placements: Vec<SpillPlacement>,
}

const N_AXES: usize = 7;

impl SearchSpace {
    /// A sensible default space for a scenario: single-device scenarios
    /// drop the sharding axes, two-tier scenarios drop the disk knobs.
    pub fn default_for(devices: usize, three_tier: bool) -> Self {
        let (strategies, layouts, microbatches) = if devices <= 1 {
            (vec![ShardStrategy::DataParallel], vec![LayoutChoice::Contiguous], vec![1])
        } else {
            (
                vec![ShardStrategy::DataParallel, ShardStrategy::Pipeline],
                vec![LayoutChoice::Contiguous, LayoutChoice::Cyclic, LayoutChoice::Weighted],
                vec![1, 2, 4],
            )
        };
        let (dram_slots, disk_batch, spill_placements) = if three_tier {
            (
                vec![2, 4, 8],
                vec![1, 2, 4],
                vec![SpillPlacement::Trailing, SpillPlacement::Interleaved],
            )
        } else {
            (vec![4], vec![1], vec![SpillPlacement::Trailing])
        };
        Self {
            strategies,
            layouts,
            microbatches,
            slots: vec![2, 3, 4],
            dram_slots,
            disk_batch,
            spill_placements,
        }
    }

    fn radices(&self) -> [usize; N_AXES] {
        [
            self.strategies.len(),
            self.layouts.len(),
            self.microbatches.len(),
            self.slots.len(),
            self.dram_slots.len(),
            self.disk_batch.len(),
            self.spill_placements.len(),
        ]
    }

    /// Total number of candidates (0 if any axis is empty).
    pub fn size(&self) -> usize {
        self.radices().iter().product()
    }

    /// The candidate at mixed-radix index `i` (must be `< size()`).
    pub fn candidate_at(&self, i: usize) -> Candidate {
        let d = digits_of(i, &self.radices());
        Candidate {
            strategy: self.strategies[d[0]],
            layout: self.layouts[d[1]],
            microbatches: self.microbatches[d[2]],
            slots: self.slots[d[3]],
            dram_slots: self.dram_slots[d[4]],
            disk_batch: self.disk_batch[d[5]],
            spill_placement: self.spill_placements[d[6]],
        }
    }

    /// All candidates in index order.
    pub fn candidates(&self) -> Vec<Candidate> {
        (0..self.size()).map(|i| self.candidate_at(i)).collect()
    }
}

fn digits_of(mut i: usize, r: &[usize; N_AXES]) -> [usize; N_AXES] {
    let mut d = [0usize; N_AXES];
    for (slot, &radix) in d.iter_mut().zip(r) {
        *slot = i % radix;
        i /= radix;
    }
    d
}

fn index_of(d: &[usize; N_AXES], r: &[usize; N_AXES]) -> usize {
    let mut i = 0;
    let mut mul = 1;
    for (digit, radix) in d.iter().zip(r) {
        i += digit * mul;
        mul *= radix;
    }
    i
}

/// Indices reachable from `i` by moving one axis one position (the beam's
/// neighbourhood).
fn neighbors(i: usize, r: &[usize; N_AXES]) -> Vec<usize> {
    let d = digits_of(i, r);
    let mut out = Vec::new();
    for axis in 0..N_AXES {
        if d[axis] > 0 {
            let mut m = d;
            m[axis] -= 1;
            out.push(index_of(&m, r));
        }
        if d[axis] + 1 < r[axis] {
            let mut m = d;
            m[axis] += 1;
            out.push(index_of(&m, r));
        }
    }
    out
}

/// The oracle's answer for one candidate.
#[derive(Debug, Clone)]
pub enum Verdict {
    Feasible { step_s: f64, tokens_per_s: f64, bottleneck: String },
    Infeasible { reason: String },
}

/// A feasible candidate with its predicted performance.
#[derive(Debug, Clone)]
pub struct Evaluated {
    pub cand: Candidate,
    pub step_s: f64,
    pub tokens_per_s: f64,
    pub bottleneck: String,
}

fn infeasible(reason: impl Into<String>) -> Verdict {
    Verdict::Infeasible { reason: reason.into() }
}

/// Non-panicking mirror of the CLI's `ensure_budget_feasible`: `Some`
/// carries the pruning reason when `plan` does not fit `budget`.
fn budget_overflow(plan: &TierPlan, budget: &MemoryBudget, who: &str) -> Option<String> {
    if plan.peaks.dram > budget.dram {
        return Some(format!(
            "{who}: DDR peak {} bytes (incl. the {}-slot staging window) exceeds the \
             {}-byte --dram-budget",
            plan.peaks.dram, plan.dram_slots, budget.dram
        ));
    }
    if !budget.fits(&plan.peaks) {
        return Some(format!(
            "{who}: tier peaks {:?} do not fit the host budget {:?}",
            plan.peaks, budget
        ));
    }
    None
}

/// Price one candidate with the analytic simulator, mirroring `zo2
/// simulate`'s exact planning path so the winner replays bit-for-bit
/// through `simulate --config tuned.json`.  Every constraint the CLI
/// enforces with a hard error becomes an [`Verdict::Infeasible`] here —
/// the tuner prunes, it never panics.
pub fn evaluate(sc: &Scenario, c: &Candidate) -> Verdict {
    let devices = sc.devices();
    if devices == 0 {
        return infeasible("empty hardware list: --device-spec must name at least one device");
    }
    if c.slots == 0 || c.dram_slots == 0 || c.disk_batch == 0 || c.microbatches == 0 {
        return infeasible("slots, dram-slots, disk-batch and microbatches must all be >= 1");
    }
    let (layout, weighted) = match c.layout {
        LayoutChoice::Contiguous => (ShardLayout::Contiguous, false),
        LayoutChoice::Cyclic => (ShardLayout::Cyclic, false),
        LayoutChoice::Weighted => (ShardLayout::Contiguous, true),
    };
    if weighted && (devices == 1 || c.strategy != ShardStrategy::Pipeline) {
        return infeasible(
            "--layout weighted is a pipeline placement hint: it needs more than one device \
             with --shard pipeline",
        );
    }
    if c.microbatches > 1 && (devices == 1 || c.strategy != ShardStrategy::Pipeline) {
        return infeasible(
            "--microbatches M splits the step for pipeline sharding only: it needs more than \
             one device with --shard pipeline",
        );
    }

    let wl = &sc.wl;
    let mut policy = Policy {
        overlap: true,
        reusable_mem: true,
        efficient_update: true,
        slots: c.slots,
        disk_batch: c.disk_batch,
        spill_placement: c.spill_placement,
        dram_slots: c.dram_slots,
        ..Policy::default()
    };

    if devices > 1 {
        if sc.links.len() != devices {
            return infeasible(format!(
                "scenario lists {} link(s) for {devices} device(s)",
                sc.links.len()
            ));
        }
        let spec =
            ShardSpec { devices, layout, strategy: c.strategy, microbatches: c.microbatches };
        let cluster = Cluster { devices: sc.hw.clone(), links: sc.links.clone() };
        let costs = match ClusterCost::new(&cluster, wl) {
            Ok(cc) => cc,
            Err(e) => return infeasible(e.to_string()),
        };
        let owners: Option<Vec<usize>> = if weighted {
            let weights = bottleneck_weights(&costs, devices);
            Some(weighted_contiguous_owners(wl.shape.n_layers, &weights))
        } else {
            None
        };
        let per_dev = match &owners {
            Some(o) => blocks_per_device_of(o, devices),
            None => blocks_per_device(layout, wl.shape.n_layers, devices),
        };

        let mut tiers: Option<Vec<DeviceTier>> = None;
        if let Some(budget_bytes) = &sc.dram_budget_bytes {
            if budget_bytes.len() != devices {
                return infeasible(format!(
                    "scenario lists {} DRAM budget(s) for {devices} device(s)",
                    budget_bytes.len()
                ));
            }
            if c.strategy == ShardStrategy::Pipeline {
                let budgets: Vec<MemoryBudget> = budget_bytes
                    .iter()
                    .zip(&sc.hw)
                    .map(|(&dram, hw)| MemoryBudget { hbm: hw.hbm_capacity, dram, nvme: 2 << 40 })
                    .collect();
                let counts: Vec<usize> = per_dev.iter().map(|v| v.len()).collect();
                let hws: Vec<&Hardware> = sc.hw.iter().collect();
                let plans = plan_three_tier_owned(
                    wl,
                    &budgets,
                    &counts,
                    policy.slots,
                    c.dram_slots,
                    sc.param_bytes,
                    &hws,
                    c.spill_placement,
                );
                for (d, plan) in plans.iter().enumerate() {
                    if let Some(reason) = budget_overflow(
                        plan,
                        &budgets[d],
                        &format!("device {d} ({})", sc.hw[d].name),
                    ) {
                        return infeasible(reason);
                    }
                }
                policy.tiering = Tiering::ThreeTier;
                policy.spilled = plans.iter().map(|p| p.spilled_blocks).sum();
                tiers = Some(plans.iter().map(|p| p.device_tier()).collect());
            } else {
                // DP: one shared spill plan per replica — distinct per-host
                // budgets cannot be honoured on this path (same CLI rule).
                if !budget_bytes.windows(2).all(|w| w[0] == w[1]) {
                    return infeasible(
                        "--shard dp runs a full replica per host with one shared spill plan; \
                         distinct per-host --dram-budget values need --shard pipeline",
                    );
                }
                let hbm = match min_hbm_capacity(&sc.hw) {
                    Ok(h) => h,
                    Err(e) => return infeasible(e.to_string()),
                };
                let budget = MemoryBudget { hbm, dram: budget_bytes[0], nvme: 2 << 40 };
                let plan = plan_three_tier(
                    wl,
                    &budget,
                    policy.slots,
                    c.dram_slots,
                    sc.param_bytes,
                    &sc.hw[0],
                    c.spill_placement,
                );
                if let Some(reason) = budget_overflow(&plan, &budget, "each DP replica's host") {
                    return infeasible(reason);
                }
                policy.tiering = Tiering::ThreeTier;
                policy.spilled = plan.spilled_blocks;
                policy.dram_slots = plan.dram_slots.max(1);
            }
        }

        let plan = build_sharded_plan_tiered(
            wl.shape.n_layers,
            sc.steps,
            policy,
            &spec,
            tiers.as_deref(),
            owners.as_deref(),
        );
        let (sched, _) = simulate(&plan, &costs, policy);
        let tokens_per_step = match c.strategy {
            ShardStrategy::DataParallel => (devices * wl.batch * wl.seq) as f64,
            ShardStrategy::Pipeline => (wl.batch * wl.seq) as f64,
        };
        return Verdict::Feasible {
            step_s: sched.steady_step_s,
            tokens_per_s: tokens_per_step / sched.steady_step_s,
            bottleneck: sched.bottleneck().to_string(),
        };
    }

    // Single device (the paper's setting).
    let hw = &sc.hw[0];
    if let Some(budget_bytes) = &sc.dram_budget_bytes {
        let budget = MemoryBudget { hbm: hw.hbm_capacity, dram: budget_bytes[0], nvme: 2 << 40 };
        let plan = plan_three_tier(
            wl,
            &budget,
            policy.slots,
            c.dram_slots,
            sc.param_bytes,
            hw,
            c.spill_placement,
        );
        if let Some(reason) = budget_overflow(&plan, &budget, "this host") {
            return infeasible(reason);
        }
        policy.tiering = Tiering::ThreeTier;
        policy.spilled = plan.spilled_blocks;
        policy.dram_slots = plan.dram_slots.max(1);
    }
    let costs = SimCost::new(hw, wl);
    let plan = build_plan(wl.shape.n_layers, sc.steps, policy);
    let (sched, _) = simulate(&plan, &costs, policy);
    let tokens = (wl.batch * wl.seq) as f64;
    Verdict::Feasible {
        step_s: sched.steady_step_s,
        tokens_per_s: tokens / sched.steady_step_s,
        bottleneck: sched.bottleneck().to_string(),
    }
}

/// Search-driver knobs (all CLI flags of `zo2 tune`).
#[derive(Debug, Clone, Copy)]
pub struct TuneOpts {
    /// Seeds every random draw (`--tune-seed`); same seed + same space +
    /// same scenario → byte-identical report.
    pub seed: u64,
    /// Beam width (`--beam`).
    pub beam: usize,
    /// Annealing-fallback iterations (`--anneal-iters`).
    pub anneal_iters: usize,
    /// Frontier size in the report (`--topk`).
    pub topk: usize,
}

impl Default for TuneOpts {
    fn default() -> Self {
        Self { seed: 0, beam: 4, anneal_iters: 64, topk: 5 }
    }
}

/// Outcome of one tune run.
#[derive(Debug, Clone)]
pub struct TuneResult {
    /// Best feasible candidate (None when the whole space is infeasible).
    pub best: Option<Evaluated>,
    /// Top-k feasible candidates, best first.
    pub frontier: Vec<Evaluated>,
    /// Distinct candidates priced (feasible + pruned).
    pub explored: usize,
    /// Every pruned candidate with its reason, in enumeration order.
    pub pruned: Vec<(Candidate, String)>,
    /// Cardinality of the full space.
    pub space_size: usize,
}

fn eval_cached(
    sc: &Scenario,
    space: &SearchSpace,
    i: usize,
    cache: &mut BTreeMap<usize, Verdict>,
) -> Verdict {
    if let Some(v) = cache.get(&i) {
        return v.clone();
    }
    let v = evaluate(sc, &space.candidate_at(i));
    cache.insert(i, v.clone());
    v
}

/// Run the search: beam over single-knob neighbours from deterministic
/// probe points, then a seeded annealing pass that can cross valleys the
/// beam cannot (and is the only searcher when every beam probe lands
/// infeasible).  Fully deterministic for a given `(scenario, space, opts)`.
pub fn tune(sc: &Scenario, space: &SearchSpace, opts: &TuneOpts) -> Result<TuneResult> {
    let n = space.size();
    anyhow::ensure!(n > 0, "empty search space: every axis needs at least one value");
    let radices = space.radices();
    let beam_w = opts.beam.max(1);
    let mut rng = GaussianRng::new(opts.seed, 0x7u64);
    let mut cache: BTreeMap<usize, Verdict> = BTreeMap::new();
    let mut visited: BTreeSet<usize> = BTreeSet::new();

    // Probe points: evenly spaced across the enumeration plus seeded
    // random draws — cheap coverage before the beam starts climbing.
    let mut queue: Vec<usize> = (0..beam_w).map(|k| k * n / beam_w.max(1)).collect();
    for _ in 0..beam_w {
        queue.push(rng.next_below(n as u64) as usize);
    }
    queue.sort_unstable();
    queue.dedup();

    let mut rounds = 0usize;
    while !queue.is_empty() && rounds <= n {
        rounds += 1;
        for i in queue.drain(..) {
            if visited.insert(i) {
                eval_cached(sc, space, i, &mut cache);
            }
        }
        // Current beam: the best feasible points seen so far.
        let mut pool: Vec<(f64, usize)> = cache
            .iter()
            .filter_map(|(&i, v)| match v {
                Verdict::Feasible { step_s, .. } => Some((*step_s, i)),
                Verdict::Infeasible { .. } => None,
            })
            .collect();
        pool.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        pool.truncate(beam_w);
        let mut next: Vec<usize> = pool
            .iter()
            .flat_map(|&(_, i)| neighbors(i, &radices))
            .filter(|j| !visited.contains(j))
            .collect();
        next.sort_unstable();
        next.dedup();
        queue = next;
    }

    // Annealing fallback: random single-axis rerolls with temperature-
    // gated uphill acceptance.
    let best_of = |cache: &BTreeMap<usize, Verdict>| -> Option<(f64, usize)> {
        cache
            .iter()
            .filter_map(|(&i, v)| match v {
                Verdict::Feasible { step_s, .. } => Some((*step_s, i)),
                Verdict::Infeasible { .. } => None,
            })
            .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
    };
    let (mut cur_step, mut cur) = match best_of(&cache) {
        Some((s, i)) => (s, i),
        None => (f64::INFINITY, rng.next_below(n as u64) as usize),
    };
    let mut temp = if cur_step.is_finite() { (cur_step * 0.25).max(1e-9) } else { 1.0 };
    for _ in 0..opts.anneal_iters {
        let axis = rng.next_below(N_AXES as u64) as usize;
        let mut d = digits_of(cur, &radices);
        d[axis] = rng.next_below(radices[axis] as u64) as usize;
        let j = index_of(&d, &radices);
        visited.insert(j);
        if let Verdict::Feasible { step_s, .. } = eval_cached(sc, space, j, &mut cache) {
            let accept = step_s < cur_step
                || rng.next_uniform() < (-(step_s - cur_step) / temp.max(1e-12)).exp();
            if accept {
                cur = j;
                cur_step = step_s;
            }
        }
        temp *= 0.9;
    }

    // Assemble the result from the full evaluation cache.
    let mut feasible: Vec<(usize, Evaluated)> = cache
        .iter()
        .filter_map(|(&i, v)| match v {
            Verdict::Feasible { step_s, tokens_per_s, bottleneck } => Some((
                i,
                Evaluated {
                    cand: space.candidate_at(i),
                    step_s: *step_s,
                    tokens_per_s: *tokens_per_s,
                    bottleneck: bottleneck.clone(),
                },
            )),
            Verdict::Infeasible { .. } => None,
        })
        .collect();
    feasible.sort_by(|a, b| a.1.step_s.total_cmp(&b.1.step_s).then(a.0.cmp(&b.0)));
    let pruned: Vec<(Candidate, String)> = cache
        .iter()
        .filter_map(|(&i, v)| match v {
            Verdict::Infeasible { reason } => Some((space.candidate_at(i), reason.clone())),
            Verdict::Feasible { .. } => None,
        })
        .collect();
    let explored = cache.len();
    let best = feasible.first().map(|(_, e)| e.clone());
    let frontier: Vec<Evaluated> =
        feasible.into_iter().take(opts.topk.max(1)).map(|(_, e)| e).collect();
    Ok(TuneResult { best, frontier, explored, pruned, space_size: n })
}

/// Calibration inputs the report records (`tune --calibrate`).
#[derive(Debug, Clone, Default)]
pub struct CalibrationReport {
    /// Files fed to `--calibrate`, in the order given.
    pub files: Vec<String>,
    /// Whether host-kernel rates were loaded (and applied to the oracle).
    pub host_kernels: bool,
    /// Measured `sim_steady_step_s` gauges: `(model, devices, strategy,
    /// measured seconds)`.  Drift vs. prediction is reported when an entry
    /// matches the tuned scenario; the oracle itself is never rescaled by
    /// these (that would break `--config` replay equality).
    pub sim_gauges: Vec<(String, usize, String, f64)>,
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn s(v: impl Into<String>) -> Json {
    Json::Str(v.into())
}

fn flags_obj(flags: &BTreeMap<String, String>) -> Json {
    Json::Obj(flags.iter().map(|(k, v)| (k.clone(), s(v.clone()))).collect())
}

fn cli_of(flags: &BTreeMap<String, String>) -> String {
    let mut out = String::from("zo2 simulate");
    for (k, v) in flags {
        out.push_str(&format!(" --{k} {v}"));
    }
    out
}

fn evaluated_obj(
    e: &Evaluated,
    scenario_flags: &BTreeMap<String, String>,
) -> BTreeMap<String, Json> {
    let mut flags = scenario_flags.clone();
    flags.extend(e.cand.flags());
    BTreeMap::from([
        ("config".to_string(), s(e.cand.key())),
        ("predicted_step_s".to_string(), num(e.step_s)),
        ("predicted_tokens_per_s".to_string(), num(e.tokens_per_s)),
        ("bottleneck".to_string(), s(e.bottleneck.clone())),
        ("flags".to_string(), flags_obj(&flags)),
    ])
}

/// Render the byte-deterministic `zo2-tune-v1` report.  `scenario_flags`
/// are the CLI flags that reproduce the scenario (model, devices, budgets,
/// wire, …); each reported config merges its knob flags over them, so
/// `simulate --config tuned.json` replays the exact evaluated point.
pub fn report_json(
    sc: &Scenario,
    space: &SearchSpace,
    opts: &TuneOpts,
    result: &TuneResult,
    scenario_flags: &BTreeMap<String, String>,
    calibration: &CalibrationReport,
) -> Json {
    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), s(TUNE_SCHEMA));
    doc.insert("objective".to_string(), s("steady_step_s"));
    doc.insert("seed".to_string(), num(opts.seed as f64));

    let dram_gb: Json = match &sc.dram_budget_bytes {
        Some(b) => Json::Arr(
            b.iter().map(|&bytes| num(bytes as f64 / (1u64 << 30) as f64)).collect(),
        ),
        None => Json::Null,
    };
    doc.insert(
        "scenario".to_string(),
        Json::Obj(BTreeMap::from([
            ("model".to_string(), s(sc.wl.shape.name.clone())),
            ("devices".to_string(), num(sc.devices() as f64)),
            (
                "tiering".to_string(),
                s(if sc.three_tier() { Tiering::ThreeTier } else { Tiering::TwoTier }.name()),
            ),
            ("dram_budget_gb".to_string(), dram_gb),
            ("sim_steps".to_string(), num(sc.steps as f64)),
            ("flags".to_string(), flags_obj(scenario_flags)),
        ])),
    );
    doc.insert(
        "space".to_string(),
        Json::Obj(BTreeMap::from([
            ("size".to_string(), num(space.size() as f64)),
            (
                "strategies".to_string(),
                Json::Arr(space.strategies.iter().map(|v| s(v.name())).collect()),
            ),
            (
                "layouts".to_string(),
                Json::Arr(space.layouts.iter().map(|v| s(v.name())).collect()),
            ),
            (
                "microbatches".to_string(),
                Json::Arr(space.microbatches.iter().map(|&v| num(v as f64)).collect()),
            ),
            ("slots".to_string(), Json::Arr(space.slots.iter().map(|&v| num(v as f64)).collect())),
            (
                "dram_slots".to_string(),
                Json::Arr(space.dram_slots.iter().map(|&v| num(v as f64)).collect()),
            ),
            (
                "disk_batch".to_string(),
                Json::Arr(space.disk_batch.iter().map(|&v| num(v as f64)).collect()),
            ),
            (
                "spill_placements".to_string(),
                Json::Arr(space.spill_placements.iter().map(|v| s(v.name())).collect()),
            ),
        ])),
    );
    doc.insert(
        "search".to_string(),
        Json::Obj(BTreeMap::from([
            ("algorithm".to_string(), s("beam+anneal")),
            ("beam".to_string(), num(opts.beam.max(1) as f64)),
            ("anneal_iters".to_string(), num(opts.anneal_iters as f64)),
            ("explored".to_string(), num(result.explored as f64)),
            ("pruned".to_string(), num(result.pruned.len() as f64)),
            ("space_size".to_string(), num(result.space_size as f64)),
        ])),
    );
    doc.insert(
        "pruned_examples".to_string(),
        Json::Arr(
            result
                .pruned
                .iter()
                .take(8)
                .map(|(c, reason)| {
                    Json::Obj(BTreeMap::from([
                        ("config".to_string(), s(c.key())),
                        ("reason".to_string(), s(reason.clone())),
                    ]))
                })
                .collect(),
        ),
    );
    doc.insert(
        "best".to_string(),
        match &result.best {
            Some(e) => {
                let mut obj = evaluated_obj(e, scenario_flags);
                let mut flags = scenario_flags.clone();
                flags.extend(e.cand.flags());
                obj.insert("cli".to_string(), s(cli_of(&flags)));
                Json::Obj(obj)
            }
            None => Json::Null,
        },
    );
    doc.insert(
        "frontier".to_string(),
        Json::Arr(
            result.frontier.iter().map(|e| Json::Obj(evaluated_obj(e, scenario_flags))).collect(),
        ),
    );

    let gauges = Json::Arr(
        calibration
            .sim_gauges
            .iter()
            .map(|(model, devices, strategy, measured)| {
                // Predicted-vs-measured drift where the gauge matches the
                // tuned scenario: the best frontier point with the gauge's
                // strategy is the prediction for that row.
                let predicted = if *model == sc.wl.shape.name && *devices == sc.devices() {
                    result
                        .frontier
                        .iter()
                        .find(|e| e.cand.strategy.name() == strategy.as_str())
                        .map(|e| e.step_s)
                } else {
                    None
                };
                Json::Obj(BTreeMap::from([
                    ("model".to_string(), s(model.clone())),
                    ("devices".to_string(), num(*devices as f64)),
                    ("strategy".to_string(), s(strategy.clone())),
                    ("measured_step_s".to_string(), num(*measured)),
                    (
                        "predicted_step_s".to_string(),
                        predicted.map(num).unwrap_or(Json::Null),
                    ),
                ]))
            })
            .collect(),
    );
    doc.insert(
        "calibration".to_string(),
        Json::Obj(BTreeMap::from([
            (
                "files".to_string(),
                Json::Arr(calibration.files.iter().map(|f| s(f.clone())).collect()),
            ),
            ("host_kernels".to_string(), Json::Bool(calibration.host_kernels)),
            ("sim_gauges".to_string(), gauges),
        ])),
    );
    Json::Obj(doc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::ComputeMode;
    use crate::model::opt_by_name;
    use crate::precision::Codec;

    fn scenario(devices: usize, dram_gb: Option<u64>) -> Scenario {
        let hw: Vec<Hardware> = vec![Hardware::a100_pcie4(); devices];
        let wl = Workload {
            shape: opt_by_name("OPT-13B").unwrap(),
            batch: 1,
            seq: 2048,
            wire: Codec::Fp16,
            compute: ComputeMode::Fp16,
        };
        Scenario {
            wl,
            links: vec![Interconnect::nvlink(); devices],
            hw,
            dram_budget_bytes: dram_gb.map(|gb| vec![gb << 30; devices]),
            steps: 4,
            param_bytes: 2,
        }
    }

    #[test]
    fn mixed_radix_enumeration_round_trips() {
        let space = SearchSpace::default_for(2, true);
        let r = space.radices();
        assert_eq!(space.size(), r.iter().product::<usize>());
        for i in (0..space.size()).step_by(7) {
            assert_eq!(index_of(&digits_of(i, &r), &r), i);
        }
        // Neighbours differ in exactly one axis by exactly one position.
        for j in neighbors(17 % space.size(), &r) {
            let a = digits_of(17 % space.size(), &r);
            let b = digits_of(j, &r);
            let diffs: Vec<usize> = (0..N_AXES).filter(|&k| a[k] != b[k]).collect();
            assert_eq!(diffs.len(), 1);
            assert_eq!(a[diffs[0]].abs_diff(b[diffs[0]]), 1);
        }
    }

    #[test]
    fn evaluate_never_panics_and_prunes_structural_combos() {
        let sc = scenario(1, None);
        // Microbatches / weighted layout without a pipeline are pruned.
        let c = Candidate {
            strategy: ShardStrategy::DataParallel,
            layout: LayoutChoice::Weighted,
            microbatches: 1,
            slots: 3,
            dram_slots: 4,
            disk_batch: 1,
            spill_placement: SpillPlacement::Trailing,
        };
        assert!(matches!(evaluate(&sc, &c), Verdict::Infeasible { .. }));
        let c = Candidate { layout: LayoutChoice::Contiguous, microbatches: 2, ..c };
        assert!(matches!(evaluate(&sc, &c), Verdict::Infeasible { .. }));
        // An empty hardware list is a pruned point, not a panic — the
        // min().unwrap() regression the tuner previously could hit.
        let mut empty = scenario(2, Some(24));
        empty.hw.clear();
        empty.links.clear();
        let c = Candidate { layout: LayoutChoice::Contiguous, microbatches: 1, ..c };
        match evaluate(&empty, &c) {
            Verdict::Infeasible { reason } => assert!(reason.contains("--device-spec"), "{reason}"),
            Verdict::Feasible { .. } => panic!("empty cluster must be infeasible"),
        }
    }

    #[test]
    fn tune_is_deterministic_and_respects_the_objective() {
        let sc = scenario(2, Some(24));
        let space = SearchSpace::default_for(2, true);
        let opts = TuneOpts { seed: 11, beam: 3, anneal_iters: 24, topk: 4 };
        let a = tune(&sc, &space, &opts).unwrap();
        let b = tune(&sc, &space, &opts).unwrap();
        assert_eq!(a.explored, b.explored);
        assert_eq!(a.pruned.len(), b.pruned.len());
        let ea = a.best.as_ref().expect("a feasible point exists");
        let eb = b.best.as_ref().unwrap();
        assert_eq!(ea.cand, eb.cand);
        assert_eq!(ea.step_s.to_bits(), eb.step_s.to_bits());
        // The frontier is sorted by the objective and bounded by topk.
        assert!(a.frontier.len() <= 4 && !a.frontier.is_empty());
        for w in a.frontier.windows(2) {
            assert!(w[0].step_s <= w[1].step_s);
        }
        // The reported best is exactly reproducible through the oracle.
        match evaluate(&sc, &ea.cand) {
            Verdict::Feasible { step_s, .. } => assert_eq!(step_s.to_bits(), ea.step_s.to_bits()),
            Verdict::Infeasible { reason } => panic!("best became infeasible: {reason}"),
        }
    }

    #[test]
    fn pruned_points_reproduce_their_infeasibility() {
        // A 1 GB budget on OPT-13B×2 prunes real points (deep windows that
        // cannot fit); every recorded prune must reproduce.
        let sc = scenario(2, Some(1));
        let space = SearchSpace::default_for(2, true);
        let r = tune(&sc, &space, &TuneOpts { seed: 3, ..TuneOpts::default() }).unwrap();
        assert!(!r.pruned.is_empty(), "expected infeasible points at a 1 GB budget");
        for (cand, reason) in &r.pruned {
            match evaluate(&sc, cand) {
                Verdict::Infeasible { reason: again } => assert_eq!(&again, reason),
                Verdict::Feasible { .. } => panic!("pruned {} re-evaluates feasible", cand.key()),
            }
        }
    }

    #[test]
    fn report_is_byte_deterministic_and_parses() {
        let sc = scenario(2, Some(24));
        let space = SearchSpace::default_for(2, true);
        let opts = TuneOpts { seed: 5, beam: 2, anneal_iters: 12, topk: 3 };
        let flags: BTreeMap<String, String> = BTreeMap::from([
            ("model".to_string(), "OPT-13B".to_string()),
            ("devices".to_string(), "2".to_string()),
            ("tiering".to_string(), "three".to_string()),
            ("dram-budget".to_string(), "24".to_string()),
            ("wire".to_string(), "fp16".to_string()),
            ("compute".to_string(), "fp16".to_string()),
        ]);
        let cal = CalibrationReport {
            files: vec!["BENCH_multi_gpu.json".to_string()],
            host_kernels: false,
            sim_gauges: vec![("OPT-13B".to_string(), 2, "dp".to_string(), 1.5)],
        };
        let r1 = tune(&sc, &space, &opts).unwrap();
        let r2 = tune(&sc, &space, &opts).unwrap();
        let j1 = report_json(&sc, &space, &opts, &r1, &flags, &cal).to_string_pretty();
        let j2 = report_json(&sc, &space, &opts, &r2, &flags, &cal).to_string_pretty();
        assert_eq!(j1, j2, "same seed + space must render byte-identical reports");
        let doc = Json::parse(&j1).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), TUNE_SCHEMA);
        let best = doc.get("best").unwrap();
        let replay = best.get("flags").unwrap().as_obj().unwrap();
        assert_eq!(replay.get("model").unwrap().as_str().unwrap(), "OPT-13B");
        assert!(replay.contains_key("shard") && replay.contains_key("slots"));
        assert!(best.get("cli").unwrap().as_str().unwrap().starts_with("zo2 simulate --"));
    }
}
