//! Multi-device sharding of the ZO2 schedule (simulated multi-GPU).
//!
//! ZO2 targets one constrained GPU, but its stream DAG generalises directly
//! to N devices, and ZO's gradient is uniquely cheap to data-parallelise:
//! workers only need to agree on the perturbation seed and exchange one
//! projected-gradient scalar per step (the ZO benchmark survey's point
//! about ZO's communication advantage over first-order DP).  This module
//! partitions transformer blocks across simulated devices and builds
//! device-indexed task DAGs for two execution strategies:
//!
//! * **Pipeline sharding** ([`ShardStrategy::Pipeline`]): blocks are
//!   partitioned across devices ([`ShardLayout::Contiguous`] ranges or
//!   [`ShardLayout::Cyclic`] round-robin); the dual-path hidden state flows
//!   device-to-device over [`StreamKind::Interconnect`], and each device's
//!   CPU↔GPU traffic covers only its own blocks — the PCIe load divides
//!   across the hosts' lanes.  The per-device slot rings let device 0 start
//!   step *j+1* while later devices finish step *j* (cross-step
//!   pipelining); the projected gradient of step *j* is broadcast from the
//!   head device before any device's step *j+1* compute applies its
//!   deferred update.
//!
//!   With [`ShardSpec::microbatches`] `M > 1` the step's batch additionally
//!   splits into M **microbatches** so devices overlap *within* a step:
//!   each block still moves once per step (one U, one O — the slot ring,
//!   DRAM window and the PCIe/NVMe load are untouched by M), but its
//!   compute splits into M per-microbatch slices and every ownership
//!   change hops M smaller activations, so device *d+1* computes
//!   microbatch *i* while device *d* is already on microbatch *i+1*.  The
//!   per-step wire contract is unchanged: still exactly one g broadcast
//!   per step, after the last microbatch's head.
//! * **Seed-synchronous data parallelism** ([`ShardStrategy::DataParallel`]):
//!   each device runs the *full* single-device ZO2 pipeline on its own
//!   batch shard.  Per-step communication is exactly one seed broadcast
//!   plus one scalar all-reduce on the interconnect stream — uploads for
//!   the next step may prefetch before the all-reduce lands, only the first
//!   *compute* of the next step waits for it (the deferred update needs ḡ).
//!   (Batch slicing for DP is the engine's `--dp-shards`, not
//!   `microbatches`, which is a pipeline-only knob.)
//!
//! `N = 1` is the degenerate case of the same builder — both strategies
//! emit no interconnect tasks and collapse to the paper's single-GPU
//! schedule, byte-for-byte (this is what [`crate::sched::build_plan`]
//! calls; asserted against a frozen pre-refactor copy in
//! `tests/sched_golden_v1.rs`).  Likewise `M = 1` is the degenerate case
//! of the microbatched pipeline builder, asserted byte-identical to a
//! frozen copy of the pre-microbatching multi-device builder in the same
//! test file.
//!
//! Three-tier spill sets can be **per-partition**
//! ([`build_sharded_plan_spilled`]): pipeline device *d* spills
//! `per_device_spilled[d]` of *its own* blocks, positioned by
//! `policy.spill_placement` within its owned list — sized by
//! [`crate::costmodel::plan_three_tier_partitioned`] against each host's
//! own DRAM budget.

use crate::sched::{
    is_spilled_block, CostProvider, DeviceId, Microbatch, Module, Policy, StreamId, StreamKind,
    Task, TaskKind, Tiering,
};

/// How blocks map to devices under pipeline sharding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardLayout {
    /// Balanced contiguous ranges: device d owns blocks
    /// `[d·n/N, (d+1)·n/N)`; activations cross the link N−1 times per step.
    Contiguous,
    /// Round-robin: block i on device i mod N; activations cross the link
    /// at (almost) every block boundary — the layout ablation that shows
    /// placement matters.
    Cyclic,
}

impl ShardLayout {
    /// The canonical CLI spelling (`--layout contiguous|cyclic`).
    pub fn name(self) -> &'static str {
        match self {
            ShardLayout::Contiguous => "contiguous",
            ShardLayout::Cyclic => "cyclic",
        }
    }

    /// Parse a CLI spelling (aliases included).  `weighted` is not a
    /// `ShardLayout` — it is contiguous placement plus an owner hint; the
    /// CLI and the tuner model it separately.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "contiguous" | "block" => Some(ShardLayout::Contiguous),
            "cyclic" | "roundrobin" => Some(ShardLayout::Cyclic),
            _ => None,
        }
    }
}

/// Execution strategy across devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardStrategy {
    /// Model-parallel: blocks partitioned, activations pipelined.
    Pipeline,
    /// Seed-synchronous data-parallel: full model per device, batch
    /// sharded, one seed broadcast + one scalar all-reduce per step.
    DataParallel,
}

impl ShardStrategy {
    /// The canonical CLI spelling (`--shard dp|pipeline`).
    pub fn name(self) -> &'static str {
        match self {
            ShardStrategy::Pipeline => "pipeline",
            ShardStrategy::DataParallel => "dp",
        }
    }

    /// Parse a CLI spelling (aliases included).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "dp" | "data-parallel" => Some(ShardStrategy::DataParallel),
            "pipeline" | "pp" => Some(ShardStrategy::Pipeline),
            _ => None,
        }
    }
}

/// A sharding configuration: how many devices, which layout, which
/// execution strategy, and (pipeline only) how many intra-step
/// microbatches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub devices: usize,
    pub layout: ShardLayout,
    pub strategy: ShardStrategy,
    /// Intra-step pipeline microbatches (`M`); 1 = un-microbatched (the
    /// pre-microbatching schedule, byte-for-byte).  Ignored by
    /// [`ShardStrategy::DataParallel`].
    pub microbatches: usize,
}

impl ShardSpec {
    /// The single-device degenerate case (what [`crate::sched::build_plan`]
    /// uses): layout and strategy are irrelevant at N = 1.
    pub fn single() -> Self {
        Self {
            devices: 1,
            layout: ShardLayout::Contiguous,
            strategy: ShardStrategy::Pipeline,
            microbatches: 1,
        }
    }

    pub fn pipeline(devices: usize, layout: ShardLayout) -> Self {
        Self {
            devices: devices.max(1),
            layout,
            strategy: ShardStrategy::Pipeline,
            microbatches: 1,
        }
    }

    /// Pipeline sharding with `microbatches` intra-step slices
    /// (CLI `--microbatches M`).
    pub fn pipeline_microbatched(devices: usize, layout: ShardLayout, microbatches: usize) -> Self {
        Self {
            devices: devices.max(1),
            layout,
            strategy: ShardStrategy::Pipeline,
            microbatches: microbatches.max(1),
        }
    }

    pub fn data_parallel(devices: usize) -> Self {
        Self {
            devices: devices.max(1),
            layout: ShardLayout::Contiguous,
            strategy: ShardStrategy::DataParallel,
            microbatches: 1,
        }
    }
}

/// Owning device of block `i` under `layout` (0 when `devices <= 1`).
pub fn block_owner(layout: ShardLayout, n_blocks: usize, devices: usize, i: usize) -> usize {
    let devices = devices.max(1);
    match layout {
        ShardLayout::Contiguous => i * devices / n_blocks.max(1),
        ShardLayout::Cyclic => i % devices,
    }
}

/// Blocks owned by each device (index = device), for reporting and memory
/// accounting.
pub fn blocks_per_device(layout: ShardLayout, n_blocks: usize, devices: usize) -> Vec<Vec<usize>> {
    let devices = devices.max(1);
    let mut per: Vec<Vec<usize>> = vec![Vec::new(); devices];
    for i in 0..n_blocks {
        per[block_owner(layout, n_blocks, devices, i)].push(i);
    }
    per
}

/// Blocks owned by each device under an explicit owner map
/// (`owners[i]` = owning device of block `i`).
pub fn blocks_per_device_of(owners: &[usize], devices: usize) -> Vec<Vec<usize>> {
    let mut per: Vec<Vec<usize>> = vec![Vec::new(); devices.max(1)];
    for (i, &d) in owners.iter().enumerate() {
        per[d].push(i);
    }
    per
}

/// Per-device three-tier parameters resolved by the partitioned planner
/// ([`crate::costmodel::plan_three_tier_partitioned`] /
/// `plan_three_tier_owned`): how many of the device's *own* blocks spill
/// and how deep its DRAM staging window is.  Carrying the window depth per
/// device (instead of collapsing all plans into one `Policy::dram_slots`)
/// keeps a small-budget host's prefetch look-ahead honest while an ample
/// sibling keeps the full window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceTier {
    /// Blocks of this device's partition spilled to its NVMe tier.
    pub spilled: usize,
    /// This host's DRAM staging-window depth (0 when nothing spills).
    pub dram_slots: usize,
}

/// Bottleneck-aware layout hint: contiguous block counts proportional to
/// `weights` (largest-remainder apportionment; ties to the lower device).
/// Use a device's block-round throughput as its weight
/// ([`bottleneck_weights`]) to put more blocks on faster devices — the
/// heterogeneous-cluster placement the `multi_gpu` bench quantifies.
/// Ownership is monotone like [`ShardLayout::Contiguous`], so activation
/// hops stay at device-count − 1 per step.
pub fn weighted_contiguous_owners(n_blocks: usize, weights: &[f64]) -> Vec<usize> {
    let devices = weights.len().max(1);
    let total: f64 = weights.iter().map(|w| w.max(0.0)).sum();
    if devices == 1 || total <= 0.0 {
        return (0..n_blocks)
            .map(|i| block_owner(ShardLayout::Contiguous, n_blocks, devices, i))
            .collect();
    }
    let shares: Vec<f64> =
        weights.iter().map(|w| w.max(0.0) / total * n_blocks as f64).collect();
    let mut counts: Vec<usize> = shares.iter().map(|s| s.floor() as usize).collect();
    let mut rem = n_blocks - counts.iter().sum::<usize>();
    let mut order: Vec<usize> = (0..devices).collect();
    order.sort_by(|&a, &b| {
        let fa = shares[a] - shares[a].floor();
        let fb = shares[b] - shares[b].floor();
        fb.total_cmp(&fa).then(a.cmp(&b))
    });
    for &d in &order {
        if rem == 0 {
            break;
        }
        counts[d] += 1;
        rem -= 1;
    }
    let mut owners = Vec::with_capacity(n_blocks);
    for (d, &c) in counts.iter().enumerate() {
        owners.extend(std::iter::repeat(d).take(c));
    }
    owners
}

/// Per-device weight for [`weighted_contiguous_owners`]: the inverse of the
/// device's block-round critical time (the slowest of its compute, upload
/// and offload paths for one block) under `costs`.  On a homogeneous
/// cluster all weights are equal and the hint reduces to the balanced
/// contiguous layout.
pub fn bottleneck_weights(costs: &dyn CostProvider, devices: usize) -> Vec<f64> {
    (0..devices.max(1))
        .map(|d| {
            let dev = DeviceId(d);
            let round = costs
                .compute_s_on(dev, Module::Block(0))
                .max(costs.upload_s_on(dev) + costs.host_decode_s_on(dev))
                .max(costs.offload_s_on(dev) + costs.host_encode_s_on(dev));
            if round > 0.0 {
                1.0 / round
            } else {
                1.0
            }
        })
        .collect()
}

/// Per-device scheduler lane: the stream cursors and resource rings of one
/// device (its reusable-buffer slot ring and DRAM staging window).
struct Lane {
    device: DeviceId,
    /// Last task id per stream kind, for FIFO chaining.
    last_on: [Option<usize>; 6],
    /// id of O(Wᵢ) per in-flight reusable-buffer slot.
    offload_ring: Vec<Option<usize>>,
    ring_pos: usize,
    /// id of W(Wᵢ) per DRAM staging-window slot (three-tier).
    dram_ring: Vec<Option<usize>>,
    dram_pos: usize,
    /// id of the previous *compute* task on this device (cudaMalloc sync
    /// in the no-reusable-memory ablation).
    prev_compute: Option<usize>,
    /// id of this device's last task (naive per-device global sync).
    prev_any: Option<usize>,
}

impl Lane {
    /// `dram_slots` is this device's own staging-window depth — the
    /// per-partition planner hands small-budget hosts a smaller window than
    /// their siblings (callers without per-device plans pass
    /// `policy.dram_slots`).
    fn new(device: usize, policy: &Policy, dram_slots: usize) -> Self {
        Self {
            device: DeviceId(device),
            last_on: [None; 6],
            offload_ring: vec![None; policy.slots.max(1)],
            ring_pos: 0,
            dram_ring: vec![None; dram_slots.max(1)],
            dram_pos: 0,
            prev_compute: None,
            prev_any: None,
        }
    }
}

/// Accumulates the task list, applying the dependency rules shared by all
/// strategies: per-stream FIFO, naive per-device global sync, backward-only
/// deps.
struct PlanBuilder {
    tasks: Vec<Task>,
    policy: Policy,
}

impl PlanBuilder {
    fn new(policy: Policy) -> Self {
        Self { tasks: Vec::new(), policy }
    }

    #[allow(clippy::too_many_arguments)]
    fn push(
        &mut self,
        lane: &mut Lane,
        step: usize,
        module: Module,
        kind: TaskKind,
        mut deps: Vec<usize>,
        extra_latency: f64,
        microbatch: Option<Microbatch>,
    ) -> usize {
        let stream_kind = if self.policy.overlap {
            kind.stream_kind()
        } else {
            StreamKind::Compute // naive: one stream per device serialises everything
        };
        let stream = StreamId { device: lane.device, kind: stream_kind };
        let id = self.tasks.len();
        // Stream FIFO.
        if let Some(p) = lane.last_on[stream_kind.index()] {
            deps.push(p);
        }
        // Naive global sync, per device: a device syncs after each of *its*
        // tasks (on one device this is every task — the original ablation —
        // while sibling devices of a sharded plan stay independent hardware;
        // cross-device ordering still comes from the explicit link deps).
        if !self.policy.overlap {
            if let Some(p) = lane.prev_any {
                deps.push(p);
            }
        }
        deps.sort_unstable();
        deps.dedup();
        self.tasks.push(Task { id, step, module, kind, stream, deps, extra_latency, microbatch });
        lane.last_on[stream_kind.index()] = Some(id);
        lane.prev_any = Some(id);
        if matches!(kind, TaskKind::Compute | TaskKind::Update) {
            lane.prev_compute = Some(id);
        }
        id
    }

    /// Emit one block round's transfer prologue — [R] U — wiring the
    /// slot-ring / DRAM-window / read-after-write rules; returns the upload
    /// task's id (the dependency of the round's first compute).
    fn begin_block_round(
        &mut self,
        lane: &mut Lane,
        step: usize,
        block: usize,
        on_disk: bool,
        last_write: &mut Option<usize>,
    ) -> usize {
        let module = Module::Block(block);
        let mut deps = Vec::new();
        // Three-tier: R(Wᵢ) stages the spilled bucket into the DRAM window
        // before the upload can push it over PCIe.
        if on_disk {
            let mut rdeps = Vec::new();
            // DRAM-window rule: R needs a free staging slot, freed by the W
            // that ran `dram_slots` spills earlier.
            if let Some(w) = lane.dram_ring[lane.dram_pos] {
                rdeps.push(w);
            }
            // Read-after-write: the on-disk bucket is the one the previous
            // step's W wrote back.
            if let Some(w) = *last_write {
                rdeps.push(w);
            }
            let r = self.push(lane, step, module, TaskKind::DiskRead, rdeps, 0.0, None);
            deps.push(r);
        }
        // Slot reuse: U waits for the offload that frees this slot.
        if let Some(o) = lane.offload_ring[lane.ring_pos] {
            deps.push(o);
        }
        if !self.policy.reusable_mem {
            // cudaMalloc synchronises with the device: the upload cannot
            // overlap in-flight compute.
            if let Some(c) = lane.prev_compute {
                deps.push(c);
            }
        }
        self.push(lane, step, module, TaskKind::Upload, deps, 0.0, None)
    }

    /// Emit one block round's epilogue — O [W] — after the round's last
    /// compute `last_compute`, advancing the slot ring and DRAM window.
    fn end_block_round(
        &mut self,
        lane: &mut Lane,
        step: usize,
        block: usize,
        on_disk: bool,
        last_write: &mut Option<usize>,
        last_compute: usize,
    ) {
        let module = Module::Block(block);
        let o = self.push(lane, step, module, TaskKind::Offload, vec![last_compute], 0.0, None);
        lane.offload_ring[lane.ring_pos] = Some(o);
        lane.ring_pos = (lane.ring_pos + 1) % lane.offload_ring.len();

        // W(Wᵢ) ← O(Wᵢ): write the updated bucket back to NVMe and free its
        // DRAM staging slot.
        if on_disk {
            let w = self.push(lane, step, module, TaskKind::DiskWrite, vec![o], 0.0, None);
            lane.dram_ring[lane.dram_pos] = Some(w);
            lane.dram_pos = (lane.dram_pos + 1) % lane.dram_ring.len();
            *last_write = Some(w);
        }
    }

    /// Emit one block's round — [R] U C(kind `compute_kind`) O [W] — on
    /// `lane`, wiring the slot-ring / DRAM-window / read-after-write rules.
    /// `compute_extra_deps` are added to the compute task (activation
    /// handoff, gradient broadcast); returns the compute task's id.
    #[allow(clippy::too_many_arguments)]
    fn push_block_round(
        &mut self,
        lane: &mut Lane,
        step: usize,
        block: usize,
        on_disk: bool,
        last_write: &mut Option<usize>,
        compute_kind: TaskKind,
        compute_extra_deps: &[usize],
    ) -> usize {
        let u = self.begin_block_round(lane, step, block, on_disk, last_write);
        let mut cdeps = vec![u];
        cdeps.extend_from_slice(compute_extra_deps);
        let c = self.push(lane, step, Module::Block(block), compute_kind, cdeps, 0.0, None);
        self.end_block_round(lane, step, block, on_disk, last_write, c);
        c
    }
}

/// Build the device-indexed task DAG for `spec` over `steps` training steps
/// of `n_blocks` offloaded transformer blocks.  With `spec.devices == 1`
/// both strategies reduce to the single-GPU schedule of
/// [`crate::sched::build_plan`].
pub fn build_sharded_plan(
    n_blocks: usize,
    steps: usize,
    policy: Policy,
    spec: &ShardSpec,
) -> Vec<Task> {
    build_sharded_plan_spilled(n_blocks, steps, policy, spec, None)
}

/// [`build_sharded_plan`] with an explicit **per-partition** three-tier
/// spill set: pipeline device `d` spills `per_device_spilled[d]` of its own
/// blocks, positioned by `policy.spill_placement` *within its owned list*
/// (the per-device plans come from
/// [`crate::costmodel::plan_three_tier_partitioned`], which sizes each
/// partition against its own host's DRAM budget).  `None` keeps the global
/// `policy.spilled` set.  Every device keeps the global `policy.dram_slots`
/// window — use [`build_sharded_plan_tiered`] to carry per-device window
/// depths too.  Data-parallel plans ignore the per-device vector: every DP
/// replica holds the full model against its own host's budget, so the
/// global (single-replica) spill plan applies per device as-is.
pub fn build_sharded_plan_spilled(
    n_blocks: usize,
    steps: usize,
    policy: Policy,
    spec: &ShardSpec,
    per_device_spilled: Option<&[usize]>,
) -> Vec<Task> {
    let tiers: Option<Vec<DeviceTier>> = per_device_spilled.map(|sp| {
        sp.iter().map(|&s| DeviceTier { spilled: s, dram_slots: policy.dram_slots }).collect()
    });
    build_sharded_plan_tiered(n_blocks, steps, policy, spec, tiers.as_deref(), None)
}

/// The general pipeline entry point behind `build_sharded_plan*`:
///
/// * `tiers` — per-device three-tier parameters (spill count **and** DRAM
///   staging-window depth per host), from the per-partition planner.
///   `None` keeps the global `policy.spilled` / `policy.dram_slots`.
/// * `owners` — explicit block→device map overriding `spec.layout`
///   (the bottleneck-aware layout hint, e.g.
///   [`weighted_contiguous_owners`]).  `None` keeps the layout's owner rule.
///
/// Data-parallel plans ignore both (full replica per device).  Mis-sized
/// slices panic: a stale vector would silently mis-place blocks or
/// under-spill a device and report an optimistic schedule.
pub fn build_sharded_plan_tiered(
    n_blocks: usize,
    steps: usize,
    policy: Policy,
    spec: &ShardSpec,
    tiers: Option<&[DeviceTier]>,
    owners: Option<&[usize]>,
) -> Vec<Task> {
    let devices = spec.devices.max(1);
    if let Some(tv) = tiers {
        assert_eq!(tv.len(), devices, "tiers must have one entry per device");
    }
    if let Some(o) = owners {
        assert_eq!(o.len(), n_blocks, "owners must name every block's device");
        assert!(o.iter().all(|&d| d < devices), "owner out of range");
    }
    let tasks = match spec.strategy {
        ShardStrategy::Pipeline => pipeline_plan(
            n_blocks,
            steps,
            policy,
            devices,
            spec.layout,
            spec.microbatches.max(1),
            tiers,
            owners,
        ),
        ShardStrategy::DataParallel => dp_plan(n_blocks, steps, policy, devices),
    };
    // Debug builds statically re-check every plan the builders emit against
    // the scheduling contract (linear in tasks + deps); release builds get
    // the same sweep on demand via `zo2 lint --plans`.
    #[cfg(debug_assertions)]
    {
        let dram: Option<Vec<usize>> = match spec.strategy {
            // DP replicas always use the global window depth; per-device
            // tiers only steer pipeline partitions.
            ShardStrategy::Pipeline => {
                tiers.map(|tv| tv.iter().map(|t| t.dram_slots).collect())
            }
            ShardStrategy::DataParallel => None,
        };
        if let Err(errs) = crate::sched::validate_plan(&tasks, &policy, dram.as_deref()) {
            panic!(
                "plan builder violated the scheduling contract ({} finding{}):\n{}",
                errs.len(),
                if errs.len() == 1 { "" } else { "s" },
                errs.join("\n")
            );
        }
    }
    tasks
}

fn spilled_count(policy: &Policy, n_blocks: usize) -> usize {
    match policy.tiering {
        Tiering::TwoTier => 0,
        Tiering::ThreeTier => policy.spilled.min(n_blocks),
    }
}

/// Pipeline-sharded plan: blocks partitioned by `layout`, embedding on the
/// first device, LM head on the last block's owner, activations crossing
/// the interconnect at every ownership change.
///
/// With `microbatches > 1` every compute splits into per-microbatch slices
/// and every ownership change hops one activation *per microbatch*;
/// uploads, offloads and the disk chain stay once-per-block-per-step
/// (weights do not change within a step), so the slot-ring and DRAM-window
/// resource rules are untouched.  Emission stays block-major — a block's M
/// compute slices run back-to-back on its owner — which keeps the schedule
/// memory-true under any slot count: the overlap comes from *boundary*
/// blocks, whose downstream consumer starts on microbatch i while the
/// sender computes microbatch i+1.
#[allow(clippy::too_many_arguments)]
fn pipeline_plan(
    n_blocks: usize,
    steps: usize,
    policy: Policy,
    devices: usize,
    layout: ShardLayout,
    microbatches: usize,
    tiers: Option<&[DeviceTier]>,
    owners: Option<&[usize]>,
) -> Vec<Task> {
    let m_count = microbatches.max(1);
    // Microbatch tag: `None` at M = 1 so un-microbatched plans are
    // byte-identical to the pre-microbatching builder (and the simulator
    // prices them through the exact same code path).
    let mb = |m: usize| {
        if m_count > 1 {
            Some(Microbatch { index: m, of: m_count })
        } else {
            None
        }
    };
    let mut b = PlanBuilder::new(policy);
    // Each lane's staging window is its own host's: per-partition plans
    // size it per device, everything else keeps the global policy depth.
    let lane_dram = |d: usize| tiers.map_or(policy.dram_slots, |tv| tv[d].dram_slots);
    let mut lanes: Vec<Lane> =
        (0..devices).map(|d| Lane::new(d, &policy, lane_dram(d))).collect();
    let mut last_write: Vec<Option<usize>> = vec![None; n_blocks];
    let global_spilled = spilled_count(&policy, n_blocks);
    let owner = |i: usize| match owners {
        Some(o) => o[i],
        None => block_owner(layout, n_blocks, devices, i),
    };
    let per_dev_blocks = match owners {
        Some(o) => blocks_per_device_of(o, devices),
        None => blocks_per_device(layout, n_blocks, devices),
    };
    let on_disk = |i: usize| -> bool {
        match tiers {
            None => is_spilled_block(i, n_blocks, global_spilled, policy.spill_placement),
            Some(tv) => {
                if policy.tiering != Tiering::ThreeTier {
                    return false;
                }
                // Per-partition spill set: the placement rule applies to
                // block i's rank within its owner's list, against that
                // device's own spill count.
                let d = owner(i);
                let list = &per_dev_blocks[d];
                let rank = list
                    .iter()
                    .position(|&j| j == i)
                    .expect("owner lists cover every block");
                is_spilled_block(
                    rank,
                    list.len(),
                    tv.get(d).map_or(0, |t| t.spilled),
                    policy.spill_placement,
                )
            }
        }
    };
    let head_dev = if n_blocks == 0 { 0 } else { owner(n_blocks - 1) };
    // Projected-gradient broadcast of the previous step (devices > 1 only):
    // a device's first compute of step j+1 applies the deferred update, so
    // it must wait for g_j to arrive from the head device.
    let mut grad_bcast: Option<usize> = None;

    for step in 0..steps {
        // C(Embedding) — resident on the first device, no upload; one
        // compute slice per microbatch, the first gated on g (the deferred
        // update), the rest chained by the compute-stream FIFO.
        let mut prev_c: Vec<usize> = Vec::with_capacity(m_count);
        for m in 0..m_count {
            let mut edeps = Vec::new();
            if m == 0 {
                if let Some(g) = grad_bcast {
                    edeps.push(g);
                }
            }
            let c =
                b.push(&mut lanes[0], step, Module::Embed, TaskKind::Compute, edeps, 0.0, mb(m));
            prev_c.push(c);
        }
        let mut prev_dev = 0usize;
        // Which devices already gated their first compute on the broadcast.
        let mut gated = vec![false; devices];
        gated[0] = true;

        // Upload of block 0 may overlap the embedding compute (§5.2).
        for i in 0..n_blocks {
            let d = owner(i);
            let cross = d != prev_dev;
            // Activation handoff when the previous module ran elsewhere:
            // the dual-path hidden state crosses the link per microbatch,
            // charged on the sender's interconnect stream.  The first
            // microbatch's hop is emitted before the round's R/U so the
            // M = 1 sequence is the pre-microbatching plan byte-for-byte.
            let act0 = if cross {
                b.push(
                    &mut lanes[prev_dev],
                    step,
                    Module::Block(i),
                    TaskKind::ActivationXfer,
                    vec![prev_c[0]],
                    0.0,
                    mb(0),
                )
            } else {
                prev_c[0]
            };
            let u = b.begin_block_round(&mut lanes[d], step, i, on_disk(i), &mut last_write[i]);
            let mut cdeps = vec![u, act0];
            if !gated[d] {
                if let Some(g) = grad_bcast {
                    cdeps.push(g);
                }
                gated[d] = true;
            }
            let mut cs: Vec<usize> = Vec::with_capacity(m_count);
            cs.push(b.push(
                &mut lanes[d],
                step,
                Module::Block(i),
                TaskKind::Compute,
                cdeps,
                0.0,
                mb(0),
            ));
            for m in 1..m_count {
                let act = if cross {
                    b.push(
                        &mut lanes[prev_dev],
                        step,
                        Module::Block(i),
                        TaskKind::ActivationXfer,
                        vec![prev_c[m]],
                        0.0,
                        mb(m),
                    )
                } else {
                    prev_c[m]
                };
                cs.push(b.push(
                    &mut lanes[d],
                    step,
                    Module::Block(i),
                    TaskKind::Compute,
                    vec![act],
                    0.0,
                    mb(m),
                ));
            }
            let last_c = *cs.last().unwrap();
            b.end_block_round(&mut lanes[d], step, i, on_disk(i), &mut last_write[i], last_c);
            prev_c = cs;
            prev_dev = d;
        }

        // C(LMHead) — resident on the last block's device (= prev_dev after
        // the loop, so the head never needs an activation hop of its own);
        // per-microbatch slices chained by FIFO.
        let mut c_head = 0usize;
        for (m, &p) in prev_c.iter().enumerate() {
            c_head = b.push(
                &mut lanes[head_dev],
                step,
                Module::Head,
                TaskKind::Compute,
                vec![p],
                0.0,
                mb(m),
            );
        }

        // g of this step — known only after the *last* microbatch's head —
        // announced to every device (needed both by the next step's
        // deferred updates and by the non-efficient-update ablation's
        // standalone round below).  One broadcast per step regardless of M:
        // the wire contract stays seed + one scalar.
        if devices > 1 {
            grad_bcast = Some(b.push(
                &mut lanes[head_dev],
                step,
                Module::Head,
                TaskKind::GradReduce,
                vec![c_head],
                0.0,
                None,
            ));
        }

        if !policy.efficient_update {
            // Fig. 5a: a second upload→update→offload round per block, after
            // the step's projected gradient is known (i.e. after the head).
            // The update is a per-parameter pass — never microbatched.
            let g_dep = grad_bcast;
            let mut upd_gated = vec![false; devices];
            upd_gated[head_dev] = true; // head device's FIFO already orders it
            for i in 0..n_blocks {
                let d = owner(i);
                let mut extra = Vec::new();
                if !upd_gated[d] {
                    if let Some(g) = g_dep {
                        extra.push(g);
                    }
                    upd_gated[d] = true;
                }
                b.push_block_round(
                    &mut lanes[d],
                    step,
                    i,
                    on_disk(i),
                    &mut last_write[i],
                    TaskKind::Update,
                    &extra,
                );
            }
        }
    }
    b.tasks
}

/// Seed-synchronous data-parallel plan: every device runs the full
/// single-device schedule on its batch shard; per step the link carries one
/// seed broadcast (before any perturbation) and one scalar all-reduce
/// (after every device's head).
fn dp_plan(n_blocks: usize, steps: usize, policy: Policy, devices: usize) -> Vec<Task> {
    if devices <= 1 {
        return pipeline_plan(n_blocks, steps, policy, 1, ShardLayout::Contiguous, 1, None, None);
    }
    let mut b = PlanBuilder::new(policy);
    let mut lanes: Vec<Lane> =
        (0..devices).map(|d| Lane::new(d, &policy, policy.dram_slots)).collect();
    // Each device owns a full replica: per-device read-after-write chains.
    let mut last_write: Vec<Vec<Option<usize>>> = vec![vec![None; n_blocks]; devices];
    let spilled = spilled_count(&policy, n_blocks);
    let on_disk = |i: usize| is_spilled_block(i, n_blocks, spilled, policy.spill_placement);
    let mut grad_reduce: Option<usize> = None;

    for step in 0..steps {
        // Seed broadcast on the link: workers agree on the step's
        // perturbation seed before anything perturbs (8 bytes).
        let mut sdeps = Vec::new();
        if let Some(g) = grad_reduce {
            sdeps.push(g);
        }
        let seed =
            b.push(&mut lanes[0], step, Module::Embed, TaskKind::SeedBcast, sdeps, 0.0, None);

        let mut heads = Vec::with_capacity(devices);
        for d in 0..devices {
            let mut edeps = vec![seed];
            // The deferred update fused into this step's computes needs the
            // all-reduced ḡ of the previous step.
            if let Some(g) = grad_reduce {
                edeps.push(g);
            }
            let c_embed =
                b.push(&mut lanes[d], step, Module::Embed, TaskKind::Compute, edeps, 0.0, None);
            let mut prev_c = c_embed;
            for i in 0..n_blocks {
                let c = b.push_block_round(
                    &mut lanes[d],
                    step,
                    i,
                    on_disk(i),
                    &mut last_write[d][i],
                    TaskKind::Compute,
                    &[prev_c],
                );
                prev_c = c;
            }
            let c_head = b.push(
                &mut lanes[d],
                step,
                Module::Head,
                TaskKind::Compute,
                vec![prev_c],
                0.0,
                None,
            );
            heads.push(c_head);
        }

        // One scalar all-reduce joins every worker's projected gradient.
        grad_reduce = Some(b.push(
            &mut lanes[0],
            step,
            Module::Head,
            TaskKind::GradReduce,
            heads,
            0.0,
            None,
        ));

        if !policy.efficient_update {
            // Fig. 5a ablation, DP form: every replica applies the
            // all-reduced g in a standalone round.
            let g_dep = [grad_reduce.unwrap()];
            for d in 0..devices {
                let mut first = true;
                for i in 0..n_blocks {
                    let extra: &[usize] = if first { &g_dep } else { &[] };
                    b.push_block_round(
                        &mut lanes[d],
                        step,
                        i,
                        on_disk(i),
                        &mut last_write[d][i],
                        TaskKind::Update,
                        extra,
                    );
                    first = false;
                }
            }
        }
    }
    b.tasks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::build_plan;

    fn plans_equal(a: &[Task], b: &[Task]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| {
                x.step == y.step
                    && x.module == y.module
                    && x.kind == y.kind
                    && x.stream == y.stream
                    && x.deps == y.deps
                    && x.microbatch == y.microbatch
            })
    }

    #[test]
    fn single_device_strategies_coincide_with_build_plan() {
        for policy in [
            Policy::default(),
            Policy::naive(),
            Policy::three_tier(3, 2),
            Policy { efficient_update: false, ..Policy::default() },
        ] {
            let base = build_plan(6, 2, policy);
            for spec in [
                ShardSpec::single(),
                ShardSpec::pipeline(1, ShardLayout::Cyclic),
                ShardSpec::data_parallel(1),
            ] {
                let p = build_sharded_plan(6, 2, policy, &spec);
                assert!(plans_equal(&base, &p), "{spec:?} under {policy:?} diverged at N=1");
            }
        }
    }

    #[test]
    fn contiguous_layout_is_balanced_and_monotone() {
        for (n, dev) in [(12usize, 4usize), (13, 4), (5, 2), (7, 3), (8, 8)] {
            let per = blocks_per_device(ShardLayout::Contiguous, n, dev);
            assert_eq!(per.iter().map(|v| v.len()).sum::<usize>(), n);
            let (min, max) = (
                per.iter().map(|v| v.len()).min().unwrap(),
                per.iter().map(|v| v.len()).max().unwrap(),
            );
            assert!(max - min <= 1, "n={n} dev={dev}: {per:?}");
            // Ownership is non-decreasing along the block order.
            let owners: Vec<usize> =
                (0..n).map(|i| block_owner(ShardLayout::Contiguous, n, dev, i)).collect();
            assert!(owners.windows(2).all(|w| w[0] <= w[1]), "{owners:?}");
        }
    }

    #[test]
    fn pipeline_plan_divides_uploads_and_hops_activations() {
        let n = 8;
        let devices = 4;
        let plan = build_sharded_plan(
            n,
            1,
            Policy::default(),
            &ShardSpec::pipeline(devices, ShardLayout::Contiguous),
        );
        // Every block's upload runs on its owner's upload stream.
        for t in plan.iter().filter(|t| t.kind == TaskKind::Upload) {
            let i = match t.module {
                Module::Block(i) => i,
                _ => unreachable!(),
            };
            assert_eq!(
                t.stream,
                StreamId::new(
                    block_owner(ShardLayout::Contiguous, n, devices, i),
                    StreamKind::Upload
                )
            );
        }
        // Contiguous layout: exactly devices-1 activation hops per step,
        // plus the per-step gradient broadcast.
        let hops = plan.iter().filter(|t| t.kind == TaskKind::ActivationXfer).count();
        assert_eq!(hops, devices - 1);
        assert_eq!(plan.iter().filter(|t| t.kind == TaskKind::GradReduce).count(), 1);
        // Cyclic layout bounces at every boundary.
        let cyc = build_sharded_plan(
            n,
            1,
            Policy::default(),
            &ShardSpec::pipeline(devices, ShardLayout::Cyclic),
        );
        let cyc_hops = cyc.iter().filter(|t| t.kind == TaskKind::ActivationXfer).count();
        assert_eq!(cyc_hops, n - 1, "cyclic: a hop at every block boundary after block 0");
    }

    #[test]
    fn microbatched_pipeline_splits_compute_but_not_transfers() {
        let n = 8;
        let devices = 4;
        let steps = 2;
        let m = 4;
        let base = build_sharded_plan(
            n,
            steps,
            Policy::default(),
            &ShardSpec::pipeline(devices, ShardLayout::Contiguous),
        );
        let micro = build_sharded_plan(
            n,
            steps,
            Policy::default(),
            &ShardSpec::pipeline_microbatched(devices, ShardLayout::Contiguous, m),
        );
        let count = |p: &[Task], k: TaskKind| p.iter().filter(|t| t.kind == k).count();
        // Parameters still move once per block per step: the PCIe load (and
        // the disk chain, were it three-tier) is untouched by M.
        assert_eq!(count(&micro, TaskKind::Upload), count(&base, TaskKind::Upload));
        assert_eq!(count(&micro, TaskKind::Offload), count(&base, TaskKind::Offload));
        // One g broadcast per step regardless of M (the wire contract).
        assert_eq!(count(&micro, TaskKind::GradReduce), steps);
        // Compute and activation hops split M ways.
        assert_eq!(count(&micro, TaskKind::Compute), m * count(&base, TaskKind::Compute));
        assert_eq!(
            count(&micro, TaskKind::ActivationXfer),
            m * count(&base, TaskKind::ActivationXfer)
        );
        // Every compute/hop carries its microbatch tag; nothing else does.
        for t in &micro {
            match t.kind {
                TaskKind::Compute | TaskKind::ActivationXfer => {
                    let mb = t.microbatch.expect("compute/hop must be tagged");
                    assert_eq!(mb.of, m);
                    assert!(mb.index < m);
                }
                _ => assert!(t.microbatch.is_none(), "{:?} must not be microbatched", t.kind),
            }
        }
        // Each block's M compute slices depend on the same single upload:
        // slice 0 explicitly, the rest through the owner's compute FIFO.
        for i in 0..n {
            let u = micro
                .iter()
                .find(|t| t.kind == TaskKind::Upload && t.module == Module::Block(i) && t.step == 0)
                .unwrap();
            let c0 = micro
                .iter()
                .find(|t| {
                    t.kind == TaskKind::Compute
                        && t.module == Module::Block(i)
                        && t.step == 0
                        && t.microbatch.unwrap().index == 0
                })
                .unwrap();
            assert!(c0.deps.contains(&u.id), "C(W{i}, m=0) must wait for U(W{i})");
        }
    }

    #[test]
    fn microbatched_hops_connect_same_microbatch_producers() {
        // Every activation hop's dependency is the previous module's
        // compute of the *same* microbatch, and the hop sits on the
        // sender's interconnect stream.
        let n = 6;
        let devices = 3;
        let m = 3;
        for layout in [ShardLayout::Contiguous, ShardLayout::Cyclic] {
            let plan = build_sharded_plan(
                n,
                2,
                Policy::default(),
                &ShardSpec::pipeline_microbatched(devices, layout, m),
            );
            for hop in plan.iter().filter(|t| t.kind == TaskKind::ActivationXfer) {
                let i = match hop.module {
                    Module::Block(i) => i,
                    _ => unreachable!("hops are per-block"),
                };
                let mbi = hop.microbatch.unwrap().index;
                let producer = hop
                    .deps
                    .iter()
                    .map(|&d| &plan[d])
                    .find(|p| p.kind == TaskKind::Compute)
                    .expect("hop must depend on a compute");
                let want_module =
                    if i == 0 { Module::Embed } else { Module::Block(i - 1) };
                assert_eq!(producer.module, want_module, "hop into block {i}");
                assert_eq!(producer.step, hop.step);
                assert_eq!(producer.microbatch.unwrap().index, mbi, "microbatch mismatch");
                assert_eq!(
                    hop.stream,
                    StreamId::new(producer.device().0, StreamKind::Interconnect),
                    "hop charged to the wrong sender"
                );
            }
        }
    }

    #[test]
    fn per_partition_spill_sets_follow_owner_ranks() {
        // 8 blocks on 2 devices (contiguous: {0..3} and {4..7}); device 0
        // spills 1 of its 4, device 1 spills 3 of its 4, trailing within
        // each partition: {3} and {5, 6, 7}.
        let policy = Policy::three_tier(0, 4); // spilled count comes from the vec
        let spec = ShardSpec::pipeline(2, ShardLayout::Contiguous);
        let plan = build_sharded_plan_spilled(8, 1, policy, &spec, Some(&[1, 3]));
        let reads: Vec<usize> = plan
            .iter()
            .filter(|t| t.kind == TaskKind::DiskRead)
            .map(|t| match t.module {
                Module::Block(i) => i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(reads, vec![3, 5, 6, 7]);
        // Each read runs on its owner's disk stream.
        for t in plan.iter().filter(|t| t.kind == TaskKind::DiskRead) {
            let i = match t.module {
                Module::Block(i) => i,
                _ => unreachable!(),
            };
            assert_eq!(t.device(), DeviceId(block_owner(ShardLayout::Contiguous, 8, 2, i)));
        }
        // Cyclic: device 0 owns {0,2,4,6}, device 1 owns {1,3,5,7};
        // trailing ranks spill the tail of each owned list.
        let plan = build_sharded_plan_spilled(
            8,
            1,
            policy,
            &ShardSpec::pipeline(2, ShardLayout::Cyclic),
            Some(&[2, 1]),
        );
        let mut reads: Vec<usize> = plan
            .iter()
            .filter(|t| t.kind == TaskKind::DiskRead)
            .map(|t| match t.module {
                Module::Block(i) => i,
                _ => unreachable!(),
            })
            .collect();
        reads.sort_unstable();
        assert_eq!(reads, vec![4, 6, 7]);
        // Two-tier policies ignore the vector entirely.
        let two = build_sharded_plan_spilled(8, 1, Policy::default(), &spec, Some(&[4, 4]));
        assert_eq!(two.iter().filter(|t| t.kind == TaskKind::DiskRead).count(), 0);
    }

    #[test]
    fn weighted_owners_apportion_by_weight_and_stay_monotone() {
        // 2:1 weights over 12 blocks → 8 + 4.
        assert_eq!(
            weighted_contiguous_owners(12, &[2.0, 1.0]),
            vec![0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1]
        );
        // Equal weights reduce to the balanced contiguous layout.
        for (n, dev) in [(12usize, 4usize), (13, 4), (7, 3)] {
            let owners = weighted_contiguous_owners(n, &vec![1.0; dev]);
            let balanced: Vec<usize> =
                (0..n).map(|i| block_owner(ShardLayout::Contiguous, n, dev, i)).collect();
            assert_eq!(owners, balanced, "n={n} dev={dev}");
        }
        // Always: every block owned, ownership monotone, counts ∝ weights
        // within 1 block, degenerate weights fall back to balanced.
        let owners = weighted_contiguous_owners(10, &[3.0, 1.0, 1.0]);
        assert_eq!(owners.len(), 10);
        assert!(owners.windows(2).all(|w| w[0] <= w[1]));
        let per = blocks_per_device_of(&owners, 3);
        assert_eq!(per[0].len(), 6);
        assert_eq!(per[1].len(), 2);
        assert_eq!(per[2].len(), 2);
        let zero = weighted_contiguous_owners(8, &[0.0, 0.0]);
        assert_eq!(blocks_per_device_of(&zero, 2)[0].len(), 4);
    }

    #[test]
    fn custom_owner_map_routes_blocks_and_hops() {
        // 6 blocks, hinted 4/2 split: device 0 owns {0..3}, device 1 {4,5}.
        let owners = weighted_contiguous_owners(6, &[2.0, 1.0]);
        assert_eq!(owners, vec![0, 0, 0, 0, 1, 1]);
        let spec = ShardSpec::pipeline(2, ShardLayout::Contiguous);
        let plan =
            build_sharded_plan_tiered(6, 1, Policy::default(), &spec, None, Some(&owners));
        for t in plan.iter().filter(|t| {
            matches!(t.kind, TaskKind::Upload | TaskKind::Compute | TaskKind::Offload)
        }) {
            if let Module::Block(i) = t.module {
                assert_eq!(t.device(), DeviceId(owners[i]), "block {i} {:?}", t.kind);
            }
        }
        // Monotone owners: exactly one ownership change → one hop.
        assert_eq!(plan.iter().filter(|t| t.kind == TaskKind::ActivationXfer).count(), 1);
        // And the balanced layout (owners = None) is untouched by the new
        // parameter: identical to the historical builder output.
        let base = build_sharded_plan(6, 1, Policy::default(), &spec);
        let via_tiered =
            build_sharded_plan_tiered(6, 1, Policy::default(), &spec, None, None);
        assert!(plans_equal(&base, &via_tiered));
    }

    #[test]
    fn per_device_tiers_carry_their_own_dram_windows() {
        // Device 0: 3 spills through a 1-slot window (serialised); device 1:
        // 3 spills through a 3-slot window.  The windows must not leak into
        // each other: d0's R(W_next) waits for its own W, d1's do not.
        let policy = Policy { dram_slots: 4, ..Policy::three_tier(0, 4) };
        let spec = ShardSpec::pipeline(2, ShardLayout::Contiguous);
        let tiers = [
            DeviceTier { spilled: 3, dram_slots: 1 },
            DeviceTier { spilled: 3, dram_slots: 3 },
        ];
        let plan = build_sharded_plan_tiered(6, 1, policy, &spec, Some(&tiers), None);
        let read = |i: usize| {
            plan.iter()
                .find(|t| t.kind == TaskKind::DiskRead && t.module == Module::Block(i))
                .unwrap_or_else(|| panic!("block {i} must spill"))
        };
        let write = |i: usize| {
            plan.iter()
                .find(|t| t.kind == TaskKind::DiskWrite && t.module == Module::Block(i))
                .unwrap_or_else(|| panic!("block {i} must spill"))
        };
        // Device 0 owns {0,1,2}, all spilled, window 1: R(W1) ← W(W0).
        assert!(read(1).deps.contains(&write(0).id), "1-slot window must serialise d0");
        assert!(read(2).deps.contains(&write(1).id));
        // Device 1 owns {3,4,5}, all spilled, window 3: no W deps among its
        // reads (the ring is deep enough for the whole partition).
        for i in [4usize, 5] {
            let r = read(i);
            let w_dep = r
                .deps
                .iter()
                .any(|&d| plan[d].kind == TaskKind::DiskWrite);
            assert!(!w_dep, "d1's window 3 must not serialise R(W{i})");
        }
        // Reads stay on their owner's streams.
        for i in 0..3 {
            assert_eq!(read(i).device(), DeviceId(0));
        }
        for i in 3..6 {
            assert_eq!(read(i).device(), DeviceId(1));
        }
    }

    #[test]
    fn spilled_wrapper_matches_tiered_with_uniform_windows() {
        // `build_sharded_plan_spilled` is now a thin wrapper: same plan as
        // `build_sharded_plan_tiered` with every device at policy.dram_slots.
        let policy = Policy::three_tier(0, 2);
        let spec = ShardSpec::pipeline(2, ShardLayout::Cyclic);
        let spilled = [2usize, 1];
        let tiers: Vec<DeviceTier> =
            spilled.iter().map(|&s| DeviceTier { spilled: s, dram_slots: 2 }).collect();
        let a = build_sharded_plan_spilled(8, 2, policy, &spec, Some(&spilled));
        let b = build_sharded_plan_tiered(8, 2, policy, &spec, Some(&tiers), None);
        assert!(plans_equal(&a, &b));
    }

    #[test]
    fn dp_plan_has_exactly_seed_and_reduce_per_step() {
        let n = 6;
        let steps = 3;
        let devices = 4;
        let plan =
            build_sharded_plan(n, steps, Policy::default(), &ShardSpec::data_parallel(devices));
        assert_eq!(plan.iter().filter(|t| t.kind == TaskKind::SeedBcast).count(), steps);
        assert_eq!(plan.iter().filter(|t| t.kind == TaskKind::GradReduce).count(), steps);
        assert_eq!(plan.iter().filter(|t| t.kind == TaskKind::ActivationXfer).count(), 0);
        // Every device runs the full model every step.
        for d in 0..devices {
            let uploads = plan
                .iter()
                .filter(|t| {
                    t.kind == TaskKind::Upload && t.stream == StreamId::new(d, StreamKind::Upload)
                })
                .count();
            assert_eq!(uploads, n * steps, "device {d}");
        }
        // The all-reduce depends on every device's head.
        let reduce = plan.iter().find(|t| t.kind == TaskKind::GradReduce).unwrap();
        let head_deps = reduce
            .deps
            .iter()
            .filter(|&&d| plan[d].kind == TaskKind::Compute && plan[d].module == Module::Head)
            .count();
        assert_eq!(head_deps, devices);
    }

    #[test]
    fn deps_always_point_backwards() {
        for spec in [
            ShardSpec::pipeline(2, ShardLayout::Contiguous),
            ShardSpec::pipeline(4, ShardLayout::Cyclic),
            ShardSpec::pipeline_microbatched(2, ShardLayout::Contiguous, 4),
            ShardSpec::pipeline_microbatched(4, ShardLayout::Cyclic, 3),
            ShardSpec::data_parallel(2),
            ShardSpec::data_parallel(4),
        ] {
            for policy in [
                Policy::default(),
                Policy::naive(),
                Policy::three_tier(4, 2),
                Policy { efficient_update: false, ..Policy::default() },
            ] {
                let plan = build_sharded_plan(7, 2, policy, &spec);
                for t in &plan {
                    for &d in &t.deps {
                        assert!(d < t.id, "{spec:?}: dep {} of task {} not backward", d, t.id);
                    }
                }
            }
        }
    }
}
