//! Deterministic discrete-event execution of a task plan on virtual time.
//!
//! Streams are FIFO processors; a task starts at
//! `max(stream free, all dep ends) + extra latency` and runs for its
//! [`CostProvider`] duration.  Because the plan builders emit tasks in
//! issue order with backward-only deps, a single forward pass computes the
//! exact event times — this *is* the event-driven semantics of CUDA streams
//! with `cudaStreamWaitEvent` dependencies, just resolved analytically.
//! Streams are device-indexed ([`StreamId`]): a single-GPU plan occupies
//! device 0's streams, a sharded plan ([`crate::shard`]) one set of streams
//! per device plus the interconnect.

use std::collections::BTreeMap;

use super::{CostProvider, DeviceId, Policy, StreamId, StreamKind, Task, TaskKind};
use crate::telemetry::{TraceEvent, Timeline};

/// Scheduled times for one plan.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub start: Vec<f64>,
    pub end: Vec<f64>,
    pub makespan: f64,
    /// Steady-state per-step time: (end of last step − end of first step) /
    /// (steps − 1), falling back to makespan for single-step plans.
    pub steady_step_s: f64,
    /// Seconds each stream spent busy, keyed by device-indexed stream.
    /// `BTreeMap` so every iteration (reports, traces, totals) walks
    /// streams in one canonical order — the determinism contract the
    /// `deterministic-collections` lint rule enforces for this module.
    pub busy: BTreeMap<StreamId, f64>,
}

/// Shared 4-way diagnosis used at device and cluster level: interconnect
/// wins only when it strictly dominates (so an idle link never wins), the
/// historical disk ≥ pcie ≥ compute cascade breaks the remaining ties.
fn classify(compute: f64, pcie: f64, disk: f64, ic: f64) -> &'static str {
    if ic > disk && ic > pcie && ic > compute {
        "interconnect-bound"
    } else if disk >= pcie && disk >= compute {
        "disk-bound"
    } else if pcie >= compute {
        "pcie-bound"
    } else {
        "compute-bound"
    }
}

impl Schedule {
    /// Busy seconds of the named stream kind, summed across devices
    /// (device 0's streams keep their historical bare names, so
    /// `busy_of("upload")` on a single-GPU schedule reads exactly as
    /// before the device-indexed refactor).
    pub fn busy_of(&self, stream: &str) -> f64 {
        self.busy
            .iter()
            .filter(|(id, _)| id.kind.name() == stream)
            .map(|(_, &s)| s)
            .sum()
    }

    /// Busy seconds of one device's stream of the given kind.
    pub fn busy_on(&self, device: DeviceId, kind: StreamKind) -> f64 {
        self.busy.get(&StreamId { device, kind }).copied().unwrap_or(0.0)
    }

    /// Devices that own at least one busy stream, ascending.
    pub fn devices(&self) -> Vec<DeviceId> {
        let mut ds: Vec<DeviceId> = self.busy.keys().map(|id| id.device).collect();
        ds.sort_unstable();
        ds.dedup();
        ds
    }

    /// Which resource one device's pipeline is limited by: the busiest of
    /// its compute stream, its PCIe link (upload/offload), its NVMe queues
    /// (disk read/write) and its interconnect stream.
    pub fn bottleneck_of(&self, device: DeviceId) -> &'static str {
        let compute = self.busy_on(device, StreamKind::Compute);
        let pcie = self
            .busy_on(device, StreamKind::Upload)
            .max(self.busy_on(device, StreamKind::Offload));
        let disk = self
            .busy_on(device, StreamKind::DiskRead)
            .max(self.busy_on(device, StreamKind::DiskWrite));
        let ic = self.busy_on(device, StreamKind::Interconnect);
        classify(compute, pcie, disk, ic)
    }

    /// Cluster-level diagnosis: the worst device's per-category load, with
    /// the interconnect (summed across devices — it is one shared link)
    /// winning only when it strictly dominates.  Single-device schedules
    /// carry no interconnect tasks, so this reduces to the historical
    /// three-way compute/pcie/disk diagnosis.
    pub fn bottleneck(&self) -> &'static str {
        let mut compute = 0.0f64;
        let mut pcie = 0.0f64;
        let mut disk = 0.0f64;
        for d in self.devices() {
            compute = compute.max(self.busy_on(d, StreamKind::Compute));
            pcie = pcie.max(
                self.busy_on(d, StreamKind::Upload).max(self.busy_on(d, StreamKind::Offload)),
            );
            disk = disk.max(
                self.busy_on(d, StreamKind::DiskRead).max(self.busy_on(d, StreamKind::DiskWrite)),
            );
        }
        let ic: f64 = self
            .busy
            .iter()
            .filter(|(id, _)| id.kind == StreamKind::Interconnect)
            .map(|(_, &s)| s)
            .sum();
        classify(compute, pcie, disk, ic)
    }
}

/// Run `tasks` (from [`super::build_plan`] or
/// [`crate::shard::build_sharded_plan`]) under `costs`, returning the
/// schedule and a timeline trace (paper Fig. 4).
///
/// Upload/offload durations include the provider's host fused-kernel terms
/// (`host_decode_s` / `host_encode_s`) — in the real engine the codec runs
/// on host cores inside those stream threads.  With `policy.disk_batch > 1`
/// back-to-back queued disk reads coalesce io_uring-style per device: the
/// first read of a batch pays the full submission latency, follow-ups that
/// were already queued when it finished pay bandwidth only.
pub fn simulate(tasks: &[Task], costs: &dyn CostProvider, policy: Policy) -> (Schedule, Timeline) {
    let mut start = vec![0.0f64; tasks.len()];
    let mut end = vec![0.0f64; tasks.len()];
    let mut stream_free: BTreeMap<StreamId, f64> = BTreeMap::new();
    let mut busy: BTreeMap<StreamId, f64> = BTreeMap::new();
    let mut timeline = Timeline::new();
    // Disk-read batching state, per read stream (one per device): length of
    // the current batch, and whether the previous task on the stream was
    // itself a read (batches never span interleaved foreign tasks, which
    // only occur in naive mode).
    let mut read_batch_len: BTreeMap<StreamId, usize> = BTreeMap::new();
    let mut last_was_read: BTreeMap<StreamId, bool> = BTreeMap::new();

    for t in tasks {
        let stream_prev: f64 = *stream_free.get(&t.stream).unwrap_or(&0.0);
        let mut t0 = stream_prev;
        for &d in &t.deps {
            t0 = t0.max(end[d]);
        }
        t0 += t.extra_latency;
        // Durations go through the device-aware `_on`/`_from` variants: a
        // heterogeneous provider prices each device from its own hardware
        // (and each hop from the sender's link); the trait defaults forward
        // to the device-less methods, so everything else is unchanged.
        let dev = t.device();
        let dur = match t.kind {
            TaskKind::Upload => {
                let base = costs.upload_s_on(dev) + costs.host_decode_s_on(dev);
                if policy.reusable_mem { base } else { base + costs.malloc_s_on(dev) }
            }
            TaskKind::Compute => match t.microbatch {
                Some(mb) => costs.compute_microbatch_s_on(dev, t.module, mb.index, mb.of),
                None => costs.compute_s_on(dev, t.module),
            },
            TaskKind::Offload => costs.offload_s_on(dev) + costs.host_encode_s_on(dev),
            TaskKind::Update => costs.update_s_on(dev),
            TaskKind::DiskRead => {
                // A read joins the running batch iff it was already queued
                // when the stream freed up (no idle gap), the previous task
                // on this stream was a read, and the batch has room.
                let queued = t0 <= stream_prev + 1e-12;
                let batch = read_batch_len.entry(t.stream).or_insert(0);
                let coalesce = policy.disk_batch > 1
                    && queued
                    && last_was_read.get(&t.stream).copied().unwrap_or(false)
                    && *batch > 0
                    && *batch < policy.disk_batch;
                if coalesce {
                    *batch += 1;
                    costs.disk_read_bw_s_on(dev)
                } else {
                    *batch = 1;
                    costs.disk_read_s_on(dev)
                }
            }
            TaskKind::DiskWrite => costs.disk_write_s_on(dev),
            TaskKind::ActivationXfer => match t.microbatch {
                Some(mb) => costs.link_activation_microbatch_s_from(dev, mb.of),
                None => costs.link_activation_s_from(dev),
            },
            TaskKind::SeedBcast => costs.link_seed_s(),
            TaskKind::GradReduce => costs.link_grad_s(),
        };
        last_was_read.insert(t.stream, t.kind == TaskKind::DiskRead);
        let t1 = t0 + dur;
        start[t.id] = t0;
        end[t.id] = t1;
        stream_free.insert(t.stream, t1);
        *busy.entry(t.stream).or_default() += dur;
        timeline.push(TraceEvent {
            stream: t.stream.name(),
            cat: t.kind.cat_name(),
            label: format!("{:?} {:?} s{}", t.kind, t.module, t.step),
            start: t0,
            end: t1,
        });
    }

    let makespan = end.iter().copied().fold(0.0, f64::max);
    // Steady-state per-step rate from per-step last-end times.
    let n_steps = tasks.iter().map(|t| t.step).max().map(|s| s + 1).unwrap_or(0);
    let steady_step_s = if n_steps >= 2 {
        let mut step_end = vec![0.0f64; n_steps];
        for t in tasks {
            step_end[t.step] = step_end[t.step].max(end[t.id]);
        }
        (step_end[n_steps - 1] - step_end[0]) / (n_steps - 1) as f64
    } else {
        makespan
    };

    (Schedule { start, end, makespan, steady_step_s, busy }, timeline)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{build_plan, Module};
    use crate::shard::{build_sharded_plan, ShardLayout, ShardSpec};

    struct FixedCosts {
        up: f64,
        off: f64,
        comp: f64,
    }

    impl CostProvider for FixedCosts {
        fn upload_s(&self) -> f64 {
            self.up
        }
        fn offload_s(&self) -> f64 {
            self.off
        }
        fn compute_s(&self, _m: Module) -> f64 {
            self.comp
        }
        fn update_s(&self) -> f64 {
            self.comp * 0.1
        }
    }

    #[test]
    fn overlap_hides_communication_when_compute_dominates() {
        // Dual-forward compute (2x single) longer than transfer: ZO2's core
        // claim — communication fully hidden, makespan ≈ compute-bound.
        let costs = FixedCosts { up: 1.0, off: 1.0, comp: 3.0 };
        let n = 8;
        let plan = build_plan(n, 1, Policy::default());
        let (sched, _) = simulate(&plan, &costs, Policy::default());
        let compute_total = (n as f64 + 2.0) * 3.0; // embed + blocks + head
        assert!(sched.makespan < compute_total + 2.0 + 1e-9,
                "makespan {} should be ~compute-bound {}", sched.makespan, compute_total);

        let naive_plan = build_plan(n, 1, Policy::naive());
        let (naive, _) = simulate(&naive_plan, &costs, Policy::naive());
        // Naive pays every transfer serially.
        let expect_naive = compute_total + n as f64 * 2.0;
        assert!((naive.makespan - expect_naive).abs() < 1e-9);
        assert!(naive.makespan > sched.makespan * 1.3);
    }

    #[test]
    fn comm_bound_regime_is_limited_by_uploads() {
        // Transfers longer than compute: upload stream is the bottleneck
        // (paper's OPT-1.3B FP16 regime).
        let costs = FixedCosts { up: 5.0, off: 5.0, comp: 1.0 };
        let n = 6;
        let plan = build_plan(n, 1, Policy::default());
        let (sched, _) = simulate(&plan, &costs, Policy::default());
        // Lower bound: n serial uploads.
        assert!(sched.makespan >= n as f64 * 5.0);
        // And far below naive (which adds offloads + computes serially).
        let (naive, _) = simulate(&build_plan(n, 1, Policy::naive()), &costs, Policy::naive());
        assert!(naive.makespan > sched.makespan + n as f64 * 1.0 - 1e-9);
    }

    #[test]
    fn no_task_starts_before_deps() {
        let costs = FixedCosts { up: 0.7, off: 1.3, comp: 2.1 };
        let plan = build_plan(5, 3, Policy::default());
        let (sched, _) = simulate(&plan, &costs, Policy::default());
        for t in &plan {
            for &d in &t.deps {
                assert!(sched.start[t.id] >= sched.end[d] - 1e-12);
            }
        }
    }

    #[test]
    fn steady_state_step_rate() {
        let costs = FixedCosts { up: 1.0, off: 1.0, comp: 3.0 };
        let plan = build_plan(4, 4, Policy::default());
        let (sched, _) = simulate(&plan, &costs, Policy::default());
        assert!(sched.steady_step_s > 0.0);
        assert!(sched.steady_step_s <= sched.makespan);
    }

    struct DiskCosts {
        inner: FixedCosts,
        read: f64,
        write: f64,
    }

    impl CostProvider for DiskCosts {
        fn upload_s(&self) -> f64 {
            self.inner.up
        }
        fn offload_s(&self) -> f64 {
            self.inner.off
        }
        fn compute_s(&self, m: Module) -> f64 {
            self.inner.comp * if m == Module::Embed { 0.1 } else { 1.0 }
        }
        fn update_s(&self) -> f64 {
            self.inner.comp * 0.1
        }
        fn disk_read_s(&self) -> f64 {
            self.read
        }
        fn disk_read_bw_s(&self) -> f64 {
            // Latency-heavy model: half the read cost is submission latency
            // that an io_uring batch amortises.
            self.read * 0.5
        }
        fn disk_write_s(&self) -> f64 {
            self.write
        }
    }

    #[test]
    fn disk_prefetch_overlaps_compute() {
        // Fast disk, slow compute, deep window: the reads for later blocks
        // must run while earlier blocks compute, so makespan stays near
        // compute-bound despite every block living on disk.
        let costs = DiskCosts { inner: FixedCosts { up: 0.2, off: 0.2, comp: 3.0 }, read: 1.0, write: 1.0 };
        let n = 8;
        let policy = crate::sched::Policy::three_tier(n, 4);
        let plan = build_plan(n, 1, policy);
        let (sched, _) = simulate(&plan, &costs, policy);
        let compute_total = 0.1 * 3.0 + (n as f64 + 1.0) * 3.0;
        assert!(
            sched.makespan < compute_total + 2.0,
            "disk reads should hide behind compute: makespan {} vs compute {}",
            sched.makespan,
            compute_total
        );
        assert_eq!(sched.bottleneck(), "compute-bound");
        // A DiskRead for a later block must start before an earlier block's
        // compute ends (the look-ahead actually looks ahead).
        let r_late = plan.iter().find(|t| {
            t.kind == TaskKind::DiskRead && t.module == Module::Block(2)
        }).unwrap();
        let c_early = plan.iter().find(|t| {
            t.kind == TaskKind::Compute && t.module == Module::Block(0)
        }).unwrap();
        assert!(
            sched.start[r_late.id] < sched.end[c_early.id],
            "R(W2) at {} should overlap C(W0) ending {}",
            sched.start[r_late.id],
            sched.end[c_early.id]
        );
    }

    #[test]
    fn slow_disk_makes_pipeline_disk_bound() {
        let costs = DiskCosts { inner: FixedCosts { up: 0.5, off: 0.5, comp: 1.0 }, read: 4.0, write: 4.0 };
        let n = 6;
        let policy = crate::sched::Policy::three_tier(n, 3);
        let plan = build_plan(n, 2, policy);
        let (sched, _) = simulate(&plan, &costs, policy);
        assert_eq!(sched.bottleneck(), "disk-bound");
        // Lower bound: the read stream alone needs n*steps serial reads.
        assert!(sched.makespan >= 2.0 * n as f64 * 4.0 - 1e-9);
    }

    #[test]
    fn batched_disk_reads_amortise_latency() {
        // Disk-bound pipeline: queued reads pile up behind each other, so
        // batching them must strictly shrink the makespan, monotonically in
        // the batch depth, and `disk_batch = 1` must reproduce the
        // unbatched schedule exactly.
        let costs = DiskCosts {
            inner: FixedCosts { up: 0.2, off: 0.2, comp: 0.5 },
            read: 4.0,
            write: 1.0,
        };
        let n = 8;
        let base = crate::sched::Policy::three_tier(n, 4);
        let plan = build_plan(n, 2, base);
        let (unbatched, _) = simulate(&plan, &costs, base);

        let one = Policy { disk_batch: 1, ..base };
        let (same, _) = simulate(&build_plan(n, 2, one), &costs, one);
        assert_eq!(unbatched.makespan, same.makespan, "batch=1 is the identity");

        let mut last = unbatched.makespan;
        for batch in [2usize, 4, 8] {
            let p = Policy { disk_batch: batch, ..base };
            let (s, _) = simulate(&build_plan(n, 2, p), &costs, p);
            assert!(
                s.makespan < last + 1e-12,
                "batch {batch}: {} must not exceed {last}",
                s.makespan
            );
            last = s.makespan;
        }
        // With depth-4 batches at most 1 in 4 reads pays latency: the read
        // stream's busy time must drop accordingly.
        let p4 = Policy { disk_batch: 4, ..base };
        let (s4, _) = simulate(&build_plan(n, 2, p4), &costs, p4);
        assert!(
            s4.busy_of("disk_read") < unbatched.busy_of("disk_read") - 1e-9,
            "batching must shed read-stream busy time"
        );
    }

    #[test]
    fn host_kernel_terms_extend_upload_and_offload() {
        struct HostHeavy(FixedCosts);
        impl CostProvider for HostHeavy {
            fn upload_s(&self) -> f64 {
                self.0.up
            }
            fn offload_s(&self) -> f64 {
                self.0.off
            }
            fn compute_s(&self, m: Module) -> f64 {
                self.0.compute_s(m)
            }
            fn update_s(&self) -> f64 {
                self.0.update_s()
            }
            fn host_decode_s(&self) -> f64 {
                4.0
            }
            fn host_encode_s(&self) -> f64 {
                4.0
            }
        }
        let plain = FixedCosts { up: 1.0, off: 1.0, comp: 3.0 };
        let heavy = HostHeavy(FixedCosts { up: 1.0, off: 1.0, comp: 3.0 });
        let p = Policy::default();
        let plan = build_plan(6, 2, p);
        let (s0, _) = simulate(&plan, &plain, p);
        let (s1, _) = simulate(&plan, &heavy, p);
        assert!(s1.makespan > s0.makespan, "host kernel time must show up");
        // Slow host kernels turn a compute-bound pipeline transfer-bound.
        assert_eq!(s0.bottleneck(), "compute-bound");
        assert_eq!(s1.bottleneck(), "pcie-bound");
    }

    #[test]
    fn malloc_ablation_is_slower_than_naive() {
        // Table 4: "no reusable memory" hurts more than "no overlap".
        let costs = FixedCosts { up: 1.0, off: 1.0, comp: 3.0 };
        let full = Policy::default();
        let no_reuse = Policy { reusable_mem: false, ..full };
        let naive = Policy::naive();
        let n = 8;
        struct MallocHeavy(FixedCosts);
        impl CostProvider for MallocHeavy {
            fn upload_s(&self) -> f64 { self.0.upload_s() }
            fn offload_s(&self) -> f64 { self.0.offload_s() }
            fn compute_s(&self, m: Module) -> f64 { self.0.compute_s(m) }
            fn update_s(&self) -> f64 { self.0.update_s() }
            fn malloc_s(&self) -> f64 { 2.0 }
        }
        let heavy = MallocHeavy(FixedCosts { up: 1.0, off: 1.0, comp: 3.0 });
        let (s_full, _) = simulate(&build_plan(n, 2, full), &costs, full);
        let (s_nor, _) = simulate(&build_plan(n, 2, no_reuse), &heavy, no_reuse);
        let (s_naive, _) = simulate(&build_plan(n, 2, naive), &costs, naive);
        assert!(s_full.makespan < s_naive.makespan);
        assert!(s_naive.makespan < s_nor.makespan,
                "no-reusable-memory ({}) should be slower than naive ({})",
                s_nor.makespan, s_naive.makespan);
    }

    struct LinkCosts {
        inner: FixedCosts,
        act: f64,
        seed: f64,
        grad: f64,
    }

    impl CostProvider for LinkCosts {
        fn upload_s(&self) -> f64 {
            self.inner.up
        }
        fn offload_s(&self) -> f64 {
            self.inner.off
        }
        fn compute_s(&self, m: Module) -> f64 {
            self.inner.compute_s(m)
        }
        fn update_s(&self) -> f64 {
            self.inner.update_s()
        }
        fn link_activation_s(&self) -> f64 {
            self.act
        }
        fn link_seed_s(&self) -> f64 {
            self.seed
        }
        fn link_grad_s(&self) -> f64 {
            self.grad
        }
    }

    #[test]
    fn dp_sharding_overlaps_devices_and_pays_only_scalar_comm() {
        // Compute-bound single device; 4-way DP with cheap scalar comm must
        // keep the per-step time near one device's (weak scaling).
        let costs = LinkCosts {
            inner: FixedCosts { up: 0.5, off: 0.5, comp: 2.0 },
            act: 0.0,
            seed: 0.01,
            grad: 0.02,
        };
        let n = 6;
        let steps = 3;
        let single = build_plan(n, steps, Policy::default());
        let (s1, _) = simulate(&single, &costs, Policy::default());
        let dp = build_sharded_plan(n, steps, Policy::default(), &ShardSpec::data_parallel(4));
        let (s4, _) = simulate(&dp, &costs, Policy::default());
        // 4x the batch throughput for ~the same step time (+ the reduce).
        assert!(
            s4.steady_step_s < s1.steady_step_s * 1.1 + 0.03 + 1e-9,
            "DP step {} should stay near single-device {}",
            s4.steady_step_s,
            s1.steady_step_s
        );
        // All four devices' compute streams are busy.
        assert_eq!(s4.devices().len(), 4);
        for d in s4.devices() {
            assert!(s4.busy_on(d, StreamKind::Compute) > 0.0, "{d:?} idle");
        }
    }

    #[test]
    fn slow_link_makes_dp_interconnect_bound() {
        let costs = LinkCosts {
            inner: FixedCosts { up: 0.1, off: 0.1, comp: 0.2 },
            act: 0.0,
            seed: 2.0,
            grad: 3.0,
        };
        let dp = build_sharded_plan(4, 3, Policy::default(), &ShardSpec::data_parallel(4));
        let (s, _) = simulate(&dp, &costs, Policy::default());
        assert_eq!(s.bottleneck(), "interconnect-bound");
        // Device 0 carries the link streams in the DP plan.
        assert_eq!(s.bottleneck_of(DeviceId(0)), "interconnect-bound");
        assert_eq!(s.bottleneck_of(DeviceId(1)), "compute-bound");
    }

    #[test]
    fn pipeline_sharding_pipelines_across_steps() {
        // Upload-bound regime: pipeline sharding splits the PCIe traffic
        // across devices, so with N devices the steady-state step time must
        // beat one device's.
        let costs = LinkCosts {
            inner: FixedCosts { up: 4.0, off: 4.0, comp: 0.5 },
            act: 0.05,
            seed: 0.0,
            grad: 0.01,
        };
        let n = 8;
        let steps = 4;
        let single = build_plan(n, steps, Policy::default());
        let (s1, _) = simulate(&single, &costs, Policy::default());
        let pipe = build_sharded_plan(
            n,
            steps,
            Policy::default(),
            &ShardSpec::pipeline(4, ShardLayout::Contiguous),
        );
        let (s4, _) = simulate(&pipe, &costs, Policy::default());
        assert!(
            s4.steady_step_s < s1.steady_step_s * 0.5,
            "4-way pipeline {} should at least halve the upload-bound step {}",
            s4.steady_step_s,
            s1.steady_step_s
        );
    }
}
