//! The ZO2 dynamic scheduler (paper §5.2, Algorithm 3), extended with a
//! disk tier and device-indexed streams.
//!
//! Two-tier mode mirrors the paper's three CUDA streams — Upload, Compute,
//! Offload — with two dependency rules:
//!
//!  1. per-block chain:   U(Wᵢ) → C(Wᵢ) → O(Wᵢ)
//!  2. per-stream FIFO:   X(Wᵢ) waits for X(Wᵢ₋₁) of the same stream
//!
//! plus the resource rule that an upload needs a free slot of the reusable
//! block buffer (slot of block *i* frees when O(Wᵢ) completes; with S slots
//! U(Wᵢ) therefore waits on O(Wᵢ₋ₛ)).
//!
//! Three-tier mode ([`Tiering::ThreeTier`]) adds two streams — DiskRead,
//! DiskWrite — for blocks spilled to NVMe.  A spilled block's chain grows
//! to R(Wᵢ) → U(Wᵢ) → C(Wᵢ) → O(Wᵢ) → W(Wᵢ), with two more rules:
//!
//!  3. DRAM-window resource rule (mirror of the reusable-buffer rule): a
//!     disk read needs a free slot of the DRAM staging window; the slot of
//!     block *i* frees when W(Wᵢ) completes, so with D slots R waits on the
//!     W that ran D spills earlier.  The window is also the *look-ahead*
//!     of the prefetcher: reads run up to D spilled blocks ahead of
//!     compute, so the read for block i+k overlaps compute on block i.
//!  4. disk read-after-write: R of block *i* at step *j+1* waits for W of
//!     block *i* at step *j* (the bucket on disk is the updated one).
//!
//! # Device-indexed streams
//!
//! A stream's identity is [`StreamId`] — a `(device, kind)` pair — so the
//! same dependency rules describe one GPU (every stream on [`DeviceId`] 0;
//! the paper's setting) or N simulated GPUs, each with its own
//! Upload/Compute/Offload(/DiskRead/DiskWrite) streams plus an
//! [`StreamKind::Interconnect`] stream for device-to-device traffic.  The
//! multi-device plans (pipeline-sharded and seed-synchronous data-parallel
//! ZO) are built by [`crate::shard`]; `N = 1` is the degenerate case of the
//! same builder, not a special code path, and produces byte-identical plans
//! to the original single-device scheduler.
//!
//! The same task DAG drives two executions:
//!  * [`analytic`]: a deterministic discrete-event schedule on virtual time
//!    using a [`CostProvider`] — this is how paper-scale (OPT-30B…175B)
//!    configurations are evaluated, and what emits the Fig. 4 timelines;
//!  * the *real* threaded engine in [`crate::zo::Zo2Engine`], which
//!    realises the same dependency structure with worker threads around
//!    actual PJRT executions (plus real file I/O for the disk tier).
//!
//! Ablation flags reproduce Table 4:
//!  * `overlap = false` — the naive §5.2/Fig. 4a schedule: global sync after
//!    every task (single CUDA stream).
//!  * `reusable_mem = false` — every upload pays a cudaMalloc, and (as with
//!    real cudaMalloc) synchronises with the compute stream.
//!  * `efficient_update = false` — the §5.4 fusion is disabled: each step
//!    appends a second upload→update→offload round per block (Fig. 5a).

pub mod analytic;

pub use analytic::{simulate, Schedule};

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::sync::OnceLock;

/// A simulated accelerator in the cluster (device 0 in single-GPU runs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeviceId(pub usize);

/// What a stream *does* (the paper's three CUDA streams, the two disk
/// queues of the three-tier extension, and the device-to-device link).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum StreamKind {
    Upload,
    Compute,
    Offload,
    DiskRead,
    DiskWrite,
    /// Device-to-device traffic: pipeline activation handoffs, the DP seed
    /// broadcast and the DP projected-gradient all-reduce.
    Interconnect,
}

pub const STREAM_KINDS: [StreamKind; 6] = [
    StreamKind::Upload,
    StreamKind::Compute,
    StreamKind::Offload,
    StreamKind::DiskRead,
    StreamKind::DiskWrite,
    StreamKind::Interconnect,
];

impl StreamKind {
    pub fn name(self) -> &'static str {
        match self {
            StreamKind::Upload => "upload",
            StreamKind::Compute => "compute",
            StreamKind::Offload => "offload",
            StreamKind::DiskRead => "disk_read",
            StreamKind::DiskWrite => "disk_write",
            StreamKind::Interconnect => "interconnect",
        }
    }

    pub(crate) fn index(self) -> usize {
        match self {
            StreamKind::Upload => 0,
            StreamKind::Compute => 1,
            StreamKind::Offload => 2,
            StreamKind::DiskRead => 3,
            StreamKind::DiskWrite => 4,
            StreamKind::Interconnect => 5,
        }
    }
}

/// Device-indexed stream identity.  Everything that used to be keyed by the
/// old five-variant `Stream` enum is keyed by this pair now; single-device
/// schedules put every task on device 0.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StreamId {
    pub device: DeviceId,
    pub kind: StreamKind,
}

impl StreamId {
    pub fn new(device: usize, kind: StreamKind) -> Self {
        Self { device: DeviceId(device), kind }
    }

    /// Display name.  Device 0 keeps the historical bare names ("upload",
    /// "compute", …) so single-GPU timelines, busy maps and gantt charts
    /// are unchanged by the device-indexed refactor; other devices prefix
    /// the device ("d1.upload").
    pub fn name(&self) -> &'static str {
        if self.device.0 == 0 {
            return self.kind.name();
        }
        static NAMES: OnceLock<Mutex<BTreeMap<(usize, usize), &'static str>>> = OnceLock::new();
        let cache = NAMES.get_or_init(|| Mutex::new(BTreeMap::new()));
        let mut cache = cache.lock().unwrap();
        *cache
            .entry((self.device.0, self.kind.index()))
            .or_insert_with(|| {
                Box::leak(format!("d{}.{}", self.device.0, self.kind.name()).into_boxed_str())
            })
    }
}

/// Module position in the forward order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Module {
    Embed,
    Block(usize),
    Head,
}

/// Intra-step pipeline microbatch identity of a compute or activation task:
/// microbatch `index` of `of` (paper-batch split into `of` slices so
/// adjacent pipeline devices overlap *within* a step).  Tasks of an
/// un-microbatched plan (and every upload/offload/disk/collective task —
/// parameters move once per step regardless of `of`) carry `None`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Microbatch {
    /// 0-based slice index within the step.
    pub index: usize,
    /// Total microbatches per step (`--microbatches M`).
    pub of: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Upload a block bucket CPU→GPU (includes decompression in AMP mode).
    Upload,
    /// Dual-forward compute (+ fused deferred update, §5.4).
    Compute,
    /// Offload a block bucket GPU→CPU (includes compression in AMP mode).
    Offload,
    /// Standalone parameter-update compute (only in the
    /// `efficient_update = false` ablation, Fig. 5a).
    Update,
    /// Read a spilled block bucket NVMe→DDR (three-tier prefetch).
    DiskRead,
    /// Write an updated spilled bucket DDR→NVMe (three-tier write-back).
    DiskWrite,
    /// Activation handoff between consecutive blocks on different devices
    /// (pipeline sharding; the dual-path hidden state crosses the link).
    ActivationXfer,
    /// Per-step perturbation-seed broadcast (seed-synchronous DP: the only
    /// data workers must agree on before perturbing — 8 bytes).
    SeedBcast,
    /// Projected-gradient exchange: the scalar all-reduce of DP ZO, or the
    /// head-to-all g broadcast of the pipeline schedule.
    GradReduce,
}

impl TaskKind {
    /// Which stream kind this task occupies in an overlapped schedule.
    pub fn stream_kind(self) -> StreamKind {
        match self {
            TaskKind::Upload => StreamKind::Upload,
            TaskKind::Compute | TaskKind::Update => StreamKind::Compute,
            TaskKind::Offload => StreamKind::Offload,
            TaskKind::DiskRead => StreamKind::DiskRead,
            TaskKind::DiskWrite => StreamKind::DiskWrite,
            TaskKind::ActivationXfer | TaskKind::SeedBcast | TaskKind::GradReduce => {
                StreamKind::Interconnect
            }
        }
    }

    /// Canonical short name of the task category.  Both the simulator's
    /// plan trace and the engine's measured trace tag events with this
    /// vocabulary (the Chrome-trace `cat` field), and the drift report
    /// joins the two traces on it.
    pub fn cat_name(self) -> &'static str {
        match self {
            TaskKind::Upload => "upload",
            TaskKind::Compute => "compute",
            TaskKind::Offload => "offload",
            TaskKind::Update => "update",
            TaskKind::DiskRead => "disk_read",
            TaskKind::DiskWrite => "disk_write",
            TaskKind::ActivationXfer => "activation_xfer",
            TaskKind::SeedBcast => "seed_bcast",
            TaskKind::GradReduce => "grad_reduce",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Task {
    pub id: usize,
    pub step: usize,
    pub module: Module,
    pub kind: TaskKind,
    pub stream: StreamId,
    /// Indices of tasks that must complete first (beyond stream FIFO).
    pub deps: Vec<usize>,
    /// Extra fixed latency charged at task start (cudaMalloc in the
    /// no-reusable-memory ablation).
    pub extra_latency: f64,
    /// Which intra-step microbatch this compute/activation task covers
    /// (`None` everywhere in un-microbatched plans, so `M = 1` schedules
    /// are byte-identical to the pre-microbatching builder).
    pub microbatch: Option<Microbatch>,
}

impl Task {
    /// The device this task runs on (or, for link tasks, originates from).
    pub fn device(&self) -> DeviceId {
        self.stream.device
    }
}

/// Where block master copies live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tiering {
    /// Paper baseline: every block bucket DDR-resident.
    TwoTier,
    /// Disk tier below DDR: buckets beyond the DRAM budget spill to NVMe
    /// and stream through the DRAM staging window.
    ThreeTier,
}

impl Tiering {
    /// The canonical CLI spelling (`--tiering two|three`).
    pub fn name(self) -> &'static str {
        match self {
            Tiering::TwoTier => "two",
            Tiering::ThreeTier => "three",
        }
    }

    /// Parse a CLI spelling; shared by `main.rs` and the tune report so
    /// every emitted flag value round-trips through the same table.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "two" | "2" => Some(Tiering::TwoTier),
            "three" | "3" => Some(Tiering::ThreeTier),
            _ => None,
        }
    }
}

/// Which blocks spill to the disk tier (three-tier mode).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpillPlacement {
    /// The last `spilled` blocks spill (the original policy): disk traffic
    /// arrives in one burst at the tail of every step.
    Trailing,
    /// Spills spread evenly across the block order: disk reads interleave
    /// with DDR-resident uploads, smoothing the NVMe queues over the step.
    Interleaved,
}

impl SpillPlacement {
    /// The canonical CLI spelling (`--spill-placement trailing|interleaved`).
    pub fn name(self) -> &'static str {
        match self {
            SpillPlacement::Trailing => "trailing",
            SpillPlacement::Interleaved => "interleaved",
        }
    }

    /// Parse a CLI spelling (aliases included, like `main.rs` accepts).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "trailing" | "tail" => Some(SpillPlacement::Trailing),
            "interleaved" | "interleave" => Some(SpillPlacement::Interleaved),
            _ => None,
        }
    }
}

/// Whether block `i` of `n_blocks` lives on the disk tier when `spilled`
/// blocks spill under `placement`.  Shared by the analytic planner, the DAG
/// builder and the real engine, so all three agree on the spill set.
pub fn is_spilled_block(
    i: usize,
    n_blocks: usize,
    spilled: usize,
    placement: SpillPlacement,
) -> bool {
    let spilled = spilled.min(n_blocks);
    if spilled == 0 || n_blocks == 0 {
        return false;
    }
    match placement {
        SpillPlacement::Trailing => i >= n_blocks - spilled,
        // Even spread: exactly `spilled` indices, ~n/spilled apart (the
        // classic Bresenham selection).
        SpillPlacement::Interleaved => (i + 1) * spilled / n_blocks > i * spilled / n_blocks,
    }
}

/// Scheduler policy / ablation switches (Table 4 + the disk tier).
#[derive(Debug, Clone, Copy)]
pub struct Policy {
    pub overlap: bool,
    pub reusable_mem: bool,
    pub efficient_update: bool,
    /// Reusable buffer slots (3 = compute + prefetch + offload in flight).
    pub slots: usize,
    pub tiering: Tiering,
    /// DRAM staging-window slots = disk prefetch look-ahead (three-tier).
    pub dram_slots: usize,
    /// Number of blocks spilled to the disk tier (three-tier; 0 = everything
    /// fits in DDR and the plan degenerates to two-tier).
    pub spilled: usize,
    /// Which blocks spill (trailing burst vs interleaved).
    pub spill_placement: SpillPlacement,
    /// io_uring-style disk-read batching: up to this many back-to-back
    /// queued reads share one submission-latency charge (1 = off).  Only
    /// the latency coalesces — bandwidth is still paid per read.
    pub disk_batch: usize,
}

impl Default for Policy {
    fn default() -> Self {
        Self {
            overlap: true,
            reusable_mem: true,
            efficient_update: true,
            slots: 3,
            tiering: Tiering::TwoTier,
            dram_slots: 4,
            spilled: 0,
            spill_placement: SpillPlacement::Trailing,
            disk_batch: 1,
        }
    }
}

impl Policy {
    pub fn naive() -> Self {
        Self { overlap: false, ..Self::default() }
    }

    /// Three-tier policy with `spilled` blocks on the disk tier.
    pub fn three_tier(spilled: usize, dram_slots: usize) -> Self {
        Self { tiering: Tiering::ThreeTier, spilled, dram_slots, ..Self::default() }
    }
}

/// Build the task DAG for `steps` training steps over `n_blocks` offloaded
/// transformer blocks (embedding and LM head stay GPU-resident, §5.2) on a
/// single device.  In three-tier mode `policy.spilled` blocks additionally
/// stream through the disk tier (R before U, W after O).
///
/// This is the `N = 1` case of [`crate::shard::build_sharded_plan`] — the
/// device-indexed builder degenerates to the paper's single-GPU five-stream
/// schedule, byte-for-byte (asserted against a frozen copy of the
/// pre-refactor builder in `tests/sched_golden_v1.rs`).
pub fn build_plan(n_blocks: usize, steps: usize, policy: Policy) -> Vec<Task> {
    crate::shard::build_sharded_plan(n_blocks, steps, policy, &crate::shard::ShardSpec::single())
}

/// Task durations, supplied either by the analytic cost model
/// ([`crate::costmodel`]) or by real measurements (calibration tests).
pub trait CostProvider {
    /// Upload duration for one block bucket (wire bytes / H2D bandwidth).
    fn upload_s(&self) -> f64;
    /// Offload duration for one block bucket.
    fn offload_s(&self) -> f64;
    /// Dual-forward (+fused update) duration for the given module.
    fn compute_s(&self, module: Module) -> f64;
    /// Standalone update duration (non-efficient-update ablation).
    fn update_s(&self) -> f64;
    /// cudaMalloc latency charged per upload when the reusable buffer is
    /// disabled.
    fn malloc_s(&self) -> f64 {
        300e-6
    }
    /// Host fused-kernel decode per upload (the real engine decodes wire
    /// bytes on host cores in the upload thread).  Providers that do not
    /// model host kernels keep the zero default.
    fn host_decode_s(&self) -> f64 {
        0.0
    }
    /// Host fused-kernel encode per offload.
    fn host_encode_s(&self) -> f64 {
        0.0
    }
    /// NVMe read of one spilled block bucket (three-tier only; two-tier
    /// providers keep the zero default).
    fn disk_read_s(&self) -> f64 {
        0.0
    }
    /// Bandwidth-only cost of a read that joins an io_uring-style batch
    /// (its submission latency was charged by the batch's first read).
    /// Defaults to the full read cost, i.e. batching gains nothing unless
    /// the provider separates latency from bandwidth.
    fn disk_read_bw_s(&self) -> f64 {
        self.disk_read_s()
    }
    /// NVMe write-back of one spilled block bucket.
    fn disk_write_s(&self) -> f64 {
        0.0
    }
    /// Device-to-device activation handoff (pipeline sharding): the
    /// dual-path hidden state of one module boundary crossing the link.
    /// Single-device providers keep the zero default.
    fn link_activation_s(&self) -> f64 {
        0.0
    }
    /// Per-step perturbation-seed broadcast (seed-synchronous DP).
    fn link_seed_s(&self) -> f64 {
        0.0
    }
    /// Projected-gradient exchange: scalar all-reduce (DP) or the head's g
    /// broadcast (pipeline).
    fn link_grad_s(&self) -> f64 {
        0.0
    }
    /// Duration of microbatch `index` of `of` of `module`'s dual-forward
    /// when the step is split by pipeline microbatching.  The default is an
    /// even split (ideal scaling); providers with per-launch overheads or
    /// once-per-step terms (the fused deferred update, codec kernels)
    /// override and typically charge those on `index == 0`.  Never called
    /// for un-microbatched plans, so `M = 1` schedules cannot be perturbed
    /// by an override's different floating-point association.
    fn compute_microbatch_s(&self, module: Module, index: usize, of: usize) -> f64 {
        let _ = index;
        self.compute_s(module) / of.max(1) as f64
    }
    /// One microbatch's activation handoff when the step carries `of`
    /// microbatches (pipeline sharding).  Default: an even split of the
    /// full handoff; link providers override to keep the per-op latency.
    fn link_activation_microbatch_s(&self, of: usize) -> f64 {
        self.link_activation_s() / of.max(1) as f64
    }

    // --- device-aware pricing (heterogeneous clusters) -----------------------
    //
    // The simulator routes every task through these, passing the task's
    // device.  The defaults ignore the device and forward to the device-less
    // method, so single-device providers and homogeneous clusters are
    // untouched (bit-identical schedules — golden-frozen); a heterogeneous
    // provider ([`crate::costmodel::ClusterCost`]) overrides them to price
    // each device from its own `Hardware`, and link tasks from the sender's
    // own interconnect.

    /// Upload duration on `device`.
    fn upload_s_on(&self, device: DeviceId) -> f64 {
        let _ = device;
        self.upload_s()
    }
    /// Offload duration on `device`.
    fn offload_s_on(&self, device: DeviceId) -> f64 {
        let _ = device;
        self.offload_s()
    }
    /// Dual-forward duration of `module` on `device`.
    fn compute_s_on(&self, device: DeviceId, module: Module) -> f64 {
        let _ = device;
        self.compute_s(module)
    }
    /// Standalone update duration on `device`.
    fn update_s_on(&self, device: DeviceId) -> f64 {
        let _ = device;
        self.update_s()
    }
    /// cudaMalloc latency on `device`.
    fn malloc_s_on(&self, device: DeviceId) -> f64 {
        let _ = device;
        self.malloc_s()
    }
    /// Host fused decode on `device`'s host.
    fn host_decode_s_on(&self, device: DeviceId) -> f64 {
        let _ = device;
        self.host_decode_s()
    }
    /// Host fused encode on `device`'s host.
    fn host_encode_s_on(&self, device: DeviceId) -> f64 {
        let _ = device;
        self.host_encode_s()
    }
    /// NVMe read on `device`'s host.
    fn disk_read_s_on(&self, device: DeviceId) -> f64 {
        let _ = device;
        self.disk_read_s()
    }
    /// Bandwidth-only batched NVMe read on `device`'s host.
    fn disk_read_bw_s_on(&self, device: DeviceId) -> f64 {
        let _ = device;
        self.disk_read_bw_s()
    }
    /// NVMe write-back on `device`'s host.
    fn disk_write_s_on(&self, device: DeviceId) -> f64 {
        let _ = device;
        self.disk_write_s()
    }
    /// One microbatch slice of `module` on `device`.
    fn compute_microbatch_s_on(
        &self,
        device: DeviceId,
        module: Module,
        index: usize,
        of: usize,
    ) -> f64 {
        let _ = device;
        self.compute_microbatch_s(module, index, of)
    }
    /// Activation handoff sent by `device` (charged on the sender's
    /// interconnect stream; heterogeneous clusters price the sender's link).
    fn link_activation_s_from(&self, device: DeviceId) -> f64 {
        let _ = device;
        self.link_activation_s()
    }
    /// Microbatched activation handoff sent by `device`.
    fn link_activation_microbatch_s_from(&self, device: DeviceId, of: usize) -> f64 {
        let _ = device;
        self.link_activation_microbatch_s(of)
    }
}

// --- plan validation ---------------------------------------------------------

/// Orderable key for [`Module`] (which deliberately doesn't derive `Ord` —
/// block indices and the Embed/Head sentinels are not one number line).
fn mkey(m: Module) -> (u8, usize) {
    match m {
        Module::Embed => (0, 0),
        Module::Block(i) => (1, i),
        Module::Head => (2, 0),
    }
}

/// Orderable key for [`TaskKind`] (map-key use only).
fn kkey(k: TaskKind) -> u8 {
    match k {
        TaskKind::Upload => 0,
        TaskKind::Compute => 1,
        TaskKind::Offload => 2,
        TaskKind::Update => 3,
        TaskKind::DiskRead => 4,
        TaskKind::DiskWrite => 5,
        TaskKind::ActivationXfer => 6,
        TaskKind::SeedBcast => 7,
        TaskKind::GradReduce => 8,
    }
}

/// Statically check a built plan against the scheduling contract this
/// module's header documents — the semantic half of `zo2 lint`.
///
/// Checks, in order:
///
/// 1. **structure** — ids are the positions, deps are strictly ascending
///    and backward-only (so the DAG is acyclic by construction);
/// 2. **stream assignment** — overlapped plans put every task on its kind's
///    stream, naive plans serialise everything onto the compute stream;
/// 3. **per-stream FIFO** (rule 2) — every task depends on its stream
///    predecessor;
/// 4. **per-block chain** (rules 1 and the three-tier R→U / O→W links) —
///    within one `(device, step, block)` round-slot, each upload feeds a
///    compute, each first-microbatch compute consumes an upload, each
///    offload follows a compute, each disk read feeds an upload and each
///    disk write follows an offload;
/// 5. **read-after-write** (rule 4) — a disk read of a bucket depends on
///    the write that last updated it;
/// 6. **slot ring** — the k-th upload on a device waits for the offload
///    that freed its reusable-buffer slot (`policy.slots` earlier);
/// 7. **DRAM window** (rule 3) — the k-th disk read waits for the write
///    that freed its staging slot (that device's window depth earlier;
///    `dram_slots_per_device` carries per-partition depths, `None` means
///    the global `policy.dram_slots`);
/// 8. **placement** — pipeline plans upload each block on exactly one
///    device, DP plans (recognised by their seed broadcast) upload every
///    block on every device, once per step (twice when the efficient-update
///    ablation adds the standalone round), with identical per-replica spill
///    sets;
/// 9. **microbatches** — tags only on compute/activation tasks, one `of`
///    per plan, indices in range and strictly increasing within a stream's
///    per-module slice sequence.
///
/// Debug builds run this on every plan the builders emit (see
/// [`crate::shard::build_sharded_plan_tiered`]); `zo2 lint --plans` sweeps
/// it over a policy grid in release builds too.
pub fn validate_plan(
    tasks: &[Task],
    policy: &Policy,
    dram_slots_per_device: Option<&[usize]>,
) -> Result<(), Vec<String>> {
    use std::collections::BTreeSet;

    let mut errs: Vec<String> = Vec::new();

    // 1. Structure first: everything after indexes tasks by dep id.
    for (i, t) in tasks.iter().enumerate() {
        if t.id != i {
            errs.push(format!("task at position {i} carries id {}", t.id));
        }
        let mut prev: Option<usize> = None;
        for &d in &t.deps {
            if d >= t.id {
                errs.push(format!("task {}: dep {d} is not backward", t.id));
            }
            if let Some(p) = prev {
                if d <= p {
                    errs.push(format!("task {}: deps not strictly ascending", t.id));
                    break;
                }
            }
            prev = Some(d);
        }
    }
    if !errs.is_empty() {
        return Err(errs);
    }
    let has_dep = |t: &Task, id: usize| t.deps.binary_search(&id).is_ok();

    // 2. Stream assignment.
    for t in tasks {
        let want = if policy.overlap { t.kind.stream_kind() } else { StreamKind::Compute };
        if t.stream.kind != want {
            errs.push(format!(
                "task {} ({}): on stream {} but belongs on {}",
                t.id,
                t.kind.cat_name(),
                t.stream.kind.name(),
                want.name()
            ));
        }
    }

    // 3. Per-stream FIFO.
    let mut last_on: BTreeMap<StreamId, usize> = BTreeMap::new();
    for t in tasks {
        if let Some(&p) = last_on.get(&t.stream) {
            if !has_dep(t, p) {
                errs.push(format!(
                    "task {} ({}): skips its {} stream predecessor {p}",
                    t.id,
                    t.kind.cat_name(),
                    t.stream.name()
                ));
            }
        }
        last_on.insert(t.stream, t.id);
    }

    // 4. Per-block chain, within each (device, step, block) round-slot.
    #[derive(Default)]
    struct Slot {
        reads: Vec<usize>,
        uploads: Vec<usize>,
        computes: Vec<usize>,
        offloads: Vec<usize>,
        writes: Vec<usize>,
    }
    let mut slots: BTreeMap<(usize, usize, usize), Slot> = BTreeMap::new();
    for t in tasks {
        let bi = match t.module {
            Module::Block(i) => i,
            _ => continue,
        };
        let slot = slots.entry((t.device().0, t.step, bi)).or_default();
        match t.kind {
            TaskKind::DiskRead => slot.reads.push(t.id),
            TaskKind::Upload => slot.uploads.push(t.id),
            TaskKind::Compute | TaskKind::Update => slot.computes.push(t.id),
            TaskKind::Offload => slot.offloads.push(t.id),
            TaskKind::DiskWrite => slot.writes.push(t.id),
            _ => {}
        }
    }
    for ((dev, step, bi), slot) in &slots {
        let ctx = format!("device {dev} step {step} block {bi}");
        for &u in &slot.uploads {
            if !slot.computes.iter().any(|&c| has_dep(&tasks[c], u)) {
                errs.push(format!("{ctx}: upload {u} feeds no compute of its round"));
            }
        }
        for &c in &slot.computes {
            let t = &tasks[c];
            if t.microbatch.map_or(0, |m| m.index) == 0
                && !slot.uploads.iter().any(|&u| has_dep(t, u))
            {
                errs.push(format!("{ctx}: compute {c} runs without its round's upload"));
            }
        }
        for &o in &slot.offloads {
            if !slot.computes.iter().any(|&c| has_dep(&tasks[o], c)) {
                errs.push(format!("{ctx}: offload {o} does not follow a compute"));
            }
        }
        for &r in &slot.reads {
            if !slot.uploads.iter().any(|&u| has_dep(&tasks[u], r)) {
                errs.push(format!("{ctx}: disk read {r} feeds no upload"));
            }
        }
        for &w in &slot.writes {
            if !slot.offloads.iter().any(|&o| has_dep(&tasks[w], o)) {
                errs.push(format!("{ctx}: disk write {w} does not follow an offload"));
            }
        }
    }

    // 5. Read-after-write, per (device, block) in emission order.
    let mut last_w: BTreeMap<(usize, usize), usize> = BTreeMap::new();
    for t in tasks {
        let bi = match t.module {
            Module::Block(i) => i,
            _ => continue,
        };
        let key = (t.device().0, bi);
        match t.kind {
            TaskKind::DiskRead => {
                if let Some(&w) = last_w.get(&key) {
                    if !has_dep(t, w) {
                        errs.push(format!(
                            "task {}: disk read of block {bi} ignores its last write {w}",
                            t.id
                        ));
                    }
                }
            }
            TaskKind::DiskWrite => {
                last_w.insert(key, t.id);
            }
            _ => {}
        }
    }

    // 6 + 7. Resource rings: uploads/offloads and reads/writes strictly
    // alternate per device (each round opens with one and closes with the
    // other), so the k-th acquirer must wait on the (k - depth)-th releaser.
    let mut ups: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    let mut offs: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    let mut reads: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    let mut writes: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for t in tasks {
        let d = t.device().0;
        match t.kind {
            TaskKind::Upload => ups.entry(d).or_default().push(t.id),
            TaskKind::Offload => offs.entry(d).or_default().push(t.id),
            TaskKind::DiskRead => reads.entry(d).or_default().push(t.id),
            TaskKind::DiskWrite => writes.entry(d).or_default().push(t.id),
            _ => {}
        }
    }
    let n_slots = policy.slots.max(1);
    for (dev, us) in &ups {
        let os = offs.get(dev).map_or(&[][..], |v| v.as_slice());
        for (k, &u) in us.iter().enumerate() {
            if k < n_slots {
                continue;
            }
            match os.get(k - n_slots) {
                Some(&o) if has_dep(&tasks[u], o) => {}
                _ => errs.push(format!(
                    "device {dev}: upload {u} reuses slot {} without waiting for its offload",
                    k % n_slots
                )),
            }
        }
    }
    for (dev, rs) in &reads {
        let depth = dram_slots_per_device
            .and_then(|v| v.get(*dev).copied())
            .unwrap_or(policy.dram_slots)
            .max(1);
        let ws = writes.get(dev).map_or(&[][..], |v| v.as_slice());
        for (k, &r) in rs.iter().enumerate() {
            if k < depth {
                continue;
            }
            match ws.get(k - depth) {
                Some(&w) if has_dep(&tasks[r], w) => {}
                _ => errs.push(format!(
                    "device {dev}: disk read {r} reuses DRAM slot {} without its write-back",
                    k % depth
                )),
            }
        }
    }

    // 8. Placement: DP plans (which open each step with a seed broadcast)
    // replicate every block on every device; pipeline plans upload each
    // block on exactly one.  Both move each block once per step — twice
    // when the efficient-update ablation appends the standalone round.
    let steps = tasks.iter().map(|t| t.step + 1).max().unwrap_or(0);
    let rounds = if policy.efficient_update { 1 } else { 2 };
    let is_dp = tasks.iter().any(|t| t.kind == TaskKind::SeedBcast);
    let block_set: BTreeSet<usize> = tasks
        .iter()
        .filter_map(|t| match t.module {
            Module::Block(i) => Some(i),
            _ => None,
        })
        .collect();
    if is_dp {
        let dev_set: BTreeSet<usize> =
            tasks.iter().filter(|t| t.kind == TaskKind::Compute).map(|t| t.device().0).collect();
        for &d in &dev_set {
            for &bi in &block_set {
                for s in 0..steps {
                    let got = slots.get(&(d, s, bi)).map_or(0, |sl| sl.uploads.len());
                    if got != rounds {
                        errs.push(format!(
                            "device {d} step {s} block {bi}: {got} uploads, replica needs {rounds}"
                        ));
                    }
                }
            }
        }
        // Seed-synchronous replicas must agree on what spills: the on-disk
        // set is a property of the (shared) model + budget, not the worker.
        let mut spill_sets: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
        for &d in &dev_set {
            spill_sets.insert(d, BTreeSet::new());
        }
        for t in tasks.iter().filter(|t| t.kind == TaskKind::DiskRead) {
            if let Module::Block(bi) = t.module {
                spill_sets.entry(t.device().0).or_default().insert(bi);
            }
        }
        let mut iter = spill_sets.values();
        if let Some(first) = iter.next() {
            if iter.any(|s| s != first) {
                errs.push("DP replicas disagree on the spill set".to_string());
            }
        }
    } else {
        for &bi in &block_set {
            let devs: BTreeSet<usize> = tasks
                .iter()
                .filter(|t| t.kind == TaskKind::Upload && t.module == Module::Block(bi))
                .map(|t| t.device().0)
                .collect();
            if devs.len() > 1 {
                errs.push(format!(
                    "block {bi} uploads on {} devices; pipeline owns it once",
                    devs.len()
                ));
            }
            if let Some(&d) = devs.iter().next() {
                for s in 0..steps {
                    let got = slots.get(&(d, s, bi)).map_or(0, |sl| sl.uploads.len());
                    if got != rounds {
                        errs.push(format!(
                            "device {d} step {s} block {bi}: {got} uploads, expected {rounds}"
                        ));
                    }
                }
            }
        }
    }

    // 9. Microbatch tags.
    let mut of_seen: Option<usize> = None;
    let mut mb_last: BTreeMap<(usize, usize, (u8, usize), u8), usize> = BTreeMap::new();
    for t in tasks {
        let Some(m) = t.microbatch else { continue };
        if !matches!(t.kind, TaskKind::Compute | TaskKind::ActivationXfer) {
            errs.push(format!(
                "task {} ({}): only compute/activation tasks carry microbatch tags",
                t.id,
                t.kind.cat_name()
            ));
        }
        match of_seen {
            None => of_seen = Some(m.of),
            Some(o) if o != m.of => {
                errs.push(format!("task {}: microbatch of={} vs plan-wide of={o}", t.id, m.of));
            }
            _ => {}
        }
        if m.index >= m.of {
            errs.push(format!("task {}: microbatch index {} out of {}", t.id, m.index, m.of));
        }
        let key = (t.device().0, t.step, mkey(t.module), kkey(t.kind));
        if let Some(&prev) = mb_last.get(&key) {
            if m.index <= prev {
                errs.push(format!(
                    "task {}: microbatch index {} does not advance past {prev}",
                    t.id, m.index
                ));
            }
        }
        mb_last.insert(key, m.index);
    }

    if errs.is_empty() {
        Ok(())
    } else {
        Err(errs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_shape_one_step() {
        let p = build_plan(4, 1, Policy::default());
        // embed + 4*(U,C,O) + head = 14 tasks
        assert_eq!(p.len(), 14);
        let uploads = p.iter().filter(|t| t.kind == TaskKind::Upload).count();
        let offloads = p.iter().filter(|t| t.kind == TaskKind::Offload).count();
        assert_eq!(uploads, 4);
        assert_eq!(offloads, 4);
        // Single-device plans put every task on device 0.
        assert!(p.iter().all(|t| t.device() == DeviceId(0)));
    }

    #[test]
    fn non_efficient_update_doubles_transfers() {
        let p = build_plan(4, 1, Policy { efficient_update: false, ..Policy::default() });
        let uploads = p.iter().filter(|t| t.kind == TaskKind::Upload).count();
        let offloads = p.iter().filter(|t| t.kind == TaskKind::Offload).count();
        assert_eq!(uploads, 8, "each block uploaded twice per step (Fig. 5a)");
        assert_eq!(offloads, 8);
    }

    #[test]
    fn deps_point_backwards_and_chain() {
        let p = build_plan(6, 3, Policy::default());
        for t in &p {
            for &d in &t.deps {
                assert!(d < t.id, "dep {} of task {} must precede it", d, t.id);
            }
        }
        // Every compute on a block depends on its upload.
        for t in &p {
            if let (TaskKind::Compute, Module::Block(i)) = (t.kind, t.module) {
                let has_upload_dep = t.deps.iter().any(|&d| {
                    p[d].kind == TaskKind::Upload && p[d].module == Module::Block(i)
                        && p[d].step == t.step
                });
                assert!(has_upload_dep, "C(W{i}) must wait for U(W{i})");
            }
        }
    }

    #[test]
    fn naive_plan_is_single_stream() {
        let p = build_plan(4, 2, Policy::naive());
        assert!(p.iter().all(|t| t.stream == StreamId::new(0, StreamKind::Compute)));
    }

    #[test]
    fn slot_ring_blocks_uploads() {
        // With 1 slot, U(W1) must depend on O(W0).
        let p = build_plan(3, 1, Policy { slots: 1, ..Policy::default() });
        let u1 = p.iter().find(|t| t.kind == TaskKind::Upload && t.module == Module::Block(1)).unwrap();
        let dep_is_offload0 = u1.deps.iter().any(|&d| {
            p[d].kind == TaskKind::Offload && p[d].module == Module::Block(0)
        });
        assert!(dep_is_offload0);
    }

    #[test]
    fn three_tier_with_zero_spill_equals_two_tier() {
        let two = build_plan(5, 2, Policy::default());
        let three = build_plan(5, 2, Policy::three_tier(0, 4));
        assert_eq!(two.len(), three.len());
        for (a, b) in two.iter().zip(&three) {
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.deps, b.deps);
        }
    }

    #[test]
    fn spilled_blocks_get_full_five_task_chain() {
        // 6 blocks, 2 spilled: blocks 4 and 5 are on disk.
        let p = build_plan(6, 1, Policy::three_tier(2, 4));
        assert_eq!(p.len(), 14 + 6 + 2 * 2); // two-tier shape + 2 extra blocks' UCO + 2*(R+W)
        for i in 0..6 {
            let has_read = p.iter().any(|t| {
                t.kind == TaskKind::DiskRead && t.module == Module::Block(i)
            });
            let has_write = p.iter().any(|t| {
                t.kind == TaskKind::DiskWrite && t.module == Module::Block(i)
            });
            assert_eq!(has_read, i >= 4, "block {i} read");
            assert_eq!(has_write, i >= 4, "block {i} write");
        }
        // Chain: U(W4) depends on R(W4); W(W4) depends on O(W4).
        let r4 = p.iter().find(|t| t.kind == TaskKind::DiskRead && t.module == Module::Block(4)).unwrap();
        let u4 = p.iter().find(|t| t.kind == TaskKind::Upload && t.module == Module::Block(4)).unwrap();
        assert!(u4.deps.contains(&r4.id), "U(W4) must wait for R(W4)");
        let o4 = p.iter().find(|t| t.kind == TaskKind::Offload && t.module == Module::Block(4)).unwrap();
        let w4 = p.iter().find(|t| t.kind == TaskKind::DiskWrite && t.module == Module::Block(4)).unwrap();
        assert!(w4.deps.contains(&o4.id), "W(W4) must wait for O(W4)");
    }

    #[test]
    fn disk_read_waits_for_previous_steps_write() {
        // All blocks spilled, 2 steps: R at step 1 must depend on the same
        // block's W at step 0.
        let p = build_plan(3, 2, Policy::three_tier(3, 8));
        for i in 0..3 {
            let w0 = p.iter().find(|t| {
                t.kind == TaskKind::DiskWrite && t.module == Module::Block(i) && t.step == 0
            }).unwrap();
            let r1 = p.iter().find(|t| {
                t.kind == TaskKind::DiskRead && t.module == Module::Block(i) && t.step == 1
            }).unwrap();
            assert!(r1.deps.contains(&w0.id), "R(W{i}) step 1 must wait for W(W{i}) step 0");
        }
    }

    #[test]
    fn dram_ring_blocks_reads() {
        // 1 DRAM slot, all spilled: R(W1) must depend on W(W0).
        let p = build_plan(3, 1, Policy::three_tier(3, 1));
        let w0 = p.iter().find(|t| t.kind == TaskKind::DiskWrite && t.module == Module::Block(0)).unwrap();
        let r1 = p.iter().find(|t| t.kind == TaskKind::DiskRead && t.module == Module::Block(1)).unwrap();
        assert!(r1.deps.contains(&w0.id), "DRAM window of 1 must serialise spills");
    }

    #[test]
    fn interleaved_placement_spreads_the_spill_set() {
        // 6 blocks, 2 spilled: trailing = {4,5}, interleaved = {2,5}.
        let spilled =
            |pl| (0..6).filter(|&i| is_spilled_block(i, 6, 2, pl)).collect::<Vec<_>>();
        assert_eq!(spilled(SpillPlacement::Trailing), vec![4, 5]);
        assert_eq!(spilled(SpillPlacement::Interleaved), vec![2, 5]);
        // Every (n, spilled) pair places exactly `spilled` blocks.
        for n in 1..12usize {
            for s in 0..=n {
                for pl in [SpillPlacement::Trailing, SpillPlacement::Interleaved] {
                    let count = (0..n).filter(|&i| is_spilled_block(i, n, s, pl)).count();
                    assert_eq!(count, s, "n={n} spilled={s} {pl:?}");
                }
            }
        }
    }

    #[test]
    fn interleaved_plan_moves_disk_tasks_off_the_tail() {
        let policy = Policy {
            spill_placement: SpillPlacement::Interleaved,
            ..Policy::three_tier(2, 4)
        };
        let p = build_plan(6, 1, policy);
        let reads: Vec<usize> = p
            .iter()
            .filter(|t| t.kind == TaskKind::DiskRead)
            .map(|t| match t.module {
                Module::Block(i) => i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(reads, vec![2, 5]);
    }

    #[test]
    fn stream_names_are_stable() {
        assert_eq!(StreamId::new(0, StreamKind::Upload).name(), "upload");
        assert_eq!(StreamId::new(0, StreamKind::Interconnect).name(), "interconnect");
        assert_eq!(StreamId::new(1, StreamKind::Upload).name(), "d1.upload");
        assert_eq!(StreamId::new(3, StreamKind::DiskWrite).name(), "d3.disk_write");
        // Interned: repeated lookups return the same pointer.
        let a = StreamId::new(2, StreamKind::Compute).name();
        let b = StreamId::new(2, StreamKind::Compute).name();
        assert!(std::ptr::eq(a, b));
    }
}
