//! The ZO2 dynamic scheduler (paper §5.2, Algorithm 3).
//!
//! Three logical streams — Upload, Compute, Offload — mirror the three CUDA
//! streams of the paper.  Two dependency rules define correctness:
//!
//!  1. per-block chain:   U(Wᵢ) → C(Wᵢ) → O(Wᵢ)
//!  2. per-stream FIFO:   X(Wᵢ) waits for X(Wᵢ₋₁) of the same stream
//!
//! plus the resource rule that an upload needs a free slot of the reusable
//! block buffer (slot of block *i* frees when O(Wᵢ) completes; with S slots
//! U(Wᵢ) therefore waits on O(Wᵢ₋ₛ)).
//!
//! The same task DAG drives two executions:
//!  * [`analytic`]: a deterministic discrete-event schedule on virtual time
//!    using a [`CostProvider`] — this is how paper-scale (OPT-30B…175B)
//!    configurations are evaluated, and what emits the Fig. 4 timelines;
//!  * the *real* threaded engine in [`crate::zo::zo2_engine`], which
//!    realises the same dependency structure with worker threads around
//!    actual PJRT executions.
//!
//! Ablation flags reproduce Table 4:
//!  * `overlap = false` — the naive §5.2/Fig. 4a schedule: global sync after
//!    every task (single CUDA stream).
//!  * `reusable_mem = false` — every upload pays a cudaMalloc, and (as with
//!    real cudaMalloc) synchronises with the compute stream.
//!  * `efficient_update = false` — the §5.4 fusion is disabled: each step
//!    appends a second upload→update→offload round per block (Fig. 5a).

pub mod analytic;

pub use analytic::{simulate, Schedule};

/// Which stream a task runs on (paper Fig. 2's three CUDA streams).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stream {
    Upload,
    Compute,
    Offload,
}

/// Module position in the forward order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Module {
    Embed,
    Block(usize),
    Head,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Upload a block bucket CPU→GPU (includes decompression in AMP mode).
    Upload,
    /// Dual-forward compute (+ fused deferred update, §5.4).
    Compute,
    /// Offload a block bucket GPU→CPU (includes compression in AMP mode).
    Offload,
    /// Standalone parameter-update compute (only in the
    /// `efficient_update = false` ablation, Fig. 5a).
    Update,
}

#[derive(Debug, Clone)]
pub struct Task {
    pub id: usize,
    pub step: usize,
    pub module: Module,
    pub kind: TaskKind,
    pub stream: Stream,
    /// Indices of tasks that must complete first (beyond stream FIFO).
    pub deps: Vec<usize>,
    /// Extra fixed latency charged at task start (cudaMalloc in the
    /// no-reusable-memory ablation).
    pub extra_latency: f64,
}

/// Scheduler policy / ablation switches (Table 4).
#[derive(Debug, Clone, Copy)]
pub struct Policy {
    pub overlap: bool,
    pub reusable_mem: bool,
    pub efficient_update: bool,
    /// Reusable buffer slots (3 = compute + prefetch + offload in flight).
    pub slots: usize,
}

impl Default for Policy {
    fn default() -> Self {
        Self { overlap: true, reusable_mem: true, efficient_update: true, slots: 3 }
    }
}

impl Policy {
    pub fn naive() -> Self {
        Self { overlap: false, ..Self::default() }
    }
}

/// Build the task DAG for `steps` training steps over `n_blocks` offloaded
/// transformer blocks (embedding and LM head stay GPU-resident, §5.2).
pub fn build_plan(n_blocks: usize, steps: usize, policy: Policy) -> Vec<Task> {
    let mut tasks: Vec<Task> = Vec::new();
    // Per-stream last task id, for FIFO chaining.
    let mut last_on: [Option<usize>; 3] = [None, None, None];
    // id of O(Wᵢ) per in-flight slot ring.
    let mut offload_ring: Vec<Option<usize>> = vec![None; policy.slots.max(1)];
    let mut ring_pos = 0usize;
    // id of the last task overall (for naive global sync).
    let mut prev_any: Option<usize> = None;
    // id of the previous *compute* task (cudaMalloc sync in the
    // no-reusable-memory ablation).
    let mut prev_compute: Option<usize> = None;

    let stream_idx = |s: Stream| match s {
        Stream::Upload => 0,
        Stream::Compute => 1,
        Stream::Offload => 2,
    };

    let push = |tasks: &mut Vec<Task>,
                    last_on: &mut [Option<usize>; 3],
                    prev_any: &mut Option<usize>,
                    prev_compute: &mut Option<usize>,
                    step: usize,
                    module: Module,
                    kind: TaskKind,
                    mut deps: Vec<usize>,
                    extra_latency: f64| {
        let stream = if policy.overlap {
            match kind {
                TaskKind::Upload => Stream::Upload,
                TaskKind::Compute | TaskKind::Update => Stream::Compute,
                TaskKind::Offload => Stream::Offload,
            }
        } else {
            Stream::Compute // naive: one stream serialises everything
        };
        let id = tasks.len();
        // Stream FIFO.
        if let Some(p) = last_on[stream_idx(stream)] {
            deps.push(p);
        }
        // Naive global sync: depend on *every* previous task (equivalent to
        // depending on the last one since the single stream is FIFO anyway).
        if !policy.overlap {
            if let Some(p) = *prev_any {
                deps.push(p);
            }
        }
        deps.sort_unstable();
        deps.dedup();
        tasks.push(Task { id, step, module, kind, stream, deps, extra_latency });
        last_on[stream_idx(stream)] = Some(id);
        *prev_any = Some(id);
        if matches!(kind, TaskKind::Compute | TaskKind::Update) {
            *prev_compute = Some(id);
        }
        id
    };

    let malloc_sync = !policy.reusable_mem;

    for step in 0..steps {
        // C(Embedding) — resident, no upload.
        let c_embed = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                           step, Module::Embed, TaskKind::Compute, vec![], 0.0);
        let mut prev_c = c_embed;

        // Upload of block 0 may overlap the embedding compute (§5.2).
        let mut upload_ids: Vec<usize> = Vec::with_capacity(n_blocks);
        let mut compute_ids: Vec<usize> = Vec::with_capacity(n_blocks);

        for i in 0..n_blocks {
            // Slot reuse: U waits for the offload that frees this slot.
            let mut deps = Vec::new();
            if let Some(o) = offload_ring[ring_pos] {
                deps.push(o);
            }
            if malloc_sync {
                // cudaMalloc synchronises with the device: the upload cannot
                // overlap in-flight compute.
                if let Some(c) = prev_compute {
                    deps.push(c);
                }
            }
            let extra = 0.0; // malloc latency charged via CostProvider::malloc_s
            let u = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                         step, Module::Block(i), TaskKind::Upload, deps, extra);
            upload_ids.push(u);

            // C(Wᵢ) ← U(Wᵢ) (+ FIFO after previous compute).
            let c = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                         step, Module::Block(i), TaskKind::Compute, vec![u, prev_c], 0.0);
            compute_ids.push(c);
            prev_c = c;

            // O(Wᵢ) ← C(Wᵢ) (+ FIFO after previous offload).
            let o = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                         step, Module::Block(i), TaskKind::Offload, vec![c], 0.0);
            offload_ring[ring_pos] = Some(o);
            ring_pos = (ring_pos + 1) % offload_ring.len();
        }

        // C(LMHead) — resident.
        let _c_head = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                           step, Module::Head, TaskKind::Compute, vec![prev_c], 0.0);

        if !policy.efficient_update {
            // Fig. 5a: a second upload→update→offload round per block, after
            // the step's projected gradient is known (i.e. after the head).
            for i in 0..n_blocks {
                let mut deps = Vec::new();
                if let Some(o) = offload_ring[ring_pos] {
                    deps.push(o);
                }
                if malloc_sync {
                    if let Some(c) = prev_compute {
                        deps.push(c);
                    }
                }
                let u = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                             step, Module::Block(i), TaskKind::Upload, deps, 0.0);
                let c = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                             step, Module::Block(i), TaskKind::Update, vec![u], 0.0);
                let o = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                             step, Module::Block(i), TaskKind::Offload, vec![c], 0.0);
                offload_ring[ring_pos] = Some(o);
                ring_pos = (ring_pos + 1) % offload_ring.len();
            }
        }
    }
    tasks
}

/// Task durations, supplied either by the analytic cost model
/// ([`crate::costmodel`]) or by real measurements (calibration tests).
pub trait CostProvider {
    /// Upload duration for one block bucket (wire bytes / H2D bandwidth).
    fn upload_s(&self) -> f64;
    /// Offload duration for one block bucket.
    fn offload_s(&self) -> f64;
    /// Dual-forward (+fused update) duration for the given module.
    fn compute_s(&self, module: Module) -> f64;
    /// Standalone update duration (non-efficient-update ablation).
    fn update_s(&self) -> f64;
    /// cudaMalloc latency charged per upload when the reusable buffer is
    /// disabled.
    fn malloc_s(&self) -> f64 {
        300e-6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_shape_one_step() {
        let p = build_plan(4, 1, Policy::default());
        // embed + 4*(U,C,O) + head = 14 tasks
        assert_eq!(p.len(), 14);
        let uploads = p.iter().filter(|t| t.kind == TaskKind::Upload).count();
        let offloads = p.iter().filter(|t| t.kind == TaskKind::Offload).count();
        assert_eq!(uploads, 4);
        assert_eq!(offloads, 4);
    }

    #[test]
    fn non_efficient_update_doubles_transfers() {
        let p = build_plan(4, 1, Policy { efficient_update: false, ..Policy::default() });
        let uploads = p.iter().filter(|t| t.kind == TaskKind::Upload).count();
        let offloads = p.iter().filter(|t| t.kind == TaskKind::Offload).count();
        assert_eq!(uploads, 8, "each block uploaded twice per step (Fig. 5a)");
        assert_eq!(offloads, 8);
    }

    #[test]
    fn deps_point_backwards_and_chain() {
        let p = build_plan(6, 3, Policy::default());
        for t in &p {
            for &d in &t.deps {
                assert!(d < t.id, "dep {} of task {} must precede it", d, t.id);
            }
        }
        // Every compute on a block depends on its upload.
        for t in &p {
            if let (TaskKind::Compute, Module::Block(i)) = (t.kind, t.module) {
                let has_upload_dep = t.deps.iter().any(|&d| {
                    p[d].kind == TaskKind::Upload && p[d].module == Module::Block(i)
                        && p[d].step == t.step
                });
                assert!(has_upload_dep, "C(W{i}) must wait for U(W{i})");
            }
        }
    }

    #[test]
    fn naive_plan_is_single_stream() {
        let p = build_plan(4, 2, Policy::naive());
        assert!(p.iter().all(|t| t.stream == Stream::Compute));
    }

    #[test]
    fn slot_ring_blocks_uploads() {
        // With 1 slot, U(W1) must depend on O(W0).
        let p = build_plan(3, 1, Policy { slots: 1, ..Policy::default() });
        let u1 = p.iter().find(|t| t.kind == TaskKind::Upload && t.module == Module::Block(1)).unwrap();
        let dep_is_offload0 = u1.deps.iter().any(|&d| {
            p[d].kind == TaskKind::Offload && p[d].module == Module::Block(0)
        });
        assert!(dep_is_offload0);
    }
}
