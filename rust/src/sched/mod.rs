//! The ZO2 dynamic scheduler (paper §5.2, Algorithm 3), extended with a
//! disk tier.
//!
//! Two-tier mode mirrors the paper's three CUDA streams — Upload, Compute,
//! Offload — with two dependency rules:
//!
//!  1. per-block chain:   U(Wᵢ) → C(Wᵢ) → O(Wᵢ)
//!  2. per-stream FIFO:   X(Wᵢ) waits for X(Wᵢ₋₁) of the same stream
//!
//! plus the resource rule that an upload needs a free slot of the reusable
//! block buffer (slot of block *i* frees when O(Wᵢ) completes; with S slots
//! U(Wᵢ) therefore waits on O(Wᵢ₋ₛ)).
//!
//! Three-tier mode ([`Tiering::ThreeTier`]) adds two streams — DiskRead,
//! DiskWrite — for blocks spilled to NVMe.  A spilled block's chain grows
//! to R(Wᵢ) → U(Wᵢ) → C(Wᵢ) → O(Wᵢ) → W(Wᵢ), with two more rules:
//!
//!  3. DRAM-window resource rule (mirror of the reusable-buffer rule): a
//!     disk read needs a free slot of the DRAM staging window; the slot of
//!     block *i* frees when W(Wᵢ) completes, so with D slots R waits on the
//!     W that ran D spills earlier.  The window is also the *look-ahead*
//!     of the prefetcher: reads run up to D spilled blocks ahead of
//!     compute, so the read for block i+k overlaps compute on block i.
//!  4. disk read-after-write: R of block *i* at step *j+1* waits for W of
//!     block *i* at step *j* (the bucket on disk is the updated one).
//!
//! The same task DAG drives two executions:
//!  * [`analytic`]: a deterministic discrete-event schedule on virtual time
//!    using a [`CostProvider`] — this is how paper-scale (OPT-30B…175B)
//!    configurations are evaluated, and what emits the Fig. 4 timelines;
//!  * the *real* threaded engine in [`crate::zo::Zo2Engine`], which
//!    realises the same dependency structure with worker threads around
//!    actual PJRT executions (plus real file I/O for the disk tier).
//!
//! Ablation flags reproduce Table 4:
//!  * `overlap = false` — the naive §5.2/Fig. 4a schedule: global sync after
//!    every task (single CUDA stream).
//!  * `reusable_mem = false` — every upload pays a cudaMalloc, and (as with
//!    real cudaMalloc) synchronises with the compute stream.
//!  * `efficient_update = false` — the §5.4 fusion is disabled: each step
//!    appends a second upload→update→offload round per block (Fig. 5a).

pub mod analytic;

pub use analytic::{simulate, Schedule};

/// Which stream a task runs on (the paper's three CUDA streams, plus the
/// two disk queues of the three-tier extension).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stream {
    Upload,
    Compute,
    Offload,
    DiskRead,
    DiskWrite,
}

pub const ALL_STREAMS: [Stream; 5] =
    [Stream::Upload, Stream::Compute, Stream::Offload, Stream::DiskRead, Stream::DiskWrite];

/// Module position in the forward order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Module {
    Embed,
    Block(usize),
    Head,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Upload a block bucket CPU→GPU (includes decompression in AMP mode).
    Upload,
    /// Dual-forward compute (+ fused deferred update, §5.4).
    Compute,
    /// Offload a block bucket GPU→CPU (includes compression in AMP mode).
    Offload,
    /// Standalone parameter-update compute (only in the
    /// `efficient_update = false` ablation, Fig. 5a).
    Update,
    /// Read a spilled block bucket NVMe→DDR (three-tier prefetch).
    DiskRead,
    /// Write an updated spilled bucket DDR→NVMe (three-tier write-back).
    DiskWrite,
}

#[derive(Debug, Clone)]
pub struct Task {
    pub id: usize,
    pub step: usize,
    pub module: Module,
    pub kind: TaskKind,
    pub stream: Stream,
    /// Indices of tasks that must complete first (beyond stream FIFO).
    pub deps: Vec<usize>,
    /// Extra fixed latency charged at task start (cudaMalloc in the
    /// no-reusable-memory ablation).
    pub extra_latency: f64,
}

/// Where block master copies live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tiering {
    /// Paper baseline: every block bucket DDR-resident.
    TwoTier,
    /// Disk tier below DDR: buckets beyond the DRAM budget spill to NVMe
    /// and stream through the DRAM staging window.
    ThreeTier,
}

/// Scheduler policy / ablation switches (Table 4 + the disk tier).
#[derive(Debug, Clone, Copy)]
pub struct Policy {
    pub overlap: bool,
    pub reusable_mem: bool,
    pub efficient_update: bool,
    /// Reusable buffer slots (3 = compute + prefetch + offload in flight).
    pub slots: usize,
    pub tiering: Tiering,
    /// DRAM staging-window slots = disk prefetch look-ahead (three-tier).
    pub dram_slots: usize,
    /// Number of trailing blocks spilled to the disk tier (three-tier;
    /// 0 = everything fits in DDR and the plan degenerates to two-tier).
    pub spilled: usize,
    /// io_uring-style disk-read batching: up to this many back-to-back
    /// queued reads share one submission-latency charge (1 = off).  Only
    /// the latency coalesces — bandwidth is still paid per read.
    pub disk_batch: usize,
}

impl Default for Policy {
    fn default() -> Self {
        Self {
            overlap: true,
            reusable_mem: true,
            efficient_update: true,
            slots: 3,
            tiering: Tiering::TwoTier,
            dram_slots: 4,
            spilled: 0,
            disk_batch: 1,
        }
    }
}

impl Policy {
    pub fn naive() -> Self {
        Self { overlap: false, ..Self::default() }
    }

    /// Three-tier policy with `spilled` blocks on the disk tier.
    pub fn three_tier(spilled: usize, dram_slots: usize) -> Self {
        Self { tiering: Tiering::ThreeTier, spilled, dram_slots, ..Self::default() }
    }
}

/// Build the task DAG for `steps` training steps over `n_blocks` offloaded
/// transformer blocks (embedding and LM head stay GPU-resident, §5.2).
/// In three-tier mode the last `policy.spilled` blocks additionally stream
/// through the disk tier (R before U, W after O).
pub fn build_plan(n_blocks: usize, steps: usize, policy: Policy) -> Vec<Task> {
    let mut tasks: Vec<Task> = Vec::new();
    // Per-stream last task id, for FIFO chaining.
    let mut last_on: [Option<usize>; 5] = [None; 5];
    // id of O(Wᵢ) per in-flight slot ring.
    let mut offload_ring: Vec<Option<usize>> = vec![None; policy.slots.max(1)];
    let mut ring_pos = 0usize;
    // id of W(Wᵢ) per DRAM staging-window slot ring (three-tier).
    let mut dram_ring: Vec<Option<usize>> = vec![None; policy.dram_slots.max(1)];
    let mut dram_pos = 0usize;
    // id of the last DiskWrite per block (read-after-write across steps).
    let mut last_write: Vec<Option<usize>> = vec![None; n_blocks];
    // id of the last task overall (for naive global sync).
    let mut prev_any: Option<usize> = None;
    // id of the previous *compute* task (cudaMalloc sync in the
    // no-reusable-memory ablation).
    let mut prev_compute: Option<usize> = None;

    let spilled = match policy.tiering {
        Tiering::TwoTier => 0,
        Tiering::ThreeTier => policy.spilled.min(n_blocks),
    };
    let on_disk = |i: usize| i >= n_blocks - spilled;

    let stream_idx = |s: Stream| match s {
        Stream::Upload => 0,
        Stream::Compute => 1,
        Stream::Offload => 2,
        Stream::DiskRead => 3,
        Stream::DiskWrite => 4,
    };

    let push = |tasks: &mut Vec<Task>,
                    last_on: &mut [Option<usize>; 5],
                    prev_any: &mut Option<usize>,
                    prev_compute: &mut Option<usize>,
                    step: usize,
                    module: Module,
                    kind: TaskKind,
                    mut deps: Vec<usize>,
                    extra_latency: f64| {
        let stream = if policy.overlap {
            match kind {
                TaskKind::Upload => Stream::Upload,
                TaskKind::Compute | TaskKind::Update => Stream::Compute,
                TaskKind::Offload => Stream::Offload,
                TaskKind::DiskRead => Stream::DiskRead,
                TaskKind::DiskWrite => Stream::DiskWrite,
            }
        } else {
            Stream::Compute // naive: one stream serialises everything
        };
        let id = tasks.len();
        // Stream FIFO.
        if let Some(p) = last_on[stream_idx(stream)] {
            deps.push(p);
        }
        // Naive global sync: depend on *every* previous task (equivalent to
        // depending on the last one since the single stream is FIFO anyway).
        if !policy.overlap {
            if let Some(p) = *prev_any {
                deps.push(p);
            }
        }
        deps.sort_unstable();
        deps.dedup();
        tasks.push(Task { id, step, module, kind, stream, deps, extra_latency });
        last_on[stream_idx(stream)] = Some(id);
        *prev_any = Some(id);
        if matches!(kind, TaskKind::Compute | TaskKind::Update) {
            *prev_compute = Some(id);
        }
        id
    };

    let malloc_sync = !policy.reusable_mem;

    for step in 0..steps {
        // C(Embedding) — resident, no upload.
        let c_embed = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                           step, Module::Embed, TaskKind::Compute, vec![], 0.0);
        let mut prev_c = c_embed;

        // Upload of block 0 may overlap the embedding compute (§5.2).
        for i in 0..n_blocks {
            let mut deps = Vec::new();
            // Three-tier: R(Wᵢ) stages the spilled bucket into the DRAM
            // window before the upload can push it over PCIe.
            if on_disk(i) {
                let mut rdeps = Vec::new();
                // DRAM-window rule: R needs a free staging slot, freed by
                // the W that ran `dram_slots` spills earlier.
                if let Some(w) = dram_ring[dram_pos] {
                    rdeps.push(w);
                }
                // Read-after-write: the on-disk bucket is the one the
                // previous step's W wrote back.
                if let Some(w) = last_write[i] {
                    rdeps.push(w);
                }
                let r = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                             step, Module::Block(i), TaskKind::DiskRead, rdeps, 0.0);
                deps.push(r);
            }
            // Slot reuse: U waits for the offload that frees this slot.
            if let Some(o) = offload_ring[ring_pos] {
                deps.push(o);
            }
            if malloc_sync {
                // cudaMalloc synchronises with the device: the upload cannot
                // overlap in-flight compute.
                if let Some(c) = prev_compute {
                    deps.push(c);
                }
            }
            let extra = 0.0; // malloc latency charged via CostProvider::malloc_s
            let u = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                         step, Module::Block(i), TaskKind::Upload, deps, extra);

            // C(Wᵢ) ← U(Wᵢ) (+ FIFO after previous compute).
            let c = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                         step, Module::Block(i), TaskKind::Compute, vec![u, prev_c], 0.0);
            prev_c = c;

            // O(Wᵢ) ← C(Wᵢ) (+ FIFO after previous offload).
            let o = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                         step, Module::Block(i), TaskKind::Offload, vec![c], 0.0);
            offload_ring[ring_pos] = Some(o);
            ring_pos = (ring_pos + 1) % offload_ring.len();

            // W(Wᵢ) ← O(Wᵢ): write the updated bucket back to NVMe and free
            // its DRAM staging slot.
            if on_disk(i) {
                let w = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                             step, Module::Block(i), TaskKind::DiskWrite, vec![o], 0.0);
                dram_ring[dram_pos] = Some(w);
                dram_pos = (dram_pos + 1) % dram_ring.len();
                last_write[i] = Some(w);
            }
        }

        // C(LMHead) — resident.
        let _c_head = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                           step, Module::Head, TaskKind::Compute, vec![prev_c], 0.0);

        if !policy.efficient_update {
            // Fig. 5a: a second upload→update→offload round per block, after
            // the step's projected gradient is known (i.e. after the head).
            for i in 0..n_blocks {
                let mut deps = Vec::new();
                if on_disk(i) {
                    let mut rdeps = Vec::new();
                    if let Some(w) = dram_ring[dram_pos] {
                        rdeps.push(w);
                    }
                    if let Some(w) = last_write[i] {
                        rdeps.push(w);
                    }
                    let r = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                                 step, Module::Block(i), TaskKind::DiskRead, rdeps, 0.0);
                    deps.push(r);
                }
                if let Some(o) = offload_ring[ring_pos] {
                    deps.push(o);
                }
                if malloc_sync {
                    if let Some(c) = prev_compute {
                        deps.push(c);
                    }
                }
                let u = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                             step, Module::Block(i), TaskKind::Upload, deps, 0.0);
                let c = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                             step, Module::Block(i), TaskKind::Update, vec![u], 0.0);
                let o = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                             step, Module::Block(i), TaskKind::Offload, vec![c], 0.0);
                offload_ring[ring_pos] = Some(o);
                ring_pos = (ring_pos + 1) % offload_ring.len();
                if on_disk(i) {
                    let w = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                                 step, Module::Block(i), TaskKind::DiskWrite, vec![o], 0.0);
                    dram_ring[dram_pos] = Some(w);
                    dram_pos = (dram_pos + 1) % dram_ring.len();
                    last_write[i] = Some(w);
                }
            }
        }
    }
    tasks
}

/// Task durations, supplied either by the analytic cost model
/// ([`crate::costmodel`]) or by real measurements (calibration tests).
pub trait CostProvider {
    /// Upload duration for one block bucket (wire bytes / H2D bandwidth).
    fn upload_s(&self) -> f64;
    /// Offload duration for one block bucket.
    fn offload_s(&self) -> f64;
    /// Dual-forward (+fused update) duration for the given module.
    fn compute_s(&self, module: Module) -> f64;
    /// Standalone update duration (non-efficient-update ablation).
    fn update_s(&self) -> f64;
    /// cudaMalloc latency charged per upload when the reusable buffer is
    /// disabled.
    fn malloc_s(&self) -> f64 {
        300e-6
    }
    /// Host fused-kernel decode per upload (the real engine decodes wire
    /// bytes on host cores in the upload thread).  Providers that do not
    /// model host kernels keep the zero default.
    fn host_decode_s(&self) -> f64 {
        0.0
    }
    /// Host fused-kernel encode per offload.
    fn host_encode_s(&self) -> f64 {
        0.0
    }
    /// NVMe read of one spilled block bucket (three-tier only; two-tier
    /// providers keep the zero default).
    fn disk_read_s(&self) -> f64 {
        0.0
    }
    /// Bandwidth-only cost of a read that joins an io_uring-style batch
    /// (its submission latency was charged by the batch's first read).
    /// Defaults to the full read cost, i.e. batching gains nothing unless
    /// the provider separates latency from bandwidth.
    fn disk_read_bw_s(&self) -> f64 {
        self.disk_read_s()
    }
    /// NVMe write-back of one spilled block bucket.
    fn disk_write_s(&self) -> f64 {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_shape_one_step() {
        let p = build_plan(4, 1, Policy::default());
        // embed + 4*(U,C,O) + head = 14 tasks
        assert_eq!(p.len(), 14);
        let uploads = p.iter().filter(|t| t.kind == TaskKind::Upload).count();
        let offloads = p.iter().filter(|t| t.kind == TaskKind::Offload).count();
        assert_eq!(uploads, 4);
        assert_eq!(offloads, 4);
    }

    #[test]
    fn non_efficient_update_doubles_transfers() {
        let p = build_plan(4, 1, Policy { efficient_update: false, ..Policy::default() });
        let uploads = p.iter().filter(|t| t.kind == TaskKind::Upload).count();
        let offloads = p.iter().filter(|t| t.kind == TaskKind::Offload).count();
        assert_eq!(uploads, 8, "each block uploaded twice per step (Fig. 5a)");
        assert_eq!(offloads, 8);
    }

    #[test]
    fn deps_point_backwards_and_chain() {
        let p = build_plan(6, 3, Policy::default());
        for t in &p {
            for &d in &t.deps {
                assert!(d < t.id, "dep {} of task {} must precede it", d, t.id);
            }
        }
        // Every compute on a block depends on its upload.
        for t in &p {
            if let (TaskKind::Compute, Module::Block(i)) = (t.kind, t.module) {
                let has_upload_dep = t.deps.iter().any(|&d| {
                    p[d].kind == TaskKind::Upload && p[d].module == Module::Block(i)
                        && p[d].step == t.step
                });
                assert!(has_upload_dep, "C(W{i}) must wait for U(W{i})");
            }
        }
    }

    #[test]
    fn naive_plan_is_single_stream() {
        let p = build_plan(4, 2, Policy::naive());
        assert!(p.iter().all(|t| t.stream == Stream::Compute));
    }

    #[test]
    fn slot_ring_blocks_uploads() {
        // With 1 slot, U(W1) must depend on O(W0).
        let p = build_plan(3, 1, Policy { slots: 1, ..Policy::default() });
        let u1 = p.iter().find(|t| t.kind == TaskKind::Upload && t.module == Module::Block(1)).unwrap();
        let dep_is_offload0 = u1.deps.iter().any(|&d| {
            p[d].kind == TaskKind::Offload && p[d].module == Module::Block(0)
        });
        assert!(dep_is_offload0);
    }

    #[test]
    fn three_tier_with_zero_spill_equals_two_tier() {
        let two = build_plan(5, 2, Policy::default());
        let three = build_plan(5, 2, Policy::three_tier(0, 4));
        assert_eq!(two.len(), three.len());
        for (a, b) in two.iter().zip(&three) {
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.deps, b.deps);
        }
    }

    #[test]
    fn spilled_blocks_get_full_five_task_chain() {
        // 6 blocks, 2 spilled: blocks 4 and 5 are on disk.
        let p = build_plan(6, 1, Policy::three_tier(2, 4));
        assert_eq!(p.len(), 14 + 6 + 2 * 2); // two-tier shape + 2 extra blocks' UCO + 2*(R+W)
        for i in 0..6 {
            let has_read = p.iter().any(|t| {
                t.kind == TaskKind::DiskRead && t.module == Module::Block(i)
            });
            let has_write = p.iter().any(|t| {
                t.kind == TaskKind::DiskWrite && t.module == Module::Block(i)
            });
            assert_eq!(has_read, i >= 4, "block {i} read");
            assert_eq!(has_write, i >= 4, "block {i} write");
        }
        // Chain: U(W4) depends on R(W4); W(W4) depends on O(W4).
        let r4 = p.iter().find(|t| t.kind == TaskKind::DiskRead && t.module == Module::Block(4)).unwrap();
        let u4 = p.iter().find(|t| t.kind == TaskKind::Upload && t.module == Module::Block(4)).unwrap();
        assert!(u4.deps.contains(&r4.id), "U(W4) must wait for R(W4)");
        let o4 = p.iter().find(|t| t.kind == TaskKind::Offload && t.module == Module::Block(4)).unwrap();
        let w4 = p.iter().find(|t| t.kind == TaskKind::DiskWrite && t.module == Module::Block(4)).unwrap();
        assert!(w4.deps.contains(&o4.id), "W(W4) must wait for O(W4)");
    }

    #[test]
    fn disk_read_waits_for_previous_steps_write() {
        // All blocks spilled, 2 steps: R at step 1 must depend on the same
        // block's W at step 0.
        let p = build_plan(3, 2, Policy::three_tier(3, 8));
        for i in 0..3 {
            let w0 = p.iter().find(|t| {
                t.kind == TaskKind::DiskWrite && t.module == Module::Block(i) && t.step == 0
            }).unwrap();
            let r1 = p.iter().find(|t| {
                t.kind == TaskKind::DiskRead && t.module == Module::Block(i) && t.step == 1
            }).unwrap();
            assert!(r1.deps.contains(&w0.id), "R(W{i}) step 1 must wait for W(W{i}) step 0");
        }
    }

    #[test]
    fn dram_ring_blocks_reads() {
        // 1 DRAM slot, all spilled: R(W1) must depend on W(W0).
        let p = build_plan(3, 1, Policy::three_tier(3, 1));
        let w0 = p.iter().find(|t| t.kind == TaskKind::DiskWrite && t.module == Module::Block(0)).unwrap();
        let r1 = p.iter().find(|t| t.kind == TaskKind::DiskRead && t.module == Module::Block(1)).unwrap();
        assert!(r1.deps.contains(&w0.id), "DRAM window of 1 must serialise spills");
    }
}
