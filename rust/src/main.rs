//! zo2 — CLI for the ZO2 reproduction.
//!
//! Subcommands:
//!   train      train a compiled config with MeZO or ZO2 (real PJRT execution)
//!   simulate   paper-scale throughput/memory via the discrete-event simulator
//!   memory     print the Fig. 1 memory table (analytic accounting)
//!   info       show a config's manifest summary
//!   tune       autotune the offload/shard knobs against the simulator
//!              (deterministic beam+anneal search; emits a replayable
//!              `zo2-tune-v1` report — see README "Autotuning")
//!   report     diff a simulated trace against a measured one (drift JSON)
//!   dp         run the elastic fault-tolerant DP backend (real transports,
//!              fault schedules, checkpoints — see README "Fault tolerance")
//!   dp-worker  internal: one DP worker process (spawned by `dp` with
//!              `--dp-processes`)
//!   lint       repo-native static analysis: the determinism, panic-freedom,
//!              unsafe-audit and schema-literal rules plus the scheduling-DAG
//!              validator (`--plans`) — exits nonzero on unwaived findings
//!
//! `train` and `simulate` accept `--trace-out FILE.json` (Chrome
//! trace-event JSON, openable in chrome://tracing or ui.perfetto.dev) and
//! `--metrics-out FILE.json` (labeled metrics snapshot).  Without those
//! flags the instrumentation is fully disabled — no events, no registry
//! entries, bit-identical trajectories.
//!
//! Every numeric flag is parsed *checked*: a malformed value (`--devices
//! foo`, `--lr 1e-4x`) is a hard error naming the flag and token, never a
//! silent fall-back to the default.  `--device-spec`, `--dram-budget`,
//! `--link` and `--link-gbps` accept comma lists for heterogeneous
//! clusters (one entry per device, or a single entry for all).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use zo2::coordinator::{train, EngineKind, TrainConfig};
use zo2::costmodel::{
    gpu_memory_bytes, min_hbm_capacity, plan_three_tier, plan_three_tier_owned,
    two_tier_dram_bytes, Cluster, ClusterCost, ComputeMode, Hardware, HostKernels, Interconnect,
    MemoryBudget, SimCost, Strategy, TierPlan, Workload,
};
use zo2::model::{opt_by_name, opt_family};
use zo2::precision::Codec;
use zo2::runtime::Runtime;
use zo2::sched::{build_plan, simulate, Policy, SpillPlacement, Tiering};
use zo2::shard::{
    blocks_per_device, blocks_per_device_of, bottleneck_weights, build_sharded_plan_tiered,
    weighted_contiguous_owners, DeviceTier, ShardLayout, ShardSpec, ShardStrategy,
};
use zo2::tune::{
    report_json, tune, CalibrationReport, LayoutChoice, Scenario, SearchSpace, TuneOpts,
    TUNE_SCHEMA,
};
use zo2::util::cli::Args;
use zo2::util::fmt_mb;
use zo2::util::json::Json;
use zo2::zo::{RunMode, UpdateSite, ZoConfig};

/// Flags that never take a value (so `zo2 run --timeline cfg.json` keeps
/// `cfg.json` positional — see `util::cli`).
const BOOL_FLAGS: &[&str] = &[
    "timeline",
    "no-reusable-mem",
    "no-efficient-update",
    "resume",
    "dp-processes",
    "host-pin",
    "plans",
];

/// Apply the process-wide host-kernel switches (`--host-simd`,
/// `--disk-uring`) before any subcommand builds an engine.  Both default to
/// `auto`; unknown values are hard errors, never silent fallbacks.
fn set_kernel_switches(args: &Args) -> Result<()> {
    let simd = args.get_or("host-simd", "auto");
    let mode = zo2::simd::SimdMode::parse(&simd)
        .ok_or_else(|| anyhow::anyhow!("unknown --host-simd `{simd}` (expected auto|off)"))?;
    zo2::simd::set_mode(mode);
    match args.get_or("disk-uring", "auto").as_str() {
        "auto" => zo2::memory::disk::set_disk_uring(true),
        "off" => zo2::memory::disk::set_disk_uring(false),
        u => bail!("unknown --disk-uring `{u}` (expected auto|off)"),
    }
    Ok(())
}

/// `--host-threads N` (0 = auto-detect machine parallelism).  Parsed
/// checked like every numeric flag; a value beyond the pool's 512-CPU
/// affinity-mask limit is rejected rather than silently clamped.
fn parse_host_threads(args: &Args) -> Result<usize> {
    let t = args.get_usize_checked("host-threads", 0)?;
    anyhow::ensure!(t <= 512, "bad --host-threads: {t} (max 512; 0 = auto-detect)");
    Ok(t)
}

fn main() -> Result<()> {
    let mut args = Args::from_env_with_bools(BOOL_FLAGS);
    apply_tuned_config(&mut args)?;
    set_kernel_switches(&args)?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("tune") => cmd_tune(&args),
        Some("memory") => cmd_memory(&args),
        Some("info") => cmd_info(&args),
        Some("report") => cmd_report(&args),
        Some("dp") => cmd_dp(&args),
        Some("dp-worker") => cmd_dp_worker(&args),
        Some("lint") => cmd_lint(&args),
        _ => {
            eprintln!(
                "usage: zo2 <train|simulate|tune|memory|info|report|lint> [--config tiny] [--engine zo2|mezo]\n\
                 \x20      [--steps N] [--lr F] [--eps F] [--seed N] [--wire fp32|bf16|fp16|fp8]\n\
                 \x20      [--mode seq|overlap] [--model OPT-13B] [--compute fp32|tf32|fp16]\n\
                 \x20      [--tiering two|three] [--dram-budget GB[,GB,...]] [--dram-slots N]\n\
                 \x20      [--nvme-gbps F] [--nvme-write-gbps F] [--disk-batch N]\n\
                 \x20      [--spill-placement trailing|interleaved]\n\
                 \x20      [--update-site device|cpu] [--host-threads N] [--host-simd auto|off]\n\
                 \x20      [--host-pin] [--disk-uring auto|off] [--dp-workers K] [--dp-shards S]\n\
                 \x20      [--devices N] [--device-spec a100:2,rtx4090:2] [--shard dp|pipeline]\n\
                 \x20      [--layout contiguous|cyclic|weighted] [--link nvlink|pcie[,...]]\n\
                 \x20      [--link-gbps F[,F,...]] [--microbatches M]\n\
                 \x20      [--trace-out FILE.json] [--metrics-out FILE.json]\n\
                 \x20  tune [simulate scenario flags] [--tune-seed N] [--beam K] [--anneal-iters N]\n\
                 \x20      [--topk K] [--calibrate BENCH.json[,BENCH2.json]] [--out tuned.json]\n\
                 \x20      [--tune-slots L] [--tune-dram-slots L] [--tune-disk-batch L]\n\
                 \x20      [--tune-microbatches L] [--tune-strategies dp,pipeline]\n\
                 \x20      [--tune-layouts contiguous,cyclic,weighted] [--tune-spill trailing,...]\n\
                 \x20  simulate|train --config tuned.json   (replay a tune report's best flags)\n\
                 \x20  report --sim sim_trace.json --measured run_trace.json [--out drift.json]\n\
                 \x20  lint [--src DIR] [--json REPORT.json] [--plans]\n\
                 \x20  dp [--dp-transport chan|unix[:/path]|tcp[:host:port]] [--dp-workers K]\n\
                 \x20      [--dp-shards S] [--steps N] [--fault-schedule SPEC|seeded:N|none]\n\
                 \x20      [--checkpoint FILE.pool] [--checkpoint-every N] [--resume]\n\
                 \x20      [--dp-processes] [--losses-out FILE.json] [--metrics-out FILE.json]"
            );
            Ok(())
        }
    }
}

fn parse_tiering(args: &Args) -> Result<Tiering> {
    let t = args.get_or("tiering", "two");
    Tiering::parse(&t).ok_or_else(|| anyhow::anyhow!("unknown tiering `{t}` (expected two|three)"))
}

fn parse_spill_placement(args: &Args) -> Result<SpillPlacement> {
    let p = args.get_or("spill-placement", "trailing");
    SpillPlacement::parse(&p).ok_or_else(|| {
        anyhow::anyhow!("unknown spill placement `{p}` (expected trailing|interleaved)")
    })
}

/// Parse `--dram-budget` as GB values in bytes — one per host, or one value
/// broadcast to all hosts (`--dram-budget 64` / `--dram-budget 64,32,32,64`).
/// Shared by `train` and `simulate`: the flag is required whenever the
/// caller reaches this (three-tier mode), and every entry must be a
/// positive number — no silent defaults, no zero/negative budgets.
fn parse_dram_budgets(args: &Args, hosts: usize) -> Result<Vec<u64>> {
    let list = args.get_f64_list_checked("dram-budget")?.ok_or_else(|| {
        anyhow::anyhow!(
            "--tiering three requires --dram-budget <GB[,GB,...]> (the DDR budget per host \
             that decides which blocks spill)"
        )
    })?;
    for &gb in &list {
        anyhow::ensure!(
            gb > 0.0 && gb.is_finite(),
            "bad --dram-budget: {gb} GB (every host budget must be positive)"
        );
    }
    let bytes: Vec<u64> = list.iter().map(|gb| (gb * (1u64 << 30) as f64) as u64).collect();
    if bytes.len() == 1 {
        return Ok(vec![bytes[0]; hosts.max(1)]);
    }
    anyhow::ensure!(
        bytes.len() == hosts,
        "--dram-budget lists {} budgets for {hosts} host(s); give one value or one per host",
        bytes.len()
    );
    Ok(bytes)
}

/// Parse `--device-spec a100:2,rtx4090:2` into one [`Hardware`] per device
/// (entries are `preset[:count]`, expanded in order — device 0 first).
/// Without the flag: `devices_flag` copies of the A100 default.  With both
/// flags, the expanded list length must agree with `--devices`.
fn parse_device_specs(args: &Args, devices_flag: Option<usize>) -> Result<Vec<Hardware>> {
    let Some(raw) = args.get("device-spec") else {
        return Ok(vec![Hardware::a100_pcie4(); devices_flag.unwrap_or(1).max(1)]);
    };
    let mut out = Vec::new();
    for entry in raw.split(',') {
        let entry = entry.trim();
        let (name, count) = match entry.split_once(':') {
            Some((n, c)) => {
                let count: usize = c.parse().map_err(|_| {
                    anyhow::anyhow!(
                        "bad --device-spec `{raw}`: count `{c}` in `{entry}` is not an \
                         unsigned integer"
                    )
                })?;
                (n, count)
            }
            None => (entry, 1),
        };
        anyhow::ensure!(count > 0, "bad --device-spec `{raw}`: `{entry}` asks for zero devices");
        let hw = Hardware::by_name(name).ok_or_else(|| {
            anyhow::anyhow!(
                "bad --device-spec `{raw}`: unknown hardware `{name}` (known presets: {})",
                Hardware::PRESET_NAMES.join(", ")
            )
        })?;
        out.extend(std::iter::repeat(hw).take(count));
    }
    anyhow::ensure!(!out.is_empty(), "--device-spec must name at least one device");
    if let Some(n) = devices_flag {
        anyhow::ensure!(
            out.len() == n,
            "--device-spec lists {} device(s) but --devices says {n}; drop one flag or make \
             them agree",
            out.len()
        );
    }
    Ok(out)
}

/// Parse `--link` / `--link-gbps` into one [`Interconnect`] per device —
/// `links[d]` is what device `d` *sends* on.  Single entries broadcast;
/// lists must have one entry per device.
fn parse_links(args: &Args, devices: usize) -> Result<Vec<Interconnect>> {
    let raw = args.get_or("link", "nvlink");
    let mut base: Vec<Interconnect> = Vec::new();
    for tok in raw.split(',') {
        match tok.trim() {
            "nvlink" => base.push(Interconnect::nvlink()),
            "pcie" | "pcie-p2p" => base.push(Interconnect::pcie_p2p()),
            l => bail!("unknown link `{l}` in --link `{raw}` (expected nvlink|pcie)"),
        }
    }
    let mut links = if base.len() == 1 {
        vec![base[0].clone(); devices]
    } else {
        anyhow::ensure!(
            base.len() == devices,
            "--link lists {} link(s) for {devices} device(s); give one class or one per device",
            base.len()
        );
        base
    };
    if let Some(gbps) = args.get_f64_list_checked("link-gbps")? {
        for &g in &gbps {
            anyhow::ensure!(g > 0.0 && g.is_finite(), "bad --link-gbps: {g} (must be positive)");
        }
        if gbps.len() == 1 {
            for l in links.iter_mut() {
                *l = l.clone().with_gbps(gbps[0]);
            }
        } else {
            anyhow::ensure!(
                gbps.len() == devices,
                "--link-gbps lists {} value(s) for {devices} device(s); give one or one per \
                 device",
                gbps.len()
            );
            for (l, &g) in links.iter_mut().zip(&gbps) {
                *l = l.clone().with_gbps(g);
            }
        }
    }
    Ok(links)
}

/// Refuse a tier plan its host cannot actually hold: a DDR peak (including
/// the plan's own staging window) above the budget, or any other tier
/// overflowing.  `who` names the host in the error.
fn ensure_budget_feasible(plan: &TierPlan, budget: &MemoryBudget, who: &str) -> Result<()> {
    anyhow::ensure!(
        plan.peaks.dram <= budget.dram,
        "{who}: DDR peak {} MB (incl. the {}-slot staging window) exceeds its --dram-budget \
         ({} MB) — lower --dram-slots or raise this host's budget",
        fmt_mb(plan.peaks.dram),
        plan.dram_slots,
        fmt_mb(budget.dram),
    );
    anyhow::ensure!(
        budget.fits(&plan.peaks),
        "{who}: tier peaks {:?} do not fit the host budget {:?}",
        plan.peaks,
        budget,
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let tiering = parse_tiering(args)?;
    // Three-tier requires an explicit budget; a budget given in two-tier
    // mode is still validated (never silently ignored or defaulted).
    let dram_budget_bytes = if tiering == Tiering::ThreeTier || args.has("dram-budget") {
        Some(parse_dram_budgets(args, 1)?[0])
    } else {
        None
    };
    let cfg = TrainConfig {
        config_name: args.get_or("config", "tiny"),
        steps: args.get_usize_checked("steps", 20)?,
        zo: ZoConfig {
            lr: args.get_f64_checked("lr", 1e-4)? as f32,
            eps: args.get_f64_checked("eps", 1e-3)? as f32,
            seed: args.get_usize_checked("seed", 42)? as u64,
        },
        engine: match args.get_or("engine", "zo2").as_str() {
            "mezo" => EngineKind::Mezo,
            "zo2" => EngineKind::Zo2,
            e => bail!("unknown engine `{e}`"),
        },
        wire: Codec::parse(&args.get_or("wire", "fp32")).ok_or_else(|| anyhow::anyhow!("bad wire"))?,
        run_mode: match args.get_or("mode", "overlap").as_str() {
            "seq" => RunMode::Sequential,
            "overlap" => RunMode::Overlapped,
            m => bail!("unknown mode `{m}`"),
        },
        log_every: args.get_usize_checked("log-every", 10)?,
        tiering,
        dram_budget_bytes,
        dram_slots: args.get_usize_checked("dram-slots", 4)?,
        spill_placement: parse_spill_placement(args)?,
        update_site: match args.get_or("update-site", "device").as_str() {
            "device" | "gpu" => UpdateSite::Device,
            "cpu" | "host" => UpdateSite::Cpu,
            s => bail!("unknown update site `{s}` (expected device|cpu)"),
        },
        host_threads: parse_host_threads(args)?,
        host_pin: args.get_bool("host-pin"),
        dp_workers: args.get_usize_checked("dp-workers", 1)?.max(1),
        dp_shards: args.get_usize_checked("dp-shards", 0)?,
        trace_out: args.get("trace-out").map(String::from),
        metrics_out: args.get("metrics-out").map(String::from),
    };
    let report = train(&cfg, true)?;
    println!(
        "done: {:.0} tok/s, final eval loss {:.4}, device peak {} MB, transfers {} MB",
        report.tokens_per_s,
        report.final_eval_loss,
        fmt_mb(report.device_peak_bytes),
        fmt_mb(report.transfer_bytes)
    );
    if report.spilled_blocks > 0 {
        println!(
            "disk tier: {} spilled blocks, {} MB NVMe traffic",
            report.spilled_blocks,
            fmt_mb(report.disk_bytes)
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let name = args.get_or("model", "OPT-13B");
    let shape = opt_by_name(&name).ok_or_else(|| anyhow::anyhow!("unknown model {name}"))?;
    let read_gbps = args.get_f64_checked("nvme-gbps", 6.8)?;
    anyhow::ensure!(read_gbps > 0.0, "bad --nvme-gbps: {read_gbps} (must be positive)");
    let write_gbps = args.get_f64_checked("nvme-write-gbps", read_gbps * 0.75)?;
    anyhow::ensure!(write_gbps > 0.0, "bad --nvme-write-gbps: {write_gbps} (must be positive)");

    // Device list: `--devices N` homogeneous A100s, or an explicit
    // (possibly mixed) `--device-spec` list.
    let devices_flag = if args.has("devices") {
        Some(args.get_usize_checked("devices", 1)?.max(1))
    } else {
        None
    };
    let hw_list: Vec<Hardware> = parse_device_specs(args, devices_flag)?
        .into_iter()
        .map(|hw| hw.with_nvme_gbps(read_gbps, write_gbps))
        .collect();
    let devices = hw_list.len();

    let wire = Codec::parse(&args.get_or("wire", "fp32"))
        .ok_or_else(|| anyhow::anyhow!("bad wire"))?;
    let wl = Workload {
        shape,
        batch: args.get_usize_checked("batch", 1)?,
        seq: args.get_usize_checked("seq", 2048)?,
        wire,
        compute: match args.get_or("compute", "fp32").as_str() {
            "tf32" => ComputeMode::Tf32,
            "fp16" => ComputeMode::Fp16,
            "bf16" => ComputeMode::Bf16,
            _ => ComputeMode::Fp32,
        },
    };
    let param_bytes = wire.bytes_per_el().min(4);
    let tiering = parse_tiering(args)?;
    let dram_slots = args.get_usize_checked("dram-slots", 4)?;
    let spill_placement = parse_spill_placement(args)?;
    let steps = args.get_usize_checked("sim-steps", 4)?;
    let microbatches = args.get_usize_checked("microbatches", 1)?.max(1);
    let strategy = match args.get_or("shard", "dp").as_str() {
        "dp" | "data-parallel" => ShardStrategy::DataParallel,
        "pipeline" | "pp" => ShardStrategy::Pipeline,
        s => bail!("unknown shard strategy `{s}` (expected dp|pipeline)"),
    };
    // `weighted` is the bottleneck-aware placement hint: contiguous, but
    // with block counts proportional to each device's block-round
    // throughput (more blocks on the faster hosts of a mixed cluster).
    let (layout, weighted) = match args.get_or("layout", "contiguous").as_str() {
        "contiguous" | "block" => (ShardLayout::Contiguous, false),
        "cyclic" | "roundrobin" => (ShardLayout::Cyclic, false),
        "weighted" | "hint" => (ShardLayout::Contiguous, true),
        l => bail!("unknown layout `{l}` (expected contiguous|cyclic|weighted)"),
    };
    if weighted && (devices == 1 || strategy != ShardStrategy::Pipeline) {
        bail!(
            "--layout weighted is a pipeline block-placement hint: it needs --devices N \
             (or --device-spec) with --shard pipeline"
        );
    }
    if microbatches > 1 && (devices == 1 || strategy != ShardStrategy::Pipeline) {
        bail!(
            "--microbatches M splits the step for pipeline sharding: it needs \
             --devices N --shard pipeline (for DP, batch slicing is the engine's \
             --dp-shards)"
        );
    }
    let mut policy = Policy {
        overlap: args.get_or("mode", "overlap") != "seq",
        reusable_mem: !args.has("no-reusable-mem"),
        efficient_update: !args.has("no-efficient-update"),
        slots: args.get_usize_checked("slots", 3)?,
        disk_batch: args.get_usize_checked("disk-batch", 1)?.max(1),
        spill_placement,
        dram_slots,
        ..Policy::default()
    };

    // Flags outside their active branch are still validated, never silently
    // dropped: a malformed budget or link list is a hard error in ANY mode
    // (the checked-parsing contract this CLI promises).
    if tiering == Tiering::TwoTier && args.has("dram-budget") {
        parse_dram_budgets(args, devices)?;
    }
    if devices == 1 && (args.has("link") || args.has("link-gbps")) {
        parse_links(args, 1)?;
    }

    if devices > 1 {
        // Multi-GPU simulation: per-device streams, per-device hardware
        // pricing, and per-device links.
        let links = parse_links(args, devices)?;
        let spec = ShardSpec { devices, layout, strategy, microbatches };
        let link_desc = if links.windows(2).all(|w| w[0].name == w[1].name) {
            links[0].name.clone()
        } else {
            "mixed".to_string()
        };
        let cluster = Cluster { devices: hw_list.clone(), links };
        let costs = ClusterCost::new(&cluster, &wl)?;

        // Block placement: the layout's owner rule, or the weighted hint.
        let owners: Option<Vec<usize>> = if weighted {
            let weights = bottleneck_weights(&costs, devices);
            Some(weighted_contiguous_owners(wl.shape.n_layers, &weights))
        } else {
            None
        };
        let per_dev = match &owners {
            Some(o) => blocks_per_device_of(o, devices),
            None => blocks_per_device(layout, wl.shape.n_layers, devices),
        };

        let mut tiers: Option<Vec<DeviceTier>> = None;
        if tiering == Tiering::ThreeTier {
            let budget_bytes = parse_dram_budgets(args, devices)?;
            if strategy == ShardStrategy::Pipeline {
                // Per-partition planning: each pipeline host holds only its
                // own blocks, so its spill set AND its staging-window depth
                // are sized against its own DRAM budget.
                let budgets: Vec<MemoryBudget> = budget_bytes
                    .iter()
                    .zip(&hw_list)
                    .map(|(&dram, hw)| MemoryBudget { hbm: hw.hbm_capacity, dram, nvme: 2 << 40 })
                    .collect();
                let counts: Vec<usize> = per_dev.iter().map(|v| v.len()).collect();
                let hws: Vec<&Hardware> = hw_list.iter().collect();
                let plans = plan_three_tier_owned(
                    &wl,
                    &budgets,
                    &counts,
                    policy.slots,
                    dram_slots,
                    param_bytes,
                    &hws,
                    spill_placement,
                );
                policy.tiering = Tiering::ThreeTier;
                policy.spilled = plans.iter().map(|p| p.spilled_blocks).sum();
                println!(
                    "tiers (per partition; a full copy would need {} MB):",
                    fmt_mb(two_tier_dram_bytes(&wl)),
                );
                for (d, plan) in plans.iter().enumerate() {
                    // A budget smaller than the staging window itself is
                    // infeasible — refuse, naming the device, rather than
                    // simulate a host that cannot hold its own window.
                    ensure_budget_feasible(
                        plan,
                        &budgets[d],
                        &format!("device {d} ({})", hw_list[d].name),
                    )?;
                    println!(
                        "  device {d} ({}, {:.0} GB DDR): {} blocks in DDR + {} on NVMe | \
                         peaks: DDR {} MB, NVMe {} MB",
                        hw_list[d].name,
                        budget_bytes[d] as f64 / (1u64 << 30) as f64,
                        plan.resident_blocks,
                        plan.spilled_blocks,
                        fmt_mb(plan.peaks.dram),
                        fmt_mb(plan.peaks.nvme),
                    );
                }
                tiers = Some(plans.iter().map(|p| p.device_tier()).collect());
            } else {
                // DP: every replica holds a full copy under one shared spill
                // plan, so genuinely distinct per-host budgets cannot be
                // honoured on this path yet.
                anyhow::ensure!(
                    budget_bytes.windows(2).all(|w| w[0] == w[1]),
                    "--shard dp runs a full replica per host with one shared spill plan; \
                     distinct per-host --dram-budget values need --shard pipeline (or give \
                     every host the same budget)"
                );
                // Checked min: an empty device list reaches this through
                // programmatic callers (the autotuner sweeps here too) and
                // must be a named error, never an unwrap panic.
                let hbm = min_hbm_capacity(&hw_list)?;
                let budget = MemoryBudget { hbm, dram: budget_bytes[0], nvme: 2 << 40 };
                let plan = plan_three_tier(
                    &wl,
                    &budget,
                    policy.slots,
                    dram_slots,
                    param_bytes,
                    &hw_list[0],
                    spill_placement,
                );
                ensure_budget_feasible(&plan, &budget, "each DP replica's host")?;
                policy.tiering = Tiering::ThreeTier;
                policy.spilled = plan.spilled_blocks;
                policy.dram_slots = plan.dram_slots.max(1);
                println!(
                    "tiers (per DP replica): {} blocks in DDR + {} on NVMe | peaks: DDR {} MB \
                     (two-tier would need {} MB), NVMe {} MB",
                    plan.resident_blocks,
                    plan.spilled_blocks,
                    fmt_mb(plan.peaks.dram),
                    fmt_mb(two_tier_dram_bytes(&wl)),
                    fmt_mb(plan.peaks.nvme),
                );
            }
        }

        let plan = build_sharded_plan_tiered(
            wl.shape.n_layers,
            steps,
            policy,
            &spec,
            tiers.as_deref(),
            owners.as_deref(),
        );
        let (sched, timeline) = simulate(&plan, &costs, policy);
        // DP runs one batch shard per device (weak scaling); pipeline runs
        // the single stream across devices.
        let tokens_per_step = match strategy {
            ShardStrategy::DataParallel => (devices * wl.batch * wl.seq) as f64,
            ShardStrategy::Pipeline => (wl.batch * wl.seq) as f64,
        };
        println!(
            "{name} x{devices} {} ({}{}): step {:.3}s  ->  {:.0} tokens/s  \
             (makespan {:.3}s over {steps} steps, {}, link {})",
            match strategy {
                ShardStrategy::DataParallel => "dp",
                ShardStrategy::Pipeline => "pipeline",
            },
            if weighted {
                "weighted"
            } else {
                match layout {
                    ShardLayout::Contiguous => "contiguous",
                    ShardLayout::Cyclic => "cyclic",
                }
            },
            if microbatches > 1 { format!(", M={microbatches}") } else { String::new() },
            sched.steady_step_s,
            tokens_per_step / sched.steady_step_s,
            sched.makespan,
            sched.bottleneck(),
            link_desc,
        );
        for d in sched.devices() {
            let owned = match strategy {
                ShardStrategy::Pipeline => per_dev[d.0].len(),
                ShardStrategy::DataParallel => wl.shape.n_layers,
            };
            println!(
                "  device {} ({}): {} blocks, {}",
                d.0,
                hw_list[d.0].name,
                owned,
                sched.bottleneck_of(d)
            );
        }
        if args.has("timeline") {
            println!("{}", timeline.to_ascii_gantt(100));
        }
        write_sim_observability(args, &sched, &timeline)?;
        return Ok(());
    }

    // Single device (the paper's setting).
    let hw = &hw_list[0];
    if tiering == Tiering::ThreeTier {
        let dram = parse_dram_budgets(args, 1)?[0];
        let budget = MemoryBudget { hbm: hw.hbm_capacity, dram, nvme: 2 << 40 };
        let plan =
            plan_three_tier(&wl, &budget, policy.slots, dram_slots, param_bytes, hw, spill_placement);
        // Same feasibility rule as the sharded branches: a budget smaller
        // than the staging window cannot run at all.
        ensure_budget_feasible(&plan, &budget, "this host")?;
        policy.tiering = Tiering::ThreeTier;
        policy.spilled = plan.spilled_blocks;
        policy.dram_slots = plan.dram_slots.max(1);
        println!(
            "tiers: {} blocks in DDR + {} on NVMe | peaks: HBM {} MB, DDR {} MB \
             (two-tier would need {} MB), NVMe {} MB",
            plan.resident_blocks,
            plan.spilled_blocks,
            fmt_mb(plan.peaks.hbm),
            fmt_mb(plan.peaks.dram),
            fmt_mb(two_tier_dram_bytes(&wl)),
            fmt_mb(plan.peaks.nvme),
        );
    }

    let costs = SimCost::new(hw, &wl);
    let plan = build_plan(wl.shape.n_layers, steps, policy);
    let (sched, timeline) = simulate(&plan, &costs, policy);
    let tokens = (wl.batch * wl.seq) as f64;
    println!(
        "{name}: step {:.3}s  ->  {:.0} tokens/s  (makespan {:.3}s over {steps} steps, {})",
        sched.steady_step_s,
        tokens / sched.steady_step_s,
        sched.makespan,
        sched.bottleneck(),
    );
    if args.has("timeline") {
        println!("{}", timeline.to_ascii_gantt(100));
    }
    write_sim_observability(args, &sched, &timeline)?;
    Ok(())
}

/// Shared `--trace-out` / `--metrics-out` tail of both `simulate` branches:
/// the plan timeline goes through the same Chrome-trace exporter the engine
/// uses, and the schedule's busy map becomes a metrics snapshot.
fn write_sim_observability(
    args: &Args,
    sched: &zo2::sched::Schedule,
    timeline: &zo2::telemetry::Timeline,
) -> Result<()> {
    if let Some(path) = args.get("trace-out") {
        zo2::telemetry::trace::write_chrome_trace(path, timeline)?;
        println!("wrote trace {path}");
    }
    if let Some(path) = args.get("metrics-out") {
        let reg = zo2::telemetry::metrics::MetricsRegistry::new();
        let mut streams: Vec<_> = sched.busy.iter().collect();
        streams.sort_by_key(|(id, _)| **id);
        for (id, &busy) in streams {
            let device = id.device.0.to_string();
            reg.gauge_set(
                "sim_stream_busy_s",
                &[("device", device.as_str()), ("stream", id.kind.name())],
                busy,
            );
        }
        reg.gauge_set("sim_makespan_s", &[], sched.makespan);
        reg.gauge_set("sim_steady_step_s", &[], sched.steady_step_s);
        std::fs::write(path, reg.snapshot_json().to_string_pretty())
            .map_err(|e| anyhow::anyhow!("writing metrics {path}: {e}"))?;
        println!("wrote metrics {path}");
    }
    Ok(())
}

/// Parse `--KEY a,b,c` as positive integers (search-space overrides for
/// `tune`).  Checked like every list flag: malformed, fractional or zero
/// entries are hard errors naming the flag.
fn parse_usize_list(args: &Args, key: &str) -> Result<Option<Vec<usize>>> {
    let Some(list) = args.get_f64_list_checked(key)? else {
        return Ok(None);
    };
    let mut out = Vec::with_capacity(list.len());
    for &v in &list {
        anyhow::ensure!(
            v.is_finite() && v >= 1.0 && v.fract() == 0.0,
            "bad --{key}: {v} (expected positive integers)"
        );
        out.push(v as usize);
    }
    Ok(Some(out))
}

/// Parse `--KEY name1,name2` through a knob's `parse` function (search-space
/// overrides for `tune`); unknown names are hard errors naming the flag.
fn parse_name_list<T>(
    args: &Args,
    key: &str,
    parse: impl Fn(&str) -> Option<T>,
    expected: &str,
) -> Result<Option<Vec<T>>> {
    let Some(raw) = args.get(key) else {
        return Ok(None);
    };
    let mut out = Vec::new();
    for tok in raw.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        out.push(
            parse(tok)
                .ok_or_else(|| anyhow::anyhow!("bad --{key}: `{tok}` (expected {expected})"))?,
        );
    }
    anyhow::ensure!(!out.is_empty(), "bad --{key}: empty list (expected {expected})");
    Ok(Some(out))
}

/// `zo2 tune` — search the policy space for the scenario these flags
/// describe, with the analytic simulator as the oracle (see the [`zo2::tune`]
/// module docs).  Scenario parsing mirrors `simulate` exactly, so the
/// reported best config replays bit-for-bit through
/// `simulate --config tuned.json`.
fn cmd_tune(args: &Args) -> Result<()> {
    let name = args.get_or("model", "OPT-13B");
    let shape = opt_by_name(&name).ok_or_else(|| anyhow::anyhow!("unknown model {name}"))?;
    let read_gbps = args.get_f64_checked("nvme-gbps", 6.8)?;
    anyhow::ensure!(read_gbps > 0.0, "bad --nvme-gbps: {read_gbps} (must be positive)");
    let write_gbps = args.get_f64_checked("nvme-write-gbps", read_gbps * 0.75)?;
    anyhow::ensure!(write_gbps > 0.0, "bad --nvme-write-gbps: {write_gbps} (must be positive)");
    let devices_flag = if args.has("devices") {
        Some(args.get_usize_checked("devices", 1)?.max(1))
    } else {
        None
    };
    let mut hw_list: Vec<Hardware> = parse_device_specs(args, devices_flag)?
        .into_iter()
        .map(|hw| hw.with_nvme_gbps(read_gbps, write_gbps))
        .collect();
    let devices = hw_list.len();
    let wire = Codec::parse(&args.get_or("wire", "fp32"))
        .ok_or_else(|| anyhow::anyhow!("bad wire"))?;
    let wl = Workload {
        shape,
        batch: args.get_usize_checked("batch", 1)?,
        seq: args.get_usize_checked("seq", 2048)?,
        wire,
        compute: match args.get_or("compute", "fp32").as_str() {
            "tf32" => ComputeMode::Tf32,
            "fp16" => ComputeMode::Fp16,
            "bf16" => ComputeMode::Bf16,
            _ => ComputeMode::Fp32,
        },
    };
    let param_bytes = wire.bytes_per_el().min(4);
    let tiering = parse_tiering(args)?;
    let steps = args.get_usize_checked("sim-steps", 4)?;
    let dram_budget_bytes = if tiering == Tiering::ThreeTier {
        Some(parse_dram_budgets(args, devices)?)
    } else {
        // Checked-parsing contract: a budget given in two-tier mode is
        // still validated, never silently dropped.
        if args.has("dram-budget") {
            parse_dram_budgets(args, devices)?;
        }
        None
    };
    let links = parse_links(args, devices)?;

    // Calibration: a host-kernel bench retunes the oracle's host-side
    // rates before the search; a sim-gauge snapshot is recorded for the
    // report's predicted-vs-measured drift rows.  The oracle is never
    // rescaled by measured gauges — that would break `--config` replay.
    let mut calibration = CalibrationReport::default();
    if let Some(raw) = args.get("calibrate") {
        for path in raw.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            calibration.files.push(path.to_string());
            match HostKernels::from_bench_json(path) {
                Ok(hk) => {
                    for hw in hw_list.iter_mut() {
                        hw.host = hk;
                    }
                    calibration.host_kernels = true;
                }
                Err(host_err) => match SimCost::from_bench_json(path) {
                    Ok(gauges) => {
                        for (k, v) in gauges.entries() {
                            calibration.sim_gauges.push((k.0.clone(), k.1, k.2.clone(), v));
                        }
                    }
                    Err(sim_err) => bail!(
                        "--calibrate {path}: not a host-kernel bench ({host_err}) and not a \
                         sim-gauge snapshot ({sim_err})"
                    ),
                },
            }
        }
    }

    let mut space = SearchSpace::default_for(devices, tiering == Tiering::ThreeTier);
    if let Some(v) = parse_usize_list(args, "tune-slots")? {
        space.slots = v;
    }
    if let Some(v) = parse_usize_list(args, "tune-dram-slots")? {
        space.dram_slots = v;
    }
    if let Some(v) = parse_usize_list(args, "tune-disk-batch")? {
        space.disk_batch = v;
    }
    if let Some(v) = parse_usize_list(args, "tune-microbatches")? {
        space.microbatches = v;
    }
    if let Some(v) = parse_name_list(args, "tune-strategies", ShardStrategy::parse, "dp|pipeline")?
    {
        space.strategies = v;
    }
    if let Some(v) =
        parse_name_list(args, "tune-layouts", LayoutChoice::parse, "contiguous|cyclic|weighted")?
    {
        space.layouts = v;
    }
    if let Some(v) =
        parse_name_list(args, "tune-spill", SpillPlacement::parse, "trailing|interleaved")?
    {
        space.spill_placements = v;
    }

    let opts = TuneOpts {
        seed: args.get_usize_checked("tune-seed", 0)? as u64,
        beam: args.get_usize_checked("beam", 4)?.max(1),
        anneal_iters: args.get_usize_checked("anneal-iters", 64)?,
        topk: args.get_usize_checked("topk", 5)?.max(1),
    };

    // Scenario flags: everything `simulate --config tuned.json` needs to
    // rebuild this exact scenario (the tuned knobs come from the winning
    // candidate; explicit CLI flags at replay time still win).
    let mut scenario_flags: BTreeMap<String, String> = BTreeMap::new();
    scenario_flags.insert("model".to_string(), name.clone());
    if let Some(spec) = args.get("device-spec") {
        scenario_flags.insert("device-spec".to_string(), spec.to_string());
    } else {
        scenario_flags.insert("devices".to_string(), devices.to_string());
    }
    scenario_flags.insert("tiering".to_string(), tiering.name().to_string());
    for key in [
        "wire",
        "compute",
        "batch",
        "seq",
        "sim-steps",
        "nvme-gbps",
        "nvme-write-gbps",
        "link",
        "link-gbps",
        "dram-budget",
    ] {
        if let Some(v) = args.get(key) {
            scenario_flags.insert(key.to_string(), v.to_string());
        }
    }

    let sc = Scenario { wl, hw: hw_list, links, dram_budget_bytes, steps, param_bytes };
    let result = tune(&sc, &space, &opts)?;

    println!(
        "space: {} configs | explored {} ({} pruned as infeasible) | seed {}",
        result.space_size,
        result.explored,
        result.pruned.len(),
        opts.seed,
    );
    match &result.best {
        Some(best) => {
            println!("best: {}", best.cand.key());
            println!(
                "  predicted: step {:.4}s -> {:.0} tokens/s ({})",
                best.step_s, best.tokens_per_s, best.bottleneck
            );
        }
        None => {
            println!("no feasible configuration in the space (see the report's pruned reasons)")
        }
    }
    let report = report_json(&sc, &space, &opts, &result, &scenario_flags, &calibration);
    let text = report.to_string_pretty();
    if let Some(out) = args.get("out") {
        std::fs::write(out, &text).map_err(|e| anyhow::anyhow!("writing report {out}: {e}"))?;
        println!("wrote tune report {out} (replay: zo2 simulate --config {out})");
    } else {
        println!("{text}");
    }
    Ok(())
}

/// `--config FILE.json` replays a `zo2-tune-v1` report: the best config's
/// flags fill in every flag the command line leaves unset (explicit flags
/// win), then the flag itself is consumed so downstream parsing never sees
/// it.  Non-`.json` values are compiled-config names (`train --config
/// tiny`) and pass through untouched.
fn apply_tuned_config(args: &mut Args) -> Result<()> {
    let Some(path) = args.get("config").map(String::from) else {
        return Ok(());
    };
    if !path.ends_with(".json") {
        return Ok(());
    }
    let text =
        std::fs::read_to_string(&path).map_err(|e| anyhow::anyhow!("--config {path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("--config {path}: {e}"))?;
    let schema =
        doc.get("schema").and_then(|s| s.as_str()).map(str::to_string).unwrap_or_default();
    anyhow::ensure!(
        schema == TUNE_SCHEMA,
        "--config {path}: schema `{schema}` is not a tune report (expected {TUNE_SCHEMA})"
    );
    let best = doc.get("best").map_err(|e| anyhow::anyhow!("--config {path}: {e}"))?;
    anyhow::ensure!(
        !matches!(best, Json::Null),
        "--config {path}: the report records no feasible best config to replay"
    );
    let flags = best
        .get("flags")
        .and_then(|f| f.as_obj())
        .map_err(|e| anyhow::anyhow!("--config {path}: {e}"))?;
    for (k, v) in flags {
        let v = v.as_str().map_err(|e| anyhow::anyhow!("--config {path}: flag {k}: {e}"))?;
        if !args.flags.contains_key(k) {
            args.flags.insert(k.clone(), v.to_string());
        }
    }
    args.flags.remove("config");
    Ok(())
}

/// `zo2 report --sim a.json --measured b.json [--out drift.json]`:
/// per-stream, per-task-kind and makespan drift between a simulated plan
/// trace and a measured run trace of the same config.
fn cmd_report(args: &Args) -> Result<()> {
    use zo2::telemetry::trace;
    let sim_path = args
        .get("sim")
        .ok_or_else(|| anyhow::anyhow!("report needs --sim SIM_TRACE.json"))?;
    let measured_path = args
        .get("measured")
        .ok_or_else(|| anyhow::anyhow!("report needs --measured RUN_TRACE.json"))?;
    let sim = trace::load_trace(sim_path)?;
    let measured = trace::load_trace(measured_path)?;
    let rep = trace::drift_report(&sim, &measured)?;

    let mk = rep.get("makespan_s")?;
    print!(
        "makespan: sim {:.3}s, measured {:.3}s",
        mk.get("sim")?.as_f64()?,
        mk.get("measured")?.as_f64()?
    );
    match mk.get("ratio")? {
        zo2::util::json::Json::Num(r) => println!(" ({r:.2}x)"),
        _ => println!(),
    }
    for s in rep.get("streams")?.as_arr()? {
        println!(
            "  d{} {:<12} sim {:>9.3}s  measured {:>9.3}s  delta {:+.3}s",
            s.get("device")?.as_usize()?,
            s.get("stream")?.as_str()?,
            s.get("sim_busy_s")?.as_f64()?,
            s.get("measured_busy_s")?.as_f64()?,
            s.get("delta_s")?.as_f64()?,
        );
    }

    if let Some(out) = args.get("out") {
        std::fs::write(out, rep.to_string_pretty())
            .map_err(|e| anyhow::anyhow!("writing report {out}: {e}"))?;
        println!("wrote drift report {out}");
    } else {
        println!("{}", rep.to_string_pretty());
    }
    Ok(())
}

/// `zo2 lint [--src DIR] [--json FILE] [--plans]` — the repo-native
/// static-analysis pass (see [`zo2::analysis`]).  Prints every unwaived
/// finding, optionally writes the deterministic `zo2-lint-v1` report, and
/// exits nonzero whenever an unwaived finding or a plan violation exists —
/// that nonzero exit is the CI gate.
fn cmd_lint(args: &Args) -> Result<()> {
    let src = args.get_or("src", "src");
    let mut rep = zo2::analysis::run_lint(std::path::Path::new(&src))?;
    if args.get_bool("plans") {
        rep.plans = Some(zo2::analysis::lint_plans());
    }

    for f in rep.findings.iter().filter(|f| !f.waived) {
        eprintln!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
    }
    if let Some(p) = &rep.plans {
        for v in &p.violations {
            eprintln!("plan: {v}");
        }
    }

    println!(
        "lint: {} file(s), {} finding(s) ({} unwaived), {} waiver(s), {} unsafe site(s) \
         ({} undocumented)",
        rep.files_scanned,
        rep.findings.len(),
        rep.unwaived(),
        rep.waivers.len(),
        rep.unsafe_sites.len(),
        rep.undocumented_unsafe(),
    );
    if let Some(p) = &rep.plans {
        println!("plans: {} checked, {} violation(s)", p.checked, p.violations.len());
    }

    if let Some(out) = args.get("json") {
        std::fs::write(out, rep.render())
            .map_err(|e| anyhow::anyhow!("writing lint report {out}: {e}"))?;
        println!("wrote lint report {out}");
    }

    let bad = rep.unwaived() + rep.plan_violations();
    if bad > 0 {
        bail!("lint: {bad} unwaived finding(s) / plan violation(s)");
    }
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    let hw = Hardware::a100_pcie4();
    let batch = args.get_usize_checked("batch", 1)?;
    let seq = args.get_usize_checked("seq", 2048)?;
    println!("{:<10} {:>12} {:>12} {:>12} {:>12}   (MB, B={batch} T={seq})",
             "model", "AdamW", "SGD", "MeZO", "ZO2");
    for shape in opt_family() {
        let wl = Workload { shape: shape.clone(), batch, seq, wire: Codec::F32, compute: ComputeMode::Fp32 };
        let cell = |s: Strategy| {
            let b = gpu_memory_bytes(s, &wl, 4, &hw);
            if b > hw.hbm_capacity {
                format!("X({})", fmt_mb(b))
            } else {
                fmt_mb(b)
            }
        };
        println!(
            "{:<10} {:>12} {:>12} {:>12} {:>12}",
            shape.name,
            cell(Strategy::AdamW),
            cell(Strategy::Sgd),
            cell(Strategy::Mezo),
            cell(Strategy::Zo2 { slots: 3 })
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = Runtime::load_config(&args.get_or("config", "tiny"))?;
    let m = rt.manifest();
    m.validate()?;
    println!(
        "{}: d={} h={} L={} V={} B={} T={}  params={:.2}M  buckets: embed {} / block {} / head {}",
        m.config.name, m.config.d_model, m.config.n_heads, m.config.n_layers,
        m.config.vocab, m.config.batch, m.config.seq_len,
        m.config.total_params as f64 / 1e6,
        m.embed.size, m.block.size, m.head.size
    );
    for (name, file) in &m.artifacts {
        println!("  {name:<14} {file}");
    }
    Ok(())
}

fn cmd_dp(args: &Args) -> Result<()> {
    use zo2::coordinator::{train_elastic, ElasticTrainConfig};
    use zo2::dp::{ElasticRunConfig, FaultSchedule, TransportKind};

    let workers = args.get_usize_checked("dp-workers", 2)?;
    let shards = args.get_usize_checked("dp-shards", 4)?;
    let steps = args.get_usize_checked("steps", 24)? as u64;
    let schedule =
        FaultSchedule::parse(args.get_or("fault-schedule", "none").as_str(), workers, steps)?;
    let cfg = ElasticTrainConfig {
        run: ElasticRunConfig {
            transport: TransportKind::parse(args.get_or("dp-transport", "chan").as_str())?,
            workers,
            shards,
            shard_len: args.get_usize_checked("shard-len", 8)?,
            steps,
            schedule,
            checkpoint: args.get("checkpoint").map(std::path::PathBuf::from),
            checkpoint_every: args.get_usize_checked("checkpoint-every", 0)? as u64,
            resume: args.get_bool("resume"),
            seed: args.get_usize_checked("seed", 90)? as u64,
            data_seed: args.get_usize_checked("data-seed", 4242)? as u64,
            n_params: args.get_usize_checked("n-params", 64)?,
            processes: args.get_bool("dp-processes"),
        },
        losses_out: args.get("losses-out").map(str::to_string),
        metrics_out: args.get("metrics-out").map(str::to_string),
        log_every: args.get_usize_checked("log-every", 1)?,
    };
    train_elastic(&cfg, true)?;
    Ok(())
}

fn cmd_dp_worker(args: &Args) -> Result<()> {
    use zo2::dp::{connect, serve, SeedZoWorker, WorkerFaults};

    let addr = args
        .get("connect")
        .ok_or_else(|| anyhow::anyhow!("dp-worker needs --connect <tcp:..|unix:..>"))?;
    let id = args.get_usize_checked("worker", 0)? as u32;
    let seed = args.get_usize_checked("seed", 90)? as u64;
    let n_params = args.get_usize_checked("n-params", 64)?;
    let kill_step = match args.get("kill-at") {
        Some(_) => Some(args.get_usize_checked("kill-at", 0)? as u64),
        None => None,
    };
    let stall = match args.get("stall-at") {
        Some(_) => Some((
            args.get_usize_checked("stall-at", 0)? as u64,
            args.get_usize_checked("stall-ms", 10)? as u64,
        )),
        None => None,
    };
    let faults = WorkerFaults { kill_step, stall };
    let t = connect(addr)?;
    let worker = SeedZoWorker::new(seed, n_params);
    serve(t, worker, id, faults, std::time::Duration::from_secs(120))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse_with_bools(v.iter().map(|x| x.to_string()), BOOL_FLAGS)
    }

    #[test]
    fn device_specs_expand_counts_in_order() {
        let a = args(&["simulate", "--device-spec", "a100:2,rtx4090:2"]);
        let hws = parse_device_specs(&a, None).unwrap();
        assert_eq!(hws.len(), 4);
        assert_eq!(hws[0].name, "A100-80GB-PCIe4");
        assert_eq!(hws[1].name, "A100-80GB-PCIe4");
        assert_eq!(hws[2].name, "RTX4090-24GB-PCIe4");
        assert_eq!(hws[3].name, "RTX4090-24GB-PCIe4");
        // Count-less entries mean one device; agreement with --devices holds.
        let a = args(&["simulate", "--device-spec", "h100,a100", "--devices", "2"]);
        let hws = parse_device_specs(&a, Some(2)).unwrap();
        assert_eq!(hws[0].name, "H100-80GB-PCIe5");
        // Disagreement, unknown presets and bad counts are loud errors.
        let a = args(&["simulate", "--device-spec", "a100:2"]);
        assert!(parse_device_specs(&a, Some(4)).unwrap_err().to_string().contains("--devices"));
        let a = args(&["simulate", "--device-spec", "tpu:2"]);
        let e = parse_device_specs(&a, None).unwrap_err().to_string();
        assert!(e.contains("tpu") && e.contains("a100"), "{e}");
        let a = args(&["simulate", "--device-spec", "a100:x"]);
        assert!(parse_device_specs(&a, None).is_err());
        let a = args(&["simulate", "--device-spec", "a100:0"]);
        assert!(parse_device_specs(&a, None).is_err());
        // No spec: N default devices.
        assert_eq!(parse_device_specs(&args(&["simulate"]), Some(3)).unwrap().len(), 3);
    }

    #[test]
    fn dram_budget_lists_broadcast_and_validate() {
        let a = args(&["simulate", "--dram-budget", "64"]);
        assert_eq!(parse_dram_budgets(&a, 4).unwrap(), vec![64u64 << 30; 4]);
        let a = args(&["simulate", "--dram-budget", "64,32,32,64"]);
        assert_eq!(
            parse_dram_budgets(&a, 4).unwrap(),
            vec![64u64 << 30, 32u64 << 30, 32u64 << 30, 64u64 << 30]
        );
        // Missing, malformed, non-positive and mis-sized lists all fail.
        let e = parse_dram_budgets(&args(&["simulate"]), 1).unwrap_err().to_string();
        assert!(e.contains("--dram-budget"), "{e}");
        assert!(parse_dram_budgets(&args(&["simulate", "--dram-budget", "64x"]), 1).is_err());
        assert!(parse_dram_budgets(&args(&["simulate", "--dram-budget", "0"]), 1).is_err());
        assert!(parse_dram_budgets(&args(&["simulate", "--dram-budget", "64,-32"]), 2).is_err());
        let e = parse_dram_budgets(&args(&["simulate", "--dram-budget", "64,32"]), 4)
            .unwrap_err()
            .to_string();
        assert!(e.contains("2 budgets") && e.contains("4 host(s)"), "{e}");
    }

    #[test]
    fn link_lists_broadcast_and_apply_gbps() {
        let a = args(&["simulate", "--link", "nvlink"]);
        let links = parse_links(&a, 4).unwrap();
        assert_eq!(links.len(), 4);
        assert!(links.iter().all(|l| l.name == "NVLink"));
        let a = args(&["simulate", "--link", "nvlink,nvlink,pcie,pcie"]);
        let links = parse_links(&a, 4).unwrap();
        assert_eq!(links[0].name, "NVLink");
        assert_eq!(links[3].name, "PCIe-P2P");
        let a = args(&["simulate", "--link", "nvlink", "--link-gbps", "100,100,12,12"]);
        let links = parse_links(&a, 4).unwrap();
        assert!(links[0].bytes_per_s > links[2].bytes_per_s);
        // Mis-sized and malformed lists are loud.
        assert!(parse_links(&args(&["simulate", "--link", "nvlink,pcie"]), 4).is_err());
        assert!(parse_links(&args(&["simulate", "--link-gbps", "1,2,3"]), 4).is_err());
        assert!(parse_links(&args(&["simulate", "--link-gbps", "fast"]), 2).is_err());
        assert!(parse_links(&args(&["simulate", "--link-gbps", "-5"]), 2).is_err());
        assert!(parse_links(&args(&["simulate", "--link", "token-ring"]), 2).is_err());
    }

    #[test]
    fn kernel_switches_validate_loudly() {
        // Valid spellings set the switches without error.
        set_kernel_switches(&args(&["train", "--host-simd", "auto", "--disk-uring", "auto"]))
            .unwrap();
        set_kernel_switches(&args(&["train", "--host-simd", "off", "--disk-uring", "off"]))
            .unwrap();
        // Unknown values are loud, naming the flag.
        let e = set_kernel_switches(&args(&["train", "--host-simd", "avx9"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--host-simd") && e.contains("avx9"), "{e}");
        let e = set_kernel_switches(&args(&["train", "--disk-uring", "maybe"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--disk-uring") && e.contains("maybe"), "{e}");
        // `--host-pin` is a boolean flag: it must not eat the next token.
        let a = args(&["train", "--host-pin", "--steps", "3"]);
        assert!(a.get_bool("host-pin"));
        assert_eq!(a.get("steps"), Some("3"));
        // Leave the process defaults restored for other tests.
        set_kernel_switches(&args(&["train"])).unwrap();
    }

    #[test]
    fn host_threads_zero_is_auto_and_bounds_are_enforced() {
        assert_eq!(parse_host_threads(&args(&["train"])).unwrap(), 0);
        assert_eq!(parse_host_threads(&args(&["train", "--host-threads", "0"])).unwrap(), 0);
        assert_eq!(parse_host_threads(&args(&["train", "--host-threads", "512"])).unwrap(), 512);
        // Malformed (negative / non-numeric / overflow) values fail via the
        // checked parser; beyond the affinity-mask limit fails the bound.
        assert!(parse_host_threads(&args(&["train", "--host-threads", "-1"])).is_err());
        assert!(parse_host_threads(&args(&["train", "--host-threads", "8x"])).is_err());
        assert!(
            parse_host_threads(&args(&["train", "--host-threads", "99999999999999999999"]))
                .is_err()
        );
        let e = parse_host_threads(&args(&["train", "--host-threads", "513"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("513") && e.contains("512"), "{e}");
    }

    #[test]
    fn observability_flags_take_values() {
        // `--trace-out`/`--metrics-out` are value flags: they must consume
        // the path token, leaving other positionals/flags intact.
        let a = args(&[
            "simulate",
            "--trace-out",
            "t.json",
            "--metrics-out",
            "m.json",
            "--timeline",
            "--model",
            "OPT-13B",
        ]);
        assert_eq!(a.get("trace-out"), Some("t.json"));
        assert_eq!(a.get("metrics-out"), Some("m.json"));
        assert!(a.has("timeline"));
        assert_eq!(a.get("model"), Some("OPT-13B"));
    }

    #[test]
    fn empty_device_lists_error_loudly_instead_of_panicking() {
        // CLI form: an empty --device-spec value is a named error.
        let e = parse_device_specs(&args(&["simulate", "--device-spec", ""]), None)
            .unwrap_err()
            .to_string();
        assert!(e.contains("--device-spec"), "{e}");
        // Programmatic form (the autotuner sweeps this path): the checked
        // min over HBM capacities names the flag instead of unwrap-panicking
        // on an empty list.
        let e = min_hbm_capacity(&[]).unwrap_err().to_string();
        assert!(e.contains("--device-spec"), "{e}");
        assert_eq!(
            min_hbm_capacity(&[Hardware::a100_pcie4(), Hardware::rtx4090_pcie4()]).unwrap(),
            Hardware::rtx4090_pcie4().hbm_capacity
        );
    }

    #[test]
    fn tuned_config_replay_merges_flags_with_cli_precedence() {
        let dir = std::env::temp_dir().join(format!("zo2_tunecfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tuned.json");
        std::fs::write(
            &path,
            r#"{"schema": "zo2-tune-v1", "best": {"flags": {"model": "OPT-30B", "slots": "4"}}}"#,
        )
        .unwrap();
        let p = path.to_str().unwrap().to_string();
        // Report flags fill unset flags; explicit CLI flags win; the
        // --config flag itself is consumed.
        let mut a = args(&["simulate", "--config", &p, "--slots", "6"]);
        apply_tuned_config(&mut a).unwrap();
        assert_eq!(a.get("model"), Some("OPT-30B"));
        assert_eq!(a.get("slots"), Some("6"));
        assert_eq!(a.get("config"), None);
        // Non-.json values are compiled-config names: untouched.
        let mut a = args(&["train", "--config", "tiny"]);
        apply_tuned_config(&mut a).unwrap();
        assert_eq!(a.get("config"), Some("tiny"));
        // Wrong schema, a report with no feasible best, and a missing file
        // are loud errors naming the path.
        let bad = dir.join("bad.json");
        std::fs::write(&bad, r#"{"schema": "zo2-metrics-v1", "best": null}"#).unwrap();
        let mut a = args(&["simulate", "--config", bad.to_str().unwrap()]);
        let e = apply_tuned_config(&mut a).unwrap_err().to_string();
        assert!(e.contains("zo2-tune-v1"), "{e}");
        let none = dir.join("none.json");
        std::fs::write(&none, r#"{"schema": "zo2-tune-v1", "best": null}"#).unwrap();
        let mut a = args(&["simulate", "--config", none.to_str().unwrap()]);
        let e = apply_tuned_config(&mut a).unwrap_err().to_string();
        assert!(e.contains("no feasible"), "{e}");
        let missing = dir.join("missing.json");
        let mut a = args(&["simulate", "--config", missing.to_str().unwrap()]);
        assert!(apply_tuned_config(&mut a).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn tune_list_overrides_parse_checked() {
        let a = args(&["tune", "--tune-slots", "2,4,8"]);
        assert_eq!(parse_usize_list(&a, "tune-slots").unwrap(), Some(vec![2, 4, 8]));
        assert_eq!(parse_usize_list(&a, "tune-dram-slots").unwrap(), None);
        assert!(parse_usize_list(&args(&["tune", "--tune-slots", "0"]), "tune-slots").is_err());
        assert!(parse_usize_list(&args(&["tune", "--tune-slots", "2.5"]), "tune-slots").is_err());
        assert!(parse_usize_list(&args(&["tune", "--tune-slots", "x"]), "tune-slots").is_err());
        let a = args(&["tune", "--tune-strategies", "dp,pipeline"]);
        let v = parse_name_list(&a, "tune-strategies", ShardStrategy::parse, "dp|pipeline")
            .unwrap()
            .unwrap();
        assert_eq!(v, vec![ShardStrategy::DataParallel, ShardStrategy::Pipeline]);
        let a = args(&["tune", "--tune-layouts", "fancy"]);
        let e = parse_name_list(&a, "tune-layouts", LayoutChoice::parse, "contiguous|cyclic")
            .unwrap_err()
            .to_string();
        assert!(e.contains("--tune-layouts") && e.contains("fancy"), "{e}");
    }
}
