//! zo2 — CLI for the ZO2 reproduction.
//!
//! Subcommands:
//!   train     train a compiled config with MeZO or ZO2 (real PJRT execution)
//!   simulate  paper-scale throughput/memory via the discrete-event simulator
//!   memory    print the Fig. 1 memory table (analytic accounting)
//!   info      show a config's manifest summary

use anyhow::{bail, Result};

use zo2::coordinator::{train, EngineKind, TrainConfig};
use zo2::costmodel::{
    gpu_memory_bytes, plan_three_tier, plan_three_tier_partitioned, two_tier_dram_bytes, Cluster,
    ClusterCost, ComputeMode, Hardware, Interconnect, MemoryBudget, SimCost, Strategy, Workload,
};
use zo2::model::{opt_by_name, opt_family};
use zo2::precision::Codec;
use zo2::runtime::Runtime;
use zo2::sched::{build_plan, simulate, Policy, SpillPlacement, Tiering};
use zo2::shard::{
    blocks_per_device, build_sharded_plan_spilled, ShardLayout, ShardSpec, ShardStrategy,
};
use zo2::util::cli::Args;
use zo2::util::fmt_mb;
use zo2::zo::{RunMode, UpdateSite, ZoConfig};

/// Flags that never take a value (so `zo2 run --timeline cfg.json` keeps
/// `cfg.json` positional — see `util::cli`).
const BOOL_FLAGS: &[&str] = &["timeline", "no-reusable-mem", "no-efficient-update"];

fn main() -> Result<()> {
    let args = Args::from_env_with_bools(BOOL_FLAGS);
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("memory") => cmd_memory(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: zo2 <train|simulate|memory|info> [--config tiny] [--engine zo2|mezo]\n\
                 \x20      [--steps N] [--lr F] [--eps F] [--seed N] [--wire fp32|bf16|fp16|fp8]\n\
                 \x20      [--mode seq|overlap] [--model OPT-13B] [--compute fp32|tf32|fp16]\n\
                 \x20      [--tiering two|three] [--dram-budget GB] [--dram-slots N]\n\
                 \x20      [--nvme-gbps F] [--nvme-write-gbps F] [--disk-batch N]\n\
                 \x20      [--spill-placement trailing|interleaved]\n\
                 \x20      [--update-site device|cpu] [--host-threads N] [--dp-workers K] [--dp-shards S]\n\
                 \x20      [--devices N] [--shard dp|pipeline] [--layout contiguous|cyclic]\n\
                 \x20      [--link nvlink|pcie] [--link-gbps F] [--microbatches M]"
            );
            Ok(())
        }
    }
}

fn parse_tiering(args: &Args) -> Result<Tiering> {
    match args.get_or("tiering", "two").as_str() {
        "two" | "2" => Ok(Tiering::TwoTier),
        "three" | "3" => Ok(Tiering::ThreeTier),
        t => bail!("unknown tiering `{t}` (expected two|three)"),
    }
}

fn parse_spill_placement(args: &Args) -> Result<SpillPlacement> {
    match args.get_or("spill-placement", "trailing").as_str() {
        "trailing" | "tail" => Ok(SpillPlacement::Trailing),
        "interleaved" | "interleave" => Ok(SpillPlacement::Interleaved),
        p => bail!("unknown spill placement `{p}` (expected trailing|interleaved)"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let tiering = parse_tiering(args)?;
    let dram_budget_bytes = match args.get("dram-budget") {
        None => None,
        Some(s) => match s.parse::<f64>() {
            Ok(gb) if gb > 0.0 => Some((gb * (1u64 << 30) as f64) as u64),
            _ => bail!("bad --dram-budget `{s}` (gigabytes, e.g. 64)"),
        },
    };
    // Refuse to silently train two-tier when the user asked for three.
    if tiering == Tiering::ThreeTier && dram_budget_bytes.is_none() {
        bail!("--tiering three requires --dram-budget <GB> (the DDR budget that decides which blocks spill)");
    }
    let cfg = TrainConfig {
        config_name: args.get_or("config", "tiny"),
        steps: args.get_usize("steps", 20),
        zo: ZoConfig {
            lr: args.get_f64("lr", 1e-4) as f32,
            eps: args.get_f64("eps", 1e-3) as f32,
            seed: args.get_usize("seed", 42) as u64,
        },
        engine: match args.get_or("engine", "zo2").as_str() {
            "mezo" => EngineKind::Mezo,
            "zo2" => EngineKind::Zo2,
            e => bail!("unknown engine `{e}`"),
        },
        wire: Codec::parse(&args.get_or("wire", "fp32")).ok_or_else(|| anyhow::anyhow!("bad wire"))?,
        run_mode: match args.get_or("mode", "overlap").as_str() {
            "seq" => RunMode::Sequential,
            "overlap" => RunMode::Overlapped,
            m => bail!("unknown mode `{m}`"),
        },
        log_every: args.get_usize("log-every", 10),
        tiering,
        dram_budget_bytes,
        dram_slots: args.get_usize("dram-slots", 4),
        spill_placement: parse_spill_placement(args)?,
        update_site: match args.get_or("update-site", "device").as_str() {
            "device" | "gpu" => UpdateSite::Device,
            "cpu" | "host" => UpdateSite::Cpu,
            s => bail!("unknown update site `{s}` (expected device|cpu)"),
        },
        host_threads: args.get_usize("host-threads", 0),
        dp_workers: args.get_usize("dp-workers", 1).max(1),
        dp_shards: args.get_usize("dp-shards", 0),
    };
    let report = train(&cfg, true)?;
    println!(
        "done: {:.0} tok/s, final eval loss {:.4}, device peak {} MB, transfers {} MB",
        report.tokens_per_s,
        report.final_eval_loss,
        fmt_mb(report.device_peak_bytes),
        fmt_mb(report.transfer_bytes)
    );
    if report.spilled_blocks > 0 {
        println!(
            "disk tier: {} spilled blocks, {} MB NVMe traffic",
            report.spilled_blocks,
            fmt_mb(report.disk_bytes)
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let name = args.get_or("model", "OPT-13B");
    let shape = opt_by_name(&name).ok_or_else(|| anyhow::anyhow!("unknown model {name}"))?;
    let read_gbps = args.get_f64("nvme-gbps", 6.8);
    let write_gbps = args.get_f64("nvme-write-gbps", read_gbps * 0.75);
    let hw = Hardware::a100_pcie4().with_nvme_gbps(read_gbps, write_gbps);
    let wire = Codec::parse(&args.get_or("wire", "fp32")).unwrap();
    let wl = Workload {
        shape,
        batch: args.get_usize("batch", 1),
        seq: args.get_usize("seq", 2048),
        wire,
        compute: match args.get_or("compute", "fp32").as_str() {
            "tf32" => ComputeMode::Tf32,
            "fp16" => ComputeMode::Fp16,
            "bf16" => ComputeMode::Bf16,
            _ => ComputeMode::Fp32,
        },
    };
    let param_bytes = wire.bytes_per_el().min(4);
    let tiering = parse_tiering(args)?;
    let dram_slots = args.get_usize("dram-slots", 4);
    let spill_placement = parse_spill_placement(args)?;
    let steps = args.get_usize("sim-steps", 4);
    let devices = args.get_usize("devices", 1).max(1);
    let microbatches = args.get_usize("microbatches", 1).max(1);
    let strategy = match args.get_or("shard", "dp").as_str() {
        "dp" | "data-parallel" => ShardStrategy::DataParallel,
        "pipeline" | "pp" => ShardStrategy::Pipeline,
        s => bail!("unknown shard strategy `{s}` (expected dp|pipeline)"),
    };
    let layout = match args.get_or("layout", "contiguous").as_str() {
        "contiguous" | "block" => ShardLayout::Contiguous,
        "cyclic" | "roundrobin" => ShardLayout::Cyclic,
        l => bail!("unknown layout `{l}` (expected contiguous|cyclic)"),
    };
    if microbatches > 1 && (devices == 1 || strategy != ShardStrategy::Pipeline) {
        bail!(
            "--microbatches M splits the step for pipeline sharding: it needs \
             --devices N --shard pipeline (for DP, batch slicing is the engine's \
             --dp-shards)"
        );
    }
    let mut policy = Policy {
        overlap: args.get_or("mode", "overlap") != "seq",
        reusable_mem: !args.has("no-reusable-mem"),
        efficient_update: !args.has("no-efficient-update"),
        slots: args.get_usize("slots", 3),
        disk_batch: args.get_usize("disk-batch", 1).max(1),
        spill_placement,
        ..Policy::default()
    };
    let mut per_device_spilled: Option<Vec<usize>> = None;
    if tiering == Tiering::ThreeTier {
        let budget = MemoryBudget {
            hbm: hw.hbm_capacity,
            dram: (args.get_f64("dram-budget", 64.0) * (1u64 << 30) as f64) as u64,
            nvme: 2 << 40,
        };
        if devices > 1 && strategy == ShardStrategy::Pipeline {
            // Per-partition planning: each pipeline host holds only its own
            // blocks, so its spill set is sized against its own DRAM budget
            // (`--dram-budget` is per host).
            let budgets = vec![budget; devices];
            let plans = plan_three_tier_partitioned(
                &wl,
                &budgets,
                layout,
                policy.slots,
                dram_slots,
                param_bytes,
                &hw,
                spill_placement,
            );
            policy.tiering = Tiering::ThreeTier;
            policy.spilled = plans.iter().map(|p| p.spilled_blocks).sum();
            policy.dram_slots = plans.iter().map(|p| p.dram_slots).max().unwrap_or(1).max(1);
            println!(
                "tiers (per partition, {} GB DDR per host; a full copy would need {} MB):",
                args.get_f64("dram-budget", 64.0),
                fmt_mb(two_tier_dram_bytes(&wl)),
            );
            for (d, plan) in plans.iter().enumerate() {
                // A budget smaller than the staging window itself is
                // infeasible — refuse rather than simulate a host that
                // cannot hold its own prefetch window.
                anyhow::ensure!(
                    plan.peaks.dram <= budgets[d].dram,
                    "device {d}: DDR peak {} MB (incl. the {}-slot staging window) exceeds \
                     the per-host --dram-budget ({} MB) — lower --dram-slots or raise \
                     --dram-budget",
                    fmt_mb(plan.peaks.dram),
                    plan.dram_slots,
                    fmt_mb(budgets[d].dram),
                );
                // Any other tier overflowing is a different knob — name it.
                anyhow::ensure!(
                    budgets[d].fits(&plan.peaks),
                    "device {d}: tier peaks {:?} do not fit the host budget {:?}",
                    plan.peaks,
                    budgets[d],
                );
                println!(
                    "  device {d}: {} blocks in DDR + {} on NVMe | peaks: DDR {} MB, NVMe {} MB",
                    plan.resident_blocks,
                    plan.spilled_blocks,
                    fmt_mb(plan.peaks.dram),
                    fmt_mb(plan.peaks.nvme),
                );
            }
            per_device_spilled = Some(plans.iter().map(|p| p.spilled_blocks).collect());
        } else {
            // Single device, or DP: every host holds a full copy, so the
            // single-replica spill plan applies per device as-is.
            let plan = plan_three_tier(
                &wl,
                &budget,
                policy.slots,
                dram_slots,
                param_bytes,
                &hw,
                spill_placement,
            );
            // Same feasibility rule as the per-partition branch: a budget
            // smaller than the staging window cannot run at all.
            anyhow::ensure!(
                plan.peaks.dram <= budget.dram,
                "DDR peak {} MB (incl. the {}-slot staging window) exceeds --dram-budget \
                 ({} MB) — lower --dram-slots or raise --dram-budget",
                fmt_mb(plan.peaks.dram),
                plan.dram_slots,
                fmt_mb(budget.dram),
            );
            policy.tiering = Tiering::ThreeTier;
            policy.spilled = plan.spilled_blocks;
            policy.dram_slots = plan.dram_slots.max(1);
            println!(
                "tiers: {} blocks in DDR + {} on NVMe | peaks: HBM {} MB, DDR {} MB \
                 (two-tier would need {} MB), NVMe {} MB",
                plan.resident_blocks,
                plan.spilled_blocks,
                fmt_mb(plan.peaks.hbm),
                fmt_mb(plan.peaks.dram),
                fmt_mb(two_tier_dram_bytes(&wl)),
                fmt_mb(plan.peaks.nvme),
            );
        }
    }

    if devices > 1 {
        // Multi-GPU simulation: per-device streams + an interconnect.
        let link = match args.get_or("link", "nvlink").as_str() {
            "nvlink" => Interconnect::nvlink(),
            "pcie" | "pcie-p2p" => Interconnect::pcie_p2p(),
            l => bail!("unknown link `{l}` (expected nvlink|pcie)"),
        };
        let link = match args.get("link-gbps") {
            Some(s) => match s.parse::<f64>() {
                Ok(gbps) if gbps > 0.0 => link.with_gbps(gbps),
                _ => bail!("bad --link-gbps `{s}`"),
            },
            None => link,
        };
        let spec = ShardSpec { devices, layout, strategy, microbatches };
        let cluster = Cluster::homogeneous(hw, devices, link);
        let costs = ClusterCost::new(&cluster, &wl)?;
        let plan = build_sharded_plan_spilled(
            wl.shape.n_layers,
            steps,
            policy,
            &spec,
            per_device_spilled.as_deref(),
        );
        let (sched, timeline) = simulate(&plan, &costs, policy);
        // DP runs one batch shard per device (weak scaling); pipeline runs
        // the single stream across devices.
        let tokens_per_step = match strategy {
            ShardStrategy::DataParallel => (devices * wl.batch * wl.seq) as f64,
            ShardStrategy::Pipeline => (wl.batch * wl.seq) as f64,
        };
        println!(
            "{name} x{devices} {} ({}{}): step {:.3}s  ->  {:.0} tokens/s  \
             (makespan {:.3}s over {steps} steps, {}, link {})",
            match strategy {
                ShardStrategy::DataParallel => "dp",
                ShardStrategy::Pipeline => "pipeline",
            },
            match layout {
                ShardLayout::Contiguous => "contiguous",
                ShardLayout::Cyclic => "cyclic",
            },
            if microbatches > 1 { format!(", M={microbatches}") } else { String::new() },
            sched.steady_step_s,
            tokens_per_step / sched.steady_step_s,
            sched.makespan,
            sched.bottleneck(),
            cluster.link.name,
        );
        let per_dev = blocks_per_device(layout, wl.shape.n_layers, devices);
        for d in sched.devices() {
            let owned = match strategy {
                ShardStrategy::Pipeline => per_dev[d.0].len(),
                ShardStrategy::DataParallel => wl.shape.n_layers,
            };
            println!(
                "  device {}: {} blocks, {}",
                d.0,
                owned,
                sched.bottleneck_of(d)
            );
        }
        if args.has("timeline") {
            println!("{}", timeline.to_ascii_gantt(100));
        }
        return Ok(());
    }

    let costs = SimCost::new(&hw, &wl);
    let plan = build_plan(wl.shape.n_layers, steps, policy);
    let (sched, timeline) = simulate(&plan, &costs, policy);
    let tokens = (wl.batch * wl.seq) as f64;
    println!(
        "{name}: step {:.3}s  ->  {:.0} tokens/s  (makespan {:.3}s over {steps} steps, {})",
        sched.steady_step_s,
        tokens / sched.steady_step_s,
        sched.makespan,
        sched.bottleneck(),
    );
    if args.has("timeline") {
        println!("{}", timeline.to_ascii_gantt(100));
    }
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    let hw = Hardware::a100_pcie4();
    let batch = args.get_usize("batch", 1);
    let seq = args.get_usize("seq", 2048);
    println!("{:<10} {:>12} {:>12} {:>12} {:>12}   (MB, B={batch} T={seq})",
             "model", "AdamW", "SGD", "MeZO", "ZO2");
    for shape in opt_family() {
        let wl = Workload { shape: shape.clone(), batch, seq, wire: Codec::F32, compute: ComputeMode::Fp32 };
        let cell = |s: Strategy| {
            let b = gpu_memory_bytes(s, &wl, 4, &hw);
            if b > hw.hbm_capacity {
                format!("X({})", fmt_mb(b))
            } else {
                fmt_mb(b)
            }
        };
        println!(
            "{:<10} {:>12} {:>12} {:>12} {:>12}",
            shape.name,
            cell(Strategy::AdamW),
            cell(Strategy::Sgd),
            cell(Strategy::Mezo),
            cell(Strategy::Zo2 { slots: 3 })
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = Runtime::load_config(&args.get_or("config", "tiny"))?;
    let m = rt.manifest();
    m.validate()?;
    println!(
        "{}: d={} h={} L={} V={} B={} T={}  params={:.2}M  buckets: embed {} / block {} / head {}",
        m.config.name, m.config.d_model, m.config.n_heads, m.config.n_layers,
        m.config.vocab, m.config.batch, m.config.seq_len,
        m.config.total_params as f64 / 1e6,
        m.embed.size, m.block.size, m.head.size
    );
    for (name, file) in &m.artifacts {
        println!("  {name:<14} {file}");
    }
    Ok(())
}
