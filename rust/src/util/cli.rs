//! Tiny CLI flag parser (`--key value` / `--flag` / positionals).
//!
//! `--key value` syntax is inherently ambiguous for boolean flags: in
//! `run --overlap config.json` the parser cannot know whether `config.json`
//! is the flag's value or a positional.  Callers therefore declare their
//! boolean flags ([`Args::parse_with_bools`] / [`Args::from_env_with_bools`]);
//! a declared flag never consumes the next token.  `--flag=value` stays
//! unambiguous and works for booleans too (`--overlap=false`).

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse with no declared boolean flags: every `--key token` pair is
    /// treated as key/value (the historical behaviour).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Self {
        Self::parse_with_bools(it, &[])
    }

    /// Parse with `bools` declared as value-less flags: `--overlap x` keeps
    /// `x` positional and records `overlap=true`.
    pub fn parse_with_bools<I: IntoIterator<Item = String>>(it: I, bools: &[&str]) -> Self {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if !bools.contains(&key)
                    && it.peek().map(|n| !n.starts_with("--")).unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(key.to_string(), v);
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn from_env_with_bools(bools: &[&str]) -> Self {
        Self::parse_with_bools(std::env::args().skip(1), bools)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Unchecked read: malformed values silently fall back to the default.
    /// CLI code should prefer [`Self::get_usize_checked`] — a typo like
    /// `--devices foo` must be an error, not a 1-device run.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Unchecked read: malformed values silently fall back to the default
    /// (`--lr 1e-4x` trains at the default).  Prefer
    /// [`Self::get_f64_checked`] in CLI code.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Checked read of a numeric flag: absent → `default`, present but
    /// malformed → an error naming the flag and the offending token.
    pub fn get_usize_checked(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| {
                anyhow::anyhow!("bad --{key} `{s}` (expected an unsigned integer)")
            }),
        }
    }

    /// Checked read of a float flag: absent → `default`, present but
    /// malformed → an error naming the flag and the offending token.
    pub fn get_f64_checked(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => {
                s.parse().map_err(|_| anyhow::anyhow!("bad --{key} `{s}` (expected a number)"))
            }
        }
    }

    /// Checked read of a comma-separated float list (`--dram-budget
    /// 64,32,32,64`).  Absent → `Ok(None)`; any malformed entry → an error
    /// naming the flag and the offending token.  Empty entries (`64,,32`)
    /// are malformed too.
    pub fn get_f64_list_checked(&self, key: &str) -> anyhow::Result<Option<Vec<f64>>> {
        let Some(raw) = self.get(key) else {
            return Ok(None);
        };
        let mut out = Vec::new();
        for tok in raw.split(',') {
            let v: f64 = tok.trim().parse().map_err(|_| {
                anyhow::anyhow!("bad --{key} `{raw}`: entry `{tok}` is not a number")
            })?;
            out.push(v);
        }
        Ok(Some(out))
    }

    /// Boolean flag value: absent → false; present with no value (or
    /// `true`/`1`/`yes`/`on`) → true; `false`/`0`/`no`/`off` → false; any
    /// other value (a swallowed token under un-declared parsing) → true,
    /// since the flag was explicitly given.
    pub fn get_bool(&self, key: &str) -> bool {
        match self.get(key) {
            None => false,
            Some(v) => !matches!(
                v.to_ascii_lowercase().as_str(),
                "false" | "0" | "no" | "off"
            ),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let a = Args::parse(s(&["train", "--config", "tiny", "--steps=10", "--verbose", "--lr", "1e-4"]));
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("config"), Some("tiny"));
        assert_eq!(a.get_usize("steps", 0), 10);
        assert!(a.has("verbose"));
        assert_eq!(a.get_f64("lr", 0.0), 1e-4);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(s(&["--dry-run"]));
        assert!(a.has("dry-run"));
    }

    #[test]
    fn declared_bool_does_not_swallow_positional() {
        // The motivating bug: `run --overlap config.json` used to parse as
        // `overlap=config.json`, losing the positional.
        let a = Args::parse_with_bools(s(&["run", "--overlap", "config.json"]), &["overlap"]);
        assert_eq!(a.positional, vec!["run", "config.json"]);
        assert_eq!(a.get("overlap"), Some("true"));
        assert!(a.get_bool("overlap"));
    }

    #[test]
    fn undeclared_flag_still_takes_a_value() {
        let a = Args::parse_with_bools(s(&["run", "--config", "tiny"]), &["overlap"]);
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("config"), Some("tiny"));
    }

    #[test]
    fn declared_bool_accepts_explicit_eq_value() {
        let a = Args::parse_with_bools(s(&["--overlap=false", "--trace=1"]), &["overlap", "trace"]);
        assert!(!a.get_bool("overlap"));
        assert!(a.get_bool("trace"));
    }

    #[test]
    fn get_bool_semantics() {
        let a = Args::parse(s(&["--a", "--b=no", "--c=ON", "--d", "weird"]));
        assert!(a.get_bool("a"), "bare flag is true");
        assert!(!a.get_bool("b"));
        assert!(a.get_bool("c"), "case-insensitive");
        assert!(a.get_bool("d"), "flag given with junk value still counts as set");
        assert!(!a.get_bool("absent"));
    }

    #[test]
    fn checked_getters_reject_malformed_tokens() {
        let a = Args::parse(s(&["simulate", "--devices", "foo", "--lr", "1e-4x", "--steps", "7"]));
        // The unchecked getters silently default — the historical bug.
        assert_eq!(a.get_usize("devices", 1), 1);
        assert_eq!(a.get_f64("lr", 1e-4), 1e-4);
        // The checked getters are loud and name flag + token.
        let e = a.get_usize_checked("devices", 1).unwrap_err().to_string();
        assert!(e.contains("--devices") && e.contains("`foo`"), "{e}");
        let e = a.get_f64_checked("lr", 1e-4).unwrap_err().to_string();
        assert!(e.contains("--lr") && e.contains("`1e-4x`"), "{e}");
        // Well-formed and absent flags behave as before.
        assert_eq!(a.get_usize_checked("steps", 0).unwrap(), 7);
        assert_eq!(a.get_usize_checked("absent", 9).unwrap(), 9);
        assert_eq!(a.get_f64_checked("absent", 2.5).unwrap(), 2.5);
        // usize flags reject negatives and floats.
        let b = Args::parse(s(&["--devices", "-2", "--slots", "2.5"]));
        assert!(b.get_usize_checked("devices", 1).is_err());
        assert!(b.get_usize_checked("slots", 3).is_err());
    }

    #[test]
    fn checked_f64_list_parses_and_rejects() {
        let a = Args::parse(s(&["--dram-budget", "64,32, 32,64"]));
        assert_eq!(
            a.get_f64_list_checked("dram-budget").unwrap(),
            Some(vec![64.0, 32.0, 32.0, 64.0])
        );
        let single = Args::parse(s(&["--dram-budget", "24"]));
        assert_eq!(single.get_f64_list_checked("dram-budget").unwrap(), Some(vec![24.0]));
        let absent = Args::parse(s(&["run"]));
        assert_eq!(absent.get_f64_list_checked("dram-budget").unwrap(), None);
        let bad = Args::parse(s(&["--dram-budget", "64,x,32"]));
        let e = bad.get_f64_list_checked("dram-budget").unwrap_err().to_string();
        assert!(e.contains("--dram-budget") && e.contains("`x`"), "{e}");
        let empty_entry = Args::parse(s(&["--dram-budget", "64,,32"]));
        assert!(empty_entry.get_f64_list_checked("dram-budget").is_err());
    }

    #[test]
    fn bool_flag_before_another_flag_and_at_end() {
        let a = Args::parse_with_bools(s(&["--overlap", "--steps", "5", "--timeline"]),
                                       &["overlap", "timeline"]);
        assert!(a.get_bool("overlap"));
        assert!(a.get_bool("timeline"));
        assert_eq!(a.get_usize("steps", 0), 5);
        assert!(a.positional.is_empty());
    }
}
