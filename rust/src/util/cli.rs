//! Tiny CLI flag parser (`--key value` / `--flag` / positionals).
//!
//! `--key value` syntax is inherently ambiguous for boolean flags: in
//! `run --overlap config.json` the parser cannot know whether `config.json`
//! is the flag's value or a positional.  Callers therefore declare their
//! boolean flags ([`Args::parse_with_bools`] / [`Args::from_env_with_bools`]);
//! a declared flag never consumes the next token.  `--flag=value` stays
//! unambiguous and works for booleans too (`--overlap=false`).

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse with no declared boolean flags: every `--key token` pair is
    /// treated as key/value (the historical behaviour).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Self {
        Self::parse_with_bools(it, &[])
    }

    /// Parse with `bools` declared as value-less flags: `--overlap x` keeps
    /// `x` positional and records `overlap=true`.
    pub fn parse_with_bools<I: IntoIterator<Item = String>>(it: I, bools: &[&str]) -> Self {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if !bools.contains(&key)
                    && it.peek().map(|n| !n.starts_with("--")).unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(key.to_string(), v);
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn from_env_with_bools(bools: &[&str]) -> Self {
        Self::parse_with_bools(std::env::args().skip(1), bools)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Boolean flag value: absent → false; present with no value (or
    /// `true`/`1`/`yes`/`on`) → true; `false`/`0`/`no`/`off` → false; any
    /// other value (a swallowed token under un-declared parsing) → true,
    /// since the flag was explicitly given.
    pub fn get_bool(&self, key: &str) -> bool {
        match self.get(key) {
            None => false,
            Some(v) => !matches!(
                v.to_ascii_lowercase().as_str(),
                "false" | "0" | "no" | "off"
            ),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let a = Args::parse(s(&["train", "--config", "tiny", "--steps=10", "--verbose", "--lr", "1e-4"]));
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("config"), Some("tiny"));
        assert_eq!(a.get_usize("steps", 0), 10);
        assert!(a.has("verbose"));
        assert_eq!(a.get_f64("lr", 0.0), 1e-4);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(s(&["--dry-run"]));
        assert!(a.has("dry-run"));
    }

    #[test]
    fn declared_bool_does_not_swallow_positional() {
        // The motivating bug: `run --overlap config.json` used to parse as
        // `overlap=config.json`, losing the positional.
        let a = Args::parse_with_bools(s(&["run", "--overlap", "config.json"]), &["overlap"]);
        assert_eq!(a.positional, vec!["run", "config.json"]);
        assert_eq!(a.get("overlap"), Some("true"));
        assert!(a.get_bool("overlap"));
    }

    #[test]
    fn undeclared_flag_still_takes_a_value() {
        let a = Args::parse_with_bools(s(&["run", "--config", "tiny"]), &["overlap"]);
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("config"), Some("tiny"));
    }

    #[test]
    fn declared_bool_accepts_explicit_eq_value() {
        let a = Args::parse_with_bools(s(&["--overlap=false", "--trace=1"]), &["overlap", "trace"]);
        assert!(!a.get_bool("overlap"));
        assert!(a.get_bool("trace"));
    }

    #[test]
    fn get_bool_semantics() {
        let a = Args::parse(s(&["--a", "--b=no", "--c=ON", "--d", "weird"]));
        assert!(a.get_bool("a"), "bare flag is true");
        assert!(!a.get_bool("b"));
        assert!(a.get_bool("c"), "case-insensitive");
        assert!(a.get_bool("d"), "flag given with junk value still counts as set");
        assert!(!a.get_bool("absent"));
    }

    #[test]
    fn bool_flag_before_another_flag_and_at_end() {
        let a = Args::parse_with_bools(s(&["--overlap", "--steps", "5", "--timeline"]),
                                       &["overlap", "timeline"]);
        assert!(a.get_bool("overlap"));
        assert!(a.get_bool("timeline"));
        assert_eq!(a.get_usize("steps", 0), 5);
        assert!(a.positional.is_empty());
    }
}
