//! Tiny CLI flag parser (`--key value` / `--flag` / positionals).

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Self {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(key.to_string(), v);
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let a = Args::parse(s(&["train", "--config", "tiny", "--steps=10", "--verbose", "--lr", "1e-4"]));
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("config"), Some("tiny"));
        assert_eq!(a.get_usize("steps", 0), 10);
        assert!(a.has("verbose"));
        assert_eq!(a.get_f64("lr", 0.0), 1e-4);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(s(&["--dry-run"]));
        assert!(a.has("dry-run"));
    }
}
