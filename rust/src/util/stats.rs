//! Timing statistics for the bench harness (criterion is unavailable in the
//! offline build, so benches collect their own samples).

#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (self.xs.len() - 1) as f64)
            .sqrt()
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let mut v = self.xs.clone();
        v.sort_by(|a, b| a.total_cmp(b));
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Measure `iters` runs of `f` (after `warmup` runs), returning seconds.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Samples {
    for _ in 0..warmup {
        f();
    }
    let mut s = Samples::new();
    for _ in 0..iters {
        // zo2-lint: allow(no-wall-clock): bench timing is the whole point here
        let t = std::time::Instant::now();
        f();
        s.push(t.elapsed().as_secs_f64());
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let mut s = Samples::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.percentile(50.0), 3.0);
        assert!((s.std() - 1.5811).abs() < 1e-3);
    }

    #[test]
    fn empty_is_nan() {
        assert!(Samples::new().mean().is_nan());
    }
}
