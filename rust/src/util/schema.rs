//! Canonical schema-version tags for every JSON artifact the crate emits
//! or parses.
//!
//! Each `zo2-*-vN` string names a frozen wire format: the tune report, the
//! Chrome trace, the metrics snapshot, the drift report, the DP checkpoint
//! sidecar, the DP loss trajectory, and the lint report.  This module is
//! the **only** place those literals may appear — the
//! `schema-version-literal` lint rule (see [`crate::analysis`]) flags the
//! tag pattern anywhere else in `src/`, so an emit site and its parse site
//! can never drift apart by one silently re-typed string.  Bump a tag here
//! (and only here) when its format changes.

/// Autotuner report (`zo2 tune --out`); replayable via `--config`.
pub const TUNE_SCHEMA: &str = "zo2-tune-v1";

/// Chrome-trace-event export (`--trace-out`), under `otherData`.
pub const TRACE_SCHEMA: &str = "zo2-trace-v1";

/// Labeled metrics snapshot (`--metrics-out`, bench calibration blocks).
pub const METRICS_SCHEMA: &str = "zo2-metrics-v1";

/// Predicted-vs-measured drift report (`zo2 report --out`).
pub const DRIFT_SCHEMA: &str = "zo2-drift-v1";

/// DP checkpoint sidecar (`<pool>.meta.json`).
pub const DP_CKPT_SCHEMA: &str = "zo2-dp-ckpt-v1";

/// Canonical DP loss trajectory (`zo2 dp --losses-out`), byte-comparable.
pub const DP_LOSSES_SCHEMA: &str = "zo2-dp-losses-v1";

/// Static-analysis report (`zo2 lint --json`).
pub const LINT_SCHEMA: &str = "zo2-lint-v1";
