//! Minimal JSON reader/writer for artifact manifests, golden indices and
//! metric dumps.  Supports the full JSON value grammar; numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key `{key}`")),
            _ => bail!("not an object (looking up `{key}`)"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s, 0);
        s
    }

    fn emit(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literal; emitting `{n}` here
                    // would produce output our own parser rejects.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => emit_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    e.emit(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                let pad = "  ".repeat(indent + 1);
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&pad);
                    emit_str(out, k);
                    out.push_str(": ");
                    v.emit(out, indent + 1);
                }
                if !m.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn emit_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected `{}` at byte {}, found `{}`", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number `{s}`: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let n = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(n).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-decode multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        s.push_str(std::str::from_utf8(&self.b[start..start + len])?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected `,` or `]`, found `{}`", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected `,` or `}}`, found `{}`", c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"config": {"name": "tiny", "d_model": 32},
                      "buckets": {"block": {"size": 12704,
                        "layout": [{"name": "ln1_w", "offset": 0, "shape": [32]}]}},
                      "ok": true, "x": null, "e": [], "f": -1.5e3}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("config").unwrap().get("d_model").unwrap().as_usize().unwrap(), 32);
        assert_eq!(
            v.get("buckets").unwrap().get("block").unwrap().get("size").unwrap().as_usize().unwrap(),
            12704
        );
        assert_eq!(v.get("f").unwrap().as_f64().unwrap(), -1500.0);
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn escapes_and_unicode() {
        let v = Json::parse(r#"{"s": "a\"b\\c\ndAé"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "a\"b\\c\ndAé");
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn non_finite_numbers_emit_null() {
        // A NaN/Inf smuggled into a report (e.g. a 0/0 drift ratio) must not
        // make the writer produce non-parseable output.
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let v = Json::Obj(BTreeMap::from([("r".to_string(), Json::Num(bad))]));
            let text = v.to_string_pretty();
            let re = Json::parse(&text).unwrap();
            assert_eq!(re.get("r").unwrap(), &Json::Null, "emitted: {text}");
        }
        // Finite numbers are unaffected.
        assert_eq!(Json::parse(&Json::Num(1.5).to_string_pretty()).unwrap(), Json::Num(1.5));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("nope").is_err());
    }
}
