//! Small self-contained utilities (the offline build has no serde / clap /
//! criterion, so the crate carries its own JSON, CLI and stats helpers).

pub mod cli;
pub mod json;
pub mod schema;
pub mod stats;

/// Release `Vec` capacity beyond 2× the live need — the scratch shrink
/// policy (DESIGN.md): steady reuse at one size never reallocates, a size
/// drop frees the excess instead of pinning the high-water mark.
pub fn shrink_excess<T>(v: &mut Vec<T>, need: usize) {
    if v.capacity() > need.saturating_mul(2) {
        v.shrink_to(need);
    }
}

/// Human-readable byte count (MiB with paper-style "MB" label).
pub fn fmt_mb(bytes: u64) -> String {
    format!("{:.0}", bytes as f64 / (1024.0 * 1024.0))
}

/// Human-readable duration from seconds.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}
