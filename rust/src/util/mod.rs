//! Small self-contained utilities (the offline build has no serde / clap /
//! criterion, so the crate carries its own JSON, CLI and stats helpers).

pub mod cli;
pub mod json;
pub mod stats;

/// Human-readable byte count (MiB with paper-style "MB" label).
pub fn fmt_mb(bytes: u64) -> String {
    format!("{:.0}", bytes as f64 / (1024.0 * 1024.0))
}

/// Human-readable duration from seconds.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}
