//! Parameter store: per-module flat buckets with layout-aware init.
//!
//! Initialisation is deterministic in the seed and identical across engines
//! (a precondition of the parity experiments): LayerNorm scales start at 1,
//! biases at 0, matrices/embeddings at N(0, 0.02) drawn from a dedicated
//! init stream of the counter RNG.

use crate::memory::HostBucket;
use crate::precision::Codec;
use crate::rng::GaussianRng;
use crate::runtime::{BucketSpec, Manifest};

/// Host-side master copies of every module bucket.
///
/// `embed` / `head` are kept as fp32 vectors (they are GPU-resident in ZO2,
/// §5.2, so they never cross the interconnect); `blocks` are [`HostBucket`]s
/// in the wire codec (fp32, or compressed in AMP mode §5.5).
pub struct ParamStore {
    pub embed: Vec<f32>,
    pub blocks: Vec<HostBucket>,
    pub head: Vec<f32>,
}

const INIT_STREAM: u64 = 0xFFFF_FFFF_0000_0001;

/// Fill one bucket according to its layout.
fn init_bucket(spec: &BucketSpec, rng: &mut GaussianRng, std: f32) -> Vec<f32> {
    let mut b = vec![0.0f32; spec.size];
    for p in &spec.layout {
        let sl = &mut b[p.offset..p.offset + p.numel()];
        if p.name.ends_with("_w") && p.shape.len() == 1 {
            // LayerNorm scale.
            sl.fill(1.0);
        } else if p.name.ends_with("_b") {
            sl.fill(0.0);
        } else {
            rng.fill_gaussian(sl);
            for x in sl.iter_mut() {
                *x *= std;
            }
        }
    }
    b
}

impl ParamStore {
    /// Deterministic init from the manifest layouts.
    pub fn init(manifest: &Manifest, seed: u64, wire: Codec) -> Self {
        let mut rng = GaussianRng::new(seed, INIT_STREAM);
        let std = 0.02f32;
        let embed = init_bucket(&manifest.embed, &mut rng, std);
        let mut blocks = Vec::with_capacity(manifest.config.n_layers);
        for _ in 0..manifest.config.n_layers {
            let b = init_bucket(&manifest.block, &mut rng, std);
            blocks.push(HostBucket::from_f32(&b, wire));
        }
        let head = init_bucket(&manifest.head, &mut rng, std);
        Self { embed, blocks, head }
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Module bucket sizes in forward order (embed, blocks…, head) — the
    /// order of the per-iteration RNG state walk.
    pub fn module_sizes(&self) -> Vec<usize> {
        let mut v = Vec::with_capacity(self.blocks.len() + 2);
        v.push(self.embed.len());
        for b in &self.blocks {
            v.push(b.numel());
        }
        v.push(self.head.len());
        v
    }

    /// Flatten everything to fp32 (test/parity comparisons).
    pub fn to_flat_f32(&self) -> Vec<f32> {
        let mut out = self.embed.clone();
        for b in &self.blocks {
            out.extend(b.to_f32());
        }
        out.extend(self.head.iter());
        out
    }

    /// Total wire bytes of all block buckets (one direction of one step).
    pub fn block_wire_bytes(&self) -> u64 {
        self.blocks.iter().map(|b| b.wire_bytes() as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
              "config": {"name": "t", "d_model": 4, "n_heads": 2, "n_layers": 2,
                         "vocab": 8, "seq_len": 2, "batch": 1, "ffn_mult": 4,
                         "total_params": 108},
              "buckets": {
                "embed": {"size": 40, "layout": [
                    {"name": "tok_emb", "offset": 0, "shape": [8, 4]},
                    {"name": "pos_emb", "offset": 32, "shape": [2, 4]}]},
                "block": {"size": 14, "layout": [
                    {"name": "ln1_w", "offset": 0, "shape": [4]},
                    {"name": "ln1_b", "offset": 4, "shape": [4]},
                    {"name": "wq", "offset": 8, "shape": [2, 3]}]},
                "head": {"size": 40, "layout": [
                    {"name": "lnf_w", "offset": 0, "shape": [4]},
                    {"name": "lnf_b", "offset": 4, "shape": [4]},
                    {"name": "lm_w", "offset": 8, "shape": [4, 8]}]}
              },
              "artifacts": {}
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn init_respects_layout_semantics() {
        let m = manifest();
        let s = ParamStore::init(&m, 1, Codec::F32);
        // ln weights = 1, biases = 0, matrices ~ N(0, 0.02).
        let b0 = s.blocks[0].to_f32();
        assert!(b0[0..4].iter().all(|&x| x == 1.0));
        assert!(b0[4..8].iter().all(|&x| x == 0.0));
        assert!(b0[8..14].iter().any(|&x| x != 0.0));
        assert!(b0[8..14].iter().all(|&x| x.abs() < 0.2));
        // Embedding is random.
        assert!(s.embed.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let m = manifest();
        let a = ParamStore::init(&m, 7, Codec::F32).to_flat_f32();
        let b = ParamStore::init(&m, 7, Codec::F32).to_flat_f32();
        let c = ParamStore::init(&m, 8, Codec::F32).to_flat_f32();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn module_sizes_order() {
        let m = manifest();
        let s = ParamStore::init(&m, 1, Codec::F32);
        assert_eq!(s.module_sizes(), vec![40, 14, 14, 40]);
    }

    #[test]
    fn compressed_store_wire_bytes() {
        let m = manifest();
        let s32 = ParamStore::init(&m, 1, Codec::F32);
        let s16 = ParamStore::init(&m, 1, Codec::Bf16);
        assert_eq!(s32.block_wire_bytes(), 2 * 14 * 4);
        assert_eq!(s16.block_wire_bytes(), 2 * 14 * 2);
    }
}
