//! Host-side ZO optimizers (extension; paper §3: "Our idea can be applied
//! to other ZO optimizers").
//!
//! Two reasons these exist:
//!
//! 1. **ZO-AdamW** — the projected-gradient trick generalises: the full
//!    gradient estimate is `g·z`, so Adam's moments are updated elementwise
//!    with `g·z_i` while `z` is replayed from the managed RNG state.  The
//!    moments (2 extra copies of the parameters) live in **CPU DRAM** — on
//!    the GPU they would erase ZO2's memory win, which is exactly the
//!    ZeRO-Offload argument for CPU-side optimizer state.
//! 2. **Update-site ablation** (DESIGN.md §7): ZO2 updates on the GPU fused
//!    with the dual forward (§5.4).  The alternative — update on the CPU
//!    while the bucket is host-resident — costs zero extra transfers but
//!    puts elementwise work on the slow side.  `CpuZoSgd` implements it
//!    bit-compatibly with the device path (same mul/mul/sub rounding as the
//!    barriered kernel) so the two sites can be compared for *throughput*
//!    without a numerics confound.
//!
//! z replay note: the device path draws z from threefry keys; replaying that
//! exact draw on the host (threefry + erfinv) is not practical, so CPU
//! optimizers draw from the host counter RNG (`fill_z`).  They are
//! therefore their *own* optimizer trajectory — deterministic and
//! self-consistent (deferred vs immediate application commutes bit-exactly,
//! see `deferred_equals_immediate` below), but not bitwise the GPU
//! trajectory.  DESIGN.md records this as the one place the two sites
//! differ.

use crate::rng::RngState;
use crate::zo::fill_z;

/// Elementwise ZO-SGD on a host-resident fp32 bucket:
/// `θ ← θ − η·g·z`, z replayed from `state`.
pub fn cpu_zo_sgd_update(bucket: &mut [f32], state: RngState, lr: f32, g: f32, z_scratch: &mut Vec<f32>) {
    if z_scratch.len() < bucket.len() {
        z_scratch.resize(bucket.len(), 0.0);
    }
    let z = &mut z_scratch[..bucket.len()];
    fill_z(state, z);
    let scale = lr * g;
    for (w, &zi) in bucket.iter_mut().zip(z.iter()) {
        // Same op order as the barriered device kernel: mul, then sub.
        *w -= scale * zi;
    }
}

/// Adam moments for one bucket (CPU DRAM resident).
#[derive(Debug, Clone)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: u64,
}

impl AdamState {
    pub fn new(n: usize) -> Self {
        Self { m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// Host bytes this state occupies (for the memory accounting story).
    pub fn bytes(&self) -> u64 {
        (self.m.len() + self.v.len()) as u64 * 4
    }
}

/// ZO-AdamW hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct AdamHp {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamHp {
    fn default() -> Self {
        Self { lr: 1e-4, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

/// One ZO-AdamW step on a host bucket: gradient estimate `gi = g·z_i`
/// (never materialised as a whole — consumed streaming), moments updated in
/// place, decoupled weight decay.
pub fn cpu_zo_adamw_update(
    bucket: &mut [f32],
    st: &mut AdamState,
    state: RngState,
    hp: AdamHp,
    g: f32,
    z_scratch: &mut Vec<f32>,
) {
    assert_eq!(st.m.len(), bucket.len());
    if z_scratch.len() < bucket.len() {
        z_scratch.resize(bucket.len(), 0.0);
    }
    let z = &mut z_scratch[..bucket.len()];
    fill_z(state, z);
    st.t += 1;
    let b1t = 1.0 - hp.beta1.powi(st.t as i32);
    let b2t = 1.0 - hp.beta2.powi(st.t as i32);
    for i in 0..bucket.len() {
        let gi = g * z[i];
        st.m[i] = hp.beta1 * st.m[i] + (1.0 - hp.beta1) * gi;
        st.v[i] = hp.beta2 * st.v[i] + (1.0 - hp.beta2) * gi * gi;
        let mhat = st.m[i] / b1t;
        let vhat = st.v[i] / b2t;
        bucket[i] -= hp.lr * (mhat / (vhat.sqrt() + hp.eps) + hp.weight_decay * bucket[i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::RngState;

    fn state(c: u64) -> RngState {
        RngState { seed: 7, stream: 1, counter: c }
    }

    #[test]
    fn sgd_update_matches_manual() {
        let mut b = vec![1.0f32, -2.0, 0.5, 3.0];
        let mut want = b.clone();
        let mut z = Vec::new();
        cpu_zo_sgd_update(&mut b, state(0), 0.1, 2.0, &mut z);
        let mut zv = vec![0.0; 4];
        fill_z(state(0), &mut zv);
        for (w, zi) in want.iter_mut().zip(&zv) {
            *w -= 0.2 * zi;
        }
        assert_eq!(b, want);
    }

    #[test]
    fn sgd_zero_g_is_noop() {
        let mut b = vec![1.0f32; 100];
        let orig = b.clone();
        let mut z = Vec::new();
        cpu_zo_sgd_update(&mut b, state(3), 1e-3, 0.0, &mut z);
        assert_eq!(b, orig);
    }

    #[test]
    fn adam_first_step_is_sign_sgd_like() {
        // With t=1, mhat = gi and vhat = gi², so the step is
        // lr·gi/(|gi|+eps) ≈ lr·sign(gi) — the classic Adam property.
        let mut b = vec![0.0f32; 1000];
        let mut st = AdamState::new(1000);
        let hp = AdamHp { lr: 1e-2, ..Default::default() };
        let mut z = Vec::new();
        cpu_zo_adamw_update(&mut b, &mut st, state(0), hp, 1.5, &mut z);
        let mut zv = vec![0.0; 1000];
        fill_z(state(0), &mut zv);
        for (w, zi) in b.iter().zip(&zv) {
            let expect = -1e-2 * (1.5 * zi).signum();
            assert!((w - expect).abs() < 1e-4, "{w} vs {expect}");
        }
        assert_eq!(st.t, 1);
    }

    #[test]
    fn adam_moments_decay_and_converge_direction() {
        // Feeding the same g and z repeatedly must keep stepping the same
        // direction with bounded magnitude (lr), never NaN.
        let mut b = vec![0.5f32; 64];
        let mut st = AdamState::new(64);
        let hp = AdamHp { lr: 1e-3, ..Default::default() };
        let mut z = Vec::new();
        let before = b.clone();
        for _ in 0..50 {
            cpu_zo_adamw_update(&mut b, &mut st, state(5), hp, 2.0, &mut z);
        }
        let mut zv = vec![0.0; 64];
        fill_z(state(5), &mut zv);
        for ((w0, w), zi) in before.iter().zip(&b).zip(&zv) {
            assert!(w.is_finite());
            // moved against the sign of g*z
            if zi.abs() > 1e-3 {
                assert!((w0 - w).signum() == (2.0 * zi).signum(), "{w0} -> {w}, z {zi}");
            }
        }
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut b = vec![1.0f32; 32];
        let mut st = AdamState::new(32);
        let hp = AdamHp { lr: 1e-2, weight_decay: 0.1, ..Default::default() };
        let mut z = Vec::new();
        cpu_zo_adamw_update(&mut b, &mut st, state(9), hp, 0.0, &mut z);
        // g = 0: pure decay, θ ← θ(1 − lr·wd)
        for w in &b {
            assert!((w - (1.0 - 1e-3)).abs() < 1e-6);
        }
    }

    #[test]
    fn adam_state_bytes() {
        assert_eq!(AdamState::new(1000).bytes(), 8000);
    }

    #[test]
    fn deferred_equals_immediate() {
        // The §5.4 reordering argument at the CPU site: applying update j
        // right after step j (MeZO order) or deferring it to just before
        // step j+1's use (ZO2 order) yields bit-identical parameters,
        // because updates are independent per bucket and replay the same z.
        let mut immediate = vec![0.3f32; 500];
        let mut z = Vec::new();
        for j in 0..5u64 {
            cpu_zo_sgd_update(&mut immediate, state(j), 1e-3, 0.5 + j as f32, &mut z);
        }
        let mut deferred = vec![0.3f32; 500];
        let mut pending: Option<(RngState, f32)> = None;
        for j in 0..5u64 {
            if let Some((st, g)) = pending.take() {
                cpu_zo_sgd_update(&mut deferred, st, 1e-3, g, &mut z);
            }
            pending = Some((state(j), 0.5 + j as f32));
        }
        if let Some((st, g)) = pending {
            cpu_zo_sgd_update(&mut deferred, st, 1e-3, g, &mut z); // flush
        }
        assert!(immediate.iter().zip(&deferred).all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}
