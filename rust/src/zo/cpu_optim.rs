//! Host-side ZO optimizers (extension; paper §3: "Our idea can be applied
//! to other ZO optimizers").
//!
//! Two reasons these exist:
//!
//! 1. **ZO-AdamW** — the projected-gradient trick generalises: the full
//!    gradient estimate is `g·z`, so Adam's moments are updated elementwise
//!    with `g·z_i` while `z` is replayed from the managed RNG state.  The
//!    moments (2 extra copies of the parameters) live in **CPU DRAM** — on
//!    the GPU they would erase ZO2's memory win, which is exactly the
//!    ZeRO-Offload argument for CPU-side optimizer state.
//! 2. **Update-site ablation** (DESIGN.md §7): ZO2 updates on the GPU fused
//!    with the dual forward (§5.4).  The alternative — update on the CPU
//!    while the bucket is host-resident — costs zero extra transfers but
//!    puts elementwise work on the slow side.  `Zo2Options::update_site`
//!    selects it in the real engine; the kernels here implement it
//!    bit-compatibly with the device path's op order (mul, then sub).
//!
//! Three implementations of the same math, all bit-identical to each other:
//!
//! * the scalar reference functions ([`cpu_zo_sgd_update`],
//!   [`cpu_zo_adamw_update`]) — single-threaded, z through a [`ZScratch`];
//! * the pooled variants (`*_pooled`) — deterministic fixed-size chunking
//!   over the [`crate::hostpool::HostPool`], z replayed per chunk from
//!   counter offsets, so the result is independent of thread count;
//! * the fused wire-domain variants ([`fused_zo_sgd`](crate::hostpool::fused::fused_zo_sgd)
//!   and [`fused_zo_adamw`]) — decode→update→encode in one pass per chunk,
//!   never materialising a bucket-sized fp32 intermediate.
//!
//! z replay note: the device path draws z from threefry keys; replaying that
//! exact draw on the host (threefry + erfinv) is not practical, so CPU
//! optimizers draw from the host counter RNG (`fill_z`).  They are
//! therefore their *own* optimizer trajectory — deterministic and
//! self-consistent (deferred vs immediate application commutes bit-exactly,
//! see `deferred_equals_immediate` below), but not bitwise the GPU
//! trajectory.  DESIGN.md records this as the one place the two sites
//! differ.

use crate::hostpool::fused::{fill_z_chunk, map_wire_chunk};
use crate::hostpool::{HostPool, SlicePtr, CHUNK_ELEMS};
use crate::precision::Codec;
use crate::rng::RngState;
use crate::telemetry::HOST_SCRATCH;
use crate::zo::fill_z;

/// Reusable z-replay scratch with a shrink policy and telemetry-accounted
/// bytes (the fix for the grow-only scratch Vecs): capacity is capped at
/// the largest *live* bucket.  The cap auto-raises to the largest request
/// seen — so a workload alternating bucket sizes never thrashes between
/// grow and shrink — and [`Self::set_cap`] lowers it when the owner knows
/// the big buckets are gone, releasing the excess instead of pinning the
/// high-water mark forever.
#[derive(Debug, Default)]
pub struct ZScratch {
    buf: Vec<f32>,
    /// Largest bucket (elements) assumed still live: the running max of
    /// requests, lowered explicitly via [`Self::set_cap`].  Capacity beyond
    /// `2 × max(cap_elems, request)` is released after each fill.
    cap_elems: usize,
}

impl ZScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare the largest bucket still live.  Lowers the retention cap
    /// (it auto-raises again as larger requests arrive), so call this when
    /// the big buckets this scratch served are gone.
    pub fn set_cap(&mut self, elems: usize) {
        self.cap_elems = elems;
    }

    /// Bytes currently held (mirrored into [`HOST_SCRATCH`]).
    pub fn bytes(&self) -> u64 {
        (self.buf.capacity() * 4) as u64
    }

    /// Fill and return the replayed z for `n` elements.
    pub fn z_for(&mut self, state: RngState, n: usize) -> &[f32] {
        let before = self.bytes();
        if self.buf.len() < n {
            self.buf.resize(n, 0.0);
        }
        self.cap_elems = self.cap_elems.max(n);
        let keep = self.cap_elems;
        if self.buf.capacity() > keep.saturating_mul(2) {
            self.buf.truncate(keep.max(n));
            self.buf.shrink_to(keep.max(n));
        }
        let after = self.bytes();
        if after > before {
            HOST_SCRATCH.add(after - before);
        } else {
            HOST_SCRATCH.sub(before - after);
        }
        let z = &mut self.buf[..n];
        fill_z(state, z);
        z
    }
}

impl Drop for ZScratch {
    fn drop(&mut self) {
        HOST_SCRATCH.sub(self.bytes());
    }
}

/// Elementwise ZO-SGD on a host-resident fp32 bucket:
/// `θ ← θ − η·g·z`, z replayed from `state`.  Scalar reference.
pub fn cpu_zo_sgd_update(bucket: &mut [f32], state: RngState, lr: f32, g: f32, z: &mut ZScratch) {
    let z = z.z_for(state, bucket.len());
    let scale = lr * g;
    for (w, &zi) in bucket.iter_mut().zip(z.iter()) {
        // Same op order as the barriered device kernel: mul, then sub.
        *w -= scale * zi;
    }
}

/// Pooled ZO-SGD: deterministic fixed-size chunks over the host pool, z
/// replayed per chunk.  Bit-identical to [`cpu_zo_sgd_update`] at any
/// thread count; needs no scratch at all.
pub fn cpu_zo_sgd_update_pooled(
    pool: &HostPool,
    bucket: &mut [f32],
    state: RngState,
    lr: f32,
    g: f32,
) {
    let scale = lr * g;
    let n = bucket.len();
    let bp = SlicePtr::new(bucket);
    pool.for_chunks(n, |_, start, len| {
        // Safety: chunk ranges are disjoint by construction.
        let w = unsafe { std::slice::from_raw_parts_mut(bp.at(start), len) };
        let mut z = [0.0f32; CHUNK_ELEMS];
        let z = &mut z[..len];
        fill_z_chunk(state, start, z);
        if !crate::simd::try_sgd_update(w, z, scale) {
            for (wi, &zi) in w.iter_mut().zip(z.iter()) {
                *wi -= scale * zi;
            }
        }
    });
}

/// Adam moments for one bucket (CPU DRAM resident).
#[derive(Debug, Clone)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: u64,
}

impl AdamState {
    pub fn new(n: usize) -> Self {
        Self { m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// Host bytes this state occupies (for the memory accounting story).
    pub fn bytes(&self) -> u64 {
        (self.m.len() + self.v.len()) as u64 * 4
    }
}

/// ZO-AdamW hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct AdamHp {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamHp {
    fn default() -> Self {
        Self { lr: 1e-4, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

/// The per-element ZO-AdamW step: returns the updated weight, mutating the
/// moment cells in place.  One body shared by the scalar, pooled and fused
/// variants — sharing it *is* the bit-identity argument (`pub(crate)` so
/// the AVX2 kernel's scalar tail reuses it too).
#[inline]
pub(crate) fn adamw_el(
    w: f32,
    m: &mut f32,
    v: &mut f32,
    gi: f32,
    hp: AdamHp,
    b1t: f32,
    b2t: f32,
) -> f32 {
    *m = hp.beta1 * *m + (1.0 - hp.beta1) * gi;
    *v = hp.beta2 * *v + (1.0 - hp.beta2) * gi * gi;
    let mhat = *m / b1t;
    let vhat = *v / b2t;
    w - hp.lr * (mhat / (vhat.sqrt() + hp.eps) + hp.weight_decay * w)
}

/// One ZO-AdamW step on a host bucket: gradient estimate `gi = g·z_i`
/// (never materialised as a whole — consumed streaming), moments updated in
/// place, decoupled weight decay.  Scalar reference.
pub fn cpu_zo_adamw_update(
    bucket: &mut [f32],
    st: &mut AdamState,
    state: RngState,
    hp: AdamHp,
    g: f32,
    z: &mut ZScratch,
) {
    assert_eq!(st.m.len(), bucket.len());
    let z = z.z_for(state, bucket.len());
    st.t += 1;
    let b1t = 1.0 - hp.beta1.powi(st.t as i32);
    let b2t = 1.0 - hp.beta2.powi(st.t as i32);
    for i in 0..bucket.len() {
        bucket[i] = adamw_el(bucket[i], &mut st.m[i], &mut st.v[i], g * z[i], hp, b1t, b2t);
    }
}

/// Pooled ZO-AdamW over fp32 buckets — bit-identical to
/// [`cpu_zo_adamw_update`] at any thread count.
pub fn cpu_zo_adamw_update_pooled(
    pool: &HostPool,
    bucket: &mut [f32],
    st: &mut AdamState,
    state: RngState,
    hp: AdamHp,
    g: f32,
) {
    assert_eq!(st.m.len(), bucket.len());
    st.t += 1;
    let b1t = 1.0 - hp.beta1.powi(st.t as i32);
    let b2t = 1.0 - hp.beta2.powi(st.t as i32);
    let n = bucket.len();
    let bp = SlicePtr::new(bucket);
    let mp = SlicePtr::new(&mut st.m);
    let vp = SlicePtr::new(&mut st.v);
    pool.for_chunks(n, |_, start, len| {
        // Safety: chunk ranges are disjoint by construction.
        let (w, m, v) = unsafe {
            (
                std::slice::from_raw_parts_mut(bp.at(start), len),
                std::slice::from_raw_parts_mut(mp.at(start), len),
                std::slice::from_raw_parts_mut(vp.at(start), len),
            )
        };
        let mut z = [0.0f32; CHUNK_ELEMS];
        let z = &mut z[..len];
        fill_z_chunk(state, start, z);
        if !crate::simd::try_adamw_update(w, m, v, z, g, hp, b1t, b2t) {
            for i in 0..len {
                w[i] = adamw_el(w[i], &mut m[i], &mut v[i], g * z[i], hp, b1t, b2t);
            }
        }
    });
}

/// Fused ZO-AdamW on an *encoded* bucket: decode→moment-update→encode in a
/// single pass per chunk, keeping the low-bit master copy low-bit the whole
/// way (the quantized-ZO motivation) while the fp32 moments stay in DRAM.
/// Bit-identical to decode → [`cpu_zo_adamw_update`] → encode.
pub fn fused_zo_adamw(
    pool: &HostPool,
    codec: Codec,
    wire: &mut [u8],
    st: &mut AdamState,
    state: RngState,
    hp: AdamHp,
    g: f32,
) {
    let n = st.m.len();
    assert_eq!(wire.len(), n * codec.bytes_per_el(), "payload size mismatch");
    st.t += 1;
    let b1t = 1.0 - hp.beta1.powi(st.t as i32);
    let b2t = 1.0 - hp.beta2.powi(st.t as i32);
    let bpe = codec.bytes_per_el();
    let wp = SlicePtr::new(wire);
    let mp = SlicePtr::new(&mut st.m);
    let vp = SlicePtr::new(&mut st.v);
    pool.for_chunks(n, |_, start, len| {
        // Safety: chunk ranges are disjoint by construction.
        let (bytes, m, v) = unsafe {
            (
                std::slice::from_raw_parts_mut(wp.at(start * bpe), len * bpe),
                std::slice::from_raw_parts_mut(mp.at(start), len),
                std::slice::from_raw_parts_mut(vp.at(start), len),
            )
        };
        let mut z = [0.0f32; CHUNK_ELEMS];
        let z = &mut z[..len];
        fill_z_chunk(state, start, z);
        if !simd_adamw_wire_chunk(codec, bytes, len, m, v, z, g, hp, b1t, b2t) {
            map_wire_chunk(codec, bytes, len, |i, w| {
                adamw_el(w, &mut m[i], &mut v[i], g * z[i], hp, b1t, b2t)
            });
        }
    });
}

/// Staged SIMD variant of the fused AdamW chunk pass (decode → vector
/// moment-update → encode through a 64 KiB stack buffer) — the AdamW twin
/// of [`crate::hostpool::fused::simd_sgd_wire_chunk`], with the same
/// bit-identity argument.  Returns `false` when the vector path is off.
#[allow(clippy::too_many_arguments)]
#[inline]
fn simd_adamw_wire_chunk(
    codec: Codec,
    bytes: &mut [u8],
    len: usize,
    m: &mut [f32],
    v: &mut [f32],
    z: &[f32],
    g: f32,
    hp: AdamHp,
    b1t: f32,
    b2t: f32,
) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if crate::simd::active() && len <= CHUNK_ELEMS {
            let mut buf = [0.0f32; CHUNK_ELEMS];
            let w = &mut buf[..len];
            // Safety: AVX2 availability is checked by `active()`; slice
            // sizes match the chunk grid.
            unsafe {
                crate::simd::avx2::decode_chunk(codec, bytes, w);
                crate::simd::avx2::adamw_update(w, m, v, &z[..len], g, hp, b1t, b2t);
                crate::simd::avx2::encode_chunk(codec, w, bytes);
            }
            return true;
        }
    }
    let _ = (codec, bytes, len, m, v, z, g, hp, b1t, b2t);
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::RngState;

    fn state(c: u64) -> RngState {
        RngState { seed: 7, stream: 1, counter: c }
    }

    #[test]
    fn sgd_update_matches_manual() {
        let mut b = vec![1.0f32, -2.0, 0.5, 3.0];
        let mut want = b.clone();
        let mut z = ZScratch::new();
        cpu_zo_sgd_update(&mut b, state(0), 0.1, 2.0, &mut z);
        let mut zv = vec![0.0; 4];
        fill_z(state(0), &mut zv);
        for (w, zi) in want.iter_mut().zip(&zv) {
            *w -= 0.2 * zi;
        }
        assert_eq!(b, want);
    }

    #[test]
    fn sgd_zero_g_is_noop() {
        let mut b = vec![1.0f32; 100];
        let orig = b.clone();
        let mut z = ZScratch::new();
        cpu_zo_sgd_update(&mut b, state(3), 1e-3, 0.0, &mut z);
        assert_eq!(b, orig);
    }

    #[test]
    fn pooled_sgd_is_bit_identical_to_scalar_at_any_thread_count() {
        let n = 3 * CHUNK_ELEMS + 451;
        let mut reference = vec![0.0f32; n];
        fill_z(state(99), &mut reference); // arbitrary deterministic weights
        let mut z = ZScratch::new();
        let mut scalar = reference.clone();
        cpu_zo_sgd_update(&mut scalar, state(4), 2e-3, 1.7, &mut z);
        for threads in [1usize, 2, 8] {
            let pool = HostPool::new(threads);
            let mut pooled = reference.clone();
            cpu_zo_sgd_update_pooled(&pool, &mut pooled, state(4), 2e-3, 1.7);
            let same = scalar.iter().zip(&pooled).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "{threads} threads");
        }
    }

    #[test]
    fn pooled_adamw_is_bit_identical_to_scalar() {
        let n = CHUNK_ELEMS + 333;
        let mut reference = vec![0.0f32; n];
        fill_z(state(50), &mut reference);
        let hp = AdamHp { lr: 1e-3, weight_decay: 0.01, ..Default::default() };
        let mut scalar = reference.clone();
        let mut st_s = AdamState::new(n);
        let mut z = ZScratch::new();
        for step in 0..3u64 {
            cpu_zo_adamw_update(&mut scalar, &mut st_s, state(step), hp, 0.8, &mut z);
        }
        let pool = HostPool::new(8);
        let mut pooled = reference.clone();
        let mut st_p = AdamState::new(n);
        for step in 0..3u64 {
            cpu_zo_adamw_update_pooled(&pool, &mut pooled, &mut st_p, state(step), hp, 0.8);
        }
        assert_eq!(st_s.t, st_p.t);
        assert!(scalar.iter().zip(&pooled).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(st_s.m.iter().zip(&st_p.m).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(st_s.v.iter().zip(&st_p.v).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn fused_adamw_matches_unfused_composition() {
        let n = CHUNK_ELEMS + 77;
        let mut xs = vec![0.0f32; n];
        fill_z(state(60), &mut xs);
        for x in xs.iter_mut() {
            *x *= 0.02;
        }
        let hp = AdamHp { lr: 1e-3, ..Default::default() };
        let pool = HostPool::new(4);
        for codec in [Codec::F32, Codec::Bf16, Codec::Fp16, Codec::Fp8E4M3] {
            let wire0 = codec.encode(&xs);
            // Reference: decode, scalar AdamW on fp32, encode.
            let mut dec = codec.decode(&wire0, n);
            let mut st_ref = AdamState::new(n);
            let mut z = ZScratch::new();
            cpu_zo_adamw_update(&mut dec, &mut st_ref, state(8), hp, 1.1, &mut z);
            let want = codec.encode(&dec);
            // Fused single pass in the wire domain.
            let mut got = wire0.clone();
            let mut st_fused = AdamState::new(n);
            fused_zo_adamw(&pool, codec, &mut got, &mut st_fused, state(8), hp, 1.1);
            assert_eq!(got, want, "{codec:?}");
            assert!(st_ref.m.iter().zip(&st_fused.m).all(|(a, b)| a.to_bits() == b.to_bits()));
            assert!(st_ref.v.iter().zip(&st_fused.v).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn adam_first_step_is_sign_sgd_like() {
        // With t=1, mhat = gi and vhat = gi², so the step is
        // lr·gi/(|gi|+eps) ≈ lr·sign(gi) — the classic Adam property.
        let mut b = vec![0.0f32; 1000];
        let mut st = AdamState::new(1000);
        let hp = AdamHp { lr: 1e-2, ..Default::default() };
        let mut z = ZScratch::new();
        cpu_zo_adamw_update(&mut b, &mut st, state(0), hp, 1.5, &mut z);
        let mut zv = vec![0.0; 1000];
        fill_z(state(0), &mut zv);
        for (w, zi) in b.iter().zip(&zv) {
            let expect = -1e-2 * (1.5 * zi).signum();
            assert!((w - expect).abs() < 1e-4, "{w} vs {expect}");
        }
        assert_eq!(st.t, 1);
    }

    #[test]
    fn adam_moments_decay_and_converge_direction() {
        // Feeding the same g and z repeatedly must keep stepping the same
        // direction with bounded magnitude (lr), never NaN.
        let mut b = vec![0.5f32; 64];
        let mut st = AdamState::new(64);
        let hp = AdamHp { lr: 1e-3, ..Default::default() };
        let mut z = ZScratch::new();
        let before = b.clone();
        for _ in 0..50 {
            cpu_zo_adamw_update(&mut b, &mut st, state(5), hp, 2.0, &mut z);
        }
        let mut zv = vec![0.0; 64];
        fill_z(state(5), &mut zv);
        for ((w0, w), zi) in before.iter().zip(&b).zip(&zv) {
            assert!(w.is_finite());
            // moved against the sign of g*z
            if zi.abs() > 1e-3 {
                assert!((w0 - w).signum() == (2.0 * zi).signum(), "{w0} -> {w}, z {zi}");
            }
        }
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut b = vec![1.0f32; 32];
        let mut st = AdamState::new(32);
        let hp = AdamHp { lr: 1e-2, weight_decay: 0.1, ..Default::default() };
        let mut z = ZScratch::new();
        cpu_zo_adamw_update(&mut b, &mut st, state(9), hp, 0.0, &mut z);
        // g = 0: pure decay, θ ← θ(1 − lr·wd)
        for w in &b {
            assert!((w - (1.0 - 1e-3)).abs() < 1e-6);
        }
    }

    #[test]
    fn adam_state_bytes() {
        assert_eq!(AdamState::new(1000).bytes(), 8000);
    }

    #[test]
    fn zscratch_shrinks_to_cap_and_accounts_bytes() {
        // NOTE: HOST_SCRATCH is process-global and other tests run
        // concurrently, so only monotonic (peak) properties are asserted on
        // the gauge; the shrink policy itself is asserted on the local
        // instance.
        let mut z = ZScratch::new();
        let _ = z.z_for(state(0), 100_000);
        assert!(z.bytes() >= 400_000);
        assert!(HOST_SCRATCH.peak() >= z.bytes(), "gauge must have seen the allocation");
        // Without a cap update the capacity is retained (alternating sizes
        // must not thrash)…
        let _ = z.z_for(state(1), 10);
        assert!(z.bytes() >= 400_000, "high-water mark retained while the big bucket lives");
        // …and declaring the big bucket dead releases the excess.
        z.set_cap(1000);
        let _ = z.z_for(state(1), 10);
        assert!(
            z.bytes() <= 2 * 4 * 1000,
            "scratch {} bytes must shrink to ~cap after the big bucket dies",
            z.bytes()
        );
        // The fill itself stays correct across grow/shrink cycles.
        let got = z.z_for(state(2), 64).to_vec();
        let mut want = vec![0.0f32; 64];
        fill_z(state(2), &mut want);
        assert_eq!(got, want);
    }

    #[test]
    fn deferred_equals_immediate() {
        // The §5.4 reordering argument at the CPU site: applying update j
        // right after step j (MeZO order) or deferring it to just before
        // step j+1's use (ZO2 order) yields bit-identical parameters,
        // because updates are independent per bucket and replay the same z.
        let mut immediate = vec![0.3f32; 500];
        let mut z = ZScratch::new();
        for j in 0..5u64 {
            cpu_zo_sgd_update(&mut immediate, state(j), 1e-3, 0.5 + j as f32, &mut z);
        }
        let mut deferred = vec![0.3f32; 500];
        let mut pending: Option<(RngState, f32)> = None;
        for j in 0..5u64 {
            if let Some((st, g)) = pending.take() {
                cpu_zo_sgd_update(&mut deferred, st, 1e-3, g, &mut z);
            }
            pending = Some((state(j), 0.5 + j as f32));
        }
        if let Some((st, g)) = pending {
            cpu_zo_sgd_update(&mut deferred, st, 1e-3, g, &mut z); // flush
        }
        assert!(immediate.iter().zip(&deferred).all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}
