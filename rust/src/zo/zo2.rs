//! The ZO2 engine (paper Algorithms 2 + 3).
//!
//! Transformer blocks live in host memory (the "CPU DDR" tier), optionally
//! compressed (AMP mode §5.5); the embedding and LM head stay device-
//! resident (§5.2).  Each training step streams every block through the
//! reusable device buffer (§5.3): upload (decode) → fused
//! deferred-update + dual-forward (§5.4) → offload (encode the *updated*
//! bucket back).  The projected gradient of step `j` is applied to each
//! block at the start of step `j+1`, with the perturbation direction
//! replayed from the RNG states recorded at step `j` (§5.1).
//!
//! Two run modes share identical numerics:
//! * [`RunMode::Sequential`] — the naive Fig. 4a schedule (ablation
//!   baseline): upload, compute, offload strictly in order.
//! * [`RunMode::Overlapped`] — the Fig. 4b dynamic schedule: an upload
//!   thread prefetches block `i+1` and an offload thread compresses block
//!   `i−1` while the main thread computes block `i`; backpressure comes
//!   from the slot ring (bounded channels), realising Algorithm 3's
//!   dependency rules with real threads.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::hostpool::HostPool;
use crate::memory::{
    DevicePool, DiskBucket, DiskPool, DramWindow, HostBucket, TransferEngine, TransferModel,
};
use crate::memory::transfer::TransferStats;
use crate::precision::Codec;
use crate::rng::{RngState, RngStateManager};
use crate::runtime::{lit_f32, lit_i32, lit_key, lit_scalar, lit_to_f32, lit_to_scalar, Runtime};
use crate::sched::{SpillPlacement, Tiering};
use crate::telemetry::{Timeline, TraceEvent};
use crate::zo::{key_of, module_states, ParamStore, StepStats, ZoConfig};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunMode {
    Sequential,
    Overlapped,
}

/// Where the deferred block update executes (the update-site ablation,
/// DESIGN.md §7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdateSite {
    /// Paper §5.4: fused into the device dual-forward executable.
    Device,
    /// Host side: a fused decode→update→encode pass over the host compute
    /// pool while the bucket is DDR-resident — zero extra transfers, the
    /// elementwise work moves off the device.  Deterministic and
    /// self-consistent, but its own trajectory (host RNG draw instead of
    /// the device threefry draw; see `cpu_optim` module docs).
    Cpu,
}

/// Engine options (the Table 4 / Table 5 switches + the disk tier).
#[derive(Debug, Clone, Copy)]
pub struct Zo2Options {
    /// Wire/storage codec for offloaded blocks (AMP compression, §5.5).
    /// The disk tier stores spilled buckets in the same codec.
    pub wire: Codec,
    pub run_mode: RunMode,
    /// §5.3 reusable buffer; `false` allocates per upload (ablation).
    pub reusable_mem: bool,
    /// §5.4 fused deferred update; `false` runs a second
    /// upload→update→offload round per block per step (ablation).
    pub efficient_update: bool,
    /// In-flight block slots (compute + prefetch + offload).
    pub slots: usize,
    /// Simulated device capacity (bytes); checked by the device pool.
    pub device_capacity: u64,
    /// Where block master copies live.  `ThreeTier` spills every block
    /// beyond `dram_resident_blocks` to a file-backed NVMe pool; the loss
    /// trajectory is bit-identical to `TwoTier` (offload location never
    /// changes the math, §5.1).
    pub tiering: Tiering,
    /// DRAM staging-window slots for spilled buckets (disk look-ahead).
    pub dram_slots: usize,
    /// Blocks whose master copy stays in DRAM under `ThreeTier`
    /// (`usize::MAX` = all resident, i.e. an empty disk tier).
    pub dram_resident_blocks: usize,
    /// Which blocks spill under `ThreeTier` (trailing burst vs interleaved
    /// through the block order).  Placement never changes the math — only
    /// which buckets live on the pool file.
    pub spill_placement: SpillPlacement,
    /// Where the deferred update runs: fused on the device (§5.4) or as a
    /// fused wire-domain pass on the host pool (update-site ablation).
    pub update_site: UpdateSite,
    /// Host compute pool participants for codec/update kernels
    /// (0 = machine parallelism).  Never changes numerics: host kernel
    /// results are bit-identical at any thread count.
    pub host_threads: usize,
    /// Pin pool workers to cores round-robined across NUMA nodes and give
    /// the pool a static chunk→worker map (first-touch locality).  Never
    /// changes numerics — chunk results are position-independent.
    pub host_pin: bool,
}

impl Default for Zo2Options {
    fn default() -> Self {
        Self {
            wire: Codec::F32,
            run_mode: RunMode::Overlapped,
            reusable_mem: true,
            efficient_update: true,
            slots: 3,
            device_capacity: u64::MAX,
            tiering: Tiering::TwoTier,
            dram_slots: 4,
            dram_resident_blocks: usize::MAX,
            spill_placement: SpillPlacement::Trailing,
            update_site: UpdateSite::Device,
            host_threads: 0,
            host_pin: false,
        }
    }
}

/// Deferred-update state carried between steps (paper Fig. 5b).
struct Pending {
    g: f32,
    states: Vec<RngState>,
}

/// Deferred-update work routed to the host (CPU update site): applied as a
/// fused wire-domain pass right before each block's upload.
#[derive(Clone, Copy)]
struct HostUpdate {
    apply: bool,
    lr: f32,
    g: f32,
}

/// The engine's disk tier: a pool file holding spilled buckets, one entry
/// per spilled block, and the accounted DRAM staging window they stream
/// through.
struct DiskTier {
    pool: DiskPool,
    /// `Some(bucket)` exactly for spilled blocks (index-aligned with
    /// `params.blocks`, whose spilled entries are placeholders).
    entries: Vec<Option<DiskBucket>>,
    window: DramWindow,
}

pub struct Zo2Engine {
    rt: Runtime,
    pub params: ParamStore,
    cfg: ZoConfig,
    pub opts: Zo2Options,
    manager: RngStateManager,
    step: u64,
    pending: Option<Pending>,
    pub device: Arc<DevicePool>,
    pub transfers: Mutex<TransferEngine>,
    pub transfer_model: TransferModel,
    disk: Option<DiskTier>,
    /// Host compute pool for codec and CPU-site update kernels — spawned
    /// once here, shared by every pipeline thread for the engine's life.
    pub hostpool: Arc<HostPool>,
    /// Timeline of the most recent step (real Fig. 4 data).
    pub last_timeline: Timeline,
}

impl Zo2Engine {
    pub fn new(rt: Runtime, cfg: ZoConfig, opts: Zo2Options) -> Result<Self> {
        // Fresh engine, fresh scratch accounting: back-to-back runs in one
        // process must not inherit the previous run's peak.
        crate::telemetry::HOST_SCRATCH.reset();
        let mut params = ParamStore::init(rt.manifest(), cfg.seed, opts.wire);
        let device = DevicePool::new(opts.device_capacity);
        // Device residency: embedding + head (fp32) + the reusable slots.
        device.alloc(((params.embed.len() + params.head.len()) * 4) as u64)?;
        if opts.reusable_mem {
            device.alloc((rt.manifest().block.size * opts.slots * 4) as u64)?;
        }
        // Disk tier: spill every block beyond the DRAM-resident budget to a
        // file-backed pool, leaving shape-only placeholders in the store.
        // The spill *set* comes from the same placement rule the analytic
        // planner uses (`sched::is_spilled_block`), so `--spill-placement`
        // means the same thing in the simulator and the real engine.
        let n_blocks = params.blocks.len();
        let resident = opts.dram_resident_blocks.min(n_blocks);
        let disk = if opts.tiering == Tiering::ThreeTier && resident < n_blocks {
            let spilled = n_blocks - resident;
            let wire = params.blocks[0].wire_bytes() as u64;
            let pool =
                DiskPool::in_temp(u64::MAX, TransferModel::nvme_read(), TransferModel::nvme_write())?;
            let window = DramWindow::new(opts.dram_slots.max(1), wire);
            let mut entries: Vec<Option<DiskBucket>> = (0..n_blocks).map(|_| None).collect();
            for i in 0..n_blocks {
                if !crate::sched::is_spilled_block(i, n_blocks, spilled, opts.spill_placement) {
                    continue;
                }
                let numel = params.blocks[i].numel();
                let codec = params.blocks[i].codec();
                let bucket =
                    std::mem::replace(&mut params.blocks[i], HostBucket::placeholder(codec, numel));
                entries[i] = Some(pool.append(codec, numel, bucket.wire())?);
            }
            Some(DiskTier { pool, entries, window })
        } else {
            None
        };
        Ok(Self {
            rt,
            params,
            cfg,
            opts,
            manager: RngStateManager::new(cfg.seed),
            step: 0,
            pending: None,
            device,
            transfers: Mutex::new(TransferEngine::new()),
            transfer_model: TransferModel::pcie4(),
            disk,
            hostpool: Arc::new(HostPool::with_opts(opts.host_threads, opts.host_pin)),
            last_timeline: Timeline::new(),
        })
    }

    /// Whether block `i`'s master copy lives on the disk tier.
    pub fn is_spilled(&self, i: usize) -> bool {
        self.disk.as_ref().map_or(false, |t| t.entries[i].is_some())
    }

    /// Number of blocks on the disk tier (0 in two-tier mode).
    pub fn spilled_blocks(&self) -> usize {
        self.disk.as_ref().map_or(0, |t| t.entries.iter().filter(|e| e.is_some()).count())
    }

    /// Bytes occupied in the disk pool file.
    pub fn disk_used_bytes(&self) -> u64 {
        self.disk.as_ref().map_or(0, |t| t.pool.used())
    }

    /// (read, write) NVMe traffic stats, if the disk tier is active.
    pub fn disk_stats(&self) -> Option<(TransferStats, TransferStats)> {
        self.disk.as_ref().map(|t| (t.pool.read_stats(), t.pool.write_stats()))
    }

    /// Peak simultaneously-staged spilled buckets (≤ configured window).
    pub fn dram_window_peak_slots(&self) -> usize {
        self.disk.as_ref().map_or(0, |t| t.window.peak_slots())
    }

    /// Take block `i`'s encoded bucket into DRAM: a disk read (through the
    /// staging window) for spilled blocks, a move out of the store for
    /// resident ones (a placeholder is left behind either way).
    fn stage_block(&mut self, i: usize) -> Result<HostBucket> {
        if let Some(tier) = &self.disk {
            if let Some(entry) = &tier.entries[i] {
                tier.window.acquire(entry.wire_len() as u64)?;
                let bytes = tier.pool.read(entry)?;
                return Ok(HostBucket::from_wire(entry.codec(), entry.numel(), bytes));
            }
        }
        let numel = self.params.blocks[i].numel();
        let codec = self.params.blocks[i].codec();
        Ok(std::mem::replace(&mut self.params.blocks[i], HostBucket::placeholder(codec, numel)))
    }

    /// Return block `i`'s bucket: write-back to disk (freeing its window
    /// slot) for spilled blocks, back into the store for resident ones.
    /// `dirty = false` (eval paths) skips the disk write.
    fn unstage_block(&mut self, i: usize, bucket: HostBucket, dirty: bool) -> Result<()> {
        if let Some(tier) = &self.disk {
            if let Some(entry) = &tier.entries[i] {
                if dirty {
                    tier.pool.write(entry, bucket.wire())?;
                }
                tier.window.release(entry.wire_len() as u64);
                return Ok(());
            }
        }
        self.params.blocks[i] = bucket;
        Ok(())
    }

    /// Every parameter as one fp32 vector, reading spilled blocks from the
    /// disk tier (the tier-agnostic counterpart of
    /// [`ParamStore::to_flat_f32`], for parity checks).
    pub fn flat_params(&self) -> Result<Vec<f32>> {
        let mut out = self.params.embed.clone();
        // One batched submission covers every spilled bucket (io_uring when
        // available, positioned reads otherwise) instead of a pread per
        // block; decode order — and therefore the output — is unchanged.
        let mut batched: Vec<Vec<u8>> = Vec::new();
        if let Some(tier) = &self.disk {
            let spilled: Vec<&DiskBucket> = tier.entries.iter().flatten().collect();
            if !spilled.is_empty() {
                batched = tier.pool.read_batch(&spilled)?;
                batched.reverse(); // pop() below yields block order
            }
        }
        for i in 0..self.params.blocks.len() {
            if let Some(tier) = &self.disk {
                if let Some(entry) = &tier.entries[i] {
                    let bytes = batched.pop().expect("one batched read per spilled bucket");
                    let mut dec = vec![0.0f32; entry.numel()];
                    crate::hostpool::fused::decode_pooled(
                        entry.codec(),
                        &bytes,
                        &mut dec,
                        &self.hostpool,
                    );
                    out.extend(dec);
                    continue;
                }
            }
            out.extend(self.params.blocks[i].to_f32_pooled(&self.hostpool));
        }
        out.extend(self.params.head.iter());
        Ok(out)
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    fn scalars(&self, g_prev: f32) -> (xla::Literal, xla::Literal, xla::Literal) {
        (lit_scalar(self.cfg.lr), lit_scalar(self.cfg.eps), lit_scalar(g_prev))
    }

    /// One Algorithm-2 iteration.
    pub fn train_step(&mut self, ids: &[i32]) -> Result<StepStats> {
        // zo2-lint: allow(no-wall-clock): step-duration telemetry returned in StepStats
        let t0 = std::time::Instant::now();
        let m = self.rt.manifest();
        let (b, t) = (m.config.batch as i64, m.config.seq_len as i64);
        anyhow::ensure!(ids.len() as i64 == b * t, "batch shape mismatch");
        // A failed overlapped pipeline leaves the store incomplete; refuse
        // to continue on wrong-shaped state rather than training silently.
        anyhow::ensure!(
            self.params.n_blocks() == m.config.n_layers,
            "engine unusable: a previous pipeline error left {} of {} blocks in the store",
            self.params.n_blocks(),
            m.config.n_layers
        );

        let sizes = self.params.module_sizes();
        let states = module_states(self.cfg.seed, self.step, &sizes);
        let _rng = self.manager.begin_iter(self.step);
        for &st in &states {
            self.manager.record_module_state(st);
        }
        // lrs: previous iteration's states + projected gradient (Alg. 2 l.4-9).
        // A NaN pending gradient is the DP sim-shard sentinel: the caller
        // ran `dp_dual_losses` but never delivered the all-reduced scalar.
        if let Some(p) = &self.pending {
            anyhow::ensure!(
                !p.g.is_nan(),
                "pending update has no gradient: a DP sim-shard step must call \
                 set_allreduced_g before the next step"
            );
        }
        let (g_prev, prev_states, had_pending) = match self.pending.take() {
            Some(p) => {
                let _ = self.manager.pop_last_states();
                (p.g, p.states, true)
            }
            None => (0.0, states.clone(), false), // g=0 → update is an exact no-op
        };

        let (lr, eps, gl) = self.scalars(g_prev);
        // CPU update site: the deferred block update runs on the host pool
        // (fused, wire-domain) right before each upload, so the device
        // executable gets g = 0 — an exact no-op — for blocks.  Embedding
        // and head are device-resident and keep the device-site update.
        let cpu_site = self.opts.update_site == UpdateSite::Cpu;
        let host_update = HostUpdate { apply: cpu_site && had_pending, lr: self.cfg.lr, g: g_prev };
        let gl_blocks = if cpu_site { lit_scalar(0.0) } else { gl.clone() };
        let ids_lit = lit_i32(ids, &[b, t])?;

        // --- embedding (device-resident) ----------------------------------
        let n_emb = self.params.embed.len();
        let outs = self.rt.run(
            "embed_step",
            &[
                lit_f32(&self.params.embed, &[n_emb as i64])?,
                lit_key(key_of(prev_states[0]))?,
                gl.clone(),
                lr.clone(),
                lit_key(key_of(states[0]))?,
                eps.clone(),
                ids_lit.clone(),
            ],
        )?;
        let mut outs = outs.into_iter();
        self.params.embed = lit_to_f32(&outs.next().unwrap())?;
        let mut hp = outs.next().unwrap();
        let mut hm = outs.next().unwrap();

        // --- offloaded transformer blocks ---------------------------------
        let n_blocks = self.params.n_blocks();
        let mut timeline = Timeline::new();
        // zo2-lint: allow(no-wall-clock): timeline event timestamps (trace export only)
        let wall0 = std::time::Instant::now();

        match self.opts.run_mode {
            RunMode::Sequential => {
                for i in 0..n_blocks {
                    let spilled = self.is_spilled(i);
                    // Disk read (three-tier): stage the spilled bucket into
                    // the DRAM window.  R(Wᵢ) → U(Wᵢ).
                    let tr = wall0.elapsed().as_secs_f64();
                    let mut bucket = self.stage_block(i)?;
                    if spilled {
                        timeline.push(TraceEvent {
                            stream: "compute",
                            cat: "disk_read",
                            label: format!("R b{i}"),
                            start: tr,
                            end: wall0.elapsed().as_secs_f64(),
                        });
                    }
                    // CPU update site: apply the deferred update as one
                    // fused wire-domain pass while the bucket is staged.
                    if host_update.apply {
                        bucket.fused_sgd_update(
                            prev_states[1 + i],
                            host_update.lr,
                            host_update.g,
                            &self.hostpool,
                        );
                    }
                    let n = bucket.numel();
                    // Upload: decode host bucket into a device slot.
                    let tu = wall0.elapsed().as_secs_f64();
                    if !self.opts.reusable_mem {
                        self.device.alloc((n * 4) as u64)?;
                    }
                    let mut slot = vec![0.0f32; n];
                    bucket.decode_into_pooled(&mut slot, &self.hostpool);
                    let wire = bucket.wire_bytes() as u64;
                    self.transfers.lock().unwrap().record_h2d(wire, &self.transfer_model);
                    timeline.push(TraceEvent {
                        stream: "compute",
                        cat: "upload",
                        label: format!("U b{i}"),
                        start: tu,
                        end: wall0.elapsed().as_secs_f64(),
                    });

                    // Compute: fused deferred-update + dual forward.
                    let tc = wall0.elapsed().as_secs_f64();
                    let outs = self.rt.run(
                        "block_step",
                        &[
                            lit_f32(&slot, &[n as i64])?,
                            lit_key(key_of(prev_states[1 + i]))?,
                            gl_blocks.clone(),
                            lr.clone(),
                            lit_key(key_of(states[1 + i]))?,
                            eps.clone(),
                            hp,
                            hm,
                        ],
                    )?;
                    let mut it = outs.into_iter();
                    let updated = lit_to_f32(&it.next().unwrap())?;
                    hp = it.next().unwrap();
                    hm = it.next().unwrap();
                    timeline.push(TraceEvent {
                        stream: "compute",
                        cat: "compute",
                        label: format!("C b{i}"),
                        start: tc,
                        end: wall0.elapsed().as_secs_f64(),
                    });

                    // Offload: encode updated bucket back to the host tier.
                    let to = wall0.elapsed().as_secs_f64();
                    bucket.encode_from_pooled(&updated, &self.hostpool);
                    self.transfers.lock().unwrap().record_d2h(wire, &self.transfer_model);
                    if !self.opts.reusable_mem {
                        self.device.free((n * 4) as u64);
                    }
                    timeline.push(TraceEvent {
                        stream: "compute",
                        cat: "offload",
                        label: format!("O b{i}"),
                        start: to,
                        end: wall0.elapsed().as_secs_f64(),
                    });

                    // Disk write-back (three-tier): O(Wᵢ) → W(Wᵢ).
                    let tw = wall0.elapsed().as_secs_f64();
                    self.unstage_block(i, bucket, true)?;
                    if spilled {
                        timeline.push(TraceEvent {
                            stream: "compute",
                            cat: "disk_write",
                            label: format!("W b{i}"),
                            start: tw,
                            end: wall0.elapsed().as_secs_f64(),
                        });
                    }
                }
            }
            RunMode::Overlapped => {
                let (h2, m2) = if self.disk.is_some() {
                    self.run_blocks_overlapped_disk(
                        &mut timeline, wall0, &prev_states, &states, hp, hm, &gl_blocks, &lr,
                        &eps, host_update,
                    )?
                } else {
                    self.run_blocks_overlapped(
                        &mut timeline, wall0, &prev_states, &states, hp, hm, &gl_blocks, &lr,
                        &eps, host_update,
                    )?
                };
                hp = h2;
                hm = m2;
            }
        }

        // --- LM head (device-resident) ------------------------------------
        let n_head = self.params.head.len();
        let outs = self.rt.run(
            "head_step",
            &[
                lit_f32(&self.params.head, &[n_head as i64])?,
                lit_key(key_of(prev_states[1 + n_blocks]))?,
                gl,
                lr,
                lit_key(key_of(states[1 + n_blocks]))?,
                eps,
                hp,
                hm,
                ids_lit,
            ],
        )?;
        let mut it = outs.into_iter();
        self.params.head = lit_to_f32(&it.next().unwrap())?;
        let loss_plus = lit_to_scalar(&it.next().unwrap())?;
        let loss_minus = lit_to_scalar(&it.next().unwrap())?;
        let g = (loss_plus - loss_minus) / (2.0 * self.cfg.eps);

        if self.opts.efficient_update {
            // §5.4: defer to the next step's upload cycle.
            self.pending = Some(Pending { g, states });
        } else {
            // Ablation (Fig. 5a): second upload→update→offload round now.
            self.apply_update_round(g, &states)?;
        }

        self.last_timeline = timeline;
        self.step += 1;
        if crate::telemetry::metrics::enabled() {
            self.record_step_metrics(t0.elapsed().as_secs_f64());
        }
        Ok(StepStats { step: self.step - 1, loss_plus, loss_minus, g, wall_s: t0.elapsed().as_secs_f64() })
    }

    /// Step-shape gauges/histograms for the process-wide metrics sink.
    /// Only reached when the sink is enabled (`--metrics-out`): the
    /// config labels make one run's series self-describing.
    fn record_step_metrics(&self, wall_s: f64) {
        use crate::telemetry::metrics;
        let tier = match self.opts.tiering {
            Tiering::TwoTier => "two",
            Tiering::ThreeTier => "three",
        };
        let site = match self.opts.update_site {
            UpdateSite::Device => "device",
            UpdateSite::Cpu => "cpu",
        };
        let labels = [("codec", self.opts.wire.name()), ("tier", tier), ("update_site", site)];
        metrics::observe("zo2_step_wall_s", &labels, wall_s);
        metrics::gauge_set("device_peak_bytes", &[("device", "0")], self.device.peak() as f64);
        metrics::gauge_set(
            "host_scratch_peak_bytes",
            &[],
            crate::telemetry::HOST_SCRATCH.peak() as f64,
        );
        if let Some(t) = &self.disk {
            metrics::gauge_set("dram_window_peak_slots", &[], t.window.peak_slots() as f64);
            metrics::gauge_set("dram_window_peak_bytes", &[], t.window.peak_bytes() as f64);
            metrics::gauge_set("disk_used_bytes", &[], t.pool.used() as f64);
        }
    }

    /// Overlapped block pipeline (Algorithm 3 with real threads).
    #[allow(clippy::too_many_arguments)]
    fn run_blocks_overlapped(
        &mut self,
        timeline: &mut Timeline,
        wall0: std::time::Instant,
        prev_states: &[RngState],
        states: &[RngState],
        hp0: xla::Literal,
        hm0: xla::Literal,
        gl: &xla::Literal,
        lr: &xla::Literal,
        eps: &xla::Literal,
        host_update: HostUpdate,
    ) -> Result<(xla::Literal, xla::Literal)> {
        let n_blocks = self.params.n_blocks();
        let slots = self.opts.slots.max(1);
        let numel = self.rt.manifest().block.size;
        let reusable = self.opts.reusable_mem;
        if !reusable {
            // Per-upload allocations still respect capacity (worst case all
            // in-flight slots live at once).
            self.device.alloc((numel * slots * 4) as u64)?;
            self.device.free((numel * slots * 4) as u64);
        }

        // Move the host buckets into the pipeline; they come back encoded.
        let buckets: Vec<HostBucket> = std::mem::take(&mut self.params.blocks);
        let wire_bytes: Vec<u64> = buckets.iter().map(|b| b.wire_bytes() as u64).collect();
        let wire_bytes = &wire_bytes; // shared by both stream threads

        struct Uploaded {
            idx: usize,
            bucket: HostBucket,
            slot: Vec<f32>,
            t_end: f64,
        }
        struct ToOffload {
            idx: usize,
            bucket: HostBucket,
            updated: Vec<f32>,
            t_ready: f64,
        }

        let (tx_up, rx_up) = mpsc::sync_channel::<Uploaded>(slots);
        let (tx_off, rx_off) = mpsc::sync_channel::<ToOffload>(slots);

        let trans = &self.transfers;
        let tmodel = self.transfer_model;
        let hostpool = &self.hostpool;
        let prev_states = prev_states.to_vec();
        let prev_states_up = prev_states.clone();
        let cur_states = states.to_vec();
        let events: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());

        let (hp, hm, done_buckets) = std::thread::scope(|s| -> Result<_> {
            // --- upload stream: prefetch ahead, bounded by the slot ring ---
            s.spawn({
                let events = &events;
                move || {
                    for (idx, mut bucket) in buckets.into_iter().enumerate() {
                        let t_start = wall0.elapsed().as_secs_f64();
                        // CPU update site: the deferred update runs here as
                        // one fused wire-domain pass, off the compute path.
                        if host_update.apply {
                            bucket.fused_sgd_update(
                                prev_states_up[1 + idx],
                                host_update.lr,
                                host_update.g,
                                hostpool,
                            );
                        }
                        let n = bucket.numel();
                        let mut slot = vec![0.0f32; n];
                        bucket.decode_into_pooled(&mut slot, hostpool);
                        trans.lock().unwrap().record_h2d(wire_bytes[idx], &tmodel);
                        let t_end = wall0.elapsed().as_secs_f64();
                        events.lock().unwrap().push(TraceEvent {
                            stream: "upload",
                            cat: "upload",
                            label: format!("U b{idx}"),
                            start: t_start,
                            end: t_end,
                        });
                        if tx_up.send(Uploaded { idx, bucket, slot, t_end }).is_err() {
                            return; // main thread errored out
                        }
                    }
                }
            });

            // --- offload stream: encode updated buckets back ---------------
            let off_handle = s.spawn({
                let events = &events;
                move || -> Vec<(usize, HostBucket)> {
                    let mut done = Vec::new();
                    while let Ok(mut job) = rx_off.recv() {
                        let t_start = wall0.elapsed().as_secs_f64().max(job.t_ready);
                        job.bucket.encode_from_pooled(&job.updated, hostpool);
                        trans.lock().unwrap().record_d2h(wire_bytes[job.idx], &tmodel);
                        events.lock().unwrap().push(TraceEvent {
                            stream: "offload",
                            cat: "offload",
                            label: format!("O b{}", job.idx),
                            start: t_start,
                            end: wall0.elapsed().as_secs_f64(),
                        });
                        done.push((job.idx, job.bucket));
                    }
                    done
                }
            });

            // --- compute stream (this thread: PJRT is not Send) ------------
            let mut hp = hp0;
            let mut hm = hm0;
            for _ in 0..n_blocks {
                let up = rx_up.recv().map_err(|_| anyhow::anyhow!("upload stream died"))?;
                let n = up.slot.len();
                let tc = wall0.elapsed().as_secs_f64();
                let outs = self.rt.run(
                    "block_step",
                    &[
                        lit_f32(&up.slot, &[n as i64])?,
                        lit_key(key_of(prev_states[1 + up.idx]))?,
                        gl.clone(),
                        lr.clone(),
                        lit_key(key_of(cur_states[1 + up.idx]))?,
                        eps.clone(),
                        hp,
                        hm,
                    ],
                )?;
                let mut it = outs.into_iter();
                let updated = lit_to_f32(&it.next().unwrap())?;
                hp = it.next().unwrap();
                hm = it.next().unwrap();
                let t_end = wall0.elapsed().as_secs_f64();
                events.lock().unwrap().push(TraceEvent {
                    stream: "compute",
                    cat: "compute",
                    label: format!("C b{}", up.idx),
                    start: tc.max(up.t_end),
                    end: t_end,
                });
                tx_off
                    .send(ToOffload { idx: up.idx, bucket: up.bucket, updated, t_ready: t_end })
                    .map_err(|_| anyhow::anyhow!("offload stream died"))?;
            }
            drop(tx_off);
            let done = off_handle.join().map_err(|_| anyhow::anyhow!("offload thread panicked"))?;
            Ok((hp, hm, done))
        })?;

        // Reassemble the host tier from the pipeline's outputs.
        let mut slots_back: Vec<Option<HostBucket>> = (0..n_blocks).map(|_| None).collect();
        for (idx, bucket) in done_buckets {
            slots_back[idx] = Some(bucket);
        }
        self.params.blocks =
            slots_back.into_iter().map(|o| o.expect("block lost in pipeline")).collect();
        for e in events.into_inner().unwrap() {
            timeline.push(e);
        }
        Ok((hp, hm))
    }

    /// Overlapped block pipeline with the disk tier: five streams realised
    /// by four worker threads + the main compute thread, mirroring the
    /// analytic DAG's R(Wᵢ)→U(Wᵢ)→C(Wᵢ)→O(Wᵢ)→W(Wᵢ) chains.  The disk-read
    /// thread prefetches spilled buckets ahead of compute, bounded by a
    /// token ring of `dram_slots` staging slots that disk-write returns as
    /// it retires buckets to NVMe — the threaded form of the DRAM-window
    /// resource rule.  Resident blocks flow through untouched, so with an
    /// empty spill set this degenerates to the two-tier pipeline.
    #[allow(clippy::too_many_arguments)]
    fn run_blocks_overlapped_disk(
        &mut self,
        timeline: &mut Timeline,
        wall0: std::time::Instant,
        prev_states: &[RngState],
        states: &[RngState],
        hp0: xla::Literal,
        hm0: xla::Literal,
        gl: &xla::Literal,
        lr: &xla::Literal,
        eps: &xla::Literal,
        host_update: HostUpdate,
    ) -> Result<(xla::Literal, xla::Literal)> {
        let n_blocks = self.params.blocks.len();
        let slots = self.opts.slots.max(1);
        let numel = self.rt.manifest().block.size;
        if !self.opts.reusable_mem {
            // Per-upload allocations still respect capacity (worst case all
            // in-flight slots live at once).
            self.device.alloc((numel * slots * 4) as u64)?;
            self.device.free((numel * slots * 4) as u64);
        }

        let tier = self.disk.as_ref().expect("disk pipeline requires a disk tier");
        let dram_slots = tier.window.slots();
        // Move the host buckets into the pipeline (placeholders for spilled
        // blocks — their bytes are read off the pool file by the R stream).
        let buckets: Vec<HostBucket> = std::mem::take(&mut self.params.blocks);
        let wire_bytes: Vec<u64> = (0..n_blocks)
            .map(|i| match &tier.entries[i] {
                Some(e) => e.wire_len() as u64,
                None => buckets[i].wire_bytes() as u64,
            })
            .collect();
        let wire_bytes = &wire_bytes; // shared by the stream threads

        struct Uploaded {
            idx: usize,
            bucket: HostBucket,
            slot: Vec<f32>,
            t_end: f64,
        }
        struct ToOffload {
            idx: usize,
            bucket: HostBucket,
            updated: Vec<f32>,
            t_ready: f64,
        }

        let (tx_feed, rx_feed) = mpsc::sync_channel::<(usize, HostBucket)>(dram_slots);
        let (tx_up, rx_up) = mpsc::sync_channel::<Uploaded>(slots);
        let (tx_off, rx_off) = mpsc::sync_channel::<ToOffload>(slots);
        let (tx_wr, rx_wr) = mpsc::sync_channel::<(usize, HostBucket)>(slots);
        // Staging-window token ring: R takes a token per spilled read, W
        // returns it after the write-back retires the DRAM copy.
        let (tx_tok, rx_tok) = mpsc::channel::<()>();
        for _ in 0..dram_slots {
            let _ = tx_tok.send(());
        }

        let trans = &self.transfers;
        let tmodel = self.transfer_model;
        let hostpool = &self.hostpool;
        let prev_states = prev_states.to_vec();
        let prev_states_up = prev_states.clone();
        let cur_states = states.to_vec();
        let events: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());
        // First NVMe failure in either disk thread; surfaced as the step's
        // error instead of a generic "stream died" / a reassembly panic.
        let pipe_err: Mutex<Option<String>> = Mutex::new(None);

        let (hp, hm, done_buckets) = std::thread::scope(|s| -> Result<_> {
            // --- disk-read stream: prefetch spilled buckets off NVMe ------
            s.spawn({
                let events = &events;
                let pipe_err = &pipe_err;
                move || {
                    for (idx, bucket) in buckets.into_iter().enumerate() {
                        let staged = match &tier.entries[idx] {
                            Some(entry) => {
                                // Time blocked on a free DRAM-window slot:
                                // the prefetcher's stall when write-backs
                                // can't retire staged buckets fast enough.
                                // zo2-lint: allow(no-wall-clock): stall-time metric, gated on metrics::enabled()
                                let t_wait = crate::telemetry::metrics::enabled()
                                    .then(std::time::Instant::now);
                                if rx_tok.recv().is_err() {
                                    return; // write stream died
                                }
                                if let Some(t) = t_wait {
                                    crate::telemetry::metrics::observe(
                                        "dram_window_stall_s",
                                        &[],
                                        t.elapsed().as_secs_f64(),
                                    );
                                }
                                tier.window
                                    .acquire(entry.wire_len() as u64)
                                    .expect("DRAM staging window overflow");
                                let t_start = wall0.elapsed().as_secs_f64();
                                let bytes = match tier.pool.read(entry) {
                                    Ok(b) => b,
                                    Err(e) => {
                                        *pipe_err.lock().unwrap() = Some(format!(
                                            "disk read of block {idx} failed: {e}"
                                        ));
                                        return;
                                    }
                                };
                                events.lock().unwrap().push(TraceEvent {
                                    stream: "disk_read",
                                    cat: "disk_read",
                                    label: format!("R b{idx}"),
                                    start: t_start,
                                    end: wall0.elapsed().as_secs_f64(),
                                });
                                HostBucket::from_wire(entry.codec(), entry.numel(), bytes)
                            }
                            None => bucket,
                        };
                        if tx_feed.send((idx, staged)).is_err() {
                            return; // downstream errored out
                        }
                    }
                }
            });

            // --- upload stream: decode into device slots ------------------
            s.spawn({
                let events = &events;
                move || {
                    while let Ok((idx, mut bucket)) = rx_feed.recv() {
                        let t_start = wall0.elapsed().as_secs_f64();
                        // CPU update site: fused wire-domain deferred update
                        // (uniform for resident and freshly-read spilled
                        // buckets; the updated bytes flow on to write-back).
                        if host_update.apply {
                            bucket.fused_sgd_update(
                                prev_states_up[1 + idx],
                                host_update.lr,
                                host_update.g,
                                hostpool,
                            );
                        }
                        let n = bucket.numel();
                        let mut slot = vec![0.0f32; n];
                        bucket.decode_into_pooled(&mut slot, hostpool);
                        trans.lock().unwrap().record_h2d(wire_bytes[idx], &tmodel);
                        let t_end = wall0.elapsed().as_secs_f64();
                        events.lock().unwrap().push(TraceEvent {
                            stream: "upload",
                            cat: "upload",
                            label: format!("U b{idx}"),
                            start: t_start,
                            end: t_end,
                        });
                        if tx_up.send(Uploaded { idx, bucket, slot, t_end }).is_err() {
                            return; // main thread errored out
                        }
                    }
                }
            });

            // --- offload stream: encode updated buckets back --------------
            s.spawn({
                let events = &events;
                move || {
                    while let Ok(mut job) = rx_off.recv() {
                        let t_start = wall0.elapsed().as_secs_f64().max(job.t_ready);
                        job.bucket.encode_from_pooled(&job.updated, hostpool);
                        trans.lock().unwrap().record_d2h(wire_bytes[job.idx], &tmodel);
                        events.lock().unwrap().push(TraceEvent {
                            stream: "offload",
                            cat: "offload",
                            label: format!("O b{}", job.idx),
                            start: t_start,
                            end: wall0.elapsed().as_secs_f64(),
                        });
                        if tx_wr.send((job.idx, job.bucket)).is_err() {
                            return;
                        }
                    }
                }
            });

            // --- disk-write stream: retire spilled buckets to NVMe --------
            let wr_handle = s.spawn({
                let events = &events;
                let pipe_err = &pipe_err;
                move || -> Vec<(usize, HostBucket)> {
                    let mut done = Vec::new();
                    while let Ok((idx, bucket)) = rx_wr.recv() {
                        match &tier.entries[idx] {
                            Some(entry) => {
                                let t_start = wall0.elapsed().as_secs_f64();
                                if let Err(e) = tier.pool.write(entry, bucket.wire()) {
                                    // Keep the pipeline complete (placeholder
                                    // + token) and surface the error after
                                    // the join instead of panicking on a
                                    // missing block at reassembly.
                                    let mut slot = pipe_err.lock().unwrap();
                                    if slot.is_none() {
                                        *slot = Some(format!(
                                            "disk write-back of block {idx} failed: {e}"
                                        ));
                                    }
                                }
                                events.lock().unwrap().push(TraceEvent {
                                    stream: "disk_write",
                                    cat: "disk_write",
                                    label: format!("W b{idx}"),
                                    start: t_start,
                                    end: wall0.elapsed().as_secs_f64(),
                                });
                                tier.window.release(entry.wire_len() as u64);
                                let _ = tx_tok.send(());
                                done.push((
                                    idx,
                                    HostBucket::placeholder(entry.codec(), entry.numel()),
                                ));
                            }
                            None => done.push((idx, bucket)),
                        }
                    }
                    done
                }
            });

            // --- compute stream (this thread: PJRT is not Send) -----------
            let mut hp = hp0;
            let mut hm = hm0;
            for _ in 0..n_blocks {
                let up = match rx_up.recv() {
                    Ok(up) => up,
                    Err(_) => {
                        let msg = pipe_err
                            .lock()
                            .unwrap()
                            .take()
                            .unwrap_or_else(|| "upload stream died".to_string());
                        return Err(anyhow::anyhow!("{msg}"));
                    }
                };
                let n = up.slot.len();
                let tc = wall0.elapsed().as_secs_f64();
                let outs = self.rt.run(
                    "block_step",
                    &[
                        lit_f32(&up.slot, &[n as i64])?,
                        lit_key(key_of(prev_states[1 + up.idx]))?,
                        gl.clone(),
                        lr.clone(),
                        lit_key(key_of(cur_states[1 + up.idx]))?,
                        eps.clone(),
                        hp,
                        hm,
                    ],
                )?;
                let mut it = outs.into_iter();
                let updated = lit_to_f32(&it.next().unwrap())?;
                hp = it.next().unwrap();
                hm = it.next().unwrap();
                let t_end = wall0.elapsed().as_secs_f64();
                events.lock().unwrap().push(TraceEvent {
                    stream: "compute",
                    cat: "compute",
                    label: format!("C b{}", up.idx),
                    start: tc.max(up.t_end),
                    end: t_end,
                });
                tx_off
                    .send(ToOffload { idx: up.idx, bucket: up.bucket, updated, t_ready: t_end })
                    .map_err(|_| anyhow::anyhow!("offload stream died"))?;
            }
            drop(tx_off);
            let done =
                wr_handle.join().map_err(|_| anyhow::anyhow!("disk-write thread panicked"))?;
            if let Some(msg) = pipe_err.lock().unwrap().take() {
                return Err(anyhow::anyhow!("{msg}"));
            }
            Ok((hp, hm, done))
        })?;

        // Reassemble the host tier (spilled slots come back as placeholders;
        // their bytes now live on the pool file).
        let mut slots_back: Vec<Option<HostBucket>> = (0..n_blocks).map(|_| None).collect();
        for (idx, bucket) in done_buckets {
            slots_back[idx] = Some(bucket);
        }
        self.params.blocks =
            slots_back.into_iter().map(|o| o.expect("block lost in pipeline")).collect();
        for e in events.into_inner().unwrap() {
            timeline.push(e);
        }
        Ok((hp, hm))
    }

    /// Non-efficient-update ablation: standalone update round (Fig. 5a) —
    /// on the device site every block crosses the interconnect a second
    /// time; on the CPU site the block updates run in place on the host
    /// pool (fused wire-domain passes, zero extra transfers).
    fn apply_update_round(&mut self, g: f32, states: &[RngState]) -> Result<()> {
        let lr = lit_scalar(self.cfg.lr);
        let gl = lit_scalar(g);

        let n_emb = self.params.embed.len();
        let out = self.rt.run(
            "update_embed",
            &[
                lit_f32(&self.params.embed, &[n_emb as i64])?,
                lit_key(key_of(states[0]))?,
                lr.clone(),
                gl.clone(),
            ],
        )?;
        self.params.embed = lit_to_f32(&out[0])?;

        if self.opts.update_site == UpdateSite::Cpu {
            for i in 0..self.params.n_blocks() {
                let mut bucket = self.stage_block(i)?;
                bucket.fused_sgd_update(states[1 + i], self.cfg.lr, g, &self.hostpool);
                self.unstage_block(i, bucket, true)?;
            }
        } else {
            for i in 0..self.params.n_blocks() {
                let mut bucket = self.stage_block(i)?;
                let n = bucket.numel();
                let decoded = bucket.to_f32_pooled(&self.hostpool);
                let wire = bucket.wire_bytes() as u64;
                self.transfers.lock().unwrap().record_h2d(wire, &self.transfer_model);
                let out = self.rt.run(
                    "update_block",
                    &[
                        lit_f32(&decoded, &[n as i64])?,
                        lit_key(key_of(states[1 + i]))?,
                        lr.clone(),
                        gl.clone(),
                    ],
                )?;
                let updated = lit_to_f32(&out[0])?;
                bucket.encode_from_pooled(&updated, &self.hostpool);
                self.transfers.lock().unwrap().record_d2h(wire, &self.transfer_model);
                self.unstage_block(i, bucket, true)?;
            }
        }

        let n_head = self.params.head.len();
        let out = self.rt.run(
            "update_head",
            &[
                lit_f32(&self.params.head, &[n_head as i64])?,
                lit_key(key_of(states[1 + self.params.n_blocks()]))?,
                lr,
                gl,
            ],
        )?;
        self.params.head = lit_to_f32(&out[0])?;
        Ok(())
    }

    /// Apply any pending deferred update (the paper's final
    /// `model.opt.zo_update(model)` — Fig. 6b).  Idempotent.
    pub fn flush_updates(&mut self) -> Result<()> {
        if let Some(p) = &self.pending {
            anyhow::ensure!(
                !p.g.is_nan(),
                "pending update has no gradient: a DP sim-shard step must call \
                 set_allreduced_g before flushing"
            );
        }
        if let Some(p) = self.pending.take() {
            self.apply_update_round_no_transfer_double_count(p.g, &p.states)?;
        }
        Ok(())
    }

    /// One seed-synchronous DP worker step over this worker's microbatch
    /// shards (≥ 1): applies the previous step's deferred update — whose
    /// gradient must already be the all-reduced ḡ, delivered via
    /// [`Self::set_allreduced_g`] — fused into the first shard's dual
    /// forward, then replays the *same* ZO step (same perturbation stream,
    /// exact no-op update) on each further shard.  Returns the per-shard
    /// `(ℓ₊, ℓ₋)` pairs in shard order; the step's own deferred update is
    /// left parked with a NaN sentinel until the all-reduce lands.
    ///
    /// Because every shard's forward sees identical post-update parameters
    /// and an identical perturbation direction, the per-shard losses do not
    /// depend on *which* worker evaluates a shard — the invariant
    /// [`crate::zo::DpSimShard`] builds on.
    pub fn dp_dual_losses(&mut self, shards: &[&[i32]]) -> Result<Vec<(f32, f32)>> {
        anyhow::ensure!(!shards.is_empty(), "a DP worker needs at least one shard");
        anyhow::ensure!(
            self.opts.efficient_update,
            "DP sim-shard requires the deferred update (efficient_update = true): the \
             non-deferred ablation applies each step's local g before the all-reduce"
        );
        let step0 = self.step;
        let mut out = Vec::with_capacity(shards.len());
        for (k, ids) in shards.iter().enumerate() {
            if k > 0 {
                // Replay the same ZO step on the next shard: the deferred
                // update was already applied by the first shard's pass, so
                // this pass must see no pending work (g = 0 is an exact
                // no-op) and the same step index (same z).
                self.step = step0;
                self.pending = None;
            }
            let st = self.train_step(ids)?;
            if k > 0 {
                // Drop the duplicate rsb record the replayed begin_iter
                // pushed (bookkeeping only; states replay via `pending`).
                let _ = self.manager.discard_current();
            }
            out.push((st.loss_plus, st.loss_minus));
        }
        if let Some(p) = self.pending.as_mut() {
            p.g = f32::NAN; // parked until the all-reduce delivers ḡ
        }
        Ok(out)
    }

    /// Evaluate additional shards for the step currently parked by
    /// [`Self::dp_dual_losses`] — the DP reassignment path when another
    /// worker dies mid-step.  Each shard replays the same ZO step (same
    /// perturbation stream, exact no-op update), so the returned pairs are
    /// bit-identical to what the dead worker would have produced, and the
    /// parked deferred update stays parked (g remains the NaN sentinel).
    pub fn dp_extra_losses(&mut self, shards: &[&[i32]]) -> Result<Vec<(f32, f32)>> {
        anyhow::ensure!(!shards.is_empty(), "reassignment needs at least one shard");
        anyhow::ensure!(
            self.pending.as_ref().is_some_and(|p| p.g.is_nan()),
            "dp_extra_losses requires a step parked by dp_dual_losses"
        );
        let step0 = self.step - 1;
        let mut out = Vec::with_capacity(shards.len());
        for ids in shards {
            // Same replay recipe as the k > 0 arm of dp_dual_losses.
            self.step = step0;
            self.pending = None;
            let st = self.train_step(ids)?;
            let _ = self.manager.discard_current();
            out.push((st.loss_plus, st.loss_minus));
        }
        if let Some(p) = self.pending.as_mut() {
            p.g = f32::NAN; // still parked until the all-reduce lands
        }
        Ok(out)
    }

    /// Deliver the all-reduced projected gradient for the step parked by
    /// [`Self::dp_dual_losses`].
    pub fn set_allreduced_g(&mut self, g: f32) {
        if let Some(p) = self.pending.as_mut() {
            p.g = g;
        }
    }

    /// Optimizer epsilon (the DP driver recomputes per-shard projected
    /// gradients from the shard losses with the same ε).
    pub fn zo_eps(&self) -> f32 {
        self.cfg.eps
    }

    /// Flush helper: same math as `apply_update_round`, but its transfers are
    /// the *regular* once-per-step cycle (not the doubled ablation traffic),
    /// so only one h2d+d2h per block is recorded.
    fn apply_update_round_no_transfer_double_count(
        &mut self,
        g: f32,
        states: &[RngState],
    ) -> Result<()> {
        self.apply_update_round(g, states)
    }

    /// Unperturbed forward on *fully-updated* parameters (flushes pending).
    pub fn eval(&mut self, ids: &[i32]) -> Result<(f32, Vec<f32>)> {
        anyhow::ensure!(
            self.params.n_blocks() == self.rt.manifest().config.n_layers,
            "engine unusable: a previous pipeline error left {} of {} blocks in the store",
            self.params.n_blocks(),
            self.rt.manifest().config.n_layers
        );
        self.flush_updates()?;
        let m = self.rt.manifest();
        let (b, t) = (m.config.batch as i64, m.config.seq_len as i64);
        let ids_lit = lit_i32(ids, &[b, t])?;
        let out = self.rt.run(
            "embed_fwd",
            &[lit_f32(&self.params.embed, &[self.params.embed.len() as i64])?, ids_lit.clone()],
        )?;
        let mut h = out.into_iter().next().unwrap();
        for i in 0..self.params.n_blocks() {
            let bucket = self.stage_block(i)?;
            let decoded = bucket.to_f32_pooled(&self.hostpool);
            let out =
                self.rt.run("block_fwd", &[lit_f32(&decoded, &[bucket.numel() as i64])?, h])?;
            h = out.into_iter().next().unwrap();
            // Eval never mutates parameters: return the bucket clean.
            self.unstage_block(i, bucket, false)?;
        }
        let out = self.rt.run(
            "head_eval",
            &[lit_f32(&self.params.head, &[self.params.head.len() as i64])?, h, ids_lit],
        )?;
        let mut it = out.into_iter();
        let loss = lit_to_scalar(&it.next().unwrap())?;
        let logits = lit_to_f32(&it.next().unwrap())?;
        Ok((loss, logits))
    }
}

