//! The ZO2 engine (paper Algorithms 2 + 3).
//!
//! Transformer blocks live in host memory (the "CPU DDR" tier), optionally
//! compressed (AMP mode §5.5); the embedding and LM head stay device-
//! resident (§5.2).  Each training step streams every block through the
//! reusable device buffer (§5.3): upload (decode) → fused
//! deferred-update + dual-forward (§5.4) → offload (encode the *updated*
//! bucket back).  The projected gradient of step `j` is applied to each
//! block at the start of step `j+1`, with the perturbation direction
//! replayed from the RNG states recorded at step `j` (§5.1).
//!
//! Two run modes share identical numerics:
//! * [`RunMode::Sequential`] — the naive Fig. 4a schedule (ablation
//!   baseline): upload, compute, offload strictly in order.
//! * [`RunMode::Overlapped`] — the Fig. 4b dynamic schedule: an upload
//!   thread prefetches block `i+1` and an offload thread compresses block
//!   `i−1` while the main thread computes block `i`; backpressure comes
//!   from the slot ring (bounded channels), realising Algorithm 3's
//!   dependency rules with real threads.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::memory::{DevicePool, HostBucket, TransferEngine, TransferModel};
use crate::precision::Codec;
use crate::rng::{RngState, RngStateManager};
use crate::runtime::{lit_f32, lit_i32, lit_key, lit_scalar, lit_to_f32, lit_to_scalar, Runtime};
use crate::telemetry::{Timeline, TraceEvent};
use crate::zo::{key_of, module_states, ParamStore, StepStats, ZoConfig};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunMode {
    Sequential,
    Overlapped,
}

/// Engine options (the Table 4 / Table 5 switches).
#[derive(Debug, Clone, Copy)]
pub struct Zo2Options {
    /// Wire/storage codec for offloaded blocks (AMP compression, §5.5).
    pub wire: Codec,
    pub run_mode: RunMode,
    /// §5.3 reusable buffer; `false` allocates per upload (ablation).
    pub reusable_mem: bool,
    /// §5.4 fused deferred update; `false` runs a second
    /// upload→update→offload round per block per step (ablation).
    pub efficient_update: bool,
    /// In-flight block slots (compute + prefetch + offload).
    pub slots: usize,
    /// Simulated device capacity (bytes); checked by the device pool.
    pub device_capacity: u64,
}

impl Default for Zo2Options {
    fn default() -> Self {
        Self {
            wire: Codec::F32,
            run_mode: RunMode::Overlapped,
            reusable_mem: true,
            efficient_update: true,
            slots: 3,
            device_capacity: u64::MAX,
        }
    }
}

/// Deferred-update state carried between steps (paper Fig. 5b).
struct Pending {
    g: f32,
    states: Vec<RngState>,
}

pub struct Zo2Engine {
    rt: Runtime,
    pub params: ParamStore,
    cfg: ZoConfig,
    pub opts: Zo2Options,
    manager: RngStateManager,
    step: u64,
    pending: Option<Pending>,
    pub device: Arc<DevicePool>,
    pub transfers: Mutex<TransferEngine>,
    pub transfer_model: TransferModel,
    /// Timeline of the most recent step (real Fig. 4 data).
    pub last_timeline: Timeline,
}

impl Zo2Engine {
    pub fn new(rt: Runtime, cfg: ZoConfig, opts: Zo2Options) -> Result<Self> {
        let params = ParamStore::init(rt.manifest(), cfg.seed, opts.wire);
        let device = DevicePool::new(opts.device_capacity);
        // Device residency: embedding + head (fp32) + the reusable slots.
        device.alloc(((params.embed.len() + params.head.len()) * 4) as u64)?;
        if opts.reusable_mem {
            device.alloc((rt.manifest().block.size * opts.slots * 4) as u64)?;
        }
        Ok(Self {
            rt,
            params,
            cfg,
            opts,
            manager: RngStateManager::new(cfg.seed),
            step: 0,
            pending: None,
            device,
            transfers: Mutex::new(TransferEngine::new()),
            transfer_model: TransferModel::pcie4(),
            last_timeline: Timeline::new(),
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    fn scalars(&self, g_prev: f32) -> (xla::Literal, xla::Literal, xla::Literal) {
        (lit_scalar(self.cfg.lr), lit_scalar(self.cfg.eps), lit_scalar(g_prev))
    }

    /// One Algorithm-2 iteration.
    pub fn train_step(&mut self, ids: &[i32]) -> Result<StepStats> {
        let t0 = std::time::Instant::now();
        let m = self.rt.manifest();
        let (b, t) = (m.config.batch as i64, m.config.seq_len as i64);
        anyhow::ensure!(ids.len() as i64 == b * t, "batch shape mismatch");

        let sizes = self.params.module_sizes();
        let states = module_states(self.cfg.seed, self.step, &sizes);
        let _rng = self.manager.begin_iter(self.step);
        for &st in &states {
            self.manager.record_module_state(st);
        }
        // lrs: previous iteration's states + projected gradient (Alg. 2 l.4-9).
        let (g_prev, prev_states) = match self.pending.take() {
            Some(p) => {
                let _ = self.manager.pop_last_states();
                (p.g, p.states)
            }
            None => (0.0, states.clone()), // g=0 → update is an exact no-op
        };

        let (lr, eps, gl) = self.scalars(g_prev);
        let ids_lit = lit_i32(ids, &[b, t])?;

        // --- embedding (device-resident) ----------------------------------
        let n_emb = self.params.embed.len();
        let outs = self.rt.run(
            "embed_step",
            &[
                lit_f32(&self.params.embed, &[n_emb as i64])?,
                lit_key(key_of(prev_states[0]))?,
                gl.clone(),
                lr.clone(),
                lit_key(key_of(states[0]))?,
                eps.clone(),
                ids_lit.clone(),
            ],
        )?;
        let mut outs = outs.into_iter();
        self.params.embed = lit_to_f32(&outs.next().unwrap())?;
        let mut hp = outs.next().unwrap();
        let mut hm = outs.next().unwrap();

        // --- offloaded transformer blocks ---------------------------------
        let n_blocks = self.params.n_blocks();
        let mut timeline = Timeline::new();
        let wall0 = std::time::Instant::now();

        match self.opts.run_mode {
            RunMode::Sequential => {
                for i in 0..n_blocks {
                    let n = self.params.blocks[i].numel();
                    // Upload: decode host bucket into a device slot.
                    let tu = wall0.elapsed().as_secs_f64();
                    if !self.opts.reusable_mem {
                        self.device.alloc((n * 4) as u64)?;
                    }
                    let mut slot = vec![0.0f32; n];
                    self.params.blocks[i].decode_into(&mut slot);
                    let wire = self.params.blocks[i].wire_bytes() as u64;
                    self.transfers.lock().unwrap().record_h2d(wire, &self.transfer_model);
                    timeline.push(TraceEvent {
                        stream: "compute",
                        label: format!("U b{i}"),
                        start: tu,
                        end: wall0.elapsed().as_secs_f64(),
                    });

                    // Compute: fused deferred-update + dual forward.
                    let tc = wall0.elapsed().as_secs_f64();
                    let outs = self.rt.run(
                        "block_step",
                        &[
                            lit_f32(&slot, &[n as i64])?,
                            lit_key(key_of(prev_states[1 + i]))?,
                            gl.clone(),
                            lr.clone(),
                            lit_key(key_of(states[1 + i]))?,
                            eps.clone(),
                            hp,
                            hm,
                        ],
                    )?;
                    let mut it = outs.into_iter();
                    let updated = lit_to_f32(&it.next().unwrap())?;
                    hp = it.next().unwrap();
                    hm = it.next().unwrap();
                    timeline.push(TraceEvent {
                        stream: "compute",
                        label: format!("C b{i}"),
                        start: tc,
                        end: wall0.elapsed().as_secs_f64(),
                    });

                    // Offload: encode updated bucket back to the host tier.
                    let to = wall0.elapsed().as_secs_f64();
                    self.params.blocks[i].encode_from(&updated);
                    self.transfers.lock().unwrap().record_d2h(wire, &self.transfer_model);
                    if !self.opts.reusable_mem {
                        self.device.free((n * 4) as u64);
                    }
                    timeline.push(TraceEvent {
                        stream: "compute",
                        label: format!("O b{i}"),
                        start: to,
                        end: wall0.elapsed().as_secs_f64(),
                    });
                }
            }
            RunMode::Overlapped => {
                let (h2, m2) = self.run_blocks_overlapped(
                    &mut timeline, wall0, &prev_states, &states, hp, hm, &gl, &lr, &eps,
                )?;
                hp = h2;
                hm = m2;
            }
        }

        // --- LM head (device-resident) ------------------------------------
        let n_head = self.params.head.len();
        let outs = self.rt.run(
            "head_step",
            &[
                lit_f32(&self.params.head, &[n_head as i64])?,
                lit_key(key_of(prev_states[1 + n_blocks]))?,
                gl,
                lr,
                lit_key(key_of(states[1 + n_blocks]))?,
                eps,
                hp,
                hm,
                ids_lit,
            ],
        )?;
        let mut it = outs.into_iter();
        self.params.head = lit_to_f32(&it.next().unwrap())?;
        let loss_plus = lit_to_scalar(&it.next().unwrap())?;
        let loss_minus = lit_to_scalar(&it.next().unwrap())?;
        let g = (loss_plus - loss_minus) / (2.0 * self.cfg.eps);

        if self.opts.efficient_update {
            // §5.4: defer to the next step's upload cycle.
            self.pending = Some(Pending { g, states });
        } else {
            // Ablation (Fig. 5a): second upload→update→offload round now.
            self.apply_update_round(g, &states)?;
        }

        self.last_timeline = timeline;
        self.step += 1;
        Ok(StepStats { step: self.step - 1, loss_plus, loss_minus, g, wall_s: t0.elapsed().as_secs_f64() })
    }

    /// Overlapped block pipeline (Algorithm 3 with real threads).
    #[allow(clippy::too_many_arguments)]
    fn run_blocks_overlapped(
        &mut self,
        timeline: &mut Timeline,
        wall0: std::time::Instant,
        prev_states: &[RngState],
        states: &[RngState],
        hp0: xla::Literal,
        hm0: xla::Literal,
        gl: &xla::Literal,
        lr: &xla::Literal,
        eps: &xla::Literal,
    ) -> Result<(xla::Literal, xla::Literal)> {
        let n_blocks = self.params.n_blocks();
        let slots = self.opts.slots.max(1);
        let numel = self.rt.manifest().block.size;
        let reusable = self.opts.reusable_mem;
        if !reusable {
            // Per-upload allocations still respect capacity (worst case all
            // in-flight slots live at once).
            self.device.alloc((numel * slots * 4) as u64)?;
            self.device.free((numel * slots * 4) as u64);
        }

        // Move the host buckets into the pipeline; they come back encoded.
        let buckets: Vec<HostBucket> = std::mem::take(&mut self.params.blocks);
        let wire_bytes: Vec<u64> = buckets.iter().map(|b| b.wire_bytes() as u64).collect();
        let wire_bytes = &wire_bytes; // shared by both stream threads

        struct Uploaded {
            idx: usize,
            bucket: HostBucket,
            slot: Vec<f32>,
            t_end: f64,
        }
        struct ToOffload {
            idx: usize,
            bucket: HostBucket,
            updated: Vec<f32>,
            t_ready: f64,
        }

        let (tx_up, rx_up) = mpsc::sync_channel::<Uploaded>(slots);
        let (tx_off, rx_off) = mpsc::sync_channel::<ToOffload>(slots);

        let trans = &self.transfers;
        let tmodel = self.transfer_model;
        let prev_states = prev_states.to_vec();
        let cur_states = states.to_vec();
        let events: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());

        let (hp, hm, done_buckets) = std::thread::scope(|s| -> Result<_> {
            // --- upload stream: prefetch ahead, bounded by the slot ring ---
            s.spawn({
                let events = &events;
                move || {
                    for (idx, bucket) in buckets.into_iter().enumerate() {
                        let t_start = wall0.elapsed().as_secs_f64();
                        let n = bucket.numel();
                        let mut slot = vec![0.0f32; n];
                        bucket.decode_into(&mut slot);
                        trans.lock().unwrap().record_h2d(wire_bytes[idx], &tmodel);
                        let t_end = wall0.elapsed().as_secs_f64();
                        events.lock().unwrap().push(TraceEvent {
                            stream: "upload",
                            label: format!("U b{idx}"),
                            start: t_start,
                            end: t_end,
                        });
                        if tx_up.send(Uploaded { idx, bucket, slot, t_end }).is_err() {
                            return; // main thread errored out
                        }
                    }
                }
            });

            // --- offload stream: encode updated buckets back ---------------
            let off_handle = s.spawn({
                let events = &events;
                move || -> Vec<(usize, HostBucket)> {
                    let mut done = Vec::new();
                    while let Ok(mut job) = rx_off.recv() {
                        let t_start = wall0.elapsed().as_secs_f64().max(job.t_ready);
                        job.bucket.encode_from(&job.updated);
                        trans.lock().unwrap().record_d2h(wire_bytes[job.idx], &tmodel);
                        events.lock().unwrap().push(TraceEvent {
                            stream: "offload",
                            label: format!("O b{}", job.idx),
                            start: t_start,
                            end: wall0.elapsed().as_secs_f64(),
                        });
                        done.push((job.idx, job.bucket));
                    }
                    done
                }
            });

            // --- compute stream (this thread: PJRT is not Send) ------------
            let mut hp = hp0;
            let mut hm = hm0;
            for _ in 0..n_blocks {
                let up = rx_up.recv().map_err(|_| anyhow::anyhow!("upload stream died"))?;
                let n = up.slot.len();
                let tc = wall0.elapsed().as_secs_f64();
                let outs = self.rt.run(
                    "block_step",
                    &[
                        lit_f32(&up.slot, &[n as i64])?,
                        lit_key(key_of(prev_states[1 + up.idx]))?,
                        gl.clone(),
                        lr.clone(),
                        lit_key(key_of(cur_states[1 + up.idx]))?,
                        eps.clone(),
                        hp,
                        hm,
                    ],
                )?;
                let mut it = outs.into_iter();
                let updated = lit_to_f32(&it.next().unwrap())?;
                hp = it.next().unwrap();
                hm = it.next().unwrap();
                let t_end = wall0.elapsed().as_secs_f64();
                events.lock().unwrap().push(TraceEvent {
                    stream: "compute",
                    label: format!("C b{}", up.idx),
                    start: tc.max(up.t_end),
                    end: t_end,
                });
                tx_off
                    .send(ToOffload { idx: up.idx, bucket: up.bucket, updated, t_ready: t_end })
                    .map_err(|_| anyhow::anyhow!("offload stream died"))?;
            }
            drop(tx_off);
            let done = off_handle.join().map_err(|_| anyhow::anyhow!("offload thread panicked"))?;
            Ok((hp, hm, done))
        })?;

        // Reassemble the host tier from the pipeline's outputs.
        let mut slots_back: Vec<Option<HostBucket>> = (0..n_blocks).map(|_| None).collect();
        for (idx, bucket) in done_buckets {
            slots_back[idx] = Some(bucket);
        }
        self.params.blocks =
            slots_back.into_iter().map(|o| o.expect("block lost in pipeline")).collect();
        for e in events.into_inner().unwrap() {
            timeline.push(e);
        }
        Ok((hp, hm))
    }

    /// Non-efficient-update ablation: standalone update round (Fig. 5a) —
    /// every block crosses the interconnect a second time.
    fn apply_update_round(&mut self, g: f32, states: &[RngState]) -> Result<()> {
        let lr = lit_scalar(self.cfg.lr);
        let gl = lit_scalar(g);

        let n_emb = self.params.embed.len();
        let out = self.rt.run(
            "update_embed",
            &[
                lit_f32(&self.params.embed, &[n_emb as i64])?,
                lit_key(key_of(states[0]))?,
                lr.clone(),
                gl.clone(),
            ],
        )?;
        self.params.embed = lit_to_f32(&out[0])?;

        for i in 0..self.params.n_blocks() {
            let n = self.params.blocks[i].numel();
            let decoded = self.params.blocks[i].to_f32();
            let wire = self.params.blocks[i].wire_bytes() as u64;
            self.transfers.lock().unwrap().record_h2d(wire, &self.transfer_model);
            let out = self.rt.run(
                "update_block",
                &[
                    lit_f32(&decoded, &[n as i64])?,
                    lit_key(key_of(states[1 + i]))?,
                    lr.clone(),
                    gl.clone(),
                ],
            )?;
            let updated = lit_to_f32(&out[0])?;
            self.params.blocks[i].encode_from(&updated);
            self.transfers.lock().unwrap().record_d2h(wire, &self.transfer_model);
        }

        let n_head = self.params.head.len();
        let out = self.rt.run(
            "update_head",
            &[
                lit_f32(&self.params.head, &[n_head as i64])?,
                lit_key(key_of(states[1 + self.params.n_blocks()]))?,
                lr,
                gl,
            ],
        )?;
        self.params.head = lit_to_f32(&out[0])?;
        Ok(())
    }

    /// Apply any pending deferred update (the paper's final
    /// `model.opt.zo_update(model)` — Fig. 6b).  Idempotent.
    pub fn flush_updates(&mut self) -> Result<()> {
        if let Some(p) = self.pending.take() {
            self.apply_update_round_no_transfer_double_count(p.g, &p.states)?;
        }
        Ok(())
    }

    /// Flush helper: same math as `apply_update_round`, but its transfers are
    /// the *regular* once-per-step cycle (not the doubled ablation traffic),
    /// so only one h2d+d2h per block is recorded.
    fn apply_update_round_no_transfer_double_count(
        &mut self,
        g: f32,
        states: &[RngState],
    ) -> Result<()> {
        self.apply_update_round(g, states)
    }

    /// Unperturbed forward on *fully-updated* parameters (flushes pending).
    pub fn eval(&mut self, ids: &[i32]) -> Result<(f32, Vec<f32>)> {
        self.flush_updates()?;
        let m = self.rt.manifest();
        let (b, t) = (m.config.batch as i64, m.config.seq_len as i64);
        let ids_lit = lit_i32(ids, &[b, t])?;
        let out = self.rt.run(
            "embed_fwd",
            &[lit_f32(&self.params.embed, &[self.params.embed.len() as i64])?, ids_lit.clone()],
        )?;
        let mut h = out.into_iter().next().unwrap();
        for blk in &self.params.blocks {
            let out = self
                .rt
                .run("block_fwd", &[lit_f32(&blk.to_f32(), &[blk.numel() as i64])?, h])?;
            h = out.into_iter().next().unwrap();
        }
        let out = self.rt.run(
            "head_eval",
            &[lit_f32(&self.params.head, &[self.params.head.len() as i64])?, h, ids_lit],
        )?;
        let mut it = out.into_iter();
        let loss = lit_to_scalar(&it.next().unwrap())?;
        let logits = lit_to_f32(&it.next().unwrap())?;
        Ok((loss, logits))
    }
}

