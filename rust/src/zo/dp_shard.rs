//! Deterministic in-process data-parallel ZO ("DP sim-shard").
//!
//! Seed-synchronous DP ZO (the sharding strategy the analytic simulator
//! prices in [`crate::shard`]) has a communication contract of exactly two
//! items per step: the perturbation **seed** (all workers draw the same z)
//! and the **projected-gradient scalar** (all-reduced across workers).
//! This module makes the "no accuracy loss" half of that contract testable
//! *without any real hardware*: [`DpSimShard`] runs K logical workers
//! in-process over a fixed set of S microbatch shards and reduces their
//! per-shard gradients in canonical shard order.
//!
//! # The invariant
//!
//! The trajectory is a function of the shard set S, **never** of the worker
//! count K.  Worker k evaluates shards `{k, k+K, k+2K, …}`; since every
//! worker replica applies the same all-reduced updates and draws the same
//! per-step z (in-process, workers share the base seed and replay their
//! per-step streams by counter offset), each per-shard loss pair is
//! bit-identical no matter which worker computes it, and the reduction
//! `ḡ = (Σₛ gₛ)/S` runs in fixed shard order with fixed f32 arithmetic.
//! K = 4 therefore reproduces the K = 1 ("single worker evaluates every
//! shard") loss trajectory bit-for-bit — asserted by the property tests in
//! `tests/scheduler_props.rs` on a host-only worker and by
//! `tests/dp_shard.rs` on real [`crate::zo::Zo2Engine`] replicas
//! (artifact-gated).

use anyhow::Result;

use crate::zo::{StepStats, Zo2Engine};

/// A logical DP worker: owns a full model replica and can evaluate one ZO
/// step's dual losses on a list of microbatch shards.
pub trait DpWorker {
    /// Run this step's dual forward on each shard (applying the previous
    /// step's deferred update, whose gradient was delivered by
    /// [`Self::set_allreduced_g`], before the first shard).  Returns one
    /// `(ℓ₊, ℓ₋)` pair per shard, in the given order.
    fn dp_dual_losses(&mut self, shards: &[&[i32]]) -> Result<Vec<(f32, f32)>>;

    /// Evaluate *additional* shards for the step already parked by
    /// [`Self::dp_dual_losses`] — the reassignment path after another
    /// worker failed mid-step.  Must replay the same perturbation (same
    /// step, same z) and leave the parked deferred update untouched.
    fn dp_extra_losses(&mut self, shards: &[&[i32]]) -> Result<Vec<(f32, f32)>>;

    /// Deliver the all-reduced projected gradient for the step just
    /// evaluated.
    fn set_allreduced_g(&mut self, g: f32);

    /// Perturbation scale ε (for recomputing per-shard gradients).
    fn eps(&self) -> f32;
}

impl DpWorker for Zo2Engine {
    fn dp_dual_losses(&mut self, shards: &[&[i32]]) -> Result<Vec<(f32, f32)>> {
        Zo2Engine::dp_dual_losses(self, shards)
    }

    fn dp_extra_losses(&mut self, shards: &[&[i32]]) -> Result<Vec<(f32, f32)>> {
        Zo2Engine::dp_extra_losses(self, shards)
    }

    fn set_allreduced_g(&mut self, g: f32) {
        Zo2Engine::set_allreduced_g(self, g)
    }

    fn eps(&self) -> f32 {
        self.zo_eps()
    }
}

/// K logical seed-synchronous DP workers over S microbatch shards.
pub struct DpSimShard<W> {
    workers: Vec<W>,
    shards: usize,
    step: u64,
}

impl<W: DpWorker> DpSimShard<W> {
    /// `workers` must all be replicas initialised from the same seed; the
    /// shard count is fixed for the run (it is part of the trajectory's
    /// identity — the worker count is not).  The round-robin assignment
    /// handles uneven splits, so any `K ≤ S` is accepted — which is what
    /// keeps the sim running when K shrinks after a worker failure.
    pub fn new(workers: Vec<W>, shards: usize) -> Result<Self> {
        anyhow::ensure!(!workers.is_empty(), "need at least one DP worker");
        anyhow::ensure!(shards >= 1, "need at least one shard");
        anyhow::ensure!(
            workers.len() <= shards,
            "{} workers but only {shards} shards: extra workers would sit idle with \
             no shard to evaluate",
            workers.len()
        );
        Ok(Self { workers, shards, step: 0 })
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn n_shards(&self) -> usize {
        self.shards
    }

    pub fn workers(&self) -> &[W] {
        &self.workers
    }

    pub fn workers_mut(&mut self) -> &mut [W] {
        &mut self.workers
    }

    /// One DP ZO step over a global batch of `ids`, which must split into
    /// `n_shards()` equal shards (each shaped like one engine batch).
    ///
    /// Worker k evaluates shards `{k, k+K, …}` in ascending order; the
    /// all-reduce recomputes every shard's `gₛ = (ℓ₊ − ℓ₋)/2ε` and averages
    /// in canonical shard order, then broadcasts ḡ to every worker's parked
    /// deferred update.  The reported loss is the shard-mean of the dual
    /// losses.
    ///
    /// The step is atomic with respect to worker failure: a worker whose
    /// evaluation errors is removed from the group and its shards are
    /// re-evaluated on the survivors (via [`DpWorker::dp_extra_losses`],
    /// which replays the same perturbation) *before* any all-reduced
    /// gradient is delivered, so the committed trajectory is unchanged.
    /// Only when every worker fails does the step itself fail — and then
    /// without having delivered a partial update to anyone.
    pub fn train_step(&mut self, ids: &[i32]) -> Result<StepStats> {
        // zo2-lint: allow(no-wall-clock): step-duration telemetry returned in StepStats
        let t0 = std::time::Instant::now();
        let s = self.shards;
        anyhow::ensure!(
            !ids.is_empty() && ids.len() % s == 0,
            "batch of {} ids does not split into {s} shards",
            ids.len()
        );
        let shard_len = ids.len() / s;
        let shards: Vec<&[i32]> = ids.chunks(shard_len).collect();
        let k = self.workers.len();

        let mut per_shard: Vec<Option<(f32, f32)>> = vec![None; s];
        let mut failed: Vec<usize> = Vec::new();
        for (w, worker) in self.workers.iter_mut().enumerate() {
            let mine_idx: Vec<usize> = (w..s).step_by(k).collect();
            let mine: Vec<&[i32]> = mine_idx.iter().map(|&i| shards[i]).collect();
            match worker.dp_dual_losses(&mine) {
                Ok(losses) => {
                    anyhow::ensure!(losses.len() == mine.len(), "worker {w} shard count mismatch");
                    for (j, l) in losses.into_iter().enumerate() {
                        per_shard[mine_idx[j]] = Some(l);
                    }
                }
                Err(_) => failed.push(w),
            }
        }

        // Reassign the failed workers' shards to survivors before any
        // gradient is committed anywhere.
        if !failed.is_empty() {
            for &w in failed.iter().rev() {
                self.workers.remove(w);
            }
            anyhow::ensure!(
                !self.workers.is_empty(),
                "all {k} DP workers failed at step {}; no partial update was delivered",
                self.step
            );
            let missing: Vec<usize> =
                per_shard.iter().enumerate().filter(|(_, p)| p.is_none()).map(|(i, _)| i).collect();
            crate::telemetry::metrics::counter_add(
                "zo2_dp_reassigned_shards",
                &[],
                missing.len() as u64,
            );
            let survivors = self.workers.len();
            for (j, &si) in missing.iter().enumerate() {
                let extra = [shards[si]];
                let losses = self.workers[j % survivors].dp_extra_losses(&extra)?;
                anyhow::ensure!(losses.len() == 1, "reassigned shard count mismatch");
                per_shard[si] = Some(losses[0]);
            }
        }

        // Canonical all-reduce: fixed shard order, plain f32 accumulation —
        // the reduction is identical for every worker count and for every
        // assignment of shards to workers.
        let eps = self.workers[0].eps();
        let mut g_sum = 0.0f32;
        let mut lp_sum = 0.0f32;
        let mut lm_sum = 0.0f32;
        for pair in per_shard.iter().flatten() {
            let (lp, lm) = *pair;
            g_sum += (lp - lm) / (2.0 * eps);
            lp_sum += lp;
            lm_sum += lm;
        }
        let g = g_sum / s as f32;
        for worker in &mut self.workers {
            worker.set_allreduced_g(g);
        }

        self.step += 1;
        Ok(StepStats {
            step: self.step - 1,
            loss_plus: lp_sum / s as f32,
            loss_minus: lm_sum / s as f32,
            g,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }
}
