//! Deterministic in-process data-parallel ZO ("DP sim-shard").
//!
//! Seed-synchronous DP ZO (the sharding strategy the analytic simulator
//! prices in [`crate::shard`]) has a communication contract of exactly two
//! items per step: the perturbation **seed** (all workers draw the same z)
//! and the **projected-gradient scalar** (all-reduced across workers).
//! This module makes the "no accuracy loss" half of that contract testable
//! *without any real hardware*: [`DpSimShard`] runs K logical workers
//! in-process over a fixed set of S microbatch shards and reduces their
//! per-shard gradients in canonical shard order.
//!
//! # The invariant
//!
//! The trajectory is a function of the shard set S, **never** of the worker
//! count K.  Worker k evaluates shards `{k, k+K, k+2K, …}`; since every
//! worker replica applies the same all-reduced updates and draws the same
//! per-step z (in-process, workers share the base seed and replay their
//! per-step streams by counter offset), each per-shard loss pair is
//! bit-identical no matter which worker computes it, and the reduction
//! `ḡ = (Σₛ gₛ)/S` runs in fixed shard order with fixed f32 arithmetic.
//! K = 4 therefore reproduces the K = 1 ("single worker evaluates every
//! shard") loss trajectory bit-for-bit — asserted by the property tests in
//! `tests/scheduler_props.rs` on a host-only worker and by
//! `tests/dp_shard.rs` on real [`crate::zo::Zo2Engine`] replicas
//! (artifact-gated).

use anyhow::Result;

use crate::zo::{StepStats, Zo2Engine};

/// A logical DP worker: owns a full model replica and can evaluate one ZO
/// step's dual losses on a list of microbatch shards.
pub trait DpWorker {
    /// Run this step's dual forward on each shard (applying the previous
    /// step's deferred update, whose gradient was delivered by
    /// [`Self::set_allreduced_g`], before the first shard).  Returns one
    /// `(ℓ₊, ℓ₋)` pair per shard, in the given order.
    fn dp_dual_losses(&mut self, shards: &[&[i32]]) -> Result<Vec<(f32, f32)>>;

    /// Deliver the all-reduced projected gradient for the step just
    /// evaluated.
    fn set_allreduced_g(&mut self, g: f32);

    /// Perturbation scale ε (for recomputing per-shard gradients).
    fn eps(&self) -> f32;
}

impl DpWorker for Zo2Engine {
    fn dp_dual_losses(&mut self, shards: &[&[i32]]) -> Result<Vec<(f32, f32)>> {
        Zo2Engine::dp_dual_losses(self, shards)
    }

    fn set_allreduced_g(&mut self, g: f32) {
        Zo2Engine::set_allreduced_g(self, g)
    }

    fn eps(&self) -> f32 {
        self.zo_eps()
    }
}

/// K logical seed-synchronous DP workers over S microbatch shards.
pub struct DpSimShard<W> {
    workers: Vec<W>,
    shards: usize,
    step: u64,
}

impl<W: DpWorker> DpSimShard<W> {
    /// `workers` must all be replicas initialised from the same seed; the
    /// shard count is fixed for the run (it is part of the trajectory's
    /// identity — the worker count is not) and must divide evenly across
    /// the workers.
    pub fn new(workers: Vec<W>, shards: usize) -> Result<Self> {
        anyhow::ensure!(!workers.is_empty(), "need at least one DP worker");
        anyhow::ensure!(shards >= 1, "need at least one shard");
        anyhow::ensure!(
            shards % workers.len() == 0,
            "{shards} shards do not divide across {} workers",
            workers.len()
        );
        Ok(Self { workers, shards, step: 0 })
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn n_shards(&self) -> usize {
        self.shards
    }

    pub fn workers(&self) -> &[W] {
        &self.workers
    }

    pub fn workers_mut(&mut self) -> &mut [W] {
        &mut self.workers
    }

    /// One DP ZO step over a global batch of `ids`, which must split into
    /// `n_shards()` equal shards (each shaped like one engine batch).
    ///
    /// Worker k evaluates shards `{k, k+K, …}` in ascending order; the
    /// all-reduce recomputes every shard's `gₛ = (ℓ₊ − ℓ₋)/2ε` and averages
    /// in canonical shard order, then broadcasts ḡ to every worker's parked
    /// deferred update.  The reported loss is the shard-mean of the dual
    /// losses.
    pub fn train_step(&mut self, ids: &[i32]) -> Result<StepStats> {
        let t0 = std::time::Instant::now();
        let s = self.shards;
        anyhow::ensure!(
            !ids.is_empty() && ids.len() % s == 0,
            "batch of {} ids does not split into {s} shards",
            ids.len()
        );
        let shard_len = ids.len() / s;
        let shards: Vec<&[i32]> = ids.chunks(shard_len).collect();
        let k = self.workers.len();

        let mut per_shard: Vec<(f32, f32)> = vec![(0.0, 0.0); s];
        for (w, worker) in self.workers.iter_mut().enumerate() {
            let mine: Vec<&[i32]> = (w..s).step_by(k).map(|i| shards[i]).collect();
            let losses = worker.dp_dual_losses(&mine)?;
            anyhow::ensure!(losses.len() == mine.len(), "worker {w} shard count mismatch");
            for (j, l) in losses.into_iter().enumerate() {
                per_shard[w + j * k] = l;
            }
        }

        // Canonical all-reduce: fixed shard order, plain f32 accumulation —
        // the reduction is identical for every worker count.
        let eps = self.workers[0].eps();
        let mut g_sum = 0.0f32;
        let mut lp_sum = 0.0f32;
        let mut lm_sum = 0.0f32;
        for &(lp, lm) in &per_shard {
            g_sum += (lp - lm) / (2.0 * eps);
            lp_sum += lp;
            lm_sum += lm;
        }
        let g = g_sum / s as f32;
        for worker in &mut self.workers {
            worker.set_allreduced_g(g);
        }

        self.step += 1;
        Ok(StepStats {
            step: self.step - 1,
            loss_plus: lp_sum / s as f32,
            loss_minus: lm_sum / s as f32,
            g,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }
}
