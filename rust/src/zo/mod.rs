//! Zeroth-order optimization engines.
//!
//! * [`MezoEngine`] — the baseline (paper Algorithm 1 / MeZO): the whole
//!   model is GPU-resident; each step runs the fused dual-forward through
//!   every module, computes the projected gradient `g = (ℓ₊−ℓ₋)/2ε`, then
//!   re-walks the modules applying `θ ← θ − η·g·z` with `z` replayed from
//!   the recorded RNG states.
//! * [`Zo2Engine`] — the paper's system (Algorithms 2+3): transformer
//!   blocks live in host memory (optionally compressed, §5.5), stream
//!   through a reusable device buffer (§5.3), and the update is *deferred*:
//!   block `i` at step `j` is updated with `g_{j−1}` inside the same fused
//!   executable that runs step `j`'s dual forward (§5.4) — one
//!   upload+offload cycle per block per step.  `run_mode` selects the naive
//!   sequential schedule or the overlapped three-stream schedule (§5.2).
//! * [`DpSimShard`] — deterministic in-process data-parallel ZO: K logical
//!   workers over a fixed shard set, seed-synchronous perturbations, one
//!   scalar all-reduce per step in canonical shard order — the trajectory
//!   is bit-identical for any K (the "no accuracy loss" contract of the
//!   simulated multi-GPU DP strategy, testable without hardware).
//!
//! Both engines drive the *same* AOT executables with the *same*
//! counter-RNG discipline, which is what makes ZO2 bit-identical to MeZO
//! (verified by `tests/parity.rs`).

pub mod cpu_optim;
pub mod dp_shard;
pub mod mezo;
pub mod param_store;
pub mod zo2;

pub use cpu_optim::{
    cpu_zo_adamw_update, cpu_zo_adamw_update_pooled, cpu_zo_sgd_update, cpu_zo_sgd_update_pooled,
    fused_zo_adamw, AdamHp, AdamState, ZScratch,
};
pub use dp_shard::{DpSimShard, DpWorker};
pub use mezo::MezoEngine;
pub use param_store::ParamStore;
pub use zo2::{RunMode, UpdateSite, Zo2Engine, Zo2Options};

pub use crate::sched::Tiering;

use crate::rng::{GaussianRng, RngState};

/// Optimizer hyper-parameters (paper §7: lr 1e-7…, eps 1e-3, seed).
#[derive(Debug, Clone, Copy)]
pub struct ZoConfig {
    pub lr: f32,
    pub eps: f32,
    pub seed: u64,
}

impl Default for ZoConfig {
    fn default() -> Self {
        Self { lr: 1e-4, eps: 1e-3, seed: 42 }
    }
}

/// Per-step report.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub step: u64,
    pub loss_plus: f32,
    pub loss_minus: f32,
    /// Projected gradient (paper Eq. 2) — a scalar; the full gradient
    /// `g·z` is never materialised.
    pub g: f32,
    pub wall_s: f64,
}

impl StepStats {
    /// The loss reported for the step (mean of the two perturbed losses,
    /// matching MeZO's reporting convention).
    pub fn loss(&self) -> f32 {
        0.5 * (self.loss_plus + self.loss_minus)
    }
}

/// Number of counter ticks `fill_gaussian` consumes for `n` elements.
pub fn gaussian_ticks(n: usize) -> u64 {
    ((n + 1) / 2) as u64
}

/// Compute all per-module RNG states for iteration `j` without generating
/// any values (counter arithmetic — an exact fast-forward).  Module order:
/// embed, blocks 0..N, head.  Both engines derive their states through this
/// single function, which *is* the bit-exactness guarantee.
pub fn module_states(seed: u64, iter: u64, sizes: &[usize]) -> Vec<RngState> {
    let mut states = Vec::with_capacity(sizes.len());
    let mut counter = 0u64;
    for &n in sizes {
        states.push(RngState { seed, stream: iter, counter });
        counter += gaussian_ticks(n);
    }
    states
}

/// Fill `z` from a saved module state (replaying the perturbation draw).
/// Used by host-side oracles/tests; the engines ship [`key_of`] instead.
pub fn fill_z(state: RngState, z: &mut [f32]) {
    GaussianRng::from_state(state).fill_gaussian(z);
}

/// Threefry key data shipped to the executables in place of a z vector:
/// the device regenerates `z = normal(key, P)` on its own RNG hardware
/// (paper §5.1 — the RNG state lives with the manager, never the vector).
/// Deterministic in the managed state, so perturbation (step j) and the
/// deferred update (step j+1) replay identical directions.
pub fn key_of(state: RngState) -> [u32; 2] {
    #[inline]
    fn mix(mut x: u64) -> u64 {
        x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^ (x >> 31)
    }
    let v = mix(state.seed ^ mix(state.stream) ^ mix(state.counter).rotate_left(23));
    [(v >> 32) as u32, v as u32]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_states_match_sequential_generation() {
        let sizes = [10, 7, 32];
        let states = module_states(5, 3, &sizes);
        // Walk a single generator through the modules; the states must line
        // up with the precomputed fast-forward.
        let mut rng = GaussianRng::new(5, 3);
        for (i, &n) in sizes.iter().enumerate() {
            assert_eq!(rng.state(), states[i], "module {i}");
            let mut z = vec![0.0; n];
            rng.fill_gaussian(&mut z);
        }
    }

    #[test]
    fn fill_z_is_replayable() {
        let states = module_states(1, 0, &[100, 50]);
        let mut a = vec![0.0; 50];
        let mut b = vec![0.0; 50];
        fill_z(states[1], &mut a);
        fill_z(states[1], &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn step_stats_loss() {
        let s = StepStats { step: 0, loss_plus: 2.0, loss_minus: 4.0, g: 0.1, wall_s: 0.0 };
        assert_eq!(s.loss(), 3.0);
    }
}
