//! MeZO baseline engine (paper Algorithm 1): whole model GPU-resident.
//!
//! Canonical MeZO order: dual-forward through every module with the
//! *current* parameters, compute the projected gradient, then re-walk the
//! modules applying the update with `z` **replayed from the recorded RNG
//! states** — the z vectors are never stored or shipped (MeZO's memory
//! trick, §3): only the 8-byte key derived from the managed state reaches
//! the device, which regenerates `z` locally.
//!
//! The baseline's host-side bucket staging (decode before each executable
//! call, encode after each update) runs through the same [`HostPool`]
//! chunk kernels as the ZO2 engine, so baseline-vs-ZO2 comparisons charge
//! the same host-kernel cost on both sides (pooled fp32 staging is a
//! chunked copy — bit-identical to the scalar path at any thread count).

use std::sync::Arc;

use anyhow::Result;

use crate::hostpool::HostPool;
use crate::memory::DevicePool;
use crate::precision::Codec;
use crate::rng::RngStateManager;
use crate::runtime::{lit_f32, lit_i32, lit_key, lit_scalar, lit_to_f32, lit_to_scalar, Runtime};
use crate::zo::{key_of, module_states, ParamStore, StepStats, ZoConfig};

pub struct MezoEngine {
    rt: Runtime,
    pub params: ParamStore,
    cfg: ZoConfig,
    manager: RngStateManager,
    step: u64,
    pub device: std::sync::Arc<DevicePool>,
    /// Host compute pool for bucket staging (shared cost basis with ZO2).
    pub hostpool: Arc<HostPool>,
}

impl MezoEngine {
    pub fn new(rt: Runtime, cfg: ZoConfig) -> Result<Self> {
        // A 1-thread pool is exactly the serial staging path; callers that
        // want parallel host staging use `with_host_threads`.
        Self::with_host_threads(rt, cfg, 1)
    }

    /// Like [`Self::new`], with `host_threads` pool participants
    /// (0 = machine parallelism) for the bucket staging kernels.
    pub fn with_host_threads(rt: Runtime, cfg: ZoConfig, host_threads: usize) -> Result<Self> {
        Self::with_host_pool_opts(rt, cfg, host_threads, false)
    }

    /// Like [`Self::with_host_threads`], optionally pinning pool workers to
    /// cores (`--host-pin`).  Pinning never changes numerics.
    pub fn with_host_pool_opts(
        rt: Runtime,
        cfg: ZoConfig,
        host_threads: usize,
        host_pin: bool,
    ) -> Result<Self> {
        let params = ParamStore::init(rt.manifest(), cfg.seed, Codec::F32);
        let device = DevicePool::unlimited();
        // MeZO keeps every parameter resident on the device.
        let total: usize = params.module_sizes().iter().sum();
        device.alloc((total * 4) as u64)?;
        Ok(Self {
            rt,
            params,
            cfg,
            manager: RngStateManager::new(cfg.seed),
            step: 0,
            device,
            hostpool: Arc::new(HostPool::with_opts(host_threads, host_pin)),
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// One Algorithm-1 iteration on a [B, T] batch of token ids.
    pub fn train_step(&mut self, ids: &[i32]) -> Result<StepStats> {
        // zo2-lint: allow(no-wall-clock): step-duration telemetry returned in StepStats
        let t0 = std::time::Instant::now();
        let m = self.rt.manifest();
        let (b, t) = (m.config.batch as i64, m.config.seq_len as i64);
        anyhow::ensure!(ids.len() as i64 == b * t, "batch shape mismatch");

        let sizes = self.params.module_sizes();
        let states = module_states(self.cfg.seed, self.step, &sizes);
        // Bookkeeping mirrors Algorithm 2's rsb even though MeZO applies the
        // update in-step (states are consumed again below for the update).
        let _rng = self.manager.begin_iter(self.step);
        for &st in &states {
            self.manager.record_module_state(st);
        }

        let lr = lit_scalar(self.cfg.lr);
        let eps = lit_scalar(self.cfg.eps);
        let zero = lit_scalar(0.0);
        let ids_lit = lit_i32(ids, &[b, t])?;

        // --- dual forward (perturbation fused into the executables).
        // g_prev = 0 makes the fused deferred update an exact no-op, so the
        // current key doubles as key_prev.
        let n_emb = self.params.embed.len();
        let k_emb = lit_key(key_of(states[0]))?;
        let outs = self.rt.run(
            "embed_step",
            &[
                lit_f32(&self.params.embed, &[n_emb as i64])?,
                k_emb.clone(),
                zero.clone(),
                lr.clone(),
                k_emb,
                eps.clone(),
                ids_lit.clone(),
            ],
        )?;
        let mut it = outs.into_iter().skip(1);
        let mut hp = it.next().unwrap();
        let mut hm = it.next().unwrap();

        for i in 0..self.params.n_blocks() {
            let n = self.params.blocks[i].numel();
            let k = lit_key(key_of(states[1 + i]))?;
            let outs = self.rt.run(
                "block_step",
                &[
                    lit_f32(&self.params.blocks[i].to_f32_pooled(&self.hostpool), &[n as i64])?,
                    k.clone(),
                    zero.clone(),
                    lr.clone(),
                    k,
                    eps.clone(),
                    hp,
                    hm,
                ],
            )?;
            let mut it = outs.into_iter().skip(1);
            hp = it.next().unwrap();
            hm = it.next().unwrap();
        }

        let n_head = self.params.head.len();
        let k_head = lit_key(key_of(states[1 + self.params.n_blocks()]))?;
        let outs = self.rt.run(
            "head_step",
            &[
                lit_f32(&self.params.head, &[n_head as i64])?,
                k_head.clone(),
                zero,
                lr,
                k_head,
                eps,
                hp,
                hm,
                ids_lit,
            ],
        )?;
        let loss_plus = lit_to_scalar(&outs[1])?;
        let loss_minus = lit_to_scalar(&outs[2])?;
        let g = (loss_plus - loss_minus) / (2.0 * self.cfg.eps);

        // --- in-step update: replay z on device from the recorded states.
        self.apply_update(g, &states)?;

        self.step += 1;
        Ok(StepStats { step: self.step - 1, loss_plus, loss_minus, g, wall_s: t0.elapsed().as_secs_f64() })
    }

    /// θ ← θ − η·g·z for every module, z replayed from `states`.
    fn apply_update(&mut self, g: f32, states: &[crate::rng::RngState]) -> Result<()> {
        let lr = lit_scalar(self.cfg.lr);
        let gl = lit_scalar(g);

        let n_emb = self.params.embed.len();
        let out = self.rt.run(
            "update_embed",
            &[
                lit_f32(&self.params.embed, &[n_emb as i64])?,
                lit_key(key_of(states[0]))?,
                lr.clone(),
                gl.clone(),
            ],
        )?;
        self.params.embed = lit_to_f32(&out[0])?;

        for i in 0..self.params.n_blocks() {
            let n = self.params.blocks[i].numel();
            let out = self.rt.run(
                "update_block",
                &[
                    lit_f32(&self.params.blocks[i].to_f32_pooled(&self.hostpool), &[n as i64])?,
                    lit_key(key_of(states[1 + i]))?,
                    lr.clone(),
                    gl.clone(),
                ],
            )?;
            let updated = lit_to_f32(&out[0])?;
            self.params.blocks[i].encode_from_pooled(&updated, &self.hostpool);
        }

        let n_head = self.params.head.len();
        let out = self.rt.run(
            "update_head",
            &[
                lit_f32(&self.params.head, &[n_head as i64])?,
                lit_key(key_of(states[1 + self.params.n_blocks()]))?,
                lr,
                gl,
            ],
        )?;
        self.params.head = lit_to_f32(&out[0])?;
        Ok(())
    }

    /// Unperturbed forward: (mean next-token loss, last-position logits).
    pub fn eval(&self, ids: &[i32]) -> Result<(f32, Vec<f32>)> {
        let m = self.rt.manifest();
        let (b, t) = (m.config.batch as i64, m.config.seq_len as i64);
        let ids_lit = lit_i32(ids, &[b, t])?;
        let out = self.rt.run(
            "embed_fwd",
            &[lit_f32(&self.params.embed, &[self.params.embed.len() as i64])?, ids_lit.clone()],
        )?;
        let mut h = out.into_iter().next().unwrap();
        for blk in &self.params.blocks {
            let out = self.rt.run(
                "block_fwd",
                &[lit_f32(&blk.to_f32_pooled(&self.hostpool), &[blk.numel() as i64])?, h],
            )?;
            h = out.into_iter().next().unwrap();
        }
        let out = self.rt.run(
            "head_eval",
            &[lit_f32(&self.params.head, &[self.params.head.len() as i64])?, h, ids_lit],
        )?;
        let mut it = out.into_iter();
        let loss = lit_to_scalar(&it.next().unwrap())?;
        let logits = lit_to_f32(&it.next().unwrap())?;
        Ok((loss, logits))
    }
}
