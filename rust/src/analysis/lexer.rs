//! A minimal, dependency-free Rust lexer for the `zo2 lint` pass.
//!
//! This is **not** a full Rust grammar — the lint rules only need a token
//! stream that is comment-, string- and raw-string-aware, so that e.g. the
//! word `unsafe` inside a doc comment or a string literal is never mistaken
//! for the keyword, and schema literals like `"zo2-tune-v1"` are seen as one
//! string token with known contents.  Every token and comment carries its
//! 1-based source line, which is all the rule engine needs to attach
//! findings and resolve inline waivers.
//!
//! The lexer is intentionally forgiving: on malformed input (unterminated
//! string, stray byte) it degrades to single-character punctuation tokens
//! rather than failing, because lint must never be the reason a build
//! breaks on a file rustc itself accepts.

/// One lexed token with its source line.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    /// 1-based line the token starts on.
    pub line: usize,
    pub tok: Tok,
}

/// Token kinds.  Only the distinctions the rules need are made: identifiers
/// and string contents are kept verbatim, everything else collapses to a
/// coarse class.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    /// Identifier or keyword (`unsafe`, `HashMap`, `unwrap`, ...).
    Ident(String),
    /// String literal *contents* (cooked, raw, or byte), escapes unresolved.
    Str(String),
    /// Numeric literal (value irrelevant to every rule).
    Num,
    /// Character or byte literal.
    Char,
    /// Lifetime (`'a`) — distinguished from `Char` so `'static` never looks
    /// like a literal.
    Life,
    /// Any other single character (`.`, `!`, `#`, `{`, ...).
    Punct(char),
}

/// One comment (line or block) with the line range it covers.
#[derive(Debug, Clone)]
pub struct Comment {
    /// 1-based line the comment starts on.
    pub start_line: usize,
    /// 1-based line the comment ends on (== `start_line` for line comments).
    pub end_line: usize,
    /// Raw comment text including the `//` / `/* */` markers.
    pub text: String,
}

/// The result of lexing one source file.
#[derive(Debug, Clone, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

impl Lexed {
    /// All comments whose line range covers `line`.
    pub fn comments_covering(&self, line: usize) -> impl Iterator<Item = &Comment> {
        self.comments
            .iter()
            .filter(move |c| c.start_line <= line && line <= c.end_line)
    }

    /// The comment (if any) that *ends* exactly on `line`.
    pub fn comment_ending_on(&self, line: usize) -> Option<&Comment> {
        self.comments.iter().find(|c| c.end_line == line)
    }
}

fn is_ident_start(c: char) -> bool {
    c == '_' || c.is_ascii_alphabetic()
}

fn is_ident_cont(c: char) -> bool {
    c == '_' || c.is_ascii_alphanumeric()
}

/// Lex one source file into tokens + comments.
pub fn lex(source: &str) -> Lexed {
    let cs: Vec<char> = source.chars().collect();
    let n = cs.len();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut out = Lexed::default();

    while i < n {
        let c = cs[i];
        // Newlines drive the line counter everywhere below; handle the
        // common top-level case first.
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Comments.
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            out.comments.push(Comment {
                start_line: line,
                end_line: line,
                text: cs[start..i].iter().collect(),
            });
            continue;
        }
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            out.comments.push(Comment {
                start_line,
                end_line: line,
                text: cs[start..i.min(n)].iter().collect(),
            });
            continue;
        }
        // Raw / byte-raw strings: r"...", r#"..."#, br"...", br#"..."#.
        if (c == 'r' || c == 'b') && i + 1 < n {
            let (prefix_len, is_raw) = match (c, cs[i + 1]) {
                ('r', '"') | ('r', '#') => (1, true),
                ('b', 'r') if i + 2 < n && (cs[i + 2] == '"' || cs[i + 2] == '#') => (2, true),
                _ => (0, false),
            };
            if is_raw {
                let tok_line = line;
                let mut j = i + prefix_len;
                let mut hashes = 0usize;
                while j < n && cs[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && cs[j] == '"' {
                    j += 1;
                    let content_start = j;
                    // Scan for `"` followed by `hashes` hashes.
                    let mut content_end = n;
                    while j < n {
                        if cs[j] == '\n' {
                            line += 1;
                            j += 1;
                            continue;
                        }
                        if cs[j] == '"' {
                            let mut k = j + 1;
                            let mut seen = 0usize;
                            while k < n && seen < hashes && cs[k] == '#' {
                                seen += 1;
                                k += 1;
                            }
                            if seen == hashes {
                                content_end = j;
                                j = k;
                                break;
                            }
                        }
                        j += 1;
                    }
                    out.tokens.push(Token {
                        line: tok_line,
                        tok: Tok::Str(cs[content_start..content_end.min(n)].iter().collect()),
                    });
                    i = j;
                    continue;
                }
                // `r` / `b` not actually starting a raw string (e.g. ident
                // `r#foo` raw identifier) — fall through to ident handling.
            }
        }
        // Byte string b"..." and byte char b'..'.
        if c == 'b' && i + 1 < n && (cs[i + 1] == '"' || cs[i + 1] == '\'') {
            if cs[i + 1] == '"' {
                let tok_line = line;
                let mut j = i + 2;
                let content_start = j;
                while j < n {
                    if cs[j] == '\\' {
                        j += 2;
                    } else if cs[j] == '\n' {
                        line += 1;
                        j += 1;
                    } else if cs[j] == '"' {
                        break;
                    } else {
                        j += 1;
                    }
                }
                out.tokens.push(Token {
                    line: tok_line,
                    tok: Tok::Str(cs[content_start..j.min(n)].iter().collect()),
                });
                i = (j + 1).min(n);
                continue;
            }
            // b'x' byte literal.
            let mut j = i + 2;
            if j < n && cs[j] == '\\' {
                j += 2;
            } else {
                j += 1;
            }
            while j < n && cs[j] != '\'' {
                j += 1;
            }
            out.tokens.push(Token { line, tok: Tok::Char });
            i = (j + 1).min(n);
            continue;
        }
        // Cooked strings.
        if c == '"' {
            let tok_line = line;
            let mut j = i + 1;
            let content_start = j;
            while j < n {
                if cs[j] == '\\' {
                    j += 2;
                } else if cs[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if cs[j] == '"' {
                    break;
                } else {
                    j += 1;
                }
            }
            out.tokens.push(Token {
                line: tok_line,
                tok: Tok::Str(cs[content_start..j.min(n)].iter().collect()),
            });
            i = (j + 1).min(n);
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && cs[i + 1] == '\\' {
                // Escaped char literal: skip the escape pair, then to the
                // closing quote.
                let mut j = i + 3;
                while j < n && cs[j] != '\'' {
                    j += 1;
                }
                out.tokens.push(Token { line, tok: Tok::Char });
                i = (j + 1).min(n);
                continue;
            }
            if i + 2 < n && cs[i + 2] == '\'' {
                out.tokens.push(Token { line, tok: Tok::Char });
                i += 3;
                continue;
            }
            // Lifetime: consume ident chars after the quote.
            let mut j = i + 1;
            while j < n && is_ident_cont(cs[j]) {
                j += 1;
            }
            out.tokens.push(Token { line, tok: Tok::Life });
            i = j;
            continue;
        }
        // Numbers.  `0..5` must lex as Num, '.', '.', Num — so '.' is only
        // consumed when followed by a digit.
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n {
                let d = cs[j];
                if is_ident_cont(d) {
                    j += 1;
                } else if d == '.' && j + 1 < n && cs[j + 1].is_ascii_digit() {
                    j += 1;
                } else {
                    break;
                }
            }
            out.tokens.push(Token { line, tok: Tok::Num });
            i = j;
            continue;
        }
        // Identifiers / keywords.
        if is_ident_start(c) {
            let mut j = i + 1;
            while j < n && is_ident_cont(cs[j]) {
                j += 1;
            }
            out.tokens.push(Token {
                line,
                tok: Tok::Ident(cs[i..j].iter().collect()),
            });
            i = j;
            continue;
        }
        // Everything else: single-char punctuation.
        out.tokens.push(Token { line, tok: Tok::Punct(c) });
        i += 1;
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(lexed: &Lexed) -> Vec<&str> {
        lexed
            .tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Ident(s) => Some(s.as_str()),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn comments_are_not_tokens() {
        let l = lex("// unsafe HashMap\nfn f() {}\n/* unwrap */ let x = 1;");
        assert!(!idents(&l).contains(&"unsafe"));
        assert!(!idents(&l).contains(&"HashMap"));
        assert!(!idents(&l).contains(&"unwrap"));
        assert_eq!(l.comments.len(), 2);
        assert_eq!(l.comments[0].start_line, 1);
        assert_eq!(l.comments[1].start_line, 3);
    }

    #[test]
    fn strings_keep_contents_and_hide_keywords() {
        let l = lex(r#"let s = "unsafe zo2-tune-v1";"#);
        assert!(!idents(&l).contains(&"unsafe"));
        let strs: Vec<&str> = l
            .tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Str(s) => Some(s.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(strs, vec!["unsafe zo2-tune-v1"]);
    }

    #[test]
    fn raw_strings_and_hashes() {
        let l = lex("let s = r#\"a \"quoted\" zo2-x-v2\"#; let t = r\"plain\";");
        let strs: Vec<&str> = l
            .tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Str(s) => Some(s.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(strs, vec!["a \"quoted\" zo2-x-v2", "plain"]);
    }

    #[test]
    fn lifetimes_vs_chars() {
        let l = lex("fn f<'a>(x: &'a str) { let c = 'x'; let e = '\\n'; }");
        let lifes = l.tokens.iter().filter(|t| t.tok == Tok::Life).count();
        let chars = l.tokens.iter().filter(|t| t.tok == Tok::Char).count();
        assert_eq!(lifes, 2);
        assert_eq!(chars, 2);
    }

    #[test]
    fn ranges_do_not_eat_dots() {
        let l = lex("for i in 0..5 {}");
        let dots = l
            .tokens
            .iter()
            .filter(|t| t.tok == Tok::Punct('.'))
            .count();
        assert_eq!(dots, 2);
        let nums = l.tokens.iter().filter(|t| t.tok == Tok::Num).count();
        assert_eq!(nums, 2);
    }

    #[test]
    fn multiline_block_comment_line_tracking() {
        let l = lex("/* a\n b\n c */\nlet x = 1;");
        assert_eq!(l.comments[0].start_line, 1);
        assert_eq!(l.comments[0].end_line, 3);
        // `let` lands on line 4.
        assert_eq!(l.tokens[0].line, 4);
    }

    #[test]
    fn line_numbers_across_strings() {
        let l = lex("let a = \"x\ny\";\nlet b = 1;");
        // `b` is on line 3 (string spans lines 1-2).
        let b = l
            .tokens
            .iter()
            .find(|t| t.tok == Tok::Ident("b".into()))
            .unwrap();
        assert_eq!(b.line, 3);
    }
}
