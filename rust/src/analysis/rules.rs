//! The `zo2 lint` rule engine: five source rules over the token stream of
//! [`super::lexer`], plus the inline waiver protocol.
//!
//! # Rules
//!
//! * `unsafe-needs-safety-comment` — every `unsafe` keyword (block, fn,
//!   impl, trait) must carry a safety argument: a comment containing the
//!   word "safety" on the same line or in the contiguous comment run above
//!   it (attribute lines are skipped, so `// SAFETY:` above
//!   `#[target_feature]` counts, as does a `/// # Safety` doc section).
//!   Every site — documented or not — lands in the unsafe inventory.
//! * `deterministic-collections` — no `HashMap`/`HashSet` in the modules
//!   whose iteration order reaches plans, reports or golden files
//!   (`sched/`, `shard/`, `tune/`, `telemetry/`, `dp/`, `costmodel/`);
//!   `BTreeMap`/`BTreeSet` iterate canonically.
//! * `no-wall-clock` — no `Instant::now()` / `SystemTime::now()` outside
//!   `clock/`: wall-clock reads are nondeterminism on the committed
//!   trajectory unless a waiver argues they are telemetry-only.
//! * `no-panic-in-cli-planner` — no `.unwrap()` / `.expect()` / `panic!`
//!   on CLI-reachable paths (`main.rs`, `tune/`): user errors surface as
//!   checked `anyhow` errors, not panics.
//! * `schema-version-literal` — every versioned schema string
//!   (`zo2-*-vN`) is spelled exactly once, in `util/schema.rs`; all other
//!   sites must route through those constants so readers and writers can
//!   never drift apart.
//!
//! # Waivers
//!
//! A violation is acknowledged — not silenced — with an inline waiver that
//! must argue *why* the site is sound:
//!
//! ```text
//! // zo2-lint: allow(no-wall-clock): step-duration telemetry only
//! ```
//!
//! covers findings of that rule on the comment's lines and the two lines
//! after it; `allow-file(<rule>): <reason>` covers the whole file.  A
//! waiver with an empty reason is ignored.  Waived findings stay in the
//! report (marked, with the reason) — the waiver ledger is part of the
//! audit, so `--json` consumers can diff it across revisions.

use super::lexer::{lex, Lexed, Tok};

pub const RULE_UNSAFE: &str = "unsafe-needs-safety-comment";
pub const RULE_DET_COLLECTIONS: &str = "deterministic-collections";
pub const RULE_WALL_CLOCK: &str = "no-wall-clock";
pub const RULE_PANIC: &str = "no-panic-in-cli-planner";
pub const RULE_SCHEMA: &str = "schema-version-literal";

/// Every rule the engine knows, in report order.
pub const RULES: &[&str] =
    &[RULE_DET_COLLECTIONS, RULE_PANIC, RULE_SCHEMA, RULE_UNSAFE, RULE_WALL_CLOCK];

/// Directories (relative to `src/`) whose collections must iterate in a
/// canonical order: their outputs land in plans, tuning reports, traces and
/// golden files.
const DETERMINISTIC_DIRS: &[&str] =
    &["costmodel/", "dp/", "sched/", "shard/", "telemetry/", "tune/"];

/// One rule violation at a source location.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
    /// `true` when an inline or file-level waiver acknowledges this site.
    pub waived: bool,
    /// The waiver's stated reason, when waived.
    pub waiver_reason: Option<String>,
}

/// One parsed waiver comment.
#[derive(Debug, Clone)]
pub struct Waiver {
    pub file: String,
    /// Line the waiver comment starts on.
    pub line: usize,
    /// Line the waiver comment ends on (inline waivers cover findings up to
    /// two lines below this).
    pub end_line: usize,
    pub rule: String,
    pub reason: String,
    /// `allow-file` covers the whole file for `rule`.
    pub file_level: bool,
}

/// One `unsafe` occurrence, for the audit inventory.
#[derive(Debug, Clone)]
pub struct UnsafeSite {
    pub file: String,
    pub line: usize,
    /// "unsafe block" / "unsafe fn" / "unsafe impl" / "unsafe trait".
    pub context: String,
    pub documented: bool,
}

/// Everything the engine extracted from one source file.
#[derive(Debug, Clone, Default)]
pub struct FileReport {
    pub findings: Vec<Finding>,
    pub waivers: Vec<Waiver>,
    pub unsafe_sites: Vec<UnsafeSite>,
}

impl FileReport {
    pub fn unwaived(&self) -> usize {
        self.findings.iter().filter(|f| !f.waived).count()
    }
}

/// Lint one source file.  `path` is the file's path relative to the source
/// root with `/` separators (e.g. `sched/mod.rs`) — rule scoping keys on it.
pub fn lint_source(path: &str, source: &str) -> FileReport {
    let lexed = lex(source);
    let ctx = FileCtx::new(&lexed);
    let mut rep = FileReport {
        waivers: parse_waivers(path, &lexed),
        ..FileReport::default()
    };
    rule_unsafe(path, &lexed, &ctx, &mut rep);
    rule_deterministic_collections(path, &lexed, &mut rep);
    rule_wall_clock(path, &lexed, &ctx, &mut rep);
    rule_panic(path, &lexed, &ctx, &mut rep);
    rule_schema_literal(path, &lexed, &ctx, &mut rep);
    apply_waivers(&mut rep);
    rep.findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    rep
}

/// Per-file precomputation shared by the rules.
struct FileCtx {
    /// Lines whose first token is `#` (attribute lines — skipped when
    /// walking upward looking for a safety comment).
    attr_lines: std::collections::BTreeSet<usize>,
    /// Line ranges (inclusive) of `#[cfg(test)]` items.
    test_ranges: Vec<(usize, usize)>,
}

impl FileCtx {
    fn new(lexed: &Lexed) -> Self {
        let mut first_on_line: std::collections::BTreeMap<usize, &Tok> =
            std::collections::BTreeMap::new();
        for t in &lexed.tokens {
            first_on_line.entry(t.line).or_insert(&t.tok);
        }
        let attr_lines = first_on_line
            .iter()
            .filter(|(_, tok)| matches!(tok, Tok::Punct('#')))
            .map(|(&l, _)| l)
            .collect();
        Self { attr_lines, test_ranges: test_ranges(lexed) }
    }

    /// Is `line` inside a `#[cfg(test)]` item?
    fn in_test(&self, line: usize) -> bool {
        self.test_ranges.iter().any(|&(s, e)| s <= line && line <= e)
    }
}

/// Line ranges of `#[cfg(test)]` items: the attribute, any further
/// attributes, then the brace-matched body (or the item up to `;`).
fn test_ranges(lexed: &Lexed) -> Vec<(usize, usize)> {
    let t = &lexed.tokens;
    let n = t.len();
    let is = |k: usize, want: char| {
        matches!(t.get(k).map(|x| &x.tok), Some(Tok::Punct(c)) if *c == want)
    };
    let is_ident = |k: usize, want: &str| {
        matches!(t.get(k).map(|x| &x.tok), Some(Tok::Ident(s)) if s == want)
    };
    let mut out = Vec::new();
    let mut k = 0usize;
    while k < n {
        let hit = is(k, '#')
            && is(k + 1, '[')
            && is_ident(k + 2, "cfg")
            && is(k + 3, '(')
            && is_ident(k + 4, "test")
            && is(k + 5, ')')
            && is(k + 6, ']');
        if !hit {
            k += 1;
            continue;
        }
        let start_line = t[k].line;
        let mut j = k + 7;
        // Skip any further attributes on the same item.
        while j < n && is(j, '#') && is(j + 1, '[') {
            let mut depth = 0usize;
            let mut m = j + 1;
            while m < n {
                match t[m].tok {
                    Tok::Punct('[') => depth += 1,
                    Tok::Punct(']') => {
                        depth -= 1;
                        if depth == 0 {
                            m += 1;
                            break;
                        }
                    }
                    _ => {}
                }
                m += 1;
            }
            j = m;
        }
        // The item body: brace-match the first `{`, or end at `;` for
        // braceless items (`#[cfg(test)] use ...;`).
        let mut end_line = start_line;
        let mut m = j;
        while m < n {
            match t[m].tok {
                Tok::Punct(';') => {
                    end_line = t[m].line;
                    m += 1;
                    break;
                }
                Tok::Punct('{') => {
                    let mut depth = 0usize;
                    while m < n {
                        match t[m].tok {
                            Tok::Punct('{') => depth += 1,
                            Tok::Punct('}') => {
                                depth -= 1;
                                if depth == 0 {
                                    end_line = t[m].line;
                                    break;
                                }
                            }
                            _ => {}
                        }
                        m += 1;
                    }
                    m += 1;
                    break;
                }
                _ => m += 1,
            }
        }
        out.push((start_line, end_line));
        k = m.max(k + 1);
    }
    out
}

/// Parse every waiver comment of the file.
fn parse_waivers(path: &str, lexed: &Lexed) -> Vec<Waiver> {
    let mut out = Vec::new();
    for c in &lexed.comments {
        let Some((rule, reason, file_level)) = parse_waiver_text(&c.text) else { continue };
        out.push(Waiver {
            file: path.to_string(),
            line: c.start_line,
            end_line: c.end_line,
            rule,
            reason,
            file_level,
        });
    }
    out
}

/// `zo2-lint: allow(<rule>): <reason>` / `zo2-lint: allow-file(<rule>):
/// <reason>` anywhere inside a comment.  Returns `None` (waiver ignored)
/// when the rule or the reason is empty — a waiver must argue its case.
fn parse_waiver_text(text: &str) -> Option<(String, String, bool)> {
    let pos = text.find("zo2-lint:")?;
    let rest = text[pos + "zo2-lint:".len()..].trim_start();
    let (file_level, rest) = if let Some(r) = rest.strip_prefix("allow-file(") {
        (true, r)
    } else if let Some(r) = rest.strip_prefix("allow(") {
        (false, r)
    } else {
        return None;
    };
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    let after = rest[close + 1..].trim_start();
    let reason_raw = after.strip_prefix(':')?;
    let reason = reason_raw.trim().trim_end_matches("*/").trim().to_string();
    if rule.is_empty() || reason.is_empty() {
        return None;
    }
    Some((rule, reason, file_level))
}

/// Mark findings covered by a waiver: file-level waivers cover the whole
/// file for their rule; inline waivers cover the comment's own lines plus
/// the two lines after (comment directly above the site, or trailing on the
/// same line).
fn apply_waivers(rep: &mut FileReport) {
    for f in &mut rep.findings {
        for w in &rep.waivers {
            if w.rule != f.rule {
                continue;
            }
            let hit = w.file_level || (f.line >= w.line && f.line <= w.end_line + 2);
            if hit {
                f.waived = true;
                f.waiver_reason = Some(w.reason.clone());
                break;
            }
        }
    }
}

fn push(rep: &mut FileReport, rule: &'static str, path: &str, line: usize, message: String) {
    rep.findings.push(Finding {
        rule,
        file: path.to_string(),
        line,
        message,
        waived: false,
        waiver_reason: None,
    });
}

fn has_safety_word(text: &str) -> bool {
    text.to_lowercase().contains("safety")
}

/// `unsafe-needs-safety-comment` + the unsafe inventory.
fn rule_unsafe(path: &str, lexed: &Lexed, ctx: &FileCtx, rep: &mut FileReport) {
    for (k, t) in lexed.tokens.iter().enumerate() {
        let Tok::Ident(name) = &t.tok else { continue };
        if name != "unsafe" {
            continue;
        }
        let context = match lexed.tokens.get(k + 1).map(|n| &n.tok) {
            Some(Tok::Ident(s)) if s == "fn" => "unsafe fn",
            Some(Tok::Ident(s)) if s == "impl" => "unsafe impl",
            Some(Tok::Ident(s)) if s == "trait" => "unsafe trait",
            Some(Tok::Punct('{')) => "unsafe block",
            _ => "unsafe",
        };
        let documented = unsafe_documented(lexed, ctx, t.line);
        rep.unsafe_sites.push(UnsafeSite {
            file: path.to_string(),
            line: t.line,
            context: context.to_string(),
            documented,
        });
        if !documented {
            push(
                rep,
                RULE_UNSAFE,
                path,
                t.line,
                format!("{context} without a safety comment (`// SAFETY: ...` or `# Safety`)"),
            );
        }
    }
}

/// A site is documented if a comment mentioning "safety" sits on its line
/// or in the contiguous comment run above it (attribute lines skipped).
fn unsafe_documented(lexed: &Lexed, ctx: &FileCtx, line: usize) -> bool {
    if lexed.comments_covering(line).any(|c| has_safety_word(&c.text)) {
        return true;
    }
    let mut l = line.saturating_sub(1);
    while l >= 1 {
        if ctx.attr_lines.contains(&l) {
            l -= 1;
            continue;
        }
        if let Some(c) = lexed.comments.iter().find(|c| c.start_line <= l && l <= c.end_line) {
            if has_safety_word(&c.text) {
                return true;
            }
            if c.start_line == 0 || c.start_line == 1 {
                return false;
            }
            l = c.start_line - 1;
            continue;
        }
        // Code or blank line: the comment run (if any) ended.
        return false;
    }
    false
}

/// `deterministic-collections`.
fn rule_deterministic_collections(path: &str, lexed: &Lexed, rep: &mut FileReport) {
    if !DETERMINISTIC_DIRS.iter().any(|d| path.starts_with(d)) {
        return;
    }
    for t in &lexed.tokens {
        let Tok::Ident(name) = &t.tok else { continue };
        if name == "HashMap" || name == "HashSet" {
            push(
                rep,
                RULE_DET_COLLECTIONS,
                path,
                t.line,
                format!("{name} in a determinism-critical module; use the BTree equivalent"),
            );
        }
    }
}

/// `no-wall-clock`: `Instant::now` / `SystemTime::now` outside `clock/`.
fn rule_wall_clock(path: &str, lexed: &Lexed, ctx: &FileCtx, rep: &mut FileReport) {
    if path.starts_with("clock/") {
        return;
    }
    let toks = &lexed.tokens;
    for k in 0..toks.len() {
        let Tok::Ident(name) = &toks[k].tok else { continue };
        if name != "Instant" && name != "SystemTime" {
            continue;
        }
        let call = matches!(toks.get(k + 1).map(|t| &t.tok), Some(Tok::Punct(':')))
            && matches!(toks.get(k + 2).map(|t| &t.tok), Some(Tok::Punct(':')))
            && matches!(toks.get(k + 3).map(|t| &t.tok), Some(Tok::Ident(s)) if s == "now");
        if call && !ctx.in_test(toks[k].line) {
            push(
                rep,
                RULE_WALL_CLOCK,
                path,
                toks[k].line,
                format!("{name}::now outside clock/ (wall-clock nondeterminism)"),
            );
        }
    }
}

/// `no-panic-in-cli-planner`: `.unwrap()` / `.expect()` / `panic!` on
/// CLI-reachable paths.
fn rule_panic(path: &str, lexed: &Lexed, ctx: &FileCtx, rep: &mut FileReport) {
    let in_scope = path == "main.rs" || path.starts_with("tune/");
    if !in_scope {
        return;
    }
    let toks = &lexed.tokens;
    for k in 0..toks.len() {
        let Tok::Ident(name) = &toks[k].tok else { continue };
        if ctx.in_test(toks[k].line) {
            continue;
        }
        let dotted = k > 0 && matches!(&toks[k - 1].tok, Tok::Punct('.'));
        if dotted && (name == "unwrap" || name == "expect") {
            push(
                rep,
                RULE_PANIC,
                path,
                toks[k].line,
                format!(".{name}() on a CLI-reachable path; return a checked error instead"),
            );
        }
        if name == "panic" && matches!(toks.get(k + 1).map(|t| &t.tok), Some(Tok::Punct('!'))) {
            push(
                rep,
                RULE_PANIC,
                path,
                toks[k].line,
                "panic! on a CLI-reachable path; return a checked error instead".to_string(),
            );
        }
    }
}

/// `schema-version-literal`: versioned `zo2-*-vN` strings outside
/// `util/schema.rs`.
fn rule_schema_literal(path: &str, lexed: &Lexed, ctx: &FileCtx, rep: &mut FileReport) {
    if path == "util/schema.rs" {
        return;
    }
    for t in &lexed.tokens {
        let Tok::Str(s) = &t.tok else { continue };
        if ctx.in_test(t.line) {
            continue;
        }
        if let Some(lit) = find_schema_literal(s) {
            push(
                rep,
                RULE_SCHEMA,
                path,
                t.line,
                format!("schema literal \"{lit}\" inline; use the util::schema constant"),
            );
        }
    }
}

/// First `zo2-...-vN` schema-version literal embedded in `s`, if any.
fn find_schema_literal(s: &str) -> Option<String> {
    let mut start = 0usize;
    while let Some(off) = s[start..].find("zo2-") {
        let p = start + off;
        let run: String = s[p..]
            .chars()
            .take_while(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == '-')
            .collect();
        if let Some(vpos) = run.rfind("-v") {
            let tail = &run[vpos + 2..];
            if !tail.is_empty() && tail.bytes().all(|b| b.is_ascii_digit()) {
                return Some(run);
            }
        }
        start = p + 4;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unwaived_rules(rep: &FileReport) -> Vec<&'static str> {
        rep.findings.iter().filter(|f| !f.waived).map(|f| f.rule).collect()
    }

    #[test]
    fn undocumented_unsafe_fires_and_safety_comment_clears() {
        let bad = "fn f() {\n    let x = unsafe { g() };\n}\n";
        let rep = lint_source("memory/x.rs", bad);
        assert_eq!(unwaived_rules(&rep), vec![RULE_UNSAFE]);
        assert_eq!(rep.unsafe_sites.len(), 1);
        assert!(!rep.unsafe_sites[0].documented);
        assert_eq!(rep.unsafe_sites[0].context, "unsafe block");

        let good = "fn f() {\n    // SAFETY: g touches only its own buffer.\n    \
                    let x = unsafe { g() };\n}\n";
        let rep = lint_source("memory/x.rs", good);
        assert!(rep.findings.is_empty());
        assert!(rep.unsafe_sites[0].documented);
    }

    #[test]
    fn safety_comment_skips_attribute_lines_and_doc_sections() {
        let src = "\
/// Does vector things.
// SAFETY: register-only; callers carry the target feature.
#[inline]
#[target_feature(enable = \"avx2\")]
unsafe fn v() {}
";
        let rep = lint_source("simd/x.rs", src);
        assert!(rep.findings.is_empty(), "{:?}", rep.findings);
        assert_eq!(rep.unsafe_sites[0].context, "unsafe fn");

        let doc = "\
/// Fills the buffer.
///
/// # Safety
/// Caller guarantees `out` is 8-aligned.
pub unsafe fn fill() {}
";
        let rep = lint_source("simd/x.rs", doc);
        assert!(rep.findings.is_empty(), "{:?}", rep.findings);
    }

    #[test]
    fn hashmap_fires_only_in_deterministic_dirs() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(unwaived_rules(&lint_source("sched/x.rs", src)), vec![RULE_DET_COLLECTIONS]);
        assert_eq!(unwaived_rules(&lint_source("dp/x.rs", src)), vec![RULE_DET_COLLECTIONS]);
        assert!(lint_source("memory/x.rs", src).findings.is_empty());
        // Mentions in comments and strings don't count.
        let quoted = "// HashMap is banned here\nconst S: &str = \"HashMap\";\n";
        assert!(lint_source("sched/x.rs", quoted).findings.is_empty());
    }

    #[test]
    fn wall_clock_fires_outside_clock_and_respects_waivers() {
        let src = "fn f() { let t = std::time::Instant::now(); }\n";
        assert_eq!(unwaived_rules(&lint_source("zo/x.rs", src)), vec![RULE_WALL_CLOCK]);
        assert!(lint_source("clock/mod.rs", src).findings.is_empty());
        // An Instant in type position is not a wall-clock read.
        let ty = "struct S { t0: std::time::Instant }\n";
        assert!(lint_source("zo/x.rs", ty).findings.is_empty());

        let waived = "\
fn f() {
    // zo2-lint: allow(no-wall-clock): duration telemetry only
    let t = std::time::Instant::now();
}
";
        let rep = lint_source("zo/x.rs", waived);
        assert_eq!(rep.findings.len(), 1);
        assert!(rep.findings[0].waived);
        assert_eq!(rep.findings[0].waiver_reason.as_deref(), Some("duration telemetry only"));
        assert_eq!(rep.unwaived(), 0);
    }

    #[test]
    fn file_level_waiver_covers_everything_and_empty_reason_is_ignored() {
        let src = "\
// zo2-lint: allow-file(no-wall-clock): deadline timers never feed results
fn f() { let a = std::time::Instant::now(); }
fn g() { let b = std::time::Instant::now(); }
";
        let rep = lint_source("dp/x.rs", src);
        assert_eq!(rep.findings.len(), 2);
        assert!(rep.findings.iter().all(|f| f.waived));

        let empty = "\
// zo2-lint: allow(no-wall-clock):
fn f() { let a = std::time::Instant::now(); }
";
        let rep = lint_source("zo/x.rs", empty);
        assert_eq!(rep.unwaived(), 1, "empty-reason waiver must not count");
        assert!(rep.waivers.is_empty());
    }

    #[test]
    fn panic_rule_scopes_to_cli_paths_and_skips_tests() {
        let src = "fn f(v: Option<u32>) -> u32 { v.unwrap() }\n";
        assert_eq!(unwaived_rules(&lint_source("main.rs", src)), vec![RULE_PANIC]);
        assert_eq!(unwaived_rules(&lint_source("tune/mod.rs", src)), vec![RULE_PANIC]);
        assert!(lint_source("zo/x.rs", src).findings.is_empty());

        let kinds = "fn f() { x.expect(\"boom\"); panic!(\"no\"); }\n";
        let rep = lint_source("main.rs", kinds);
        assert_eq!(rep.findings.len(), 2);

        let tested = "\
fn ok() -> u32 { 1 }
#[cfg(test)]
mod tests {
    #[test]
    fn t() { Some(1).unwrap(); panic!(\"fine in tests\"); }
}
";
        assert!(lint_source("main.rs", tested).findings.is_empty());
    }

    #[test]
    fn schema_literal_fires_outside_schema_rs() {
        let src = "const S: &str = \"zo2-tune-v1\";\n";
        assert_eq!(unwaived_rules(&lint_source("tune/mod.rs", src)), vec![RULE_SCHEMA]);
        assert!(lint_source("util/schema.rs", src).findings.is_empty());
        // Embedded in a larger string still fires; non-versioned zo2-
        // strings (like the waiver marker itself) do not.
        assert_eq!(
            unwaived_rules(&lint_source("x.rs", "let s = \"schema is zo2-trace-v2 here\";\n")),
            vec![RULE_SCHEMA]
        );
        assert!(lint_source("x.rs", "let s = \"zo2-lint: allow(x): y\";\n").findings.is_empty());
        assert!(lint_source("x.rs", "let s = \"zo2-tune\";\n").findings.is_empty());
    }

    #[test]
    fn findings_sort_by_line_then_rule() {
        let src = "\
use std::collections::HashMap;
fn f() { let t = std::time::Instant::now(); }
";
        let rep = lint_source("sched/x.rs", src);
        let lines: Vec<usize> = rep.findings.iter().map(|f| f.line).collect();
        assert_eq!(lines, vec![1, 2]);
    }

    #[test]
    fn cfg_test_region_detection_handles_nested_braces() {
        let src = "\
fn live() { let t = std::time::Instant::now(); }
#[cfg(test)]
mod tests {
    fn helper() { if true { { } } }
    #[test]
    fn t() { let t = std::time::Instant::now(); }
}
fn live2() { let t = std::time::Instant::now(); }
";
        let rep = lint_source("zo/x.rs", src);
        let lines: Vec<usize> = rep.findings.iter().map(|f| f.line).collect();
        assert_eq!(lines, vec![1, 8], "test-region clock reads must be exempt");
    }
}
