//! `zo2 lint` — the repo-native static-analysis pass.
//!
//! This crate's correctness story rests on contracts that rustc cannot
//! check: schedules and reports must be byte-deterministic (golden-file
//! freezes diff them), wall-clock time must never leak into a committed
//! trajectory, CLI-reachable paths must fail with checked errors, every
//! `unsafe` must carry its safety argument, and schema version strings
//! must have exactly one spelling.  `zo2 lint` machine-checks all of them:
//!
//! * a hand-rolled lexer ([`lexer`]) tokenises each source file
//!   (comment-, string- and raw-string-aware — no external parser);
//! * a rule engine ([`rules`]) walks the token stream with five rules and
//!   an inline-waiver protocol (`// zo2-lint: allow(<rule>): <reason>`);
//! * a semantic pass ([`crate::sched::validate_plan`]) re-checks built
//!   scheduling DAGs against the dependency contract — run on every plan
//!   in debug builds, and swept over a policy grid by `zo2 lint --plans`.
//!
//! The report serialises as deterministic `zo2-lint-v1` JSON (sorted keys,
//! sorted findings), so two runs over the same tree are byte-identical and
//! CI can archive and diff them.  The CLI gate exits nonzero on any
//! unwaived finding.

pub mod lexer;
pub mod rules;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

pub use crate::util::schema::LINT_SCHEMA;
pub use rules::{lint_source, FileReport, Finding, UnsafeSite, Waiver, RULES};

/// Result of the `--plans` semantic sweep: how many built plans were
/// checked against [`crate::sched::validate_plan`], and every violation.
#[derive(Debug, Clone, Default)]
pub struct PlanSummary {
    pub checked: usize,
    pub violations: Vec<String>,
}

/// Aggregated lint results over a source tree.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
    pub waivers: Vec<Waiver>,
    pub unsafe_sites: Vec<UnsafeSite>,
    pub plans: Option<PlanSummary>,
}

impl LintReport {
    /// Findings not covered by a waiver — the gate count.
    pub fn unwaived(&self) -> usize {
        self.findings.iter().filter(|f| !f.waived).count()
    }

    /// Unsafe sites still missing a safety comment.
    pub fn undocumented_unsafe(&self) -> usize {
        self.unsafe_sites.iter().filter(|s| !s.documented).count()
    }

    /// Plan violations found by the `--plans` sweep (0 when not run).
    pub fn plan_violations(&self) -> usize {
        self.plans.as_ref().map_or(0, |p| p.violations.len())
    }

    /// The deterministic `zo2-lint-v1` report document.
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("schema".to_string(), Json::Str(LINT_SCHEMA.to_string()));
        root.insert("files_scanned".to_string(), Json::Num(self.files_scanned as f64));

        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                let mut m = BTreeMap::new();
                m.insert("rule".to_string(), Json::Str(f.rule.to_string()));
                m.insert("file".to_string(), Json::Str(f.file.clone()));
                m.insert("line".to_string(), Json::Num(f.line as f64));
                m.insert("message".to_string(), Json::Str(f.message.clone()));
                m.insert("waived".to_string(), Json::Bool(f.waived));
                if let Some(r) = &f.waiver_reason {
                    m.insert("waiver_reason".to_string(), Json::Str(r.clone()));
                }
                Json::Obj(m)
            })
            .collect();
        root.insert("findings".to_string(), Json::Arr(findings));

        let waivers: Vec<Json> = self
            .waivers
            .iter()
            .map(|w| {
                let mut m = BTreeMap::new();
                m.insert("file".to_string(), Json::Str(w.file.clone()));
                m.insert("line".to_string(), Json::Num(w.line as f64));
                m.insert("rule".to_string(), Json::Str(w.rule.clone()));
                m.insert("reason".to_string(), Json::Str(w.reason.clone()));
                m.insert("file_level".to_string(), Json::Bool(w.file_level));
                Json::Obj(m)
            })
            .collect();
        root.insert("waivers".to_string(), Json::Arr(waivers));

        let inventory: Vec<Json> = self
            .unsafe_sites
            .iter()
            .map(|s| {
                let mut m = BTreeMap::new();
                m.insert("file".to_string(), Json::Str(s.file.clone()));
                m.insert("line".to_string(), Json::Num(s.line as f64));
                m.insert("context".to_string(), Json::Str(s.context.clone()));
                m.insert("documented".to_string(), Json::Bool(s.documented));
                Json::Obj(m)
            })
            .collect();
        root.insert("unsafe_inventory".to_string(), Json::Arr(inventory));

        if let Some(p) = &self.plans {
            let mut m = BTreeMap::new();
            m.insert("checked".to_string(), Json::Num(p.checked as f64));
            m.insert(
                "violations".to_string(),
                Json::Arr(p.violations.iter().map(|v| Json::Str(v.clone())).collect()),
            );
            root.insert("plans".to_string(), Json::Obj(m));
        }

        let mut summary = BTreeMap::new();
        summary.insert("findings".to_string(), Json::Num(self.findings.len() as f64));
        summary.insert("unwaived".to_string(), Json::Num(self.unwaived() as f64));
        summary.insert("waivers".to_string(), Json::Num(self.waivers.len() as f64));
        summary.insert("unsafe_sites".to_string(), Json::Num(self.unsafe_sites.len() as f64));
        summary.insert(
            "undocumented_unsafe".to_string(),
            Json::Num(self.undocumented_unsafe() as f64),
        );
        summary.insert("plan_violations".to_string(), Json::Num(self.plan_violations() as f64));
        root.insert("summary".to_string(), Json::Obj(summary));

        Json::Obj(root)
    }

    /// Pretty-printed report (what `--json` writes) — deterministic: keys
    /// are BTreeMap-ordered and every list is sorted.
    pub fn render(&self) -> String {
        self.to_json().to_string_pretty()
    }
}

/// Lint every `.rs` file under `src_root` (recursive, sorted walk).
pub fn run_lint(src_root: &Path) -> Result<LintReport> {
    let mut files: Vec<(String, PathBuf)> = Vec::new();
    collect_rs(src_root, src_root, &mut files)
        .with_context(|| format!("scanning {}", src_root.display()))?;
    files.sort();
    let mut rep = LintReport { files_scanned: files.len(), ..LintReport::default() };
    for (label, path) in &files {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let fr = rules::lint_source(label, &text);
        rep.findings.extend(fr.findings);
        rep.waivers.extend(fr.waivers);
        rep.unsafe_sites.extend(fr.unsafe_sites);
    }
    rep.findings
        .sort_by(|a, b| (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule)));
    rep.waivers.sort_by(|a, b| (a.file.as_str(), a.line).cmp(&(b.file.as_str(), b.line)));
    rep.unsafe_sites.sort_by(|a, b| (a.file.as_str(), a.line).cmp(&(b.file.as_str(), b.line)));
    Ok(rep)
}

fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<(String, PathBuf)>) -> Result<()> {
    let mut entries: Vec<std::fs::DirEntry> = std::fs::read_dir(dir)
        .with_context(|| format!("reading dir {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(root, &p, out)?;
        } else if p.extension().and_then(|s| s.to_str()) == Some("rs") {
            let rel = p
                .strip_prefix(root)
                .unwrap_or(p.as_path())
                .to_string_lossy()
                .replace('\\', "/");
            out.push((rel, p));
        }
    }
    Ok(())
}

/// The `--plans` semantic sweep: build the scheduling DAG for a grid of
/// policies × shard specs (ablations, tiering, spill placements, slot and
/// window depths, microbatching, per-partition tiers, weighted owners) and
/// check every one against [`crate::sched::validate_plan`].
pub fn lint_plans() -> PlanSummary {
    use crate::sched::{validate_plan, Policy, SpillPlacement, Task};
    use crate::shard::{
        build_sharded_plan, build_sharded_plan_tiered, weighted_contiguous_owners, DeviceTier,
        ShardLayout, ShardSpec,
    };

    let n_blocks = 8usize;
    let steps = 2usize;
    let mut checked = 0usize;
    let mut violations: Vec<String> = Vec::new();
    let mut check = |name: String, tasks: &[Task], policy: &Policy, dram: Option<&[usize]>| {
        checked += 1;
        if let Err(errs) = validate_plan(tasks, policy, dram) {
            for e in errs.into_iter().take(8) {
                violations.push(format!("{name}: {e}"));
            }
        }
    };

    let policies = [
        Policy::default(),
        Policy::naive(),
        Policy { reusable_mem: false, ..Policy::default() },
        Policy { efficient_update: false, ..Policy::default() },
        Policy { slots: 1, ..Policy::default() },
        Policy { slots: 2, ..Policy::default() },
        Policy::three_tier(3, 2),
        Policy::three_tier(n_blocks, 1),
        Policy { spill_placement: SpillPlacement::Interleaved, ..Policy::three_tier(4, 2) },
        Policy { overlap: false, ..Policy::three_tier(5, 3) },
        Policy { efficient_update: false, ..Policy::three_tier(4, 2) },
    ];
    let specs = [
        ShardSpec::single(),
        ShardSpec::pipeline(2, ShardLayout::Contiguous),
        ShardSpec::pipeline(4, ShardLayout::Cyclic),
        ShardSpec::pipeline_microbatched(2, ShardLayout::Contiguous, 4),
        ShardSpec::pipeline_microbatched(4, ShardLayout::Cyclic, 3),
        ShardSpec::data_parallel(2),
        ShardSpec::data_parallel(4),
    ];
    for (pi, policy) in policies.iter().enumerate() {
        for spec in &specs {
            let tasks = build_sharded_plan(n_blocks, steps, *policy, spec);
            let name = format!(
                "policy{pi}/{}x{}m{}",
                spec.strategy.name(),
                spec.devices,
                spec.microbatches
            );
            check(name, &tasks, policy, None);
        }
    }

    // Per-partition tiers: each pipeline device spills through its own
    // DRAM window depth.
    let policy = Policy::three_tier(0, 4);
    let spec = ShardSpec::pipeline(2, ShardLayout::Contiguous);
    let tiers =
        [DeviceTier { spilled: 3, dram_slots: 1 }, DeviceTier { spilled: 2, dram_slots: 3 }];
    let tasks =
        build_sharded_plan_tiered(n_blocks, steps, policy, &spec, Some(tiers.as_slice()), None);
    let dram: Vec<usize> = tiers.iter().map(|t| t.dram_slots).collect();
    check("tiered/pipelinex2".to_string(), &tasks, &policy, Some(dram.as_slice()));

    // Weighted (bottleneck-aware) owner map.
    let owners = weighted_contiguous_owners(n_blocks, &[2.0, 1.0]);
    let wpolicy = Policy::default();
    let tasks =
        build_sharded_plan_tiered(n_blocks, steps, wpolicy, &spec, None, Some(owners.as_slice()));
    check("weighted/pipelinex2".to_string(), &tasks, &wpolicy, None);

    PlanSummary { checked, violations }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_sweep_is_clean() {
        let p = lint_plans();
        assert!(p.checked >= 70, "grid shrank to {}", p.checked);
        assert!(p.violations.is_empty(), "{:?}", p.violations);
    }

    #[test]
    fn report_rendering_is_deterministic() {
        let mut rep = LintReport::default();
        let fr = rules::lint_source(
            "zo/x.rs",
            "fn f() { let t = std::time::Instant::now(); }\n",
        );
        rep.files_scanned = 1;
        rep.findings.extend(fr.findings);
        rep.waivers.extend(fr.waivers);
        rep.unsafe_sites.extend(fr.unsafe_sites);
        let a = rep.render();
        let b = rep.clone().render();
        assert_eq!(a, b);
        let parsed = Json::parse(&a).expect("report must parse");
        assert_eq!(parsed.get("schema").unwrap().as_str().unwrap(), LINT_SCHEMA);
        assert_eq!(
            parsed.get("summary").unwrap().get("unwaived").unwrap().as_usize().unwrap(),
            1
        );
    }
}
