//! # ZO2 — Zeroth-Order Offloading for extremely large LLM fine-tuning
//!
//! Rust coordinator (L3) of the three-layer reproduction of
//! *ZO2: Scalable Zeroth-Order Fine-Tuning for Extremely Large Language
//! Models with Limited GPU Memory* (Wang et al., 2025).
//!
//! The compute graph (L2, JAX) and its hot-spot kernels (L1, Pallas) are
//! AOT-lowered once by `make artifacts` into per-module HLO-text
//! executables; this crate loads them through the PJRT C API (`xla` crate)
//! and drives the paper's system around them:
//!
//! * [`rng`] — counter-based Gaussian streams + the RNG state manager
//!   (paper §5.1, Algorithm 2) that makes block-disaggregated ZO training
//!   bit-identical to monolithic MeZO.
//! * [`memory`] — the tiered memory substrate: host "DDR" and device "HBM"
//!   pools, communication buckets, the reusable block buffer (§5.3), the
//!   transfer engine, and the disk tier ([`memory::disk`]) — file-backed
//!   NVMe buckets below DDR with an accounted DRAM staging window.
//! * [`sched`] — the dynamic scheduler (§5.2, Algorithm 3) with
//!   device-indexed streams ([`sched::StreamId`]): three streams per device
//!   in two-tier mode, five (± DiskRead/DiskWrite) in three-tier mode, an
//!   Interconnect stream for device-to-device traffic, the naive
//!   global-sync counterpart (ablation), and a discrete-event simulator
//!   sharing one dependency-rule core.
//! * [`shard`] — simulated multi-GPU sharding on top of the device-indexed
//!   scheduler: block-contiguous / block-cyclic pipeline partitions with
//!   intra-step microbatching ([`shard::ShardSpec::microbatches`]) and
//!   per-partition three-tier spill sets, plus seed-synchronous
//!   data-parallel ZO (one seed broadcast + one scalar all-reduce per
//!   step).
//! * [`precision`] — bf16 / fp16 / fp8(e4m3) transfer codecs (AMP, §5.5)
//!   with table-driven hot paths and chunk-range entry points; the disk
//!   tier stores spilled buckets in the same wire format.
//! * [`hostpool`] — the persistent host compute pool: cache-blocked chunk
//!   kernels over encoded buckets, including fused
//!   decode→ZO-update→encode passes that never materialise a full-bucket
//!   fp32 intermediate; bit-identical at any thread count, with opt-in
//!   NUMA-aware worker pinning (`--host-pin`).
//! * [`simd`] — runtime-dispatched AVX2 host kernels (`--host-simd`):
//!   vectorised codec, Gaussian-fill and ZO-update loops, each
//!   bit-identical to its scalar reference.
//! * [`zo`] — ZO-SGD math, the MeZO baseline engine (Algorithm 1) and the
//!   ZO2 engine (Algorithms 2 + 3, deferred updates §5.4) with
//!   [`sched::Tiering`] selecting two- or three-tier parameter placement
//!   (bit-identical trajectories either way).
//! * [`baselines`] — first-order (SGD / AdamW) offloading cost + memory
//!   models for Figure 1 / §4.1 comparisons.
//! * [`costmodel`] — analytic compute/transfer cost model + calibration
//!   used by the discrete-event simulator for paper-scale (OPT-175B) runs,
//!   including NVMe bandwidths, the [`costmodel::MemoryBudget`] /
//!   [`costmodel::plan_three_tier`] tier placement (per-pipeline-partition
//!   variants: [`costmodel::plan_three_tier_partitioned`] /
//!   [`costmodel::plan_three_tier_owned`]), and heterogeneous
//!   [`costmodel::Cluster`]s — mixed per-device [`costmodel::Hardware`]
//!   and per-device links, priced per device by
//!   [`costmodel::ClusterCost`].
//! * [`dp`] — the elastic fault-tolerant data-parallel backend: the
//!   seed+scalar wire protocol over in-process channels or Unix/TCP
//!   sockets, deterministic fault injection, a supervising coordinator
//!   with heartbeat-based membership and shard reassignment, and
//!   `DiskPool`-backed checkpoint/restore — all bit-identical to the
//!   fault-free single-worker trajectory.
//! * [`tune`] — the simulator-driven autotuner (`zo2 tune`): deterministic
//!   beam search with a seeded annealing fallback over the policy knobs,
//!   the tier planners as hard feasibility constraints, steady-state step
//!   time as the objective, and a replayable `zo2-tune-v1` report.
//! * [`runtime`] — PJRT client, artifact manifests, executable cache.
//! * [`coordinator`] — the trainer: data, train/eval loops, metrics.
//! * [`analysis`] — `zo2 lint`: the repo-native static-analysis pass that
//!   machine-checks the determinism, panic-freedom, unsafe-audit and
//!   schema-literal contracts (five token-level rules with an inline
//!   waiver protocol) and re-validates built scheduling DAGs against the
//!   dependency rules ([`sched::validate_plan`], `--plans`).

pub mod analysis;
pub mod baselines;
pub mod clock;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod dp;
pub mod hostpool;
pub mod memory;
pub mod model;
pub mod precision;
pub mod rng;
pub mod runtime;
pub mod sched;
pub mod shard;
pub mod simd;
pub mod telemetry;
pub mod tune;
pub mod util;
pub mod zo;

/// Locate the artifacts directory: `$ZO2_ARTIFACTS` or `<repo>/artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("ZO2_ARTIFACTS") {
        return p.into();
    }
    let base = std::env::var("CARGO_MANIFEST_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("."));
    base.join("artifacts")
}

/// Whether the AOT artifacts for `config` exist (i.e. `make artifacts` ran,
/// or `$ZO2_ARTIFACTS` points at a bundle).  Tests that execute real PJRT
/// steps skip — with a message — when this is false, so `cargo test` stays
/// green on machines that only build the rust layer.
pub fn artifacts_available(config: &str) -> bool {
    artifacts_dir().join(config).join("manifest.json").is_file()
}
