//! Offline stub of the `xla` PJRT bindings.
//!
//! The real backend (the `xla` crate over xla_extension / PJRT) is not
//! available in the offline build environment, and the AOT artifacts it
//! would execute are produced separately by `make artifacts`.  This stub
//! keeps the whole workspace compiling and unit-testable without either:
//!
//! * [`Literal`] is a *real* host-side tensor container — creation,
//!   scalar wrapping and `to_vec` round-trips work exactly;
//! * everything that needs an actual PJRT runtime ([`HloModuleProto`]
//!   parsing, [`PjRtClient::compile`], execution) returns a clear error.
//!
//! Swap this path dependency for the real `xla` crate (and run
//! `make artifacts`) to execute models; no caller code changes.

use std::fmt;

/// Error type mirroring the real bindings' debug-printable errors.
#[derive(Clone)]
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str = "xla stub: PJRT backend not available in this build \
                        (swap rust/vendor/xla-stub for the real `xla` crate \
                        and run `make artifacts` to execute models)";

fn stub_err<T>() -> Result<T> {
    Err(Error(STUB_MSG.to_string()))
}

/// Element dtypes used by this workspace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
}

impl ElementType {
    pub fn byte_width(self) -> usize {
        4
    }
}

/// Plain-old-data element that can be read back out of a [`Literal`].
pub trait NativeType: Sized + Copy {
    const TY: ElementType;
    fn from_le(chunk: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(chunk: [u8; 4]) -> Self {
        f32::from_le_bytes(chunk)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(chunk: [u8; 4]) -> Self {
        i32::from_le_bytes(chunk)
    }
}

impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
    fn from_le(chunk: [u8; 4]) -> Self {
        u32::from_le_bytes(chunk)
    }
}

/// Host-side tensor value (dtype + dims + little-endian payload).
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = dims.iter().product();
        if numel * ty.byte_width() != data.len() {
            return Err(Error(format!(
                "literal shape {:?} ({numel} elements) vs {} payload bytes",
                dims,
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec() })
    }

    pub fn scalar(x: f32) -> Literal {
        Literal { ty: ElementType::F32, dims: Vec::new(), bytes: x.to_le_bytes().to_vec() }
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error(format!("literal is {:?}, asked for {:?}", self.ty, T::TY)));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Decompose a tuple literal.  Stub literals are never tuples (they only
    /// come from [`Literal::create_from_shape_and_untyped_data`]), and stub
    /// execution never produces one, so this is unreachable in practice.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        stub_err()
    }
}

/// Parsed HLO module handle (opaque; parsing requires the real backend).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub_err()
    }
}

/// Computation wrapper around a parsed HLO module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-side buffer produced by an execution.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err()
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err()
    }
}

/// PJRT client.  Construction succeeds (so manifests can be inspected and
/// artifact-less code paths exercised); compilation is where the stub stops.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let data: Vec<f32> = vec![1.5, -2.0, 0.25];
        let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert!(lit.to_vec::<i32>().is_err(), "dtype mismatch must error");
        let s = Literal::scalar(4.25);
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![4.25]);
        assert_eq!(s.dims(), &[] as &[usize]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4])
            .is_err());
    }

    #[test]
    fn runtime_paths_error_clearly() {
        let client = PjRtClient::cpu().unwrap();
        let err = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(format!("{err:?}").contains("stub"));
        let comp = XlaComputation::from_proto(&HloModuleProto(()));
        assert!(client.compile(&comp).is_err());
    }
}
