//! Offline vendored subset of the `anyhow` API.
//!
//! The build environment for this repository is fully offline, so the crate
//! graph must be self-contained.  This is a drop-in implementation of the
//! slice of `anyhow` the workspace actually uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] / [`ensure!`] macros and the [`Context`]
//! extension trait.  Errors are a single formatted message with an optional
//! chain of context strings — no backtraces, no downcasting.

use std::fmt;

/// A string-backed error value, layered with context messages.
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string(), context: Vec::new() }
    }

    fn push_context(mut self, c: String) -> Self {
        self.context.push(c);
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Outermost context first, root cause last (anyhow's ordering).
        for c in self.context.iter().rev() {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

/// Errors from std (and any other `std::error::Error`) convert via `?`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`; the error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).push_context(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).push_context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root cause {}", 42);
    }

    #[test]
    fn display_chains_context() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: root cause 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io().is_err());
    }

    #[test]
    fn ensure_and_option_context() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(check(1).is_ok());
        assert!(check(-1).is_err());
        let v: Option<i32> = None;
        assert!(v.with_context(|| "missing").is_err());
    }
}
