//! DP sim-shard acceptance on the real engine (needs `make artifacts`).
//!
//! K seed-synchronous `Zo2Engine` replicas over a fixed shard set must
//! reproduce the single-worker trajectory bit-for-bit: same per-step dual
//! losses, same final parameters.  This is the engine half of the
//! "no accuracy loss" contract for simulated multi-GPU DP; the host-only
//! property (no artifacts needed) lives in `tests/scheduler_props.rs`.

use zo2::runtime::Runtime;
use zo2::zo::{DpSimShard, RunMode, Zo2Engine, Zo2Options, ZoConfig};

macro_rules! require_artifacts {
    () => {
        if !zo2::artifacts_available("tiny") {
            eprintln!(
                "SKIP {}: no PJRT artifacts for config `tiny` (run `make artifacts` \
                 or set $ZO2_ARTIFACTS)",
                module_path!()
            );
            return;
        }
    };
}

const STEPS: usize = 4;
const SHARDS: usize = 2;

fn cfg() -> ZoConfig {
    ZoConfig { lr: 1e-3, eps: 1e-3, seed: 2027 }
}

fn engine(run_mode: RunMode) -> Zo2Engine {
    let rt = Runtime::load_config("tiny").unwrap();
    Zo2Engine::new(rt, cfg(), Zo2Options { run_mode, ..Zo2Options::default() }).unwrap()
}

/// Run STEPS DP steps with `workers` replicas over SHARDS shards; returns
/// (per-step dual losses, final flat params).
fn dp_trajectory(workers: usize, run_mode: RunMode) -> (Vec<(f32, f32)>, Vec<f32>) {
    let ws: Vec<Zo2Engine> = (0..workers).map(|_| engine(run_mode)).collect();
    let (b, t) = {
        let m = ws[0].runtime().manifest();
        (m.config.batch, m.config.seq_len)
    };
    let vocab = ws[0].runtime().manifest().config.vocab;
    let mut dp = DpSimShard::new(ws, SHARDS).unwrap();
    let mut corpus = zo2::data::SyntheticCorpus::new(vocab, 555);
    let mut losses = Vec::new();
    for _ in 0..STEPS {
        let mut ids = Vec::with_capacity(SHARDS * b * t);
        for _ in 0..SHARDS {
            ids.extend(corpus.sample(b, t).ids);
        }
        let st = dp.train_step(&ids).unwrap();
        losses.push((st.loss_plus, st.loss_minus));
    }
    for w in dp.workers_mut() {
        w.flush_updates().unwrap();
    }
    let params = dp.workers()[0].flat_params().unwrap();
    (losses, params)
}

#[test]
fn dp_two_workers_reproduce_single_worker_bitwise() {
    require_artifacts!();
    for run_mode in [RunMode::Sequential, RunMode::Overlapped] {
        let (l1, p1) = dp_trajectory(1, run_mode);
        let (l2, p2) = dp_trajectory(2, run_mode);
        for (i, (a, b)) in l1.iter().zip(&l2).enumerate() {
            assert_eq!(a.0.to_bits(), b.0.to_bits(), "{run_mode:?} step {i} loss+");
            assert_eq!(a.1.to_bits(), b.1.to_bits(), "{run_mode:?} step {i} loss-");
        }
        let diffs = p1.iter().zip(&p2).filter(|(a, b)| a.to_bits() != b.to_bits()).count();
        assert_eq!(diffs, 0, "{run_mode:?}: {diffs}/{} params differ", p1.len());
    }
}

#[test]
fn dp_worker_replicas_stay_in_lockstep() {
    require_artifacts!();
    let ws: Vec<Zo2Engine> = (0..2).map(|_| engine(RunMode::Sequential)).collect();
    let (b, t, vocab) = {
        let m = ws[0].runtime().manifest();
        (m.config.batch, m.config.seq_len, m.config.vocab)
    };
    let mut dp = DpSimShard::new(ws, 2).unwrap();
    let mut corpus = zo2::data::SyntheticCorpus::new(vocab, 7);
    for _ in 0..3 {
        let mut ids = Vec::new();
        for _ in 0..2 {
            ids.extend(corpus.sample(b, t).ids);
        }
        dp.train_step(&ids).unwrap();
    }
    for w in dp.workers_mut() {
        w.flush_updates().unwrap();
    }
    let p0 = dp.workers()[0].flat_params().unwrap();
    let p1 = dp.workers()[1].flat_params().unwrap();
    let diffs = p0.iter().zip(&p1).filter(|(a, b)| a.to_bits() != b.to_bits()).count();
    assert_eq!(diffs, 0, "replicas diverged: {diffs}/{} params", p0.len());
}

#[test]
fn missing_allreduce_is_a_loud_error() {
    require_artifacts!();
    let mut e = engine(RunMode::Sequential);
    let m = e.runtime().manifest();
    let (b, t, vocab) = (m.config.batch, m.config.seq_len, m.config.vocab);
    let mut corpus = zo2::data::SyntheticCorpus::new(vocab, 9);
    let ids = corpus.sample(b, t).ids;
    e.dp_dual_losses(&[&ids]).unwrap();
    // No set_allreduced_g: the parked NaN must refuse to train or flush.
    let err = e.train_step(&ids).unwrap_err().to_string();
    assert!(err.contains("set_allreduced_g"), "unexpected error: {err}");
    let err = e.flush_updates().unwrap_err().to_string();
    assert!(err.contains("set_allreduced_g"), "unexpected error: {err}");
}
