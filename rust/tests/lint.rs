//! Integration tests for `zo2 lint` — the repo-native static-analysis pass.
//!
//! Three layers:
//!
//! 1. a fixture corpus exercising every rule's fire / scope / waive paths
//!    through the public [`zo2::analysis::lint_source`] entry point;
//! 2. the self-hosting gate — the shipped source tree must lint clean,
//!    which is exactly what the CI `zo2 lint` step enforces;
//! 3. byte-determinism of the rendered `zo2-lint-v1` report (two full
//!    runs over the same tree serialise identically).

use std::path::Path;

use zo2::analysis::rules::{
    RULE_DET_COLLECTIONS, RULE_PANIC, RULE_SCHEMA, RULE_UNSAFE, RULE_WALL_CLOCK,
};
use zo2::analysis::{lint_plans, lint_source, run_lint, LINT_SCHEMA};
use zo2::util::json::Json;

/// Distinct rules with at least one unwaived finding, in report order.
fn rules_fired(path: &str, src: &str) -> Vec<&'static str> {
    let mut fired: Vec<&'static str> =
        lint_source(path, src).findings.iter().filter(|f| !f.waived).map(|f| f.rule).collect();
    fired.dedup();
    fired
}

#[test]
fn unsafe_rule_fires_clears_and_waives() {
    let bad = "pub fn read(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
    assert_eq!(rules_fired("memory/x.rs", bad), vec![RULE_UNSAFE]);
    let rep = lint_source("memory/x.rs", bad);
    assert_eq!(rep.unsafe_sites.len(), 1);
    assert!(!rep.unsafe_sites[0].documented);

    let good = "pub fn read(p: *const u8) -> u8 {\n    \
                // SAFETY: the caller guarantees `p` is valid for reads.\n    \
                unsafe { *p }\n}\n";
    assert!(rules_fired("memory/x.rs", good).is_empty());
    assert!(lint_source("memory/x.rs", good).unsafe_sites[0].documented);

    let waived = "// zo2-lint: allow(unsafe-needs-safety-comment): fixture for the waiver path\n\
                  pub fn read(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
    let rep = lint_source("memory/x.rs", waived);
    assert_eq!(rep.unwaived(), 0);
    assert_eq!(rep.waivers.len(), 1);
    // The waiver silences the finding but the inventory still lists the
    // site as undocumented — waivers are not safety arguments.
    assert!(!rep.unsafe_sites[0].documented);
}

#[test]
fn deterministic_collections_rule_is_scoped() {
    let src = "use std::collections::HashMap;\n\
               pub fn f() -> HashMap<u32, u32> {\n    HashMap::new()\n}\n";
    assert_eq!(rules_fired("sched/x.rs", src), vec![RULE_DET_COLLECTIONS]);
    assert_eq!(rules_fired("tune/x.rs", src), vec![RULE_DET_COLLECTIONS]);
    // Outside the determinism-audited directories the rule stays silent.
    assert!(rules_fired("memory/x.rs", src).is_empty());

    let btree = "use std::collections::BTreeMap;\n\
                 pub fn f() -> BTreeMap<u32, u32> {\n    BTreeMap::new()\n}\n";
    assert!(rules_fired("sched/x.rs", btree).is_empty());

    let waived = "// zo2-lint: allow(deterministic-collections): order never observed here\n\
                  use std::collections::HashSet;\n";
    assert!(rules_fired("dp/x.rs", waived).is_empty());
}

#[test]
fn wall_clock_rule_exempts_the_clock_module() {
    let src = "pub fn stamp() -> std::time::Instant {\n    std::time::Instant::now()\n}\n";
    assert_eq!(rules_fired("telemetry/x.rs", src), vec![RULE_WALL_CLOCK]);
    assert!(rules_fired("clock/mod.rs", src).is_empty());

    let sys = "pub fn epoch() {\n    let _ = std::time::SystemTime::now();\n}\n";
    assert_eq!(rules_fired("coordinator/x.rs", sys), vec![RULE_WALL_CLOCK]);

    let waived = "pub fn stamp() -> std::time::Instant {\n    \
                  // zo2-lint: allow(no-wall-clock): fixture; never feeds a trajectory\n    \
                  std::time::Instant::now()\n}\n";
    assert!(rules_fired("telemetry/x.rs", waived).is_empty());
}

#[test]
fn panic_rule_covers_cli_and_planner_only() {
    let src = "pub fn f(v: Option<u32>) -> u32 {\n    v.unwrap()\n}\n";
    assert_eq!(rules_fired("main.rs", src), vec![RULE_PANIC]);
    assert_eq!(rules_fired("tune/search.rs", src), vec![RULE_PANIC]);
    // Library crates use assert!/panic! as contract checks — out of scope.
    assert!(rules_fired("sched/mod.rs", src).is_empty());

    let expl = "pub fn g() {\n    panic!(\"boom\");\n}\n";
    assert_eq!(rules_fired("main.rs", expl), vec![RULE_PANIC]);

    // Test modules may unwrap freely even inside the scoped files.
    let tests = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        \
                 Some(1).unwrap();\n    }\n}\n";
    assert!(rules_fired("main.rs", tests).is_empty());

    let waived = "pub fn f(v: Option<u32>) -> u32 {\n    \
                  // zo2-lint: allow(no-panic-in-cli-planner): invariant upheld by caller\n    \
                  v.unwrap()\n}\n";
    assert!(rules_fired("main.rs", waived).is_empty());
}

#[test]
fn schema_literal_rule_pins_util_schema() {
    let src = "pub const S: &str = \"zo2-tune-v1\";\n";
    assert_eq!(rules_fired("tune/mod.rs", src), vec![RULE_SCHEMA]);
    // The one authorised home for version literals.
    assert!(rules_fired("util/schema.rs", src).is_empty());

    // zo2-prefixed strings without a version suffix are fine anywhere.
    let plain = "pub const S: &str = \"zo2-lint\";\n";
    assert!(rules_fired("tune/mod.rs", plain).is_empty());

    let waived = "// zo2-lint: allow(schema-version-literal): doc example, not a live literal\n\
                  pub const S: &str = \"zo2-dp-ckpt-v1\";\n";
    assert!(rules_fired("tune/mod.rs", waived).is_empty());
}

#[test]
fn waivers_without_reasons_do_not_waive() {
    let src = "// zo2-lint: allow(no-wall-clock):\n\
               pub fn stamp() {\n    let _ = std::time::Instant::now();\n}\n";
    let rep = lint_source("telemetry/x.rs", src);
    assert_eq!(rep.unwaived(), 1, "a reason-less waiver must be ignored");
    assert!(rep.waivers.is_empty());
}

#[test]
fn shipped_source_tree_lints_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let rep = run_lint(&src).expect("lint walk over src/");
    let loud: Vec<_> = rep.findings.iter().filter(|f| !f.waived).collect();
    assert!(loud.is_empty(), "unwaived findings in the shipped tree: {loud:#?}");
    let undoc: Vec<_> = rep.unsafe_sites.iter().filter(|s| !s.documented).collect();
    assert!(undoc.is_empty(), "undocumented unsafe in the shipped tree: {undoc:#?}");
    assert!(rep.files_scanned > 40, "walk found only {} files", rep.files_scanned);
    // Every waiver in the tree must carry a reason (the parser enforces
    // this, so an empty reason here means the parser regressed).
    assert!(rep.waivers.iter().all(|w| !w.reason.is_empty()));
}

#[test]
fn report_is_byte_deterministic() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut a = run_lint(&src).expect("first lint run");
    a.plans = Some(lint_plans());
    let mut b = run_lint(&src).expect("second lint run");
    b.plans = Some(lint_plans());
    let ra = a.render();
    assert_eq!(ra, b.render(), "two lint runs must serialise byte-identically");

    let doc = Json::parse(&ra).expect("report must be valid JSON");
    assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), LINT_SCHEMA);
    let plans = doc.get("plans").unwrap();
    assert!(plans.get("checked").unwrap().as_usize().unwrap() >= 70);
    assert_eq!(plans.get("violations").unwrap().as_arr().unwrap().len(), 0);
}
