//! End-to-end tests of `zo2 tune`: byte-determinism of the report under a
//! fixed `--tune-seed`, replay equality of the winning config through
//! `simulate --config tuned.json`, pruning correctness, and the
//! `--calibrate` round trip over bench-shaped fixtures.

use std::path::{Path, PathBuf};

use zo2::costmodel::{HostKernels, SimCost};
use zo2::telemetry::metrics::find_value;
use zo2::util::json::Json;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("zo2_tune_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run the compiled `zo2` binary, panicking (with stderr) on failure.
fn zo2_ok(cwd: &Path, args: &[&str]) -> String {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_zo2"))
        .current_dir(cwd)
        .args(args)
        .output()
        .expect("spawn zo2");
    assert!(
        out.status.success(),
        "zo2 {args:?} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn zo2_err(cwd: &Path, args: &[&str]) -> String {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_zo2"))
        .current_dir(cwd)
        .args(args)
        .output()
        .expect("spawn zo2");
    assert!(!out.status.success(), "zo2 {args:?} unexpectedly succeeded");
    String::from_utf8_lossy(&out.stderr).into_owned()
}

#[test]
fn tune_reports_are_byte_identical_for_the_same_seed() {
    let dir = temp_dir("det");
    let t1 = dir.join("t1.json");
    let t2 = dir.join("t2.json");
    let base = [
        "tune",
        "--model",
        "OPT-13B",
        "--devices",
        "2",
        "--wire",
        "fp16",
        "--compute",
        "fp16",
        "--tiering",
        "three",
        "--dram-budget",
        "24",
        "--tune-seed",
        "7",
        "--out",
    ];
    let mut a1: Vec<&str> = base.to_vec();
    a1.push(t1.to_str().unwrap());
    let mut a2: Vec<&str> = base.to_vec();
    a2.push(t2.to_str().unwrap());
    zo2_ok(&dir, &a1);
    zo2_ok(&dir, &a2);
    let b1 = std::fs::read(&t1).unwrap();
    let b2 = std::fs::read(&t2).unwrap();
    assert!(!b1.is_empty());
    assert_eq!(b1, b2, "same --tune-seed must produce byte-identical reports");

    // A different seed still converges on a frontier (the report stays
    // well-formed), though the explored set may differ.
    let t3 = dir.join("t3.json");
    let mut a3: Vec<&str> = base.to_vec();
    a3.truncate(base.len() - 3); // drop `--tune-seed 7 --out`
    a3.extend(["--tune-seed", "8", "--out", t3.to_str().unwrap()]);
    zo2_ok(&dir, &a3);
    let doc = Json::parse(&std::fs::read_to_string(&t3).unwrap()).unwrap();
    assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "zo2-tune-v1");
    assert!(!doc.get("frontier").unwrap().as_arr().unwrap().is_empty());

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn best_config_replays_through_simulate_within_1e9() {
    let dir = temp_dir("replay");
    let tuned = dir.join("tuned.json");
    zo2_ok(
        &dir,
        &[
            "tune",
            "--model",
            "OPT-13B",
            "--devices",
            "2",
            "--wire",
            "fp16",
            "--compute",
            "fp16",
            "--tiering",
            "three",
            "--dram-budget",
            "24",
            "--tune-seed",
            "7",
            "--out",
            tuned.to_str().unwrap(),
        ],
    );
    let doc = Json::parse(&std::fs::read_to_string(&tuned).unwrap()).unwrap();
    let best = doc.get("best").unwrap();
    let predicted = best.get("predicted_step_s").unwrap().as_f64().unwrap();
    assert!(predicted.is_finite() && predicted > 0.0);
    // The report's replay flags carry the full scenario + the winning knobs.
    let flags = best.get("flags").unwrap().as_obj().unwrap();
    for key in ["model", "devices", "tiering", "dram-budget", "shard", "slots", "dram-slots"] {
        assert!(flags.contains_key(key), "replay flags miss `{key}`");
    }

    let metrics = dir.join("metrics.json");
    zo2_ok(
        &dir,
        &[
            "simulate",
            "--config",
            tuned.to_str().unwrap(),
            "--metrics-out",
            metrics.to_str().unwrap(),
        ],
    );
    let snapshot = Json::parse(&std::fs::read_to_string(&metrics).unwrap()).unwrap();
    let replayed = find_value(&snapshot, "sim_steady_step_s", &[])
        .expect("simulate --metrics-out writes sim_steady_step_s");
    assert!(
        (replayed - predicted).abs() < 1e-9,
        "replayed step {replayed} drifts from predicted {predicted}"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn infeasible_spaces_prune_everything_and_refuse_replay() {
    // A 1 GB DDR budget cannot even hold the staging window of one
    // OPT-13B fp16 block pair: every three-tier candidate must be pruned
    // (never a panic), the report's best must be null, and replaying the
    // report must be a loud error.
    let dir = temp_dir("prune");
    let tuned = dir.join("tuned.json");
    zo2_ok(
        &dir,
        &[
            "tune",
            "--model",
            "OPT-13B",
            "--devices",
            "2",
            "--wire",
            "fp16",
            "--compute",
            "fp16",
            "--tiering",
            "three",
            "--dram-budget",
            "1",
            "--tune-seed",
            "1",
            "--out",
            tuned.to_str().unwrap(),
        ],
    );
    let doc = Json::parse(&std::fs::read_to_string(&tuned).unwrap()).unwrap();
    assert!(matches!(doc.get("best").unwrap(), Json::Null), "1 GB budget must have no winner");
    assert!(doc.get("frontier").unwrap().as_arr().unwrap().is_empty());
    let search = doc.get("search").unwrap();
    let explored = search.get("explored").unwrap().as_f64().unwrap();
    let pruned = search.get("pruned").unwrap().as_f64().unwrap();
    assert!(explored > 0.0 && pruned == explored, "explored {explored} vs pruned {pruned}");
    // Pruned examples carry reasons (budget feasibility, not panics).
    let examples = doc.get("pruned_examples").unwrap().as_arr().unwrap();
    assert!(!examples.is_empty());
    for ex in examples {
        assert!(!ex.get("reason").unwrap().as_str().unwrap().is_empty());
    }
    let e = zo2_err(&dir, &["simulate", "--config", tuned.to_str().unwrap()]);
    assert!(e.contains("no feasible"), "{e}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn calibrate_round_trip_feeds_both_oracles() {
    let dir = temp_dir("cal");

    // Host-kernel fixture: the legacy flat `calibration` block.
    let hk_path = dir.join("BENCH_host_kernels.json");
    std::fs::write(
        &hk_path,
        r#"{
  "calibration": {
    "fp32_bytes_per_s_per_thread": 1500000000,
    "bf16_bytes_per_s_per_thread": 2000000000,
    "fp16_bytes_per_s_per_thread": 2100000000,
    "fp8_bytes_per_s_per_thread": 3000000000
  }
}"#,
    )
    .unwrap();
    let hk = HostKernels::from_bench_json(hk_path.to_str().unwrap()).unwrap();
    assert_eq!(hk.fp32_bytes_per_s, 1.5e9);
    assert_eq!(hk.fp8_bytes_per_s, 3.0e9);

    // Sim-gauge fixture: a `BENCH_multi_gpu.json`-style metrics snapshot.
    let mg_path = dir.join("BENCH_multi_gpu.json");
    std::fs::write(
        &mg_path,
        r#"{
  "metrics": {
    "schema": "zo2-metrics-v1",
    "metrics": [
      {
        "name": "sim_steady_step_s",
        "labels": {"model": "OPT-13B", "devices": "2", "strategy": "dp"},
        "kind": "gauge",
        "value": 1.25
      }
    ]
  }
}"#,
    )
    .unwrap();
    let gauges = SimCost::from_bench_json(mg_path.to_str().unwrap()).unwrap();
    assert_eq!(gauges.steady_step_s("OPT-13B", 2, "dp"), Some(1.25));

    // The CLI loop: both files through --calibrate, recorded in the report.
    let tuned = dir.join("tuned.json");
    let cal_arg = format!("{},{}", hk_path.to_str().unwrap(), mg_path.to_str().unwrap());
    zo2_ok(
        &dir,
        &[
            "tune",
            "--model",
            "OPT-13B",
            "--devices",
            "2",
            "--wire",
            "fp16",
            "--compute",
            "fp16",
            "--calibrate",
            &cal_arg,
            "--tune-seed",
            "2",
            "--out",
            tuned.to_str().unwrap(),
        ],
    );
    let doc = Json::parse(&std::fs::read_to_string(&tuned).unwrap()).unwrap();
    let cal = doc.get("calibration").unwrap();
    assert_eq!(cal.get("files").unwrap().as_arr().unwrap().len(), 2);
    assert!(matches!(cal.get("host_kernels").unwrap(), Json::Bool(true)));
    let rows = cal.get("sim_gauges").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].get("measured_step_s").unwrap().as_f64().unwrap(), 1.25);
    // The gauge matches the tuned scenario (OPT-13B × 2 devices), so a
    // predicted counterpart must be attached when dp made the frontier.
    let best = doc.get("best").unwrap();
    assert!(best.get("predicted_step_s").unwrap().as_f64().unwrap() > 0.0);

    // A file that is neither shape is a loud error naming the path.
    let junk = dir.join("junk.json");
    std::fs::write(&junk, r#"{"hello": 1}"#).unwrap();
    let e = zo2_err(
        &dir,
        &["tune", "--model", "OPT-13B", "--calibrate", junk.to_str().unwrap()],
    );
    assert!(e.contains("--calibrate") && e.contains("junk.json"), "{e}");
    std::fs::remove_dir_all(&dir).unwrap();
}
