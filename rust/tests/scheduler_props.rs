//! Property tests over the dynamic scheduler (no proptest in the offline
//! build — randomised cases come from the crate's own deterministic RNG).
//!
//! Invariants checked across random (n_blocks, steps, durations, policies),
//! including three-tier policies with random spill counts and DRAM windows:
//!  1. dependency safety: no task starts before any dependency ends;
//!  2. stream exclusivity: tasks on one stream never overlap (all kinds);
//!  3. overlap dominance: the dynamic schedule is never slower than naive;
//!  4. critical-path lower bounds hold;
//!  5. slot safety: at most `slots` blocks in flight at any instant;
//!  6. chain safety: spilled blocks run R(Wᵢ)→U(Wᵢ)→C(Wᵢ)→O(Wᵢ)→W(Wᵢ);
//!  7. window safety: at most `dram_slots` spilled buckets staged at once;
//!
//! plus the device-indexed refactor's invariants:
//!  8. N = 1 sharded plans are identical to `build_plan` (the frozen
//!     pre-refactor comparison lives in `tests/sched_golden_v1.rs`);
//!  9. per-device stream FIFO and cross-device dependency ordering hold
//!     for N ∈ {2, 4}, both strategies, both layouts;
//! 10. the DP sim-shard trajectory is bit-identical for any worker count;
//!
//! plus the pipeline-microbatching / per-partition-spill invariants:
//! 11. microbatched plans keep per-stream FIFO, emit microbatch slices in
//!     index order, and never schedule an activation hop before its
//!     same-microbatch producer compute ends;
//! 12. for compute-bound configurations under an ideal (evenly-split) cost
//!     provider, step time is monotonically non-increasing in M;
//! 13. per-partition three-tier spill sets are pairwise disjoint, live on
//!     their owner's streams, and each partition's plan fits the owning
//!     host's `MemoryBudget`;
//!
//! plus the heterogeneous-cluster invariants:
//! 14. under per-device cost factors, every device's per-step compute work
//!     lower-bounds the makespan (the slowest device paces the pipeline),
//!     and slowing any one device never speeds the schedule up;
//! 15. per-host spill sets respect their *own* budget when budgets differ,
//!     and raising one host's budget never changes a sibling's plan.

use zo2::costmodel::{
    plan_three_tier, plan_three_tier_partitioned, ComputeMode, Hardware, MemoryBudget, Workload,
};
use zo2::model::opt_by_name;
use zo2::precision::Codec;
use zo2::rng::GaussianRng;
use zo2::sched::{
    build_plan, simulate, validate_plan, CostProvider, DeviceId, Module, Policy, SpillPlacement,
    StreamId, StreamKind, Task, TaskKind, Tiering, STREAM_KINDS,
};
use zo2::shard::{
    block_owner, blocks_per_device, build_sharded_plan, build_sharded_plan_spilled,
    build_sharded_plan_tiered, DeviceTier, ShardLayout, ShardSpec,
};
use zo2::zo::{DpSimShard, DpWorker};

#[derive(Clone, Copy)]
struct RandCosts {
    up: f64,
    off: f64,
    comp: f64,
    upd: f64,
    read: f64,
    write: f64,
    act: f64,
    seed: f64,
    grad: f64,
}

impl CostProvider for RandCosts {
    fn upload_s(&self) -> f64 {
        self.up
    }
    fn offload_s(&self) -> f64 {
        self.off
    }
    fn compute_s(&self, _m: Module) -> f64 {
        self.comp
    }
    fn update_s(&self) -> f64 {
        self.upd
    }
    fn disk_read_s(&self) -> f64 {
        self.read
    }
    fn disk_write_s(&self) -> f64 {
        self.write
    }
    fn link_activation_s(&self) -> f64 {
        self.act
    }
    fn link_seed_s(&self) -> f64 {
        self.seed
    }
    fn link_grad_s(&self) -> f64 {
        self.grad
    }
}

fn rand_case(rng: &mut GaussianRng) -> (usize, usize, RandCosts, Policy) {
    let n_blocks = 1 + rng.next_below(12) as usize;
    let steps = 1 + rng.next_below(4) as usize;
    let costs = RandCosts {
        up: 0.01 + rng.next_uniform() * 2.0,
        off: 0.01 + rng.next_uniform() * 2.0,
        comp: 0.01 + rng.next_uniform() * 4.0,
        upd: 0.01 + rng.next_uniform() * 0.5,
        read: 0.01 + rng.next_uniform() * 3.0,
        write: 0.01 + rng.next_uniform() * 3.0,
        act: rng.next_uniform() * 0.5,
        seed: rng.next_uniform() * 0.1,
        grad: rng.next_uniform() * 0.2,
    };
    // Half the cases are three-tier with a random spill count and window.
    let three = rng.next_below(2) == 0;
    let policy = Policy {
        overlap: true,
        reusable_mem: rng.next_below(2) == 0,
        efficient_update: rng.next_below(2) == 0,
        slots: 1 + rng.next_below(4) as usize,
        tiering: if three { Tiering::ThreeTier } else { Tiering::TwoTier },
        spilled: if three { rng.next_below(1 + n_blocks as u64) as usize } else { 0 },
        spill_placement: if rng.next_below(2) == 0 {
            SpillPlacement::Trailing
        } else {
            SpillPlacement::Interleaved
        },
        dram_slots: 1 + rng.next_below(4) as usize,
        disk_batch: 1 + rng.next_below(4) as usize,
    };
    (n_blocks, steps, costs, policy)
}

fn rand_spec(rng: &mut GaussianRng) -> ShardSpec {
    let devices = [2usize, 4][rng.next_below(2) as usize];
    let layout =
        [ShardLayout::Contiguous, ShardLayout::Cyclic][rng.next_below(2) as usize];
    if rng.next_below(2) == 0 {
        ShardSpec::pipeline(devices, layout)
    } else {
        ShardSpec::data_parallel(devices)
    }
}

/// All streams a plan actually uses.
fn streams_of(plan: &[Task]) -> Vec<StreamId> {
    let mut ss: Vec<StreamId> = plan.iter().map(|t| t.stream).collect();
    ss.sort_unstable();
    ss.dedup();
    ss
}

#[test]
fn dependencies_and_stream_exclusivity_hold() {
    let mut rng = GaussianRng::new(2024, 0);
    for case in 0..60 {
        let (n, steps, costs, policy) = rand_case(&mut rng);
        let plan = build_plan(n, steps, policy);
        let (sched, _) = simulate(&plan, &costs, policy);

        for t in &plan {
            for &d in &t.deps {
                assert!(
                    sched.start[t.id] >= sched.end[d] - 1e-12,
                    "case {case}: task {} starts before dep {}",
                    t.id,
                    d
                );
            }
        }
        for k in STREAM_KINDS {
            let s = StreamId::new(0, k);
            let mut ivals: Vec<(f64, f64)> = plan
                .iter()
                .filter(|t| t.stream == s)
                .map(|t| (sched.start[t.id], sched.end[t.id]))
                .collect();
            ivals.sort_by(|a, b| a.0.total_cmp(&b.0));
            for w in ivals.windows(2) {
                assert!(w[1].0 >= w[0].1 - 1e-12, "case {case}: stream {s:?} overlap");
            }
        }
    }
}

#[test]
fn overlap_never_loses_to_naive() {
    let mut rng = GaussianRng::new(7, 1);
    for case in 0..40 {
        let (n, steps, costs, policy) = rand_case(&mut rng);
        let dynamic = Policy { overlap: true, ..policy };
        let naive = Policy { overlap: false, ..policy };
        let (sd, _) = simulate(&build_plan(n, steps, dynamic), &costs, dynamic);
        let (sn, _) = simulate(&build_plan(n, steps, naive), &costs, naive);
        assert!(
            sd.makespan <= sn.makespan + 1e-9,
            "case {case}: dynamic {} > naive {}",
            sd.makespan,
            sn.makespan
        );
    }
}

#[test]
fn critical_path_lower_bounds() {
    let mut rng = GaussianRng::new(99, 2);
    for _ in 0..40 {
        let (n, steps, costs, policy) = rand_case(&mut rng);
        let plan = build_plan(n, steps, policy);
        let (sched, _) = simulate(&plan, &costs, policy);
        // Compute stream total is a lower bound (it is one FIFO processor).
        let compute_total: f64 = plan
            .iter()
            .filter(|t| t.stream.kind == StreamKind::Compute)
            .map(|t| match t.kind {
                TaskKind::Compute => costs.compute_s(t.module),
                TaskKind::Update => costs.update_s(),
                TaskKind::Upload => costs.upload_s() + if policy.reusable_mem { 0.0 } else { costs.malloc_s() },
                TaskKind::Offload => costs.offload_s(),
                TaskKind::DiskRead => costs.disk_read_s(),
                TaskKind::DiskWrite => costs.disk_write_s(),
                TaskKind::ActivationXfer => costs.link_activation_s(),
                TaskKind::SeedBcast => costs.link_seed_s(),
                TaskKind::GradReduce => costs.link_grad_s(),
            })
            .sum();
        assert!(sched.makespan >= compute_total - 1e-9);
        // Per-block chain U→C→O is a lower bound too (R+…+W for spilled).
        let chain = costs.upload_s() + costs.compute_s(Module::Block(0)) + costs.offload_s();
        assert!(sched.makespan >= chain - 1e-9);
        if policy.spilled > 0 && policy.tiering == Tiering::ThreeTier {
            let full_chain = costs.disk_read_s() + chain + costs.disk_write_s();
            assert!(sched.makespan >= full_chain - 1e-9, "five-task chain bound");
        }
    }
}

#[test]
fn slot_ring_bounds_in_flight_blocks() {
    let mut rng = GaussianRng::new(5, 3);
    for _ in 0..30 {
        let (n, steps, costs, policy) = rand_case(&mut rng);
        let plan = build_plan(n, steps, policy);
        let (sched, _) = simulate(&plan, &costs, policy);
        // A block occupies a slot from U start to O end.  Count max overlap
        // of those intervals; it must never exceed `slots`.
        let mut intervals: Vec<(f64, f64)> = Vec::new();
        for t in &plan {
            if t.kind == TaskKind::Upload {
                if let Module::Block(i) = t.module {
                    // find the matching offload of the same round
                    let off = plan.iter().find(|o| {
                        o.kind == TaskKind::Offload
                            && o.module == Module::Block(i)
                            && o.step == t.step
                            && o.id > t.id
                    });
                    if let Some(o) = off {
                        intervals.push((sched.start[t.id], sched.end[o.id]));
                    }
                }
            }
        }
        let peak = max_overlap(&intervals);
        assert!(
            peak as usize <= policy.slots.max(1),
            "{peak} blocks in flight with {} slots",
            policy.slots
        );
    }
}

/// Max number of simultaneously-open intervals.
fn max_overlap(intervals: &[(f64, f64)]) -> i32 {
    let mut events: Vec<(f64, i32)> = Vec::new();
    for (a, b) in intervals {
        events.push((*a, 1));
        events.push((*b, -1));
    }
    events.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.cmp(&y.1)));
    let mut cur = 0;
    let mut peak = 0;
    for (_, d) in events {
        cur += d;
        peak = peak.max(cur);
    }
    peak
}

#[test]
fn spilled_blocks_have_full_chain_in_order() {
    // Rule 6: for every spilled block round, R → U → C → O → W hold as
    // *scheduled times*, not just as declared deps.
    let mut rng = GaussianRng::new(41, 4);
    for case in 0..40 {
        let (n, steps, costs, mut policy) = rand_case(&mut rng);
        policy.tiering = Tiering::ThreeTier;
        policy.spilled = 1 + rng.next_below(n as u64) as usize;
        let plan = build_plan(n, steps, policy);
        let (sched, _) = simulate(&plan, &costs, policy);
        for r in plan.iter().filter(|t| t.kind == TaskKind::DiskRead) {
            let (i, step) = match r.module {
                Module::Block(i) => (i, r.step),
                _ => unreachable!("disk reads are per-block"),
            };
            // Find the chain members of the same round (first with id > r.id).
            let mut chain_end = sched.end[r.id];
            for kind in [TaskKind::Upload, TaskKind::Compute, TaskKind::Offload, TaskKind::DiskWrite] {
                let next = plan
                    .iter()
                    .find(|t| {
                        t.id > r.id
                            && t.step == step
                            && t.module == Module::Block(i)
                            && (t.kind == kind
                                || (kind == TaskKind::Compute && t.kind == TaskKind::Update))
                    })
                    .unwrap_or_else(|| panic!("case {case}: missing {kind:?} after R(W{i})"));
                assert!(
                    sched.start[next.id] >= chain_end - 1e-12,
                    "case {case}: {kind:?} of W{i} starts before previous chain task ends"
                );
                chain_end = sched.end[next.id];
            }
        }
    }
}

#[test]
fn per_stream_fifo_is_structural() {
    // Rule 2 strengthened: on every stream, declared FIFO deps force start
    // times to follow issue order exactly.
    let mut rng = GaussianRng::new(17, 5);
    for _ in 0..30 {
        let (n, steps, costs, policy) = rand_case(&mut rng);
        let plan = build_plan(n, steps, policy);
        let (sched, _) = simulate(&plan, &costs, policy);
        for s in streams_of(&plan) {
            let ids: Vec<usize> =
                plan.iter().filter(|t| t.stream == s).map(|t| t.id).collect();
            for w in ids.windows(2) {
                assert!(
                    sched.start[w[1]] >= sched.end[w[0]] - 1e-12,
                    "stream {s:?}: issue order {} -> {} violated",
                    w[0],
                    w[1]
                );
            }
        }
    }
}

#[test]
fn dram_window_never_exceeds_slot_count() {
    // Rule 7: a spilled bucket occupies a staging slot from R start to W
    // end; the max overlap of those intervals is bounded by dram_slots in
    // every simulated schedule.
    let mut rng = GaussianRng::new(23, 6);
    for case in 0..40 {
        let (n, steps, costs, mut policy) = rand_case(&mut rng);
        policy.tiering = Tiering::ThreeTier;
        policy.spilled = 1 + rng.next_below(n as u64) as usize;
        let plan = build_plan(n, steps, policy);
        let (sched, _) = simulate(&plan, &costs, policy);
        let mut intervals: Vec<(f64, f64)> = Vec::new();
        for r in plan.iter().filter(|t| t.kind == TaskKind::DiskRead) {
            let w = plan
                .iter()
                .find(|t| {
                    t.id > r.id && t.kind == TaskKind::DiskWrite && t.module == r.module
                        && t.step == r.step
                })
                .expect("every R has a matching W");
            intervals.push((sched.start[r.id], sched.end[w.id]));
        }
        let peak = max_overlap(&intervals);
        assert!(
            peak as usize <= policy.dram_slots.max(1),
            "case {case}: {peak} staged buckets with a {}-slot DRAM window",
            policy.dram_slots
        );
    }
}

#[test]
fn efficient_update_halves_interconnect_busy_time() {
    let costs = RandCosts {
        up: 1.0,
        off: 1.0,
        comp: 0.5,
        upd: 0.05,
        read: 0.2,
        write: 0.2,
        act: 0.0,
        seed: 0.0,
        grad: 0.0,
    };
    let base = Policy::default();
    let noeff = Policy { efficient_update: false, ..base };
    let (s1, _) = simulate(&build_plan(8, 2, base), &costs, base);
    let (s2, _) = simulate(&build_plan(8, 2, noeff), &costs, noeff);
    let b1 = s1.busy_of("upload") + s1.busy_of("offload");
    let b2 = s2.busy_of("upload") + s2.busy_of("offload");
    assert!((b2 / b1 - 2.0).abs() < 0.2, "transfer busy should ~double: {b1} -> {b2}");
}

// --- device-indexed / sharded invariants (rules 8-10) -----------------------

#[test]
fn single_device_sharded_plans_match_build_plan() {
    // Rule 8 (the frozen v1 comparison is in tests/sched_golden_v1.rs;
    // this closes the loop N=1 sharded == build_plan for random policies).
    let mut rng = GaussianRng::new(31, 7);
    for case in 0..40 {
        let (n, steps, _costs, policy) = rand_case(&mut rng);
        let base = build_plan(n, steps, policy);
        for spec in [
            ShardSpec::single(),
            ShardSpec::pipeline(1, ShardLayout::Cyclic),
            ShardSpec::data_parallel(1),
        ] {
            let p = build_sharded_plan(n, steps, policy, &spec);
            assert_eq!(base.len(), p.len(), "case {case} {spec:?}");
            for (a, b) in base.iter().zip(&p) {
                assert_eq!(a.kind, b.kind, "case {case} {spec:?}");
                assert_eq!(a.stream, b.stream, "case {case} {spec:?}");
                assert_eq!(a.deps, b.deps, "case {case} {spec:?}");
                assert_eq!(a.module, b.module, "case {case} {spec:?}");
                assert_eq!(a.step, b.step, "case {case} {spec:?}");
            }
        }
    }
}

#[test]
fn sharded_plans_keep_per_device_fifo_and_backward_deps() {
    // Rule 9a: on every device-indexed stream of an N ∈ {2,4} plan, issue
    // order is schedule order, and every dependency points backward.
    let mut rng = GaussianRng::new(53, 8);
    for case in 0..60 {
        let (n, steps, costs, policy) = rand_case(&mut rng);
        let spec = rand_spec(&mut rng);
        let plan = build_sharded_plan(n, steps, policy, &spec);
        let (sched, _) = simulate(&plan, &costs, policy);
        for t in &plan {
            for &d in &t.deps {
                assert!(d < t.id, "case {case} {spec:?}: dep {} of {} forward", d, t.id);
                assert!(
                    sched.start[t.id] >= sched.end[d] - 1e-12,
                    "case {case} {spec:?}: task {} starts before dep {}",
                    t.id,
                    d
                );
            }
        }
        for s in streams_of(&plan) {
            let ids: Vec<usize> = plan.iter().filter(|t| t.stream == s).map(|t| t.id).collect();
            for w in ids.windows(2) {
                assert!(
                    sched.start[w[1]] >= sched.end[w[0]] - 1e-12,
                    "case {case} {spec:?}: stream {s:?} FIFO violated"
                );
            }
        }
    }
}

#[test]
fn pipeline_cross_device_ordering_holds() {
    // Rule 9b, pipeline: block computes run in block order even across
    // devices (the activation chain), every ownership change crosses the
    // link, and each block's U/C/O sit on its owner's streams.
    let mut rng = GaussianRng::new(67, 9);
    for case in 0..40 {
        let (n, steps, costs, policy) = rand_case(&mut rng);
        let devices = [2usize, 4][rng.next_below(2) as usize];
        let layout = [ShardLayout::Contiguous, ShardLayout::Cyclic][rng.next_below(2) as usize];
        let spec = ShardSpec::pipeline(devices, layout);
        let plan = build_sharded_plan(n, steps, policy, &spec);
        let (sched, _) = simulate(&plan, &costs, policy);

        for t in plan.iter().filter(|t| {
            matches!(t.kind, TaskKind::Upload | TaskKind::Compute | TaskKind::Offload)
        }) {
            if let Module::Block(i) = t.module {
                assert_eq!(
                    t.device(),
                    DeviceId(block_owner(layout, n, devices, i)),
                    "case {case}: block {i} {:?} on wrong device",
                    t.kind
                );
            }
        }
        // Compute of block i never starts before compute of block i-1 ends
        // (within a step) — the activation dependency crosses devices.
        for step in 0..steps {
            let c_of = |i: usize| {
                plan.iter().find(|t| {
                    t.kind == TaskKind::Compute && t.module == Module::Block(i) && t.step == step
                })
            };
            for i in 1..n {
                let (a, b) = (c_of(i - 1).unwrap(), c_of(i).unwrap());
                assert!(
                    sched.start[b.id] >= sched.end[a.id] - 1e-12,
                    "case {case}: C(W{i}) before C(W{}) ended",
                    i - 1
                );
                if block_owner(layout, n, devices, i) != block_owner(layout, n, devices, i - 1) {
                    let hop = plan.iter().find(|t| {
                        t.kind == TaskKind::ActivationXfer
                            && t.module == Module::Block(i)
                            && t.step == step
                    });
                    let hop = hop.unwrap_or_else(|| {
                        panic!("case {case}: no activation hop into block {i}")
                    });
                    assert_eq!(
                        hop.device(),
                        DeviceId(block_owner(layout, n, devices, i - 1)),
                        "case {case}: hop charged to the wrong sender"
                    );
                    assert!(b.deps.contains(&hop.id), "case {case}: C(W{i}) missing hop dep");
                }
            }
        }
    }
}

#[test]
fn dp_cross_device_ordering_holds() {
    // Rule 9b, data-parallel: the seed broadcast precedes every compute of
    // its step; the all-reduce follows every device's head; and no compute
    // of step j+1 starts before step j's all-reduce lands.
    let mut rng = GaussianRng::new(71, 10);
    for case in 0..40 {
        let (n, steps, costs, mut policy) = rand_case(&mut rng);
        // The DP engine contract requires the deferred update.
        policy.efficient_update = true;
        let devices = [2usize, 4][rng.next_below(2) as usize];
        let plan = build_sharded_plan(n, steps, policy, &ShardSpec::data_parallel(devices));
        let (sched, _) = simulate(&plan, &costs, policy);

        for step in 0..steps {
            let seed = plan
                .iter()
                .find(|t| t.kind == TaskKind::SeedBcast && t.step == step)
                .unwrap();
            let reduce = plan
                .iter()
                .find(|t| t.kind == TaskKind::GradReduce && t.step == step)
                .unwrap();
            let computes: Vec<&Task> = plan
                .iter()
                .filter(|t| t.kind == TaskKind::Compute && t.step == step)
                .collect();
            assert_eq!(
                computes.iter().filter(|t| t.module == Module::Head).count(),
                devices,
                "case {case}: every device runs its head"
            );
            for c in &computes {
                assert!(
                    sched.start[c.id] >= sched.end[seed.id] - 1e-12,
                    "case {case} step {step}: compute before seed broadcast"
                );
                assert!(
                    sched.start[reduce.id] + 1e-12
                        >= if c.module == Module::Head { sched.end[c.id] } else { 0.0 },
                    "case {case} step {step}: all-reduce before head"
                );
            }
            if step + 1 < steps {
                for c in plan
                    .iter()
                    .filter(|t| t.kind == TaskKind::Compute && t.step == step + 1)
                {
                    assert!(
                        sched.start[c.id] >= sched.end[reduce.id] - 1e-12,
                        "case {case}: step {} compute before step {step} all-reduce",
                        step + 1
                    );
                }
            }
        }
    }
}

// --- DP sim-shard bit-identity (rule 10) ------------------------------------

/// Host-only seed-synchronous ZO worker over a quadratic surrogate loss —
/// the same DpWorker contract the real engine implements, with no PJRT
/// dependency, so the K-invariance property runs everywhere.
struct ToyZoWorker {
    params: Vec<f32>,
    seed: u64,
    step: u64,
    eps: f32,
    lr: f32,
    /// (step, g); g is NaN until the all-reduce delivers it.
    pending: Option<(u64, f32)>,
    /// Fail (error out of `dp_dual_losses`) at this step — the atomicity
    /// test's injected mid-step worker death.
    fail_at: Option<u64>,
}

impl ToyZoWorker {
    fn new(seed: u64, dim: usize) -> Self {
        let mut params = vec![0.0f32; dim];
        GaussianRng::new(seed, u64::MAX).fill_gaussian(&mut params);
        Self { params, seed, step: 0, eps: 1e-3, lr: 1e-2, pending: None, fail_at: None }
    }

    fn z(&self, step: u64) -> Vec<f32> {
        let mut z = vec![0.0f32; self.params.len()];
        GaussianRng::new(self.seed, step).fill_gaussian(&mut z);
        z
    }

    /// Deterministic per-shard loss: squared distance to a target derived
    /// from the shard's tokens.
    fn loss(params: &[f32], shard: &[i32]) -> f32 {
        let mut acc = 0.0f32;
        for (j, &p) in params.iter().enumerate() {
            let tok = shard[j % shard.len()];
            let target = ((tok as f32) * 0.01).sin();
            let d = p - target;
            acc += d * d;
        }
        acc / params.len() as f32
    }
}

impl DpWorker for ToyZoWorker {
    fn dp_dual_losses(&mut self, shards: &[&[i32]]) -> anyhow::Result<Vec<(f32, f32)>> {
        if self.fail_at == Some(self.step) {
            anyhow::bail!("toy worker injected failure at step {}", self.step);
        }
        // Deferred update with the all-reduced gradient of the last step.
        if let Some((step, g)) = self.pending.take() {
            anyhow::ensure!(!g.is_nan(), "toy worker missing all-reduced g");
            let z = self.z(step);
            for (p, zi) in self.params.iter_mut().zip(&z) {
                *p -= self.lr * g * zi;
            }
        }
        let z = self.z(self.step);
        let mut out = Vec::with_capacity(shards.len());
        for ids in shards {
            let plus: Vec<f32> =
                self.params.iter().zip(&z).map(|(p, zi)| p + self.eps * zi).collect();
            let minus: Vec<f32> =
                self.params.iter().zip(&z).map(|(p, zi)| p - self.eps * zi).collect();
            out.push((Self::loss(&plus, ids), Self::loss(&minus, ids)));
        }
        self.pending = Some((self.step, f32::NAN));
        self.step += 1;
        Ok(out)
    }

    fn dp_extra_losses(&mut self, shards: &[&[i32]]) -> anyhow::Result<Vec<(f32, f32)>> {
        // Reassignment path: replay the parked step's perturbation without
        // touching the params or the parked deferred update.
        let (step, g) =
            self.pending.ok_or_else(|| anyhow::anyhow!("no parked step to replay"))?;
        anyhow::ensure!(g.is_nan(), "parked step already has its all-reduced g");
        let z = self.z(step);
        let mut out = Vec::with_capacity(shards.len());
        for ids in shards {
            let plus: Vec<f32> =
                self.params.iter().zip(&z).map(|(p, zi)| p + self.eps * zi).collect();
            let minus: Vec<f32> =
                self.params.iter().zip(&z).map(|(p, zi)| p - self.eps * zi).collect();
            out.push((Self::loss(&plus, ids), Self::loss(&minus, ids)));
        }
        Ok(out)
    }

    fn set_allreduced_g(&mut self, g: f32) {
        if let Some(p) = self.pending.as_mut() {
            p.1 = g;
        }
    }

    fn eps(&self) -> f32 {
        self.eps
    }
}

/// Run `steps` DP steps with `workers` workers over `shards` fixed shards;
/// returns (per-step losses, final params of worker 0).
fn toy_dp_trajectory(workers: usize, shards: usize, steps: usize) -> (Vec<(f32, f32)>, Vec<f32>) {
    let ws: Vec<ToyZoWorker> = (0..workers).map(|_| ToyZoWorker::new(90, 64)).collect();
    let mut dp = DpSimShard::new(ws, shards).unwrap();
    // Deterministic global batch stream: shards * 8 tokens per step.
    let mut data_rng = GaussianRng::new(4242, 0);
    let mut losses = Vec::new();
    for _ in 0..steps {
        let ids: Vec<i32> =
            (0..shards * 8).map(|_| data_rng.next_below(50_000) as i32).collect();
        let st = dp.train_step(&ids).unwrap();
        losses.push((st.loss_plus, st.loss_minus));
    }
    let params = dp.workers()[0].params.clone();
    (losses, params)
}

#[test]
fn dp_sim_shard_trajectory_is_bit_identical_for_any_worker_count() {
    // Rule 10: with the shard set fixed (S = 4), K ∈ {1, 2, 3, 4} workers
    // produce bit-identical loss trajectories and final parameters — the
    // "single-worker run" is K = 1 evaluating every shard itself.  K = 3 is
    // the uneven split (worker 0 owns two shards) the round-robin
    // assignment handles since divisibility was lifted.
    let steps = 12;
    let (l1, p1) = toy_dp_trajectory(1, 4, steps);
    for k in [2usize, 3, 4] {
        let (lk, pk) = toy_dp_trajectory(k, 4, steps);
        for (i, (a, b)) in l1.iter().zip(&lk).enumerate() {
            assert_eq!(a.0.to_bits(), b.0.to_bits(), "K={k} step {i} loss+");
            assert_eq!(a.1.to_bits(), b.1.to_bits(), "K={k} step {i} loss-");
        }
        let diffs =
            p1.iter().zip(&pk).filter(|(a, b)| a.to_bits() != b.to_bits()).count();
        assert_eq!(diffs, 0, "K={k}: {diffs}/{} params differ bitwise", p1.len());
    }
    // Sanity: the trajectory actually moves (the test is not vacuous).
    assert!(l1.first().unwrap().0 != l1.last().unwrap().0);
}

#[test]
fn dp_sim_shard_rejects_bad_configurations() {
    // Uneven splits are fine now (K ≤ S); only idle workers are rejected.
    let ws: Vec<ToyZoWorker> = (0..3).map(|_| ToyZoWorker::new(1, 8)).collect();
    assert!(DpSimShard::new(ws, 4).is_ok(), "4 shards on 3 workers is a valid uneven split");
    let ws: Vec<ToyZoWorker> = (0..5).map(|_| ToyZoWorker::new(1, 8)).collect();
    assert!(DpSimShard::new(ws, 4).is_err(), "5 workers on 4 shards would idle one");
    let ws: Vec<ToyZoWorker> = (0..2).map(|_| ToyZoWorker::new(1, 8)).collect();
    let mut dp = DpSimShard::new(ws, 2).unwrap();
    assert!(dp.train_step(&[1, 2, 3]).is_err(), "odd batch cannot split into 2 shards");
    assert!(DpSimShard::<ToyZoWorker>::new(Vec::new(), 2).is_err(), "no workers");
}

#[test]
fn dp_sim_shard_worker_failure_is_atomic_and_trajectory_preserving() {
    // Satellite (b): a worker erroring mid-step is removed and its shards
    // are re-evaluated on the survivors *before* any all-reduced gradient
    // is delivered, so the committed trajectory matches the healthy run
    // bit-for-bit and no replica sees a partial update.
    let steps = 10;
    let (healthy, p_h) = toy_dp_trajectory(1, 4, steps);

    let mut ws: Vec<ToyZoWorker> = (0..4).map(|_| ToyZoWorker::new(90, 64)).collect();
    ws[2].fail_at = Some(5);
    let mut dp = DpSimShard::new(ws, 4).unwrap();
    let mut data_rng = GaussianRng::new(4242, 0);
    let mut losses = Vec::new();
    for _ in 0..steps {
        let ids: Vec<i32> = (0..4 * 8).map(|_| data_rng.next_below(50_000) as i32).collect();
        let st = dp.train_step(&ids).unwrap();
        losses.push((st.loss_plus, st.loss_minus));
    }
    assert_eq!(dp.n_workers(), 3, "the failed worker was removed from the group");
    for (i, (a, b)) in healthy.iter().zip(&losses).enumerate() {
        assert_eq!(a.0.to_bits(), b.0.to_bits(), "step {i} loss+ diverged after the failure");
        assert_eq!(a.1.to_bits(), b.1.to_bits(), "step {i} loss- diverged after the failure");
    }
    let p_f = &dp.workers()[0].params;
    let diffs = p_h.iter().zip(p_f).filter(|(a, b)| a.to_bits() != b.to_bits()).count();
    assert_eq!(diffs, 0, "{diffs}/{} params differ from the healthy run", p_h.len());

    // Every worker failing at once is a loud error, not a partial update.
    let mut ws: Vec<ToyZoWorker> = (0..2).map(|_| ToyZoWorker::new(90, 64)).collect();
    ws[0].fail_at = Some(0);
    ws[1].fail_at = Some(0);
    let mut dp = DpSimShard::new(ws, 2).unwrap();
    assert!(dp.train_step(&[1i32; 16]).is_err(), "all-workers-dead must fail the step");
}

// --- pipeline microbatching / per-partition spills (rules 11-13) -------------

#[test]
fn microbatched_pipeline_keeps_fifo_and_hop_producer_ordering() {
    // Rule 11: across random policies (incl. three-tier), layouts, N and M,
    // (a) deps are backward and respected by the schedule, (b) every stream
    // executes in issue order, (c) each stream's compute slices for one
    // block appear in microbatch-index order, and (d) no activation hop
    // starts before its same-microbatch producer compute ends.
    let mut rng = GaussianRng::new(0x4D42, 11);
    for case in 0..60 {
        let (n, steps, costs, policy) = rand_case(&mut rng);
        let devices = [2usize, 4][rng.next_below(2) as usize];
        let layout = [ShardLayout::Contiguous, ShardLayout::Cyclic][rng.next_below(2) as usize];
        let m = [2usize, 3, 4, 8][rng.next_below(4) as usize];
        let spec = ShardSpec::pipeline_microbatched(devices, layout, m);
        let plan = build_sharded_plan(n, steps, policy, &spec);
        let (sched, _) = simulate(&plan, &costs, policy);

        // (a) dependency safety.
        for t in &plan {
            for &d in &t.deps {
                assert!(d < t.id, "case {case}: forward dep {} of {}", d, t.id);
                assert!(
                    sched.start[t.id] >= sched.end[d] - 1e-12,
                    "case {case}: task {} starts before dep {}",
                    t.id,
                    d
                );
            }
        }
        // (b) per-stream FIFO, every stream (incl. interconnect).
        for s in streams_of(&plan) {
            let ids: Vec<usize> = plan.iter().filter(|t| t.stream == s).map(|t| t.id).collect();
            for w in ids.windows(2) {
                assert!(
                    sched.start[w[1]] >= sched.end[w[0]] - 1e-12,
                    "case {case}: stream {s:?} FIFO violated"
                );
            }
        }
        // (c) per-microbatch index order within each (stream, module).
        for s in streams_of(&plan) {
            for i in 0..n {
                for step in 0..steps {
                    let idxs: Vec<usize> = plan
                        .iter()
                        .filter(|t| {
                            t.stream == s
                                && t.module == Module::Block(i)
                                && t.step == step
                                && t.kind == TaskKind::Compute
                        })
                        .map(|t| t.microbatch.expect("microbatched computes are tagged").index)
                        .collect();
                    let mut sorted = idxs.clone();
                    sorted.sort_unstable();
                    assert_eq!(idxs, sorted, "case {case}: slices of W{i} out of order");
                    if !idxs.is_empty() {
                        assert_eq!(idxs.len(), m, "case {case}: W{i} must have {m} slices");
                    }
                }
            }
        }
        // (d) hops follow their same-microbatch producers.
        for hop in plan.iter().filter(|t| t.kind == TaskKind::ActivationXfer) {
            let mb = hop.microbatch.expect("hops are per-microbatch");
            assert_eq!(mb.of, m);
            let producer = hop
                .deps
                .iter()
                .map(|&d| &plan[d])
                .find(|p| p.kind == TaskKind::Compute)
                .expect("hop must depend on a compute");
            assert_eq!(
                producer.microbatch.map(|p| p.index),
                Some(mb.index),
                "case {case}: hop {} fed by the wrong microbatch",
                hop.id
            );
            assert!(
                sched.start[hop.id] >= sched.end[producer.id] - 1e-12,
                "case {case}: hop {} before its producer {} ends",
                hop.id,
                producer.id
            );
        }
    }
}

/// Exactly-dyadic durations: every per-microbatch split (`x / M` for
/// M ∈ {2,4,8}) and every sum of slices is exact in f64, so the
/// monotonicity assertion is about the *scheduler*, not rounding.
struct DyadicCosts;

impl CostProvider for DyadicCosts {
    fn upload_s(&self) -> f64 {
        0.125
    }
    fn offload_s(&self) -> f64 {
        0.125
    }
    fn compute_s(&self, _m: Module) -> f64 {
        2.0
    }
    fn update_s(&self) -> f64 {
        0.25
    }
    fn link_activation_s(&self) -> f64 {
        0.03125
    }
    fn link_seed_s(&self) -> f64 {
        0.0
    }
    fn link_grad_s(&self) -> f64 {
        0.0078125
    }
}

#[test]
fn step_time_is_monotone_non_increasing_in_microbatches_when_compute_bound() {
    // Rule 12: finer microbatching only ever relaxes the schedule under an
    // ideal evenly-split cost provider (the trait default): each M-slice
    // group refines the M'-slice group for M' | M, so both makespan and
    // steady-state step time are non-increasing along 1 -> 2 -> 4 -> 8.
    for layout in [ShardLayout::Contiguous, ShardLayout::Cyclic] {
        for devices in [2usize, 4] {
            let policy = Policy::default();
            let mut last_makespan = f64::INFINITY;
            let mut last_step = f64::INFINITY;
            for m in [1usize, 2, 4, 8] {
                let spec = ShardSpec::pipeline_microbatched(devices, layout, m);
                let plan = build_sharded_plan(8, 3, policy, &spec);
                let (sched, _) = simulate(&plan, &DyadicCosts, policy);
                assert!(
                    sched.makespan <= last_makespan + 1e-9,
                    "{layout:?} N={devices}: M={m} makespan {} > previous {}",
                    sched.makespan,
                    last_makespan
                );
                assert!(
                    sched.steady_step_s <= last_step + 1e-9,
                    "{layout:?} N={devices}: M={m} step {} > previous {}",
                    sched.steady_step_s,
                    last_step
                );
                last_makespan = sched.makespan;
                last_step = sched.steady_step_s;
            }
        }
    }
    // And microbatching strictly helps somewhere: the cyclic 4-device
    // pipeline at M=8 must beat its M=1 makespan (boundaries at every
    // block leave a real bubble for M to fill).
    let policy = Policy::default();
    let m1 = {
        let plan =
            build_sharded_plan(8, 3, policy, &ShardSpec::pipeline(4, ShardLayout::Cyclic));
        simulate(&plan, &DyadicCosts, policy).0.makespan
    };
    let m8 = {
        let spec = ShardSpec::pipeline_microbatched(4, ShardLayout::Cyclic, 8);
        let plan = build_sharded_plan(8, 3, policy, &spec);
        simulate(&plan, &DyadicCosts, policy).0.makespan
    };
    assert!(m8 < m1 - 1e-9, "M=8 ({m8}) must strictly beat M=1 ({m1}) on the cyclic pipeline");
}

// --- heterogeneous clusters (rules 14-15) ------------------------------------

/// Per-device cost factors over a base provider: device `d`'s compute and
/// transfer times scale by `factor[d]` — heterogeneous pricing without the
/// paper-scale cost model (the device-less methods price device 0, exactly
/// like `costmodel::ClusterCost`).
struct HeteroCosts {
    base: RandCosts,
    factor: Vec<f64>,
}

impl CostProvider for HeteroCosts {
    fn upload_s(&self) -> f64 {
        self.base.up * self.factor[0]
    }
    fn offload_s(&self) -> f64 {
        self.base.off * self.factor[0]
    }
    fn compute_s(&self, _m: Module) -> f64 {
        self.base.comp * self.factor[0]
    }
    fn update_s(&self) -> f64 {
        self.base.upd * self.factor[0]
    }
    fn disk_read_s(&self) -> f64 {
        self.base.read * self.factor[0]
    }
    fn disk_write_s(&self) -> f64 {
        self.base.write * self.factor[0]
    }
    fn link_activation_s(&self) -> f64 {
        self.base.act
    }
    fn link_seed_s(&self) -> f64 {
        self.base.seed
    }
    fn link_grad_s(&self) -> f64 {
        self.base.grad
    }
    fn upload_s_on(&self, d: DeviceId) -> f64 {
        self.base.up * self.factor[d.0]
    }
    fn offload_s_on(&self, d: DeviceId) -> f64 {
        self.base.off * self.factor[d.0]
    }
    fn compute_s_on(&self, d: DeviceId, _m: Module) -> f64 {
        self.base.comp * self.factor[d.0]
    }
    fn update_s_on(&self, d: DeviceId) -> f64 {
        self.base.upd * self.factor[d.0]
    }
    fn disk_read_s_on(&self, d: DeviceId) -> f64 {
        self.base.read * self.factor[d.0]
    }
    fn disk_write_s_on(&self, d: DeviceId) -> f64 {
        self.base.write * self.factor[d.0]
    }
    fn compute_microbatch_s_on(&self, d: DeviceId, m: Module, _i: usize, of: usize) -> f64 {
        self.compute_s_on(d, m) / of.max(1) as f64
    }
}

#[test]
fn heterogeneous_pipeline_is_paced_by_the_slowest_device() {
    // Rule 14: device d's compute stream serially runs its per-step work
    // `steps` times inside the makespan, so steps × work_d lower-bounds the
    // makespan for EVERY device — in particular the slowest one.  And
    // slowing any single device (longer durations, same DAG) never shrinks
    // any task's end time, so the makespan is monotone in every device's
    // factor.
    let mut rng = GaussianRng::new(0x4845, 14);
    for case in 0..40 {
        let n = 4 + rng.next_below(9) as usize;
        let steps = 3;
        let devices = [2usize, 4][rng.next_below(2) as usize];
        let layout = [ShardLayout::Contiguous, ShardLayout::Cyclic][rng.next_below(2) as usize];
        let base = RandCosts {
            up: 0.01 + rng.next_uniform() * 0.5,
            off: 0.01 + rng.next_uniform() * 0.5,
            comp: 0.1 + rng.next_uniform() * 2.0,
            upd: 0.01 + rng.next_uniform() * 0.2,
            read: 0.01 + rng.next_uniform() * 0.5,
            write: 0.01 + rng.next_uniform() * 0.5,
            act: rng.next_uniform() * 0.1,
            seed: 0.0,
            grad: rng.next_uniform() * 0.05,
        };
        let factor: Vec<f64> = (0..devices).map(|_| 0.5 + rng.next_uniform() * 3.0).collect();
        let costs = HeteroCosts { base, factor: factor.clone() };
        let policy = Policy::default();
        let spec = ShardSpec::pipeline(devices, layout);
        let plan = build_sharded_plan(n, steps, policy, &spec);
        let (sched, _) = simulate(&plan, &costs, policy);

        let per = blocks_per_device(layout, n, devices);
        let head_dev = block_owner(layout, n, devices, n - 1);
        for d in 0..devices {
            let mut work =
                per[d].len() as f64 * costs.compute_s_on(DeviceId(d), Module::Block(0));
            if d == 0 {
                work += costs.compute_s_on(DeviceId(0), Module::Embed);
            }
            if d == head_dev {
                work += costs.compute_s_on(DeviceId(d), Module::Head);
            }
            assert!(
                sched.makespan >= steps as f64 * work - 1e-9,
                "case {case}: makespan {} below device {d}'s serial compute {}",
                sched.makespan,
                steps as f64 * work
            );
        }

        // Slow the slowest device further: the schedule may only get worse.
        let slowest = (0..devices)
            .max_by(|&a, &b| factor[a].total_cmp(&factor[b]))
            .unwrap();
        let mut slower = factor.clone();
        slower[slowest] *= 2.0;
        let costs2 = HeteroCosts { base: costs.base, factor: slower };
        let (sched2, _) = simulate(&plan, &costs2, policy);
        assert!(
            sched2.makespan >= sched.makespan - 1e-9,
            "case {case}: slowing device {slowest} shrank the makespan"
        );
    }
}

#[test]
fn per_host_budgets_bind_their_own_spill_sets_under_random_budgets() {
    // Rule 15: with genuinely distinct random per-host budgets, every
    // partition's plan fits its OWN host, and changing one host's budget
    // never perturbs a sibling's plan.
    let hw = Hardware::a100_pcie4();
    let w = Workload {
        shape: opt_by_name("OPT-30B").unwrap(),
        batch: 1,
        seq: 2048,
        wire: Codec::Fp16,
        compute: ComputeMode::Fp16,
    };
    let gb = 1u64 << 30;
    let mut rng = GaussianRng::new(0xB0D6, 15);
    for case in 0..30 {
        let devices = 2 + rng.next_below(3) as usize;
        // Budgets at least one window (4 slots) deep, spread widely enough
        // that some hosts spill and some do not.
        let budgets: Vec<MemoryBudget> = (0..devices)
            .map(|_| MemoryBudget {
                hbm: 18 * gb,
                dram: (6 + rng.next_below(40)) * gb,
                nvme: 2 << 40,
            })
            .collect();
        for layout in [ShardLayout::Contiguous, ShardLayout::Cyclic] {
            let plans = plan_three_tier_partitioned(
                &w,
                &budgets,
                layout,
                3,
                4,
                2,
                &hw,
                SpillPlacement::Trailing,
            );
            let per = blocks_per_device(layout, w.shape.n_layers, devices);
            for (d, p) in plans.iter().enumerate() {
                assert_eq!(
                    p.resident_blocks + p.spilled_blocks,
                    per[d].len(),
                    "case {case} {layout:?} device {d}"
                );
                assert!(
                    budgets[d].fits(&p.peaks),
                    "case {case} {layout:?} device {d}: {:?} must fit its own {:?}",
                    p.peaks,
                    budgets[d]
                );
            }
            // Raise one host's budget: only that host's plan may change,
            // and its spill count may only drop.
            let k = rng.next_below(devices as u64) as usize;
            let mut raised = budgets.clone();
            raised[k].dram += 8 * gb;
            let plans2 = plan_three_tier_partitioned(
                &w,
                &raised,
                layout,
                3,
                4,
                2,
                &hw,
                SpillPlacement::Trailing,
            );
            for d in 0..devices {
                if d == k {
                    assert!(
                        plans2[d].spilled_blocks <= plans[d].spilled_blocks,
                        "case {case} {layout:?}: more DRAM must never spill more"
                    );
                } else {
                    assert_eq!(
                        plans2[d].spilled_blocks, plans[d].spilled_blocks,
                        "case {case} {layout:?}: host {k}'s budget leaked into host {d}"
                    );
                    assert_eq!(plans2[d].dram_slots, plans[d].dram_slots);
                }
            }
        }
    }
}

#[test]
fn per_partition_spill_sets_are_disjoint_and_fit_their_hosts() {
    // Rule 13: plan per-partition spills for mixed host budgets, build the
    // plan, and check the spill sets never overlap across devices, live on
    // their owner's disk streams, and match the planner's counts; each
    // per-device plan fits its own host's budget.
    let hw = Hardware::a100_pcie4();
    let w = Workload {
        shape: opt_by_name("OPT-30B").unwrap(),
        batch: 1,
        seq: 2048,
        wire: Codec::Fp16,
        compute: ComputeMode::Fp16,
    };
    let gb = 1u64 << 30;
    let budgets = vec![
        MemoryBudget { hbm: 18 * gb, dram: 8 * gb, nvme: 2 << 40 },
        MemoryBudget { hbm: 18 * gb, dram: 10 * gb, nvme: 2 << 40 },
        MemoryBudget { hbm: 18 * gb, dram: 1024 * gb, nvme: 2 << 40 },
        MemoryBudget { hbm: 18 * gb, dram: 8 * gb, nvme: 2 << 40 },
    ];
    let devices = budgets.len();
    let n = w.shape.n_layers;
    let steps = 2;
    for layout in [ShardLayout::Contiguous, ShardLayout::Cyclic] {
        for placement in [SpillPlacement::Trailing, SpillPlacement::Interleaved] {
            let plans =
                plan_three_tier_partitioned(&w, &budgets, layout, 3, 4, 2, &hw, placement);
            let spilled: Vec<usize> = plans.iter().map(|p| p.spilled_blocks).collect();
            let per = blocks_per_device(layout, n, devices);
            for (d, p) in plans.iter().enumerate() {
                assert_eq!(p.resident_blocks + p.spilled_blocks, per[d].len());
                assert!(
                    budgets[d].fits(&p.peaks),
                    "{layout:?} {placement:?} device {d}: {:?} vs {:?}",
                    p.peaks,
                    budgets[d]
                );
            }
            assert!(spilled.iter().sum::<usize>() > 0, "the starved hosts must spill");
            assert_eq!(spilled[2], 0, "the 1 TB host must not spill");

            let policy = Policy {
                tiering: Tiering::ThreeTier,
                spilled: spilled.iter().sum(),
                dram_slots: 4,
                spill_placement: placement,
                ..Policy::default()
            };
            let spec = ShardSpec::pipeline(devices, layout);
            let plan = build_sharded_plan_spilled(n, steps, policy, &spec, Some(&spilled));
            // Spilled blocks, per reading device, step 0.
            let mut per_dev_reads: Vec<Vec<usize>> = vec![Vec::new(); devices];
            for t in plan.iter().filter(|t| t.kind == TaskKind::DiskRead && t.step == 0) {
                let i = match t.module {
                    Module::Block(i) => i,
                    _ => unreachable!("disk reads are per-block"),
                };
                per_dev_reads[t.device().0].push(i);
            }
            for (d, reads) in per_dev_reads.iter().enumerate() {
                assert_eq!(
                    reads.len(),
                    spilled[d],
                    "{layout:?} {placement:?} device {d}: spill count mismatch"
                );
                // Every spilled block is owned by the device that reads it.
                for &i in reads {
                    assert_eq!(
                        block_owner(layout, n, devices, i),
                        d,
                        "{layout:?}: device {d} reads foreign block {i}"
                    );
                }
            }
            // Pairwise disjoint across devices (ownership partitions the
            // blocks, so one shared block would be a builder bug).
            let mut all: Vec<usize> = per_dev_reads.iter().flatten().copied().collect();
            let total = all.len();
            all.sort_unstable();
            all.dedup();
            assert_eq!(all.len(), total, "{layout:?} {placement:?}: overlapping spill sets");
        }
    }
}

/// 16. `plan_three_tier` is monotone in the DDR budget — raising a host's
///     budget never grows its spill set — and an exact-fit budget
///     (`n_blocks * block_wire_bytes`) is window-free: everything resident,
///     no staging window reserved on top, and the planner's u128 sizing
///     math never wraps into a bogus all-resident answer.
#[test]
fn prop_three_tier_spill_monotone_in_budget_and_exact_fit_window_free() {
    let hw = Hardware::a100_pcie4();
    let wl = Workload {
        shape: opt_by_name("OPT-30B").unwrap(),
        batch: 1,
        seq: 2048,
        wire: Codec::Fp16,
        compute: ComputeMode::Fp16,
    };
    let gb = 1u64 << 30;
    let mut rng = GaussianRng::new(0x3717, 16);
    let plan = |dram: u64, slots: usize, dram_slots: usize, placement: SpillPlacement| {
        let budget = MemoryBudget { hbm: 18 * gb, dram, nvme: 2 << 40 };
        plan_three_tier(&wl, &budget, slots, dram_slots, 2, &hw, placement)
    };
    for case in 0..40 {
        let slots = 2 + rng.next_below(4) as usize;
        let dram_slots = 1 + rng.next_below(8) as usize;
        let placement = if rng.next_below(2) == 0 {
            SpillPlacement::Trailing
        } else {
            SpillPlacement::Interleaved
        };
        let b1 = gb * (1 + rng.next_below(96));
        let b2 = b1 + gb * rng.next_below(64);
        let lo = plan(b1, slots, dram_slots, placement);
        let hi = plan(b2, slots, dram_slots, placement);
        assert!(
            hi.spilled_blocks <= lo.spilled_blocks,
            "case {case}: raising the budget {b1} -> {b2} grew the spill set ({} -> {})",
            lo.spilled_blocks,
            hi.spilled_blocks
        );
        // Placement is total: every block is resident or spilled.
        assert_eq!(lo.resident_blocks + lo.spilled_blocks, wl.shape.n_layers);
        assert_eq!(hi.resident_blocks + hi.spilled_blocks, wl.shape.n_layers);
    }

    // Exact fit is window-free; one byte less must spill.
    let exact = wl.shape.n_layers as u64 * wl.block_wire_bytes();
    let p = plan(exact, 3, 4, SpillPlacement::Trailing);
    assert_eq!(p.spilled_blocks, 0, "exact-fit budget must keep every block resident");
    assert_eq!(p.dram_slots, 0, "an all-resident plan needs no staging window");
    assert_eq!(p.peaks.dram, exact, "exact fit must not reserve a window on top");
    let q = plan(exact - 1, 3, 4, SpillPlacement::Trailing);
    assert!(q.spilled_blocks > 0, "one byte under the exact fit must spill");
}

// --- static plan validation (`zo2 lint --plans` backbone, rules 17-19) --------

#[test]
fn validate_plan_accepts_every_randomly_built_plan() {
    // Rule 17: the static checker accepts every plan the builders produce —
    // 200 random policies (both tierings, random spills/windows/slots, both
    // ablations) across single-device, sharded and microbatched builders.
    // In debug builds the builders already self-check, so a false positive
    // would panic inside `build_sharded_plan`; this test additionally pins
    // the public entry point and the release-build behaviour.
    let mut rng = GaussianRng::new(0x11A7, 17);
    for case in 0..200 {
        let (n, steps, _costs, policy) = rand_case(&mut rng);
        let plan = build_plan(n, steps, policy);
        if let Err(errs) = validate_plan(&plan, &policy, None) {
            panic!("case {case}: build_plan plan rejected:\n{}", errs.join("\n"));
        }

        let spec = rand_spec(&mut rng);
        let plan = build_sharded_plan(n, steps, policy, &spec);
        if let Err(errs) = validate_plan(&plan, &policy, None) {
            panic!("case {case} {spec:?}: sharded plan rejected:\n{}", errs.join("\n"));
        }

        let devices = [2usize, 4][rng.next_below(2) as usize];
        let layout = [ShardLayout::Contiguous, ShardLayout::Cyclic][rng.next_below(2) as usize];
        let m = [2usize, 3, 4, 8][rng.next_below(4) as usize];
        let mspec = ShardSpec::pipeline_microbatched(devices, layout, m);
        let plan = build_sharded_plan(n, steps, policy, &mspec);
        if let Err(errs) = validate_plan(&plan, &policy, None) {
            panic!("case {case} {mspec:?}: microbatched plan rejected:\n{}", errs.join("\n"));
        }
    }
}

#[test]
fn validate_plan_accepts_the_golden_freeze_configurations() {
    // Rule 18: the configurations frozen by tests/sched_golden_v1.rs (the
    // single-device v1 plans and the M = 1 microbatched pipeline) must pass
    // the validator — the golden files prove the plans are byte-stable, the
    // validator proves they are *contract*-stable.
    for policy in [
        Policy::default(),
        Policy::naive(),
        Policy { reusable_mem: false, ..Policy::default() },
        Policy { efficient_update: false, ..Policy::default() },
        Policy::three_tier(3, 2),
        Policy { spill_placement: SpillPlacement::Interleaved, ..Policy::three_tier(5, 2) },
    ] {
        let plan = build_plan(12, 3, policy);
        assert!(
            validate_plan(&plan, &policy, None).is_ok(),
            "golden single-device config rejected: {policy:?}"
        );
        for devices in [1usize, 2, 4] {
            for layout in [ShardLayout::Contiguous, ShardLayout::Cyclic] {
                let spec = ShardSpec::pipeline_microbatched(devices, layout, 1);
                let plan = build_sharded_plan(12, 3, policy, &spec);
                assert!(
                    validate_plan(&plan, &policy, None).is_ok(),
                    "golden M=1 pipeline config rejected: {policy:?} N={devices} {layout:?}"
                );
            }
        }
    }

    // Per-partition tiers thread their own DRAM window depths through.
    let policy = Policy::three_tier(0, 4);
    let spec = ShardSpec::pipeline(2, ShardLayout::Contiguous);
    let tiers =
        [DeviceTier { spilled: 3, dram_slots: 1 }, DeviceTier { spilled: 2, dram_slots: 3 }];
    let plan = build_sharded_plan_tiered(12, 3, policy, &spec, Some(tiers.as_slice()), None);
    let dram: Vec<usize> = tiers.iter().map(|t| t.dram_slots).collect();
    assert!(validate_plan(&plan, &policy, Some(dram.as_slice())).is_ok());
}

#[test]
fn validate_plan_rejects_corrupted_plans() {
    // Rule 19: the checker is not vacuous — removing a dependency, moving a
    // task to the wrong stream, pointing a dep forward, or validating
    // against the wrong policy all produce findings.
    let policy = Policy::default();
    let good = build_plan(6, 2, policy);
    assert!(validate_plan(&good, &policy, None).is_ok());

    // (a) dropped dependencies on a mid-plan compute.
    let mut bad = good.clone();
    let idx = bad
        .iter()
        .position(|t| t.kind == TaskKind::Compute && t.module == Module::Block(2))
        .expect("block 2 computes somewhere");
    bad[idx].deps.clear();
    assert!(validate_plan(&bad, &policy, None).is_err(), "dropped deps must be caught");

    // (b) an upload mis-filed onto the compute stream.
    let mut bad = good.clone();
    let idx = bad.iter().position(|t| t.kind == TaskKind::Upload).expect("some upload");
    bad[idx].stream = StreamId::new(0, StreamKind::Compute);
    assert!(validate_plan(&bad, &policy, None).is_err(), "wrong stream must be caught");

    // (c) a forward dependency.
    let mut bad = good.clone();
    let last = bad.len() - 1;
    bad[0].deps = vec![last];
    assert!(validate_plan(&bad, &policy, None).is_err(), "forward dep must be caught");

    // (d) policy mismatch: a 4-slot plan checked against a 1-slot ring.
    let roomy = Policy { slots: 4, ..Policy::default() };
    let tight = Policy { slots: 1, ..roomy };
    let plan = build_plan(8, 2, roomy);
    assert!(validate_plan(&plan, &roomy, None).is_ok());
    assert!(
        validate_plan(&plan, &tight, None).is_err(),
        "slot-ring depth mismatch must be caught"
    );
}
