//! Property tests over the dynamic scheduler (no proptest in the offline
//! build — randomised cases come from the crate's own deterministic RNG).
//!
//! Invariants checked across random (n_blocks, steps, durations, policies),
//! including three-tier policies with random spill counts and DRAM windows:
//!  1. dependency safety: no task starts before any dependency ends;
//!  2. stream exclusivity: tasks on one stream never overlap (all five);
//!  3. overlap dominance: the dynamic schedule is never slower than naive;
//!  4. critical-path lower bounds hold;
//!  5. slot safety: at most `slots` blocks in flight at any instant;
//!  6. chain safety: spilled blocks run R(Wᵢ)→U(Wᵢ)→C(Wᵢ)→O(Wᵢ)→W(Wᵢ);
//!  7. window safety: at most `dram_slots` spilled buckets staged at once.

use zo2::rng::GaussianRng;
use zo2::sched::{
    build_plan, simulate, CostProvider, Module, Policy, Stream, TaskKind, Tiering, ALL_STREAMS,
};

struct RandCosts {
    up: f64,
    off: f64,
    comp: f64,
    upd: f64,
    read: f64,
    write: f64,
}

impl CostProvider for RandCosts {
    fn upload_s(&self) -> f64 {
        self.up
    }
    fn offload_s(&self) -> f64 {
        self.off
    }
    fn compute_s(&self, _m: Module) -> f64 {
        self.comp
    }
    fn update_s(&self) -> f64 {
        self.upd
    }
    fn disk_read_s(&self) -> f64 {
        self.read
    }
    fn disk_write_s(&self) -> f64 {
        self.write
    }
}

fn rand_case(rng: &mut GaussianRng) -> (usize, usize, RandCosts, Policy) {
    let n_blocks = 1 + rng.next_below(12) as usize;
    let steps = 1 + rng.next_below(4) as usize;
    let costs = RandCosts {
        up: 0.01 + rng.next_uniform() * 2.0,
        off: 0.01 + rng.next_uniform() * 2.0,
        comp: 0.01 + rng.next_uniform() * 4.0,
        upd: 0.01 + rng.next_uniform() * 0.5,
        read: 0.01 + rng.next_uniform() * 3.0,
        write: 0.01 + rng.next_uniform() * 3.0,
    };
    // Half the cases are three-tier with a random spill count and window.
    let three = rng.next_below(2) == 0;
    let policy = Policy {
        overlap: true,
        reusable_mem: rng.next_below(2) == 0,
        efficient_update: rng.next_below(2) == 0,
        slots: 1 + rng.next_below(4) as usize,
        tiering: if three { Tiering::ThreeTier } else { Tiering::TwoTier },
        spilled: if three { rng.next_below(1 + n_blocks as u64) as usize } else { 0 },
        dram_slots: 1 + rng.next_below(4) as usize,
        disk_batch: 1 + rng.next_below(4) as usize,
    };
    (n_blocks, steps, costs, policy)
}

#[test]
fn dependencies_and_stream_exclusivity_hold() {
    let mut rng = GaussianRng::new(2024, 0);
    for case in 0..60 {
        let (n, steps, costs, policy) = rand_case(&mut rng);
        let plan = build_plan(n, steps, policy);
        let (sched, _) = simulate(&plan, &costs, policy);

        for t in &plan {
            for &d in &t.deps {
                assert!(
                    sched.start[t.id] >= sched.end[d] - 1e-12,
                    "case {case}: task {} starts before dep {}",
                    t.id,
                    d
                );
            }
        }
        for s in ALL_STREAMS {
            let mut ivals: Vec<(f64, f64)> = plan
                .iter()
                .filter(|t| t.stream == s)
                .map(|t| (sched.start[t.id], sched.end[t.id]))
                .collect();
            ivals.sort_by(|a, b| a.0.total_cmp(&b.0));
            for w in ivals.windows(2) {
                assert!(w[1].0 >= w[0].1 - 1e-12, "case {case}: stream {s:?} overlap");
            }
        }
    }
}

#[test]
fn overlap_never_loses_to_naive() {
    let mut rng = GaussianRng::new(7, 1);
    for case in 0..40 {
        let (n, steps, costs, policy) = rand_case(&mut rng);
        let dynamic = Policy { overlap: true, ..policy };
        let naive = Policy { overlap: false, ..policy };
        let (sd, _) = simulate(&build_plan(n, steps, dynamic), &costs, dynamic);
        let (sn, _) = simulate(&build_plan(n, steps, naive), &costs, naive);
        assert!(
            sd.makespan <= sn.makespan + 1e-9,
            "case {case}: dynamic {} > naive {}",
            sd.makespan,
            sn.makespan
        );
    }
}

#[test]
fn critical_path_lower_bounds() {
    let mut rng = GaussianRng::new(99, 2);
    for _ in 0..40 {
        let (n, steps, costs, policy) = rand_case(&mut rng);
        let plan = build_plan(n, steps, policy);
        let (sched, _) = simulate(&plan, &costs, policy);
        // Compute stream total is a lower bound (it is one FIFO processor).
        let compute_total: f64 = plan
            .iter()
            .filter(|t| t.stream == Stream::Compute)
            .map(|t| match t.kind {
                TaskKind::Compute => costs.compute_s(t.module),
                TaskKind::Update => costs.update_s(),
                TaskKind::Upload => costs.upload_s() + if policy.reusable_mem { 0.0 } else { costs.malloc_s() },
                TaskKind::Offload => costs.offload_s(),
                TaskKind::DiskRead => costs.disk_read_s(),
                TaskKind::DiskWrite => costs.disk_write_s(),
            })
            .sum();
        assert!(sched.makespan >= compute_total - 1e-9);
        // Per-block chain U→C→O is a lower bound too (R+…+W for spilled).
        let chain = costs.upload_s() + costs.compute_s(Module::Block(0)) + costs.offload_s();
        assert!(sched.makespan >= chain - 1e-9);
        if policy.spilled > 0 && policy.tiering == Tiering::ThreeTier {
            let full_chain = costs.disk_read_s() + chain + costs.disk_write_s();
            assert!(sched.makespan >= full_chain - 1e-9, "five-task chain bound");
        }
    }
}

#[test]
fn slot_ring_bounds_in_flight_blocks() {
    let mut rng = GaussianRng::new(5, 3);
    for _ in 0..30 {
        let (n, steps, costs, policy) = rand_case(&mut rng);
        let plan = build_plan(n, steps, policy);
        let (sched, _) = simulate(&plan, &costs, policy);
        // A block occupies a slot from U start to O end.  Count max overlap
        // of those intervals; it must never exceed `slots`.
        let mut intervals: Vec<(f64, f64)> = Vec::new();
        for t in &plan {
            if t.kind == TaskKind::Upload {
                if let Module::Block(i) = t.module {
                    // find the matching offload of the same round
                    let off = plan.iter().find(|o| {
                        o.kind == TaskKind::Offload
                            && o.module == Module::Block(i)
                            && o.step == t.step
                            && o.id > t.id
                    });
                    if let Some(o) = off {
                        intervals.push((sched.start[t.id], sched.end[o.id]));
                    }
                }
            }
        }
        let peak = max_overlap(&intervals);
        assert!(
            peak as usize <= policy.slots.max(1),
            "{peak} blocks in flight with {} slots",
            policy.slots
        );
    }
}

/// Max number of simultaneously-open intervals.
fn max_overlap(intervals: &[(f64, f64)]) -> i32 {
    let mut events: Vec<(f64, i32)> = Vec::new();
    for (a, b) in intervals {
        events.push((*a, 1));
        events.push((*b, -1));
    }
    events.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.cmp(&y.1)));
    let mut cur = 0;
    let mut peak = 0;
    for (_, d) in events {
        cur += d;
        peak = peak.max(cur);
    }
    peak
}

#[test]
fn spilled_blocks_have_full_chain_in_order() {
    // Rule 6: for every spilled block round, R → U → C → O → W hold as
    // *scheduled times*, not just as declared deps.
    let mut rng = GaussianRng::new(41, 4);
    for case in 0..40 {
        let (n, steps, costs, mut policy) = rand_case(&mut rng);
        policy.tiering = Tiering::ThreeTier;
        policy.spilled = 1 + rng.next_below(n as u64) as usize;
        let plan = build_plan(n, steps, policy);
        let (sched, _) = simulate(&plan, &costs, policy);
        for r in plan.iter().filter(|t| t.kind == TaskKind::DiskRead) {
            let (i, step) = match r.module {
                Module::Block(i) => (i, r.step),
                _ => unreachable!("disk reads are per-block"),
            };
            // Find the chain members of the same round (first with id > r.id).
            let mut chain_end = sched.end[r.id];
            for kind in [TaskKind::Upload, TaskKind::Compute, TaskKind::Offload, TaskKind::DiskWrite] {
                let next = plan
                    .iter()
                    .find(|t| {
                        t.id > r.id
                            && t.step == step
                            && t.module == Module::Block(i)
                            && (t.kind == kind
                                || (kind == TaskKind::Compute && t.kind == TaskKind::Update))
                    })
                    .unwrap_or_else(|| panic!("case {case}: missing {kind:?} after R(W{i})"));
                assert!(
                    sched.start[next.id] >= chain_end - 1e-12,
                    "case {case}: {kind:?} of W{i} starts before previous chain task ends"
                );
                chain_end = sched.end[next.id];
            }
        }
    }
}

#[test]
fn per_stream_fifo_is_structural() {
    // Rule 2 strengthened: on every stream, declared FIFO deps force start
    // times to follow issue order exactly.
    let mut rng = GaussianRng::new(17, 5);
    for _ in 0..30 {
        let (n, steps, costs, policy) = rand_case(&mut rng);
        let plan = build_plan(n, steps, policy);
        let (sched, _) = simulate(&plan, &costs, policy);
        for s in ALL_STREAMS {
            let ids: Vec<usize> =
                plan.iter().filter(|t| t.stream == s).map(|t| t.id).collect();
            for w in ids.windows(2) {
                assert!(
                    sched.start[w[1]] >= sched.end[w[0]] - 1e-12,
                    "stream {s:?}: issue order {} -> {} violated",
                    w[0],
                    w[1]
                );
            }
        }
    }
}

#[test]
fn dram_window_never_exceeds_slot_count() {
    // Rule 7: a spilled bucket occupies a staging slot from R start to W
    // end; the max overlap of those intervals is bounded by dram_slots in
    // every simulated schedule.
    let mut rng = GaussianRng::new(23, 6);
    for case in 0..40 {
        let (n, steps, costs, mut policy) = rand_case(&mut rng);
        policy.tiering = Tiering::ThreeTier;
        policy.spilled = 1 + rng.next_below(n as u64) as usize;
        let plan = build_plan(n, steps, policy);
        let (sched, _) = simulate(&plan, &costs, policy);
        let mut intervals: Vec<(f64, f64)> = Vec::new();
        for r in plan.iter().filter(|t| t.kind == TaskKind::DiskRead) {
            let w = plan
                .iter()
                .find(|t| {
                    t.id > r.id && t.kind == TaskKind::DiskWrite && t.module == r.module
                        && t.step == r.step
                })
                .expect("every R has a matching W");
            intervals.push((sched.start[r.id], sched.end[w.id]));
        }
        let peak = max_overlap(&intervals);
        assert!(
            peak as usize <= policy.dram_slots.max(1),
            "case {case}: {peak} staged buckets with a {}-slot DRAM window",
            policy.dram_slots
        );
    }
}

#[test]
fn efficient_update_halves_interconnect_busy_time() {
    let costs = RandCosts { up: 1.0, off: 1.0, comp: 0.5, upd: 0.05, read: 0.2, write: 0.2 };
    let base = Policy::default();
    let noeff = Policy { efficient_update: false, ..base };
    let (s1, _) = simulate(&build_plan(8, 2, base), &costs, base);
    let (s2, _) = simulate(&build_plan(8, 2, noeff), &costs, noeff);
    let b1 = s1.busy.get("upload").unwrap() + s1.busy.get("offload").unwrap();
    let b2 = s2.busy.get("upload").unwrap() + s2.busy.get("offload").unwrap();
    assert!((b2 / b1 - 2.0).abs() < 0.2, "transfer busy should ~double: {b1} -> {b2}");
}
