//! End-to-end training at tiny scale (real PJRT execution):
//! the whole three-layer stack must compose and the loss must move.

use zo2::coordinator::{train, EngineKind, TrainConfig};
use zo2::data::{table3_tasks, SyntheticCorpus};
use zo2::precision::Codec;
use zo2::runtime::Runtime;
use zo2::zo::{RunMode, Zo2Engine, Zo2Options, ZoConfig};

/// Skip (with a message) when the PJRT artifacts are absent, instead of
/// erroring: these tests need `make artifacts` (or `$ZO2_ARTIFACTS`).
macro_rules! require_artifacts {
    () => {
        if !zo2::artifacts_available("tiny") {
            eprintln!(
                "SKIP {}: no PJRT artifacts for config `tiny` (run `make artifacts` \
                 or set $ZO2_ARTIFACTS)",
                module_path!()
            );
            return;
        }
    };
}

#[test]
fn zo2_loss_decreases_on_synthetic_corpus() {
    require_artifacts!();
    let cfg = TrainConfig {
        config_name: "tiny".into(),
        steps: 60,
        zo: ZoConfig { lr: 2e-3, eps: 1e-2, seed: 7 },
        engine: EngineKind::Zo2,
        wire: Codec::F32,
        run_mode: RunMode::Overlapped,
        log_every: 1000,
        ..TrainConfig::default()
    };
    let report = train(&cfg, false).unwrap();
    let first = report.losses.points[..10].iter().map(|p| p.1).sum::<f64>() / 10.0;
    let last = report.losses.tail_mean(10);
    assert!(
        last < first - 0.01,
        "loss should fall: first10 {first:.4} -> last10 {last:.4}"
    );
    assert!(report.final_eval_loss.is_finite());
    assert!(report.tokens_per_s > 0.0);
    assert!(report.transfer_bytes > 0, "blocks must have crossed the interconnect");
}

#[test]
fn eval_is_deterministic_and_flush_idempotent() {
    require_artifacts!();
    let rt = Runtime::load_config("tiny").unwrap();
    let m = rt.manifest();
    let mut corpus = SyntheticCorpus::new(m.config.vocab, 3);
    let ids = corpus.sample(m.config.batch, m.config.seq_len).ids;
    let mut e = Zo2Engine::new(rt, ZoConfig::default(), Zo2Options::default()).unwrap();
    e.train_step(&ids).unwrap();
    let (l1, g1) = e.eval(&ids).unwrap(); // flushes
    let (l2, g2) = e.eval(&ids).unwrap(); // second flush is a no-op
    assert_eq!(l1.to_bits(), l2.to_bits());
    assert_eq!(g1.len(), g2.len());
    assert!(g1.iter().zip(&g2).all(|(a, b)| a.to_bits() == b.to_bits()));
}

#[test]
fn classification_pipeline_runs_and_scores() {
    require_artifacts!();
    // Table-3 style task plumbing: train briefly on one synthetic task and
    // verify the accuracy metric is computed from last-position logits.
    let rt = Runtime::load_config("tiny").unwrap();
    let m = rt.manifest();
    let (b, t, v) = (m.config.batch, m.config.seq_len, m.config.vocab);
    let mut tasks = table3_tasks(v, 11);
    let task = &mut tasks[0];
    let mut e = Zo2Engine::new(rt, ZoConfig { lr: 1e-3, eps: 1e-2, seed: 5 }, Zo2Options::default())
        .unwrap();
    for _ in 0..5 {
        let (batch, _) = task.sample(b, t);
        e.train_step(&batch.ids).unwrap();
    }
    let (batch, labels) = task.sample(b, t);
    let (_, logits) = e.eval(&batch.ids).unwrap();
    let acc = task.accuracy(&logits, v, &labels);
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn device_capacity_is_enforced() {
    require_artifacts!();
    // A capacity too small for even the resident modules must fail fast.
    let rt = Runtime::load_config("tiny").unwrap();
    let err = Zo2Engine::new(
        rt,
        ZoConfig::default(),
        Zo2Options { device_capacity: 1024, ..Default::default() },
    );
    assert!(err.is_err(), "1KB device must OOM");
}

#[test]
fn transfer_accounting_matches_wire_format() {
    require_artifacts!();
    let steps = 3usize;
    for (wire, bytes_per_el) in [(Codec::F32, 4u64), (Codec::Bf16, 2), (Codec::Fp8E4M3, 1)] {
        let rt = Runtime::load_config("tiny").unwrap();
        let m = rt.manifest();
        let n_blocks = m.config.n_layers as u64;
        let block_sz = m.block.size as u64;
        let mut corpus = SyntheticCorpus::new(m.config.vocab, 3);
        let ids = corpus.sample(m.config.batch, m.config.seq_len).ids;
        let mut e = Zo2Engine::new(
            rt,
            ZoConfig::default(),
            Zo2Options { wire, run_mode: RunMode::Sequential, ..Default::default() },
        )
        .unwrap();
        for _ in 0..steps {
            e.train_step(&ids).unwrap();
        }
        let tr = e.transfers.lock().unwrap();
        let expect = steps as u64 * n_blocks * block_sz * bytes_per_el;
        assert_eq!(tr.h2d.bytes, expect, "{wire:?} h2d");
        assert_eq!(tr.d2h.bytes, expect, "{wire:?} d2h");
        assert_eq!(tr.h2d.ops, steps as u64 * n_blocks);
    }
}
